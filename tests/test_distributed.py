"""Sharding rules, collectives, and a real (reduced-device) dry-run."""

import os
import subprocess
import sys
import textwrap

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.distributed import sharding as shd

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


class FakeMesh:
    """Shape-only stand-in so rule tests don't touch jax devices."""

    def __init__(self, **shape):
        self.shape = shape
        self.axis_names = tuple(shape)


MESH = FakeMesh(data=16, model=16)
POD = FakeMesh(pod=2, data=16, model=16)


def spec(axes, shape, kind="act", mesh=MESH):
    return shd._resolve(tuple(axes), tuple(shape), mesh,
                        shd.RULE_SETS["default"][0 if kind == "act" else 1])


# ------------------------------------------------------------------ rules
def test_batch_folds_over_pod_and_data():
    assert spec(("batch", "seq"), (256, 4096), mesh=POD) == \
        P(("pod", "data"), "model")


def test_heads_shard_when_divisible():
    s = spec(("batch", "seq", "heads", "head_dim"), (32, 4096, 48, 128))
    assert s == P("data", None, "model", None)


def test_seq_parallel_fallback_when_heads_dont_divide():
    """llama4: 40 heads % 16 != 0 -> seq takes the model axis."""
    s = spec(("batch", "seq", "heads", "head_dim"), (32, 4096, 40, 128))
    assert s == P("data", "model", None, None)


def test_kv_cache_seq_sharding_fallback():
    # starcoder2 decode: kv=4 can't shard -> kv_seq takes model
    s = spec(("batch", "kv_seq", "kvheads", "head_dim"),
             (128, 32768, 4, 128))
    assert s == P("data", "model", None, None)
    # qwen2moe: kv=16 shards -> kv_seq stays unsharded
    s = spec(("batch", "kv_seq", "kvheads", "head_dim"),
             (128, 32768, 16, 128))
    assert s == P("data", None, "model", None)


def test_expert_ep_full_sharding():
    """llama4 experts: (expert->model, ffn->data) — no FSDP dim left."""
    s = spec(("layers", "expert", "expert_out", "expert_in"),
             (24, 128, 8192, 5120), kind="param")
    assert s == P(None, "model", "data", None)


def test_expert_fallback_per_expert_tp():
    """qwen2-moe: 60 experts don't divide -> expert_out falls to model."""
    s = spec(("layers", "expert", "expert_out", "expert_in"),
             (24, 60, 1408, 2048), kind="param")
    assert s == P(None, None, "model", None)


def test_param_fsdp_embed_on_data():
    s = spec(("mlp", "embed"), (24576, 6144), kind="param")
    assert s == P("model", "data")


def test_param_specs_tree():
    cfg = configs.get_smoke("starcoder2_15b")
    from repro.models import transformer as T

    shapes = jax.eval_shape(
        lambda: T.init_params(jax.random.PRNGKey(0), cfg))
    specs = shd.param_specs(shapes, MESH)
    flat = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat) == len(jax.tree.leaves(shapes))


def test_constrain_noop_without_mesh():
    import jax.numpy as jnp

    x = jnp.ones((4, 4))
    assert shd.constrain(x, "batch", "embed") is x


# ------------------------------------------------------------ collectives
def test_int8_all_gather_subprocess():
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.distributed import compat
        from repro.distributed.collectives import int8_all_gather
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        x = jax.random.normal(jax.random.PRNGKey(0), (8, 6)) * 0.3
        spec = P("data", "model")
        xs = jax.device_put(x, NamedSharding(mesh, spec))
        def f(x):
            g = int8_all_gather(x, mesh, spec, axis="data")
            return g, jnp.sum(g * jnp.arange(48.0).reshape(8, 6))
        with compat.set_mesh(mesh):
            out = jax.jit(lambda x: f(x)[0])(xs)
            err = float(jnp.max(jnp.abs(out - x)))
            assert err <= float(jnp.max(jnp.abs(x))) / 127 + 1e-6, err
            gr = jax.jit(jax.grad(lambda x: f(x)[1]))(xs)
            assert bool(jnp.allclose(gr, jnp.arange(48.0).reshape(8, 6)))
            hlo = jax.jit(lambda x: f(x)[0]).lower(xs).compile().as_text()
            assert any("all-gather(" in l and "= s8" in l
                       for l in hlo.splitlines()), "no int8 wire format"
        print("INT8_AG_OK")
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env={**os.environ, "PYTHONPATH": SRC})
    assert "INT8_AG_OK" in r.stdout, r.stdout + r.stderr


# ------------------------------------------------------------ dry-run
def test_dryrun_parse_collectives():
    sys.path.insert(0, SRC)
    hlo = """
  %ag = bf16[8,128]{1,0} all-gather(%p0), replica_groups=[2,4]<=[8]
  %ar.1 = f32[64]{0} all-reduce(%x), to_apply=%sum
  %a2a = f32[2,4,8]{2,1,0} all-to-all(%y), dimensions={0}
"""
    # the module sets XLA_FLAGS at import (its documented contract);
    # jax is already initialized here, so only the env var needs restoring
    jax.devices()
    prev = os.environ.get("XLA_FLAGS")
    try:
        from repro.launch import dryrun
    finally:
        if prev is None:
            os.environ.pop("XLA_FLAGS", None)
        else:
            os.environ["XLA_FLAGS"] = prev

    c = dryrun.parse_collectives(hlo)
    assert c["all-gather"]["count"] == 1
    assert c["all-gather"]["bytes"] == 8 * 128 * 2
    assert c["all-reduce"]["bytes"] == 64 * 4
    assert c["all-to-all"]["count"] == 1


@pytest.mark.slow
def test_dryrun_single_cell_subprocess():
    """Full dry-run machinery on one real cell (the production 16x16 mesh
    with 512 forced host devices) — the same path --all uses."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
        from repro.launch import dryrun
        res = dryrun.run_cell("gemma_2b", "decode_32k", multi_pod=False,
                              quant="msgemm", verbose=False)
        assert res["status"] == "ok", res
        assert res["memory"]["total_per_device_gb"] < 16.0
        print("DRYRUN_CELL_OK", res["memory"]["total_per_device_gb"])
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=560,
                       env={**os.environ, "PYTHONPATH": SRC})
    assert "DRYRUN_CELL_OK" in r.stdout, r.stdout[-2000:] + r.stderr[-2000:]


def test_all_dryrun_artifacts_ok():
    """Every recorded dry-run artifact is ok/skipped (none failed)."""
    import glob
    import json

    files = glob.glob(os.path.join(
        os.path.dirname(__file__), "..", "benchmarks", "results", "dryrun",
        "*.json"))
    if not files:
        pytest.skip("dry-run artifacts not generated")
    bad = []
    for f in files:
        with open(f) as fh:
            r = json.load(fh)
        if r["status"] == "failed":
            bad.append(r["cell"])
    assert not bad, bad
