"""Pallas kernel validation: interpret-mode allclose vs pure-jnp oracles,
swept over shapes, d, scale blocks, tile sizes, and dtypes.

Bit-exactness strategy: on *exactly representable* inputs (integer-valued
activations, power-of-two scales) every sum/product in the kernels is
exact, so the reordered-grid kernel, the legacy kernel, the tile-replay
oracle, AND the plain consume oracle must agree bit for bit — any logic
error (wrong scale block, index, or tile edge) still changes the integer
result, while FMA/fusion codegen ulps (which differ legitimately between
separately compiled XLA programs) vanish.  Generic float inputs are
checked with few-ulp tolerances on top.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import packing, scales as scales_mod
from repro.core.epilogue import Epilogue
from repro.kernels import ops, ref
from repro.kernels.msgemm import msgemm_pallas
from repro.kernels.int4_matmul import int4_matmul_pallas


def _mk(rng, m, k, b, scale_block):
    codes = jnp.asarray(rng.integers(0, 16, size=(m, k)), jnp.uint8)
    x = jnp.asarray(rng.standard_normal((k, b)), jnp.float32)
    sc = jnp.asarray(
        np.abs(rng.standard_normal((m, -(-k // scale_block)))) + 0.1,
        jnp.float32)
    return codes, x, sc


def _mk_exact(rng, m, k, b, scale_block):
    """Inputs on which all kernel arithmetic is exact (see module doc)."""
    codes = jnp.asarray(rng.integers(0, 16, size=(m, k)), jnp.uint8)
    x = jnp.asarray(rng.integers(-4, 5, size=(k, b)), jnp.float32)
    sc = jnp.asarray(2.0 ** rng.integers(-2, 3,
                                         size=(m, -(-k // scale_block))),
                     jnp.float32)
    return codes, x, sc


# ------------------------------------------------------------- msgemm kernel
@pytest.mark.parametrize("d", [1, 2, 3])
@pytest.mark.parametrize("m,k,b", [(8, 12, 4), (16, 36, 8), (32, 72, 16),
                                   (128, 144, 128)])
def test_msgemm_kernel_vs_ref(d, m, k, b):
    scale_block = 6 * d  # multiple of every d in the sweep
    if k % scale_block:
        k = -(-k // scale_block) * scale_block
    rng = np.random.default_rng(d * 1000 + m + k + b)
    codes, x, sc = _mk(rng, m, k, b, scale_block)
    got = ops.msgemm(codes, x, d, scales=sc, scale_block=scale_block)
    idx = packing.pack_indices(codes, d)
    want = ref.msgemm_ref(idx, x, sc, d=d, scale_block=scale_block)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=5e-4)


@pytest.mark.parametrize("tm,tj,tb", [(8, 2, 8), (16, 4, 16), (8, 8, 32)])
def test_msgemm_kernel_tiling_invariance(tm, tj, tb):
    d, scale_block = 2, 4
    m, kc, b = 16, 8, 32
    rng = np.random.default_rng(42)
    codes, x, sc = _mk(rng, m, kc * d, b, scale_block)
    idx = packing.pack_indices(codes, d)
    got = msgemm_pallas(idx, x, sc, d=d, scale_block=scale_block,
                        tm=tm, tj=tj, tb=tb, interpret=True)
    want = ref.msgemm_ref(idx, x, sc, d=d, scale_block=scale_block)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_msgemm_kernel_unpadded_shapes():
    """Wrapper pads ragged (m, k, b) transparently."""
    d, scale_block = 3, 6
    rng = np.random.default_rng(7)
    codes, x, sc = _mk(rng, 13, 30, 5, scale_block)
    got = ops.msgemm(codes, x, d, scales=sc, scale_block=scale_block)
    idx = packing.pack_indices(codes, d)
    want = ref.msgemm_ref(idx, x, sc, d=d, scale_block=scale_block)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_msgemm_kernel_matches_quantized_dense():
    """End-to-end: quantize real weights, kernel == dequant @ x."""
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.standard_normal((32, 72)), jnp.float32)
    qt = scales_mod.quantize_int4(w, block=12)
    x = jnp.asarray(rng.standard_normal((72, 16)), jnp.float32)
    got = ops.msgemm(qt.codes, x, 3, scales=qt.scales, scale_block=12)
    want = scales_mod.dequantize(qt) @ x
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_msgemm_kernel_vector_x():
    rng = np.random.default_rng(1)
    codes, x, sc = _mk(rng, 8, 12, 1, 6)
    got = ops.msgemm(codes, x[:, 0], 3, scales=sc, scale_block=6)
    assert got.shape == (8,)
    want = ref.msgemm_ref(packing.pack_indices(codes, 3), x, sc,
                          d=3, scale_block=6)[:, 0]
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


# ------------------------------------------ reordered grid / VMEM acc stripe
# (d, scale_block, m, k, b): sweeps LUT depth, scale-block sizes, ragged
# everything, non-power-of-two kc (k/d = 43, 35), and b=1 decode shapes.
BITEXACT_SHAPES = [
    (1, 6, 13, 30, 5),
    (2, 4, 16, 24, 8),
    (2, 8, 40, 104, 3),     # kc = 52
    (3, 6, 32, 90, 16),     # kc = 30
    (3, 12, 64, 258, 1),    # kc = 86 (non-pow2), b = 1 decode
    (3, 9, 7, 129, 2),      # kc = 43 (prime), ragged m
    (4, 8, 24, 140, 4),     # d = 4, kc = 35
]


@pytest.mark.parametrize("d,scale_block,m,k,b", BITEXACT_SHAPES)
def test_msgemm_bitexact_sweep(d, scale_block, m, k, b):
    """Reordered-grid + scratch-accumulator kernel is bit-identical to the
    legacy kernel, to the tile-replay oracle, and to kernels/ref.py's
    consume oracle on exactly representable inputs."""
    rng = np.random.default_rng(d * 101 + m + k + b)
    codes, x, sc = _mk_exact(rng, m, k, b, scale_block)
    tm, tj, tb = ops.msgemm_tiles(m, -(-k // d), b, d, scale_block)
    new = np.asarray(ops.msgemm(codes, x, d, scales=sc,
                                scale_block=scale_block))
    old = np.asarray(ops.msgemm(codes, x, d, scales=sc,
                                scale_block=scale_block, acc_in_vmem=False))
    tiled = np.asarray(ref.msgemm_tiled_ref(
        codes, x, sc, d=d, scale_block=scale_block, tm=tm, tj=tj, tb=tb))
    plain = np.asarray(ref.msgemm_ref(packing.pack_indices(codes, d), x, sc,
                                      d=d, scale_block=scale_block))
    np.testing.assert_array_equal(new, old)
    np.testing.assert_array_equal(new, tiled)
    np.testing.assert_array_equal(new, plain)


@pytest.mark.parametrize("d,scale_block,m,k,b", BITEXACT_SHAPES[:5])
def test_msgemm_new_vs_legacy_float(d, scale_block, m, k, b):
    """Generic floats: reordered kernel vs legacy within a few ulps (the
    two are the same op order; residual diffs are XLA codegen FMA
    contraction, not algorithm)."""
    rng = np.random.default_rng(d * 77 + m + k + b)
    codes, x, sc = _mk(rng, m, k, b, scale_block)
    new = ops.msgemm(codes, x, d, scales=sc, scale_block=scale_block)
    old = ops.msgemm(codes, x, d, scales=sc, scale_block=scale_block,
                     acc_in_vmem=False)
    np.testing.assert_allclose(new, old, rtol=3e-6, atol=3e-5)


EPILOGUES = [
    Epilogue(),
    Epilogue(act="relu"),
    Epilogue(act="gelu"),
    Epilogue(act="silu"),
    Epilogue(bias=True),
    Epilogue(act="relu", bias=True),
    Epilogue(residual=True),
    Epilogue(act="gelu", bias=True, residual=True),
    Epilogue(act="silu", residual=True, out_dtype="bfloat16"),
    Epilogue(out_dtype="bfloat16"),
]


@pytest.mark.parametrize("ep", EPILOGUES, ids=lambda e: (
    f"{e.act}{'+b' if e.bias else ''}{'+r' if e.residual else ''}"
    f"{'+' + e.out_dtype if e.out_dtype else ''}"))
def test_msgemm_epilogue_variants(ep):
    """Every epilogue variant: fused output equals the tile-replay oracle
    bit for bit on exact inputs (identity/relu/bias/residual/cast are
    exact ops there; gelu/silu get few-ulp tolerance), and fused equals
    the legacy-kernel + unfused-epilogue composition."""
    d, scale_block, m, k, b = 3, 6, 32, 90, 5
    rng = np.random.default_rng(EPILOGUES.index(ep))  # reproducible seed
    codes, x, sc = _mk_exact(rng, m, k, b, scale_block)
    bias = (jnp.asarray(rng.integers(-3, 4, size=m), jnp.float32)
            if ep.bias else None)
    res = (jnp.asarray(rng.integers(-3, 4, size=(m, b)), jnp.float32)
           if ep.residual else None)
    tm, tj, tb = ops.msgemm_tiles(m, -(-k // d), b, d, scale_block)
    fused = ops.msgemm(codes, x, d, scales=sc, scale_block=scale_block,
                       epilogue=ep, bias=bias, residual=res)
    tiled = ref.msgemm_tiled_ref(codes, x, sc, d=d, scale_block=scale_block,
                                 tm=tm, tj=tj, tb=tb, epilogue=ep,
                                 bias=bias, residual=res)
    unfused = ops.msgemm(codes, x, d, scales=sc, scale_block=scale_block,
                         acc_in_vmem=False, epilogue=ep, bias=bias,
                         residual=res)
    want_dtype = jnp.dtype(ep.out_dtype) if ep.out_dtype else jnp.float32
    assert fused.dtype == want_dtype and unfused.dtype == want_dtype
    f32 = lambda a: np.asarray(a, np.float32)
    if ep.act in ("none", "relu"):  # exact ops end to end
        np.testing.assert_array_equal(f32(fused), f32(tiled))
        np.testing.assert_array_equal(f32(fused), f32(unfused))
    else:  # transcendental activations: same math, codegen-ulp tolerance
        np.testing.assert_allclose(f32(fused), f32(tiled),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(f32(fused), f32(unfused),
                                   rtol=1e-5, atol=1e-5)


def test_msgemm_identity_epilogue_is_noop():
    """Epilogue() must change nothing vs a no-epilogue call (bitwise,
    generic floats — same compiled program modulo the epilogue arg)."""
    rng = np.random.default_rng(11)
    codes, x, sc = _mk(rng, 16, 36, 8, 6)
    plain = ops.msgemm(codes, x, 3, scales=sc, scale_block=6)
    with_ep = ops.msgemm(codes, x, 3, scales=sc, scale_block=6,
                         epilogue=Epilogue())
    np.testing.assert_array_equal(np.asarray(plain), np.asarray(with_ep))


def test_int4_bitexact_and_epilogue():
    """int4 kernel: fused-acc path vs legacy bitwise on exact inputs;
    fused epilogue equals unfused composition."""
    m, k, b, scale_block = 24, 64, 6, 8
    rng = np.random.default_rng(3)
    codes, x, sc = _mk_exact(rng, m, k, b, scale_block)
    u8 = packing.pack_storage(codes)
    new = np.asarray(ops.int4_matmul(u8, sc, x, scale_block=scale_block))
    old = np.asarray(ops.int4_matmul(u8, sc, x, scale_block=scale_block,
                                     acc_in_vmem=False))
    np.testing.assert_array_equal(new, old)
    want = np.asarray(ref.int4_matmul_ref(u8, sc, x,
                                          scale_block=scale_block))
    np.testing.assert_array_equal(new, want)
    ep = Epilogue(act="relu", bias=True, residual=True)
    bias = jnp.asarray(rng.integers(-3, 4, size=m), jnp.float32)
    res = jnp.asarray(rng.integers(-3, 4, size=(m, b)), jnp.float32)
    fused = ops.int4_matmul(u8, sc, x, scale_block=scale_block, epilogue=ep,
                            bias=bias, residual=res)
    unfused = ops.int4_matmul(u8, sc, x, scale_block=scale_block,
                              acc_in_vmem=False, epilogue=ep, bias=bias,
                              residual=res)
    np.testing.assert_array_equal(np.asarray(fused), np.asarray(unfused))


def test_msgemm_large_m_stripe_fallback(monkeypatch):
    """When the VMEM acc+out stripe cannot fit even at the tb floor
    (vocab-sized lm-head m), the wrapper falls back to the legacy
    accumulation instead of allocating an unbuildable scratch — and the
    planner plans it that way up front."""
    from repro import dispatch

    assert ops.acc_stripe_fits(2048, 256, 8)
    assert not ops.acc_stripe_fits(2_000_000, 512, 8)
    # a fused residual keeps its own (mp, tb) block resident — counted
    assert ops.acc_stripe_fits(8192, 256, 128)
    assert not ops.acc_stripe_fits(8192, 256, 128, residual=True)
    spec = __import__("repro.core.spec", fromlist=["QuantSpec"]).QuantSpec(
        mode="msgemm", d=3, scale_block=12)
    hp = dispatch.heuristic_plan(spec, 3, 2_000_000, 768, 4,
                                 "msgemm_pallas", dispatch.ExecPolicy())
    assert hp.acc_in_vmem is False
    # shrink the budget so a small shape exercises the wrapper fallback
    monkeypatch.setattr(ops, "ACC_BUDGET", 64)
    rng = np.random.default_rng(9)
    codes, x, sc = _mk_exact(rng, 32, 36, 4, 6)
    got = np.asarray(ops.msgemm(codes, x, 3, scales=sc, scale_block=6))
    want = np.asarray(ref.msgemm_ref(packing.pack_indices(codes, 3), x, sc,
                                     d=3, scale_block=6))
    np.testing.assert_array_equal(got, want)


def test_msgemm_explicit_tiles_skip_heuristic(monkeypatch):
    """An ExecPlan that names all three tiles must not pay the heuristic
    (the old wrapper recomputed it on every traced call)."""
    called = []
    orig = ops._pick_tiles
    monkeypatch.setattr(ops, "_pick_tiles",
                        lambda *a, **kw: called.append(a) or orig(*a, **kw))
    rng = np.random.default_rng(21)
    codes, x, sc = _mk(rng, 16, 24, 8, 4)
    ops.msgemm(codes, x, 2, scales=sc, scale_block=4, tm=8, tj=4, tb=8)
    assert called == []
    ops.msgemm(codes, x, 2, scales=sc, scale_block=4, tm=8, tj=4)  # tb missing
    assert len(called) == 1
    i4 = []
    orig4 = ops.int4_tiles
    monkeypatch.setattr(ops, "int4_tiles",
                        lambda *a: i4.append(a) or orig4(*a))
    u8 = packing.pack_storage(codes)
    ops.int4_matmul(u8, sc, x, scale_block=4, tm=8, tk=8, tb=8)
    assert i4 == []


# ----------------------------------------------------------- tile heuristic
@pytest.mark.parametrize("d,scale_block", [(1, 6), (2, 4), (3, 12)])
@pytest.mark.parametrize("kc", [7, 13, 29, 43, 86, 129, 255])
def test_pick_tiles_odd_kc_no_overshoot(d, scale_block, kc):
    """The tj-growth loop must never overshoot a non-power-of-two kc:
    tj stays <= kc (no dead padded chunk columns beyond one tile), stays
    a multiple of scale_block//d (§3.3 factored scales), and the LUT
    tile fits the VMEM budget whenever growth ran at all."""
    cpb = scale_block // d
    tm, tj, tb = ops.msgemm_tiles(64, kc, 16, d, scale_block)
    assert tj % cpb == 0
    assert tj <= max(kc, cpb), (tj, kc)  # never grown past kc
    if tj > cpb:  # growth only happens inside the budget...
        assert 16**d * tj * tb * 4 <= ops.VMEM_BUDGET
        assert kc % tj == 0  # ...and only into exact divisors of kc
    # the padded chunk count never exceeds one tile of slack
    assert -(-kc // tj) * tj - kc < tj


def test_pick_tiles_power_of_two_unchanged():
    """Power-of-two kc keeps the old growth behavior: doubling from
    cpb=4 until the d=3 LUT tile hits the VMEM budget at tj=32."""
    tm, tj, tb = ops.msgemm_tiles(64, 64, 16, 3, 12)
    assert (tj, tb) == (32, 16) and 64 % tj == 0


def test_pick_tiles_decode_presets():
    """Decode shapes (small b, large m): tb is the actual batch rounded
    to 8 — never padded to 128 — and the freed LUT budget grows tj
    further than the 128-wide batch tile would allow."""
    m, kc = 4096, 1024
    for b in (1, 4, 8):
        tm, tj, tb = ops.msgemm_tiles(m, kc, b, 3, 12)
        assert tb == 8, (b, tb)
        assert tm == 512  # decode branch: taller m tiles
    _, tj_decode, _ = ops.msgemm_tiles(m, kc, 4, 3, 12)
    _, tj_wide, _ = ops.msgemm_tiles(m, kc, 512, 3, 12)
    assert tj_decode > tj_wide  # narrow stripe -> bigger LUT tile
    # vocab-sized m: no tb can hold the stripe -> the shape will run the
    # legacy kernel (no stripe), so tb stays batch-wide instead of being
    # pointlessly shrunk to the floor
    tm, tj, tb = ops.msgemm_tiles(200_000, 256, 512, 2, 4)
    assert tb == 128 and not ops.acc_stripe_fits(200_000, tm, 8)
    # large-but-holdable m shrinks tb until the stripe fits
    tm, tj, tb = ops.msgemm_tiles(16384, 256, 512, 2, 4)
    assert tb < 128 and ops.acc_stripe_fits(16384, tm, tb)
    # moderate m keeps a comfortable stripe without shrinking
    tm, tj, tb = ops.msgemm_tiles(2048, 256, 512, 2, 4)
    assert tb == 128 and 2048 * tb * 8 <= ops.ACC_BUDGET


def test_msgemm_explicit_tiles_match_heuristic():
    """ExecPlan-provided tiles produce the same result as the heuristic."""
    d, scale_block = 2, 4
    rng = np.random.default_rng(21)
    codes, x, sc = _mk(rng, 16, 24, 8, scale_block)
    want = ops.msgemm(codes, x, d, scales=sc, scale_block=scale_block)
    got = ops.msgemm(codes, x, d, scales=sc, scale_block=scale_block,
                     tm=8, tj=4, tb=8)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


# ------------------------------------------------------- int4_matmul kernel
@pytest.mark.parametrize("m,k,b", [(8, 32, 4), (16, 64, 8), (64, 128, 128),
                                   (13, 40, 5)])
def test_int4_matmul_vs_ref(m, k, b):
    scale_block = 8
    rng = np.random.default_rng(m * 7 + k + b)
    codes, x, sc = _mk(rng, m, k, b, scale_block)
    u8 = packing.pack_storage(codes)
    got = ops.int4_matmul(u8, sc, x, scale_block=scale_block)
    want = ref.int4_matmul_ref(u8, sc, x, scale_block=scale_block)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_int4_vs_msgemm_same_result():
    """Both kernels compute the same quantized GeMM (different algorithms)."""
    rng = np.random.default_rng(5)
    scale_block = 12
    codes, x, sc = _mk(rng, 24, 48, 8, scale_block)
    y1 = ops.msgemm(codes, x, 3, scales=sc, scale_block=scale_block)
    y2 = ops.int4_matmul(packing.pack_storage(codes), sc, x,
                         scale_block=scale_block)
    np.testing.assert_allclose(y1, y2, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_kernel_activation_dtypes(dtype):
    rng = np.random.default_rng(3)
    codes, x, sc = _mk(rng, 16, 24, 8, 12)
    got = ops.msgemm(codes, x.astype(dtype), 3, scales=sc, scale_block=12)
    want = ref.msgemm_ref(packing.pack_indices(codes, 3),
                          x.astype(dtype).astype(jnp.float32), sc,
                          d=3, scale_block=12)
    tol = 1e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol)


# ------------------------------------------------------- flash attention
@pytest.mark.parametrize("Sq,Skv,H,Hk,dh", [(32, 32, 4, 4, 16),
                                            (48, 48, 4, 2, 16),
                                            (40, 40, 2, 1, 8)])
@pytest.mark.parametrize("kwargs", [dict(causal=True),
                                    dict(causal=True, window=16),
                                    dict(causal=True, softcap=30.0)])
def test_flash_attention_vs_ref(Sq, Skv, H, Hk, dh, kwargs):
    B = 2
    key = jax.random.PRNGKey(Sq + H)
    q = jax.random.normal(key, (B, Sq, H, dh))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, Skv, Hk, dh))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, Skv, Hk, dh))
    got = ops.flash_attention(q, k, v, **kwargs)
    kr, vr = (jnp.repeat(t, H // Hk, axis=2) for t in (k, v))
    flat = lambda t: jnp.moveaxis(t, 2, 1).reshape(B * H, t.shape[1], dh)
    want = ref.flash_attention_ref(flat(q), flat(kr), flat(vr), **kwargs)
    want = jnp.moveaxis(want.reshape(B, H, Sq, dh), 1, 2)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_flash_attention_gqa_native_layout():
    """The kernel consumes k/v in their native (B, Hk, Skv, dh) layout —
    no H//Hk-fold jnp.repeat materialization — and still matches the
    broadcast reference for every group size including MQA."""
    from repro.kernels.flash_attention import flash_attention_pallas

    B, Sq, dh = 2, 32, 16
    for H, Hk in [(4, 4), (4, 2), (4, 1), (6, 3)]:
        q = jax.random.normal(jax.random.PRNGKey(H), (B, H, Sq, dh))
        k = jax.random.normal(jax.random.PRNGKey(H + 1), (B, Hk, Sq, dh))
        v = jax.random.normal(jax.random.PRNGKey(H + 2), (B, Hk, Sq, dh))
        got = flash_attention_pallas(q, k, v, causal=True, tq=16, tk=16,
                                     interpret=True)
        kr = jnp.repeat(k, H // Hk, axis=1)
        vr = jnp.repeat(v, H // Hk, axis=1)
        want = ref.flash_attention_ref(
            q.reshape(B * H, Sq, dh), kr.reshape(B * H, Sq, dh),
            vr.reshape(B * H, Sq, dh), causal=True).reshape(B, H, Sq, dh)
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_flash_attention_matches_model_sdpa():
    """Kernel agrees with the model's jnp attention path end to end."""
    from repro.models import layers
    from repro.models.config import ModelConfig

    cfg = ModelConfig(num_layers=1, d_model=32, num_heads=4, num_kv_heads=2,
                      d_ff=64, vocab_size=97)
    B, S, dh = 2, 24, cfg.head_dim
    q = jax.random.normal(jax.random.PRNGKey(0), (B, S, 4, dh))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, 2, dh))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, 2, dh))
    want = layers._sdpa(cfg, q, k, v, layers.causal_mask(S, S))
    got = ops.flash_attention(q, k, v, causal=True).reshape(B, S, -1)
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)
