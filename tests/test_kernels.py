"""Pallas kernel validation: interpret-mode allclose vs pure-jnp oracles,
swept over shapes, d, scale blocks, tile sizes, and dtypes."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import packing, scales as scales_mod
from repro.kernels import ops, ref
from repro.kernels.msgemm import msgemm_pallas
from repro.kernels.int4_matmul import int4_matmul_pallas


def _mk(rng, m, k, b, scale_block):
    codes = jnp.asarray(rng.integers(0, 16, size=(m, k)), jnp.uint8)
    x = jnp.asarray(rng.standard_normal((k, b)), jnp.float32)
    sc = jnp.asarray(
        np.abs(rng.standard_normal((m, -(-k // scale_block)))) + 0.1,
        jnp.float32)
    return codes, x, sc


# ------------------------------------------------------------- msgemm kernel
@pytest.mark.parametrize("d", [1, 2, 3])
@pytest.mark.parametrize("m,k,b", [(8, 12, 4), (16, 36, 8), (32, 72, 16),
                                   (128, 144, 128)])
def test_msgemm_kernel_vs_ref(d, m, k, b):
    scale_block = 6 * d  # multiple of every d in the sweep
    if k % scale_block:
        k = -(-k // scale_block) * scale_block
    rng = np.random.default_rng(d * 1000 + m + k + b)
    codes, x, sc = _mk(rng, m, k, b, scale_block)
    got = ops.msgemm(codes, x, d, scales=sc, scale_block=scale_block)
    idx = packing.pack_indices(codes, d)
    want = ref.msgemm_ref(idx, x, sc, d=d, scale_block=scale_block)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=5e-4)


@pytest.mark.parametrize("tm,tj,tb", [(8, 2, 8), (16, 4, 16), (8, 8, 32)])
def test_msgemm_kernel_tiling_invariance(tm, tj, tb):
    d, scale_block = 2, 4
    m, kc, b = 16, 8, 32
    rng = np.random.default_rng(42)
    codes, x, sc = _mk(rng, m, kc * d, b, scale_block)
    idx = packing.pack_indices(codes, d)
    got = msgemm_pallas(idx, x, sc, d=d, scale_block=scale_block,
                        tm=tm, tj=tj, tb=tb, interpret=True)
    want = ref.msgemm_ref(idx, x, sc, d=d, scale_block=scale_block)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_msgemm_kernel_unpadded_shapes():
    """Wrapper pads ragged (m, k, b) transparently."""
    d, scale_block = 3, 6
    rng = np.random.default_rng(7)
    codes, x, sc = _mk(rng, 13, 30, 5, scale_block)
    got = ops.msgemm(codes, x, d, scales=sc, scale_block=scale_block)
    idx = packing.pack_indices(codes, d)
    want = ref.msgemm_ref(idx, x, sc, d=d, scale_block=scale_block)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_msgemm_kernel_matches_quantized_dense():
    """End-to-end: quantize real weights, kernel == dequant @ x."""
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.standard_normal((32, 72)), jnp.float32)
    qt = scales_mod.quantize_int4(w, block=12)
    x = jnp.asarray(rng.standard_normal((72, 16)), jnp.float32)
    got = ops.msgemm(qt.codes, x, 3, scales=qt.scales, scale_block=12)
    want = scales_mod.dequantize(qt) @ x
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_msgemm_kernel_vector_x():
    rng = np.random.default_rng(1)
    codes, x, sc = _mk(rng, 8, 12, 1, 6)
    got = ops.msgemm(codes, x[:, 0], 3, scales=sc, scale_block=6)
    assert got.shape == (8,)
    want = ref.msgemm_ref(packing.pack_indices(codes, 3), x, sc,
                          d=3, scale_block=6)[:, 0]
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


# ----------------------------------------------------------- tile heuristic
@pytest.mark.parametrize("d,scale_block", [(1, 6), (2, 4), (3, 12)])
@pytest.mark.parametrize("kc", [7, 13, 29, 43, 86, 129, 255])
def test_pick_tiles_odd_kc_no_overshoot(d, scale_block, kc):
    """The tj-growth loop must never overshoot a non-power-of-two kc:
    tj stays <= kc (no dead padded chunk columns beyond one tile), stays
    a multiple of scale_block//d (§3.3 factored scales), and the LUT
    tile fits the VMEM budget whenever growth ran at all."""
    cpb = scale_block // d
    tm, tj, tb = ops.msgemm_tiles(64, kc, 16, d, scale_block)
    assert tj % cpb == 0
    assert tj <= max(kc, cpb), (tj, kc)  # never grown past kc
    if tj > cpb:  # growth only happens inside the budget...
        assert 16**d * tj * tb * 4 <= ops.VMEM_BUDGET
        assert kc % tj == 0  # ...and only into exact divisors of kc
    # the padded chunk count never exceeds one tile of slack
    assert -(-kc // tj) * tj - kc < tj


def test_pick_tiles_power_of_two_unchanged():
    """Power-of-two kc keeps the old growth behavior: doubling from
    cpb=4 until the d=3 LUT tile hits the VMEM budget at tj=32."""
    tm, tj, tb = ops.msgemm_tiles(64, 64, 16, 3, 12)
    assert (tj, tb) == (32, 16) and 64 % tj == 0


def test_msgemm_explicit_tiles_match_heuristic():
    """ExecPlan-provided tiles produce the same result as the heuristic."""
    d, scale_block = 2, 4
    rng = np.random.default_rng(21)
    codes, x, sc = _mk(rng, 16, 24, 8, scale_block)
    want = ops.msgemm(codes, x, d, scales=sc, scale_block=scale_block)
    got = ops.msgemm(codes, x, d, scales=sc, scale_block=scale_block,
                     tm=8, tj=4, tb=8)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


# ------------------------------------------------------- int4_matmul kernel
@pytest.mark.parametrize("m,k,b", [(8, 32, 4), (16, 64, 8), (64, 128, 128),
                                   (13, 40, 5)])
def test_int4_matmul_vs_ref(m, k, b):
    scale_block = 8
    rng = np.random.default_rng(m * 7 + k + b)
    codes, x, sc = _mk(rng, m, k, b, scale_block)
    u8 = packing.pack_storage(codes)
    got = ops.int4_matmul(u8, sc, x, scale_block=scale_block)
    want = ref.int4_matmul_ref(u8, sc, x, scale_block=scale_block)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_int4_vs_msgemm_same_result():
    """Both kernels compute the same quantized GeMM (different algorithms)."""
    rng = np.random.default_rng(5)
    scale_block = 12
    codes, x, sc = _mk(rng, 24, 48, 8, scale_block)
    y1 = ops.msgemm(codes, x, 3, scales=sc, scale_block=scale_block)
    y2 = ops.int4_matmul(packing.pack_storage(codes), sc, x,
                         scale_block=scale_block)
    np.testing.assert_allclose(y1, y2, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_kernel_activation_dtypes(dtype):
    rng = np.random.default_rng(3)
    codes, x, sc = _mk(rng, 16, 24, 8, 12)
    got = ops.msgemm(codes, x.astype(dtype), 3, scales=sc, scale_block=12)
    want = ref.msgemm_ref(packing.pack_indices(codes, 3),
                          x.astype(dtype).astype(jnp.float32), sc,
                          d=3, scale_block=12)
    tol = 1e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol)


# ------------------------------------------------------- flash attention
@pytest.mark.parametrize("Sq,Skv,H,Hk,dh", [(32, 32, 4, 4, 16),
                                            (48, 48, 4, 2, 16),
                                            (40, 40, 2, 1, 8)])
@pytest.mark.parametrize("kwargs", [dict(causal=True),
                                    dict(causal=True, window=16),
                                    dict(causal=True, softcap=30.0)])
def test_flash_attention_vs_ref(Sq, Skv, H, Hk, dh, kwargs):
    B = 2
    key = jax.random.PRNGKey(Sq + H)
    q = jax.random.normal(key, (B, Sq, H, dh))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, Skv, Hk, dh))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, Skv, Hk, dh))
    got = ops.flash_attention(q, k, v, **kwargs)
    kr, vr = (jnp.repeat(t, H // Hk, axis=2) for t in (k, v))
    flat = lambda t: jnp.moveaxis(t, 2, 1).reshape(B * H, t.shape[1], dh)
    want = ref.flash_attention_ref(flat(q), flat(kr), flat(vr), **kwargs)
    want = jnp.moveaxis(want.reshape(B, H, Sq, dh), 1, 2)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_flash_attention_matches_model_sdpa():
    """Kernel agrees with the model's jnp attention path end to end."""
    from repro.models import layers
    from repro.models.config import ModelConfig

    cfg = ModelConfig(num_layers=1, d_model=32, num_heads=4, num_kv_heads=2,
                      d_ff=64, vocab_size=97)
    B, S, dh = 2, 24, cfg.head_dim
    q = jax.random.normal(jax.random.PRNGKey(0), (B, S, 4, dh))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, 2, dh))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, 2, dh))
    want = layers._sdpa(cfg, q, k, v, layers.causal_mask(S, S))
    got = ops.flash_attention(q, k, v, causal=True).reshape(B, S, -1)
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)
