"""Perf-model tests: calibration fit/round-trip, model-guided autotune
pruning (winner parity with the full sweep under a deterministic clock),
the measured-vs-predicted regression sentinel, and the interpret-tagged
timing rows the calibration partitions on."""

import json

import pytest

from repro import dispatch, obs
from repro.core.spec import QuantSpec
from repro.dispatch import autotune as at
from repro.obs import perfmodel as pm

MS2 = QuantSpec(mode="msgemm", d=2, scale_block=12, storage="packed_idx")

# ground-truth constants for the synthetic clock: every "measured" time
# is exactly the model evaluated at these, so fits recover them and the
# model's ranking provably matches the timing ranking
SYNTH = {"launch_s": 1e-4, "step_s": 1e-5, "produce_s_per_flop": 2e-9,
         "consume_s_per_op": 1e-9, "hbm_s_per_byte": 5e-10}
SYNTH_CAL = pm.Calibration(device="cpu", interpret=True,
                           constants={"*": SYNTH},
                           fit={"n_samples": 99}, created_unix=1.0)


@pytest.fixture(autouse=True)
def _isolated(tmp_path, monkeypatch):
    """Fresh plan cache + no ambient calibration for every test."""
    monkeypatch.setenv("REPRO_PLAN_CACHE", str(tmp_path / "plans.json"))
    monkeypatch.setenv("REPRO_CALIBRATION", str(tmp_path / "calib.json"))
    dispatch.set_cache_path(None)
    obs.registry().reset()
    yield
    dispatch.set_cache_path(None)


def _synthetic_sample(backend, m, k, b, *, d=2, tm=None, tj=None, tb=None,
                      chunk=1, acc=True, scale=1.0, device="cpu",
                      interpret=True):
    feats = pm.features(backend, "msgemm", d, 12, m, k, b, tm=tm, tj=tj,
                        tb=tb, consume_chunk=chunk, acc_in_vmem=acc)
    t = sum(SYNTH[n] * feats[n] for n in pm.CONSTANT_NAMES) * scale
    return pm.Sample(backend=backend, mode="msgemm", d=d, scale_block=12,
                     m=m, k=k, b=b, measured_s=t, device=device,
                     interpret=interpret, tm=tm, tj=tj, tb=tb,
                     consume_chunk=chunk, acc_in_vmem=acc,
                     source=f"synth:m{m}k{k}b{b}")


def _synthetic_grid():
    out = []
    for backend in ("msgemm_pallas", "msgemm_jnp"):
        for (m, k, b) in [(16, 24, 8), (64, 24, 8), (16, 48, 8),
                          (128, 96, 16), (256, 24, 64)]:
            for chunk in (1, 2):
                out.append(_synthetic_sample(backend, m, k, b, chunk=chunk))
    return out


def _patch_synthetic_clock(monkeypatch):
    """Replace autotune's wall-clock candidate timer with the exact
    SYNTH model — deterministic, so winner comparisons can't flake."""
    calls = []

    def fake_time(be, spec, p, params, x, k, reps):
        b = x.shape[0]
        m = params["scales"].shape[0]
        d = dispatch.plan_d(spec, m, k)
        feats = pm.features(be.name, spec.mode, d, spec.scale_block,
                            m, k, b, tm=p.tm, tj=p.tj, tb=p.tb,
                            consume_chunk=p.consume_chunk,
                            acc_in_vmem=p.acc_in_vmem)
        calls.append(p)
        return sum(SYNTH[n] * feats[n] for n in pm.CONSTANT_NAMES)

    monkeypatch.setattr(at, "_time_plan", fake_time)
    return calls


# ------------------------------------------------------------- features
def test_features_amortization_visible_to_model():
    """The model must price the legacy grid's per-m-tile re-produce —
    that asymmetry is what lets it rank acc_in_vmem correctly."""
    new = pm.features("msgemm_pallas", "msgemm", 3, 12, 2048, 768, 8,
                      tm=256, tj=128, tb=8, acc_in_vmem=True)
    legacy = pm.features("msgemm_pallas", "msgemm", 3, 12, 2048, 768, 8,
                         tm=256, tj=128, tb=8, acc_in_vmem=False)
    assert legacy["produce_s_per_flop"] == pytest.approx(
        8 * new["produce_s_per_flop"])  # nm = 2048/256
    assert legacy["hbm_s_per_byte"] > new["hbm_s_per_byte"]
    assert new["step_s"] == legacy["step_s"]


def test_predict_uncalibrated_falls_back():
    plan = dispatch.ExecPlan(backend="msgemm_pallas")
    c = pm.predict(plan, MS2, 64, 24, 8)
    assert c.t_total_s > 0 and not c.calibrated
    c2 = pm.predict(plan, MS2, 64, 24, 8, calib=SYNTH_CAL)
    assert c2.calibrated and c2.t_total_s > 0


# ---------------------------------------------------------- calibration
def test_calibration_fit_recovers_synthetic_constants():
    cal = pm.fit(_synthetic_grid(), device="cpu", interpret=True)
    assert cal.fit["n_samples"] == len(_synthetic_grid())
    # exact linear data -> near-exact fit
    assert cal.fit["max_abs_rel_err"] < 1e-6
    for s in _synthetic_grid()[:4]:
        assert pm.predict_sample(s, cal).t_total_s == pytest.approx(
            s.measured_s, rel=1e-6)


def test_calibration_roundtrip_identical_predictions(tmp_path):
    cal = pm.fit(_synthetic_grid(), device="cpu", interpret=True)
    path = tmp_path / "c.json"
    cal.save(path)
    assert pm.validate_calibration_file(path) == []
    loaded = pm.load_calibration(path, device="cpu", interpret=True)
    assert loaded is not None
    for s in _synthetic_grid():
        assert (pm.predict_sample(s, loaded).t_total_s
                == pm.predict_sample(s, cal).t_total_s)  # bitwise


def test_calibration_partition_and_staleness(tmp_path):
    cal = pm.fit(_synthetic_grid(), device="cpu", interpret=True)
    path = tmp_path / "c.json"
    cal.save(path)
    # wrong partition -> stale -> None
    assert pm.load_calibration(path, device="tpu", interpret=True) is None
    assert pm.load_calibration(path, device="cpu", interpret=False) is None
    assert pm.load_calibration(path, device="cpu", interpret=True)
    # corrupt / wrong version -> None + validator errors
    doc = json.loads(path.read_text())
    doc["version"] = 99
    path.write_text(json.dumps(doc))
    assert pm.load_calibration(path, device="cpu", interpret=True) is None
    assert pm.validate_calibration_file(path)
    path.write_text("{not json")
    assert pm.load_calibration(path, device="cpu", interpret=True) is None


def test_fit_requires_samples_in_partition():
    wrong = [_synthetic_sample("msgemm_jnp", 16, 24, 8, interpret=False)
             for _ in range(5)]
    with pytest.raises(ValueError, match="needs >= 3 samples"):
        pm.fit(wrong, device="cpu", interpret=True)


# ----------------------------------------------- model-guided autotune
def test_model_guided_matches_full_search_winner(monkeypatch, tmp_path):
    """On a shape grid, the model-guided sweep (<= MODEL_TOP_K measured)
    picks the same winner as the full sweep, and the full winner is
    always inside the model's predicted top-k — under a deterministic
    synthetic clock equal to the calibration's own ground truth."""
    device = at.registry.device_kind()
    cal = pm.Calibration(device=device, interpret=True,
                         constants={"*": SYNTH},
                         fit={"n_samples": 99}, created_unix=1.0)
    cal.save(tmp_path / "calib.json")
    calls = _patch_synthetic_clock(monkeypatch)
    # shapes chosen so the candidate grid is strictly larger than
    # MODEL_TOP_K (tiny shapes collapse to <= 3 candidates and the
    # model-guided path correctly degenerates to the full sweep)
    grid = [(256, 24, 64), (128, 48, 16), (64, 48, 8)]
    for m, k, b in grid:
        calls.clear()
        dispatch.set_cache_path(tmp_path / "full.json")
        full = at.autotune(MS2, m, k, b, "msgemm_pallas", interpret=True,
                           search="full")
        n_full = len(calls)
        calls.clear()
        dispatch.set_cache_path(tmp_path / "model.json")
        guided = at.autotune(MS2, m, k, b, "msgemm_pallas",
                             interpret=True, search="model")
        assert len(calls) <= at.MODEL_TOP_K < n_full
        assert guided == full
        # full winner sits inside the model's predicted top-k
        d = dispatch.plan_d(MS2, m, k)
        cands = at.candidate_plans(MS2, d, m, k, b, "msgemm_pallas",
                                   True)
        base = dispatch.heuristic_plan(
            MS2, d, m, k, b, "msgemm_pallas",
            dispatch.ExecPolicy(interpret=True))
        kept = at._model_prune(cands, MS2, d, m, k, b, "msgemm_pallas",
                               base, cal)
        assert dataclasses_replace_nosrc(full) in {
            dataclasses_replace_nosrc(p) for p in kept}
    snap = obs.registry().snapshot()
    pruned = [c for c in snap["counters"]
              if c["name"] == "dispatch_autotune_model_pruned_total"]
    assert pruned and pruned[0]["value"] > 0


def dataclasses_replace_nosrc(p):
    import dataclasses

    return dataclasses.replace(p, interpret=None, source="x")


def test_full_search_bypasses_model(monkeypatch, tmp_path):
    device = at.registry.device_kind()
    pm.Calibration(device=device, interpret=True, constants={"*": SYNTH},
                   fit={"n_samples": 9},
                   created_unix=1.0).save(tmp_path / "calib.json")
    calls = _patch_synthetic_clock(monkeypatch)
    at.autotune(MS2, 256, 24, 64, "msgemm_pallas", interpret=True,
                search="full")
    assert len(calls) > at.MODEL_TOP_K
    snap = obs.registry().snapshot()
    assert not [c for c in snap["counters"]
                if c["name"] == "dispatch_autotune_model_pruned_total"]


def test_model_search_falls_back_without_calibration(monkeypatch,
                                                     tmp_path):
    # REPRO_CALIBRATION points at a missing file -> full sweep + counter
    calls = _patch_synthetic_clock(monkeypatch)
    at.autotune(MS2, 256, 24, 64, "msgemm_pallas", interpret=True,
                search="model")
    assert len(calls) > at.MODEL_TOP_K
    snap = obs.registry().snapshot()
    fb = [c for c in snap["counters"]
          if c["name"] == "dispatch_autotune_model_fallback_total"]
    assert fb and fb[0]["value"] == 1


def test_timings_rows_carry_partition_tags(monkeypatch):
    _patch_synthetic_clock(monkeypatch)
    at.autotune(MS2, 16, 24, 8, "msgemm_jnp", interpret=True,
                search="full")
    key = next(iter(at.cache()._timings))
    rows = at.cache().timings(key)
    assert rows
    for r in rows:
        assert r["interpret"] is True
        assert r["device"] == at.registry.device_kind()


def test_samples_from_plan_cache_skips_untagged(monkeypatch, tmp_path):
    _patch_synthetic_clock(monkeypatch)
    at.autotune(MS2, 16, 24, 8, "msgemm_jnp", interpret=True,
                search="full")
    path = at.cache().path
    doc = json.loads(path.read_text())
    key = next(iter(doc["timings"]))
    legacy_row = dict(doc["timings"][key][0])
    legacy_row.pop("interpret")
    legacy_row.pop("device")
    doc["timings"][key].append(legacy_row)  # a pre-tag row
    doc.pop("crc", None)  # hand-edited: drop the stamp, legacy-style load
    path.write_text(json.dumps(doc))
    samples, untagged = pm.samples_from_plan_cache(path)
    assert untagged == 1
    assert len(samples) == len(doc["timings"][key]) - 1
    assert all(s.interpret for s in samples)


# ------------------------------------------------------------- sentinel
def test_sentinel_passes_clean_and_flags_injected_regression():
    cal = pm.fit(_synthetic_grid(), device="cpu", interpret=True)
    clean = pm.check_regressions(_synthetic_grid(), cal)
    assert clean["ok"] and clean["n_outliers"] == 0
    assert clean["n_samples"] == len(_synthetic_grid())

    slowed = _synthetic_grid()
    bad = _synthetic_sample("msgemm_pallas", 16, 24, 8,
                            scale=10 * pm.DEFAULT_TOLERANCE)
    slowed.append(bad)
    report = pm.check_regressions(slowed, cal)
    assert not report["ok"] and report["n_outliers"] == 1
    # ranked: the regression is row 0
    assert report["rows"][0]["outlier"]
    assert report["rows"][0]["source"] == bad.source
    text = pm.render_report(report)
    assert "REGRESSION" in text and "OUTLIER" in text


def test_sentinel_skips_other_partition_and_fast_rows_pass():
    cal = pm.fit(_synthetic_grid(), device="cpu", interpret=True)
    mixed = [_synthetic_sample("msgemm_jnp", 16, 24, 8, interpret=False),
             _synthetic_sample("msgemm_jnp", 16, 24, 8, scale=0.01)]
    report = pm.check_regressions(mixed, cal)
    assert report["ok"]
    assert report["n_skipped_other_partition"] == 1
    assert report["n_fast"] == 1  # faster than predicted never fails


def test_samples_from_snapshot_requires_labels():
    reg = obs.Registry()
    reg.histogram("kernel_gemm_s", help="t", backend="msgemm_jnp",
                  m=16, k=24, b=8, mode="msgemm", d=2,
                  sb=12).observe(0.5)
    reg.histogram("kernel_gemm_s", help="t", backend="msgemm_jnp",
                  m=16, k=24, b=8).observe(0.5)  # pre-tag series
    samples = pm.samples_from_snapshot(reg.snapshot(), device="cpu",
                                       interpret=True)
    assert len(samples) == 1
    s = samples[0]
    assert (s.mode, s.d, s.scale_block) == ("msgemm", 2, 12)
    assert s.measured_s == pytest.approx(0.5)


# ------------------------------------------------------------------ CLI
def test_obs_cli_calibrate_and_check_regressions(monkeypatch, tmp_path,
                                                 capsys):
    from repro.obs.__main__ import main as obs_main

    _patch_synthetic_clock(monkeypatch)
    for m, k, b in [(16, 24, 8), (64, 24, 8), (32, 48, 16)]:
        at.autotune(MS2, m, k, b, "msgemm_jnp", interpret=True,
                    search="full")
    cache_path = str(at.cache().path)
    calib = str(tmp_path / "cli_calib.json")
    assert obs_main(["--calibrate", "--plan-cache", cache_path,
                     "--calibration", calib]) == 0
    assert obs_main(["--validate-calibration", calib]) == 0
    report = str(tmp_path / "report.md")
    assert obs_main(["--check-regressions", "--plan-cache", cache_path,
                     "--calibration", calib, "--report-out",
                     report]) == 0
    assert "verdict: OK" in open(report).read()
    # inject a slowdown -> exit 1
    doc = json.loads(open(cache_path).read())
    key = next(iter(doc["timings"]))
    doc["timings"][key][0]["s"] *= 100 * pm.DEFAULT_TOLERANCE
    doc.pop("crc", None)  # hand-edited: drop the stamp, legacy-style load
    slow = tmp_path / "slow.json"
    slow.write_text(json.dumps(doc))
    capsys.readouterr()
    assert obs_main(["--check-regressions", "--plan-cache", str(slow),
                     "--calibration", calib]) == 1
    assert "OUTLIER" in capsys.readouterr().out
