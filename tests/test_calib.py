"""Calibration & codebook subsystem: codebook round-trips and degenerate
equivalence with uniform int4, codebook msGeMM vs dense oracle (jnp +
Pallas), GPTQ-lite objective, stats collection, calibrate() end-to-end
(quality win, checkpoint round-trip, continuous-engine parity), stacked /
expert quantize_model, and eager QuantConfig validation."""

import tempfile

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import calib
from repro.calib.codebook import Codebook, uniform_values
from repro.core import linear, lut, packing, scales
from repro.core.linear import QuantConfig
from repro.data.pipeline import DataConfig, SyntheticStream
from repro.kernels import ops
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.quant import quantize_model
from repro.runtime import serve as SV

jax.config.update("jax_enable_x64", False)


def rand_codebook(rng):
    return jnp.asarray(
        np.concatenate([[0.0], np.sort(rng.standard_normal(15) * 5)]),
        jnp.float32)


# ------------------------------------------------------------- codebook
def test_uniform_codebook_is_degenerate_case():
    """quantize_codebook on the uniform table == quantize_int4, bit-exact."""
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.standard_normal((9, 24)), jnp.float32)
    qa = scales.quantize_int4(w, 12)
    qb = scales.quantize_codebook(w, uniform_values(), 12)
    assert np.array_equal(np.asarray(qa.codes), np.asarray(qb.codes))
    np.testing.assert_array_equal(np.asarray(scales.dequantize(qa)),
                                  np.asarray(scales.dequantize(qb)))


def test_codebook_encode_decode_roundtrip():
    """Values already in the codebook encode/decode exactly."""
    rng = np.random.default_rng(1)
    cb = Codebook(values=np.asarray(rand_codebook(rng))).check()
    codes = jnp.asarray(rng.integers(0, 16, size=(7, 13)), jnp.uint8)
    vals = cb.decode(codes)
    assert np.array_equal(np.asarray(cb.encode(vals)), np.asarray(codes))


def test_codebook_pack_unpack_roundtrip():
    """Codebook codes ride the same 4-bit packings as uniform int4."""
    rng = np.random.default_rng(2)
    cb = Codebook(values=np.asarray(rand_codebook(rng)))
    w = jnp.asarray(rng.standard_normal((5, 23)), jnp.float32)
    qt = scales.quantize_codebook(w, cb.values, 12)
    for d in (2, 3):
        idx = packing.pack_indices(qt.codes, d)
        assert np.array_equal(np.asarray(packing.unpack_indices(idx, d, 23)),
                              np.asarray(qt.codes))
    u8 = packing.pack_storage(qt.codes)
    assert np.array_equal(np.asarray(packing.unpack_storage(u8, 23)),
                          np.asarray(qt.codes))


def test_from_centroids_pins_zero():
    cb = Codebook.from_centroids([1.5, -2.0, 3.0]).check()
    assert cb.values[0] == 0.0
    with pytest.raises(ValueError):
        Codebook(values=np.ones(16, np.float32)).check()  # no zero at code 0


@pytest.mark.parametrize("d", [2, 3])
def test_codebook_msgemm_matches_dense(d):
    """Learned-codebook msGeMM == dequantize->dense, jnp and Pallas paths."""
    rng = np.random.default_rng(d)
    cb = rand_codebook(rng)
    w = jnp.asarray(rng.standard_normal((8, 24)), jnp.float32)
    x = jnp.asarray(rng.standard_normal((24, 3)), jnp.float32)
    qt = scales.quantize_codebook(w, cb, 12)
    want = scales.dequantize(qt) @ x
    got = lut.msgemm(qt.codes, x, d=d, scales=qt.scales, scale_block=12,
                     codebook=cb)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
    got_pl = ops.msgemm(qt.codes, x, d, scales=qt.scales, scale_block=12,
                        codebook=cb)
    np.testing.assert_allclose(np.asarray(got_pl), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("mode", ["int4_dequant", "msgemm"])
@pytest.mark.parametrize("storage", ["packed_idx", "packed_u8"])
def test_codebook_linear_layer(mode, storage):
    rng = np.random.default_rng(7)
    cb = rand_codebook(rng)
    w = jnp.asarray(rng.standard_normal((10, 24)), jnp.float32)
    cfg = QuantConfig(mode=mode, d=3, scale_block=12, storage=storage,
                      codebook="learned")
    p = linear.from_dense(w, cfg, codebook=cb)
    assert "codebook" in p
    x = jnp.asarray(rng.standard_normal((4, 24)), jnp.float32)
    got = linear.apply(p, x, cfg, in_dim=24)
    want = x @ scales.dequantize(scales.quantize_codebook(w, cb, 12)).T
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-3, atol=1e-3)


# ------------------------------------------------------------- fitting
def test_fit_codebook_never_worse_than_uniform():
    """Lloyd from the uniform grid init is monotone in weighted MSE."""
    rng = np.random.default_rng(3)
    z = rng.standard_normal(4096) * 3
    wts = 1 + rng.random(4096)
    cbv = calib.fit_codebook(z, wts, iters=20)
    assert cbv[0] == 0.0

    def werr(vals):
        deq = vals[np.argmin(np.abs(z[:, None] - vals[None, :]), axis=1)]
        return np.sum(wts * (z - deq) ** 2)

    assert werr(cbv.astype(np.float64)) <= werr(
        uniform_values().astype(np.float64))


def test_gptq_reduces_output_mse():
    rng = np.random.default_rng(4)
    m, k, blk = 12, 32, 16
    w = rng.standard_normal((m, k))
    X = rng.standard_normal((256, k)) * (1 + 2 * rng.random(k))
    H = X.T @ X / X.shape[0]
    vals = uniform_values()
    s, wb, _ = calib.fit_block_scales(w, vals, blk)
    z = wb / s[..., None]
    codes_n = np.argmin(np.abs(z[..., None] - vals), axis=-1)
    codes_n = codes_n.reshape(m, -1)[:, :k]
    codes_g = calib.gptq_codes(w, H, vals, s, blk)
    sfull = np.repeat(s, blk, 1)[:, :k]

    def out_mse(codes):
        E = w - vals[codes] * sfull
        return np.mean(np.einsum("ik,kl,il->i", E, H, E))

    assert out_mse(codes_g) < out_mse(codes_n)


# ------------------------------------------------------------- stats
def test_stats_collector_tags_and_moments():
    cfg = ModelConfig(num_layers=1, d_model=32, num_heads=2, num_kv_heads=2,
                      d_ff=64, vocab_size=97, max_seq_len=64)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    stream = SyntheticStream(DataConfig(vocab_size=97, seq_len=16,
                                        global_batch=2))
    col = calib.collect(params, cfg,
                        [{k: jnp.asarray(v) for k, v in
                          stream.host_batch(0).items()}])
    for tag, k in (("wq", 32), ("up", 32), ("down", 64), ("lm_head", 32)):
        st = col.get(tag, k)
        assert st.count > 0, tag
        m2 = st.second_moment
        assert m2.shape == (k,) and np.all(m2 > 0)
    # observer uninstalled after collect: serving records nothing new
    n = col.get("wq", 32).count
    T.forward(params, cfg, {"tokens": jnp.zeros((1, 4), jnp.int32)})
    assert col.get("wq", 32).count == n


# ------------------------------------------------------------- calibrate
CFG = ModelConfig(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                  d_ff=128, vocab_size=211, max_seq_len=128)


@pytest.fixture(scope="module")
def dense_model():
    params = T.init_params(jax.random.PRNGKey(0), CFG)
    stream = SyntheticStream(DataConfig(vocab_size=211, seq_len=32,
                                        global_batch=4))
    return params, stream


@pytest.fixture(scope="module")
def calibrated(dense_model):
    params, stream = dense_model
    return calib.calibrate(
        params, CFG, stream, calib.Recipe(calib_steps=2, kmeans_iters=10),
        quant=QuantConfig(mode="msgemm", d=3, scale_block=36))


def test_calibrate_beats_uniform_weighted_error(calibrated):
    agg = calibrated.report["aggregate"]
    assert agg["learned_weighted_err"] < agg["uniform_weighted_err"]
    for path, entry in calibrated.report.items():
        if path == "aggregate":
            continue
        assert (entry["learned_weighted_err"]
                <= entry["uniform_weighted_err"] + 1e-12), path


def test_calibrated_serves_and_checkpoints(calibrated):
    """Quantize -> save -> restore into a fresh init -> identical tokens
    (codebooks persist alongside the packed codes)."""
    from repro.checkpoint import CheckpointManager

    qcfg = CFG.replace(quant=calibrated.quant)
    batch = {"tokens": jnp.asarray(
        np.random.default_rng(0).integers(0, 211, (2, 12)), jnp.int32)}
    toks = SV.generate(calibrated.params, qcfg, batch, max_new_tokens=6)
    with tempfile.TemporaryDirectory() as td:
        mgr = CheckpointManager(td)
        mgr.save(0, calibrated.params)
        target = T.init_params(jax.random.PRNGKey(9), qcfg)
        restored = mgr.restore(0, target)
    toks2 = SV.generate(restored, qcfg, batch, max_new_tokens=6)
    assert np.array_equal(np.asarray(toks), np.asarray(toks2))


def test_calibrated_continuous_engine_parity(calibrated):
    """Codebook-quantized models serve token-identical through the paged
    continuous-batching engine."""
    from repro.serving import Engine, Request

    qcfg = CFG.replace(quant=calibrated.quant)
    prompt = tuple(int(t) for t in
                   np.random.default_rng(1).integers(0, 211, 7))
    eng = Engine(calibrated.params, qcfg, max_slots=2, block_size=4,
                 prefill_chunk=4, max_model_len=64)
    res = eng.run([Request(rid=0, prompt=prompt, max_new_tokens=6)])
    ref = SV.generate(calibrated.params, qcfg,
                      {"tokens": np.array([prompt], np.int32)},
                      max_new_tokens=6)
    assert res[0].generated == [int(t) for t in np.asarray(ref)[0]]


def test_calibrate_quality_harness(dense_model, calibrated):
    params, stream = dense_model
    qcfg = CFG.replace(quant=calibrated.quant)
    rep = calib.quality.compare(
        params, CFG,
        {"uniform": (quantize_model(params, CFG, calibrated.quant), qcfg),
         "learned": (calibrated.params, qcfg)},
        stream, steps=1)
    assert rep["bf16"]["logit_mse"] == 0.0
    assert rep["learned"]["logit_mse"] < rep["uniform"]["logit_mse"]


# -------------------------------------------------- stacked / expert trees
MOE_CFG = ModelConfig(num_layers=2, d_model=32, num_heads=2, num_kv_heads=2,
                      d_ff=64, vocab_size=97, max_seq_len=64,
                      block_pattern=("moe",), num_experts=4,
                      num_experts_per_tok=2, moe_d_ff=48)


def test_quantize_model_stacked_and_expert_weights():
    """Scan-grouped (G, ...) and expert (G, E, ...) stacked weights
    quantize with per-slice codebooks and still forward."""
    params = T.init_params(jax.random.PRNGKey(2), MOE_CFG)
    qc = QuantConfig(mode="msgemm", d=3, scale_block=36, codebook="learned")
    qp = quantize_model(params, MOE_CFG, qc)
    expert_up = qp["blocks"]["0:moe"]["moe"]["experts"]["up"]
    assert expert_up["codebook"].shape == (2, 4, 16)  # (groups, experts, 16)
    assert expert_up["idx"].shape[:2] == (2, 4)
    wq = qp["blocks"]["0:moe"]["attn"]["wq"]
    assert wq["codebook"].shape == (2, 16)  # scan-grouped
    logits, _ = T.forward(qp, MOE_CFG.replace(quant=qc),
                          {"tokens": jnp.zeros((1, 8), jnp.int32)})
    assert logits.shape == (1, 8, 97)


def test_calibrate_moe_per_layer_codebooks():
    params = T.init_params(jax.random.PRNGKey(3), MOE_CFG)
    stream = SyntheticStream(DataConfig(vocab_size=97, seq_len=16,
                                        global_batch=2))
    res = calib.calibrate(params, MOE_CFG, stream,
                          calib.Recipe(calib_steps=1, kmeans_iters=6),
                          quant=QuantConfig(mode="msgemm", d=3,
                                            scale_block=36))
    cb = res.codebooks["blocks/0:moe/moe/experts/up"]
    assert cb.shape == (2, 4, 16)
    # re-applying the fitted tables through quantize_model reproduces them
    qp = quantize_model(params, MOE_CFG, res.quant, codebooks=res.codebooks)
    np.testing.assert_allclose(
        np.asarray(qp["blocks"]["0:moe"]["moe"]["experts"]["up"]["codebook"]),
        cb, rtol=1e-6)
    agg = res.report["aggregate"]
    assert agg["learned_weighted_err"] < agg["uniform_weighted_err"]


# ------------------------------------------------------------- validation
def test_quantconfig_eager_validation():
    """Config/scale-block incompatibilities surface at construction, not
    deep inside the kernels (core.scales.check_applicable)."""
    with pytest.raises(ValueError):
        QuantConfig(mode="msgemm", d=3, scale_block=10)  # 3 does not divide 10
    with pytest.raises(ValueError):
        QuantConfig(mode="msgemm", d=3, scale_block=2)  # block < d
    with pytest.raises(ValueError):
        QuantConfig(mode="msgemm", d="adaptive", scale_block=9)  # odd block
    with pytest.raises(ValueError):
        QuantConfig(mode="msgemm", d=5)  # 16^5 LUT
    with pytest.raises(ValueError):
        QuantConfig(mode="msgemm", d=0)
    with pytest.raises(ValueError):
        QuantConfig(storage="zip")
    with pytest.raises(ValueError):
        QuantConfig(impl="cuda")
    with pytest.raises(ValueError):
        QuantConfig(codebook="maybe")
    with pytest.raises(ValueError):
        QuantConfig(consume_chunk=0)
    # valid corners still construct
    QuantConfig(mode="msgemm", d="adaptive")
    QuantConfig(mode="msgemm", d=2, scale_block=16, codebook="learned")


def test_scale_search_never_worse_than_base():
    """fit_block_scales' shrink search always evaluates the base
    bounding-box scale too — candidates=1 must not shrink blocks
    unconditionally when that increases the error."""
    from repro.calib.fit import fit_block_scales

    rng = np.random.default_rng(3)
    w = rng.standard_normal((6, 24))
    vals = np.asarray(uniform_values(), np.float64)

    def err(s, wb):
        z = wb / s[..., None]
        deq = vals[np.argmin(np.abs(z[..., None] - vals), axis=-1)]
        return ((wb - deq * s[..., None]) ** 2).sum()

    base_s, wb, _ = fit_block_scales(w, uniform_values(), 12)
    for cands in (1, 2, 5):
        s, wb2, _ = fit_block_scales(w, uniform_values(), 12,
                                     candidates=cands)
        assert err(s, wb2) <= err(base_s, wb) + 1e-12, cands
