"""Core msGeMM correctness: packing round-trips, bit-exactness vs dense,
complexity formulas vs instrumented counts, §3.3 scale rules, hypothesis
property tests."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import complexity, linear, lut, packing, scales

jax.config.update("jax_enable_x64", False)


def rand_codes(rng, m, k):
    return jnp.asarray(rng.integers(0, 16, size=(m, k)), jnp.uint8)


# ------------------------------------------------------------------ packing
def test_b_roundtrip():
    vals = packing.b_values()
    codes = packing.b_hat(vals)
    assert np.array_equal(np.asarray(codes), np.arange(16))
    assert vals[0b0000] == 0 and vals[0b0111] == 7
    assert vals[0b1000] == -8 and vals[0b1111] == -1  # paper §3.1 examples


@pytest.mark.parametrize("k", [4, 7, 16, 33])
def test_storage_roundtrip(k):
    rng = np.random.default_rng(0)
    c = rand_codes(rng, 5, k)
    assert np.array_equal(packing.unpack_storage(packing.pack_storage(c), k), c)


@pytest.mark.parametrize("d", [1, 2, 3, 4])
@pytest.mark.parametrize("k", [6, 12, 13])
def test_index_roundtrip(d, k):
    rng = np.random.default_rng(d * 100 + k)
    c = rand_codes(rng, 4, k)
    idx = packing.pack_indices(c, d)
    assert idx.shape == (4, -(-k // d))
    assert np.array_equal(packing.unpack_indices(idx, d, k), c)


def test_d2_byte_is_index():
    """For d=2 the storage byte IS the LUT index (TPU fast path)."""
    rng = np.random.default_rng(3)
    c = rand_codes(rng, 8, 10)
    u8 = packing.pack_storage(c)
    assert np.array_equal(
        packing.indices_from_storage(u8, 2, 10), packing.pack_indices(c, 2))


# ------------------------------------------------------------------ lut
def test_paper_running_example():
    """§3.2: M(0,:) = {2,4,3,5}  =>  y(0) = L(0010,0100,0) + L(0011,0101,1)."""
    x = jnp.asarray([1.5, -2.0, 0.25, 3.0])
    codes = packing.b_hat(jnp.asarray([[2, 4, 3, 5]]))
    table = lut.produce(x[:, None], d=2)  # (256, 2, 1)
    idx_blue_red = 0b0010_0100
    idx_2 = 0b0011_0101
    y = table[idx_blue_red, 0, 0] + table[idx_2, 1, 0]
    expected = 2 * 1.5 + 4 * -2.0 + 3 * 0.25 + 5 * 3.0
    np.testing.assert_allclose(y, expected, rtol=1e-6)
    got = lut.msgemm(codes, x, d=2)
    np.testing.assert_allclose(got, [expected], rtol=1e-6)


@pytest.mark.parametrize("d", [1, 2, 3])
@pytest.mark.parametrize("m,k,b", [(3, 6, 1), (16, 12, 4), (9, 13, 2), (1, 24, 7)])
def test_msgemm_matches_dense(d, m, k, b):
    rng = np.random.default_rng(d + m + k)
    codes = rand_codes(rng, m, k)
    x = jnp.asarray(rng.standard_normal((k, b)), jnp.float32)
    got = lut.msgemm(codes, x, d=d)
    want = lut.msgemm_reference(codes, x, d=d)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_msgemm_exact_on_integers():
    """Integer activations => float ops are exact => bitwise equality."""
    rng = np.random.default_rng(7)
    codes = rand_codes(rng, 32, 24)
    x = jnp.asarray(rng.integers(-50, 50, size=(24, 3)), jnp.float32)
    got = lut.msgemm(codes, x, d=3)
    want = lut.msgemm_reference(codes, x, d=3)
    assert np.array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("chunk", [1, 2, 4])
def test_consume_chunking_invariant(chunk):
    rng = np.random.default_rng(11)
    codes = rand_codes(rng, 8, 18)
    x = jnp.asarray(rng.standard_normal((18, 2)), jnp.float32)
    base = lut.msgemm(codes, x, d=3, chunk=1)
    got = lut.msgemm(codes, x, d=3, chunk=chunk)
    np.testing.assert_allclose(got, base, rtol=1e-6)


@settings(max_examples=30, deadline=None)
@given(
    m=st.integers(1, 12), kc=st.integers(1, 6), b=st.integers(1, 4),
    d=st.integers(1, 3), seed=st.integers(0, 2**31 - 1),
)
def test_property_msgemm_equals_dense(m, kc, b, d, seed):
    """Property: for ALL int4 M and real X, msGeMM(M, X) == M @ X (Eq. 5)."""
    rng = np.random.default_rng(seed)
    k = kc * d
    codes = rand_codes(rng, m, k)
    x = jnp.asarray(rng.standard_normal((k, b)), jnp.float32)
    got = lut.msgemm(codes, x, d=d)
    want = lut.msgemm_reference(codes, x, d=d)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


@settings(max_examples=15, deadline=None)
@given(d=st.integers(1, 3), seed=st.integers(0, 2**31 - 1))
def test_property_linearity(d, seed):
    """LUT linearity (§4.1): msgemm(M, a*x) == a * msgemm(M, x)."""
    rng = np.random.default_rng(seed)
    codes = rand_codes(rng, 6, 6 * d)
    x = jnp.asarray(rng.standard_normal((6 * d, 2)), jnp.float32)
    y1 = lut.msgemm(codes, 2.5 * x, d=d)
    y2 = 2.5 * lut.msgemm(codes, x, d=d)
    np.testing.assert_allclose(y1, y2, rtol=1e-5, atol=1e-5)


# ------------------------------------------------------------------ scales
def test_scale_rules():
    scales.check_applicable(6, 3)  # r multiple of d: ok
    with pytest.raises(ValueError):
        scales.check_applicable(4, 3)  # r not multiple of d
    with pytest.raises(ValueError):
        scales.check_applicable(2, 3)  # r < d
    with pytest.raises(ValueError):
        scales.check_applicable(6, 3, axis="column")  # §3.3 column boxes


@pytest.mark.parametrize("power_of_two", [False, True])
def test_quantize_dequantize(power_of_two):
    rng = np.random.default_rng(5)
    w = jnp.asarray(rng.standard_normal((16, 48)), jnp.float32)
    qt = scales.quantize_int4(w, block=12, power_of_two=power_of_two)
    err = scales.quantization_error(w, qt)
    # symmetric int4 (amax -> +-7): error <= scale/2; pow2 scales <= 2x scale
    amax = float(jnp.max(jnp.abs(w)))
    assert float(err) <= amax / 7 * (1.0 if power_of_two else 0.5) + 1e-6


def test_msgemm_with_scales_matches_dequant_dense():
    rng = np.random.default_rng(9)
    w = jnp.asarray(rng.standard_normal((24, 36)), jnp.float32)
    qt = scales.quantize_int4(w, block=12)
    x = jnp.asarray(rng.standard_normal((36, 5)), jnp.float32)
    got = lut.msgemm(qt.codes, x, d=3, scales=qt.scales, scale_block=12)
    want = scales.dequantize(qt) @ x
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


# ------------------------------------------------------------------ linear
@pytest.mark.parametrize("mode", ["bf16", "int4_dequant", "msgemm"])
@pytest.mark.parametrize("storage", ["packed_idx", "packed_u8"])
def test_linear_modes_agree(mode, storage):
    cfg = linear.QuantConfig(mode=mode, d=3, scale_block=12, storage=storage)
    key = jax.random.PRNGKey(0)
    p_dense = linear.init(key, 24, 16, linear.DENSE)
    p = linear.from_dense(p_dense["w"], cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 5, 24))
    y = linear.apply(p, x, cfg, in_dim=24)
    y_ref = linear.apply(p_dense, x, linear.DENSE)
    assert y.shape == (2, 5, 16)
    # quantized paths approximate the dense weight; both quant modes must
    # agree with the *dequantized* weight tightly.
    if mode == "bf16":
        np.testing.assert_allclose(y, y_ref, rtol=1e-5)
    else:
        qt = scales.quantize_int4(p_dense["w"], 12)
        y_dq = x @ scales.dequantize(qt).T
        np.testing.assert_allclose(y, y_dq, rtol=2e-4, atol=2e-4)


# ------------------------------------------------------------------ complexity
@pytest.mark.parametrize("d", [1, 2])
@pytest.mark.parametrize("m,k,b", [(4, 4, 1), (6, 8, 2)])
def test_complexity_formulas_match_instrumented_counts(d, m, k, b):
    rng = np.random.default_rng(d * 10 + m)
    codes = np.asarray(rand_codes(rng, m, k))
    x = rng.standard_normal((k, b))
    y, counts = complexity.counted_msgemm(codes, x, d)
    assert counts.fma == complexity.c_lut(k, d) * b          # Eq. 7
    assert counts.add == complexity.c_consume(m, k, d) * b   # Eq. 9
    assert counts.mem == complexity.m_msgemm(m, k, b)        # Eq. 12
    assert counts.total_compute <= complexity.c_msgemm(m, k, b, d)
    want = lut.msgemm_reference(jnp.asarray(codes), jnp.asarray(x, jnp.float32), d)
    np.testing.assert_allclose(y, want, rtol=1e-4, atol=1e-4)
    _, gcounts = complexity.counted_gemm(rng.standard_normal((m, k)), x)
    assert gcounts.fma == complexity.c_gemm(m, k, b)         # Eq. 14
    assert gcounts.mem == complexity.m_gemm(m, k, b)


def test_paper_fig3_sweet_spot():
    """§5 / Fig. 3, reproduced from the paper's own Eqs. 18 & 21.

    Eq. 21 (MLP2, m=49152): d=3 -> 2.40 ("~2.5x" claim: holds).
    Eq. 18 (MLP1, m=12288): d=3 -> 1.50 — the figure's "~2.5x for BOTH"
    claim is inconsistent with Eq. 18; it matches only the large-m
    orientation, in line with the paper's own "the larger the number of
    rows (m) the better" observation.  EXPERIMENTS.md §Claims records this.
    """
    mlp1 = complexity.speedup(12288, 49152, d=3)
    mlp2 = complexity.speedup(49152, 12288, d=3)
    np.testing.assert_allclose(mlp1, 49152 / (2**12 * 4 + 49152 // 3 - 1))  # Eq.18
    np.testing.assert_allclose(mlp2, 49152 / (2**12 + (12288 // 3 - 1) * 4))  # Eq.21
    assert 2.3 < mlp2 < 2.7, mlp2  # the ~2.5x sweet spot
    assert 1.4 < mlp1 < 2.0, mlp1
    d2, _ = complexity.best_d(49152, 12288)
    assert d2 == 3  # d=3 is MLP2's sweet spot (Fig. 3)
    # paper: "the larger m the better ... cost of the look-up table is
    # independent of m"
    assert complexity.speedup(4 * 12288, 49152, d=3) > mlp1
    # d=5+ collapses (exponential LUT cost, §5: "d cannot be larger than 4")
    assert complexity.speedup(12288, 49152, d=5) < 1.0
    assert complexity.speedup(49152, 12288, d=5) < 1.0


# ------------------------------------------------------- adaptive depth
def test_adaptive_depth_resolution():
    """'adaptive' d picks the per-linear argmax of Eq. 15 (beyond-paper)."""
    cfg = linear.QuantConfig(mode="msgemm", d="adaptive")
    assert cfg.scale_block == 12
    # lm_head-like (m >> 16^d): deep LUT wins
    assert cfg.resolve_d(2048, 256000) >= 3
    # square projection (m ~ 16^3): shallow LUT
    assert cfg.resolve_d(5120, 5120) == 2


@pytest.mark.parametrize("storage", ["packed_idx", "packed_u8"])
def test_adaptive_depth_linear_matches_dequant(storage):
    cfg = linear.QuantConfig(mode="msgemm", d="adaptive", storage=storage)
    key = jax.random.PRNGKey(0)
    p_dense = linear.init(key, 24, 4200, linear.DENSE)  # big-m head
    p = linear.from_dense(p_dense["w"], cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (3, 24))
    y = linear.apply(p, x, cfg, in_dim=24)
    qt = scales.quantize_int4(p_dense["w"], cfg.scale_block)
    want = x @ scales.dequantize(qt).T
    np.testing.assert_allclose(y, want, rtol=3e-4, atol=3e-4)
    if storage == "packed_idx":
        d_used = cfg.resolve_d(24, 4200)
        assert p["idx"].shape[1] == -(-24 // d_used)
