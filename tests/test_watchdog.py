"""Unit coverage for distributed/watchdog.py: straggler z-score
detection, hang-timer arming/firing, the min_timeout_s floor, and the
step_finished() stats contract."""

import time

import pytest

from repro import obs
from repro.distributed.watchdog import Watchdog


@pytest.fixture(autouse=True)
def _clean_registry():
    obs.registry().reset()
    yield
    obs.registry().reset()


def _warm(wd, n, dt=0.0):
    """Feed n fast synthetic steps so the rolling stats are primed."""
    for _ in range(n):
        wd.step_started()
        if dt:
            time.sleep(dt)
        wd.step_finished()


def test_no_arming_before_min_steps():
    wd = Watchdog(min_steps=5, min_timeout_s=0.01)
    for _ in range(5):
        wd.step_started()
        assert wd._timer is None  # not enough history yet
        info = wd.step_finished()
        assert info["straggler"] is False and info["step_time"] >= 0.0
    wd.step_started()
    assert wd._timer is not None  # history primed, timer armed
    wd.step_finished()
    assert wd._timer is None  # cancelled on finish
    assert wd.hang_count == 0


def test_hang_timer_fires_and_counts():
    fired = []
    wd = Watchdog(min_steps=2, min_timeout_s=0.05,
                  on_hang=lambda: fired.append(True))
    _warm(wd, 3)
    wd.step_started()
    timeout = wd._timer.interval
    assert timeout >= 0.05  # floor respected on tiny means
    time.sleep(timeout * 1.5)
    wd.step_finished()
    assert wd.hang_count >= 1
    assert fired
    assert obs.registry().value("counter", "watchdog_hangs_total") >= 1


def test_min_timeout_floor():
    wd = Watchdog(min_steps=2, min_timeout_s=5.0)
    _warm(wd, 3)  # mean is microseconds; floor must dominate
    wd.step_started()
    assert wd._timer.interval == pytest.approx(5.0)
    wd.step_finished()
    assert wd.hang_count == 0


def test_straggler_zscore_detection():
    seen = []
    wd = Watchdog(min_steps=3, z_threshold=4.0, min_timeout_s=10.0,
                  on_straggler=lambda dt, mean, std: seen.append(dt))
    # prime with steps of small but nonzero spread so std > 0
    for dt in (0.001, 0.002, 0.001, 0.002, 0.001):
        wd.step_started()
        time.sleep(dt)
        wd.step_finished()
    assert wd.straggler_count == 0
    wd.step_started()
    time.sleep(0.08)  # >> mean + 4 std
    info = wd.step_finished()
    assert info["straggler"] is True
    assert wd.straggler_count == 1
    assert seen and seen[0] == pytest.approx(info["step_time"])
    assert obs.registry().value(
        "counter", "watchdog_stragglers_total") == 1


def test_straggler_sample_joins_history():
    wd = Watchdog(min_steps=2, min_timeout_s=10.0)
    _warm(wd, 4)
    before = len(wd._times)
    wd.step_started()
    wd.step_finished()
    assert len(wd._times) == before + 1


def test_window_bounds_stats():
    wd = Watchdog(window=4, min_steps=2, min_timeout_s=10.0)
    wd._times.extend([10.0, 10.0, 0.001, 0.001, 0.001, 0.001])
    mean, std = wd._stats()
    # only the last `window` samples count: the 10s outliers age out
    assert mean == pytest.approx(0.001)
    assert std == pytest.approx(0.0)
