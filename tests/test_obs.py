"""Observability tests: metric registry semantics, snapshot/trace schema
validation, Chrome-trace span recording (host spans + jit marks under
jit/scan), the zero-overhead-when-disabled contract, and the engine's
token-identity invariant with tracing on vs off."""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.obs import trace as TR
from repro.obs.metrics import Registry


@pytest.fixture(autouse=True)
def _clean_tracer():
    """Tracing is process-global; leave it off and empty around every
    test so obs tests cannot leak spans into each other (or stage
    callbacks into other tests' compiles)."""
    obs.disable_tracing()
    obs.tracer().clear()
    yield
    obs.disable_tracing()
    obs.tracer().clear()


# ------------------------------------------------------------- registry
def test_registry_get_or_create_and_value():
    reg = Registry()
    c = reg.counter("t_total", region="us")
    c.inc()
    c.inc(2)
    assert reg.counter("t_total", region="us") is c
    assert reg.value("counter", "t_total", region="us") == 3
    assert reg.value("counter", "t_total", region="eu") is None
    reg.gauge("t_depth").set(7)
    assert reg.value("gauge", "t_depth") == 7


def test_registry_reset_prefix():
    reg = Registry()
    reg.counter("serving_x").inc()
    reg.counter("dispatch_y").inc()
    reg.reset(prefix="serving_")
    assert reg.value("counter", "serving_x") is None
    assert reg.value("counter", "dispatch_y") == 1
    reg.reset()
    assert reg.value("counter", "dispatch_y") is None


def test_histogram_percentile_edge_cases():
    reg = Registry()
    h = reg.histogram("t_s")
    assert h.percentile(50) is None  # empty: null, never raises
    empty = h.as_dict()
    assert empty["p50"] is None and empty["p95"] is None
    h.observe(0.25)
    assert h.percentile(50) == h.percentile(95) == 0.25  # single sample
    for v in (0.1, 0.2, 0.3, 0.4):
        h.observe(v)
    assert 0.1 <= h.percentile(50) <= h.percentile(95) <= 0.4
    d = h.as_dict()
    assert d["count"] == 5 and d["buckets"]["+Inf"] == 5
    assert d["min"] == 0.1 and d["max"] == 0.4


def test_snapshot_accepts_null_percentiles():
    """A snapshot taken before any observation carries null percentiles
    for the empty histogram — the validator accepts them (and still
    rejects non-numeric junk, and null p50 on a non-empty series)."""
    reg = Registry()
    reg.histogram("t_empty_s")  # created, never observed
    snap = reg.snapshot()
    row = snap["histograms"][0]
    assert row["count"] == 0 and row["p50"] is None
    assert obs.validate_snapshot(snap) == []
    assert obs.validate_snapshot(json.loads(json.dumps(snap))) == []
    bad = json.loads(json.dumps(snap))
    bad["histograms"][0]["p95"] = "oops"
    assert any("p95" in e for e in obs.validate_snapshot(bad))
    bad2 = json.loads(json.dumps(snap))
    bad2["histograms"][0]["count"] = 3
    assert any("null p50" in e for e in obs.validate_snapshot(bad2))


def test_snapshot_roundtrip_and_validation(tmp_path):
    reg = Registry()
    reg.counter("t_reqs", mode="msgemm").inc(4)
    reg.histogram("t_lat_s").observe(0.01)
    snap = reg.snapshot(extra={"arch": "test"})
    assert obs.validate_snapshot(snap) == []
    p = tmp_path / "m.json"
    p.write_text(json.dumps(snap))
    assert obs.validate_snapshot_file(p) == []
    # the validator actually catches breakage
    bad = dict(snap, schema_version=999)
    assert any("schema_version" in e for e in obs.validate_snapshot(bad))
    del bad["counters"]
    assert any("counters" in e for e in obs.validate_snapshot(bad))


def test_prometheus_text_and_endpoint():
    import urllib.request

    reg = Registry()
    reg.counter("t_total", help="reqs", mode="msgemm").inc(2)
    reg.histogram("t_s").observe(0.5)
    text = reg.prometheus_text()
    assert "# TYPE t_total counter" in text
    assert 't_total{mode="msgemm"} 2' in text
    assert 't_s_bucket{le="+Inf"} 1' in text and "t_s_count 1" in text
    srv = obs.serve_prometheus(0, reg)  # port 0: OS-assigned
    try:
        port = srv.server_address[1]
        body = urllib.request.urlopen(
            f"http://127.0.0.1:{port}/metrics", timeout=5).read().decode()
        assert 't_total{mode="msgemm"} 2' in body
    finally:
        srv.shutdown()


# --------------------------------------------------------------- tracer
def test_host_span_nesting_and_roundtrip(tmp_path):
    obs.enable_tracing(clear=True)
    with obs.tracer().span("outer", cat="test", k=1):
        with obs.tracer().span("inner", cat="test"):
            pass
    obs.tracer().instant("mark", cat="test")
    obs.tracer().counter("queue", waiting=3)
    p = tmp_path / "t.json"
    doc = obs.tracer().save(p)
    assert obs.validate_trace(doc) == []
    assert obs.validate_trace_file(p) == []
    loaded = obs.tracer().load(p)
    by_name = {e["name"]: e for e in loaded["traceEvents"]}
    assert by_name["outer"]["ph"] == by_name["inner"]["ph"] == "X"
    # inner completes first and sits inside outer's window
    assert by_name["inner"]["dur"] <= by_name["outer"]["dur"]
    assert by_name["inner"]["ts"] >= by_name["outer"]["ts"]
    assert by_name["mark"]["ph"] == "i"
    assert by_name["queue"]["ph"] == "C"
    assert by_name["queue"]["args"] == {"waiting": 3}


def test_jit_marks_pair_under_jit():
    obs.enable_tracing(clear=True)

    def g(x):
        x = TR.jit_begin(x, "outer")
        y = TR.jit_begin(x, "inner")
        y = TR.jit_end(y + 1.0, "inner", cat="test")
        return TR.jit_end(y * 2.0, "outer", cat="test")

    jax.block_until_ready(jax.jit(g)(jnp.ones((2,))))
    jax.effects_barrier()
    evs = {e["name"]: e for e in obs.tracer().events()}
    assert evs["outer"]["ph"] == evs["inner"]["ph"] == "X"
    assert evs["inner"]["dur"] <= evs["outer"]["dur"]


def test_jit_marks_under_scan_fire_per_iteration():
    """Marks staged once at trace time fire every scan iteration, each
    pairing into its own complete event."""
    obs.enable_tracing(clear=True)

    def step(c, _):
        c = TR.jit_begin(c, "scan.step")
        c = TR.jit_end(c * 2.0, "scan.step", cat="test")
        return c, c

    f = jax.jit(lambda x: jax.lax.scan(step, x, None, length=4))
    out, _ = f(jnp.ones(()))
    jax.block_until_ready(out)
    jax.effects_barrier()
    evs = [e for e in obs.tracer().events() if e["name"] == "scan.step"]
    assert len(evs) == 4
    assert all(e["ph"] == "X" for e in evs)


def test_jit_end_records_histogram():
    obs.enable_tracing(clear=True)
    obs.registry().reset(prefix="t_kernel_")

    def g(x):
        x = TR.jit_begin(x, "m")
        return TR.jit_end(x * 2.0, "m", hist="t_kernel_s",
                          hist_labels={"k": "8"})

    jax.block_until_ready(jax.jit(g)(jnp.ones((8,))))
    jax.effects_barrier()
    assert obs.registry().value("histogram", "t_kernel_s", k="8") == 1


def test_tracing_off_is_zero_overhead():
    """The hard contract: with tracing disabled, span() returns the
    shared no-op singleton and jit_begin/jit_end stage NOTHING into the
    jitted computation (jit_marks_staged counts stagings)."""
    assert not obs.tracer().enabled
    assert obs.tracer().span("a") is obs.tracer().span("b")
    before = TR.jit_marks_staged

    def g(x):
        x = TR.jit_begin(x, "m")
        return TR.jit_end(x * 2.0, "m")

    jax.block_until_ready(jax.jit(g)(jnp.ones((4,))))
    jax.effects_barrier()
    assert TR.jit_marks_staged == before
    assert obs.tracer().events() == []


# ----------------------------------------------------------- cost model
def test_costs_eq9_produce_pinned_d124():
    """Eq.-9 produce accounting: the d-digit tuple table is built from
    shared lower-order prefix tables — sum_{i<=d} 16^i adds per d-wide
    chunk, NOT 16^d * d (the old formula scaled the shared build — and
    the matching transient LUT traffic — linearly in d)."""
    from repro.obs import costs

    k, b = 960, 8  # divisible by 1, 2, 4
    for d, table_ops in ((1, 16), (2, 16 + 256),
                         (4, 16 + 256 + 4096 + 65536)):
        assert costs.produce_table_ops(d) == table_ops
        cost = costs.gemm_cost(512, k, b, quant="msgemm", d=d)
        assert cost["produce_flops"] == 2.0 * table_ops * (k / d) * b
        assert cost["consume_ops"] == 512 * (k / d) * b
        # LUT spill traffic: table entries (16^d per chunk) written +
        # read at f32 — table *size* is unaffected by the shared build
        assert cost["lut_bytes"] == 2 * 16**d * (k / d) * b * 4.0
        assert cost["lut_bytes"] not in (0,) and \
            cost["lut_bytes"] + cost["bytes"] > cost["bytes"]
    # d=1 has no shared prefixes: old and new formulas coincide
    c1 = costs.gemm_cost(512, k, b, quant="msgemm", d=1)
    assert c1["produce_flops"] == 2 * 16 * k * b
    # the d=4 overcount the fix removes was ~3.75x (65536*4 / 69904)
    c4 = costs.gemm_cost(512, k, b, quant="msgemm", d=4)
    assert c4["produce_flops"] < 2 * 16**4 * k * b / 3


def test_costs_roofline_annotation():
    from repro.obs import costs

    cost = costs.gemm_cost(2048, 768, 8, quant="msgemm", d=3)
    # paper Eq. 9: shared-prefix table build per d-wide chunk
    assert cost["produce_flops"] == \
        2 * (16 + 16**2 + 16**3) * (768 / 3) * 8
    assert cost["consume_ops"] == 2048 * (768 // 3) * 8
    row = costs.annotate(1e-3, 2048, 768, 8, quant="msgemm", d=3,
                         dev=costs.DEVICES["cpu"])
    assert row["attainable_s"] > 0
    assert 0 < row["roofline_fraction"] <= 1.0 or row["measured_s"] == 0
    dense = costs.gemm_cost(2048, 768, 8, quant="dense")
    assert dense["produce_flops"] == 2 * 2048 * 768 * 8
    assert dense["consume_ops"] == 0


# ------------------------------------------------- engine token identity
CFG = None


def _small_model():
    from repro.models import transformer as T
    from repro.models.config import ModelConfig

    global CFG
    if CFG is None:
        CFG = ModelConfig(num_layers=2, d_model=64, num_heads=4,
                          num_kv_heads=2, d_ff=128, vocab_size=211,
                          max_seq_len=128)
    return T.init_params(jax.random.PRNGKey(0), CFG), CFG


def _drive(params, cfg):
    from repro.serving import Engine, Request

    rng = np.random.default_rng(7)
    prompts = [tuple(int(t) for t in rng.integers(0, cfg.vocab_size,
                                                  size=n))
               for n in (5, 9)]
    eng = Engine(params, cfg, max_slots=2, block_size=4, prefill_chunk=4,
                 max_model_len=32)
    res = eng.run([Request(rid=i, prompt=p, max_new_tokens=4)
                   for i, p in enumerate(prompts)])
    return eng, {rid: seq.generated for rid, seq in res.items()}


def test_engine_tokens_identical_tracing_on_vs_off():
    """Tracing must be observational only: the engine generates the
    exact same greedy tokens with tracing enabled as disabled, and the
    traced run yields the request-lifecycle + gemm spans."""
    params, cfg = _small_model()
    _, toks_off = _drive(params, cfg)

    obs.enable_tracing(clear=True)  # BEFORE build: jit marks stage now
    _, toks_on = _drive(params, cfg)
    jax.effects_barrier()
    obs.disable_tracing()
    assert toks_on == toks_off

    names = {e["name"] for e in obs.tracer().events()}
    assert "engine.prefill_chunk" in names
    assert "engine.decode_step" in names
    assert any(n.startswith("gemm.") for n in names)


def test_engine_metrics_edge_cases_and_reset():
    from repro.serving import Engine, Request

    params, cfg = _small_model()
    eng = Engine(params, cfg, max_slots=2, block_size=4, prefill_chunk=4,
                 max_model_len=32)
    m0 = eng.metrics()  # nothing finished: counts 0, percentiles None
    assert m0["requests"] == 0 and m0["tok_per_s"] == 0.0
    assert m0["latency_p50_s"] is None and m0["ttft_p95_s"] is None

    # mid-flight (submitted, nothing finished yet): still no raise
    eng.submit(Request(rid=9, prompt=(1, 2), max_new_tokens=2))
    eng.step()
    mf = eng.metrics()
    assert mf["requests"] == 0 and mf["latency_p95_s"] is None

    eng.run([Request(rid=0, prompt=(1, 2, 3), max_new_tokens=3)])
    m1 = eng.metrics()  # exactly one finished: p50 == p95, no raise
    assert m1["requests"] >= 1
    assert m1["latency_p50_s"] > 0 and m1["latency_p95_s"] > 0
    assert eng.summary() == m1

    eng.reset_metrics()
    m2 = eng.metrics()
    assert m2["requests"] == 0 and m2["generated_tokens"] == 0
    assert m2["latency_p50_s"] is None
    assert obs.registry().value(
        "histogram", "serving_ttft_s") in (None, 0)
