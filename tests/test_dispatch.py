"""Dispatch-subsystem tests: QuantSpec/QuantConfig shim split, backend
registry capability + priority selection, ExecPlan planning, the
persistent autotune cache, and engine-level backend parity."""

import dataclasses
import json
import warnings

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import dispatch
from repro.core import linear, scales
from repro.core.spec import DENSE, QuantSpec, as_spec
from repro.dispatch import ExecPlan, ExecPolicy, registry
from repro.dispatch import autotune as at
from repro.kernels import ops

MS = QuantSpec(mode="msgemm", d=3, scale_block=12)


@pytest.fixture
def lin():
    key = jax.random.PRNGKey(0)
    p_dense = linear.init(key, 24, 16, DENSE)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 5, 24))
    return p_dense, x


@pytest.fixture(autouse=True)
def _isolated_cache(tmp_path, monkeypatch):
    """Every test gets its own plan-cache file (and leaves the global
    default policy untouched)."""
    monkeypatch.setenv("REPRO_PLAN_CACHE", str(tmp_path / "plans.json"))
    dispatch.set_cache_path(None)
    yield
    dispatch.set_cache_path(None)
    dispatch.set_default_policy(None)


def _shim(**kw):
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        return linear.QuantConfig(**kw)


# ----------------------------------------------------------------- spec
def test_quantspec_validation_and_defaults():
    s = QuantSpec(mode="msgemm", d=3)
    assert s.scale_block == 36  # 0 -> 12*d
    assert QuantSpec(mode="msgemm", d="adaptive").scale_block == 12
    for bad in (dict(mode="fp8"), dict(storage="zip"), dict(codebook="x"),
                dict(mode="msgemm", d=5), dict(mode="msgemm", d=0),
                dict(mode="msgemm", d=3, scale_block=10)):
        with pytest.raises(ValueError):
            QuantSpec(**bad)


def test_as_spec_coercion():
    assert as_spec(MS) is MS
    cfg = _shim(mode="msgemm", d=3, scale_block=12)
    assert as_spec(cfg) == MS
    with pytest.raises(TypeError):
        as_spec("msgemm")


# ----------------------------------------------------------------- shim
def test_quantconfig_shim_warns_and_splits():
    with pytest.warns(DeprecationWarning, match="QuantConfig is deprecated"):
        cfg = linear.QuantConfig(mode="msgemm", d=3, scale_block=36,
                                 impl="pallas", interpret=True,
                                 consume_chunk=2, storage="packed_u8",
                                 codebook="learned")
    assert cfg.spec == QuantSpec(mode="msgemm", d=3, scale_block=36,
                                 storage="packed_u8", codebook="learned")
    assert cfg.policy == ExecPolicy(backend="msgemm_pallas", interpret=True,
                                    consume_chunk=2)
    # impl='jnp' pins the scan backend (the old default branch); non-
    # msgemm modes leave selection to the registry
    assert _shim(mode="msgemm").policy.backend == "msgemm_jnp"
    assert _shim(mode="int4_dequant").policy.backend is None
    assert _shim(mode="bf16").policy.backend is None


def test_quantconfig_shim_still_validates():
    for bad in (dict(impl="cuda"), dict(consume_chunk=0),
                dict(storage="zip"), dict(mode="msgemm", d=7)):
        with pytest.raises(ValueError), warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            linear.QuantConfig(**bad)


def test_shim_apply_equals_spec_apply(lin):
    """The acceptance invariant: the shim path is bit-identical to the
    explicit spec+policy path for every mode."""
    p_dense, x = lin
    for mode, policy in (("msgemm", ExecPolicy(backend="msgemm_jnp")),
                         ("int4_dequant", ExecPolicy()),
                         ("bf16", ExecPolicy())):
        cfg = _shim(mode=mode, d=3, scale_block=12)
        spec = cfg.spec
        p = linear.from_dense(p_dense["w"], spec)
        y_shim = linear.apply(p, x, cfg, in_dim=24)
        y_spec = linear.apply(p, x, spec, in_dim=24, policy=policy)
        assert np.array_equal(np.asarray(y_shim), np.asarray(y_spec)), mode


# ------------------------------------------------------ serving_config
def test_serving_config_mode_transitions():
    # spec -> spec
    s = linear.serving_config(QuantSpec(mode="bf16", d=3, scale_block=36),
                              "msgemm")
    assert isinstance(s, QuantSpec) and s.mode == "msgemm"
    assert s.scale_block == 36
    s2 = linear.serving_config(s, "int4_dequant")
    assert s2.mode == "int4_dequant" and s2.d == s.d
    # shim -> shim (type preserved; policy fields ride along)
    cfg = _shim(mode="msgemm", d=2, scale_block=16, impl="pallas")
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        c2 = linear.serving_config(cfg, "int4_dequant")
    assert isinstance(c2, linear.QuantConfig)
    assert c2.mode == "int4_dequant" and c2.impl == "pallas" and c2.d == 2


# -------------------------------------------------------------- infer_k
def test_infer_k_adaptive_error_is_actionable():
    spec = QuantSpec(mode="msgemm", d="adaptive")
    p = linear.from_dense(jnp.ones((4200, 24)), spec)
    with pytest.raises(ValueError) as ei:
        linear.apply(p, jnp.ones((3, 24)), spec)  # no in_dim
    msg = str(ei.value)
    assert "in_dim=" in msg          # the remedy
    assert "idx" in msg and "scales" in msg  # the params keys
    # and the remedy works
    y = linear.apply(p, jnp.ones((3, 24)), spec, in_dim=24)
    assert y.shape == (3, 4200)


def test_infer_k_bf16_and_fixed_d():
    assert linear._infer_k({"w": jnp.ones((8, 24))}, DENSE) == 24
    p = linear.from_dense(jnp.ones((8, 24)), MS)
    assert linear._infer_k(p, MS) == 24
    pu = linear.from_dense(jnp.ones((8, 24)),
                           dataclasses.replace(MS, storage="packed_u8"))
    assert linear._infer_k(pu, dataclasses.replace(MS, storage="packed_u8")) \
        == 24


# ------------------------------------------------------------- registry
def test_registry_backends_and_selection():
    names = dispatch.backend_names()
    for expected in ("dense", "msgemm_jnp", "msgemm_pallas", "int4_jnp",
                     "int4_pallas"):
        assert expected in names
    assert dispatch.select_backend(DENSE, 0, "cpu").name == "dense"
    assert dispatch.select_backend(MS, 3, "cpu").name == "msgemm_jnp"
    assert dispatch.select_backend(MS, 3, "tpu").name == "msgemm_pallas"
    i4 = QuantSpec(mode="int4_dequant", d=3, scale_block=12)
    assert dispatch.select_backend(i4, 3, "cpu").name == "int4_jnp"
    # capability: int4_pallas dequantizes the uniform grid only
    i4cb = dataclasses.replace(i4, codebook="learned")
    avail = [b.name for b in dispatch.available_backends(i4cb, 3, "cpu")]
    assert "int4_pallas" not in avail and "int4_jnp" in avail


def test_register_backend_duplicate_and_priority():
    with pytest.raises(ValueError):
        dispatch.register_backend("dense", modes=("bf16",), run=lambda: None)
    try:
        dispatch.register_backend(
            "msgemm_custom", modes=("msgemm",), priority=999,
            run=lambda spec, plan, params, x, *, k, precision=None: x)
        assert dispatch.select_backend(MS, 3, "cpu").name == "msgemm_custom"
    finally:
        dispatch.unregister_backend("msgemm_custom")
    assert dispatch.select_backend(MS, 3, "cpu").name == "msgemm_jnp"


def test_forced_backend_falls_back_for_unsupported_specs():
    """A forced backend applies only to specs it can execute; other
    linears auto-select (a model-wide --backend msgemm_pallas must not
    crash the int4_dequant experts inside an MoE msgemm model)."""
    pol = ExecPolicy(backend="msgemm_pallas")
    assert dispatch.plan(MS, 16, 24, 8, policy=pol).backend \
        == "msgemm_pallas"
    i4 = QuantSpec(mode="int4_dequant", d=3, scale_block=12)
    assert dispatch.plan(i4, 16, 24, 8, policy=pol).backend == "int4_jnp"
    assert dispatch.plan(DENSE, 16, 24, 8, policy=pol).backend == "dense"


def test_explicit_plan_capability_error(lin):
    """Explicit plans bypass selection but not the capability check:
    int4_pallas cannot dequantize a learned codebook — pinning it must
    raise instead of silently using the uniform grid."""
    p_dense, x = lin
    spec = QuantSpec(mode="int4_dequant", d=3, scale_block=12,
                     storage="packed_u8", codebook="learned")
    p = linear.from_dense(p_dense["w"], spec)
    with pytest.raises(ValueError, match="cannot execute"):
        linear.apply(p, x, spec, in_dim=24,
                     plan=dispatch.ExecPlan(backend="int4_pallas",
                                            interpret=True))


# ----------------------------------------------------------------- plan
def test_plan_is_frozen_and_hashable():
    p = dispatch.plan(MS, 16, 24, 8)
    assert isinstance(hash(p), int)
    with pytest.raises(dataclasses.FrozenInstanceError):
        p.backend = "dense"
    assert p == dispatch.plan(MS, 16, 24, 8)  # deterministic


def test_plan_heuristic_matches_ops_tiles():
    pol = ExecPolicy(backend="msgemm_pallas")
    p = dispatch.plan(MS, 64, 72, 16, policy=pol)
    kc = -(-72 // 3)
    assert (p.tm, p.tj, p.tb) == ops.msgemm_tiles(64, kc, 16, 3, 12)
    pj = dispatch.plan(MS, 64, 72, 16,
                       policy=ExecPolicy(backend="msgemm_jnp",
                                         consume_chunk=4))
    assert pj.consume_chunk == 4 and pj.tm is None


def test_explicit_plan_override(lin):
    p_dense, x = lin
    p = linear.from_dense(p_dense["w"], MS)
    want = linear.apply(p, x, MS, in_dim=24)
    plan = ExecPlan(backend="msgemm_pallas", tm=16, tj=4, tb=16,
                    interpret=True, source="explicit")
    got = linear.apply(p, x, MS, in_dim=24, plan=plan)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


# ------------------------------------------------------------- autotune
def test_autotune_persists_and_reloads(tmp_path):
    cache_file = tmp_path / "c.json"
    dispatch.set_cache_path(cache_file)
    p1 = at.autotune(MS, 16, 24, 8, "msgemm_pallas", interpret=True, reps=1)
    assert p1.source == "autotuned" and cache_file.exists()
    raw = json.loads(cache_file.read_text())
    assert raw["version"] == 3 and len(raw["plans"]) == 1
    key = next(iter(raw["plans"]))
    assert "msgemm_pallas" in key and "m16|k24|b8" in key

    # interpret is runtime policy, never persisted with the tuning
    assert "interpret" not in next(iter(raw["plans"].values()))

    # a fresh in-memory cache over the same file serves from disk
    dispatch.set_cache_path(cache_file)
    before = at.num_timed_candidates
    p2 = at.autotune(MS, 16, 24, 8, "msgemm_pallas", interpret=True, reps=1)
    assert p2 == p1
    assert at.num_timed_candidates == before  # zero re-timing
    # ...and a compiled-mode (interpret=None) resolution of the same key
    # gets the tuned tiles WITHOUT the tuning run's interpret mode
    p3 = dispatch.plan(MS, 16, 24, 8,
                       policy=ExecPolicy(backend="msgemm_pallas"))
    assert (p3.tm, p3.tj, p3.tb) == (p1.tm, p1.tj, p1.tb)
    assert p3.interpret is None


def test_autotuned_plan_flows_through_plan(tmp_path):
    dispatch.set_cache_path(tmp_path / "c.json")
    pol = ExecPolicy(backend="msgemm_jnp", autotune=True)
    p = dispatch.plan(MS, 16, 24, 8, policy=pol)
    assert p.source == "autotuned"
    # second resolution is a pure cache hit, same plan
    assert dispatch.plan(MS, 16, 24, 8, policy=pol) == p


def test_autotune_candidates_include_heuristic():
    cands = at.candidate_plans(MS, 3, 64, 72, 16, "msgemm_pallas", True)
    kc = -(-72 // 3)
    tm, tj, tb = ops.msgemm_tiles(64, kc, 16, 3, 12)
    assert any((c.tm, c.tj, c.tb) == (tm, tj, tb) for c in cands)
    cpb = 12 // 3
    assert all(c.tj % cpb == 0 for c in cands)


def test_corrupt_cache_degrades_gracefully(tmp_path):
    bad = tmp_path / "bad.json"
    bad.write_text("{not json")
    c = dispatch.PlanCache(bad)
    assert len(c) == 0
    c.put("k", ExecPlan(backend="dense"))
    assert dispatch.PlanCache(bad).get("k") == ExecPlan(backend="dense")


def test_v2_cache_migrates_to_unsharded_keys(tmp_path):
    """Format migration: a v2 cache file (no mesh/shard tags — written
    before sharded planning existed) loads with its keys mapped to the
    unsharded '-' tag: single-device lookups keep their tuned plans with
    zero re-timing, and a sharded (mesh-tagged) lookup can NEVER be
    served from it."""
    d = dispatch.plan_d(MS, 16, 24)
    v2_key = (f"cpu|msgemm_pallas|msgemm|d{d}|sb{MS.scale_block}|"
              f"{MS.storage}|cb{MS.codebook}|m16|k24|b8|accfloat32")
    cache_file = tmp_path / "v2.json"
    cache_file.write_text(json.dumps({"version": 2, "plans": {
        v2_key: {"backend": "msgemm_pallas", "tm": 16, "tj": 8, "tb": 8,
                 "consume_chunk": 1, "acc_in_vmem": True,
                 "acc_dtype": "float32", "epilogue": True}}}))
    dispatch.set_cache_path(cache_file)

    # the migrated entry serves the v3 single-device key...
    v3_key = dispatch.plan_key("msgemm_pallas", MS, d, 16, 24, 8, "cpu")
    assert v3_key == v2_key + "|sh-"
    hit = dispatch.cache().get(v3_key)
    assert hit is not None and (hit.tm, hit.tj, hit.tb) == (16, 8, 8)

    # ...with zero re-timing through the autotuner front-end...
    before = at.num_timed_candidates
    p = at.autotune(MS, 16, 24, 8, "msgemm_pallas", interpret=True, reps=1)
    assert at.num_timed_candidates == before
    assert (p.tm, p.tj, p.tb) == (16, 8, 8)

    # ...and never satisfies a mesh-tagged (sharded) lookup
    sharded_key = dispatch.plan_key(
        "msgemm_pallas", MS, d, 16, 24, 8, "cpu",
        shard="data2.model4/m=model/k=-/b=data/psum")
    assert dispatch.cache().get(sharded_key) is None

    # a save after migration writes the current (v3) format
    dispatch.cache().put("x|shdata2.model4", ExecPlan(backend="dense"))
    raw = json.loads(cache_file.read_text())
    assert raw["version"] == 3
    assert set(raw["plans"]) == {v3_key, "x|shdata2.model4"}


def test_unknown_cache_version_degrades_to_empty(tmp_path):
    f = tmp_path / "v9.json"
    f.write_text(json.dumps({"version": 9, "plans": {"k": {
        "backend": "dense"}}}))
    assert len(dispatch.PlanCache(f)) == 0


def test_autotune_suppressed_inside_trace(lin):
    """plan() must never time candidates while a jax trace is active
    (omnistaging would stage the 'timed' ops into the ambient trace) —
    it falls back to the heuristic and the traced computation still
    works end to end."""
    p_dense, x = lin
    p = linear.from_dense(p_dense["w"], MS)
    pol = ExecPolicy(backend="msgemm_jnp", autotune=True)
    before = at.num_timed_candidates

    @jax.jit
    def f(p, x):
        return linear.apply(p, x, MS, in_dim=24, policy=pol)

    y = f(p, x)
    assert at.num_timed_candidates == before  # no mid-trace timing
    np.testing.assert_allclose(y, linear.apply(p, x, MS, in_dim=24),
                               rtol=2e-5, atol=2e-5)


def test_collecting_records_requests():
    with dispatch.collecting() as reqs:
        dispatch.plan(MS, 16, 24, 8)
        dispatch.plan(MS, 16, 24, 8)
    assert len(reqs) == 2
    assert reqs[0][:5] == (MS, 16, 24, 8, "msgemm_jnp")
    assert reqs[0].shard is None and reqs[0].tag == "-"  # no mesh active
    warmed = dispatch.warm(reqs)
    assert len(warmed) == 1  # deduped


# -------------------------------------------------- default policy scope
def test_using_policy_scoped(lin):
    p_dense, x = lin
    p = linear.from_dense(p_dense["w"], MS)
    with dispatch.using_policy(ExecPolicy(backend="msgemm_pallas",
                                          interpret=True)):
        assert dispatch.get_default_policy().backend == "msgemm_pallas"
        y = linear.apply(p, x, MS, in_dim=24)
    assert dispatch.get_default_policy().backend is None
    np.testing.assert_allclose(y, linear.apply(p, x, MS, in_dim=24),
                               rtol=2e-5, atol=2e-5)


# ----------------------------------------------------- backend parity
@pytest.mark.parametrize("backend", ["msgemm_jnp", "msgemm_pallas"])
def test_msgemm_backends_match_dequant(lin, backend):
    p_dense, x = lin
    p = linear.from_dense(p_dense["w"], MS)
    qt = scales.quantize_int4(p_dense["w"], 12)
    want = x @ scales.dequantize(qt).T
    got = linear.apply(p, x, MS, in_dim=24,
                       policy=ExecPolicy(backend=backend, interpret=True))
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("backend", ["int4_jnp", "int4_pallas"])
def test_int4_backends_match_dequant(lin, backend):
    p_dense, x = lin
    spec = QuantSpec(mode="int4_dequant", d=3, scale_block=12,
                     storage="packed_u8")
    p = linear.from_dense(p_dense["w"], spec)
    qt = scales.quantize_int4(p_dense["w"], 12)
    want = x @ scales.dequantize(qt).T
    got = linear.apply(p, x, spec, in_dim=24,
                       policy=ExecPolicy(backend=backend, interpret=True))
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


# -------------------------------------------------------------- engine
def _engine_tokens(params, cfg, **eng_kw):
    from repro.serving import Engine, Request

    eng = Engine(params, cfg, max_slots=2, block_size=4, prefill_chunk=4,
                 max_model_len=32, **eng_kw)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=tuple(
        int(t) for t in rng.integers(0, cfg.vocab_size, size=n)),
        max_new_tokens=5) for i, n in enumerate((5, 9))]
    res = eng.run(reqs)
    return eng, {rid: seq.generated for rid, seq in res.items()}


@pytest.fixture(scope="module")
def small_model():
    from repro.models import transformer as T
    from repro.models.config import ModelConfig
    from repro.quant import quantize_model

    cfg = ModelConfig(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                      d_ff=128, vocab_size=211, max_seq_len=64)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    spec = QuantSpec(mode="msgemm", d=3, scale_block=36)
    return quantize_model(params, cfg, spec), cfg.replace(quant=spec)


def test_engine_token_identity_across_backends(small_model):
    """Serving outputs stay token-identical whichever registered backend
    executes the quantized linears."""
    p, c = small_model
    _, base = _engine_tokens(p, c)
    _, jnp_toks = _engine_tokens(p, c, backend="msgemm_jnp")
    _, pallas_toks = _engine_tokens(p, c, backend="msgemm_pallas")
    assert base == jnp_toks == pallas_toks


def test_engine_autotune_resolves_plans_at_build(small_model, tmp_path):
    p, c = small_model
    cache_file = tmp_path / "engine_plans.json"
    eng, toks = _engine_tokens(p, c, autotune=True,
                               autotune_cache=cache_file)
    assert eng.exec_plans, "no plans resolved at build"
    assert all(pl.source == "autotuned" for pl in eng.exec_plans.values())
    assert cache_file.exists()
    # tuned plans must not change tokens
    _, base = _engine_tokens(p, c)
    assert toks == base
    # a second engine over the same cache file re-times nothing
    dispatch.set_cache_path(cache_file)
    before = at.num_timed_candidates
    eng2, toks2 = _engine_tokens(p, c, autotune=True,
                                 autotune_cache=cache_file)
    assert at.num_timed_candidates == before
    assert toks2 == toks


# ------------------------------------------------------------- epilogue
def test_epilogue_capability_predicates():
    """Pallas kernels advertise fused-epilogue support; jnp/dense paths
    fall back to the unfused tail in dispatch.execute."""
    from repro.core.epilogue import Epilogue

    ep = Epilogue(act="gelu", residual=True)
    assert registry.get_backend("msgemm_pallas").epilogue_ok(ep)
    assert registry.get_backend("int4_pallas").epilogue_ok(ep)
    assert not registry.get_backend("msgemm_jnp").epilogue_ok(ep)
    assert not registry.get_backend("dense").epilogue_ok(ep)


@pytest.mark.parametrize("backend", ["msgemm_jnp", "msgemm_pallas"])
def test_epilogue_through_linear_apply(lin, backend):
    """linear.apply(epilogue=...) equals separate elementwise ops for
    both a fusing backend (Pallas) and the unfused fallback (jnp)."""
    from repro.core.epilogue import Epilogue

    p_dense, x = lin
    p = linear.from_dense(p_dense["w"], MS)
    pol = ExecPolicy(backend=backend, interpret=True)
    plain = linear.apply(p, x, MS, in_dim=24, policy=pol)
    bias = jax.random.normal(jax.random.PRNGKey(3), (16,))
    res = jax.random.normal(jax.random.PRNGKey(4), x.shape[:-1] + (16,))
    got = linear.apply(p, x, MS, in_dim=24, policy=pol,
                       epilogue=Epilogue(act="silu", bias=True,
                                         residual=True),
                       bias=bias, residual=res)
    want = jax.nn.silu(plain + bias) + res
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_epilogue_array_without_flag_rejected(lin):
    """A bias/residual array that the epilogue does not declare would be
    silently dropped — execute rejects the mismatch instead."""
    from repro.core.epilogue import Epilogue

    p_dense, x = lin
    p = linear.from_dense(p_dense["w"], MS)
    bias = jax.random.normal(jax.random.PRNGKey(6), (16,))
    res = jax.random.normal(jax.random.PRNGKey(7), x.shape[:-1] + (16,))
    with pytest.raises(ValueError, match="bias"):
        linear.apply(p, x, MS, in_dim=24, bias=bias)
    with pytest.raises(ValueError, match="bias"):
        linear.apply(p, x, MS, in_dim=24, epilogue=Epilogue(act="relu"),
                     bias=bias)
    with pytest.raises(ValueError, match="residual"):
        linear.apply(p, x, MS, in_dim=24, residual=res)


def test_plan_epilogue_false_forces_unfused(lin):
    """ExecPlan.epilogue=False disables fusion but computes the same
    function (execute applies the tail after the kernel)."""
    from repro.core.epilogue import Epilogue

    p_dense, x = lin
    p = linear.from_dense(p_dense["w"], MS)
    ep = Epilogue(act="relu", residual=True)
    res = jax.random.normal(jax.random.PRNGKey(5), x.shape[:-1] + (16,))
    kc = -(-24 // 3)
    tm, tj, tb = ops.msgemm_tiles(16, kc, 10, 3, 12)
    fused_plan = ExecPlan(backend="msgemm_pallas", tm=tm, tj=tj, tb=tb,
                          interpret=True)
    unfused_plan = dataclasses.replace(fused_plan, epilogue=False)
    got_f = linear.apply(p, x, MS, in_dim=24, plan=fused_plan,
                         epilogue=ep, residual=res)
    got_u = linear.apply(p, x, MS, in_dim=24, plan=unfused_plan,
                         epilogue=ep, residual=res)
    np.testing.assert_allclose(got_f, got_u, rtol=2e-5, atol=2e-5)


def test_plan_acc_knobs_validation_and_cache_roundtrip(tmp_path):
    """acc_in_vmem/acc_dtype/epilogue survive the JSON cache; bad
    acc_dtype is rejected eagerly; the key separates acc dtypes."""
    with pytest.raises(ValueError):
        ExecPlan(backend="msgemm_pallas", acc_dtype="int8")
    with pytest.raises(ValueError):
        ExecPolicy(acc_dtype="int8")
    c = dispatch.PlanCache(tmp_path / "p.json")
    plan = ExecPlan(backend="msgemm_pallas", tm=16, tj=4, tb=8,
                    acc_in_vmem=False, acc_dtype="bfloat16",
                    epilogue=False)
    c.put("k", plan)
    reloaded = dispatch.PlanCache(tmp_path / "p.json").get("k")
    assert reloaded.acc_in_vmem is False
    assert reloaded.acc_dtype == "bfloat16"
    assert reloaded.epilogue is False
    k32 = dispatch.plan_key("msgemm_pallas", MS, 3, 16, 24, 8, "cpu",
                            "float32")
    kbf = dispatch.plan_key("msgemm_pallas", MS, 3, 16, 24, 8, "cpu",
                            "bfloat16")
    assert k32 != kbf


def test_autotune_candidates_cover_acc_knob():
    """The candidate grid includes the legacy-accumulation variant for
    both Pallas backends (measurement can still pick it per shape)."""
    cands = at.candidate_plans(MS, 3, 64, 258, 16, "msgemm_pallas", True)
    assert any(not c.acc_in_vmem for c in cands)
    assert any(c.acc_in_vmem for c in cands)
    spec4 = QuantSpec(mode="int4_dequant", d=3, scale_block=8,
                      storage="packed_u8")
    cands4 = at.candidate_plans(spec4, 3, 64, 128, 16, "int4_pallas", True)
    assert any(not c.acc_in_vmem for c in cands4)


def test_decode_plan_small_batch_tb():
    """Engine decode shapes plan with tb sized to the actual batch (not
    padded to 128) and taller decode m tiles."""
    pln = dispatch.plan(MS, 2048, 768, batch=4)
    assert pln.backend in ("msgemm_jnp", "msgemm_pallas")
    hp = dispatch.heuristic_plan(MS, 3, 2048, 768, 4, "msgemm_pallas",
                                 ExecPolicy())
    assert hp.tb == 8 and hp.tm == 512


def test_model_epilogue_fusion_matches_unfused(small_model):
    """End-to-end: the model stack (attention residuals, MLP activation +
    residual in linear epilogues) computes the same logits whichever
    backend runs — i.e. fused epilogues did not change model math."""
    from repro.models import transformer as T

    p, c = small_model
    toks = np.arange(12, dtype=np.int32)[None] % c.vocab_size
    with dispatch.using_policy(ExecPolicy(backend="msgemm_pallas",
                                          interpret=True)):
        lg_pallas, _ = T.forward(p, c, {"tokens": jnp.asarray(toks)},
                                 mode="eval")
    with dispatch.using_policy(ExecPolicy(backend="msgemm_jnp")):
        lg_jnp, _ = T.forward(p, c, {"tokens": jnp.asarray(toks)},
                              mode="eval")
    np.testing.assert_allclose(lg_pallas, lg_jnp, rtol=2e-3, atol=2e-3)
