"""Hypothesis property tests on system invariants beyond the core
algorithm: packing bijectivity, quantization bounds, sharding-rule
well-formedness, checkpoint round-trips, schedule monotonicity."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.checkpoint import CheckpointManager
from repro.core import packing, scales
from repro.distributed import sharding as shd
from repro.optim import schedules


class FakeMesh:
    def __init__(self, **shape):
        self.shape = shape
        self.axis_names = tuple(shape)


@settings(max_examples=40, deadline=None)
@given(m=st.integers(1, 8), k=st.integers(1, 40), d=st.integers(1, 4),
       seed=st.integers(0, 2**31 - 1))
def test_pack_indices_bijective(m, k, d, seed):
    rng = np.random.default_rng(seed)
    codes = rng.integers(0, 16, size=(m, k)).astype(np.uint8)
    idx = packing.pack_indices(jnp.asarray(codes), d)
    back = packing.unpack_indices(idx, d, k)
    assert np.array_equal(np.asarray(back), codes)
    assert int(jnp.max(idx)) < 16**d  # valid LUT rows


@settings(max_examples=25, deadline=None)
@given(m=st.integers(1, 6), k=st.integers(1, 48),
       seed=st.integers(0, 2**31 - 1))
def test_storage_packing_bijective(m, k, seed):
    rng = np.random.default_rng(seed)
    codes = rng.integers(0, 16, size=(m, k)).astype(np.uint8)
    u8 = packing.pack_storage(jnp.asarray(codes))
    assert u8.shape[1] == -(-k // 2)  # true 4-bit storage
    assert np.array_equal(
        np.asarray(packing.unpack_storage(u8, k)), codes)


@settings(max_examples=25, deadline=None)
@given(m=st.integers(1, 6), k=st.integers(2, 40),
       block=st.sampled_from([2, 4, 8, 16]), seed=st.integers(0, 2**31 - 1))
def test_quantization_error_bound(m, k, block, seed):
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.standard_normal((m, k)) * rng.uniform(0.1, 10),
                    jnp.float32)
    qt = scales.quantize_int4(w, block=block)
    err = np.asarray(jnp.abs(w - scales.dequantize(qt)))
    # per-block bound: half a quantization step of that block's scale
    wb = np.asarray(jnp.pad(w, ((0, 0), (0, qt.scales.shape[1] * block - k)))
                    ).reshape(m, -1, block)
    bound = np.abs(wb).max(-1) / 7 * 0.5 + 1e-6
    errb = np.pad(err, ((0, 0), (0, qt.scales.shape[1] * block - k))
                  ).reshape(m, -1, block).max(-1)
    assert (errb <= bound + 1e-6).all()


@settings(max_examples=60, deadline=None)
@given(
    names=st.lists(st.sampled_from(
        ["batch", "seq", "heads", "kvheads", "mlp", "vocab", "embed",
         "expert", "expert_out", "capacity", "none", "layers"]),
        min_size=1, max_size=5),
    dims=st.lists(st.sampled_from([1, 3, 16, 40, 48, 60, 128, 256, 4096]),
                  min_size=5, max_size=5),
)
def test_sharding_resolution_wellformed(names, dims):
    """For ANY logical axes and shape: no mesh axis used twice, and every
    assigned axis divides its dim."""
    mesh = FakeMesh(pod=2, data=16, model=16)
    shape = tuple(dims[: len(names)])
    for kind in (0, 1):
        spec = shd._resolve(tuple(names), shape, mesh,
                            shd.RULE_SETS["default"][kind])
        used = []
        for i, e in enumerate(spec):
            if e is None:
                continue
            axes = e if isinstance(e, tuple) else (e,)
            total = 1
            for a in axes:
                used.append(a)
                total *= mesh.shape[a]
            assert shape[i] % total == 0, (names, shape, spec)
        assert len(used) == len(set(used)), (names, shape, spec)


@settings(max_examples=15, deadline=None)
@given(st.lists(st.tuples(st.integers(1, 5), st.integers(1, 5)),
                min_size=1, max_size=4),
       st.integers(0, 2**31 - 1))
def test_checkpoint_roundtrip_arbitrary_trees(shapes, seed):
    rng = np.random.default_rng(seed)
    tree = {f"leaf{i}": jnp.asarray(rng.standard_normal(s), jnp.float32)
            for i, s in enumerate(shapes)}
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        mgr.save(1, tree)
        back = mgr.restore(1, tree)
        for k in tree:
            np.testing.assert_array_equal(back[k], tree[k])


@settings(max_examples=20, deadline=None)
@given(peak=st.floats(1e-5, 10), warm=st.integers(1, 50),
       total=st.integers(60, 500))
def test_warmup_cosine_properties(peak, warm, total):
    fn = schedules.warmup_cosine(peak, warm, total)
    assert float(fn(0)) == 0.0
    assert abs(float(fn(warm)) - peak) < peak * 1e-5 + 1e-9
    # never exceeds peak, never below final fraction after warmup
    for s in (warm, (warm + total) // 2, total):
        v = float(fn(s))
        assert v <= peak * (1 + 1e-6)
        assert v >= peak * 0.1 * (1 - 1e-6)
