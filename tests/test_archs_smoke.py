"""Per-architecture smoke tests: reduced same-family configs, one forward
and one train step on CPU, asserting output shapes and no NaNs.  The FULL
configs are exercised only via the dry-run (ShapeDtypeStruct, no alloc) —
here we verify full-config param math instead."""

import functools

import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.models import transformer as T
from repro.models.config import param_count
from repro.runtime import train as RT
from repro.optim import AdamWConfig


def _batch(cfg, key, B=2, S=16):
    ks = jax.random.split(key, 3)
    batch = {}
    if cfg.is_encdec:
        batch["frames"] = jax.random.normal(ks[0], (B, 12, cfg.d_model))
        batch["tokens"] = jax.random.randint(ks[1], (B, S), 0, cfg.vocab_size)
        batch["labels"] = jax.random.randint(ks[2], (B, S), 0, cfg.vocab_size)
    elif cfg.frontend == "image_patches":
        P = cfg.num_patches
        batch["patch_embeds"] = jax.random.normal(ks[0], (B, P, cfg.d_model))
        batch["tokens"] = jax.random.randint(ks[1], (B, S - P), 0,
                                             cfg.vocab_size)
        labels = jax.random.randint(ks[2], (B, S), 0, cfg.vocab_size)
        batch["labels"] = labels.at[:, :P].set(RT.IGNORE)
    else:
        batch["tokens"] = jax.random.randint(ks[1], (B, S), 0, cfg.vocab_size)
        batch["labels"] = jax.random.randint(ks[2], (B, S), 0, cfg.vocab_size)
    return batch


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = configs.get_smoke(arch)
    assert cfg.block_pattern == configs.get_config(arch).block_pattern, \
        "smoke config must keep the family block structure"
    key = jax.random.PRNGKey(0)
    batch = _batch(cfg, key)
    state = RT.init_state(key, cfg)
    logits, aux = T.forward(state["params"], cfg, batch)
    B = batch["tokens"].shape[0]
    S_total = batch["labels"].shape[1]
    assert logits.shape == (B, S_total, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any()), f"{arch}: NaN logits"

    tcfg = RT.TrainConfig(optimizer=AdamWConfig())
    step = jax.jit(functools.partial(RT.train_step, cfg=cfg, tcfg=tcfg))
    new_state, metrics = step(state, batch)
    assert float(metrics["loss"]) > 0 and not bool(
        jnp.isnan(metrics["loss"])), f"{arch}: bad loss"
    # params actually changed
    diff = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b)))
                        if jnp.issubdtype(a.dtype, jnp.floating) else 0.0,
                        state["params"], new_state["params"])
    assert max(jax.tree.leaves(diff)) > 0, f"{arch}: no param update"


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_full_config_matches_assignment(arch):
    """The FULL config carries the exact assigned hyperparameters."""
    cfg = configs.get_config(arch)
    expected = {
        "llama4_maverick": (48, 5120, 40, 8, 8192, 202048),
        "qwen2_moe": (24, 2048, 16, 16, 1408, 151936),
        "whisper_medium": (24, 1024, 16, 16, 4096, 51968),
        "xlstm_1b3": (48, 2048, 4, 4, 0, 50304),
        "gemma_2b": (18, 2048, 8, 1, 16384, 256000),
        "codeqwen15_7b": (32, 4096, 32, 32, 13440, 92416),
        "starcoder2_15b": (40, 6144, 48, 4, 24576, 49152),
        "gemma2_9b": (42, 3584, 16, 8, 14336, 256000),
        "jamba_v01": (32, 4096, 32, 8, 14336, 65536),
        "phi3_vision": (32, 3072, 32, 32, 8192, 32064),
        # the paper's own model (GPT-3, §5) — not in the assigned pool
        "gpt3_175b": (96, 12288, 96, 96, 49152, 50304),
    }[arch]
    got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == expected, f"{arch}: {got} != {expected}"


def test_param_counts_plausible():
    """Analytic parameter counts land near the advertised model sizes."""
    def total(arch):
        return param_count(configs.get_config(arch))["total"]

    assert 350e9 < total("llama4_maverick") < 500e9
    assert 10e9 < total("qwen2_moe") < 20e9  # 14.3B total (2.7B active)
    assert 0.25e9 < total("whisper_medium") < 1.0e9
    assert 1.0e9 < total("xlstm_1b3") < 2.6e9
    assert 1.8e9 < total("gemma_2b") < 3.4e9
    assert 5e9 < total("codeqwen15_7b") < 9e9
    assert 12e9 < total("starcoder2_15b") < 18e9
    assert 7e9 < total("gemma2_9b") < 12e9
    assert 40e9 < total("jamba_v01") < 65e9
    assert 3e9 < total("phi3_vision") < 5e9
    # MoE active-vs-total: llama4 ~17B active of ~400B total
    pc = param_count(configs.get_config("llama4_maverick"))
    assert pc["active"] < 0.12 * pc["total"]


def test_smoke_decode_consistency_dense():
    """Reduced gemma2 (alternating local/global): decode == forward."""
    import numpy as np

    cfg = configs.get_smoke("gemma2_9b")
    key = jax.random.PRNGKey(1)
    params = T.init_params(key, cfg)
    toks = jax.random.randint(key, (2, 10), 0, cfg.vocab_size)
    full, _ = T.forward(params, cfg, {"tokens": toks})
    cache = T.init_cache(cfg, 2, 16)
    lg, cache = T.prefill(params, cfg, {"tokens": toks}, cache)
    np.testing.assert_allclose(lg, full[:, -1], rtol=3e-4, atol=3e-4)
    tok = jnp.argmax(lg, -1)
    lg2, _ = T.decode_step(params, cfg, tok, cache, jnp.full((2,), 10))
    full2, _ = T.forward(params, cfg, {"tokens": jnp.concatenate(
        [toks, tok[:, None]], 1)})
    np.testing.assert_allclose(lg2, full2[:, -1], rtol=3e-4, atol=3e-4)


def test_scan_vs_unscanned_parity():
    """scan_layers=True (production) and False (debug) are numerically
    identical — the scan is purely an HLO-compactness choice."""
    import numpy as np

    cfg = configs.get_smoke("jamba_v01")  # heterogeneous pattern
    params = T.init_params(jax.random.PRNGKey(3), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(4), (2, 8), 0,
                              cfg.vocab_size)
    l1, _ = T.forward(params, cfg, {"tokens": toks})
    l2, _ = T.forward(params, cfg.replace(scan_layers=False),
                      {"tokens": toks})
    np.testing.assert_allclose(l1, l2, rtol=2e-5, atol=2e-5)


def test_mlstm_chunkwise_parallel_equals_sequential():
    """The production chunkwise-parallel mLSTM is exactly the sequential
    recurrence (stabilizers included), for any chunking and carried state."""
    import numpy as np
    from repro.models import xlstm as X

    key = jax.random.PRNGKey(7)
    B, L, H, dh = 2, 37, 3, 8
    ks = jax.random.split(key, 5)
    q = jax.random.normal(ks[0], (B, L, H, dh))
    k = jax.random.normal(ks[1], (B, L, H, dh)) * 0.5
    v = jax.random.normal(ks[2], (B, L, H, dh))
    it = jax.random.normal(ks[3], (B, L, H)) * 2
    ft = jax.nn.log_sigmoid(jax.random.normal(ks[4], (B, L, H)) + 2)
    st = (jnp.zeros((B, H, dh, dh)), jnp.zeros((B, H, dh)),
          jnp.full((B, H), -jnp.inf))
    h_seq, (C1, n1, m1) = X.mlstm_sequence(q, k, v, it, ft, st, chunk=64)
    for W in (4, 8, 37):
        h_par, (C2, n2, m2) = X.mlstm_sequence_parallel(
            q, k, v, it, ft, st, chunk=W)
        np.testing.assert_allclose(h_par, h_seq, rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(C2, C1, rtol=2e-5, atol=2e-5)
        np.testing.assert_allclose(m2, m1, rtol=2e-5, atol=2e-5)
    # carried-state continuation (mid-sequence chunk boundary)
    _, st_mid = X.mlstm_sequence(q[:, :20], k[:, :20], v[:, :20],
                                 it[:, :20], ft[:, :20], st)
    h_cont, _ = X.mlstm_sequence_parallel(q[:, 20:], k[:, 20:], v[:, 20:],
                                          it[:, 20:], ft[:, 20:], st_mid,
                                          chunk=8)
    np.testing.assert_allclose(h_cont, h_seq[:, 20:], rtol=2e-4, atol=2e-4)
