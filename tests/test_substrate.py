"""Substrate tests: data determinism, optimizer, schedules, compression,
checkpoint atomicity/restart/elastic, watchdog, driver crash recovery."""

import functools
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.data import DataConfig, SyntheticStream
from repro.distributed.watchdog import Watchdog
from repro.models.config import ModelConfig
from repro.optim import (AdamWConfig, adamw_init, adamw_update, compression,
                         schedules)
from repro.optim.adamw import global_norm
from repro.runtime import train as RT
from repro.runtime.driver import CrashInjector, DriverConfig, run

TINY = ModelConfig(num_layers=2, d_model=32, num_heads=4, num_kv_heads=2,
                   d_ff=64, vocab_size=257, max_seq_len=64)


# ----------------------------------------------------------------- data
def test_data_deterministic_random_access():
    cfg = DataConfig(vocab_size=257, seq_len=17, global_batch=4, seed=3)
    s = SyntheticStream(cfg)
    b1, b2 = s.host_batch(5), s.host_batch(5)
    assert np.array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(b1["tokens"], s.host_batch(6)["tokens"])
    # labels are next-token shifted
    full1 = s.host_batch(5)
    assert np.array_equal(b1["labels"][:, :-1], full1["tokens"][:, 1:])


def test_data_prefetch_matches_direct():
    s = SyntheticStream(DataConfig(vocab_size=97, seq_len=9, global_batch=2))
    gen = s.prefetch(start_step=3)
    step, batch = next(gen)
    assert step == 3
    assert np.array_equal(batch["tokens"], s.host_batch(3)["tokens"])
    gen.close()


def test_data_frontends():
    s = SyntheticStream(DataConfig(vocab_size=97, seq_len=9, global_batch=2,
                                   frontend="audio_frames", d_model=16,
                                   num_frames=8))
    assert s.host_batch(0)["frames"].shape == (2, 8, 16)


# ----------------------------------------------------------------- optim
def test_adamw_converges_quadratic():
    cfg = AdamWConfig(lr=schedules.constant(0.1), grad_clip=0.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = adamw_init(params, cfg)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(200):
        g = jax.grad(loss)(params)
        params, state, _ = adamw_update(g, state, params, cfg)
    assert float(loss(params)) < 1e-3


def test_adamw_scanned_update_matches_unscanned():
    """The slice-wise (memory-bounded) update path is numerically identical."""
    key = jax.random.PRNGKey(0)
    big = jax.random.normal(key, (4, 512, 512 * 17))  # > 2^24 elements
    grads = {"w": jax.random.normal(jax.random.PRNGKey(1), big.shape)}
    params = {"w": big}
    cfg = AdamWConfig()
    st = adamw_init(params, cfg)
    p1, s1, m1 = adamw_update(grads, st, params, cfg)
    # force the unscanned path by viewing as one slice
    params2 = {"w": big.reshape(1, *big.shape)}
    grads2 = {"w": grads["w"].reshape(1, *big.shape)}
    st2 = adamw_init(params2, cfg)
    p2, s2, m2 = adamw_update(grads2, st2, params2, cfg)
    np.testing.assert_allclose(p1["w"], p2["w"][0], rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(m1["grad_norm"], m2["grad_norm"], rtol=1e-5)


def test_global_norm_matches_naive():
    tree = {"a": jnp.asarray([[3.0, 4.0]]),
            "b": jnp.full((4, 300, 17000), 0.01, jnp.bfloat16)}
    naive = np.sqrt(sum(float(jnp.sum(jnp.square(x.astype(jnp.float32))))
                        for x in jax.tree.leaves(tree)))
    np.testing.assert_allclose(float(global_norm(tree)), naive, rtol=2e-2)


def test_schedules():
    fn = schedules.warmup_cosine(1.0, 10, 100, final_frac=0.1)
    assert float(fn(0)) == 0.0
    assert abs(float(fn(10)) - 1.0) < 1e-6
    assert float(fn(100)) <= 0.11
    lin = schedules.warmup_linear(2.0, 5, 50)
    assert abs(float(lin(5)) - 2.0) < 1e-6
    assert float(lin(50)) < 1e-6


# ------------------------------------------------------------ compression
def test_int8_roundtrip_error_bounded():
    x = jnp.asarray(np.random.default_rng(0).standard_normal(1000),
                    jnp.float32)
    q, s = compression.quantize_int8(x)
    err = jnp.max(jnp.abs(compression.dequantize_int8(q, s) - x))
    assert float(err) <= float(s) / 2 + 1e-7


def test_compressed_psum_shard_map():
    """int8 wire-format psum over a 2-way axis on host devices."""
    import subprocess, sys, textwrap

    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
        import jax, jax.numpy as jnp, numpy as np, functools
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.optim import compression
        mesh = jax.make_mesh((2,), ("pod",))
        x = jnp.arange(8, dtype=jnp.float32).reshape(2, 4) / 7.0
        f = shard_map(lambda s: compression.compressed_psum(s, "pod"),
                      mesh=mesh, in_specs=P("pod", None),
                      out_specs=P("pod", None))
        got = f(x)
        want = jnp.broadcast_to(x.sum(0, keepdims=True), (2, 4))
        np.testing.assert_allclose(got, want, atol=2 * float(x.max()) / 127)
        # error-feedback tree reduce
        g = {"w": x}
        f2 = shard_map(lambda s: compression.compressed_pmean_tree(s, "pod"),
                       mesh=mesh, in_specs=(P("pod", None),),
                       out_specs=(P("pod", None), P("pod", None)))
        mean, res = f2(g)
        np.testing.assert_allclose(mean["w"],
                                   jnp.broadcast_to(x.mean(0, keepdims=True),
                                                    (2, 4)),
                                   atol=2 * float(x.max()) / 127)
        print("COMPRESSION_OK")
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True,
                       env={**os.environ,
                            "PYTHONPATH": os.path.join(
                                os.path.dirname(__file__), "..", "src")})
    assert "COMPRESSION_OK" in r.stdout, r.stdout + r.stderr


# ------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip_and_gc():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2)
        tree = {"a": jnp.arange(6).reshape(2, 3),
                "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
        for step in (1, 2, 3):
            mgr.save(step, jax.tree.map(lambda x: x + step, tree))
        assert mgr.all_steps() == [2, 3]  # keep=2 GC'd step 1
        restored = mgr.restore(3, tree)
        np.testing.assert_array_equal(restored["a"], tree["a"] + 3)
        assert restored["b"]["c"].dtype == jnp.bfloat16


def test_checkpoint_atomic_no_partial_reads():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        # a stale tmp dir from a crashed save must be invisible
        os.makedirs(os.path.join(d, "step_000000007.tmp"))
        assert mgr.latest_step() is None
        mgr.save(8, {"x": jnp.zeros(3)})
        assert mgr.latest_step() == 8


def test_checkpoint_structure_mismatch_raises():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        mgr.save(1, {"x": jnp.zeros(3)})
        with pytest.raises(ValueError):
            mgr.restore(1, {"x": jnp.zeros(3), "y": jnp.zeros(2)})
        with pytest.raises(ValueError):
            mgr.restore(1, {"x": jnp.zeros(4)})


def test_checkpoint_async_save():
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, async_save=True)
        mgr.save(5, {"x": jnp.arange(10)})
        mgr.wait()
        assert mgr.latest_step() == 5


# ------------------------------------------------------------- watchdog
def test_watchdog_flags_straggler():
    import time

    wd = Watchdog(window=20, z_threshold=3.0, min_steps=3)
    flagged = []
    wd.on_straggler = lambda dt, m, s: flagged.append(dt)
    for i in range(10):
        wd.step_started()
        time.sleep(0.002)
        wd.step_finished()
    wd.step_started()
    time.sleep(0.2)  # straggler
    info = wd.step_finished()
    assert info["straggler"] and flagged


def test_watchdog_hang_timer():
    import time

    wd = Watchdog(min_steps=2, hang_factor=1.5)
    hangs = []
    wd.on_hang = lambda: hangs.append(1)
    for _ in range(4):
        wd.step_started()
        time.sleep(0.05)
        wd.step_finished()
    wd.step_started()
    time.sleep(1.1)  # exceeds the 1s timer floor -> hang fires
    wd.step_finished()
    assert wd.hang_count >= 1 and hangs


# ----------------------------------------------------- driver fault-tolerance
def _mk_driver_bits(tmp):
    tcfg = RT.TrainConfig(optimizer=AdamWConfig(lr=schedules.constant(1e-3)))
    data = SyntheticStream(DataConfig(vocab_size=TINY.vocab_size, seq_len=17,
                                      global_batch=4))
    state = RT.init_state(jax.random.PRNGKey(0), TINY, tcfg)
    step_fn = jax.jit(functools.partial(RT.train_step, cfg=TINY, tcfg=tcfg))
    dcfg = DriverConfig(total_steps=12, checkpoint_every=5,
                        checkpoint_dir=tmp, log_every=100)
    return state, step_fn, data, dcfg


def test_driver_crash_restart_resumes_exactly():
    with tempfile.TemporaryDirectory() as tmp:
        state, step_fn, data, dcfg = _mk_driver_bits(tmp)
        # run to completion once for the reference trajectory
        ref = run(state, step_fn, data, dcfg, log=lambda *a: None)
        ref_losses = {m["step"]: m["loss"] for m in ref["metrics"]}
    with tempfile.TemporaryDirectory() as tmp:
        state, step_fn, data, dcfg = _mk_driver_bits(tmp)
        crash = CrashInjector(at_step=7)
        with pytest.raises(RuntimeError):
            run(state, step_fn, data, dcfg, crash=crash, log=lambda *a: None)
        # restart: resumes from the step-5 checkpoint, replays 6/7 exactly
        res = run(state, step_fn, data, dcfg, crash=crash,
                  log=lambda *a: None)
        assert res["resumed_at"] == 5
        got = {m["step"]: m["loss"] for m in res["metrics"]}
        for step in (6, 8, 12):
            np.testing.assert_allclose(got[step], ref_losses[step],
                                       rtol=1e-5, atol=1e-6)


def test_driver_preemption_saves_and_stops():
    with tempfile.TemporaryDirectory() as tmp:
        state, step_fn, data, dcfg = _mk_driver_bits(tmp)
        stop = [False]

        calls = []

        def log(msg):
            calls.append(msg)
            if len([c for c in calls if "step" in c]) >= 1:
                stop[0] = True  # request preemption after first log

        res = run(state, step_fn, data, dcfg, stop_flag=stop, log=log)
        assert res["preempted"]
        mgr = CheckpointManager(tmp)
        assert mgr.latest_step() is not None
