"""Quantized paged KV cache (repro.kvq): quantize/dequantize round
trips, pack/unpack bit-exactness, Pallas-vs-jnp paged-attention parity,
engine token identity at kv_bits=8, kv_bits=4 quality tolerance,
codebook checkpoint round-trip, the kv_blocks int32/double-free fixes,
and sharded serving with a quantized pool."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import kvq
from repro.kvq import attention as kvq_attn
from repro.kvq.pool import init_kv_pool
from repro.kvq.quantize import (kv_dequantize, kv_quantize, pack_codes,
                                unpack_codes)
from repro.kvq.spec import KVQuantSpec
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.serving import Engine, Request
from repro.serving.kv_blocks import BlockPool, view_slots, write_slots

CFG = ModelConfig(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                  d_ff=128, vocab_size=211, max_seq_len=128)

HAVE8 = jax.device_count() >= 8
needs_mesh = pytest.mark.skipif(
    not HAVE8, reason="needs >= 8 host devices "
    "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")


@pytest.fixture(scope="module")
def params():
    return T.init_params(jax.random.PRNGKey(0), CFG)


def _codebook(seed=0):
    rng = np.random.default_rng(seed)
    return tuple([0.0] + sorted(rng.normal(size=15).tolist()))


# ------------------------------------------------------------ spec
def test_spec_validation():
    assert KVQuantSpec(8).qmax == 127
    assert KVQuantSpec(4).qmax == 7
    assert KVQuantSpec(4).packed_dim(32) == 16
    assert KVQuantSpec(4).packed_dim(33) == 17  # odd head dims pad
    assert KVQuantSpec(8).packed_dim(32) == 32
    assert KVQuantSpec(4, codebook=_codebook()).codebook_kind == "learned"
    with pytest.raises(ValueError):
        KVQuantSpec(16)  # full precision is kv_quant=None, not a spec
    with pytest.raises(ValueError):
        KVQuantSpec(8, codebook=_codebook())  # codebooks are 4-bit only
    with pytest.raises(ValueError):
        KVQuantSpec(4, codebook=(0.0,) * 15)  # wrong length
    with pytest.raises(ValueError):
        KVQuantSpec(4, codebook=(0.5,) + (0.0,) * 15)  # entry 0 pinned


def test_spec_is_hashable_and_jit_static():
    # the spec rides ModelConfig into jit closures — must hash
    a = KVQuantSpec(4, codebook=_codebook())
    b = KVQuantSpec(4, codebook=_codebook())
    assert hash(a) == hash(b) and a == b
    assert a.with_codebook(np.asarray(a.codebook)).codebook == a.codebook


# ------------------------------------------------- round trip / packing
@pytest.mark.parametrize("bits", [8, 4])
def test_pack_unpack_bit_exact(bits):
    rng = np.random.default_rng(0)
    codes = rng.integers(0, 16 if bits == 4 else 256,
                         size=(3, 5, 2, 6)).astype(np.uint8)
    packed = pack_codes(jnp.asarray(codes), bits)
    assert packed.dtype == jnp.uint8
    back = unpack_codes(packed, bits, codes.shape[-1])
    np.testing.assert_array_equal(np.asarray(back), codes)


@pytest.mark.parametrize("bits", [8, 4])
def test_round_trip_exact_on_representable(bits):
    """Inputs of the form grid_value * 2^-k survive quantize->dequantize
    bit-exactly (power-of-two scales avoid float rounding in amax/qmax)."""
    spec = KVQuantSpec(bits)
    rng = np.random.default_rng(1)
    g = rng.integers(-spec.qmax, spec.qmax + 1, size=(4, 6, 2, 8))
    g[..., 0] = spec.qmax  # pin every row's amax so scale = 0.5 exactly
    x = jnp.asarray(g * 0.5, jnp.float32)
    codes, scales = kv_quantize(x, spec)
    back = kv_dequantize(codes, scales, spec, x.shape[-1])
    np.testing.assert_array_equal(np.asarray(back), np.asarray(x))


def test_round_trip_error_bounded():
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(4, 6, 2, 8)), jnp.float32)
    for spec in (KVQuantSpec(8), KVQuantSpec(4)):
        codes, scales = kv_quantize(x, spec)
        back = kv_dequantize(codes, scales, spec, 8)
        # half a grid step at the largest per-row scale bounds the error
        bound = 0.5 * float(jnp.max(scales)) + 1e-6
        assert float(jnp.max(jnp.abs(back - x))) <= bound


def test_codebook_dequant_matches_table():
    # max-abs entry is exactly qmax=7, so a row containing it quantizes
    # with scale == s exactly and every on-codebook value round-trips
    # bit-exactly through argmin assignment
    cb = (0.0, 7.0, -7.0, 1.0, -1.0, 2.0, -2.0, 3.0, -3.0, 4.0, -4.0,
          5.0, -5.0, 6.0, -6.0, 0.5)
    spec = KVQuantSpec(4, codebook=cb)
    s = 0.5
    idx = np.array([[1, 5, 9, 0], [2, 15, 7, 1]])  # each row holds +/-7
    vals = np.asarray(cb)[idx] * s
    x = jnp.asarray(vals[None], jnp.float32)  # (1, 2, 4)
    codes, scales = kv_quantize(x, spec)
    np.testing.assert_array_equal(np.asarray(scales), s)
    back = kv_dequantize(codes, scales, spec, 4)
    np.testing.assert_array_equal(np.asarray(back), vals[None])


def test_zero_rows_round_trip():
    for spec in (KVQuantSpec(8), KVQuantSpec(4),
                 KVQuantSpec(4, codebook=_codebook())):
        x = jnp.zeros((2, 3, 4), jnp.float32)
        codes, scales = kv_quantize(x, spec)
        assert np.all(np.asarray(scales) == 1.0)  # all-zero rows: scale 1
        back = kv_dequantize(codes, scales, spec, 4)
        assert np.all(np.asarray(back) == 0.0)


# ------------------------------------------------------- pool / capacity
def test_pool_layout_and_bytes():
    spec = KVQuantSpec(4)
    pool = init_kv_pool(spec, num_blocks=5, block_size=8, num_kv_heads=2,
                        head_dim=16)
    assert pool["k"].shape == (5, 8, 2, 8) and pool["k"].dtype == jnp.uint8
    assert pool["k_scale"].shape == (5, 8, 2)
    assert pool["k_scale"].dtype == jnp.float32
    full = kvq.bytes_per_token(CFG, None)
    kv8 = kvq.bytes_per_token(CFG, KVQuantSpec(8))
    kv4 = kvq.bytes_per_token(CFG, KVQuantSpec(4))
    assert full > kv8 > kv4
    assert full / kv4 >= 2.0  # the capacity headline must be reachable
    # blocks_for_bytes: same budget buys proportionally more blocks
    budget = 20 * 8 * full
    assert kvq.blocks_for_bytes(CFG, budget, 8, KVQuantSpec(4)) \
        >= 2 * kvq.blocks_for_bytes(CFG, budget, 8, None)
    assert kvq.blocks_for_bytes(CFG, 1, 8, None) == 2  # floor: never < 2


# ------------------------------------------------ kernel parity (pallas)
@pytest.mark.parametrize("bits,codebook", [(8, False), (4, False),
                                           (4, True)])
@pytest.mark.parametrize("softcap,window", [(0.0, 0), (5.0, 0), (0.0, 7)])
def test_pallas_matches_jnp_reference(bits, codebook, softcap, window):
    """The gate CI's kernel-parity step runs: the in-VMEM-dequant Pallas
    kernel (interpret mode off-TPU) against the jnp gather+dequant
    reference, elementwise."""
    spec = KVQuantSpec(bits, codebook=_codebook(4) if codebook else None)
    B, C, H, hk, dh, bs, nseq = 2, 4, 4, 2, 16, 8, 3
    nb = 1 + B * nseq
    rng = np.random.default_rng(5)

    class Cfg:
        num_heads, num_kv_heads, head_dim = H, hk, dh
        attn_logit_softcap = softcap

    kc, ks = kv_quantize(jnp.asarray(rng.normal(size=(nb, bs, hk, dh)),
                                     jnp.float32), spec)
    vc, vs = kv_quantize(jnp.asarray(rng.normal(size=(nb, bs, hk, dh)),
                                     jnp.float32), spec)
    pool = {"k": kc, "k_scale": ks, "v": vc, "v_scale": vs}
    q = jnp.asarray(rng.normal(size=(B, C, H, dh)), jnp.float32)
    blocks = np.arange(1, nb).reshape(B, nseq)
    vslots = jnp.asarray(
        (blocks[:, :, None] * bs + np.arange(bs)).reshape(B, -1), jnp.int32)
    positions = jnp.asarray(rng.integers(0, nseq * bs, size=(B, C)),
                            jnp.int32)
    ref = kvq_attn.run_jnp(spec, Cfg, q, pool, vslots, positions,
                           window=window)
    got = kvq_attn.run_pallas(spec, Cfg, q, pool, vslots, positions,
                              window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_backend_selection():
    # auto-selection off-TPU prefers the jnp reference (50 > 40)
    assert kvq_attn.select(KVQuantSpec(8)) == "paged_attn_jnp"
    assert kvq_attn.select(
        KVQuantSpec(8, backend="paged_attn_pallas")) == "paged_attn_pallas"
    with pytest.raises(ValueError):
        kvq_attn.select(KVQuantSpec(8, backend="msgemm_pallas"))
    # the acceptance counter: pallas materializes NO dequantized HBM copy
    assert kvq_attn.dequant_hbm_bytes(
        KVQuantSpec(8, backend="paged_attn_pallas"), CFG, 4, 64) == 0
    assert kvq_attn.dequant_hbm_bytes(
        KVQuantSpec(8, backend="paged_attn_jnp"), CFG, 4, 64) > 0


# -------------------------------------------------------- engine parity
def _generate(params, cfg, prompts, new=6, **eng_kw):
    eng_kw.setdefault("max_slots", 3)
    eng_kw.setdefault("block_size", 4)
    eng_kw.setdefault("prefill_chunk", 4)
    eng_kw.setdefault("max_model_len", 64)
    eng = Engine(params, cfg, **eng_kw)
    reqs = [Request(rid=i, prompt=p, max_new_tokens=new)
            for i, p in enumerate(prompts)]
    res = eng.run(reqs)
    return {rid: tuple(s.generated) for rid, s in res.items()}, eng


def _prompts(lens, seed=0):
    rng = np.random.default_rng(seed)
    return [tuple(int(t) for t in rng.integers(0, CFG.vocab_size, size=L))
            for L in lens]


def test_engine_kv8_token_identical(params):
    """Acceptance: kv_bits=8 serving is token-identical to the bf16-KV
    engine on the test model (int8 KV error never flips a greedy argmax
    at these scales)."""
    prompts = _prompts((5, 11, 3, 8), seed=1)
    base, _ = _generate(params, CFG, prompts)
    q8, eng = _generate(params, CFG, prompts, kv_quant=KVQuantSpec(8))
    assert base == q8
    assert eng.cfg.kv_quant == KVQuantSpec(8)
    assert eng.metrics()["max_resident_seqs"] >= 1


def test_engine_pallas_vs_jnp_token_identical(params):
    prompts = _prompts((7, 12), seed=2)
    for bits in (8, 4):
        jn, _ = _generate(params, CFG, prompts,
                          kv_quant=KVQuantSpec(bits,
                                               backend="paged_attn_jnp"))
        pl, _ = _generate(params, CFG, prompts,
                          kv_quant=KVQuantSpec(bits,
                                               backend="paged_attn_pallas"))
        assert jn == pl, f"bits={bits}"


def test_engine_kv4_quality_tolerance(params):
    """kv_bits=4 (learned codebook) through the paged path stays within
    the documented quality budget vs the dense bf16-KV forward: tight
    logit MSE and high top-1 agreement on teacher-forced positions."""
    from repro.calib.quality import evaluate_kv

    rng = np.random.default_rng(3)
    tokens = rng.integers(0, CFG.vocab_size, size=(2, 24))
    data = [{"tokens": tokens, "labels": tokens}]
    cb = kvq.fit_kv_codebook(params, CFG, [{"tokens": tokens}])
    m = evaluate_kv(params, CFG, KVQuantSpec(4, codebook=cb), data,
                    steps=1)
    # random-init logits are nearly flat, so argmax flips cheaply — the
    # bench model (trained) holds much tighter; measured here: top1
    # 0.875, logit_mse 5.6e-3
    assert m["top1_agree"] >= 0.8
    assert m["logit_mse"] <= 2e-2
    # the harness itself is clean: full-precision paged == dense
    m16 = evaluate_kv(params, CFG, None, data, steps=1)
    assert m16["logit_mse"] <= 1e-9 and m16["top1_agree"] == 1.0
    # and kv4 stays within the documented perplexity budget (README:
    # KV4_PPL_BUDGET = 1.25) even on this untrained model
    assert m["perplexity"] <= 1.25 * m16["perplexity"]


def test_kv_pool_bytes_budget(params):
    """kv_pool_bytes sizes the pool by real storage cost: the same
    budget admits >= 2x the blocks at kv4 vs full precision."""
    budget = 16 * 4 * kvq.bytes_per_token(CFG, None)
    _, e16 = _generate(params, CFG, _prompts((5,)), kv_pool_bytes=budget)
    _, e4 = _generate(params, CFG, _prompts((5,)),
                      kv_quant=KVQuantSpec(4), kv_pool_bytes=budget)
    assert e4.pool.num_blocks >= 2 * e16.pool.num_blocks


# -------------------------------------------- codebook checkpoint cycle
def test_codebook_checkpoint_round_trip(params, tmp_path):
    """A fitted KV codebook survives a CheckpointManager save/restore
    and reproduces identical serving tokens."""
    from repro.checkpoint import CheckpointManager

    cb = kvq.fit_kv_codebook(params, CFG)
    spec = KVQuantSpec(4, codebook=cb)
    prompts = _prompts((6, 9), seed=4)
    before, _ = _generate(params, CFG, prompts, kv_quant=spec)

    mgr = CheckpointManager(str(tmp_path / "ckpt"))
    tree = {"kv_codebook": np.asarray(cb, np.float32)}
    mgr.save(0, tree)
    restored = mgr.restore(0, jax.tree.map(np.zeros_like, tree))
    spec2 = KVQuantSpec(4).with_codebook(
        np.asarray(restored["kv_codebook"]))
    assert spec2 == spec
    after, _ = _generate(params, CFG, prompts, kv_quant=spec2)
    assert before == after


# ------------------------------------------------- kv_blocks regressions
def test_write_slots_int32_throughout():
    ws = write_slots([3, 1, 7], start=5, count=6, pad_to=8, block_size=4)
    assert ws.dtype == np.int32
    # position 5 lives in block index 1 (=block id 1), offset 1
    assert ws[0] == 1 * 4 + 1
    vs = view_slots([3, 1], 4, 4)
    assert vs.dtype == np.int32


def test_block_pool_double_free_raises():
    pool = BlockPool(num_blocks=6, block_size=4)
    blocks = pool.alloc(3)
    pool.free(blocks[:2])
    with pytest.raises(ValueError, match="double free"):
        pool.free([blocks[0]])
    with pytest.raises(ValueError, match="scratch"):
        pool.free([0])
    with pytest.raises(ValueError, match="outside pool"):
        pool.free([99])
    # a legal free still works after the failed ones
    pool.free([blocks[2]])
    assert pool.free_blocks == pool.capacity


def test_block_pool_alloc_free_cycle_consistent():
    pool = BlockPool(num_blocks=8, block_size=4)
    a = pool.alloc(4)
    b = pool.alloc(3)
    assert pool.alloc(1) is None  # exhausted (7 allocatable)
    pool.free(a)
    c = pool.alloc(4)
    assert set(c) == set(a)  # recycled, no duplicates vs b
    assert not set(c) & set(b)


# ------------------------------------------------------ sharded serving
@needs_mesh
def test_sharded_engine_quantized_pool(params):
    """8-host-device mesh serving with a quantized pool: tokens match
    the single-device quantized engine (the jnp backend lowers through
    GSPMD with the constrain'd pool layouts)."""
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    prompts = _prompts((5, 9), seed=6)
    base, _ = _generate(params, CFG, prompts, kv_quant=KVQuantSpec(8),
                        max_slots=4)
    sharded, eng = _generate(params, CFG, prompts,
                             kv_quant=KVQuantSpec(8), max_slots=4,
                             mesh=mesh)
    assert base == sharded
    assert eng.kv  # quantized pool leaves exist and are device-placed
    leaf = jax.tree.leaves(eng.kv)[0]
    assert len(leaf.sharding.device_set) == 8


def test_sharded_quantized_pool_subprocess(params):
    """Run the mesh test under forced host devices when this process
    couldn't (mirrors CI's dedicated sharded step)."""
    if HAVE8:
        pytest.skip("in-process mesh test already ran")
    import subprocess
    import sys

    src = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    env = {**os.environ, "PYTHONPATH": src,
           "XLA_FLAGS": "--xla_force_host_platform_device_count=8"}
    proc = subprocess.run(
        [sys.executable, "-m", "pytest", "-q", "-p", "no:cacheprovider",
         os.path.abspath(__file__),
         "-k", "test_sharded_engine_quantized_pool"],
        env=env, capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, \
        proc.stdout[-4000:] + proc.stderr[-2000:]
