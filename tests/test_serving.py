"""Continuous-batching engine tests: paged-vs-dense KV cache parity
(token-identical greedy outputs across bf16 / int4_dequant / msgemm),
chunked prefill, preemption recovery, scheduler admission order, block
accounting (no leaks, exhaustion -> eviction)."""

import jax
import numpy as np
import pytest

from repro.core.linear import QuantConfig
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.quant import quantize_model
from repro.runtime import serve as SV
from repro.serving import BlockPool, Engine, Phase, Request, Scheduler
from repro.serving.request import Sequence

CFG = ModelConfig(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                  d_ff=128, vocab_size=211, max_seq_len=128)


@pytest.fixture(scope="module")
def params():
    return T.init_params(jax.random.PRNGKey(0), CFG)


def _prompts(lens, seed=0, vocab=CFG.vocab_size):
    rng = np.random.default_rng(seed)
    return [tuple(int(t) for t in rng.integers(0, vocab, size=L))
            for L in lens]


def _static_ref(params, cfg, prompt, new):
    toks = np.array([prompt], np.int32)
    out = SV.generate(params, cfg, {"tokens": toks}, max_new_tokens=new)
    return [int(t) for t in np.asarray(out)[0]]


def _run(params, cfg, prompts, new, **eng_kw):
    eng_kw.setdefault("max_slots", 3)
    eng_kw.setdefault("block_size", 4)
    eng_kw.setdefault("prefill_chunk", 4)
    eng_kw.setdefault("max_model_len", 64)
    eng = Engine(params, cfg, **eng_kw)
    reqs = [Request(rid=i, prompt=p, max_new_tokens=new)
            for i, p in enumerate(prompts)]
    return eng, eng.run(reqs)


# ------------------------------------------------------- paged-vs-dense
@pytest.mark.parametrize("mode", ["bf16", "int4_dequant", "msgemm"])
def test_paged_matches_static_generate(params, mode):
    """The acceptance invariant: identical greedy tokens for the same
    prompts from the paged continuous engine and the static path, in
    every quantized-linear execution mode."""
    if mode == "bf16":
        p, c = params, CFG
    else:
        qc = QuantConfig(mode=mode, d=3, scale_block=36)
        p, c = quantize_model(params, CFG, qc), CFG.replace(quant=qc)
    prompts = _prompts((5, 11, 3, 8), seed=1)
    _, res = _run(p, c, prompts, new=6)
    for i, prompt in enumerate(prompts):
        assert res[i].generated == _static_ref(p, c, prompt, 6), f"req {i}"


def test_chunked_prefill_is_exact(params):
    """A prompt much longer than the prefill chunk still yields identical
    tokens (chunk boundaries change nothing)."""
    prompts = _prompts((23,), seed=2)
    _, res = _run(params, CFG, prompts, new=5, prefill_chunk=4)
    assert res[0].generated == _static_ref(params, CFG, prompts[0], 5)


def test_sliding_window_parity():
    cfg = CFG.replace(block_pattern=("local",), sliding_window=5)
    p = T.init_params(jax.random.PRNGKey(3), cfg)
    prompts = _prompts((9,), seed=3)
    _, res = _run(p, cfg, prompts, new=6)
    assert res[0].generated == _static_ref(p, cfg, prompts[0], 6)


# ------------------------------------------------------------ preemption
def test_block_exhaustion_preempts_and_recovers(params):
    """Pool too small for both sequences' full length: the later one is
    evicted mid-decode, re-prefilled, and still finishes token-identical;
    every block returns to the pool."""
    prompts = _prompts((6, 6), seed=4)
    new = 10  # final length 16 -> 4 blocks each; pool only has 6 usable
    eng, res = _run(params, CFG, prompts, new=new, max_slots=2,
                    prefill_chunk=8, max_model_len=16, num_blocks=7)
    assert eng.scheduler.num_preemptions > 0
    assert any(res[i].preemptions > 0 for i in range(2))
    for i, prompt in enumerate(prompts):
        assert res[i].generated == _static_ref(params, CFG, prompt, new)
    assert eng.pool.free_blocks == eng.pool.capacity  # no leaks


def test_no_block_leaks_normal_completion(params):
    prompts = _prompts((5, 9, 2, 7, 4), seed=5)
    eng, res = _run(params, CFG, prompts, new=4, max_slots=2)
    assert len(res) == 5
    assert eng.pool.free_blocks == eng.pool.capacity
    assert not eng.scheduler.has_work()


def test_oversized_request_rejected(params):
    eng = Engine(params, CFG, max_slots=1, block_size=4, max_model_len=16)
    with pytest.raises(ValueError):
        eng.submit(Request(rid=0, prompt=tuple(range(14)),
                           max_new_tokens=8))  # 22 > max_model_len


# ------------------------------------------------------------- scheduler
def test_fcfs_admission_order(params):
    """With one slot, completion order == submission order even when the
    later requests are much shorter."""
    prompts = _prompts((12, 2, 2), seed=6)
    finished = []
    eng = Engine(params, CFG, max_slots=1, block_size=4, prefill_chunk=4,
                 max_model_len=32)
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new_tokens=3))
    while eng.scheduler.has_work():
        finished += [s.req.rid for s in eng.step()]
    assert finished == [0, 1, 2]


def _seq(rid, plen, new=4):
    return Sequence(req=Request(rid=rid, prompt=tuple(range(1, plen + 1)),
                                max_new_tokens=new))


def test_scheduler_admits_fcfs_within_blocks():
    """Unit-level: admission is strict FCFS; the head blocks the queue
    when the pool cannot cover its prefill."""
    pool = BlockPool(num_blocks=5, block_size=4)  # 4 usable blocks
    sched = Scheduler(pool, max_slots=4, prefill_chunk=8)
    big, small = _seq(0, 12), _seq(1, 4)  # 3 blocks vs 1 block
    sched.add(big)
    sched.add(small)
    sched._admit()
    assert big.phase is Phase.PREFILL and small.phase is Phase.PREFILL
    third = _seq(2, 8)  # needs 2, none free -> waits; nobody skips it
    fourth = _seq(3, 4)
    sched.add(third)
    sched.add(fourth)
    kind, seq, start, end = sched.schedule()
    assert kind == "prefill" and seq is big and (start, end) == (0, 8)
    assert third.phase is Phase.WAITING and fourth.phase is Phase.WAITING
    sched.finish(big)  # frees 3 blocks -> third (then fourth) admit in order
    sched._admit()
    assert third.phase is Phase.PREFILL and fourth.phase is Phase.PREFILL
    assert third.admit_seqno < fourth.admit_seqno


def test_scheduler_grow_preempts_latest():
    pool = BlockPool(num_blocks=5, block_size=4)
    sched = Scheduler(pool, max_slots=2, prefill_chunk=8)
    a, b = _seq(0, 8, new=9), _seq(1, 8, new=9)
    sched.add(a)
    sched.add(b)
    sched._admit()
    a.phase = b.phase = Phase.DECODE
    a.generated = [7]  # 9 tokens -> needs a 3rd block; pool is empty
    assert sched.grow_for_decode(a) is True
    assert b.phase is Phase.WAITING and b.blocks == []  # latest evicted
    assert b.prefill_pos == 0 and sched.num_preemptions == 1
    assert sched.waiting[0] is b  # re-queued at the front
    assert len(a.blocks) == 3


def test_scheduler_self_preemption():
    """When the newest sequence itself needs the block, it is its own
    victim and its decode is skipped."""
    pool = BlockPool(num_blocks=4, block_size=4)
    sched = Scheduler(pool, max_slots=2, prefill_chunk=8)
    a, b = _seq(0, 8, new=9), _seq(1, 4, new=9)
    sched.add(a)
    sched.add(b)
    sched._admit()
    a.phase = b.phase = Phase.DECODE
    b.generated = [1, 2, 3, 4, 5]  # 9 tokens -> needs 3rd block
    assert sched.grow_for_decode(b) is False
    assert b.phase is Phase.WAITING and a.phase is Phase.DECODE
    assert pool.free_blocks == 1  # b's blocks returned


# --------------------------------------------------------------- streams
def test_streaming_and_metrics(params):
    events = []
    prompts = _prompts((4, 6), seed=7)
    eng = Engine(params, CFG, max_slots=2, block_size=4, prefill_chunk=8,
                 max_model_len=32,
                 on_token=lambda rid, tok, text: events.append((rid, tok)))
    res = eng.run([Request(rid=i, prompt=p, max_new_tokens=3)
                   for i, p in enumerate(prompts)])
    assert sorted(events) == sorted(
        (i, t) for i in res for t in res[i].generated)
    s = eng.summary()
    assert s["requests"] == 2 and s["generated_tokens"] == 6
    assert s["tok_per_s"] > 0 and s["latency_p95_s"] >= s["latency_p50_s"]
    for i in res:
        m = res[i].metrics()
        assert 0 <= m["ttft_s"] <= m["latency_s"]


def test_temperature_sampling_diverges_and_is_deterministic(params):
    prompts = _prompts((6,), seed=8)
    outs = []
    for _ in range(2):
        eng = Engine(params, CFG, max_slots=1, block_size=4,
                     prefill_chunk=8, max_model_len=32, sample_seed=7)
        res = eng.run([Request(rid=0, prompt=prompts[0], max_new_tokens=8,
                               temperature=5.0)])
        outs.append(res[0].generated)
    assert outs[0] == outs[1]  # seeded host sampling is reproducible
    assert outs[0] != _static_ref(params, CFG, prompts[0], 8)  # not greedy
