"""Tensor-parallel serving over a device mesh (repro.dispatch.shard +
Engine(mesh=)).

The mesh tests need >= 8 host devices and skip otherwise; CI runs them
in a dedicated step with ``XLA_FLAGS=--xla_force_host_platform_device_
count=8``, and ``test_sharded_suite_subprocess`` re-runs the whole
in-process set under that flag from the plain tier-1 session so the
sharded path is exercised on every ``pytest -q``.

Acceptance invariants covered here:

* Engine(mesh=...) continuous-batching output is token-identical to the
  single-device engine for the same requests — msgemm + int4 + MoE
  specs, the forced Pallas backend, reduce-scatter collectives, and
  mid-stream preemption;
* autotuner cache round-trips keyed by mesh shape with zero re-timing
  on reload;
* pipelined collectives (ISSUE 10): the chunked-contraction + ring
  path is token-identical across psum/reduce_scatter, odd chunk counts
  clamp (never reject) with the fallback counter bumped, the ring
  reductions match the XLA natives bit-for-bit, and shard_pipeline=0
  tunes the variant grid once into the additive shard_variants table.
"""

import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro import dispatch
from repro.core.spec import QuantSpec
from repro.dispatch import autotune as at
from repro.dispatch.shard import ShardSpec, shard_spec_for
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.quant import quantize_model
from repro.serving import Engine, Request

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
HAVE8 = jax.device_count() >= 8
needs_mesh = pytest.mark.skipif(
    not HAVE8, reason="needs >= 8 host devices "
    "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")

# dims chosen so every linear shards on model=4 with d=2/sb=8 quant:
# wq m=4*8=32, wk/wv m=2*8=16, wo k=32 (k_local 8 | sb), up m=64,
# down k=64 (k_local 16 | sb), lm_head m=vocab=64
CFG = ModelConfig(num_layers=2, d_model=32, num_heads=4, num_kv_heads=2,
                  d_ff=64, vocab_size=64, max_seq_len=64)
MOE_CFG = ModelConfig(num_layers=2, d_model=32, num_heads=4, num_kv_heads=2,
                      d_ff=64, vocab_size=64, max_seq_len=64,
                      block_pattern=("attn", "moe"), num_experts=4,
                      num_experts_per_tok=2)
SPEC = QuantSpec(mode="msgemm", d=2, scale_block=8)


def _mesh():
    return jax.make_mesh((2, 4), ("data", "model"))


def _prompts(lens, seed=0, vocab=64):
    rng = np.random.default_rng(seed)
    return [tuple(int(t) for t in rng.integers(0, vocab, size=L))
            for L in lens]


def _model(cfg=CFG, mode="msgemm", seed=0):
    params = T.init_params(jax.random.PRNGKey(seed), cfg)
    if mode == "bf16":
        return params, cfg
    spec = QuantSpec(mode=mode, d=2, scale_block=8)
    return quantize_model(params, cfg, spec), cfg.replace(quant=spec)


def _run(params, cfg, prompts, new=5, mesh=None, **kw):
    kw.setdefault("max_slots", 4)
    kw.setdefault("block_size", 4)
    kw.setdefault("prefill_chunk", 4)
    kw.setdefault("max_model_len", 32)
    eng = Engine(params, cfg, mesh=mesh, **kw)
    res = eng.run([Request(rid=i, prompt=p, max_new_tokens=new)
                   for i, p in enumerate(prompts)])
    return eng, {rid: seq.generated for rid, seq in res.items()}


# --------------------------------------------------- ShardSpec derivation
class FakeMesh:
    def __init__(self, **shape):
        self.shape = shape
        self.axis_names = tuple(shape)


MESH42 = FakeMesh(data=2, model=4)


def test_shard_spec_column_parallel():
    s = shard_spec_for(SPEC, ("mlp", "embed"), 64, 32, 32, MESH42,
                       lead_batch=4)
    assert (s.m, s.k, s.batch) == ("model", None, "data")
    assert s.local_mkb(64, 32, 32) == (16, 32, 16)
    assert "model4" in s.tag() and "m=model" in s.tag()


def test_shard_spec_row_parallel_and_alignment():
    # down-proj: k=mlp takes model; packed storage must split cleanly
    s = shard_spec_for(SPEC, ("embed", "mlp"), 32, 64, 32, MESH42,
                       lead_batch=4)
    assert (s.m, s.k) == (None, "model") and s.collective == "psum"
    # k_local = 9 violates scale_block alignment -> no k sharding, and
    # batch=3 rows don't divide data=2 either -> fully GSPMD (None)
    assert shard_spec_for(SPEC, ("embed", "mlp"), 32, 36, 3, MESH42,
                          lead_batch=3) is None


def test_shard_spec_reduce_scatter_fallback():
    s = shard_spec_for(SPEC, ("embed", "mlp"), 32, 64, 32, MESH42,
                       lead_batch=4, collective="reduce_scatter")
    assert s.collective == "reduce_scatter"
    # m=30 cannot scatter over model=4 -> psum fallback
    s = shard_spec_for(SPEC, ("embed", "mlp"), 30, 64, 32, MESH42,
                       lead_batch=4, collective="reduce_scatter")
    assert s.collective == "psum"


def test_shard_spec_respects_rule_set():
    """The derivation honors the selected rule set: serve_tp's batch
    rule is empty, so activations never batch-shard even when the rows
    would divide — the shard_map specs must agree with what constrain()
    does under the same rules."""
    s = shard_spec_for(SPEC, ("mlp", "embed"), 64, 32, 32, MESH42,
                       lead_batch=4, rules="serve_tp")
    assert (s.m, s.batch) == ("model", None)
    s = shard_spec_for(SPEC, ("mlp", "embed"), 64, 32, 32, MESH42,
                       lead_batch=4, rules="serve")
    assert (s.m, s.batch) == ("model", "data")


def test_shard_spec_adaptive_d_never_shards():
    spec = QuantSpec(mode="msgemm", d="adaptive", scale_block=12)
    assert shard_spec_for(spec, ("mlp", "embed"), 64, 36, 32, MESH42,
                          lead_batch=4) is None


def test_shard_spec_validation():
    with pytest.raises(ValueError):
        ShardSpec(mesh_axes=(("model", 4),), m="model", k="model")
    with pytest.raises(ValueError):
        ShardSpec(collective="allreduce")
    with pytest.raises(ValueError):
        dispatch.ExecPolicy(shard_collective="bogus")


def test_plan_key_carries_shard_tag():
    key = dispatch.plan_key("msgemm_jnp", SPEC, 2, 16, 32, 8, "cpu",
                            shard="data2.model4/m=model/k=-/b=data/psum")
    assert key.endswith("|shdata2.model4/m=model/k=-/b=data/psum")


# ------------------------------------ pipelined collectives (derivation)
def _fallbacks(kind, **labels):
    from repro import obs

    # registry.value()'s series-kind positional shadows the 'kind'
    # label, so read through the getter (creates-at-zero when unseen)
    return obs.registry().counter(
        "dispatch_shard_collective_fallback_total",
        kind=kind, **labels).value


def test_shard_spec_pipelined_tag_additive():
    """The plan-cache key discipline: pipelining is an additive tag
    suffix — a one-shot spec keys byte-identically to pre-pipelining
    caches, and the pipelined spec only appends to that key."""
    base = shard_spec_for(SPEC, ("embed", "mlp"), 32, 64, 32, MESH42,
                          lead_batch=4)
    piped = shard_spec_for(SPEC, ("embed", "mlp"), 32, 64, 32, MESH42,
                           lead_batch=4, pipeline_chunks=2,
                           collective_impl="ring")
    assert not base.is_pipelined and "/pc" not in base.tag()
    assert piped.is_pipelined and piped.tag() == base.tag() + "/pc2.ring"
    # exec shapes: tiles are planned per chunk — k divides by the chunks
    assert base.exec_mkb(32, 64, 32) == base.local_mkb(32, 64, 32)
    lm, lk, lb = piped.local_mkb(32, 64, 32)
    assert piped.exec_mkb(32, 64, 32) == (lm, lk // 2, lb)


def test_reduce_scatter_fallback_counted():
    """Satellite: the reduce_scatter->psum downgrade (m doesn't divide
    the k axis) is no longer silent, one-shot and pipelined alike."""
    before = _fallbacks("reduce_scatter_to_psum", axis="model")
    s = shard_spec_for(SPEC, ("embed", "mlp"), 30, 64, 32, MESH42,
                       lead_batch=4, collective="reduce_scatter")
    assert s.collective == "psum"
    assert _fallbacks("reduce_scatter_to_psum", axis="model") == before + 1
    # the pipelined derivation takes the same fallback AND keeps its
    # chunked ring layout (the fallback changes the collective, not the
    # pipeline)
    s = shard_spec_for(SPEC, ("embed", "mlp"), 30, 64, 32, MESH42,
                       lead_batch=4, collective="reduce_scatter",
                       pipeline_chunks=2, collective_impl="ring")
    assert s.collective == "psum"
    assert (s.pipeline_chunks, s.collective_impl) == (2, "ring")
    assert _fallbacks("reduce_scatter_to_psum", axis="model") == before + 2


def test_pipeline_chunks_clamped_counted():
    # k_local = 64/4 = 16: 3 doesn't divide -> clamp to 2 (chunk 8 stays
    # scale_block-aligned), counted
    before = _fallbacks("pipeline_chunks_clamped", axis="model",
                        requested=3, clamped=2)
    s = shard_spec_for(SPEC, ("embed", "mlp"), 32, 64, 32, MESH42,
                       lead_batch=4, pipeline_chunks=3)
    assert s.pipeline_chunks == 2
    assert _fallbacks("pipeline_chunks_clamped", axis="model",
                      requested=3, clamped=2) == before + 1
    # k_local = 32/4 = 8: chunk 4 breaks scale_block=8 alignment -> all
    # the way back to one-shot (requested 2, clamped 1)
    s = shard_spec_for(SPEC, ("embed", "mlp"), 32, 32, 32, MESH42,
                       lead_batch=4, pipeline_chunks=2)
    assert s.pipeline_chunks == 1 and "/pc" not in s.tag()
    assert _fallbacks("pipeline_chunks_clamped", axis="model",
                      requested=2, clamped=1) >= 1


def test_pipelined_spec_validation():
    with pytest.raises(ValueError):
        ShardSpec(mesh_axes=(("model", 4),), k="model",
                  collective_impl="bogus")
    with pytest.raises(ValueError):
        ShardSpec(mesh_axes=(("model", 4),), k="model", pipeline_chunks=0)
    with pytest.raises(ValueError):  # pipelining needs a k axis
        ShardSpec(mesh_axes=(("model", 4),), m="model", pipeline_chunks=2)
    with pytest.raises(ValueError):
        dispatch.ExecPolicy(shard_impl="bogus")
    with pytest.raises(ValueError):
        dispatch.ExecPolicy(shard_pipeline=-1)


def test_plan_cache_shard_variants_roundtrip(tmp_path):
    """shard_variants is an additive v3 table: files without it load
    (and answer None), files with it round-trip."""
    path = tmp_path / "plans.json"
    c1 = at.PlanCache(path)
    assert c1.shard_variant("k") is None  # no file at all
    c1.put_shard_variant("k", {"pipeline_chunks": 2,
                               "collective_impl": "ring", "rows": []})
    c2 = at.PlanCache(path)
    assert c2.shard_variant("k")["pipeline_chunks"] == 2
    # strip the table from the file -> still loads, answers None
    import json

    doc = json.loads(path.read_text())
    doc.pop("shard_variants")
    doc.pop("crc", None)
    from repro.obs import artifacts

    artifacts.atomic_write_json(path, artifacts.stamp_crc(doc))
    c3 = at.PlanCache(path)
    assert c3.shard_variant("k") is None
    assert len(c3) == len(c2)


# ------------------------------------------------------ sharded engines
@needs_mesh
@pytest.mark.parametrize("mode", ["msgemm", "int4_dequant", "bf16"])
def test_sharded_engine_token_identity(mode):
    p, c = _model(CFG, mode)
    prompts = _prompts((5, 9, 3, 7), seed=1)
    _, base = _run(p, c, prompts)
    _, sharded = _run(p, c, prompts, mesh=_mesh())
    assert sharded == base


@needs_mesh
def test_sharded_moe_token_identity():
    p, c = _model(MOE_CFG, "msgemm", seed=2)
    prompts = _prompts((4, 8, 6), seed=2)
    _, base = _run(p, c, prompts)
    _, sharded = _run(p, c, prompts, mesh=_mesh())
    assert sharded == base


@needs_mesh
def test_sharded_pallas_backend_token_identity():
    """The fused Pallas msGeMM path inside shard_map (interpret mode on
    CPU): per-shard LUT produce + VMEM accumulation under the mesh."""
    p, c = _model(CFG, "msgemm")
    prompts = _prompts((5, 7), seed=3)
    _, base = _run(p, c, prompts, backend="msgemm_pallas")
    eng, sharded = _run(p, c, prompts, backend="msgemm_pallas",
                        mesh=_mesh())
    assert sharded == base
    assert any(pl.backend == "msgemm_pallas" and pl.shard is not None
               for pl in eng.exec_plans.values())


@needs_mesh
def test_sharded_reduce_scatter_token_identity():
    p, c = _model(CFG, "msgemm")
    prompts = _prompts((5, 9, 3), seed=4)
    _, base = _run(p, c, prompts)
    eng, sharded = _run(p, c, prompts, mesh=_mesh(),
                        shard_collective="reduce_scatter")
    assert sharded == base
    assert any(pl.shard is not None
               and pl.shard.collective == "reduce_scatter"
               for pl in eng.exec_plans.values())


@needs_mesh
def test_sharded_engine_preemption_token_identity():
    """Mid-stream preemption (pool too small for all admitted seqs) is
    host-side scheduling — the sharded step must replay evicted
    sequences to the same tokens."""
    p, c = _model(CFG, "msgemm")
    # pool too small for both sequences' final length (16 tokens = 4
    # blocks each, only 6 usable): the later one is evicted mid-decode
    # and re-prefilled — same recipe as test_serving's exhaustion test
    prompts = _prompts((6, 6), seed=5)
    kw = dict(max_slots=2, block_size=4, prefill_chunk=8, num_blocks=7,
              max_model_len=16)
    eng0, base = _run(p, c, prompts, new=10, **kw)
    eng1, sharded = _run(p, c, prompts, new=10, mesh=_mesh(), **kw)
    assert eng0.scheduler.num_preemptions > 0  # scenario really preempts
    assert eng1.scheduler.num_preemptions == eng0.scheduler.num_preemptions
    assert sharded == base


@needs_mesh
def test_sharded_plans_resolved_at_build_and_keyed_by_mesh():
    p, c = _model(CFG, "msgemm")
    eng = Engine(p, c, max_slots=4, block_size=4, prefill_chunk=4,
                 max_model_len=32, mesh=_mesh())
    assert eng.exec_plans, "mesh build must resolve plans up front"
    # every key carries the mesh tag (sharded or not) — a 1-device cache
    # entry can never satisfy these lookups
    assert all("|shdata2.model4" in key for key in eng.exec_plans)
    assert any("/m=model" in key for key in eng.exec_plans)
    assert any("/k=model" in key for key in eng.exec_plans)
    sharded = [pl for pl in eng.exec_plans.values() if pl.shard is not None]
    assert sharded and all(pl.shard.is_sharded for pl in sharded)


@needs_mesh
def test_sharded_autotune_cache_roundtrip(tmp_path):
    """Acceptance: the autotune cache round-trips keyed by mesh shape —
    a second engine build over the same cache file re-times zero
    candidates and reproduces the plans exactly."""
    p, c = _model(CFG, "msgemm")
    cache = tmp_path / "plans.json"

    def build():
        return Engine(p, c, max_slots=4, block_size=4, prefill_chunk=4,
                      max_model_len=32, mesh=_mesh(), autotune=True,
                      autotune_cache=cache)

    at.num_timed_candidates = 0
    eng1 = build()
    assert at.num_timed_candidates > 0 and cache.exists()
    assert any("|shdata2.model4" in key for key in eng1.exec_plans)

    at.num_timed_candidates = 0
    eng2 = build()  # autotune_cache= resets the in-memory view -> disk
    assert at.num_timed_candidates == 0, "warm rebuild re-timed candidates"
    assert eng1.exec_plans == eng2.exec_plans


@needs_mesh
def test_single_device_cache_never_replayed_sharded(tmp_path):
    """A plan tuned off-mesh and a plan tuned under the mesh coexist in
    one cache file under different keys (the 'vice versa' half of the
    migration guarantee)."""
    cache = tmp_path / "plans.json"
    dispatch.set_cache_path(cache)
    p1 = at.autotune(SPEC, 16, 32, 8, "msgemm_jnp", reps=1)
    from repro.distributed import sharding as shd

    with shd.use(_mesh(), "serve"):
        pol = dispatch.ExecPolicy(autotune=True)
        p2 = dispatch.plan(SPEC, 16, 32, 8, policy=pol,
                           shard_axes=("mlp", "embed"), lead_batch=8)
    assert p2.shard is not None
    keys = list(dispatch.cache()._plans)
    assert any(k.endswith("|sh-") for k in keys)
    assert any("|shdata2.model4" in k for k in keys)
    assert p1.shard is None


# --------------------------------------- pipelined collectives (on-mesh)
@needs_mesh
def test_ring_collectives_match_xla():
    """The explicit ppermute ring reductions are numerically identical
    to the XLA natives they replace (same block->device layout for the
    scatter, same totals for the psum — including the non-divisible
    naive-ring fallback)."""
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.distributed import collectives as coll, compat

    mesh = jax.make_mesh((4,), ("model",))
    sm = compat.shard_map
    x = jnp.arange(4 * 8 * 16, dtype=jnp.float32).reshape(4, 8, 16)

    def pair(fn, ref, arr):
        a = jax.jit(sm(fn, mesh=mesh, in_specs=P("model"),
                       out_specs=P("model")))(arr)
        b = jax.jit(sm(ref, mesh=mesh, in_specs=P("model"),
                       out_specs=P("model")))(arr)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))

    pair(lambda y: coll.ring_reduce_scatter(y, "model", dim=-1),
         lambda y: jax.lax.psum_scatter(y, "model",
                                        scatter_dimension=y.ndim - 1,
                                        tiled=True), x)
    pair(lambda y: coll.ring_psum(y, "model"),
         lambda y: jax.lax.psum(y, "model"), x)
    pair(lambda y: coll.ring_all_gather(
             coll.ring_reduce_scatter(y, "model", dim=-1), "model", dim=-1),
         lambda y: jax.lax.psum(y, "model"), x)
    # last dim 9 doesn't divide the axis -> the naive shift-and-add ring
    x_odd = jnp.arange(4 * 2 * 9, dtype=jnp.float32).reshape(4, 2, 9)
    pair(lambda y: coll.ring_psum(y, "model"),
         lambda y: jax.lax.psum(y, "model"), x_odd)


@needs_mesh
@pytest.mark.parametrize("collective,pc",
                         [("psum", 2), ("reduce_scatter", 2), ("psum", 3)])
def test_pipelined_token_identity(collective, pc):
    """Acceptance: pipelined plans (chunked contraction + ring
    collective) generate exactly the single-device engine's tokens —
    both collectives, including an odd chunk request that clamps
    per-linear (pc=3 -> 2 on the down-proj, 1 on the attn out-proj)."""
    p, c = _model(CFG, "msgemm")
    prompts = _prompts((5, 9, 3), seed=6)
    _, base = _run(p, c, prompts)
    eng, piped = _run(p, c, prompts, mesh=_mesh(),
                      shard_collective=collective,
                      shard_pipeline=pc, shard_impl="ring")
    assert piped == base
    shards = [pl.shard for pl in eng.exec_plans.values()
              if pl.shard is not None]
    assert any(s.is_pipelined for s in shards)
    if pc == 3:  # the clamp is per-linear, never a rejection
        assert {s.pipeline_chunks for s in shards if s.k is not None} \
            <= {1, 2}


@needs_mesh
def test_pipelined_preemption_token_identity():
    """Mid-stream preemption under the pipelined path: eviction +
    re-prefill replays to the same tokens (host scheduling is oblivious
    to how the contraction is chunked)."""
    p, c = _model(CFG, "msgemm")
    prompts = _prompts((6, 6), seed=5)
    kw = dict(max_slots=2, block_size=4, prefill_chunk=8, num_blocks=7,
              max_model_len=16)
    eng0, base = _run(p, c, prompts, new=10, **kw)
    eng1, piped = _run(p, c, prompts, new=10, mesh=_mesh(),
                       shard_pipeline=2, shard_impl="ring", **kw)
    assert eng0.scheduler.num_preemptions > 0
    assert eng1.scheduler.num_preemptions == eng0.scheduler.num_preemptions
    assert piped == base


@needs_mesh
def test_shard_variant_autotune_roundtrip(tmp_path):
    """shard_pipeline=0: the autotuner times the variant grid once,
    persists winners to the additive shard_variants table, and a warm
    rebuild replays them with zero re-timing and identical plans."""
    import json

    p, c = _model(CFG, "msgemm")
    cache = tmp_path / "plans.json"

    def build():
        return Engine(p, c, max_slots=4, block_size=4, prefill_chunk=4,
                      max_model_len=32, mesh=_mesh(), autotune=True,
                      shard_pipeline=0, autotune_cache=cache)

    at.num_timed_candidates = 0
    eng1 = build()
    assert cache.exists()
    doc = json.loads(cache.read_text())
    assert doc.get("shard_variants"), "no variant winners persisted"
    for v in doc["shard_variants"].values():
        assert {"pipeline_chunks", "collective_impl", "rows"} <= set(v)
        assert any(r.get("winner") for r in v["rows"])

    at.num_timed_candidates = 0
    eng2 = build()
    assert at.num_timed_candidates == 0, "warm rebuild re-timed candidates"
    assert eng1.exec_plans == eng2.exec_plans


# ------------------------------------------------------------ subprocess
def test_sharded_suite_subprocess():
    """Run the whole mesh test set under 8 forced host devices from the
    plain (1-device) tier-1 session."""
    if HAVE8:
        pytest.skip("already running under a forced multi-device host")
    env = {**os.environ, "PYTHONPATH": SRC,
           "XLA_FLAGS": "--xla_force_host_platform_device_count=8"}
    r = subprocess.run(
        [sys.executable, "-m", "pytest", "-q", "-p", "no:cacheprovider",
         os.path.abspath(__file__), "-k", "not subprocess"],
        capture_output=True, text=True, timeout=1800, env=env)
    assert r.returncode == 0, r.stdout[-4000:] + r.stderr[-2000:]
