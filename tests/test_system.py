"""End-to-end behaviour tests for the system: train->quantize->serve
workflow, generation semantics, serve consistency across quant modes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.linear import QuantConfig
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.quant import quantize_model
from repro.quant.quantize import quantized_size_bytes
from repro.runtime import serve as SV

CFG = ModelConfig(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                  d_ff=128, vocab_size=211, max_seq_len=128)


@pytest.fixture(scope="module")
def params():
    return T.init_params(jax.random.PRNGKey(0), CFG)


def test_generate_greedy_deterministic(params):
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                          CFG.vocab_size)}
    out1 = SV.generate(params, CFG, batch, max_new_tokens=6)
    out2 = SV.generate(params, CFG, batch, max_new_tokens=6)
    assert out1.shape == (2, 6)
    assert np.array_equal(out1, out2)


def test_generate_matches_stepwise_forward(params):
    """Greedy generation == repeatedly running the full forward."""
    toks = jax.random.randint(jax.random.PRNGKey(2), (1, 6), 0,
                              CFG.vocab_size)
    gen = SV.generate(params, CFG, {"tokens": toks}, max_new_tokens=4)
    cur = toks
    for i in range(4):
        logits, _ = T.forward(params, CFG, {"tokens": cur})
        nxt = jnp.argmax(logits[:, -1], -1)
        assert int(nxt[0]) == int(gen[0, i]), f"divergence at step {i}"
        cur = jnp.concatenate([cur, nxt[:, None]], axis=1)


def test_train_quantize_serve_workflow(params):
    """The paper's deployment story: dense weights -> int4 -> msGeMM serve
    produces the same generations as the int4-dequant reference."""
    qc = QuantConfig(mode="msgemm", d=3, scale_block=36)
    p_ms = quantize_model(params, CFG, qc)
    c_ms = CFG.replace(quant=qc)
    qc2 = QuantConfig(mode="int4_dequant", d=3, scale_block=36)
    p_dq = quantize_model(params, CFG, qc2)
    c_dq = CFG.replace(quant=qc2)

    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(3), (2, 8), 0,
                                          CFG.vocab_size)}
    lg_ms, _ = T.forward(p_ms, c_ms, batch)
    lg_dq, _ = T.forward(p_dq, c_dq, batch)
    # same int4 weights, two algorithms -> near-identical logits
    np.testing.assert_allclose(lg_ms, lg_dq, rtol=2e-3, atol=2e-3)

    # quantized weights are materially smaller
    dense_bytes = quantized_size_bytes(params)
    ms_bytes = quantized_size_bytes(p_ms)
    assert ms_bytes < 0.55 * dense_bytes  # packed_idx ~10.7 bits + scales


def test_quantized_generation_quality(params):
    """int4 quantization preserves the logit structure (random-init logits
    are near-uniform, so token agreement is a poor metric; correlation of
    the next-token distribution is the right invariant)."""
    qc = QuantConfig(mode="msgemm", d=2, scale_block=16)
    p_q = quantize_model(params, CFG, qc)
    c_q = CFG.replace(quant=qc)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(4), (4, 8), 0,
                                          CFG.vocab_size)}
    lg_d, _ = T.forward(params, CFG, batch)
    lg_q, _ = T.forward(p_q, c_q, batch)
    corr = float(jnp.corrcoef(lg_d.ravel(), lg_q.ravel())[0, 1])
    assert corr > 0.95, f"quantized logits decorrelated ({corr})"


def test_temperature_sampling_changes_output(params):
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(5), (2, 8), 0,
                                          CFG.vocab_size)}
    greedy = SV.generate(params, CFG, batch, max_new_tokens=8)
    hot = SV.generate(params, CFG, batch, max_new_tokens=8, temperature=5.0,
                      key=jax.random.PRNGKey(9))
    assert not np.array_equal(greedy, hot)


def test_long_decode_states_bounded():
    """Recurrent archs decode with O(1) state (the long_500k premise)."""
    from repro import configs

    cfg = configs.get_smoke("xlstm_1b3")
    c64 = T.init_cache(cfg, 2, 64)
    c4096 = T.init_cache(cfg, 2, 4096)
    b64 = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(c64))
    b4096 = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(c4096))
    assert b64 == b4096  # state size independent of context length
