"""Fault-injection subsystem + fault-tolerant serving tests.

Covers the resilience contract end to end: deterministic seeded
schedules (repro.faults), zero overhead / token identity when disarmed,
per-class engine recovery (retry, preemption, NaN quarantine + backend
replan, deadline cancellation, load shedding), the scheduler's
preemption-thrash guard, and artifact corruption -> quarantine + rebuild
(plan cache, calibration, checkpoints)."""

import json

import jax
import numpy as np
import pytest

from repro import dispatch, faults, obs
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.runtime import serve as SV
from repro.serving import BlockPool, Engine, Request, Scheduler
from repro.serving.request import Sequence
from repro.serving.scheduler import THRASH_AFTER

CFG = ModelConfig(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                  d_ff=128, vocab_size=211, max_seq_len=128)


@pytest.fixture(scope="module")
def params():
    return T.init_params(jax.random.PRNGKey(0), CFG)


@pytest.fixture(autouse=True)
def _clean():
    """Every test starts and ends disarmed with no quarantined backends
    and fresh serving_* series (the registry is process-global)."""
    faults.disarm()
    dispatch.clear_quarantine()
    obs.registry().reset(prefix="serving_")
    yield
    faults.disarm()
    dispatch.clear_quarantine()


def _prompts(lens, seed=1):
    rng = np.random.default_rng(seed)
    return [tuple(int(t) for t in rng.integers(0, CFG.vocab_size, size=L))
            for L in lens]


PROMPTS = _prompts((5, 11, 3, 8))


def _reqs(new=6, **kw):
    return [Request(rid=i, prompt=p, max_new_tokens=new, **kw)
            for i, p in enumerate(PROMPTS)]


def _engine(params, **kw):
    kw.setdefault("max_slots", 3)
    kw.setdefault("block_size", 4)
    kw.setdefault("prefill_chunk", 4)
    kw.setdefault("max_model_len", 64)
    return Engine(params, CFG, **kw)


@pytest.fixture(scope="module")
def ref_tokens(params):
    out = {}
    for i, p in enumerate(PROMPTS):
        toks = np.array([p], np.int32)
        r = SV.generate(params, CFG, {"tokens": toks}, max_new_tokens=6)
        out[i] = [int(t) for t in np.asarray(r)[0]]
    return out


# ------------------------------------------------------------ fault plan
def test_plan_determinism_and_budget():
    a = faults.FaultPlan("step_fail:p=0.5,max=0", seed=7)
    b = faults.FaultPlan("step_fail:p=0.5,max=0", seed=7)
    sa = [a.fire("step_fail") is not None for _ in range(200)]
    sb = [b.fire("step_fail") is not None for _ in range(200)]
    assert sa == sb and 40 < sum(sa) < 160  # same stream, ~p=0.5
    c = faults.FaultPlan("step_fail:p=0.5,max=0", seed=8)
    sc = [c.fire("step_fail") is not None for _ in range(200)]
    assert sa != sc  # seed changes the stream

    capped = faults.FaultPlan("oom:p=1.0,after=3,max=2")
    fires = [capped.fire("oom") for _ in range(10)]
    assert [f is not None for f in fires] == [False] * 3 + [True] * 2 \
        + [False] * 5
    assert capped.fires("oom") == 2 and capped.exhausted()


def test_always_draw_keeps_stream_budget_independent():
    """The decision at opportunity n depends only on (seed, class, n) —
    exhausting the budget earlier must not shift later draws."""
    wide = faults.FaultPlan("oom:p=0.5,max=0", seed=3)
    narrow = faults.FaultPlan("oom:p=0.5,max=1", seed=3)
    w = [wide.fire("oom") is not None for _ in range(50)]
    n = [narrow.fire("oom") is not None for _ in range(50)]
    first = w.index(True)
    assert n[:first + 1] == w[:first + 1] and not any(n[first + 1:])


def test_parse_spec_grammar_and_validation():
    specs = faults.parse_spec("all")
    assert {s.cls for s in specs} == set(faults.CLASSES)
    [s] = faults.parse_spec("hang:p=0.25,after=2,max=3,mag=1.5")
    assert (s.p, s.after, s.max_fires, s.magnitude) == (0.25, 2, 3, 1.5)
    two = faults.parse_spec("oom;disconnect:max=2")
    assert [s.cls for s in two] == ["oom", "disconnect"]
    with pytest.raises(ValueError):
        faults.parse_spec("not_a_class")
    with pytest.raises(ValueError):
        faults.parse_spec("oom:bogus=1")
    with pytest.raises(ValueError):
        faults.FaultPlan("oom;oom")


def test_arm_disarm_gauge_and_env(monkeypatch):
    g = obs.registry().gauge("faults_armed")
    assert faults.active() is None and g.value == 0
    faults.arm("oom;hang")
    assert g.value == 2 and faults.active() is not None
    faults.disarm()
    assert g.value == 0 and faults.fire("oom") is None

    monkeypatch.setenv("REPRO_FAULTS", "latency:max=1")
    monkeypatch.setenv("REPRO_FAULT_SEED", "5")
    plan = faults.plan_from_env()
    assert plan is not None and plan.seed == 5
    assert plan.armed_classes() == ("latency",)
    faults.disarm()
    monkeypatch.setenv("REPRO_FAULTS", "")
    assert faults.plan_from_env() is None


# ------------------------------------------ disarmed = identical serving
def test_disarmed_engine_token_identical_and_armed_gauge_zero(
        params, ref_tokens):
    eng = _engine(params)
    assert obs.registry().gauge("faults_armed").value == 0
    res = eng.run(_reqs())
    for i in ref_tokens:
        assert res[i].status == "ok"
        assert res[i].generated == ref_tokens[i], f"req {i}"
    m = eng.metrics()
    assert m["shed"] == m["step_retries"] == m["replans"] == 0


# --------------------------------------------------- per-class recovery
@pytest.mark.parametrize("spec", [
    "latency:p=1.0,after=1,max=2,mag=0.01",
    "oom:p=0.5,after=1,max=4",
    "step_fail:p=1.0,after=2,max=2",
])
def test_transient_faults_recover_token_identically(
        params, ref_tokens, spec):
    faults.arm(spec)
    eng = _engine(params)
    res = eng.run(_reqs())
    faults.disarm()
    for i in ref_tokens:
        assert res[i].status == "ok"
        assert res[i].generated == ref_tokens[i], f"req {i} under {spec}"
    if spec.startswith("step_fail"):
        assert eng.num_step_retries == 2


def test_step_fail_exhausted_retries_reraise(params):
    """An unbounded failure storm beyond the retry budget must surface,
    not loop forever."""
    faults.arm("step_fail:p=1.0,after=0,max=0")
    eng = _engine(params, step_retries=2, retry_backoff_s=0.001)
    with pytest.raises(faults.InjectedFault):
        eng.run(_reqs(new=2))


def test_nan_guard_quarantines_sequence_then_backend(params, ref_tokens):
    faults.arm("nan_logits:p=1.0,after=3,max=2")
    eng = _engine(params)
    res = eng.run(_reqs())
    faults.disarm()
    statuses = {i: res[i].status for i in res}
    assert sum(1 for s in statuses.values() if s == "quarantined") == 2
    assert eng.num_nan_events == 2
    # second event crosses nan_replan_after=2 -> backend replan
    assert eng.num_replans >= 1
    for i in res:
        if res[i].status == "ok":
            assert res[i].generated == ref_tokens[i]


def test_disconnect_cancels_victim_cleanly(params, ref_tokens):
    faults.arm("disconnect:p=1.0,after=2,max=1")
    eng = _engine(params)
    res = eng.run(_reqs())
    faults.disarm()
    statuses = [res[i].status for i in res]
    assert statuses.count("disconnected") == 1
    for i in res:
        if res[i].status == "ok":
            assert res[i].generated == ref_tokens[i]


def test_hang_escalates_and_serving_continues(params):
    from repro.distributed.watchdog import Watchdog

    wd = Watchdog(min_steps=2, min_timeout_s=0.05)
    eng = _engine(params, watchdog=wd)
    eng.run(_reqs(new=2))  # warm compiles so the hang timer is tight
    eng.reset_metrics()
    faults.arm("hang:p=1.0,after=4,max=1,mag=0.1")
    res = eng.run(_reqs())
    faults.disarm()
    assert wd.hang_count >= 1
    assert eng.num_replans >= 1
    assert all(res[i].status == "ok" for i in res)
    assert all(res[i].done for i in res)


def test_injected_oom_is_indistinguishable_from_pressure(params):
    pool = BlockPool(8, 4)
    faults.arm("oom:p=1.0,after=0,max=1")
    assert pool.alloc(2) is None      # injected exhaustion
    got = pool.alloc(2)               # budget spent: real allocation
    faults.disarm()
    assert got is not None and pool.free_blocks == 5


# ------------------------------------------------- deadlines / shedding
def test_deadline_cancels_cleanly(params):
    eng = _engine(params, deadline_s=1e-6)
    res = eng.run(_reqs())
    assert all(res[i].status == "deadline" for i in res)
    m = eng.metrics()
    assert m["cancelled"] == 4 and m["shed"] == 0


def test_ttft_deadline_per_request(params):
    eng = _engine(params)
    res = eng.run([Request(rid=0, prompt=PROMPTS[0], max_new_tokens=6,
                           ttft_deadline_s=1e-7)])
    assert res[0].status == "deadline"


def test_queue_full_sheds(params):
    eng = _engine(params, max_slots=1, max_queue=1)
    res = eng.run(_reqs())
    statuses = [res[i].status for i in res]
    assert statuses.count("shed") >= 1
    for i in res:
        if res[i].status == "ok":
            assert len(res[i].generated) == 6
    assert eng.metrics()["shed"] == statuses.count("shed")


def test_deadline_hopeless_sheds_at_submit(params):
    obs.registry().histogram("serving_queue_wait_s").observe(5.0)
    eng = _engine(params)
    seq = eng.submit(Request(rid=0, prompt=PROMPTS[0], max_new_tokens=4,
                             deadline_s=0.001))
    assert seq.status == "shed"
    assert eng.rejected == [seq] and not eng.scheduler.has_work()


def test_request_deadline_validation():
    with pytest.raises(ValueError):
        Request(rid=0, prompt=(1,), max_new_tokens=1, deadline_s=0.0)
    with pytest.raises(ValueError):
        Request(rid=0, prompt=(1,), max_new_tokens=1,
                ttft_deadline_s=-1.0)


# ----------------------------------------------- satellite 3: metrics()
def test_metrics_never_raises_zero_submitted(params):
    eng = _engine(params)
    m = eng.metrics()
    assert m["requests"] == 0 and m["tok_per_s"] == 0.0
    assert m["latency_p50_s"] is None and m["ttft_p95_s"] is None
    assert m["intertoken_p95_s"] is None
    assert m["queue_wait_p95_s"] is None
    assert eng.summary() == m


def test_metrics_never_raises_mid_flight(params):
    eng = _engine(params)
    eng.submit(Request(rid=0, prompt=PROMPTS[0], max_new_tokens=6))
    eng.step()  # prefill under way, nothing finished
    m = eng.metrics()
    assert m["requests"] == 0
    assert m["latency_p50_s"] is None and m["latency_p95_s"] is None


# ---------------------------------------- satellite 1: thrash guard
def test_preemption_thrash_guard_backs_off():
    pool = BlockPool(60, 4)
    sched = Scheduler(pool, max_slots=2, prefill_chunk=4)
    hog = Sequence(req=Request(rid=0, prompt=(1,) * 8, max_new_tokens=4))
    victim = Sequence(req=Request(rid=1, prompt=(1,) * 8,
                                  max_new_tokens=4))
    sched.add(hog)
    sched.add(victim)
    sched.schedule()
    assert victim in sched.running
    victim.preemptions = THRASH_AFTER - 1  # next preempt trips the guard
    sched.preempt(victim)
    assert sched.num_thrash == 1
    assert victim.readmit_after_tick > sched.tick
    assert obs.registry().value(
        "counter", "scheduler_preempt_thrash_total") == 1
    # while backed off, the head is NOT admitted (hog still running)...
    sched.schedule()
    assert victim not in sched.running and sched.waiting[0] is victim
    # ...but FCFS order is preserved, and once the backoff expires (or
    # nothing is running) it re-admits
    for _ in range(victim.readmit_after_tick - sched.tick):
        sched.schedule()
    assert victim in sched.running


def test_thrash_backoff_ignored_when_nothing_running():
    pool = BlockPool(60, 4)
    sched = Scheduler(pool, max_slots=1, prefill_chunk=4)
    seq = Sequence(req=Request(rid=0, prompt=(1,) * 8, max_new_tokens=4))
    seq.preemptions = THRASH_AFTER + 2
    sched.add(seq)
    seq.readmit_after_tick = sched.tick + 1000
    sched.schedule()  # would deadlock if the backoff were honored
    assert seq in sched.running


# ------------------------------------- backend quarantine / degradation
def test_backend_quarantine_ladder():
    names = dispatch.backend_names()
    assert "dense_fallback" in names
    dispatch.quarantine_backend("msgemm_jnp", "test")
    assert dispatch.is_quarantined("msgemm_jnp")
    assert "msgemm_jnp" in dispatch.quarantined()
    from repro.core.spec import QuantSpec
    spec = QuantSpec(mode="msgemm", d=3, scale_block=36)
    be = dispatch.registry.select_backend(spec, 3)
    assert be.name != "msgemm_jnp"
    dispatch.clear_quarantine("msgemm_jnp")
    assert not dispatch.quarantined()
    with pytest.raises(ValueError):
        dispatch.quarantine_backend("no_such_backend", "test")


def test_quarantine_never_empties_candidates():
    from repro.core.spec import QuantSpec
    spec = QuantSpec(mode="msgemm", d=3, scale_block=36)
    for name in dispatch.backend_names():
        try:
            dispatch.quarantine_backend(name, "test")
        except ValueError:
            pass
    be = dispatch.registry.select_backend(spec, 3)  # falls back unfiltered
    assert be is not None


def test_dense_fallback_matches_msgemm_numerics():
    from repro.core import linear as qlinear
    from repro.core.spec import QuantSpec

    rng = np.random.default_rng(0)
    spec = QuantSpec(mode="msgemm", d=3, scale_block=36)
    w = jax.numpy.asarray(rng.standard_normal((16, 36)), jax.numpy.float32)
    x = jax.numpy.asarray(rng.standard_normal((5, 36)), jax.numpy.float32)
    qp = qlinear.from_dense(w, spec)
    ref = dispatch.execute(
        qp, x, spec, plan_override=dispatch.ExecPlan(backend="msgemm_jnp"))
    got = dispatch.execute(
        qp, x, spec,
        plan_override=dispatch.ExecPlan(backend="dense_fallback"))
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=1e-4, rtol=1e-4)


# ------------------------------- satellite 2 + artifacts: corruption
def test_plan_cache_atomic_write_and_corrupt_rebuild(tmp_path):
    path = tmp_path / "plans.json"
    old = dispatch.cache().path
    try:
        c = dispatch.set_cache_path(path)
        c.put("k|1", dispatch.ExecPlan(backend="msgemm_jnp"))
        assert not list(tmp_path.glob("*.tmp*"))  # atomic: no temp left
        doc = json.loads(path.read_text())
        assert "crc" in doc  # CRC-stamped
        # reload round-trips
        assert len(dispatch.set_cache_path(path)) == 1

        path.write_text('{"version": 3, "plans": {broken')
        c = dispatch.set_cache_path(path)
        assert len(c) == 0  # quarantined + rebuilt empty
        assert list(tmp_path.glob("plans.json.quarantined*"))
        c.put("k|1", dispatch.ExecPlan(backend="msgemm_jnp"))
        assert len(dispatch.set_cache_path(path)) == 1  # rebuilt
    finally:
        dispatch.set_cache_path(old)


def test_plan_cache_crc_mismatch_quarantined(tmp_path):
    path = tmp_path / "plans.json"
    old = dispatch.cache().path
    try:
        c = dispatch.set_cache_path(path)
        c.put("k|1", dispatch.ExecPlan(backend="msgemm_jnp"))
        doc = json.loads(path.read_text())
        doc["crc"] = "deadbeef"  # bit-rot the stamp
        path.write_text(json.dumps(doc))
        assert len(dispatch.set_cache_path(path)) == 0
        assert list(tmp_path.glob("plans.json.quarantined*"))
    finally:
        dispatch.set_cache_path(old)


def test_injected_plan_cache_corruption_recovers(tmp_path):
    path = tmp_path / "plans.json"
    old = dispatch.cache().path
    try:
        faults.arm("corrupt_plan_cache")
        dispatch.set_cache_path(path).put(
            "k|1", dispatch.ExecPlan(backend="msgemm_jnp"))
        faults.disarm()
        assert len(dispatch.set_cache_path(path)) == 0  # corrupt -> empty
        assert list(tmp_path.glob("plans.json.quarantined*"))
    finally:
        dispatch.set_cache_path(old)


def test_calibration_corruption_quarantined(tmp_path):
    from repro.obs import perfmodel as pm

    path = tmp_path / "calibration.json"
    device, interpret = pm.current_partition()
    cal = pm.Calibration(device=device, interpret=interpret,
                         constants={"*": {"launch_s": 1e-6, "step_s": 1e-8,
                                          "produce_s_per_flop": 1e-9,
                                          "consume_s_per_op": 1e-9,
                                          "hbm_s_per_byte": 1e-10}},
                         fit={"n_samples": 4})
    faults.arm("corrupt_calibration")
    cal.save(path)
    faults.disarm()
    assert pm.load_calibration(path) is None
    assert list(tmp_path.glob("calibration.json.quarantined*"))
    cal.save(path)  # rebuild
    assert pm.load_calibration(path) is not None


def test_checkpoint_corruption_falls_back_to_older_step(tmp_path):
    from repro.checkpoint import CheckpointManager

    mgr = CheckpointManager(str(tmp_path / "ckpt"), keep=3)
    tree = {"w": np.arange(6, dtype=np.float32)}
    mgr.save(1, tree)
    faults.arm("corrupt_checkpoint")
    mgr.save(2, tree)
    faults.disarm()
    step, restored = mgr.restore_latest(tree)
    assert step == 1 and np.array_equal(restored["w"], tree["w"])
    assert mgr.all_steps() == [1]  # corpse excluded from step listing
