"""Continuous-batching serving: paged KV cache (kv_blocks), FCFS
scheduler with chunked prefill + preemption (scheduler), and the engine
driving one shared jitted step over both phases (engine).

    from repro.serving import Engine, Request
    eng = Engine(params, cfg, max_slots=8, block_size=16)
    results = eng.run([Request(rid=0, prompt=(1, 2, 3), max_new_tokens=16)])
"""

from repro.serving.engine import Engine
from repro.serving.kv_blocks import SCRATCH, BlockPool
from repro.serving.request import (Phase, Request, Sequence, detokenize,
                                   poisson_stream)
from repro.serving.scheduler import Scheduler

__all__ = ["Engine", "Request", "Sequence", "Phase", "BlockPool",
           "Scheduler", "SCRATCH", "detokenize", "poisson_stream"]
