"""Continuous-batching scheduler: FCFS admission into a fixed set of
decode slots, token-budgeted prefill chunking, and preemption/eviction
when the KV block pool is exhausted.

Policy (vLLM-style, simplified):

* **Admission** — strict FCFS: the head of the waiting queue is admitted
  when a decode slot is free AND the pool can supply all blocks its
  prefill needs; the queue never reorders (no head-of-line skipping).
* **Prefill** — the earliest-admitted sequence still in PREFILL gets one
  chunk of at most ``prefill_chunk`` tokens per engine iteration (the
  iteration token budget), so a long prompt cannot monopolise the step
  loop: decode iterations interleave between its chunks.
* **Preemption** — when a decoding sequence needs a block and the pool is
  dry, the *latest-admitted* running sequence is evicted: blocks freed,
  re-queued at the front of the waiting queue, later re-prefilled from
  prompt ⊕ generated (token-exact, see request.Sequence).  Evicting the
  newest work first keeps FCFS latency ordering.
* **Thrash guard** — a sequence preempted ``THRASH_AFTER`` times or more
  backs off exponentially before re-admission (it stays at the queue
  head — FCFS order is preserved — but admission skips the tick), so
  sustained pool pressure degrades to slower progress instead of an
  admit/evict livelock burning steps with zero forward progress.
  ``scheduler_preempt_thrash_total`` counts guarded preemptions.  The
  backoff is ignored whenever nothing is running — waiting out an empty
  engine would be a deadlock, not a remedy.
"""

from __future__ import annotations

import heapq
import time
from collections import deque

from repro import obs
from repro.serving.kv_blocks import BlockPool
from repro.serving.request import Phase, Sequence

# preemption count at which the thrash guard kicks in, and the cap on
# its exponential re-admission backoff (in scheduler ticks)
THRASH_AFTER = 3
MAX_BACKOFF_TICKS = 64


class Scheduler:
    def __init__(self, pool: BlockPool, *, max_slots: int,
                 prefill_chunk: int, clock=time.monotonic):
        if max_slots < 1 or prefill_chunk < 1:
            raise ValueError("max_slots and prefill_chunk must be positive")
        self.pool = pool
        self.max_slots = max_slots
        self.prefill_chunk = prefill_chunk
        self.clock = clock
        self.waiting: deque[Sequence] = deque()
        self.running: list[Sequence] = []
        self._free_slots = list(range(max_slots))
        heapq.heapify(self._free_slots)
        self._seqno = 0
        self.tick = 0  # schedule() calls; the thrash backoff's clock
        self.num_admitted = 0
        self.num_preemptions = 0
        self.num_evicted_blocks = 0
        self.num_thrash = 0

    # ------------------------------------------------------------- state
    def has_work(self) -> bool:
        return bool(self.waiting or self.running)

    # --------------------------------------------------------- admission
    def add(self, seq: Sequence) -> None:
        seq.phase = Phase.WAITING
        seq.t_enqueue = self.clock()
        self.waiting.append(seq)

    def _admit(self) -> None:
        while self.waiting and self._free_slots:
            seq = self.waiting[0]
            if seq.readmit_after_tick > self.tick and self.running:
                return  # thrash backoff: head sits out this tick (FCFS
                # still holds — nobody skips it); ignored when nothing
                # is running, which would turn backoff into deadlock
            got = self.pool.alloc(self.pool.blocks_for(len(seq.prefill_tokens)))
            if got is None:
                return  # FCFS: the head waits for blocks, nobody skips it
            self.waiting.popleft()
            wait = max(0.0, self.clock() - seq.t_enqueue)
            reg = obs.registry()
            reg.histogram("serving_queue_wait_s",
                          help="waiting-queue residency per admission"
                          ).observe(wait)
            p95 = reg.histogram("serving_queue_wait_s").percentile(95)
            if p95 is not None:
                reg.gauge("serving_queue_wait_p95_s",
                          help="p95 queue wait (admission-time estimate)"
                          ).set(p95)
            seq.blocks = got
            seq.slot = heapq.heappop(self._free_slots)
            seq.phase = Phase.PREFILL
            seq.prefill_pos = 0
            seq.admit_seqno = self._seqno
            self._seqno += 1
            self.num_admitted += 1
            self.running.append(seq)
            obs.registry().counter(
                "serving_admissions_total",
                help="sequences admitted to a decode slot").inc()
            obs.tracer().instant("scheduler.admit", cat="serving",
                                 rid=seq.req.rid, slot=seq.slot,
                                 blocks=len(seq.blocks))

    # -------------------------------------------------------- scheduling
    def schedule(self):
        """Pick this iteration's work: ('prefill', seq, start, end) for one
        chunk, ('decode', seqs) for a batch iteration, or None when idle."""
        self.tick += 1
        self._admit()
        pre = [s for s in self.running if s.phase is Phase.PREFILL]
        if pre:
            seq = min(pre, key=lambda s: s.admit_seqno)
            start = seq.prefill_pos
            end = min(start + self.prefill_chunk, len(seq.prefill_tokens))
            return ("prefill", seq, start, end)
        dec = sorted((s for s in self.running if s.phase is Phase.DECODE),
                     key=lambda s: s.admit_seqno)
        if dec:
            return ("decode", dec)
        return None

    # -------------------------------------------- block growth / eviction
    def grow_for_decode(self, seq: Sequence) -> bool:
        """Ensure ``seq`` owns blocks for all ``num_tokens`` positions,
        evicting latest-admitted sequences on pool exhaustion.  Returns
        False iff ``seq`` itself was the victim (skip its decode)."""
        need = self.pool.blocks_for(seq.num_tokens)
        while len(seq.blocks) < need:
            got = self.pool.alloc(need - len(seq.blocks))
            if got is not None:
                seq.blocks.extend(got)
                return True
            victim = max(self.running, key=lambda s: s.admit_seqno)
            self.preempt(victim)
            if victim is seq:
                return False
        return True

    def preempt(self, victim: Sequence) -> None:
        self.num_preemptions += 1
        victim.preemptions += 1
        self.num_evicted_blocks += len(victim.blocks)
        reg = obs.registry()
        reg.counter("serving_preemptions_total",
                    help="sequences evicted on pool exhaustion").inc()
        reg.counter("serving_evicted_blocks_total",
                    help="KV blocks freed by preemption").inc(
                        len(victim.blocks))
        obs.tracer().instant("scheduler.preempt", cat="serving",
                             rid=victim.req.rid,
                             blocks=len(victim.blocks),
                             generated=len(victim.generated))
        victim.t_last_token = None  # next gap is requeue, not decode cadence
        self.pool.free(victim.blocks)
        victim.blocks = []
        heapq.heappush(self._free_slots, victim.slot)
        victim.slot = -1
        victim.phase = Phase.WAITING
        victim.prefill_pos = 0
        self.running.remove(victim)
        if victim.preemptions >= THRASH_AFTER:
            # exponential re-admission backoff, doubling per further
            # preemption; under sustained pressure the victim waits out
            # enough ticks for whoever kept evicting it to finish
            backoff = min(2 ** (victim.preemptions - THRASH_AFTER + 1),
                          MAX_BACKOFF_TICKS)
            victim.readmit_after_tick = self.tick + backoff
            self.num_thrash += 1
            reg.counter(
                "scheduler_preempt_thrash_total",
                help="preemptions that tripped the re-admission backoff"
            ).inc()
        # victims are picked newest-first, so appendleft keeps the waiting
        # queue sorted by original admission order
        victim.t_enqueue = self.clock()
        self.waiting.appendleft(victim)

    # --------------------------------------------------------- completion
    def finish(self, seq: Sequence) -> None:
        self.pool.free(seq.blocks)
        seq.blocks = []
        heapq.heappush(self._free_slots, seq.slot)
        seq.slot = -1
        seq.phase = Phase.FINISHED
        self.running.remove(seq)

    def remove(self, seq: Sequence) -> None:
        """Release a sequence from wherever it lives — the cancel /
        shed / disconnect path.  Frees blocks + slot when admitted,
        drops it from the waiting queue otherwise; idempotent on
        sequences already out of the scheduler."""
        if seq in self.running:
            self.finish(seq)
            return
        try:
            self.waiting.remove(seq)
        except ValueError:
            pass
        seq.phase = Phase.FINISHED
