"""Request/sequence dataclasses for the continuous-batching engine.

A ``Request`` is what a client submits (prompt tokens, budget, sampling
knobs, arrival time).  A ``Sequence`` is the engine's mutable view of one
admitted request: its generated tokens, the KV blocks it owns, where its
chunked prefill has got to, and per-request latency metrics.  Preemption
resets a sequence to WAITING with ``prefill_pos = 0`` — its next
admission re-prefills prompt + already-generated tokens, which chunked
prefill makes token-exact, so evicted sequences resume losslessly.
"""

from __future__ import annotations

import enum
from dataclasses import dataclass, field


class Phase(enum.Enum):
    WAITING = "waiting"    # queued (never admitted, or preempted)
    PREFILL = "prefill"    # admitted; prompt chunks still being ingested
    DECODE = "decode"      # one token per engine decode iteration
    FINISHED = "finished"


@dataclass(frozen=True)
class Request:
    rid: int
    prompt: tuple[int, ...]
    max_new_tokens: int
    temperature: float = 0.0  # 0 -> greedy (token-identical to the
    # static generate path); > 0 -> host-side categorical sampling
    arrival_time: float = 0.0  # seconds after engine start (simulation)
    # SLO deadlines, both relative to arrival (None = no deadline).
    # Exceeding one cancels the request cleanly (status 'deadline') —
    # it never silently queues forever.
    deadline_s: float | None = None       # total latency budget
    ttft_deadline_s: float | None = None  # first-token budget

    def __post_init__(self):
        if len(self.prompt) == 0:
            raise ValueError(f"request {self.rid}: empty prompt")
        if self.max_new_tokens < 1:
            raise ValueError(f"request {self.rid}: max_new_tokens < 1")
        for name in ("deadline_s", "ttft_deadline_s"):
            v = getattr(self, name)
            if v is not None and v <= 0:
                raise ValueError(f"request {self.rid}: {name} must be > 0")


@dataclass
class Sequence:
    req: Request
    generated: list[int] = field(default_factory=list)
    blocks: list[int] = field(default_factory=list)  # owned pool block ids
    phase: Phase = Phase.WAITING
    slot: int = -1            # decode-batch row while admitted
    prefill_pos: int = 0      # tokens of ``prefill_tokens`` already ingested
    admit_seqno: int = -1     # admission order; preemption picks the max
    preemptions: int = 0
    t_arrival: float = 0.0
    t_first_token: float | None = None
    t_last_token: float | None = None  # previous token's emit time —
    # inter-token gap source; reset on preemption (the re-prefill gap is
    # queueing, not decode cadence)
    t_finish: float | None = None
    t_enqueue: float = 0.0    # last time it (re-)entered the waiting
    # queue; admit-time queue-wait metrics read it
    readmit_after_tick: int = 0  # preemption-thrash backoff: the
    # scheduler skips admitting this sequence until its tick passes
    # status: 'ok' while live/completed; a terminal failure mode
    # otherwise ('shed' rejected at admission, 'deadline' cancelled on
    # an expired SLO, 'disconnected' client went away, 'quarantined'
    # non-finite logits twice).  Only 'ok' FINISHED sequences carry a
    # full generation.
    status: str = "ok"

    @property
    def prefill_tokens(self) -> list[int]:
        """What (re-)prefill must ingest: prompt ⊕ tokens generated before
        a preemption (empty on first admission)."""
        return list(self.req.prompt) + list(self.generated)

    @property
    def num_tokens(self) -> int:
        return len(self.req.prompt) + len(self.generated)

    @property
    def done(self) -> bool:
        return len(self.generated) >= self.req.max_new_tokens

    def metrics(self) -> dict:
        out = {"rid": self.req.rid,
               "prompt_tokens": len(self.req.prompt),
               "new_tokens": len(self.generated),
               "preemptions": self.preemptions,
               "status": self.status}
        if self.t_first_token is not None:
            out["ttft_s"] = self.t_first_token - self.t_arrival
        if self.t_finish is not None:
            out["latency_s"] = self.t_finish - self.t_arrival
            if len(self.generated) > 1 and self.t_first_token is not None:
                out["intertoken_mean_s"] = (
                    (self.t_finish - self.t_first_token)
                    / (len(self.generated) - 1))
        return out


def detokenize(tokens) -> str:
    """Synthetic-vocab detokenizer (printable ASCII) for streamed output —
    the repo has no real tokenizer; this keeps the streaming API honest."""
    return "".join(chr(33 + int(t) % 94) for t in tokens)


def poisson_stream(n: int, vocab_size: int, *, max_new_tokens: int,
                   rate: float = 0.0, min_prompt: int = 4,
                   max_prompt: int = 24, temperature: float = 0.0,
                   seed: int = 0) -> list[Request]:
    """Deterministic simulated request stream: mixed-length random
    prompts with exponential inter-arrival gaps at ``rate`` req/s
    (rate <= 0: everything arrives at t=0).  Shared by launch.serve and
    benchmarks so arrival semantics can't drift between them."""
    import numpy as np

    rng = np.random.default_rng(seed)
    lens = rng.integers(min_prompt, max_prompt + 1, size=n)
    gaps = (np.zeros(n) if rate <= 0 else rng.exponential(1.0 / rate, n))
    arrivals = np.cumsum(gaps)
    return [Request(rid=i,
                    prompt=tuple(int(t) for t in
                                 rng.integers(0, vocab_size, size=L)),
                    max_new_tokens=max_new_tokens,
                    temperature=temperature,
                    arrival_time=float(a))
            for i, (L, a) in enumerate(zip(lens, arrivals))]
