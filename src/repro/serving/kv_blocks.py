"""Paged KV cache plumbing: a fixed-size block pool with a free-list
allocator, per-sequence block tables, and the flat "cache view" index
arrays the paged attention path consumes (models.layers.attn_paged).

Block 0 is reserved as a *scratch* block: padding tokens (prefill-chunk
tail, idle decode slots) scatter their K/V there and block tables pad
with it, so every step has fully static shapes while garbage never
reaches a real sequence (masked entries get probability exactly 0).

The allocator is host-side Python (like vLLM's) — allocation decisions
are control flow, not device compute; only the pool tensors live on
device (runtime.serve.init_paged_cache).
"""

from __future__ import annotations

from collections import deque

import numpy as np

SCRATCH = 0  # reserved block id — never allocated, never trusted


class BlockPool:
    """Free-list allocator over ``num_blocks`` blocks of ``block_size``
    token slots each.  Block ids index the device-side pool tensors."""

    def __init__(self, num_blocks: int, block_size: int):
        if num_blocks < 2:
            raise ValueError("need >= 2 blocks (one is scratch)")
        if block_size < 1:
            raise ValueError("block_size must be positive")
        self.num_blocks = num_blocks
        self.block_size = block_size
        self._free: deque[int] = deque(range(1, num_blocks))
        self._free_set: set[int] = set(self._free)

    @property
    def capacity(self) -> int:
        """Allocatable blocks (excludes scratch)."""
        return self.num_blocks - 1

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    def blocks_for(self, num_tokens: int) -> int:
        return -(-num_tokens // self.block_size)

    def alloc(self, n: int) -> list[int] | None:
        """All-or-nothing allocation of ``n`` blocks (None on exhaustion)."""
        if n > len(self._free):
            return None
        from repro import faults
        if faults.fire("oom") is not None:
            return None  # injected exhaustion: same signal real pressure
            # gives the scheduler (admission stalls / preemption path)
        out = [self._free.popleft() for _ in range(n)]
        self._free_set.difference_update(out)
        return out

    def free(self, blocks: list[int]) -> None:
        """Return blocks to the free list.  Double-frees (and frees of
        ids never allocated from this pool) raise instead of silently
        corrupting the free list — a double-freed block would be handed
        to two sequences at once and their K/V writes would interleave."""
        for b in blocks:
            if b == SCRATCH:
                raise ValueError("attempt to free the scratch block")
            if not 0 < b < self.num_blocks:
                raise ValueError(f"block id {b} outside pool "
                                 f"[1, {self.num_blocks})")
            if b in self._free_set:
                raise ValueError(f"double free of block {b}")
            self._free.append(b)
            self._free_set.add(b)


def view_slots(blocks: list[int], max_blocks: int, block_size: int
               ) -> np.ndarray:
    """Flat pool slots (W,) = the sequence's cache view: view index w maps
    to the pool slot holding logical position w (scratch-padded)."""
    ids = np.full((max_blocks,), SCRATCH, np.int32)
    ids[:len(blocks)] = blocks
    off = np.arange(block_size, dtype=np.int32)
    return (ids[:, None] * block_size + off[None, :]).reshape(-1)


def write_slots(blocks: list[int], start: int, count: int, pad_to: int,
                block_size: int) -> np.ndarray:
    """Flat pool slots (pad_to,) where tokens at logical positions
    [start, start+count) scatter their K/V; tail padding goes to scratch."""
    # int32 throughout: these feed device-side scatters where x64-disabled
    # JAX would silently truncate int64 indices
    pos = np.arange(start, start + count, dtype=np.int32)
    ids = np.asarray(blocks, np.int32)[pos // block_size]
    ws = ids * block_size + pos % block_size
    pad = np.arange(pad_to - count, dtype=np.int32) % block_size  # scratch
    return np.concatenate([ws, pad]).astype(np.int32)
