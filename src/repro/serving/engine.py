"""Continuous-batching serving engine over the paged KV cache.

The engine admits a stream of variable-length requests and interleaves
chunked prefill with batched decode, all through **one shared jitted
step** (runtime.serve.paged_step): a prefill chunk is a (1, C) call and a
decode iteration a (max_slots, 1) call of the same function, so exactly
two executables cover every phase for the lifetime of the engine — no
shape-driven recompiles as requests come and go.

Why this is the msGeMM payoff path: the paper's 4-bit weights free HBM,
and a real server spends that HBM on KV cache.  Paging turns the freed
bytes into *admitted concurrent sequences* (throughput) instead of
padding inside a dense (batch, max_len) cache.

Greedy outputs are token-identical to the static ``runtime.serve.generate``
path for the same prompts (asserted in tests/test_serving.py): chunked
prefill is mathematically exact, and the paged attention view masks
non-owned slots to probability exactly 0.

Resilience (README §Resilience has the full taxonomy): per-request
deadlines with clean cancellation, queue-depth + deadline-aware load
shedding, bounded step retry with exponential backoff (token-identical —
the retried call re-runs from the sequence's paged-KV state), a NaN/Inf
logit guard that quarantines the offending sequence and on repeat
quarantines the suspect dispatch backend and replans down the
degradation ladder, and watchdog hang escalation doing the same.  All
fault *injection* lives behind ``repro.faults`` (zero overhead when
disarmed); the tolerance paths above are always on.
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import dispatch, faults, obs
from repro.distributed import sharding as shd
from repro.distributed.watchdog import Watchdog
from repro.models.config import ModelConfig
from repro.runtime import serve as SV
from repro.serving import kv_blocks
from repro.serving.kv_blocks import BlockPool
from repro.serving.request import Phase, Request, Sequence, detokenize
from repro.serving.scheduler import Scheduler

# queue depth / batch occupancy are small integers, not latencies
DEPTH_BUCKETS = (0, 1, 2, 4, 8, 16, 32, 64, 128, 256)


class _StepTimer:
    """Times one engine iteration into serving_step_s{phase=}.  Wall
    time includes device sync only when tracing is on (the engine blocks
    inside the span then); untraced it measures the host dispatch path,
    which is still the right signal for engine-loop overhead."""

    __slots__ = ("engine", "phase", "t0")

    def __init__(self, engine, phase):
        self.engine = engine
        self.phase = phase

    def __enter__(self):
        self.t0 = self.engine._clock()
        return self

    def __exit__(self, *exc):
        obs.registry().histogram(
            "serving_step_s", help="engine iteration wall time",
            phase=self.phase).observe(self.engine._clock() - self.t0)
        return False


class Engine:
    """Continuous-batching engine.

    Parameters
    ----------
    params, cfg : model parameters (optionally quantized) and its config.
    max_slots : decode-batch width (concurrent admitted sequences).
    block_size : KV block size in token positions.
    num_blocks : pool size incl. the reserved scratch block; default sizes
        the pool so paging never preempts (max_slots full-length seqs) —
        pass something smaller to exercise preemption / save HBM.
    max_model_len : per-sequence position budget (prompt + generation).
    prefill_chunk : prefill token budget per engine iteration.
    kv_quant : a ``repro.kvq.KVQuantSpec`` — store the paged pool as
        low-bit codes + scales instead of ``cache_dtype`` values and
        route paged attention through the registered kvq backends
        (in-VMEM dequant on TPU, jnp gather+dequant reference
        elsewhere).  None (default): the unchanged full-precision pool.
    kv_pool_bytes : size the pool by a device-byte budget instead of
        ``num_blocks`` (ignored when ``num_blocks`` is given): the pool
        gets as many blocks as the budget buys at the *actual* storage
        cost (repro.kvq.blocks_for_bytes), so quantized engines admit
        proportionally more resident sequences — and the scheduler,
        which admits against ``BlockPool.capacity``, sees that capacity
        automatically.
    on_token : optional ``f(rid, token, text)`` streaming callback, called
        as each token is generated (text via the synthetic detokenizer).
    backend : force a registered dispatch backend by name for every
        quantized linear (None: per-config/auto selection).
    autotune : measure candidate tile configs for every linear shape this
        engine will step and persist winners to the plan cache.  Plans
        are resolved ONCE here at engine build — an abstract eval_shape
        of both step phases collects the exact (spec, m, k, batch) keys,
        each is tuned/warmed concretely, and the later jit traces only
        ever hit the warm cache.
    autotune_cache : plan-cache JSON path override (None: REPRO_PLAN_CACHE
        env or the default user cache dir).
    mesh : a jax device mesh (e.g. ``launch.mesh.make_mesh((2, 4),
        ("data", "model"))``) — the engine becomes tensor-parallel:
        params and the paged KV pool are laid out per ``mesh_rules``
        (weights TP over 'model', the pool's kvheads over 'model', step
        batches over 'data'), the jitted step traces under the mesh so
        every quantized linear plans local-shard tiles and runs inside a
        shard_map, and ALL exec plans are resolved once at build —
        exactly the autotune warm-up path, whether or not autotuning is
        on — so tracing never derives a shard mid-step.
    mesh_rules : logical-axis rule set (distributed.sharding.RULE_SETS);
        'serve' keeps activations data-parallel and weights TP-resident
        with no FSDP gathers on the hot path.
    shard_collective : 'psum' | 'reduce_scatter' — how row-parallel
        (contraction-sharded) linears resolve partial sums.
    shard_pipeline : contraction-pipelining depth for row-parallel
        linears — 1 (default) keeps the one-shot consume+collective,
        N>1 chunks the local contraction dim so chunk i's ring
        collective overlaps chunk i+1's LUT consume, and 0 lets the
        autotuner time the variant grid per linear and replay the
        winner from the plan cache (``dispatch.autotune
        .tune_shard_variants``).
    shard_impl : 'xla' | 'ring' — collective implementation for the
        contraction reduction; 'ring' uses the explicit ppermute ring
        whose per-hop dataflow the pipelined path can overlap.
    max_queue : admission control — reject (shed) new submissions when
        the waiting queue is already this deep (None: unbounded, the
        historic behavior).  Shed requests come back with status 'shed'
        and count into ``serving_shed_total``.
    deadline_s / ttft_deadline_s : engine-wide default SLOs applied to
        requests that don't carry their own ``Request.deadline_s`` /
        ``ttft_deadline_s`` (None: no deadline).  Expired requests are
        cancelled cleanly with status 'deadline'; a deadline-carrying
        request whose budget is already hopeless against the p95 queue
        wait is shed at submission.
    step_retries / retry_backoff_s : bounded retry of a failed engine
        step with exponential backoff.  The retried call re-runs from
        the sequence's paged-KV state, so recovered output is
        token-identical.  If a failure inside the jitted call consumed
        the donated pool buffer, the engine rebuilds the pool and
        re-prefills everything (also token-exact) instead of retrying.
    watchdog : a ``distributed.watchdog.Watchdog`` (or True for a
        serving-tuned default) that times every step; a hang escalates
        after the step returns — suspect backend quarantined, step
        replanned on the remaining ladder, serving continues.  None
        (default): no per-step timers.
    nan_replan_after : total non-finite-logit events after which the
        guard also quarantines the suspect backend and replans (each
        event always quarantines the offending *sequence*).

    Decode tile presets: plans are resolved per phase shape, so the
    decode batch (max_slots rows of 1 token) plans with its *actual*
    batch — the kernel heuristic sizes tb to round_up(max_slots, 8)
    instead of padding the batch tile to 128, and spends the VMEM freed
    by the narrow stripe on a larger LUT tile (tj) and taller m tiles
    (ops.msgemm_tiles' decode branch) — the produce-amortized sweet spot.
    Under a mesh the same presets apply to the per-device shard shapes.
    """

    def __init__(self, params, cfg: ModelConfig, *, max_slots: int = 4,
                 block_size: int = 16, num_blocks: int | None = None,
                 max_model_len: int | None = None, prefill_chunk: int = 16,
                 cache_dtype=jnp.float32, on_token=None,
                 clock=time.perf_counter, sample_seed: int = 0,
                 backend: str | None = None, autotune: bool | str = False,
                 autotune_cache=None, mesh=None, mesh_rules: str = "serve",
                 shard_collective: str = "psum", shard_pipeline: int = 1,
                 shard_impl: str = "xla", kv_quant=None,
                 kv_pool_bytes: int | None = None,
                 max_queue: int | None = None,
                 deadline_s: float | None = None,
                 ttft_deadline_s: float | None = None,
                 step_retries: int = 2, retry_backoff_s: float = 0.02,
                 watchdog: "Watchdog | bool | None" = None,
                 nan_replan_after: int = 2):
        from repro import kvq

        self.mesh = mesh
        self.mesh_rules = mesh_rules
        self._input_shardings: dict = {}
        if mesh is not None:
            params = jax.device_put(params,
                                    shd.shardings(params, mesh, mesh_rules))
        self.params = params
        if kv_quant is not None:
            cfg = cfg.replace(kv_quant=kv_quant)
        self.cfg = cfg
        self.max_model_len = max_model_len or cfg.max_seq_len
        self.block_size = block_size
        self.max_blocks_per_seq = -(-self.max_model_len // block_size)
        if num_blocks is None:
            if kv_pool_bytes is not None:
                num_blocks = kvq.blocks_for_bytes(
                    cfg, kv_pool_bytes, block_size, cfg.kv_quant,
                    cache_dtype)
            else:
                num_blocks = max_slots * self.max_blocks_per_seq + 1
        self.pool = BlockPool(num_blocks, block_size)
        self._cache_dtype = cache_dtype
        self.kv = SV.init_paged_cache(cfg, num_blocks, block_size,
                                      cache_dtype, mesh=mesh,
                                      rules=mesh_rules)
        self.scheduler = Scheduler(self.pool, max_slots=max_slots,
                                   prefill_chunk=prefill_chunk, clock=clock)
        self.max_slots = max_slots
        self.prefill_chunk = prefill_chunk
        self.on_token = on_token
        self._clock = clock
        self._t0 = clock()
        self._sample_seed = sample_seed
        self._rngs: dict[int, np.random.Generator] = {}
        self.finished: list[Sequence] = []
        self.rejected: list[Sequence] = []  # shed / cancelled / ...
        self.num_prefill_steps = 0
        self.num_decode_steps = 0
        # peak concurrently-admitted sequences observed before the first
        # preemption — the capacity headline BENCH_serve.json reports
        self.max_resident_seqs = 0
        # ---- resilience knobs / state
        self.max_queue = max_queue
        self.default_deadline_s = deadline_s
        self.default_ttft_deadline_s = ttft_deadline_s
        self.step_retries = step_retries
        self.retry_backoff_s = retry_backoff_s
        self.nan_replan_after = nan_replan_after
        self.num_shed = 0
        self.num_step_retries = 0
        self.num_nan_events = 0
        self.num_replans = 0
        self.num_kv_rebuilds = 0
        # any deadline anywhere flips this; the per-step scan is skipped
        # entirely otherwise (zero overhead for deadline-free serving)
        self._deadline_watch = bool(deadline_s or ttft_deadline_s)
        self._hang_flag = threading.Event()
        if watchdog is True:
            # serving steps are ms-scale: mean*hang_factor would be
            # microseconds, so the floor carries the timeout
            watchdog = Watchdog(min_steps=3, min_timeout_s=0.5)
        self._watchdog = watchdog or None
        if self._watchdog is not None and self._watchdog.on_hang is None:
            self._watchdog.on_hang = self._hang_flag.set
        self._export_kv_gauges(num_blocks, cache_dtype)

        def raw_step(params, pool, tokens, positions, wslots, vslots,
                     last_idx):
            logits, pool = SV.paged_step(params, cfg, tokens, pool,
                                         positions, wslots, vslots, last_idx)
            # per-row finite flag, computed on device: the NaN/Inf guard
            # reads B bools per step instead of shipping logits to host
            ok = jnp.all(jnp.isfinite(logits), axis=-1)
            return jnp.argmax(logits, -1).astype(jnp.int32), logits, ok, pool

        # the one shared step: compiled once per phase shape (prefill
        # (1, C), decode (max_slots, 1)); the pool buffer is donated so
        # the KV cache is updated in place across iterations
        self._raw_step = raw_step
        self._step_fn = jax.jit(raw_step, donate_argnums=(1,))

        # execution planning: resolve every linear's ExecPlan once, at
        # build — never per step.  With no backend/autotune request and
        # no mesh the policy is None and behavior is exactly the
        # per-config default.  A mesh always triggers build-time
        # resolution (the warm-up is how sharded plans + their cache
        # keys come into existence before the trace).
        self._policy = None
        self.exec_plans: dict = {}
        if backend is not None or autotune or mesh is not None:
            if autotune_cache is not None:
                dispatch.set_cache_path(autotune_cache)
            self._policy = dispatch.ExecPolicy(
                backend=backend, autotune=autotune,
                shard_collective=shard_collective,
                shard_pipeline=shard_pipeline, shard_impl=shard_impl)
            self.exec_plans = self._resolve_plans(raw_step)

    def _export_kv_gauges(self, num_blocks: int, cache_dtype) -> None:
        """Pool-capacity gauges (kv_* prefix, NOT serving_*: capacity is
        a property of the built engine, so ``reset_metrics`` between
        measurement streams must not clear it)."""
        from repro import kvq
        from repro.kvq import attention as kvq_attn

        reg = obs.registry()
        spec = self.cfg.kv_quant
        bpt = kvq.bytes_per_token(self.cfg, spec, cache_dtype)
        reg.gauge("kv_pool_bytes",
                  help="device bytes of the paged KV pool").set(
            kvq.pool_bytes(self.cfg, num_blocks, self.block_size, spec,
                           cache_dtype))
        reg.gauge("kv_bytes_per_token",
                  help="pool bytes per token slot across all layers"
                  ).set(bpt)
        reg.gauge("kv_capacity_seqs",
                  help="max-length sequences the pool can hold").set(
            (num_blocks - 1) // self.max_blocks_per_seq)
        if spec is not None:
            W = self.max_blocks_per_seq * self.block_size
            reg.gauge(
                "kv_dequant_hbm_bytes",
                help="HBM bytes of dequantized K/V one layer-step "
                     "materializes (0: in-kernel/VMEM dequant only)",
                backend=kvq_attn.select(spec)).set(
                kvq_attn.dequant_hbm_bytes(spec, self.cfg, self.max_slots,
                                           W))

    def _mesh_ctx(self):
        return (shd.use(self.mesh, self.mesh_rules) if self.mesh is not None
                else contextlib.nullcontext())

    def _resolve_plans(self, raw_step) -> dict:
        """Collect the (spec, m, k, batch, shard) plan keys both step
        phases will request (abstract eval_shape under the mesh —
        nothing is executed), then warm/autotune each concretely so jit
        tracing only hits cache."""
        B, C = self.max_slots, self.prefill_chunk
        W = self.max_blocks_per_seq * self.block_size
        with self._mesh_ctx(), dispatch.using_policy(self._policy), \
                dispatch.collecting() as reqs:
            for nb, nt in ((1, C), (B, 1)):  # prefill chunk, decode batch
                jax.eval_shape(
                    raw_step, self.params, self.kv,
                    np.zeros((nb, nt), np.int32), np.zeros((nb, nt), np.int32),
                    np.zeros((nb, nt), np.int32), np.zeros((nb, W), np.int32),
                    np.zeros((nb,), np.int32))
        with self._mesh_ctx():
            return dispatch.warm(reqs, policy=self._policy)

    def _put_inputs(self, *arrays):
        """Device-place one step's host arrays: leading (row) dim over
        the batch mesh axis when divisible (decode: max_slots over
        'data'), replicated otherwise (prefill's single row).  The
        NamedShardings are memoized per shape — the engine only ever
        steps two shape sets (prefill chunk / decode batch), and the
        rule walk should not rerun once per generated token."""
        if self.mesh is None:
            return arrays
        from jax.sharding import NamedSharding

        out = []
        for a in arrays:
            sharding = self._input_shardings.get(a.shape)
            if sharding is None:
                spec = shd.spec_for(("batch",) + ("none",) * (a.ndim - 1),
                                    a.shape, mesh=self.mesh, kind="act",
                                    rules=self.mesh_rules)
                sharding = NamedSharding(self.mesh, spec)
                self._input_shardings[a.shape] = sharding
            out.append(jax.device_put(a, sharding))
        return tuple(out)

    def _call_step(self, params, pool, *host_arrays):
        """Invoke the shared jitted step with this engine's exec policy
        (and mesh) active — both are consumed at trace time (first call
        per phase shape), where plan() finds the cache pre-warmed by
        ``_resolve_plans``."""
        with self._mesh_ctx(), dispatch.using_policy(self._policy):
            return self._step_fn(params, pool,
                                 *self._put_inputs(*host_arrays))

    def _run_step(self, *host_arrays):
        """The guarded jitted-step call: watchdog timing, fault
        injection, and bounded retry-with-backoff.

        Injected failures (``step_fail``) raise *before* the jitted call
        touches the donated pool, so a retry re-runs from the identical
        paged-KV state and recovered output is token-identical.  An
        organic failure that consumed the donated pool buffer cannot be
        retried in place: the engine rebuilds the pool, preempts every
        running sequence (token-exact re-prefill), and returns None so
        the caller abandons this iteration."""
        attempt = 0
        while True:
            wd = self._watchdog
            try:
                if wd is not None:
                    wd.step_started()
                try:
                    ev = faults.fire("hang")
                    if ev is not None:
                        # a jitted call can't be truly wedged from
                        # Python; stalling past the *armed* hang timer
                        # models it and drives the same escalation
                        floor = 0.0
                        if wd is not None and wd._timer is not None:
                            floor = wd._timer.interval * 1.2
                        time.sleep(max(ev.magnitude, floor))
                    ev = faults.fire("step_fail")
                    if ev is not None:
                        raise faults.InjectedFault("step_fail", ev)
                    return self._call_step(self.params, self.kv,
                                           *host_arrays)
                finally:
                    if wd is not None:
                        wd.step_finished()
            except (KeyboardInterrupt, SystemExit):
                raise
            except Exception:
                attempt += 1
                self.num_step_retries += 1
                obs.registry().counter(
                    "serving_step_retries_total",
                    help="engine step failures retried").inc()
                if not self._kv_alive():
                    self._rebuild_kv()
                    return None
                if attempt > self.step_retries:
                    raise
                time.sleep(self.retry_backoff_s * 2 ** (attempt - 1))

    def _kv_alive(self) -> bool:
        for leaf in jax.tree.leaves(self.kv):
            deleted = getattr(leaf, "is_deleted", None)
            if deleted is not None and deleted():
                return False
        return True

    def _rebuild_kv(self) -> None:
        """The jitted step donates the pool buffer; a failure inside the
        call can leave it deleted.  Preempt everything (re-prefill from
        prompt ⊕ generated is token-exact) and re-init the pool so the
        engine keeps serving instead of crashing."""
        self.num_kv_rebuilds += 1
        obs.registry().counter(
            "serving_kv_rebuilds_total",
            help="paged pools re-initialized after a step failure "
                 "consumed the donated buffer").inc()
        for seq in sorted(self.scheduler.running,
                          key=lambda s: -s.admit_seqno):
            self.scheduler.preempt(seq)
        self.kv = SV.init_paged_cache(self.cfg, self.pool.num_blocks,
                                      self.block_size, self._cache_dtype,
                                      mesh=self.mesh, rules=self.mesh_rules)

    # -------------------------------------------------- degradation
    def _escalate_hang(self) -> None:
        """Watchdog hang escalation, run right after the stalled step
        finally returned: count it, quarantine the suspect backend, and
        replan the step on the remaining ladder.  The engine keeps
        serving throughout — nothing here raises."""
        self._hang_flag.clear()
        obs.registry().counter(
            "serving_hang_escalations_total",
            help="watchdog hangs escalated to a backend replan").inc()
        self._replan("hang")

    def _replan(self, reason: str) -> None:
        """Quarantine the backends the current exec plans run on (one
        rung of the pallas -> jnp -> dense-fallback ladder) and re-jit
        the step so the next trace plans on what remains."""
        self.num_replans += 1
        obs.registry().counter(
            "serving_replans_total",
            help="step replans after hang/NaN escalation",
            reason=reason).inc()
        if not self.exec_plans:
            # plans were never resolved at build (no backend/autotune/
            # mesh): resolve now so the suspects are known by name
            with contextlib.suppress(Exception):
                self.exec_plans = self._resolve_plans(self._raw_step)
        safe = {"dense", "dense_fallback"}
        suspects = sorted({p.backend for p in self.exec_plans.values()}
                          - safe)
        for name in suspects:
            with contextlib.suppress(ValueError):
                dispatch.quarantine_backend(name, reason)
        if self._policy is not None and self._policy.backend in suspects:
            self._policy = dataclasses.replace(self._policy, backend=None)
        # drop the compiled executables; the next call per phase shape
        # re-traces, and plan() now selects on the post-quarantine ladder
        self._step_fn = jax.jit(self._raw_step, donate_argnums=(1,))
        with contextlib.suppress(Exception):
            self.exec_plans = self._resolve_plans(self._raw_step)
        obs.tracer().instant("engine.replan", cat="serving",
                             reason=reason, quarantined=",".join(suspects))

    def _check_finite(self, rows, ok, done: list) -> set:
        """NaN/Inf logit guard.  ``rows``: [(seq, row_index)] consuming
        a token this step; ``ok``: the device-computed per-row finite
        flags.  Non-finite rows (organic or injected) are quarantined —
        the sequence is cancelled cleanly instead of poisoning the
        batch — and once ``nan_replan_after`` events accumulate the
        suspect backend is quarantined too.  Returns the ids of
        quarantined sequences."""
        if not rows:
            return set()
        ok_host = np.asarray(ok)
        bad = {i for (_, i) in rows if not bool(ok_host[i])}
        ev = faults.fire("nan_logits")
        if ev is not None:
            bad.add(rows[int(ev.rng.integers(len(rows)))][1])
        if not bad:
            return set()
        out = set()
        for seq, i in rows:
            if i not in bad:
                continue
            self.num_nan_events += 1
            obs.registry().counter(
                "serving_nan_quarantined_total",
                help="sequences quarantined on non-finite logits").inc()
            done.append(self.cancel(seq, "quarantined"))
            out.add(id(seq))
        if self.num_nan_events >= self.nan_replan_after:
            self._replan("nan_logits")
        return out

    # ------------------------------------------------------------- clock
    @property
    def now(self) -> float:
        return self._clock() - self._t0

    # ------------------------------------------------------------ intake
    def submit(self, req: Request, *, arrival: float | None = None
               ) -> Sequence:
        """Queue a request.  ``arrival`` backdates ``t_arrival`` (engine
        seconds) so latency metrics include queueing delay the engine was
        too busy to observe; default: now.

        Malformed requests (over the model/pool budget) still raise;
        *load* problems do not — a full queue or a hopeless deadline
        sheds the request cleanly instead (returned Sequence has status
        'shed' and never enters the scheduler)."""
        total = len(req.prompt) + req.max_new_tokens
        if total > self.max_model_len:
            raise ValueError(
                f"request {req.rid}: prompt+new = {total} exceeds "
                f"max_model_len {self.max_model_len}")
        if self.pool.blocks_for(total) > self.pool.capacity:
            raise ValueError(
                f"request {req.rid}: needs {self.pool.blocks_for(total)} "
                f"blocks, pool holds {self.pool.capacity}")
        if (req.deadline_s is None and req.ttft_deadline_s is None and
                (self.default_deadline_s or self.default_ttft_deadline_s)):
            req = dataclasses.replace(
                req, deadline_s=self.default_deadline_s,
                ttft_deadline_s=self.default_ttft_deadline_s)
        seq = Sequence(req=req,
                       t_arrival=self.now if arrival is None else arrival)
        if req.deadline_s is not None or req.ttft_deadline_s is not None:
            self._deadline_watch = True
        shed_reason = None
        if self.max_queue is not None and \
                len(self.scheduler.waiting) >= self.max_queue:
            shed_reason = "queue_full"
        elif req.deadline_s is not None:
            # deadline-aware admission: if the p95 queue wait already
            # exceeds the whole budget, queueing it is a promise the
            # engine knows it can't keep
            p95 = obs.registry().histogram(
                "serving_queue_wait_s").percentile(95)
            if p95 is not None and p95 > req.deadline_s:
                shed_reason = "deadline_hopeless"
        if shed_reason is not None:
            return self._shed(seq, shed_reason)
        self.scheduler.add(seq)
        obs.registry().counter("serving_requests_submitted_total",
                               help="requests queued").inc()
        obs.tracer().instant("request.submit", cat="serving",
                             rid=req.rid, prompt_tokens=len(req.prompt))
        return seq

    def _shed(self, seq: Sequence, reason: str) -> Sequence:
        seq.status = "shed"
        seq.phase = Phase.FINISHED
        seq.t_finish = self.now
        self.num_shed += 1
        self.rejected.append(seq)
        obs.registry().counter(
            "serving_shed_total",
            help="requests rejected at admission (load shedding)",
            reason=reason).inc()
        obs.tracer().instant("request.shed", cat="serving",
                             rid=seq.req.rid, reason=reason)
        return seq

    def cancel(self, seq: Sequence, reason: str = "cancelled") -> Sequence:
        """Cleanly terminate a queued or running sequence: scheduler
        resources freed, status recorded, counted — never an exception.
        Idempotent on already-terminal sequences."""
        if seq.phase is Phase.FINISHED:
            return seq
        self.scheduler.remove(seq)
        seq.status = reason
        seq.t_finish = self.now
        self.rejected.append(seq)
        obs.registry().counter(
            "serving_cancelled_total",
            help="live sequences cancelled (deadline/disconnect/guard)",
            reason=reason).inc()
        obs.tracer().instant("request.cancel", cat="serving",
                             rid=seq.req.rid, reason=reason,
                             generated=len(seq.generated))
        return seq

    def _enforce_deadlines(self, done: list) -> None:
        now = self.now
        for seq in list(self.scheduler.waiting) + list(self.scheduler.running):
            req = seq.req
            if req.deadline_s is not None and \
                    now - seq.t_arrival > req.deadline_s:
                done.append(self.cancel(seq, "deadline"))
            elif req.ttft_deadline_s is not None and \
                    seq.t_first_token is None and \
                    now - seq.t_arrival > req.ttft_deadline_s:
                done.append(self.cancel(seq, "deadline"))

    # -------------------------------------------------------------- step
    def step(self) -> list[Sequence]:
        """One engine iteration (one prefill chunk OR one decode batch).
        Returns sequences that *terminated* this iteration — finished
        normally (status 'ok') or cancelled (deadline / disconnect /
        quarantine; see ``Sequence.status``)."""
        done: list[Sequence] = []
        injecting = faults.active() is not None
        if injecting:
            ev = faults.fire("latency")
            if ev is not None:
                time.sleep(ev.magnitude)  # step-latency spike
            self._maybe_disconnect(done)
        if self._deadline_watch:
            self._enforce_deadlines(done)
        act = self.scheduler.schedule()
        self._sample_depths()
        if act is None:
            if self.scheduler.waiting and not injecting:
                raise RuntimeError(
                    "engine stalled: waiting requests but nothing running "
                    "and the head cannot be admitted")
            # under injection a transient (injected OOM) admission miss
            # is expected — report idle and let the caller re-step
            return done
        if act[0] == "prefill":
            self._prefill_chunk(act[1], act[2], act[3], done)
        else:
            self._decode_batch(act[1], done)
        if self._hang_flag.is_set():
            self._escalate_hang()
        return done

    def _maybe_disconnect(self, done: list) -> None:
        live = [s for s in self.scheduler.running if not s.done]
        if not live:
            return
        ev = faults.fire("disconnect")
        if ev is not None:
            victim = live[int(ev.rng.integers(len(live)))]
            done.append(self.cancel(victim, "disconnected"))

    def _sample_depths(self) -> None:
        """Per-iteration queue/occupancy samples (gauge = live view for
        /metrics; histogram = distribution for BENCH_serve.json)."""
        reg = obs.registry()
        depth = len(self.scheduler.waiting)
        running = len(self.scheduler.running)
        if self.scheduler.num_preemptions == 0:
            self.max_resident_seqs = max(self.max_resident_seqs, running)
        reg.gauge("serving_queue_depth",
                  help="waiting requests").set(depth)
        reg.gauge("serving_running_seqs",
                  help="admitted sequences").set(running)
        reg.histogram("serving_queue_depth_samples",
                      help="queue depth at each engine iteration",
                      buckets=DEPTH_BUCKETS).observe(depth)
        obs.tracer().counter("queue", waiting=depth, running=running)

    def _prefill_chunk(self, seq: Sequence, start: int, end: int,
                       done: list) -> None:
        C = self.prefill_chunk
        toks = seq.prefill_tokens
        n = end - start
        tokens = np.zeros((1, C), np.int32)
        tokens[0, :n] = toks[start:end]
        positions = (start + np.arange(C, dtype=np.int32))[None]
        ws = kv_blocks.write_slots(seq.blocks, start, n, C,
                                   self.block_size)[None]
        vs = kv_blocks.view_slots(seq.blocks, self.max_blocks_per_seq,
                                  self.block_size)[None]
        last = np.array([n - 1], np.int32)
        with obs.tracer().span("engine.prefill_chunk", cat="serving",
                               rid=seq.req.rid, start=start, end=end), \
                self._step_timer("prefill"):
            out = self._run_step(tokens, positions, ws, vs, last)
            if out is None:  # pool rebuilt; seq was preempted, re-prefills
                return
            tok, logits, ok, self.kv = out
            if obs.tracer().enabled:  # sync so the span covers compute,
                jax.block_until_ready(tok)  # never on the untraced path
        self.num_prefill_steps += 1
        seq.prefill_pos = end
        if end == len(toks):  # prompt fully ingested -> first new token
            if self._check_finite([(seq, 0)], ok, done):
                return
            seq.phase = Phase.DECODE
            self._append(seq, self._pick(seq, tok[0], logits[0]), done)

    def _decode_batch(self, seqs: list[Sequence], done: list) -> None:
        active = []
        for seq in seqs:
            if seq.phase is not Phase.DECODE:
                continue  # evicted as a preemption victim this iteration
            if self.scheduler.grow_for_decode(seq):
                active.append(seq)
        if not active:
            return
        B, bs = self.max_slots, self.block_size
        W = self.max_blocks_per_seq * bs
        tokens = np.zeros((B, 1), np.int32)
        positions = np.zeros((B, 1), np.int32)
        # idle slots write to (distinct offsets of) the scratch block and
        # view only scratch — static shapes, no effect on live sequences
        ws = (np.arange(B, dtype=np.int32) % bs)[:, None]
        vs = np.zeros((B, W), np.int32)
        for seq in active:
            b = seq.slot
            tokens[b, 0] = seq.generated[-1]
            positions[b, 0] = seq.num_tokens - 1
            ws[b] = kv_blocks.write_slots(seq.blocks, seq.num_tokens - 1,
                                          1, 1, bs)
            vs[b] = kv_blocks.view_slots(seq.blocks, self.max_blocks_per_seq,
                                         bs)
        last = np.zeros((B,), np.int32)
        with obs.tracer().span("engine.decode_step", cat="serving",
                               batch=len(active)), \
                self._step_timer("decode"):
            out = self._run_step(tokens, positions, ws, vs, last)
            if out is None:  # pool rebuilt; everyone re-prefills
                return
            tok, logits, ok, self.kv = out
            if obs.tracer().enabled:
                jax.block_until_ready(tok)
        self.num_decode_steps += 1
        obs.registry().histogram(
            "serving_decode_batch_occupancy",
            help="live rows per decode iteration (of max_slots)",
            buckets=DEPTH_BUCKETS).observe(len(active))
        # only live rows are guarded — idle slots attend scratch garbage
        bad = self._check_finite([(s, s.slot) for s in active], ok, done)
        for seq in active:
            if id(seq) in bad:
                continue
            self._append(seq, self._pick(seq, tok[seq.slot],
                                         logits[seq.slot]), done)

    # ---------------------------------------------------------- sampling
    def _pick(self, seq: Sequence, greedy_tok, logits) -> int:
        if seq.req.temperature <= 0.0:
            return int(greedy_tok)
        rng = self._rngs.setdefault(
            seq.req.rid,
            np.random.default_rng(
                np.random.SeedSequence([self._sample_seed, seq.req.rid])))
        scaled = np.asarray(logits, np.float64) / seq.req.temperature
        return int(np.argmax(scaled + rng.gumbel(size=scaled.shape)))

    def _step_timer(self, phase: str):
        return _StepTimer(self, phase)

    def _append(self, seq: Sequence, token: int, done: list) -> None:
        t = self.now
        reg = obs.registry()
        seq.generated.append(token)
        if seq.t_first_token is None:
            seq.t_first_token = t
            reg.histogram("serving_ttft_s",
                          help="time to first token (incl. queueing)"
                          ).observe(t - seq.t_arrival)
        elif seq.t_last_token is not None:
            reg.histogram("serving_intertoken_s",
                          help="gap between consecutive tokens of one "
                               "request").observe(t - seq.t_last_token)
        seq.t_last_token = t
        if self.on_token is not None:
            self.on_token(seq.req.rid, token, detokenize([token]))
        if seq.done:
            seq.t_finish = t
            self.scheduler.finish(seq)
            self.finished.append(seq)
            done.append(seq)
            reg.counter("serving_requests_finished_total",
                        help="requests run to completion").inc()
            reg.histogram("serving_request_latency_s",
                          help="arrival -> last token"
                          ).observe(t - seq.t_arrival)
            obs.tracer().instant("request.finish", cat="serving",
                                 rid=seq.req.rid,
                                 new_tokens=len(seq.generated),
                                 preemptions=seq.preemptions)

    # --------------------------------------------------------------- run
    def run(self, requests, *, wait_for_arrivals: bool = True
            ) -> dict[int, Sequence]:
        """Drive a request stream to completion.  ``arrival_time`` is
        seconds after the call; with ``wait_for_arrivals`` the engine
        sleeps through idle gaps (honest open-loop simulation), otherwise
        future arrivals are pulled forward when it would idle."""
        pending = sorted(requests, key=lambda r: (r.arrival_time, r.rid))
        results: dict[int, Sequence] = {}
        if not self.scheduler.has_work() and not self.finished:
            self._t0 = self._clock()  # fresh engine: run() starts the clock

        def _take():
            req = pending.pop(0)
            # a request queues from its *scheduled* arrival even if the
            # engine was mid-step then (min: pulled-forward arrivals are
            # stamped at actual submission, never in the future)
            seq = self.submit(req, arrival=min(req.arrival_time, self.now))
            if seq.status != "ok":  # shed at admission: terminal already
                results[req.rid] = seq

        while pending or self.scheduler.has_work():
            while pending and pending[0].arrival_time <= self.now:
                _take()
            if not self.scheduler.has_work():
                if not pending:
                    break  # everything left was shed at submission
                if wait_for_arrivals:
                    time.sleep(max(0.0, pending[0].arrival_time - self.now))
                _take()
            for seq in self.step():
                results[seq.req.rid] = seq
        return results

    def reset_metrics(self) -> None:
        """Drop finished-request history, step counters, AND the
        streaming/in-flight aggregates (serving_* registry series: TTFT,
        inter-token, step-time, queue-depth histograms) — e.g. after a
        warmup stream — without touching queued/running work."""
        self.finished = []
        self.rejected = []
        self.num_prefill_steps = 0
        self.num_decode_steps = 0
        self.max_resident_seqs = 0
        self.num_shed = 0
        self.num_step_retries = 0
        self.num_nan_events = 0
        self.num_replans = 0
        self.num_kv_rebuilds = 0
        self.scheduler.num_preemptions = 0
        self.scheduler.num_admitted = 0
        self.scheduler.num_evicted_blocks = 0
        self.scheduler.num_thrash = 0
        obs.registry().reset(prefix="serving_")
        for seq in self.scheduler.running:
            seq.t_last_token = None  # warmup gaps must not leak into the
            # measured stream's first inter-token sample

    # ----------------------------------------------------------- metrics
    def metrics(self) -> dict:
        """Aggregate serving metrics over finished requests.  Every key
        is always present and the call never raises — with 0 finished
        requests (including mid-flight: everything submitted but nothing
        done) counts and rates are 0 / 0.0 and percentiles are ``None``
        ("not measured", distinguishable from a true 0.0 latency); with
        1 finished request the percentiles are that request's value —
        never NaN, never a missing key (callers index
        ``m["tok_per_s"]`` unconditionally; display code should
        coalesce percentiles with ``or 0.0``)."""
        fin = self.finished

        def pct(xs, q):
            if len(xs) == 0:
                return None
            if len(xs) == 1:
                return float(xs[0])
            return float(np.percentile(np.asarray(xs), q))

        gen = sum(len(s.generated) for s in fin)
        span = (max(s.t_finish for s in fin)
                - min(s.t_arrival for s in fin)) if fin else 0.0
        lat = [s.t_finish - s.t_arrival for s in fin]
        ttft = [s.t_first_token - s.t_arrival for s in fin
                if s.t_first_token is not None]
        reg = obs.registry()
        inter = reg.histogram("serving_intertoken_s")
        return {
            "requests": len(fin),
            "generated_tokens": gen,
            "preemptions": self.scheduler.num_preemptions,
            "max_resident_seqs": self.max_resident_seqs,
            "evicted_blocks": self.scheduler.num_evicted_blocks,
            "admitted": self.scheduler.num_admitted,
            "prefill_steps": self.num_prefill_steps,
            "decode_steps": self.num_decode_steps,
            "tok_per_s": gen / span if span > 0 else 0.0,
            "latency_p50_s": pct(lat, 50),
            "latency_p95_s": pct(lat, 95),
            "ttft_p50_s": pct(ttft, 50),
            "ttft_p95_s": pct(ttft, 95),
            # None on an empty reservoir, same contract as pct()
            "intertoken_p50_s": inter.percentile(50),
            "intertoken_p95_s": inter.percentile(95),
            # ---- resilience
            "shed": self.num_shed,
            "cancelled": len(self.rejected) - self.num_shed,
            "step_retries": self.num_step_retries,
            "nan_quarantined": self.num_nan_events,
            "replans": self.num_replans,
            "kv_rebuilds": self.num_kv_rebuilds,
            "preempt_thrash": self.scheduler.num_thrash,
            "queue_wait_p95_s": reg.histogram(
                "serving_queue_wait_s").percentile(95),
        }

    def summary(self) -> dict:
        """Alias of :meth:`metrics` (historic name; keys are a strict
        superset of what it used to return)."""
        return self.metrics()
