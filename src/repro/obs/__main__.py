"""Artifact validation CLI — the schema gate CI runs:

    python -m repro.obs --validate-snapshot metrics.json
    python -m repro.obs --validate-trace trace.json

Exit 0 when every named artifact is schema-valid; exit 1 with one
problem per line otherwise.
"""

from __future__ import annotations

import argparse
import sys

from repro.obs import validate_snapshot_file, validate_trace_file


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.obs")
    ap.add_argument("--validate-snapshot", action="append", default=[],
                    metavar="PATH", help="metrics snapshot JSON to check")
    ap.add_argument("--validate-trace", action="append", default=[],
                    metavar="PATH", help="Chrome-trace JSON to check")
    args = ap.parse_args(argv)
    if not args.validate_snapshot and not args.validate_trace:
        ap.error("nothing to validate")

    problems: list[str] = []
    for p in args.validate_snapshot:
        problems += [f"{p}: {e}" for e in validate_snapshot_file(p)]
    for p in args.validate_trace:
        problems += [f"{p}: {e}" for e in validate_trace_file(p)]

    if problems:
        print("\n".join(problems), file=sys.stderr)
        return 1
    n = len(args.validate_snapshot) + len(args.validate_trace)
    print(f"ok: {n} artifact(s) schema-valid")
    return 0


if __name__ == "__main__":
    sys.exit(main())
