"""Observability CLI — artifact validation, calibration, and the
measured-vs-predicted regression sentinel:

    python -m repro.obs --validate-snapshot metrics.json
    python -m repro.obs --validate-trace trace.json
    python -m repro.obs --calibrate --bench benchmarks/results/BENCH_kernels.json \
        --calibration calibration.json
    python -m repro.obs --validate-calibration calibration.json
    python -m repro.obs --check-regressions --calibration calibration.json \
        --bench benchmarks/results/BENCH_kernels.json --report-out report.md

``--calibrate`` fits the analytic perf-model constants (obs.perfmodel)
from whichever measurement sources are given (``--plan-cache`` autotune
timings, ``--bench`` BENCH_kernels.json, ``--metrics`` serve-run
snapshots; the plan cache at its default path is used when no source is
named) and writes a versioned calibration.json.

``--check-regressions`` re-reads the same sources and fails (exit 1)
when any measured timing exceeds ``--tolerance`` x the model's
prediction — the CI gate that catches a kernel regression without
golden-number baselines.

Exit 0 when every requested action passes; exit 1 with one problem per
line otherwise.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from repro.obs import validate_snapshot_file, validate_trace_file
from repro.obs import perfmodel as pm


def _gather_samples(args) -> tuple[list, list]:
    """(samples, source-descriptions) from the CLI's source flags."""
    samples: list = []
    sources: list = []
    plan_caches = list(args.plan_cache)
    if not plan_caches and not args.bench and not args.metrics:
        plan_caches = [None]  # default: the process plan cache
    for p in plan_caches:
        got, untagged = pm.samples_from_plan_cache(p)
        samples += got
        sources.append(f"plan-cache:{p or 'default'}")
        if untagged:
            print(f"note: skipped {untagged} pre-tag timing row(s) in "
                  f"{p or 'default plan cache'} (no interpret tag)",
                  file=sys.stderr)
    for p in args.bench:
        samples += pm.samples_from_bench(p)
        sources.append(f"bench:{p}")
    for p in args.metrics:
        doc = json.loads(Path(p).read_text())
        samples += pm.samples_from_snapshot(doc)
        sources.append(f"metrics:{p}")
    return samples, sources


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.obs")
    ap.add_argument("--validate-snapshot", action="append", default=[],
                    metavar="PATH", help="metrics snapshot JSON to check")
    ap.add_argument("--validate-trace", action="append", default=[],
                    metavar="PATH", help="Chrome-trace JSON to check")
    ap.add_argument("--validate-calibration", action="append", default=[],
                    metavar="PATH", help="perf-model calibration to check")
    ap.add_argument("--calibrate", action="store_true",
                    help="fit perf-model constants from the measurement "
                         "sources and write --calibration")
    ap.add_argument("--check-regressions", action="store_true",
                    help="compare measured timings against the calibrated "
                         "model; exit 1 on outliers")
    ap.add_argument("--plan-cache", action="append", default=[],
                    metavar="PATH", help="plan cache JSON with autotune "
                                         "timings (measurement source)")
    ap.add_argument("--bench", action="append", default=[], metavar="PATH",
                    help="BENCH_kernels.json (measurement source)")
    ap.add_argument("--metrics", action="append", default=[],
                    metavar="PATH", help="metrics snapshot with "
                                         "kernel_gemm_s series (source)")
    ap.add_argument("--calibration", default=None, metavar="PATH",
                    help="calibration.json path (default: "
                         "$REPRO_CALIBRATION or the user cache dir)")
    ap.add_argument("--tolerance", type=float,
                    default=pm.DEFAULT_TOLERANCE,
                    help="regression band: measured > tolerance*predicted "
                         "fails (default %(default)s)")
    ap.add_argument("--report-out", default=None, metavar="PATH",
                    help="write the ranked regression report (markdown)")
    args = ap.parse_args(argv)
    actions = (args.validate_snapshot or args.validate_trace
               or args.validate_calibration or args.calibrate
               or args.check_regressions)
    if not actions:
        ap.error("nothing to do")

    problems: list[str] = []
    for p in args.validate_snapshot:
        problems += [f"{p}: {e}" for e in validate_snapshot_file(p)]
    for p in args.validate_trace:
        problems += [f"{p}: {e}" for e in validate_trace_file(p)]
    for p in args.validate_calibration:
        problems += [f"{p}: {e}" for e in pm.validate_calibration_file(p)]

    calib_path = args.calibration or pm.default_calibration_path()

    if args.calibrate:
        samples, sources = _gather_samples(args)
        try:
            cal = pm.fit(samples, sources=sources)
        except ValueError as e:
            problems.append(f"calibrate: {e}")
        else:
            # additive collective-time term: fitted from any
            # shard_variants tables the same plan caches carry (absent
            # tables -> the block is simply omitted; version unchanged)
            coll_rows: list = []
            plan_caches = list(args.plan_cache) or [None]
            for p in plan_caches:
                coll_rows += pm.collective_rows_from_plan_cache(p)
            coll = pm.fit_collective(coll_rows, device=cal.device,
                                     interpret=cal.interpret)
            if coll is not None:
                cal.collective = coll
            out = cal.save(calib_path)
            print(f"calibrated {cal.device} interpret={cal.interpret} "
                  f"from {cal.fit['n_samples']} samples "
                  f"(rms rel err {cal.fit['rms_rel_err']:.2f}, "
                  f"max {cal.fit['max_abs_rel_err']:.2f}"
                  + (f"; collective term from {coll['n_samples']} "
                     f"variant rows" if coll else "")
                  + f") -> {out}")

    if args.check_regressions and not problems:
        cal = pm.load_calibration(calib_path)
        if cal is None:
            problems.append(
                f"check-regressions: no calibration matching this "
                f"device/interpret partition at {calib_path} — run "
                f"--calibrate first")
        else:
            samples, _ = _gather_samples(args)
            report = pm.check_regressions(samples, cal,
                                          tolerance=args.tolerance)
            text = pm.render_report(report)
            if args.report_out:
                Path(args.report_out).parent.mkdir(parents=True,
                                                   exist_ok=True)
                Path(args.report_out).write_text(text + "\n")
            print(text)
            if not report["n_samples"]:
                problems.append("check-regressions: no samples in the "
                                "calibration's partition — nothing to "
                                "check")
            elif not report["ok"]:
                problems.append(
                    f"check-regressions: {report['n_outliers']} "
                    f"measurement(s) slower than "
                    f"{args.tolerance:g}x the model prediction")

    if problems:
        print("\n".join(problems), file=sys.stderr)
        return 1
    n = (len(args.validate_snapshot) + len(args.validate_trace)
         + len(args.validate_calibration))
    if n:
        print(f"ok: {n} artifact(s) schema-valid")
    return 0


if __name__ == "__main__":
    sys.exit(main())
