"""Counters / gauges / histograms with a versioned JSON snapshot and a
Prometheus-style text exposition.

This is the repo's measurement substrate (ISSUE 6): every layer —
serving engine, dispatch planner, autotuner, kernels, collectives,
benchmarks — records into one process-wide :class:`Registry`, and every
surface (``launch/serve --metrics-json/--prom-port``, the ``BENCH_*``
JSON artifacts, tests) reads the same snapshot format back out.

Design constraints, in order:

* **Near-zero overhead.**  Recording is a Python attribute bump under
  the GIL — no locks on the hot path beyond histogram reservoir
  appends, no formatting until export.  Nothing here ever stages work
  into a jit trace (that is ``obs.trace``'s job, and only when tracing
  is explicitly on).
* **Accurate serving percentiles.**  Histograms keep a bounded
  reservoir of raw samples (default 8192) next to fixed buckets, so
  p50/p95/p99 in snapshots are computed from real samples instead of
  bucket interpolation; the buckets only feed the Prometheus export.
* **Self-describing artifacts.**  ``snapshot()`` carries
  ``schema_version`` and a flat, diffable series list;
  :func:`validate_snapshot` is the schema gate CI runs against
  ``launch/serve --metrics-json`` output.
"""

from __future__ import annotations

import bisect
import json
import random
import threading
import time
from dataclasses import dataclass, field

SNAPSHOT_SCHEMA_VERSION = 1

# latency-oriented default buckets (seconds): 100us .. 60s, roughly x3
DEFAULT_BUCKETS = (1e-4, 3e-4, 1e-3, 3e-3, 1e-2, 3e-2, 0.1, 0.3, 1.0,
                   3.0, 10.0, 30.0, 60.0)

RESERVOIR_CAP = 8192


def _labels_key(labels: dict) -> tuple:
    return tuple(sorted(labels.items()))


@dataclass
class Counter:
    """Monotonic counter (float; ``inc`` only)."""

    name: str
    labels: dict = field(default_factory=dict)
    help: str = ""
    value: float = 0.0

    def inc(self, n: float = 1.0) -> None:
        self.value += n

    def as_dict(self) -> dict:
        return {"name": self.name, "labels": dict(self.labels),
                "value": self.value}


@dataclass
class Gauge:
    """Last-write-wins instantaneous value."""

    name: str
    labels: dict = field(default_factory=dict)
    help: str = ""
    value: float = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def inc(self, n: float = 1.0) -> None:
        self.value += n

    def dec(self, n: float = 1.0) -> None:
        self.value -= n

    def as_dict(self) -> dict:
        return {"name": self.name, "labels": dict(self.labels),
                "value": self.value}


class Histogram:
    """Fixed buckets for the Prometheus export + a bounded reservoir of
    raw samples for accurate snapshot percentiles.

    Reservoir policy: the first ``RESERVOIR_CAP`` samples are kept
    verbatim; past that, classic Algorithm-R replacement keeps the kept
    set a uniform sample of everything observed.  count/sum/min/max are
    exact regardless.
    """

    def __init__(self, name: str, labels: dict | None = None,
                 help: str = "", buckets=DEFAULT_BUCKETS):
        self.name = name
        self.labels = dict(labels or {})
        self.help = help
        self.buckets = tuple(sorted(buckets))
        self._bucket_counts = [0] * (len(self.buckets) + 1)  # +inf tail
        self.count = 0
        self.sum = 0.0
        self.min = float("inf")
        self.max = float("-inf")
        self._samples: list[float] = []
        self._rng = random.Random(0x5EED)
        self._lock = threading.Lock()

    def observe(self, v: float) -> None:
        v = float(v)
        with self._lock:
            self.count += 1
            self.sum += v
            if v < self.min:
                self.min = v
            if v > self.max:
                self.max = v
            self._bucket_counts[bisect.bisect_left(self.buckets, v)] += 1
            if len(self._samples) < RESERVOIR_CAP:
                self._samples.append(v)
            else:
                j = self._rng.randrange(self.count)
                if j < RESERVOIR_CAP:
                    self._samples[j] = v

    def percentile(self, q: float) -> float | None:
        """q in [0, 100]; ``None`` when the reservoir is empty (never
        raises — a snapshot taken before any observation reports null
        percentiles rather than a fabricated 0.0, and serving summaries
        with 0 or 1 samples must stay well-formed).  Callers that need
        a number coalesce: ``h.percentile(50) or 0.0``."""
        with self._lock:
            xs = sorted(self._samples)
        if not xs:
            return None
        if len(xs) == 1:
            return xs[0]
        pos = (q / 100.0) * (len(xs) - 1)
        lo = int(pos)
        hi = min(lo + 1, len(xs) - 1)
        frac = pos - lo
        return xs[lo] * (1 - frac) + xs[hi] * frac

    def as_dict(self) -> dict:
        cum = 0
        buckets = {}
        for le, n in zip(self.buckets, self._bucket_counts):
            cum += n
            buckets[f"{le:g}"] = cum
        buckets["+Inf"] = self.count
        return {"name": self.name, "labels": dict(self.labels),
                "count": self.count, "sum": self.sum,
                "min": self.min if self.count else 0.0,
                "max": self.max if self.count else 0.0,
                # null (not 0.0) before the first observation — see
                # percentile(); validate_snapshot accepts both
                "p50": self.percentile(50), "p90": self.percentile(90),
                "p95": self.percentile(95), "p99": self.percentile(99),
                "buckets": buckets}


class Registry:
    """Process-wide series store: get-or-create by (kind, name, labels)."""

    def __init__(self):
        self._series: dict[tuple, object] = {}
        self._lock = threading.Lock()

    def _get(self, kind: str, cls, name: str, help: str, labels: dict,
             **kw):
        key = (kind, name, _labels_key(labels))
        s = self._series.get(key)
        if s is None:
            with self._lock:
                s = self._series.get(key)
                if s is None:
                    s = cls(name, labels=labels, help=help, **kw) \
                        if cls is Histogram else cls(name=name,
                                                    labels=labels, help=help)
                    self._series[key] = s
        return s

    def counter(self, name: str, help: str = "", **labels) -> Counter:
        return self._get("counter", Counter, name, help, labels)

    def gauge(self, name: str, help: str = "", **labels) -> Gauge:
        return self._get("gauge", Gauge, name, help, labels)

    def histogram(self, name: str, help: str = "",
                  buckets=DEFAULT_BUCKETS, **labels) -> Histogram:
        return self._get("histogram", Histogram, name, help, labels,
                         buckets=buckets)

    # ------------------------------------------------------------ views
    def series(self, kind: str | None = None) -> list:
        return [s for (k, _, _), s in sorted(self._series.items(),
                                             key=lambda kv: kv[0])
                if kind is None or k == kind]

    def value(self, kind: str, name: str, **labels) -> float | None:
        """Current value of one series, or None if never created (tests
        and benchmark emitters read through this)."""
        s = self._series.get((kind, name, _labels_key(labels)))
        if s is None:
            return None
        return s.count if kind == "histogram" else s.value

    def reset(self, prefix: str | None = None) -> None:
        """Drop every series, or only those whose name starts with
        ``prefix`` (e.g. ``reset(prefix="serving_")`` after a warmup
        stream, leaving dispatch/kernel series intact)."""
        with self._lock:
            if prefix is None:
                self._series.clear()
            else:
                for key in [k for k in self._series
                            if k[1].startswith(prefix)]:
                    del self._series[key]

    # ---------------------------------------------------------- exports
    def snapshot(self, extra: dict | None = None) -> dict:
        """Versioned, JSON-able view of every series.  ``extra`` merges
        free-form context (engine config, benchmark args) under its own
        key so the series schema stays stable."""
        out = {
            "schema_version": SNAPSHOT_SCHEMA_VERSION,
            "created_unix": time.time(),
            "counters": [s.as_dict() for s in self.series("counter")],
            "gauges": [s.as_dict() for s in self.series("gauge")],
            "histograms": [s.as_dict() for s in self.series("histogram")],
        }
        if extra:
            out["context"] = extra
        return out

    def prometheus_text(self) -> str:
        """Prometheus exposition format (text/plain; version 0.0.4)."""
        lines: list[str] = []

        def fmt_labels(labels: dict, extra: dict | None = None) -> str:
            items = {**labels, **(extra or {})}
            if not items:
                return ""
            body = ",".join(f'{k}="{v}"' for k, v in sorted(items.items()))
            return "{" + body + "}"

        seen_meta: set[tuple[str, str]] = set()

        def meta(name: str, kind: str, help: str):
            if (name, kind) in seen_meta:
                return
            seen_meta.add((name, kind))
            if help:
                lines.append(f"# HELP {name} {help}")
            lines.append(f"# TYPE {name} {kind}")

        for s in self.series("counter"):
            meta(s.name, "counter", s.help)
            lines.append(f"{s.name}{fmt_labels(s.labels)} {s.value:g}")
        for s in self.series("gauge"):
            meta(s.name, "gauge", s.help)
            lines.append(f"{s.name}{fmt_labels(s.labels)} {s.value:g}")
        for s in self.series("histogram"):
            meta(s.name, "histogram", s.help)
            d = s.as_dict()
            for le, n in d["buckets"].items():
                lines.append(f"{s.name}_bucket"
                             f"{fmt_labels(s.labels, {'le': le})} {n}")
            lines.append(f"{s.name}_sum{fmt_labels(s.labels)} {d['sum']:g}")
            lines.append(f"{s.name}_count{fmt_labels(s.labels)} "
                         f"{d['count']}")
        return "\n".join(lines) + "\n"


_REGISTRY = Registry()


def registry() -> Registry:
    """The process-wide default registry."""
    return _REGISTRY


# ------------------------------------------------------------ validation
def validate_snapshot(doc: dict) -> list[str]:
    """Schema check for a ``Registry.snapshot()`` document.  Returns a
    list of problems (empty == valid) — CI asserts emptiness rather than
    parsing exceptions."""
    errs: list[str] = []
    if not isinstance(doc, dict):
        return ["snapshot is not an object"]
    if doc.get("schema_version") != SNAPSHOT_SCHEMA_VERSION:
        errs.append(f"schema_version={doc.get('schema_version')!r} != "
                    f"{SNAPSHOT_SCHEMA_VERSION}")
    for kind, req in (("counters", ("name", "labels", "value")),
                      ("gauges", ("name", "labels", "value")),
                      ("histograms", ("name", "labels", "count", "sum",
                                      "p50", "p95", "buckets"))):
        rows = doc.get(kind)
        if not isinstance(rows, list):
            errs.append(f"{kind} missing or not a list")
            continue
        for i, row in enumerate(rows):
            if not isinstance(row, dict):
                errs.append(f"{kind}[{i}] not an object")
                continue
            for f in req:
                if f not in row:
                    errs.append(f"{kind}[{i}] ({row.get('name')}) "
                                f"missing {f!r}")
            if not isinstance(row.get("labels", {}), dict):
                errs.append(f"{kind}[{i}] labels not an object")
            if kind == "histograms":
                # percentiles are numbers, or null for an empty series
                # (a snapshot taken before any observation)
                for f in ("p50", "p90", "p95", "p99"):
                    if f in row and not isinstance(
                            row[f], (int, float, type(None))):
                        errs.append(f"{kind}[{i}] ({row.get('name')}) "
                                    f"{f} is {type(row[f]).__name__}, "
                                    "expected number or null")
                if row.get("count") and row.get("p50") is None:
                    errs.append(f"{kind}[{i}] ({row.get('name')}) has "
                                "observations but null p50")
    return errs


def validate_snapshot_file(path) -> list[str]:
    try:
        doc = json.loads(open(path).read())
    except (OSError, ValueError) as e:
        return [f"unreadable snapshot {path}: {e}"]
    return validate_snapshot(doc)


# ---------------------------------------------------------- prom endpoint
def serve_prometheus(port: int, reg: Registry | None = None):
    """Expose ``reg`` at http://0.0.0.0:port/metrics from a daemon
    thread.  Returns the server (call ``.shutdown()`` to stop; tests
    bind port 0 and read ``server.server_address``)."""
    import http.server

    reg = reg or registry()

    class Handler(http.server.BaseHTTPRequestHandler):
        def do_GET(self):  # noqa: N802 (stdlib API)
            if self.path not in ("/metrics", "/"):
                self.send_response(404)
                self.end_headers()
                return
            body = reg.prometheus_text().encode()
            self.send_response(200)
            self.send_header("Content-Type",
                             "text/plain; version=0.0.4; charset=utf-8")
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)

        def log_message(self, *a):  # quiet
            pass

    server = http.server.ThreadingHTTPServer(("0.0.0.0", port), Handler)
    t = threading.Thread(target=server.serve_forever, daemon=True,
                         name="obs-prometheus")
    t.start()
    return server
