"""Analytic per-GeMM cost model: flops / bytes / attainable time.

``benchmarks/roofline.py`` prices *whole model steps* against a pod;
this module prices *one kernel invocation* on *this process's device*
so kernel profiling hooks (``kernels/ops``) and the microbench can
annotate every measured wall time with an achieved-vs-attainable
fraction.  Conventions match the roofline module (1 MAC = 2 FLOPs;
LUT-consume table adds = 1 op each, retired on the vector unit on
current TPUs — the paper §6 limiting factor).

Hardware table is keyed by ``jax.default_backend()``.  The tpu entry is
the tpu-v5e-class chip used throughout EXPERIMENTS.md; the cpu/gpu
entries are deliberately rough — on CPU the "fraction" column is only
useful for relative comparison between shapes, and the microbench
records which hardware model priced each row.
"""

from __future__ import annotations

from dataclasses import asdict, dataclass


@dataclass(frozen=True)
class Device:
    name: str
    matmul_flops: float   # peak dense-matmul FLOP/s (MXU / tensor core)
    vector_flops: float   # peak vector-unit op rate (LUT consume adds)
    mem_bw: float         # B/s main-memory bandwidth

    def as_dict(self) -> dict:
        return asdict(self)


DEVICES = {
    # tpu-v5e-class, mirrored from benchmarks/roofline.Hardware
    "tpu": Device("tpu-v5e-class", 197e12, 4e12, 819e9),
    # a100-class single die (PAPERS.md Tensor Core study numbers)
    "gpu": Device("a100-class", 312e12, 19.5e12, 1555e9),
    # honest-but-rough host numbers: one AVX2 socket-ish
    "cpu": Device("cpu-host", 1e11, 5e10, 3e10),
}


def device(backend: str | None = None) -> Device:
    if backend is None:
        import jax

        backend = jax.default_backend()
    return DEVICES.get(backend, DEVICES["cpu"])


def produce_table_ops(d: int) -> float:
    """Eq.-9 op count to build ONE d-digit LUT column (16^d entries)
    from one d-wide activation chunk.

    The table is built hierarchically: every i-digit prefix table is
    shared by all 16^(d-i) extensions, so level i costs 16^i adds and
    the whole build costs sum_{i=1..d} 16^i ~= 16^d * 16/15 — NOT
    16^d * d.  (The previous formula priced every entry as d
    independent multiply-adds, overcounting produce work — and the
    matching transient LUT traffic — by a factor that grows linearly
    in d; the overcount is what made d > 2 look produce-bound.)
    """
    return float(sum(16 ** i for i in range(1, d + 1)))


def lut_bytes(k: int, b: int, d: int = 3) -> float:
    """Transient LUT write+read traffic for one (k, b) produce phase,
    priced at HBM rates: 16^d entries per d-wide chunk, k/d chunks, b
    columns, f32.  The fused Pallas deployment keeps these tiles in
    VMEM (paper §4), so :func:`gemm_cost` reports this separately
    instead of folding it into ``bytes``."""
    return 2 * 16 ** d * (k / d) * b * 4.0


def gemm_cost(m: int, k: int, b: int, *, quant: str = "msgemm",
              d: int = 3, dtype_bytes: float = 2.0) -> dict:
    """Cost of one (b, k) x (k, m) GeMM invocation.

    Returns produce/consume op counts (paper Eq. 9 accounting — the
    shared-prefix table build, see :func:`produce_table_ops`), bytes
    moved through main memory, and the arithmetic totals the roofline
    fraction divides by.  ``quant`` other than msgemm prices the dense
    path (produce = the whole matmul, consume = 0).  ``lut_bytes`` is
    the transient LUT spill traffic for deployments whose LUT does NOT
    stay in VMEM; it is reported but excluded from ``bytes`` (the fused
    kernels never move it through HBM).
    """
    if quant == "msgemm":
        # Eq. 9: shared tuple-table build per d-wide chunk (adds +
        # 16 b(i)*x products per digit, the latter negligible)
        produce = 2.0 * produce_table_ops(d) * (k / d) * b
        consume = float(m) * (k / d) * b       # table adds (VPU)
        weight_bytes = (32 / d) / 8 * m * k    # packed digit indices
        lutb = lut_bytes(k, b, d)
    else:
        produce = 2.0 * m * k * b
        consume = 0.0
        weight_bytes = dtype_bytes * m * k
        lutb = 0.0
    act_bytes = dtype_bytes * b * k
    out_bytes = dtype_bytes * b * m
    return {
        "m": m, "k": k, "b": b, "quant": quant, "d": d,
        "produce_flops": produce,
        "consume_ops": consume,
        "flops": produce + consume,
        "bytes": weight_bytes + act_bytes + out_bytes,
        "weight_bytes": weight_bytes,
        "lut_bytes": lutb,
    }


def attainable_s(cost: dict, dev: Device | None = None) -> float:
    """Roofline lower bound for one invocation: max of the compute term
    (produce at matmul rate + consume at vector rate) and the memory
    term."""
    dev = dev or device()
    compute = (cost["produce_flops"] / dev.matmul_flops
               + cost["consume_ops"] / dev.vector_flops)
    memory = cost["bytes"] / dev.mem_bw
    return max(compute, memory)


def achieved_fraction(measured_s: float, cost: dict,
                      dev: Device | None = None) -> float:
    """attainable / measured — 1.0 means running at the roofline, small
    means leaving performance on the table.  0.0 when measured time is
    degenerate."""
    if measured_s <= 0.0:
        return 0.0
    return attainable_s(cost, dev) / measured_s


def annotate(measured_s: float, m: int, k: int, b: int, *,
             quant: str = "msgemm", d: int = 3,
             dev: Device | None = None) -> dict:
    """One-call convenience for benchmark rows: cost + attainable +
    fraction + the hardware model that priced it."""
    dev = dev or device()
    cost = gemm_cost(m, k, b, quant=quant, d=d)
    att = attainable_s(cost, dev)
    return {
        **cost,
        "measured_s": measured_s,
        "attainable_s": att,
        "roofline_fraction": att / measured_s if measured_s > 0 else 0.0,
        "hardware": dev.name,
    }
