"""Persisted-artifact integrity: CRC stamps, atomic writes, quarantine.

The serving stack persists three JSON artifacts it must be able to
warm-start from — the dispatch plan cache, the perf-model
``calibration.json``, and checkpoint manifests.  A half-written or
bit-rotted file must never take the server down: loads verify a CRC32
stamp (and basic schema) and, on any mismatch, *quarantine* the file —
rename it aside, bump ``artifact_quarantined_total{artifact=...}`` —
so the caller rebuilds from scratch while the corpse stays on disk for
post-mortem.

Legacy files without a ``crc`` field still parse (the stamp is
additive); only files that fail to parse or carry a *wrong* stamp are
quarantined.
"""

from __future__ import annotations

import json
import os
import zlib
from pathlib import Path

from repro import obs

CRC_FIELD = "crc"


def payload_crc(payload: dict) -> str:
    """CRC32 over the canonical JSON encoding of ``payload`` minus the
    stamp field itself (so the stamp can live inside the document)."""
    body = {k: v for k, v in payload.items() if k != CRC_FIELD}
    blob = json.dumps(body, sort_keys=True, separators=(",", ":"))
    return f"{zlib.crc32(blob.encode()) & 0xFFFFFFFF:08x}"


def stamp_crc(payload: dict) -> dict:
    payload[CRC_FIELD] = payload_crc(payload)
    return payload


def check_crc(payload: dict) -> bool:
    """True when the stamp matches or is absent (legacy file)."""
    stamp = payload.get(CRC_FIELD)
    return stamp is None or stamp == payload_crc(payload)


def atomic_write_json(path: str | os.PathLike, payload: dict, *,
                      indent: int | None = 1) -> None:
    """Crash-safe JSON publish: pid-unique tmp file in the same
    directory, fsync, then atomic rename over the target."""
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    tmp = path.with_name(f"{path.name}.{os.getpid()}.tmp")
    with open(tmp, "w") as f:
        json.dump(payload, f, indent=indent)
        f.flush()
        os.fsync(f.fileno())
    tmp.replace(path)


def quarantine(path: str | os.PathLike, artifact: str,
               reason: str = "corrupt") -> Path | None:
    """Move a corrupt artifact aside (``<name>.quarantined[.N]``) and
    count it.  Returns the quarantine path, or None when the file was
    already gone.  Never raises — a quarantine that itself fails just
    deletes the file so the rebuild can proceed."""
    path = Path(path)
    if not path.exists():
        return None
    dest = path.with_name(path.name + ".quarantined")
    n = 0
    while dest.exists():
        n += 1
        dest = path.with_name(f"{path.name}.quarantined.{n}")
    try:
        path.replace(dest)
    except OSError:
        try:
            path.unlink()
        except OSError:
            return None
        dest = None
    obs.registry().counter(
        "artifact_quarantined_total",
        help="corrupt persisted artifacts moved aside on load",
        artifact=artifact, reason=reason).inc()
    return dest


def load_json_checked(path: str | os.PathLike, artifact: str
                      ) -> dict | None:
    """Parse + CRC-verify a JSON artifact.  Returns the payload dict, or
    None after quarantining an unreadable/corrupt file.  A missing file
    returns None without quarantine."""
    path = Path(path)
    try:
        text = path.read_text()
    except FileNotFoundError:
        return None
    except (OSError, ValueError):  # ValueError covers non-UTF8 garbage
        quarantine(path, artifact, reason="unreadable")
        return None
    try:
        payload = json.loads(text)
        if not isinstance(payload, dict):
            raise ValueError("artifact root must be a JSON object")
    except ValueError:
        quarantine(path, artifact, reason="parse")
        return None
    if not check_crc(payload):
        quarantine(path, artifact, reason="crc")
        return None
    return payload
