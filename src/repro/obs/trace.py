"""Structured span/event tracer emitting Chrome-trace (Perfetto) JSON.

Two recording surfaces share one event buffer:

* **Host spans** — ``with tracer().span("engine.step"): ...`` around
  ordinary Python (the engine loop, the scheduler, benchmarks).  These
  are complete ("ph": "X") events with microsecond timestamps.
* **Jit marks** — :func:`jit_begin` / :func:`jit_end` stage a
  ``jax.debug.callback`` into the *current trace* whose firing is
  ordered by data dependency: the begin-mark depends on the kernel's
  input (fires when the input is ready ≈ compute start) and the
  end-mark on its output (fires when the result materializes ≈ compute
  end).  The host side pairs them by name into "X" events, so a jitted
  serving step yields per-linear GeMM and per-collective spans inside
  the same trace as the engine's host spans.

**Zero overhead when disabled** is a hard contract: ``tracer().enabled``
is checked at *trace time* (plain Python), so with tracing off not a
single callback is staged into the jitted computation — the lowered HLO
is byte-identical to a build without obs.  ``jit_marks_staged`` counts
staged marks so tests can assert exactly that.  Consequence: enable
tracing *before* building/compiling the thing you want traced;
already-compiled executables keep whatever was staged when they traced.

Load the written file at https://ui.perfetto.dev (or
chrome://tracing) — README §Observability.
"""

from __future__ import annotations

import json
import os
import threading
import time
from contextlib import AbstractContextManager

TRACE_SCHEMA_VERSION = 1

# observability-of-the-observability: how many jit marks were staged
# into traces since import (tests assert 0 on the tracing-off path)
jit_marks_staged = 0

# Perfetto lane ids: host-side spans vs events fired from jax callback
# threads (kept separate so reordered callback arrivals cannot corrupt
# the host lane's nesting)
TID_HOST = 0
TID_JIT = 1


class _NullSpan(AbstractContextManager):
    __slots__ = ()

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class _Span(AbstractContextManager):
    __slots__ = ("tracer", "name", "cat", "args", "t0")

    def __init__(self, tracer, name, cat, args):
        self.tracer = tracer
        self.name = name
        self.cat = cat
        self.args = args

    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self.tracer._complete(self.name, self.cat, self.t0,
                              time.perf_counter(), self.args, TID_HOST)
        return False


class Tracer:
    def __init__(self):
        self.enabled = False
        self._events: list[dict] = []
        self._t0 = time.perf_counter()
        self._lock = threading.Lock()
        self._open: dict[str, list[float]] = {}  # jit-mark pairing stacks
        self._pid = os.getpid()

    # ----------------------------------------------------------- control
    def enable(self, *, clear: bool = False) -> None:
        if clear:
            self.clear()
        self.enabled = True

    def disable(self) -> None:
        self.enabled = False

    def clear(self) -> None:
        with self._lock:
            self._events.clear()
            self._open.clear()
        self._t0 = time.perf_counter()

    # ----------------------------------------------------------- record
    def _us(self, t: float) -> float:
        return (t - self._t0) * 1e6

    def _complete(self, name, cat, t0, t1, args, tid) -> None:
        ev = {"name": name, "cat": cat, "ph": "X", "pid": self._pid,
              "tid": tid, "ts": self._us(t0),
              "dur": max(self._us(t1) - self._us(t0), 0.0)}
        if args:
            ev["args"] = args
        with self._lock:
            self._events.append(ev)

    def span(self, name: str, cat: str = "host", **args):
        """Context manager recording one complete event (no-op singleton
        when disabled — safe on hot loops)."""
        if not self.enabled:
            return _NULL_SPAN
        return _Span(self, name, cat, args or None)

    def instant(self, name: str, cat: str = "host", **args) -> None:
        if not self.enabled:
            return
        ev = {"name": name, "cat": cat, "ph": "i", "s": "p",
              "pid": self._pid, "tid": TID_HOST,
              "ts": self._us(time.perf_counter())}
        if args:
            ev["args"] = args
        with self._lock:
            self._events.append(ev)

    def counter(self, name: str, **values) -> None:
        """Chrome-trace counter track (ph "C") — e.g. queue depth over
        time next to the spans."""
        if not self.enabled:
            return
        with self._lock:
            self._events.append({"name": name, "ph": "C",
                                 "pid": self._pid, "tid": TID_HOST,
                                 "ts": self._us(time.perf_counter()),
                                 "args": values})

    # -------------------------------------------------- jit-mark pairing
    def _jit_begin(self, name: str) -> None:
        with self._lock:
            self._open.setdefault(name, []).append(time.perf_counter())

    def _jit_end(self, name: str, cat: str, args: dict | None) -> float:
        t1 = time.perf_counter()
        with self._lock:
            stack = self._open.get(name)
            t0 = stack.pop() if stack else None
        if t0 is None:  # unmatched (callback reorder): degrade to instant
            with self._lock:
                self._events.append({"name": name, "cat": cat, "ph": "i",
                                     "s": "p", "pid": self._pid,
                                     "tid": TID_JIT, "ts": self._us(t1)})
            return 0.0
        self._complete(name, cat, t0, t1, args, TID_JIT)
        return t1 - t0

    # ------------------------------------------------------------ export
    def events(self) -> list[dict]:
        with self._lock:
            return list(self._events)

    def save(self, path) -> dict:
        """Write Chrome-trace JSON (Perfetto-loadable) and return the
        document."""
        doc = {
            "traceEvents": self.events(),
            "displayTimeUnit": "ms",
            "metadata": {"schema_version": TRACE_SCHEMA_VERSION,
                         "producer": "repro.obs",
                         "pid": self._pid},
        }
        with open(path, "w") as f:
            json.dump(doc, f, indent=1)
        return doc

    @staticmethod
    def load(path) -> dict:
        with open(path) as f:
            return json.load(f)


_TRACER = Tracer()


def tracer() -> Tracer:
    return _TRACER


def enable_tracing(*, clear: bool = False) -> Tracer:
    _TRACER.enable(clear=clear)
    return _TRACER


def disable_tracing() -> None:
    _TRACER.disable()


# ------------------------------------------------------------- jit marks
def _probe(value):
    """A scalar view of ``value`` for the callback operand — the
    callback must depend on the array without shipping the whole buffer
    to the host."""
    import jax.numpy as jnp

    if hasattr(value, "ndim") and value.ndim > 0:
        return value[(0,) * value.ndim]
    return jnp.asarray(value)


def jit_begin(value, name: str):
    """Stage a begin-mark whose firing depends on ``value`` being
    computed; returns ``value`` unchanged.  No-op (nothing staged) when
    tracing is off at trace time."""
    t = _TRACER
    if not t.enabled:
        return value
    global jit_marks_staged
    jit_marks_staged += 1
    import jax

    jax.debug.callback(lambda _: t._jit_begin(name), _probe(value))
    return value


def jit_end(value, name: str, cat: str = "jit", args: dict | None = None,
            hist: str | None = None, hist_labels: dict | None = None):
    """Stage the matching end-mark on ``value`` (the op's output);
    returns ``value`` unchanged.  When ``hist`` is given, the measured
    duration is also observed into that registry histogram (e.g.
    per-collective seconds) — attribution lands in both the trace and
    the metrics snapshot."""
    t = _TRACER
    if not t.enabled:
        return value
    global jit_marks_staged
    jit_marks_staged += 1
    import jax

    labels = dict(hist_labels or {})

    def cb(_):
        dur = t._jit_end(name, cat, args)
        if hist is not None:
            from repro.obs import metrics as M

            M.registry().histogram(hist, **labels).observe(dur)

    jax.debug.callback(cb, _probe(value))
    return value


# ------------------------------------------------------------ validation
def validate_trace(doc: dict) -> list[str]:
    """Schema check for a saved trace document (empty list == valid)."""
    errs: list[str] = []
    if not isinstance(doc, dict):
        return ["trace is not an object"]
    evs = doc.get("traceEvents")
    if not isinstance(evs, list):
        return ["traceEvents missing or not a list"]
    meta = doc.get("metadata", {})
    if meta.get("schema_version") != TRACE_SCHEMA_VERSION:
        errs.append(f"metadata.schema_version="
                    f"{meta.get('schema_version')!r} != "
                    f"{TRACE_SCHEMA_VERSION}")
    for i, ev in enumerate(evs):
        if not isinstance(ev, dict):
            errs.append(f"traceEvents[{i}] not an object")
            continue
        for f in ("name", "ph", "ts", "pid", "tid"):
            if f not in ev:
                errs.append(f"traceEvents[{i}] ({ev.get('name')}) "
                            f"missing {f!r}")
        if ev.get("ph") == "X" and "dur" not in ev:
            errs.append(f"traceEvents[{i}] complete event missing dur")
    return errs


def validate_trace_file(path) -> list[str]:
    try:
        doc = json.loads(open(path).read())
    except (OSError, ValueError) as e:
        return [f"unreadable trace {path}: {e}"]
    return validate_trace(doc)
