"""Analytical kernel-time model, calibration, and regression sentinel.

``obs.costs`` prices a GeMM against an idealized roofline; this module
predicts the *wall time of our actual kernels* from a handful of
per-device constants, the way Markidis et al. predict Tensor Core
throughput from measured machine constants:

    t = launch_s
      + step_s            * grid_steps
      + produce_s_per_flop * produce_flops     (Eq.-9 LUT build, incl.
                                                legacy-grid re-production)
      + consume_s_per_op  * (consume_ops + epilogue_ops)
      + hbm_s_per_byte    * hbm_bytes          (incl. jnp LUT spill and
                                                legacy per-step writeback)

The five constants are **calibrated** by weighted least squares from
timings the stack already persists — the autotuner's per-candidate
``timings`` tables in the plan cache, ``BENCH_kernels.json`` rows, and
``kernel_gemm_s`` histograms from a traced serve run — and stored as a
versioned ``calibration.json`` artifact.  The fit minimizes *relative*
error (each row is scaled by 1/measured), so microsecond decode shapes
weigh the same as millisecond prefill shapes.

Calibrations are partitioned on (device, interpret): an interpret-mode
CPU fit is never used to predict compiled TPU kernels and vice versa
(timing rows that predate the ``interpret`` tag are skipped).

Consumers:

* ``dispatch.autotune`` ranks candidate plans by :func:`predict` and
  measures only the predicted-top-few (model-guided search);
* ``python -m repro.obs --check-regressions`` compares every measured
  timing against the model within a tolerance band and fails CI on
  outliers (the regression sentinel);
* ``benchmarks/roofline.py`` reports measured vs model-attainable time
  per shape.

Tolerance band: a measurement is an outlier when
``measured > tolerance * predicted`` (default ``DEFAULT_TOLERANCE`` =
3.0x — generous against interpret-mode jitter, tight enough that a
dropped produce amortization or a 10x-slowed kernel always trips it).
Faster-than-predicted rows are reported (``fast=true``) but never fail:
a kernel beating the model is not a regression.
"""

from __future__ import annotations

import dataclasses
import json
import math
import os
import time
from dataclasses import dataclass, field
from pathlib import Path

CALIBRATION_VERSION = 1
DEFAULT_TOLERANCE = 3.0

# model constants, in feature-vector order (the fit solves for these)
CONSTANT_NAMES = ("launch_s", "step_s", "produce_s_per_flop",
                  "consume_s_per_op", "hbm_s_per_byte")

# collective-time term (ISSUE 10): predicted extra wall time of a
# pipelined k-sharded linear relative to its one-shot plan,
#   dt = coll_call_s * d(kernel calls) + coll_hop_s * d(hops)
#      + coll_byte_s * d(bytes)
# fitted per (device, interpret) from the plan cache's shard_variants
# timing tables.  Unlike CONSTANT_NAMES these may fit NEGATIVE: a
# negative hop/byte coefficient is the measured overlap benefit — more
# ring hops *reducing* wall time because they hide under compute.  The
# block is additive in calibration.json (version stays 1; files without
# it validate, consumers fall back to measuring every variant).
COLLECTIVE_CONSTANT_NAMES = ("coll_call_s", "coll_hop_s", "coll_byte_s")

# rough per-element op counts for epilogue activations (the epilogue
# term rides the consume rate — it executes on the same vector unit)
_ACT_OPS = {"none": 0.0, "relu": 1.0, "gelu": 8.0, "silu": 6.0}


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def effective_interpret(interpret: bool | None) -> bool:
    """Resolve interpret=None exactly like the kernel wrappers do."""
    if interpret is not None:
        return bool(interpret)
    import jax

    return jax.default_backend() != "tpu"


def current_partition() -> tuple[str, bool]:
    """(device, interpret) of this process — the calibration partition
    every fresh measurement in this process belongs to."""
    import jax

    dev = jax.default_backend()
    return dev, dev != "tpu"


# =====================================================================
# samples — one measured kernel invocation, self-describing
# =====================================================================
@dataclass(frozen=True)
class Sample:
    """One measured timing plus everything the model needs to predict
    it.  ``tm/tj/tb`` may be None (heuristic tiles are derived)."""

    backend: str
    mode: str                  # 'msgemm' | 'int4_dequant' | 'bf16'
    d: int
    scale_block: int
    m: int
    k: int
    b: int
    measured_s: float
    device: str
    interpret: bool
    tm: int | None = None
    tj: int | None = None
    tb: int | None = None
    consume_chunk: int = 1
    acc_in_vmem: bool = True
    epilogue_ops: float = 0.0
    source: str = "?"

    def desc(self) -> str:
        return (f"{self.backend} {self.mode} d={self.d} m={self.m} "
                f"k={self.k} b={self.b} tm={self.tm} tj={self.tj} "
                f"tb={self.tb} chunk={self.consume_chunk} "
                f"acc={'vmem' if self.acc_in_vmem else 'legacy'} "
                f"[{self.source}]")


# =====================================================================
# feature extraction — the analytic work terms
# =====================================================================
def features(backend: str, mode: str, d: int, scale_block: int,
             m: int, k: int, b: int, *,
             tm: int | None = None, tj: int | None = None,
             tb: int | None = None, consume_chunk: int = 1,
             acc_in_vmem: bool = True,
             epilogue_ops: float = 0.0) -> dict:
    """The per-invocation work terms, one per model constant.

    Mirrors what the kernels actually execute (padded tile shapes, the
    produce-amortization factor, legacy per-step writeback, the jnp
    backend's HBM-resident LUT) rather than the idealized Eq.-9
    minimum — obs.costs answers "how fast could this be", this answers
    "how long will *our* kernel take".
    """
    from repro.obs import costs

    d = max(int(d), 1)
    sb = max(int(scale_block), d)
    f32 = 4.0
    if backend == "msgemm_pallas" and mode == "msgemm":
        from repro.kernels import ops

        kc = _ceil_div(k, d)
        if tm is None or tj is None or tb is None:
            htm, htj, htb = ops.msgemm_tiles(m, kc, b, d, sb)
            tm, tj, tb = tm or htm, tj or htj, tb or htb
        nm, nj, nb = _ceil_div(m, tm), _ceil_div(kc, tj), _ceil_div(b, tb)
        mp, kcp, bp = nm * tm, nj * tj, nb * tb
        acc = acc_in_vmem and ops.acc_stripe_fits(m, tm, tb)
        steps = nm * nj * nb
        # LUT build per (b, j) tile; the legacy grid re-produces it for
        # every m tile (the PR-4 amortization this model must see to
        # rank acc_in_vmem correctly)
        produce = 2.0 * costs.produce_table_ops(d) * kcp * bp
        if not acc:
            produce *= nm
        consume = float(mp) * kcp * bp
        idx_bytes = f32 * m * kc          # packed digit indices (int32)
        act_bytes = f32 * k * bp          # x read per produce pass
        out_bytes = f32 * mp * bp         # single VMEM->HBM writeback
        if not acc:
            act_bytes *= nm
            out_bytes *= 2.0 * nj         # y_ref += per j step (r+w)
        hbm = idx_bytes * nb + act_bytes + out_bytes
    elif backend == "msgemm_jnp" and mode == "msgemm":
        kc = _ceil_div(k, d)
        chunk = max(int(consume_chunk), 1)
        nsteps = _ceil_div(kc, chunk)
        steps = nsteps + 1                # scan steps + produce matmul
        produce = 2.0 * costs.produce_table_ops(d) * kc * b
        consume = float(m) * nsteps * chunk * b
        # XLA materializes the LUT in main memory: the spill traffic
        # the fused kernel avoids is real cost here
        hbm = (f32 * m * kc + f32 * k * b + f32 * m * b
               + costs.lut_bytes(k, b, d))
    elif backend in ("int4_pallas", "int4_jnp") or mode == "int4_dequant":
        produce = 2.0 * float(m) * k * b  # dequant + dense matmul
        consume = 0.0
        if backend == "int4_pallas":
            from repro.kernels import ops

            if tm is None or tj is None or tb is None:
                htm, htk, htb = ops.int4_tiles(m, k, b, sb)
                tm, tj, tb = tm or htm, tj or htk, tb or htb
            steps = _ceil_div(m, tm) * _ceil_div(k, tj) * _ceil_div(b, tb)
        else:
            steps = 1
        hbm = (0.5 * m * k + f32 * m * _ceil_div(k, sb)
               + f32 * k * b + f32 * m * b)
    else:                                 # dense bf16 matmul
        produce = 2.0 * float(m) * k * b
        consume = 0.0
        steps = 1
        hbm = 2.0 * m * k + 2.0 * k * b + 2.0 * m * b
    return {
        "launch_s": 1.0,
        "step_s": float(steps),
        "produce_s_per_flop": produce,
        "consume_s_per_op": consume + float(epilogue_ops),
        "hbm_s_per_byte": hbm,
    }


def sample_features(s: Sample) -> dict:
    return features(s.backend, s.mode, s.d, s.scale_block, s.m, s.k, s.b,
                    tm=s.tm, tj=s.tj, tb=s.tb,
                    consume_chunk=s.consume_chunk,
                    acc_in_vmem=s.acc_in_vmem,
                    epilogue_ops=s.epilogue_ops)


def epilogue_op_count(epilogue, m: int, b: int) -> float:
    """Per-invocation elementwise ops of a core.epilogue.Epilogue."""
    if epilogue is None or getattr(epilogue, "is_identity", True):
        return 0.0
    per = _ACT_OPS.get(getattr(epilogue, "act", "none"), 4.0)
    per += 1.0 if getattr(epilogue, "bias", False) else 0.0
    per += 1.0 if getattr(epilogue, "residual", False) else 0.0
    return per * m * b


# =====================================================================
# calibration artifact
# =====================================================================
@dataclass
class Calibration:
    """Fitted per-device model constants + fit diagnostics.  Versioned
    JSON on disk (``calibration.json``); partitioned on (device,
    interpret) so measurements from different execution modes never mix.

    ``constants`` is keyed by backend name: the launch/per-step
    overheads of the Pallas interpreter and an XLA-compiled jnp scan
    differ by orders of magnitude on the same host, so one global
    constant set cannot fit a mixed-backend sample pool.  The ``"*"``
    entry is the pooled fit over every sample and serves backends
    without enough samples for their own fit."""

    device: str
    interpret: bool
    constants: dict[str, dict[str, float]]
    fit: dict = field(default_factory=dict)
    sources: list = field(default_factory=list)
    version: int = CALIBRATION_VERSION
    created_unix: float = 0.0
    # additive (ISSUE 10): fitted COLLECTIVE_CONSTANT_NAMES + fit
    # diagnostics, empty when no shard-variant timings existed
    collective: dict = field(default_factory=dict)

    def matches(self, device: str, interpret: bool) -> bool:
        return self.device == device and self.interpret == bool(interpret)

    def constants_for(self, backend: str | None) -> dict[str, float]:
        return self.constants.get(backend) or self.constants["*"]

    def as_dict(self) -> dict:
        out = {"version": self.version, "device": self.device,
               "interpret": self.interpret,
               "constants": {bk: dict(c)
                             for bk, c in self.constants.items()},
               "fit": dict(self.fit), "sources": list(self.sources),
               "created_unix": self.created_unix}
        if self.collective:
            out["collective"] = dict(self.collective)
        return out

    def save(self, path: str | os.PathLike) -> Path:
        from repro import faults
        from repro.obs import artifacts

        p = Path(path)
        artifacts.atomic_write_json(p, artifacts.stamp_crc(self.as_dict()))
        ev = faults.fire("corrupt_calibration")
        if ev is not None:
            faults.corrupt_file(p, ev)
        return p


def default_calibration_path() -> Path:
    env = os.environ.get("REPRO_CALIBRATION")
    if env:
        return Path(env)
    base = os.environ.get("XDG_CACHE_HOME") or os.path.join(
        os.path.expanduser("~"), ".cache")
    return Path(base) / "msgemm-repro" / "calibration.json"


def validate_calibration(doc: dict) -> list[str]:
    """Schema check for a calibration artifact (empty list == valid) —
    same contract as obs.validate_snapshot."""
    errs: list[str] = []
    if not isinstance(doc, dict):
        return ["calibration is not an object"]
    if doc.get("version") != CALIBRATION_VERSION:
        errs.append(f"version={doc.get('version')!r} != "
                    f"{CALIBRATION_VERSION}")
    if not isinstance(doc.get("device"), str):
        errs.append("device missing or not a string")
    if not isinstance(doc.get("interpret"), bool):
        errs.append("interpret missing or not a bool")
    consts = doc.get("constants")
    if not isinstance(consts, dict) or not isinstance(
            consts.get("*"), dict):
        errs.append("constants missing or no pooled '*' entry")
    else:
        for bk, block in consts.items():
            if not isinstance(block, dict):
                errs.append(f"constants[{bk!r}] not an object")
                continue
            for name in CONSTANT_NAMES:
                v = block.get(name)
                if not isinstance(v, (int, float)):
                    errs.append(f"constants[{bk!r}].{name} missing or "
                                f"non-numeric")
                elif v < 0 or not math.isfinite(v):
                    errs.append(f"constants[{bk!r}].{name}={v} not "
                                f"finite/>=0")
    fit = doc.get("fit")
    if not isinstance(fit, dict) or "n_samples" not in (fit or {}):
        errs.append("fit block missing n_samples")
    # the collective block is additive and optional — only validated
    # when present.  Its constants may legitimately be negative (they
    # model a *delta* vs the one-shot plan; overlap shows up as a
    # negative hop coefficient), so only finiteness is required.
    coll = doc.get("collective")
    if coll is not None:
        if not isinstance(coll, dict):
            errs.append("collective block not an object")
        else:
            for name in COLLECTIVE_CONSTANT_NAMES:
                v = coll.get(name)
                if not isinstance(v, (int, float)):
                    errs.append(f"collective.{name} missing or "
                                f"non-numeric")
                elif not math.isfinite(v):
                    errs.append(f"collective.{name}={v} not finite")
            if "n_samples" not in coll:
                errs.append("collective block missing n_samples")
    return errs


def validate_calibration_file(path) -> list[str]:
    try:
        doc = json.loads(Path(path).read_text())
    except (OSError, ValueError) as e:
        return [f"unreadable calibration {path}: {e}"]
    return validate_calibration(doc)


def load_calibration(path: str | os.PathLike | None = None, *,
                     device: str | None = None,
                     interpret: bool | None = None,
                     max_age_s: float | None = None) -> Calibration | None:
    """Load a calibration if present, schema-valid, and matching the
    requested (device, interpret) partition — ``None`` otherwise
    (missing, corrupt, wrong version, wrong partition, or older than
    ``max_age_s``: every 'stale' case a consumer must fall back on)."""
    p = Path(path) if path is not None else default_calibration_path()
    from repro.obs import artifacts

    # parse + CRC check; corruption quarantines the file aside
    # (artifact_quarantined_total{artifact="calibration"}) and callers
    # fall back to uncalibrated heuristics, same as a missing file.
    doc = artifacts.load_json_checked(p, "calibration")
    if doc is None:
        return None
    if validate_calibration(doc):
        return None
    cal = Calibration(
        device=doc["device"], interpret=doc["interpret"],
        constants={bk: {k: float(v) for k, v in block.items()}
                   for bk, block in doc["constants"].items()},
        fit=doc.get("fit", {}), sources=doc.get("sources", []),
        version=doc["version"],
        created_unix=float(doc.get("created_unix", 0.0)),
        collective=doc.get("collective") or {})
    if device is None or interpret is None:
        dev, itp = current_partition()
        device = device if device is not None else dev
        interpret = interpret if interpret is not None else itp
    if not cal.matches(device, interpret):
        return None
    if max_age_s is not None and cal.created_unix and \
            time.time() - cal.created_unix > max_age_s:
        return None
    return cal


# =====================================================================
# prediction
# =====================================================================
@dataclass(frozen=True)
class PredictedCost:
    """Predicted wall time of one kernel invocation, by component."""

    t_total_s: float
    t_launch_s: float
    t_step_s: float
    t_produce_s: float
    t_consume_s: float
    t_hbm_s: float
    calibrated: bool
    device: str

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def _fallback_constants(device: str) -> dict[str, float]:
    """Uncalibrated constants from the obs.costs hardware table — the
    prediction degrades to a roofline-style bound (no launch/step
    overhead) so predict() always returns *something* ordered."""
    from repro.obs import costs

    dev = costs.DEVICES.get(device, costs.DEVICES["cpu"])
    return {"launch_s": 0.0, "step_s": 0.0,
            "produce_s_per_flop": 1.0 / dev.matmul_flops,
            "consume_s_per_op": 1.0 / dev.vector_flops,
            "hbm_s_per_byte": 1.0 / dev.mem_bw}


def predict_features(feats: dict, calib: Calibration | None,
                     device: str = "cpu",
                     backend: str | None = None) -> PredictedCost:
    if calib is not None:
        consts = calib.constants_for(backend)
        calibrated, device = True, calib.device
    else:
        consts, calibrated = _fallback_constants(device), False
    terms = {name: consts.get(name, 0.0) * feats.get(name, 0.0)
             for name in CONSTANT_NAMES}
    return PredictedCost(
        t_total_s=sum(terms.values()),
        t_launch_s=terms["launch_s"], t_step_s=terms["step_s"],
        t_produce_s=terms["produce_s_per_flop"],
        t_consume_s=terms["consume_s_per_op"],
        t_hbm_s=terms["hbm_s_per_byte"],
        calibrated=calibrated, device=device)


def predict(plan, spec, m: int, k: int, batch: int, *,
            calib: Calibration | None = None,
            epilogue=None) -> PredictedCost:
    """Predicted wall time for executing (spec, plan) on one
    (batch, k) x (k, m) linear.  ``plan`` is a dispatch ExecPlan (tile
    fields may be None — heuristics fill them exactly like the kernel
    wrappers); ``calib`` None falls back to the roofline-style constant
    table (``calibrated=False`` in the result)."""
    from repro.dispatch.plan import plan_d

    d = plan_d(spec, m, k)
    feats = features(
        plan.backend, spec.mode, max(d, 1), spec.scale_block, m, k, batch,
        tm=plan.tm, tj=plan.tj, tb=plan.tb,
        consume_chunk=plan.consume_chunk, acc_in_vmem=plan.acc_in_vmem,
        epilogue_ops=epilogue_op_count(epilogue, m, batch))
    device = calib.device if calib is not None else current_partition()[0]
    return predict_features(feats, calib, device, backend=plan.backend)


def predict_sample(s: Sample, calib: Calibration | None) -> PredictedCost:
    return predict_features(sample_features(s), calib, s.device,
                            backend=s.backend)


# =====================================================================
# collective-time term (pipelined k-sharded contractions, ISSUE 10)
# =====================================================================
def collective_features(*, impl: str, collective: str, axis_size: int,
                        m: int, b: int, pipeline_chunks: int = 1,
                        dtype_bytes: int = 4) -> dict:
    """(calls, hops, bytes) of resolving one k-sharded linear whose
    per-device partial output is (b, m) f32, under the given collective
    layout.  The hop/byte counts come from the single source of truth
    next to the ring implementations
    (``distributed.collectives.collective_cost``): bytes/hop x hops per
    the issue's model, summed over pipeline chunks."""
    from repro.distributed import collectives as coll

    hops, nbytes = coll.collective_cost(
        impl=impl, collective=collective, axis_size=axis_size,
        elems=m * b, dtype_bytes=dtype_bytes,
        pipeline_chunks=pipeline_chunks)
    return {"calls": max(int(pipeline_chunks), 1), "hops": hops,
            "bytes": nbytes}


def predict_collective(*, calls: float, hops: float, nbytes: float,
                       collective: dict) -> float:
    """Predicted wall-time *delta* (seconds, may be negative) of a
    collective layout relative to the one-shot xla plan of the same
    linear, from a fitted ``Calibration.collective`` block.  Used by
    the autotuner to rank pipelined candidates without measuring all
    chunk counts — only the ordering matters, so the shared one-shot
    baseline cancels."""
    return (collective.get("coll_call_s", 0.0) * (calls - 1)
            + collective.get("coll_hop_s", 0.0) * hops
            + collective.get("coll_byte_s", 0.0) * nbytes)


def collective_rows_from_plan_cache(path: str | os.PathLike | None = None
                                    ) -> list[dict]:
    """Per-variant timing rows from the plan cache's ``shard_variants``
    tables, each annotated with its base key (rows of one key share
    their compute cost, so only deltas within a key are meaningful)."""
    from repro.dispatch import autotune as at

    cache = at.PlanCache(path).load()
    out = []
    for key, var in sorted(cache._shard_variants.items()):
        for row in var.get("rows", []):
            r = dict(row)
            r["key"] = key
            out.append(r)
    return out


def fit_collective(rows: list[dict], *, device: str | None = None,
                   interpret: bool | None = None) -> dict | None:
    """Least-squares fit of COLLECTIVE_CONSTANT_NAMES from shard-variant
    timing rows (one partition).  Each key's one-shot row (pc=1, xla) is
    the per-key baseline; every other row contributes a delta equation
      s - s_base = call_s*(pc-1) + hop_s*(hops-hops_b) + byte_s*(B-B_b)
    Plain (signed) lstsq — negative coefficients are the measured
    overlap benefit.  None when fewer delta rows exist than constants
    (underdetermined fits mislead the ranking; callers fall back to
    measuring every variant)."""
    import numpy as np

    if device is None or interpret is None:
        dev, itp = current_partition()
        device = device if device is not None else dev
        interpret = interpret if interpret is not None else itp
    by_key: dict[str, list[dict]] = {}
    for r in rows:
        if r.get("device") != device or \
                bool(r.get("interpret")) != bool(interpret):
            continue
        by_key.setdefault(r.get("key", "?"), []).append(r)
    A, y = [], []
    for key, group in sorted(by_key.items()):
        base = next((r for r in group
                     if int(r.get("pipeline_chunks", 1)) == 1
                     and r.get("collective_impl") == "xla"), None)
        if base is None:
            continue
        for r in group:
            if r is base:
                continue
            A.append([int(r.get("pipeline_chunks", 1)) - 1,
                      float(r.get("hops", 0)) - float(base.get("hops", 0)),
                      float(r.get("bytes", 0.0))
                      - float(base.get("bytes", 0.0))])
            y.append(float(r["s"]) - float(base["s"]))
    if len(y) < len(COLLECTIVE_CONSTANT_NAMES):
        return None
    A_arr, y_arr = np.asarray(A, float), np.asarray(y, float)
    theta, *_ = np.linalg.lstsq(A_arr, y_arr, rcond=None)
    if not np.isfinite(theta).all():
        return None
    pred = A_arr @ theta
    resid = pred - y_arr
    out = {n: float(v)
           for n, v in zip(COLLECTIVE_CONSTANT_NAMES, theta)}
    out["n_samples"] = len(y)
    out["rms_err_s"] = float(np.sqrt(np.mean(resid ** 2)))
    return out


# =====================================================================
# calibration fit — weighted non-negative least squares
# =====================================================================
def _fit_constants(use: list[Sample]) -> dict[str, float]:
    """NNLS-lite fit of the 5 constants to one sample group.

    Weighted LS: each row is scaled by 1/measured so the objective is
    relative error — a 50us decode candidate counts as much as a 500ms
    prefill row.  Non-negativity by active-set elimination: solve,
    drop the most-negative constant, re-solve (a physical rate can
    never be negative; a dropped constant means the sample set cannot
    resolve it and it contributes 0)."""
    import numpy as np

    t = np.array([s.measured_s for s in use])
    A = np.array([[sample_features(s)[name] for name in CONSTANT_NAMES]
                  for s in use])
    Aw = A / t[:, None]                       # rows scaled by 1/measured
    ones = np.ones(len(use))
    active = list(range(len(CONSTANT_NAMES)))
    theta = np.zeros(len(CONSTANT_NAMES))
    while active:
        sol, *_ = np.linalg.lstsq(Aw[:, active], ones, rcond=None)
        if (sol >= 0).all():
            theta[:] = 0.0
            theta[active] = sol
            break
        active.pop(int(np.argmin(sol)))
    else:
        raise ValueError("calibration fit degenerate: no non-negative "
                         "constants explain the samples")
    return {n: float(v) for n, v in zip(CONSTANT_NAMES, theta)}


MIN_SAMPLES_PER_BACKEND = 3


def fit(samples: list[Sample], *, device: str | None = None,
        interpret: bool | None = None,
        sources: list | None = None) -> Calibration:
    """Fit the model constants from measured samples of one (device,
    interpret) partition.

    Constants are fitted **per backend** (each backend with >=
    ``MIN_SAMPLES_PER_BACKEND`` samples gets its own set) plus a pooled
    ``"*"`` fallback over all samples: interpreter step overhead and
    compiled dispatch overhead differ by orders of magnitude, and a
    single global constant set fitted across both systematically crushes
    whichever backend has fewer samples.  Fit diagnostics are computed
    with the same per-backend dispatch rule :func:`predict_sample` uses.
    """
    import numpy as np

    if device is None or interpret is None:
        dev, itp = current_partition()
        device = device if device is not None else dev
        interpret = interpret if interpret is not None else itp
    use = [s for s in samples
           if s.device == device and s.interpret == bool(interpret)
           and s.measured_s > 0.0]
    if len(use) < MIN_SAMPLES_PER_BACKEND:
        raise ValueError(
            f"calibration needs >= {MIN_SAMPLES_PER_BACKEND} samples in "
            f"partition (device={device!r}, interpret={interpret}); got "
            f"{len(use)} of {len(samples)} total — run the autotuner or "
            f"benchmarks/kernel_microbench.py first")
    constants = {"*": _fit_constants(use)}
    by_backend: dict[str, list[Sample]] = {}
    for s in use:
        by_backend.setdefault(s.backend, []).append(s)
    for bk, group in sorted(by_backend.items()):
        if len(group) >= MIN_SAMPLES_PER_BACKEND:
            try:
                constants[bk] = _fit_constants(group)
            except ValueError:
                pass  # degenerate group: falls back to the pooled fit
    cal = Calibration(device=device, interpret=bool(interpret),
                      constants=constants, sources=list(sources or []),
                      created_unix=time.time())
    rel = np.array([predict_sample(s, cal).t_total_s / s.measured_s - 1.0
                    for s in use])
    worst = int(np.argmax(np.abs(rel)))
    cal.fit = {"n_samples": len(use),
               "n_backends": len(constants) - 1,
               "per_backend_n": {bk: len(g)
                                 for bk, g in sorted(by_backend.items())},
               "rms_rel_err": float(np.sqrt(np.mean(rel ** 2))),
               "max_abs_rel_err": float(np.max(np.abs(rel))),
               "worst_sample": use[worst].desc()}
    return cal


# =====================================================================
# measurement sources
# =====================================================================
def parse_plan_key(key: str) -> dict | None:
    """Invert dispatch.plan.plan_key.  None for unparseable keys."""
    parts = key.split("|")
    if len(parts) < 12:
        return None
    try:
        return {"device": parts[0], "backend": parts[1], "mode": parts[2],
                "d": int(parts[3][1:]), "scale_block": int(parts[4][2:]),
                "storage": parts[5], "codebook": parts[6][2:],
                "m": int(parts[7][1:]), "k": int(parts[8][1:]),
                "b": int(parts[9][1:]), "acc_dtype": parts[10][3:],
                "shard": parts[11][2:]}
    except (ValueError, IndexError):
        return None


def samples_from_plan_cache(path: str | os.PathLike | None = None
                            ) -> tuple[list[Sample], int]:
    """(samples, n_untagged) from the autotuner's persisted per-candidate
    ``timings`` tables.  Rows written before the ``interpret`` tag
    existed cannot be partitioned and are skipped (counted)."""
    from repro.dispatch import autotune as at

    cache = at.PlanCache(path).load()
    out: list[Sample] = []
    untagged = 0
    for key in list(cache._timings):
        info = parse_plan_key(key)
        if info is None:
            continue
        for row in cache.timings(key) or []:
            if "interpret" not in row:
                untagged += 1   # pre-PR7 row: partition unknown, skip
                continue
            out.append(Sample(
                backend=info["backend"], mode=info["mode"], d=info["d"],
                scale_block=info["scale_block"], m=info["m"], k=info["k"],
                b=info["b"], measured_s=float(row["s"]),
                device=info["device"], interpret=bool(row["interpret"]),
                tm=row.get("tm"), tj=row.get("tj"), tb=row.get("tb"),
                consume_chunk=int(row.get("consume_chunk") or 1),
                acc_in_vmem=bool(row.get("acc_in_vmem", True)),
                source=f"plan-cache:{key}"))
    return out, untagged


def samples_from_bench(path: str | os.PathLike) -> list[Sample]:
    """Samples from a schema-2 BENCH_kernels.json: the new-grid and
    legacy-grid timings per shape (heuristic tiles recorded in the
    row).  Epilogue-timing columns are skipped — the unfused baseline
    times jnp ops outside the kernel."""
    doc = json.loads(Path(path).read_text())
    dev = doc.get("device", "cpu")
    interp = bool(doc.get("interpret", dev != "tpu"))
    out: list[Sample] = []
    for r in doc.get("shapes", []):
        tiles = r.get("tiles", {})
        common = dict(
            backend="msgemm_pallas", mode="msgemm", d=int(r["d"]),
            scale_block=int(r["scale_block"]), m=int(r["m"]),
            k=int(r["k"]), b=int(r["b"]), device=dev, interpret=interp,
            tm=tiles.get("tm"), tj=tiles.get("tj"), tb=tiles.get("tb"))
        if r.get("new_kernel_s"):
            out.append(Sample(**common, measured_s=float(r["new_kernel_s"]),
                              acc_in_vmem=True,
                              source=f"bench:{r['shape']}:new"))
        if r.get("legacy_kernel_s"):
            out.append(Sample(**common,
                              measured_s=float(r["legacy_kernel_s"]),
                              acc_in_vmem=False,
                              source=f"bench:{r['shape']}:legacy"))
    return out


def samples_from_snapshot(doc: dict, *, device: str | None = None,
                          interpret: bool | None = None) -> list[Sample]:
    """Samples from ``kernel_gemm_s`` histograms in a metrics snapshot
    (a serve run with tracing on).  Measured = p50 of the series; the
    plan is the shape heuristic (serving resolves heuristic-or-tuned
    plans, so p50 under the heuristic tiles is the honest comparison).
    Histograms whose labels predate the mode/d tags are skipped."""
    if device is None or interpret is None:
        dev, itp = current_partition()
        device = device if device is not None else dev
        interpret = interpret if interpret is not None else itp
    out: list[Sample] = []
    for row in doc.get("histograms", []):
        if row.get("name") != "kernel_gemm_s" or not row.get("count"):
            continue
        lb = row.get("labels", {})
        if not {"backend", "m", "k", "b", "mode", "d", "sb"} <= set(lb):
            continue
        p50 = row.get("p50")
        if not p50:
            continue
        out.append(Sample(
            backend=str(lb["backend"]), mode=str(lb["mode"]),
            d=int(lb["d"]), scale_block=int(lb["sb"]), m=int(lb["m"]),
            k=int(lb["k"]), b=int(lb["b"]), measured_s=float(p50),
            device=device, interpret=bool(interpret),
            source=(f"serve:kernel_gemm_s:{lb['backend']}"
                    f".m{lb['m']}.k{lb['k']}.b{lb['b']}")))
    return out


def samples_from_registry(reg=None) -> list[Sample]:
    """Live-registry variant of :func:`samples_from_snapshot` (the
    ``serve --check-regressions`` path)."""
    from repro import obs

    reg = reg or obs.registry()
    return samples_from_snapshot(reg.snapshot())


# =====================================================================
# regression sentinel
# =====================================================================
def check_regressions(samples: list[Sample], calib: Calibration, *,
                      tolerance: float = DEFAULT_TOLERANCE,
                      min_measured_s: float = 0.0) -> dict:
    """Compare every measured sample against the model.  Returns a
    ranked report (worst ratio first); ``ok`` is False when any sample
    in the calibration's partition exceeds the tolerance band
    (``measured > tolerance * predicted``).  Rows from other partitions
    are listed as skipped, never judged."""
    rows = []
    n_outliers = 0
    skipped = 0
    for s in samples:
        if not s.device == calib.device or \
                s.interpret != calib.interpret:
            skipped += 1
            continue
        pred = predict_sample(s, calib).t_total_s
        floor = max(calib.constants_for(s.backend)["launch_s"], 1e-9)
        ratio = s.measured_s / max(pred, floor)
        outlier = (ratio > tolerance and s.measured_s >= min_measured_s)
        n_outliers += outlier
        rows.append({"desc": s.desc(), "source": s.source,
                     "measured_s": s.measured_s, "predicted_s": pred,
                     "ratio": ratio, "outlier": outlier,
                     "fast": ratio < 1.0 / tolerance})
    rows.sort(key=lambda r: -r["ratio"])
    return {"tolerance": tolerance, "device": calib.device,
            "interpret": calib.interpret, "n_samples": len(rows),
            "n_skipped_other_partition": skipped,
            "n_outliers": n_outliers,
            "n_fast": sum(r["fast"] for r in rows),
            "ok": n_outliers == 0, "rows": rows}


def render_report(report: dict, *, top: int = 20) -> str:
    """Human-readable ranked outlier report (markdown table)."""
    lines = [
        f"# measured-vs-predicted regression report",
        f"partition: device={report['device']} "
        f"interpret={report['interpret']}  "
        f"tolerance: {report['tolerance']:g}x  "
        f"samples: {report['n_samples']} "
        f"(+{report['n_skipped_other_partition']} other-partition)  "
        f"outliers: {report['n_outliers']}  "
        f"verdict: {'OK' if report['ok'] else 'REGRESSION'}",
        "",
        "| rank | ratio | measured | predicted | flag | sample |",
        "|---|---|---|---|---|---|",
    ]
    for i, r in enumerate(report["rows"][:top]):
        flag = ("**OUTLIER**" if r["outlier"]
                else ("fast" if r["fast"] else "ok"))
        lines.append(
            f"| {i + 1} | {r['ratio']:.2f}x | {r['measured_s']:.3e}s | "
            f"{r['predicted_s']:.3e}s | {flag} | {r['desc']} |")
    if len(report["rows"]) > top:
        lines.append(f"| ... | | | | | {len(report['rows']) - top} more |")
    return "\n".join(lines)
