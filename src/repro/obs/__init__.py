"""Observability layer: metrics registry, structured tracer, cost model.

One import surface for the rest of the repo::

    from repro import obs

    obs.registry().counter("dispatch_plan_cache_total",
                           result="hit").inc()
    with obs.tracer().span("engine.step", cat="serving"):
        ...
    y = obs.jit_end(backend.run(...), "gemm", cat="dispatch")

Everything is off-by-default and near-free when off: counters are
attribute bumps, ``tracer().span`` returns a shared no-op context
manager, and :func:`jit_begin`/:func:`jit_end` stage **nothing** into
jitted code unless tracing was enabled at trace time (see
``obs.trace`` for the contract and ``tests/test_obs.py`` for the
zero-overhead assertions).
"""

from repro.obs import costs  # noqa: F401  (re-export module)
from repro.obs import perfmodel  # noqa: F401  (re-export module)


def __getattr__(name):
    # lazy: obs.artifacts imports repro.obs back for the registry, so a
    # top-level import here would be circular
    if name == "artifacts":
        import importlib
        return importlib.import_module("repro.obs.artifacts")
    raise AttributeError(name)
from repro.obs.metrics import (  # noqa: F401
    Registry,
    SNAPSHOT_SCHEMA_VERSION,
    registry,
    serve_prometheus,
    validate_snapshot,
    validate_snapshot_file,
)
from repro.obs.trace import (  # noqa: F401
    TRACE_SCHEMA_VERSION,
    Tracer,
    disable_tracing,
    enable_tracing,
    jit_begin,
    jit_end,
    tracer,
    validate_trace,
    validate_trace_file,
)

__all__ = [
    "Registry", "registry", "serve_prometheus",
    "validate_snapshot", "validate_snapshot_file",
    "SNAPSHOT_SCHEMA_VERSION",
    "Tracer", "tracer", "enable_tracing", "disable_tracing",
    "jit_begin", "jit_end",
    "validate_trace", "validate_trace_file", "TRACE_SCHEMA_VERSION",
    "costs", "perfmodel",
]
