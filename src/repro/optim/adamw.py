"""AdamW with global-norm clipping — raw-JAX pytree implementation.

Optimizer state shards exactly like the params (same logical axes), so the
ZeRO-style 2D layout of DESIGN.md §4 applies to m/v as well; the dry-run's
memory_analysis confirms the per-chip fit at 512 chips.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable

import jax
import jax.numpy as jnp

from repro.optim import schedules


@dataclass(frozen=True)
class AdamWConfig:
    lr: Callable = field(default_factory=lambda: schedules.constant(1e-3))
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip: float = 1.0
    state_dtype: str = "float32"  # 'bfloat16' halves m/v bytes (400B-scale)


def _trainable(path) -> bool:
    """int4/packed leaves are frozen (inference-only quantized params)."""
    leaf = getattr(path[-1], "key", "")
    return leaf not in ("idx", "u8")


def adamw_init(params, cfg: AdamWConfig | None = None) -> dict:
    dt = jnp.dtype(cfg.state_dtype) if cfg is not None else jnp.float32
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=dt), params)
    return {"m": zeros,
            "v": jax.tree.map(jnp.zeros_like, zeros),
            "count": jnp.zeros((), jnp.int32)}


_SCAN_ABOVE = 2**24  # slice-process leaves above 16M elements


def _scannable(x) -> bool:
    return x.ndim >= 2 and x.size > _SCAN_ABOVE and x.shape[0] > 1


def _sqsum(x):
    # tree-reduction sum (accurate); f32 upcast inside — callers bound the
    # temp footprint by passing slices of stacked leaves.
    return jnp.sum(jnp.square(x.astype(jnp.float32)))


def global_norm(tree) -> jnp.ndarray:
    # stacked leaves reduce slice-by-slice (bounds f32 temporaries to one
    # layer-group slice; a whole-leaf pass keeps full f32 copies live)
    leaves = [jnp.sum(jax.lax.map(_sqsum, x)) if _scannable(x) else _sqsum(x)
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(grads, state, params, cfg: AdamWConfig):
    """Returns (new_params, new_state, metrics)."""
    count = state["count"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9)) \
        if cfg.grad_clip else 1.0
    lr = cfg.lr(count)

    sdt = jnp.dtype(cfg.state_dtype)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
        mhat = m / (1 - cfg.b1**count.astype(jnp.float32))
        vhat = v / (1 - cfg.b2**count.astype(jnp.float32))
        step = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay:
            step = step + cfg.weight_decay * p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - lr * step).astype(p.dtype),
                m.astype(sdt), v.astype(sdt))

    flat_g = jax.tree.leaves(grads)
    new_p, new_m, new_v = [], [], []
    treedef = jax.tree.structure(params)
    for g, m, v, p in zip(flat_g, jax.tree.leaves(state["m"]),
                          jax.tree.leaves(state["v"]),
                          jax.tree.leaves(params)):
        if not jnp.issubdtype(p.dtype, jnp.floating):
            np_, nm, nv = p, m, v  # frozen integer (quantized) leaves
        elif _scannable(p):
            # slice-wise update over the layer-stack dim: bounds the f32
            # update temporaries to one group slice (llama4 expert leaves
            # are GB-scale per device; whole-leaf updates keep several
            # f32 copies live at once)
            np_, nm, nv = jax.lax.map(lambda gmvp: upd(*gmvp), (g, m, v, p))
        else:
            np_, nm, nv = upd(g, m, v, p)
        new_p.append(np_)
        new_m.append(nm)
        new_v.append(nv)
    unflat = lambda leaves: jax.tree.unflatten(treedef, leaves)
    return unflat(new_p), {"m": unflat(new_m), "v": unflat(new_v),
                           "count": count}, \
        {"grad_norm": gnorm, "lr": lr}
