from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update  # noqa: F401
from repro.optim import schedules, compression  # noqa: F401
