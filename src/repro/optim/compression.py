"""int8 gradient compression with error feedback — the cross-pod (DCN)
all-reduce optimization of DESIGN.md §4.

Cross-pod gradient reduction moves bytes over the slow DCN ('pod') axis;
quantizing to int8 (+ one f32 scale shared via a scalar pmax) cuts DCN
bytes 4x vs f32.  Summation happens in int32 — exact given the shared
scale — so the only loss is the quantization itself, which error feedback
folds into the next step.

``compressed_psum`` is used inside the train step's partial-auto
shard_map over 'pod' (runtime/train.py): the data/model axes stay under
GSPMD while the pod axis collective is explicit and compressed.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x: jnp.ndarray):
    amax = jnp.max(jnp.abs(x))
    scale = jnp.where(amax > 0, amax / 127.0, 1.0).astype(jnp.float32)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compressed_psum(x: jnp.ndarray, axis_name: str) -> jnp.ndarray:
    """psum over `axis_name` with an int8 wire format (callable inside
    shard_map/pmap where `axis_name` is manual)."""
    xf = x.astype(jnp.float32)
    scale = jax.lax.pmax(jnp.max(jnp.abs(xf)), axis_name) / 127.0
    scale = jnp.where(scale > 0, scale, 1.0)
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    s = jax.lax.psum(q.astype(jnp.int32), axis_name)
    return s.astype(jnp.float32) * scale


def compressed_pmean_tree(grads, axis_name: str, residual=None):
    """Error-feedback compressed mean of a gradient pytree over `axis_name`.

    Returns (mean_grads, new_residual).  Must run where `axis_name` is a
    manual (shard_map) axis.
    """
    n = jax.lax.psum(1, axis_name)
    if residual is None:
        residual = jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32),
                                grads)

    def one(g, r):
        gf = g.astype(jnp.float32) + r
        scale = jax.lax.pmax(jnp.max(jnp.abs(gf)), axis_name) / 127.0
        scale = jnp.where(scale > 0, scale, 1.0)
        q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
        red = (jax.lax.psum(q.astype(jnp.int32), axis_name)
               .astype(jnp.float32) * scale / n)
        # error feedback: carry THIS shard's quantization error only
        return red.astype(g.dtype), gf - q.astype(jnp.float32) * scale

    pairs = jax.tree.map(lambda g, r: one(g, r), grads, residual)
    mean = jax.tree.map(lambda p: p[0], pairs,
                        is_leaf=lambda p: isinstance(p, tuple))
    res = jax.tree.map(lambda p: p[1], pairs,
                       is_leaf=lambda p: isinstance(p, tuple))
    return mean, res
