"""Activation-statistics collection for calibration.

A :class:`StatsCollector` installs itself as the ``core.linear`` observer
(core.linear.set_observer) and records, for every *tagged* linear apply,
the input second moments over a small calibration stream:

* diag  — per-input-channel ``E[x_j^2]`` (k,), the activation-aware error
          weights for codebook fitting (AWQ-style importance);
* full  — additionally the full second-moment matrix ``E[x x^T]`` (k, k),
          the Hessian proxy GPTQ-lite's sequential error feedback needs.

Recording happens through ``jax.debug.callback`` so it works identically
whether the forward pass runs eagerly, under jit, inside the
scan-over-layers (stats aggregate across the scanned groups — scanned
layers of the same kind share one tag), or under the MoE expert vmap
(batched callbacks fold their leading dims into the sample count).

Stats are keyed by ``(tag, k)``: the tag is the linear's param-key name
("wq", "up", "moe_down", ...) and k its input width, which disambiguates
same-named linears of different width (dense vs expert FFNs).
"""

from __future__ import annotations

import contextlib
from dataclasses import dataclass

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import linear as qlinear
from repro.models import transformer


@dataclass
class TagStats:
    """Accumulated input moments for one (tag, k)."""

    k: int
    count: int = 0
    sumsq: np.ndarray | None = None   # (k,) sum of x_j^2
    outer: np.ndarray | None = None   # (k, k) sum of x x^T (mode='full')

    @property
    def second_moment(self) -> np.ndarray:
        """diag E[x^2] (k,) — ones if nothing was recorded."""
        if self.count == 0 or self.sumsq is None:
            return np.ones((self.k,), np.float64)
        return self.sumsq / self.count

    @property
    def hessian(self) -> np.ndarray | None:
        """E[x x^T] (k, k) or None when collected in diag mode."""
        if self.outer is None or self.count == 0:
            return None
        return self.outer / self.count


class StatsCollector:
    """Observer object for core.linear.set_observer."""

    def __init__(self, mode: str = "diag"):
        if mode not in ("diag", "full"):
            raise ValueError(f"stats mode {mode!r}; one of ('diag', 'full')")
        self.mode = mode
        self.stats: dict[tuple[str, int], TagStats] = {}

    # ---- traced side (called from core.linear.apply) -------------------
    def record(self, tag: str, x: jnp.ndarray) -> None:
        import functools

        k = x.shape[-1]
        xf = x.astype(jnp.float32).reshape(-1, k)
        n = xf.shape[0]
        ss = jnp.sum(xf * xf, axis=0)  # (k,)
        # tag/k/n are static trace-time values: close over them (callback
        # operands are converted to arrays, which must stay out of dict keys)
        if self.mode == "full":
            outer = xf.T @ xf  # (k, k)
            jax.debug.callback(functools.partial(
                self._accumulate_full, tag=tag, k=k, n=n), ss, outer)
        else:
            jax.debug.callback(functools.partial(
                self._accumulate, tag=tag, k=k, n=n), ss)

    # ---- host side -----------------------------------------------------
    def _entry(self, tag: str, k: int) -> TagStats:
        key = (tag, k)
        if key not in self.stats:
            self.stats[key] = TagStats(k=k)
        return self.stats[key]

    def _accumulate(self, ss, *, tag: str, k: int, n: int) -> None:
        # Under vmap the callback receives batched sums: fold the extra
        # leading dims into the sample count (n rows per batch element).
        arr = np.asarray(ss, np.float64).reshape(-1, k)
        e = self._entry(tag, k)
        e.sumsq = arr.sum(0) if e.sumsq is None else e.sumsq + arr.sum(0)
        e.count += n * arr.shape[0]

    def _accumulate_full(self, ss, outer, *, tag: str, k: int, n: int) -> None:
        self._accumulate(ss, tag=tag, k=k, n=n)
        o = np.asarray(outer, np.float64).reshape(-1, k, k).sum(0)
        e = self._entry(tag, k)
        e.outer = o if e.outer is None else e.outer + o

    # ---- lookup --------------------------------------------------------
    def get(self, tag: str, k: int) -> TagStats:
        return self.stats.get((tag, k), TagStats(k=k))

    def second_moment(self, tag: str, k: int) -> np.ndarray:
        return self.get(tag, k).second_moment


def batches_from(data, steps: int) -> list:
    """Normalize a calibration/eval data source to a list of batch dicts:
    a data.SyntheticStream-like object (has host_batch), a single batch
    dict, or any iterable of batch dicts."""
    if hasattr(data, "host_batch"):
        return [{k: jnp.asarray(v) for k, v in data.host_batch(s).items()}
                for s in range(steps)]
    if isinstance(data, dict):
        return [data]
    return list(data)


@contextlib.contextmanager
def observing(collector: StatsCollector):
    """Install ``collector`` as the linear observer for the with-block."""
    qlinear.set_observer(collector)
    try:
        yield collector
    finally:
        qlinear.set_observer(None)


def collect(params, cfg, batches, *, mode: str = "diag") -> StatsCollector:
    """Run calibration batches through the (bf16) model and collect
    per-linear input moments.

    ``batches``: an iterable of model batch dicts (``{"tokens": ...}``),
    e.g. a few steps of data.SyntheticStream.  The forward runs in 'eval'
    mode (no remat) purely for its side effect on the collector.
    """
    collector = StatsCollector(mode=mode)
    with observing(collector):
        for batch in batches:
            logits, _ = transformer.forward(params, cfg, batch, mode="eval")
            jax.block_until_ready(logits)  # flush pending debug callbacks
    return collector
