"""Quality-eval harness: compare quantization recipes on the same footing.

Metrics over a shared toy-corpus stream (data.SyntheticStream or explicit
batches), always against the bf16 reference model:

* perplexity   exp(masked token cross-entropy) on the stream's labels;
* logit_mse    mean squared error of full-sequence logits vs reference;
* top1_agree   fraction of positions whose argmax token matches reference.

``compare`` evaluates a dict of named (params, cfg) variants so recipes
(uniform int4, learned codebooks, GPTQ, ...) are directly comparable —
benchmarks/quality_vs_bits.py records its output in BENCH_quality.json.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.calib.stats import batches_from as _batches_from
from repro.models import transformer
from repro.runtime.train import cross_entropy


def _forward(params, cfg, batch):
    logits, _ = transformer.forward(params, cfg, batch, mode="eval")
    return logits


def perplexity(params, cfg, data, *, steps: int = 2) -> float:
    """exp(mean masked CE) over the stream (batches need 'labels')."""
    ces = []
    for batch in _batches_from(data, steps):
        logits = _forward(params, cfg, batch)
        ce, _ = cross_entropy(logits, batch["labels"])
        ces.append(float(ce))
    return float(np.exp(np.mean(ces)))


def evaluate(params_ref, cfg_ref, params_q, cfg_q, data, *,
             steps: int = 2) -> dict:
    """One variant vs the bf16 reference.  Returns the metric dict."""
    batches = _batches_from(data, steps)
    ces, mses, agree = [], [], []
    for batch in batches:
        ref = _forward(params_ref, cfg_ref, batch)
        got = _forward(params_q, cfg_q, batch)
        ce, _ = cross_entropy(got, batch["labels"])
        ces.append(float(ce))
        mses.append(float(jnp.mean((got - ref) ** 2)))
        agree.append(float(jnp.mean(
            (jnp.argmax(got, -1) == jnp.argmax(ref, -1)).astype(jnp.float32))))
    return {
        "perplexity": float(np.exp(np.mean(ces))),
        "logit_mse": float(np.mean(mses)),
        "top1_agree": float(np.mean(agree)),
    }


def compare(params_ref, cfg_ref, variants: dict, data, *,
            steps: int = 2) -> dict:
    """variants: name -> (params, cfg).  Returns name -> metric dict,
    including the reference itself under 'bf16'."""
    out = {"bf16": evaluate(params_ref, cfg_ref, params_ref, cfg_ref, data,
                            steps=steps)}
    for name, (p, c) in variants.items():
        out[name] = evaluate(params_ref, cfg_ref, p, c, data, steps=steps)
    return out
