"""Quality-eval harness: compare quantization recipes on the same footing.

Metrics over a shared toy-corpus stream (data.SyntheticStream or explicit
batches), always against the bf16 reference model:

* perplexity   exp(masked token cross-entropy) on the stream's labels;
* logit_mse    mean squared error of full-sequence logits vs reference;
* top1_agree   fraction of positions whose argmax token matches reference.

``compare`` evaluates a dict of named (params, cfg) variants so recipes
(uniform int4, learned codebooks, GPTQ, ...) are directly comparable —
benchmarks/quality_vs_bits.py records its output in BENCH_quality.json.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.calib.stats import batches_from as _batches_from
from repro.models import transformer
from repro.runtime.train import cross_entropy


def _forward(params, cfg, batch):
    logits, _ = transformer.forward(params, cfg, batch, mode="eval")
    return logits


def perplexity(params, cfg, data, *, steps: int = 2) -> float:
    """exp(mean masked CE) over the stream (batches need 'labels')."""
    ces = []
    for batch in _batches_from(data, steps):
        logits = _forward(params, cfg, batch)
        ce, _ = cross_entropy(logits, batch["labels"])
        ces.append(float(ce))
    return float(np.exp(np.mean(ces)))


def evaluate(params_ref, cfg_ref, params_q, cfg_q, data, *,
             steps: int = 2) -> dict:
    """One variant vs the bf16 reference.  Returns the metric dict."""
    batches = _batches_from(data, steps)
    ces, mses, agree = [], [], []
    for batch in batches:
        ref = _forward(params_ref, cfg_ref, batch)
        got = _forward(params_q, cfg_q, batch)
        ce, _ = cross_entropy(got, batch["labels"])
        ces.append(float(ce))
        mses.append(float(jnp.mean((got - ref) ** 2)))
        agree.append(float(jnp.mean(
            (jnp.argmax(got, -1) == jnp.argmax(ref, -1)).astype(jnp.float32))))
    return {
        "perplexity": float(np.exp(np.mean(ces))),
        "logit_mse": float(np.mean(mses)),
        "top1_agree": float(np.mean(agree)),
    }


def compare(params_ref, cfg_ref, variants: dict, data, *,
            steps: int = 2) -> dict:
    """variants: name -> (params, cfg).  Returns name -> metric dict,
    including the reference itself under 'bf16'."""
    out = {"bf16": evaluate(params_ref, cfg_ref, params_ref, cfg_ref, data,
                            steps=steps)}
    for name, (p, c) in variants.items():
        out[name] = evaluate(params_ref, cfg_ref, p, c, data, steps=steps)
    return out


# ------------------------------------------------------ KV-cache quality
def _paged_arrays(B: int, S: int, block_size: int):
    """Contiguous per-row block tables + the (write, view) slot arrays for
    one full-sequence paged forward: row b owns blocks [1 + b*n, 1 +
    (b+1)*n) of a pool sized exactly for the batch."""
    from repro.serving import kv_blocks

    n = -(-S // block_size)
    ws, vs = [], []
    for b in range(B):
        blocks = [1 + b * n + i for i in range(n)]
        ws.append(kv_blocks.write_slots(blocks, 0, S, S, block_size))
        vs.append(kv_blocks.view_slots(blocks, n, block_size))
    return np.stack(ws), np.stack(vs), 1 + B * n


def _forward_paged(params, cfg, batch, *, block_size: int = 8):
    """Full-sequence logits through the *paged serving* path in a single
    (B, S) chunk.  Because each attention layer scatters the (quantized)
    K/V before it gathers the view, every position's logits already
    reflect quantized-KV attention — one teacher-forced call measures
    exactly what the serving engine computes."""
    tokens = np.asarray(batch["tokens"])
    B, S = tokens.shape
    ws, vs, num_blocks = _paged_arrays(B, S, block_size)
    pool = transformer.init_paged_cache(cfg, num_blocks, block_size)
    positions = np.broadcast_to(np.arange(S, dtype=np.int32), (B, S))
    logits, _ = transformer.forward_paged(params, cfg, tokens, pool,
                                          positions, ws, vs)
    return logits


def evaluate_kv(params, cfg, kv_spec, data, *, steps: int = 2,
                block_size: int = 8) -> dict:
    """One KV-storage variant vs the bf16-KV dense forward, same weights.

    ``kv_spec`` None re-runs the paged path with full-precision pools —
    its metrics certify the harness (logit_mse 0, top1_agree 1 up to
    float noise) so nonzero deltas are attributable to KV storage alone.
    """
    cfg_q = cfg.replace(kv_quant=kv_spec)
    batches = _batches_from(data, steps)
    ces, mses, agree = [], [], []
    for batch in batches:
        ref = _forward(params, cfg, batch)
        got = _forward_paged(params, cfg_q, batch, block_size=block_size)
        ce, _ = cross_entropy(got, batch["labels"])
        ces.append(float(ce))
        mses.append(float(jnp.mean((got - ref) ** 2)))
        agree.append(float(jnp.mean(
            (jnp.argmax(got, -1) == jnp.argmax(ref, -1)).astype(jnp.float32))))
    return {
        "perplexity": float(np.exp(np.mean(ces))),
        "logit_mse": float(np.mean(mses)),
        "top1_agree": float(np.mean(agree)),
    }


def compare_kv(params, cfg, kv_variants: dict, data, *, steps: int = 2,
               block_size: int = 8) -> dict:
    """kv_variants: name -> KVQuantSpec | None.  Returns name -> metric
    dict, plus the dense-cache reference under 'bf16_kv'."""
    out = {"bf16_kv": evaluate_kv(params, cfg, None, data, steps=steps,
                                  block_size=block_size)}
    for name, spec in kv_variants.items():
        out[name] = evaluate_kv(params, cfg, spec, data, steps=steps,
                                block_size=block_size)
    return out
