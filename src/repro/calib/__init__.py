"""repro.calib — activation-aware non-uniform LUT quantization.

msGeMM's LUT machinery supports arbitrary 16-entry value codebooks at
zero kernel cost (the produce basis is an operand, paper §3.2 / Eq. 5);
this package learns those codebooks from a trained model plus a small
calibration stream:

    codebook    the Codebook abstraction (uniform int4 = degenerate case)
    stats       per-linear input second-moment collection (observer hook)
    fit         weighted k-means / scale search / GPTQ-lite + calibrate()
    quality     perplexity & logit-MSE harness vs the bf16 reference

Typical flow (examples/quantize_calibrate.py)::

    result = calib.calibrate(params, cfg, stream, calib.Recipe())
    qcfg   = cfg.replace(quant=result.quant)
    # result.params serves through runtime.serve / serving.Engine
"""

from repro.calib.codebook import Codebook, uniform_values  # noqa: F401
from repro.calib.fit import (  # noqa: F401
    CalibResult, Recipe, calibrate, fit_codebook, fit_block_scales,
    gptq_codes, quantize_slice,
)
from repro.calib.stats import StatsCollector, collect, observing  # noqa: F401
from repro.calib import quality  # noqa: F401
