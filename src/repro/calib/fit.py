"""Codebook + scale fitting and the ``calibrate`` entry point.

Post-training quantization onto learned 16-entry codebooks (calib/
codebook.py).  The fitting objective is the activation-aware weighted
reconstruction error

    E_x || (W - Q) x ||^2  ≈  sum_ij  E[x_j^2] (W_ij - Q_ij)^2

with per-channel input second moments from calib/stats.py.  Pieces:

* :func:`fit_codebook`     weighted Lloyd k-means over scale-normalized
                           weight values, centroid 0 pinned at 0 (the
                           padding code), initialized at the uniform int4
                           grid — so the learned table never does worse
                           than uniform under the same scales;
* :func:`fit_block_scales` optional per-block bounding-box shrink search
                           (round-to-nearest overload clipping trade-off);
* :func:`gptq_codes`       GPTQ-lite sequential rounding with error
                           feedback through the input second-moment
                           matrix (needs stats mode='full');
* :func:`calibrate`        the one-call workflow: collect stats -> fit
                           per-layer (or per-model) codebooks -> emit a
                           servable quantized param tree + error report.

Fitting is host-side numpy — calibration is an offline, once-per-model
step; only the resulting codebooks/codes ride the hot path.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import numpy as np
import jax.numpy as jnp

from repro.core import linear as qlinear
from repro.core import packing, scales
from repro.calib import stats as calib_stats
from repro.calib.codebook import Codebook, uniform_values
from repro.quant.quantize import QUANTIZABLE

INT4_MAX = packing.INT4_MAX
NLEVELS = packing.NLEVELS


# ---------------------------------------------------------------- recipes
@dataclass(frozen=True)
class Recipe:
    """Knobs for one calibration run (see README §Calibration)."""

    scope: str = "layer"          # layer | model  (one codebook per ...)
    method: str = "kmeans"        # kmeans | uniform (uniform = int4 grid)
    rounding: str = "nearest"     # nearest | gptq (gptq needs stats 'full')
    activation_weighting: bool = True
    kmeans_iters: int = 25
    stats_mode: str = ""          # '' -> 'full' when rounding='gptq' else 'diag'
    calib_steps: int = 4          # calibration batches drawn from the stream
    scale_search: int = 0         # >0: per-block shrink candidates to search
    scale_search_lo: float = 0.75
    sample_limit: int = 1 << 20   # max weight samples per k-means fit
    gptq_damping: float = 1e-2    # fraction of mean(diag H) added to H

    def __post_init__(self):
        if self.scope not in ("layer", "model"):
            raise ValueError(f"scope {self.scope!r}")
        if self.method not in ("kmeans", "uniform"):
            raise ValueError(f"method {self.method!r}")
        if self.rounding not in ("nearest", "gptq"):
            raise ValueError(f"rounding {self.rounding!r}")
        if self.stats_mode == "":
            object.__setattr__(
                self, "stats_mode",
                "full" if self.rounding == "gptq" else "diag")
        if self.stats_mode not in ("diag", "full"):
            raise ValueError(f"stats_mode {self.stats_mode!r}")
        if self.rounding == "gptq" and self.stats_mode != "full":
            raise ValueError("rounding='gptq' needs stats_mode='full'")


@dataclass
class CalibResult:
    params: dict                  # servable quantized param tree
    quant: Any                    # the QuantSpec the tree was built for
    codebooks: dict               # path str -> (..., 16) value table
    report: dict                  # per-layer + aggregate weighted errors
    collector: Any                # the StatsCollector (for inspection)


# ---------------------------------------------------------------- fitting
def fit_codebook(z, weights=None, *, iters: int = 25,
                 init=None, sample_limit: int = 1 << 20,
                 seed: int = 0) -> np.ndarray:
    """Weighted Lloyd k-means over normalized weight values z (flat).

    Returns a (16,) value table in code order, entry 0 pinned at 0.
    Initialized at ``init`` (default: the uniform int4 grid), so with
    nearest assignment the fitted table's weighted MSE is <= uniform's
    (Lloyd never increases the objective).
    """
    z = np.asarray(z, np.float64).reshape(-1)
    w = (np.ones_like(z) if weights is None
         else np.asarray(weights, np.float64).reshape(-1))
    if z.size > sample_limit:
        rng = np.random.default_rng(seed)
        sel = rng.choice(z.size, size=sample_limit, replace=False)
        z, w = z[sel], w[sel]
    c = np.array(uniform_values() if init is None else init, np.float64)
    for _ in range(iters):
        assign = np.argmin(np.abs(z[:, None] - c[None, :]), axis=1)
        moved = False
        for j in range(1, NLEVELS):  # code 0 stays the padding zero
            m = assign == j
            wm = w[m]
            if wm.sum() <= 0:
                continue  # empty cluster keeps its value (monotone Lloyd)
            nc = float(np.sum(wm * z[m]) / wm.sum())
            moved = moved or abs(nc - c[j]) > 1e-12
            c[j] = nc
        if not moved:
            break
    return c.astype(np.float32)


def fit_block_scales(w, values, block: int, col_weights=None, *,
                     candidates: int = 0, lo: float = 0.75):
    """Per-row-block scales for quantizing ``w`` onto ``values``.

    Base scale is the bounding box ``amax / 7`` (identical to uniform
    int4).  With ``candidates > 0``, additionally searches that many
    shrink factors in [lo, 1] per block and keeps the weighted-error
    argmin — trading clipping for finer resolution near zero.

    Returns (scales (m, nb), padded w blocks (m, nb, block), col-weight
    blocks or None).
    """
    w = np.asarray(w, np.float64)
    m, k = w.shape
    nb = -(-k // block)
    wp = np.pad(w, ((0, 0), (0, nb * block - k)))
    wb = wp.reshape(m, nb, block)
    cw_b = None
    if col_weights is not None:
        cw = np.pad(np.asarray(col_weights, np.float64),
                    (0, nb * block - k))
        cw_b = cw.reshape(1, nb, block)
    amax = np.abs(wb).max(axis=-1)
    base = np.where(amax == 0, 1.0, amax / INT4_MAX)
    if candidates <= 0:
        return base, wb, cw_b
    vals = np.asarray(values, np.float64)
    best_err = np.full((m, nb), np.inf)
    best_s = base.copy()
    # the base (unshrunk) scale is always a candidate — otherwise
    # candidates=1 degenerates to np.linspace(lo, 1, 1) == [lo] and the
    # search shrinks unconditionally even when that increases error
    for f in np.unique(np.append(np.linspace(lo, 1.0, candidates), 1.0)):
        s = base * f
        z = wb / s[..., None]
        deq = vals[np.argmin(np.abs(z[..., None] - vals), axis=-1)]
        e2 = (wb - deq * s[..., None]) ** 2
        err = (e2 * cw_b).sum(-1) if cw_b is not None else e2.sum(-1)
        better = err < best_err
        best_err = np.where(better, err, best_err)
        best_s = np.where(better, s, best_s)
    return best_s, wb, cw_b


def gptq_codes(w, H, values, scale, block: int, *,
               damping: float = 1e-2) -> np.ndarray:
    """GPTQ-lite: sequential nearest-codebook rounding with error feedback.

    Columns are quantized in index order; each column's rounding error is
    compensated in the not-yet-quantized columns through the upper
    Cholesky factor U of the inverse input second moment (H = E[x x^T],
    H^-1 = U^T U) — the GPTQ recurrence, without activation reordering or
    lazy blocking.  Minimizes E||(W - Q) x||^2 given the codebook+scales.

    w (m, k); H (k, k); scale (m, ceil(k/block)).  Returns codes (m, k).
    """
    w = np.array(w, np.float64)  # mutated
    m, k = w.shape
    H = np.array(H, np.float64)
    H = H + damping * max(np.mean(np.diag(H)), 1e-12) * np.eye(k)
    U = np.linalg.cholesky(np.linalg.inv(H)).T  # upper, H^-1 = U^T U
    vals = np.asarray(values, np.float64)
    codes = np.zeros((m, k), np.uint8)
    for j in range(k):
        s = scale[:, j // block]
        z = w[:, j] / s
        cj = np.argmin(np.abs(z[:, None] - vals[None, :]), axis=1)
        codes[:, j] = cj
        err = (w[:, j] - vals[cj] * s) / U[j, j]
        if j + 1 < k:
            w[:, j + 1:] -= np.outer(err, U[j, j + 1:])
    return codes


def quantize_slice(w, quant, values, *, col_weights=None, H=None,
                   recipe: Recipe = None) -> scales.QuantizedTensor:
    """Quantize one dense (out, in) slice onto ``values`` under ``quant``,
    honoring the recipe's scale search and rounding mode."""
    recipe = recipe or Recipe()
    w = np.asarray(w, np.float64)
    m, k = w.shape
    block = quant.scale_block
    s, wb, _ = fit_block_scales(
        w, values, block, col_weights,
        candidates=recipe.scale_search, lo=recipe.scale_search_lo)
    if recipe.rounding == "gptq" and H is not None:
        codes = gptq_codes(w, H, values, s, block,
                           damping=recipe.gptq_damping)
    else:
        vals = np.asarray(values, np.float64)
        z = wb / s[..., None]
        codes = np.argmin(np.abs(z[..., None] - vals), axis=-1)
        codes = codes.reshape(m, -1)[:, :k].astype(np.uint8)
    return scales.QuantizedTensor(
        codes=jnp.asarray(codes, jnp.uint8),
        scales=jnp.asarray(s, jnp.float32), block=block, shape=(m, k),
        codebook=jnp.asarray(values, jnp.float32))


def _sample_weights(s, wb_shape, cw_b) -> np.ndarray:
    """Per-sample k-means weights in the *unnormalized* error domain:
    cw_j * (w - s*c)^2 == (cw_j * s^2) * (z - c)^2, so weighting the
    normalized samples by cw_j * s_block^2 makes the Lloyd objective equal
    the reported weighted_quantization_error (and its monotone-improvement
    guarantee transfer to it)."""
    wt = np.broadcast_to(np.asarray(s)[..., None] ** 2, wb_shape)
    if cw_b is not None:
        wt = wt * np.broadcast_to(cw_b, wb_shape)
    return wt.reshape(-1)


# ---------------------------------------------------------------- walking
def _quantizable_leaves(params, path=()):
    """Yield (path, name, leaf_dict) for every QuantizedLinear leaf."""
    for name, v in params.items():
        if name in QUANTIZABLE and isinstance(v, dict) and "w" in v:
            yield path + (name,), name, v
        elif isinstance(v, dict):
            yield from _quantizable_leaves(v, path + (name,))


def _tag_for(path: tuple, name: str) -> str:
    return ("moe_" + name) if "experts" in path else name


def _stack_leaf(slices: list, stack_shape: tuple) -> dict:
    """Re-stack per-slice param dicts into leading stack dims."""
    keys = slices[0].keys()
    out = {}
    for kk in keys:
        arr = jnp.stack([s[kk] for s in slices], axis=0)
        out[kk] = arr.reshape(*stack_shape, *arr.shape[1:])
    return out


# ---------------------------------------------------------------- calibrate
def calibrate(params, cfg, data, recipe: Recipe = Recipe(), *,
              quant=None) -> CalibResult:
    """Activation-aware post-training quantization, end to end.

    params/cfg: a *dense* (bf16/f32) model; data: a SyntheticStream (or a
    list of batch dicts) to draw ``recipe.calib_steps`` calibration
    batches from; quant: the target QuantSpec (defaults to msgemm with
    learned codebooks; ``codebook='learned'`` is forced so the emitted
    tree carries its tables).

    Returns a :class:`CalibResult` whose ``params`` serve through every
    existing path (static generate, paged continuous batching) under
    ``cfg.replace(quant=result.quant)``.
    """
    import dataclasses

    if quant is None:
        quant = (cfg.quant if cfg.quant.mode != "bf16"
                 else qlinear.QuantSpec(mode="msgemm"))
    if quant.codebook != "learned":
        quant = dataclasses.replace(quant, codebook="learned")

    batches = calib_stats.batches_from(data, recipe.calib_steps)
    collector = calib_stats.collect(params, cfg, batches,
                                    mode=recipe.stats_mode)

    leaves = list(_quantizable_leaves(params))

    # scope='model': one codebook fitted over samples pooled from every
    # linear (normalized domain, activation-weighted), then shared.
    model_values = None
    if recipe.scope == "model" and recipe.method == "kmeans":
        zs, ws = [], []
        per_leaf = max(recipe.sample_limit // max(len(leaves), 1), 4096)
        for path, name, v in leaves:
            w = np.asarray(v["w"], np.float64)
            w2 = w.reshape(-1, w.shape[-1])
            s, wb, cw_b = fit_block_scales(
                w2, uniform_values(), quant.scale_block,
                collector.second_moment(_tag_for(path, name), w.shape[-1])
                if recipe.activation_weighting else None)
            z = (wb / s[..., None]).reshape(-1)
            wt = _sample_weights(s, wb.shape, cw_b)
            if z.size > per_leaf:
                rng = np.random.default_rng(len(zs))
                sel = rng.choice(z.size, size=per_leaf, replace=False)
                z, wt = z[sel], wt[sel]
            zs.append(z)
            ws.append(wt)
        model_values = fit_codebook(
            np.concatenate(zs), np.concatenate(ws),
            iters=recipe.kmeans_iters, sample_limit=recipe.sample_limit)
        Codebook(values=model_values).check()

    codebooks: dict[str, np.ndarray] = {}
    report: dict[str, dict] = {}
    sum_uni, sum_learned, n_leaves = 0.0, 0.0, 0

    def convert_leaf(path, name, v):
        nonlocal sum_uni, sum_learned, n_leaves
        w = np.asarray(v["w"], np.float64)
        k = w.shape[-1]
        tag = _tag_for(path, name)
        colw = (collector.second_moment(tag, k)
                if recipe.activation_weighting else None)
        H = (collector.get(tag, k).hessian
             if recipe.rounding == "gptq" else None)
        stack_shape = w.shape[:-2]
        slices, values_out = [], []
        leaf_uni, leaf_new = 0.0, 0.0
        for ix in (np.ndindex(*stack_shape) if stack_shape else [()]):
            w2 = w[ix]
            if recipe.method == "uniform" or (
                    recipe.scope == "model" and model_values is None):
                values = uniform_values()
            elif recipe.scope == "model":
                values = model_values
            else:
                s, wb, cw_b = fit_block_scales(w2, uniform_values(),
                                               quant.scale_block, colw)
                z = (wb / s[..., None]).reshape(-1)
                values = fit_codebook(z, _sample_weights(s, wb.shape, cw_b),
                                      iters=recipe.kmeans_iters,
                                      sample_limit=recipe.sample_limit)
                Codebook(values=values).check()
            qt = quantize_slice(w2, quant, values, col_weights=colw, H=H,
                                recipe=recipe)
            qt_uni = scales.quantize_int4(jnp.asarray(w2, jnp.float32),
                                          quant.scale_block)
            e_uni = float(scales.weighted_quantization_error(
                jnp.asarray(w2, jnp.float32), qt_uni, colw))
            e_new = float(scales.weighted_quantization_error(
                jnp.asarray(w2, jnp.float32), qt, colw))
            sum_uni += e_uni
            sum_learned += e_new
            leaf_uni += e_uni
            leaf_new += e_new
            n_leaves += 1
            slices.append(qlinear.from_quantized(qt, quant))
            values_out.append(values)
        pstr = "/".join(path)
        nslices = len(slices)
        if stack_shape:
            leaf = _stack_leaf(slices, stack_shape)
            codebooks[pstr] = np.stack(values_out).reshape(*stack_shape,
                                                           NLEVELS)
        else:
            leaf = slices[0]
            codebooks[pstr] = values_out[0]
        report[pstr] = {
            "uniform_weighted_err": leaf_uni / nslices,
            "learned_weighted_err": leaf_new / nslices,
        }
        return leaf

    def walk(tree, path=()):
        out = {}
        for name, v in tree.items():
            if name in QUANTIZABLE and isinstance(v, dict) and "w" in v:
                out[name] = convert_leaf(path + (name,), name, v)
            elif isinstance(v, dict):
                out[name] = walk(v, path + (name,))
            else:
                out[name] = v
        return out

    new_params = walk(params)
    report["aggregate"] = {
        "num_linears": n_leaves,
        "uniform_weighted_err": sum_uni / max(n_leaves, 1),
        "learned_weighted_err": sum_learned / max(n_leaves, 1),
    }
    return CalibResult(params=new_params, quant=quant, codebooks=codebooks,
                       report=report, collector=collector)
