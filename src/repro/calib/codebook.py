"""16-entry codebook abstraction for non-uniform LUT quantization.

msGeMM's consume phase only ever *adds table entries* — Eq. 5 never
requires the 16 coefficient levels to be the uniform int4 grid, so the
LUT machinery natively supports arbitrary learned codebooks at zero extra
kernel cost (the produce basis ``C_d`` is already a kernel operand).

Conventions shared by core.scales / core.lut / kernels:

* a codebook is a (16,) float32 value table indexed by the 4-bit code;
* ``values[0] == 0.0`` — code 0 is the k-padding code (core.packing pads
  with it and relies on a zero contribution), and the kernels pad idx
  tiles with flat index 0 whose basis row is (C[0], ..., C[0]);
* scales stay bounding-box normalized (``amax / 7``, identical to
  uniform int4), so codebook entries live in the normalized domain
  [-7, 7] and uniform/learned variants are comparable on the same scale
  grid.

``UNIFORM_INT4`` (the two's-complement value order of paper §3.1) is the
degenerate case: quantizing with it reproduces core.scales.quantize_int4
bit-for-bit.
"""

from __future__ import annotations

from typing import NamedTuple

import numpy as np
import jax.numpy as jnp

from repro.core import lut as lut_mod
from repro.core import packing

NLEVELS = packing.NLEVELS


def uniform_values() -> np.ndarray:
    """The uniform int4 grid in code order: b(0)=0 ... b(15)=-1 (§3.1)."""
    return np.asarray(packing.b_values(jnp.float32))


class Codebook(NamedTuple):
    """A 16-entry value table (per-layer, or one shared per model).

    values: (16,) float32, values[0] == 0.  For scan-stacked / expert
    weights the stacked form is a plain (G, 16) array of per-slice
    ``values`` (see quant.quantize_model).
    """

    values: np.ndarray

    @classmethod
    def uniform_int4(cls) -> "Codebook":
        return cls(values=uniform_values())

    @classmethod
    def from_centroids(cls, centroids) -> "Codebook":
        """Build a valid codebook from up to 15 learned centroids: value 0
        is pinned at code 0, the rest fill codes 1..15 in sorted order."""
        c = np.asarray(centroids, np.float64).reshape(-1)
        c = c[np.abs(c) > 1e-12]  # 0 is always present via code 0
        if c.size > NLEVELS - 1:
            raise ValueError(f"at most {NLEVELS - 1} nonzero centroids, "
                             f"got {c.size}")
        vals = np.zeros((NLEVELS,), np.float32)
        vals[1:1 + c.size] = np.sort(c).astype(np.float32)
        return cls(values=vals)

    def check(self) -> "Codebook":
        """Validate the invariants the packed/padded paths rely on."""
        v = np.asarray(self.values)
        if v.shape != (NLEVELS,):
            raise ValueError(f"codebook must be ({NLEVELS},), got {v.shape}")
        if v[0] != 0.0:
            raise ValueError(
                "codebook[0] must be 0 — code 0 is the zero-padding code "
                "(core.packing.pad_k) and padded LUT rows must contribute 0")
        if not np.all(np.isfinite(v)):
            raise ValueError("codebook values must be finite")
        return self

    def encode(self, z: jnp.ndarray) -> jnp.ndarray:
        """Nearest-entry codes for normalized values z (...,)."""
        cb = jnp.asarray(self.values, jnp.float32)
        return jnp.argmin(
            jnp.abs(z[..., None].astype(jnp.float32) - cb), axis=-1
        ).astype(jnp.uint8)

    def decode(self, codes: jnp.ndarray) -> jnp.ndarray:
        """codes (...,) uint8 -> values (...,) float32."""
        return jnp.take(jnp.asarray(self.values, jnp.float32),
                        jnp.asarray(codes, jnp.int32), axis=0)

    def basis(self, d: int, dtype=jnp.float32) -> jnp.ndarray:
        """The produce-phase tuple basis C_d (16^d, d) over this codebook."""
        return lut_mod.tuple_basis(d, dtype=dtype, codebook=self.values)

    @property
    def is_uniform(self) -> bool:
        return bool(np.array_equal(np.asarray(self.values), uniform_values()))
