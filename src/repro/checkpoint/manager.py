"""Fault-tolerant checkpointing: atomic sharded save, keep-last-k GC,
auto-resume, elastic re-shard on restore.

Layout (one directory per step)::

    <dir>/step_000123.tmp/...      # written first
    <dir>/step_000123/             # atomic os.replace when complete
        manifest.json              # step, leaf index, mesh, config hash
        leaf_00000.npy ...         # one file per pytree leaf

Atomicity = write-to-tmp + rename, so a crash mid-save never corrupts the
latest checkpoint; `latest_step` only ever sees complete directories.
Restore is *elastic*: leaves are saved unsharded (gathered) and re-placed
with whatever shardings the new mesh prescribes, so restarting on a
different mesh shape (or chip count) re-shards transparently — the
checkpoint/restart and elastic-scaling tests exercise both.
"""

from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import numpy as np


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3, async_save: bool = False):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------- paths
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:09d}")

    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                if os.path.exists(os.path.join(self.dir, name, "manifest.json")):
                    out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    # ------------------------------------------------------------- save
    def save(self, step: int, tree, *, extra: dict | None = None) -> None:
        if self._thread is not None:
            self._thread.join()  # one in-flight async save at a time
            self._thread = None
        leaves, treedef = jax.tree.flatten(tree)
        host_leaves = [np.asarray(jax.device_get(x)) for x in leaves]

        def write():
            tmp = self._step_dir(step) + ".tmp"
            final = self._step_dir(step)
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            index = []
            for i, a in enumerate(host_leaves):
                name = f"leaf_{i:05d}.npy"
                stored = a
                if str(a.dtype) == "bfloat16":  # np.save can't serialize
                    stored = a.astype(np.float32)
                np.save(os.path.join(tmp, name), stored)
                index.append({"file": name, "shape": list(a.shape),
                              "dtype": str(a.dtype)})
            manifest = {"step": step, "leaves": index,
                        "treedef": str(treedef), "extra": extra or {}}
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.replace(tmp, final)  # atomic publish
            self._gc()

        if self.async_save:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()
        else:
            write()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: max(0, len(steps) - self.keep)]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # ------------------------------------------------------------- load
    def restore(self, step: int, target_tree, *, shardings=None):
        """Restore into the structure of ``target_tree`` (shapes/dtypes
        validated).  ``shardings``: optional matching pytree of
        jax.sharding.Sharding for elastic re-placement on the current mesh.
        """
        self.wait()
        d = self._step_dir(step)
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        leaves, treedef = jax.tree.flatten(target_tree)
        if len(leaves) != len(manifest["leaves"]):
            raise ValueError(
                f"checkpoint has {len(manifest['leaves'])} leaves, "
                f"target has {len(leaves)}")
        shard_leaves = (jax.tree.leaves(shardings) if shardings is not None
                        else [None] * len(leaves))
        out = []
        for meta, tgt, shd in zip(manifest["leaves"], leaves, shard_leaves):
            a = np.load(os.path.join(d, meta["file"]))
            if list(a.shape) != list(tgt.shape):
                raise ValueError(f"shape mismatch {a.shape} vs {tgt.shape}")
            a = a.astype(tgt.dtype)  # bf16 leaves round-trip via f32
            out.append(jax.device_put(a, shd) if shd is not None
                       else jax.numpy.asarray(a))
        return jax.tree.unflatten(treedef, out)

    def restore_latest(self, target_tree, *, shardings=None):
        step = self.latest_step()
        if step is None:
            return None, None
        return step, self.restore(step, target_tree, shardings=shardings)
