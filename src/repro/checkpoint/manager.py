"""Fault-tolerant checkpointing: atomic sharded save, keep-last-k GC,
auto-resume, elastic re-shard on restore.

Layout (one directory per step)::

    <dir>/step_000123.tmp/...      # written first
    <dir>/step_000123/             # atomic os.replace when complete
        manifest.json              # step, leaf index, mesh, config hash
        leaf_00000.npy ...         # one file per pytree leaf

Atomicity = write-to-tmp + rename, so a crash mid-save never corrupts the
latest checkpoint; `latest_step` only ever sees complete directories.
On top of that, manifests carry a CRC32 per leaf file: a bit-rotted or
truncated checkpoint fails verification on restore, the whole step
directory is quarantined aside (``step_N.quarantined``, counted by
``artifact_quarantined_total{artifact="checkpoint"}``), and
``restore_latest`` falls back to the newest step that verifies — a
corrupt latest checkpoint costs one step of progress, never the server.
Restore is *elastic*: leaves are saved unsharded (gathered) and re-placed
with whatever shardings the new mesh prescribes, so restarting on a
different mesh shape (or chip count) re-shards transparently — the
checkpoint/restart and elastic-scaling tests exercise both.
"""

from __future__ import annotations

import json
import os
import re
import shutil
import threading
import zlib

import jax
import numpy as np

_STEP_RE = re.compile(r"^step_(\d+)$")


def _file_crc(path: str) -> str:
    crc = 0
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            crc = zlib.crc32(chunk, crc)
    return f"{crc & 0xFFFFFFFF:08x}"


class CheckpointCorrupt(ValueError):
    """A checkpoint step failed manifest/CRC verification."""


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3, async_save: bool = False):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------- paths
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:09d}")

    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            # strict match skips .tmp dirs, quarantined corpses
            # (step_N.quarantined), and any stray files
            m = _STEP_RE.match(name)
            if m and os.path.exists(
                    os.path.join(self.dir, name, "manifest.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    # ------------------------------------------------------------- save
    def save(self, step: int, tree, *, extra: dict | None = None) -> None:
        if self._thread is not None:
            self._thread.join()  # one in-flight async save at a time
            self._thread = None
        leaves, treedef = jax.tree.flatten(tree)
        host_leaves = [np.asarray(jax.device_get(x)) for x in leaves]

        def write():
            tmp = self._step_dir(step) + ".tmp"
            final = self._step_dir(step)
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            index = []
            for i, a in enumerate(host_leaves):
                name = f"leaf_{i:05d}.npy"
                stored = a
                if str(a.dtype) == "bfloat16":  # np.save can't serialize
                    stored = a.astype(np.float32)
                np.save(os.path.join(tmp, name), stored)
                index.append({"file": name, "shape": list(a.shape),
                              "dtype": str(a.dtype),
                              "crc": _file_crc(os.path.join(tmp, name))})
            manifest = {"step": step, "leaves": index,
                        "treedef": str(treedef), "extra": extra or {}}
            from repro.obs import artifacts
            artifacts.stamp_crc(manifest)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.replace(tmp, final)  # atomic publish
            from repro import faults
            ev = faults.fire("corrupt_checkpoint")
            if ev is not None:
                faults.corrupt_file(
                    os.path.join(final, "manifest.json"), ev)
            self._gc()

        if self.async_save:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()
        else:
            write()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: max(0, len(steps) - self.keep)]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # ------------------------------------------------------------- load
    def restore(self, step: int, target_tree, *, shardings=None):
        """Restore into the structure of ``target_tree`` (shapes/dtypes
        validated).  ``shardings``: optional matching pytree of
        jax.sharding.Sharding for elastic re-placement on the current mesh.
        """
        self.wait()
        d = self._step_dir(step)
        manifest = self._verify(step)
        leaves, treedef = jax.tree.flatten(target_tree)
        if len(leaves) != len(manifest["leaves"]):
            raise ValueError(
                f"checkpoint has {len(manifest['leaves'])} leaves, "
                f"target has {len(leaves)}")
        shard_leaves = (jax.tree.leaves(shardings) if shardings is not None
                        else [None] * len(leaves))
        out = []
        for meta, tgt, shd in zip(manifest["leaves"], leaves, shard_leaves):
            a = np.load(os.path.join(d, meta["file"]))
            if list(a.shape) != list(tgt.shape):
                raise ValueError(f"shape mismatch {a.shape} vs {tgt.shape}")
            a = a.astype(tgt.dtype)  # bf16 leaves round-trip via f32
            out.append(jax.device_put(a, shd) if shd is not None
                       else jax.numpy.asarray(a))
        return jax.tree.unflatten(treedef, out)

    def _verify(self, step: int) -> dict:
        """Parse + CRC-verify a step's manifest and leaf files; returns
        the manifest or raises :class:`CheckpointCorrupt`."""
        from repro.obs import artifacts

        d = self._step_dir(step)
        try:
            with open(os.path.join(d, "manifest.json")) as f:
                manifest = json.load(f)
            if not isinstance(manifest, dict) or \
                    not isinstance(manifest.get("leaves"), list):
                raise ValueError("bad manifest schema")
        except (OSError, ValueError) as e:
            raise CheckpointCorrupt(
                f"step {step}: unreadable manifest ({e})") from None
        if not artifacts.check_crc(manifest):
            raise CheckpointCorrupt(f"step {step}: manifest CRC mismatch")
        for meta in manifest["leaves"]:
            want = meta.get("crc")
            if want is None:
                continue  # legacy checkpoint without leaf CRCs
            path = os.path.join(d, meta["file"])
            try:
                got = _file_crc(path)
            except OSError:
                raise CheckpointCorrupt(
                    f"step {step}: missing leaf {meta['file']}") from None
            if got != want:
                raise CheckpointCorrupt(
                    f"step {step}: leaf {meta['file']} CRC "
                    f"{got} != {want}")
        return manifest

    def quarantine(self, step: int, reason: str = "corrupt"):
        """Move a corrupt step directory aside and count it."""
        from repro.obs import artifacts

        return artifacts.quarantine(
            self._step_dir(step), "checkpoint", reason=reason)

    def restore_latest(self, target_tree, *, shardings=None):
        """Restore the newest step that passes verification.  Corrupt
        steps are quarantined aside and the next older one is tried —
        ``(None, None)`` only when no step verifies."""
        self.wait()
        for step in reversed(self.all_steps()):
            try:
                return step, self.restore(step, target_tree,
                                          shardings=shardings)
            except CheckpointCorrupt as e:
                self.quarantine(step)
                import logging
                logging.getLogger(__name__).warning(
                    "quarantined corrupt checkpoint: %s", e)
        return None, None
