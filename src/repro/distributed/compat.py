"""Version-bridging wrappers for the two jax sharding APIs whose
spelling moved between releases.

The repo supports both spellings because the container pins one jax and
real deployments run another:

* ``set_mesh`` — newer jax exposes ``jax.set_mesh(mesh)`` as a context
  manager; on older releases the ``Mesh`` object itself is the context
  manager.
* ``shard_map`` — newer jax promotes ``jax.shard_map(f, mesh=, in_specs=,
  out_specs=, axis_names=, check_vma=)``; older releases spell it
  ``jax.experimental.shard_map.shard_map(f, mesh, in_specs, out_specs,
  check_rep=, auto=)`` where ``auto`` is the *complement* of the manual
  axis set.

Everything in ``repro`` that needs either API goes through this module,
so the rest of the codebase is written once against the stable surface:
``compat.set_mesh(mesh)`` and ``compat.shard_map(f, mesh=..., ...,
manual_axes=..., check=...)``.
"""

from __future__ import annotations

import jax


def set_mesh(mesh):
    """Context manager activating ``mesh`` as the ambient device mesh."""
    fn = getattr(jax, "set_mesh", None)
    if fn is not None:
        return fn(mesh)
    # older jax: Mesh is itself a context manager
    return mesh


def axis_size(axis: str) -> int:
    """Static size of a named mesh axis from inside a shard_map body.

    Newer jax exposes ``jax.lax.axis_size``; on older releases the
    idiomatic spelling is ``psum(1, axis)``, which constant-folds to a
    Python int whenever the axis extent is statically known (always
    true under the fully-manual shard_maps this repo builds)."""
    fn = getattr(jax.lax, "axis_size", None)
    if fn is not None:
        return int(fn(axis))
    return int(jax.lax.psum(1, axis))


def shard_map(f=None, *, mesh, in_specs, out_specs, manual_axes=None,
              check: bool = False):
    """``shard_map`` across jax versions.

    ``manual_axes``: mesh axis names handled manually inside ``f`` (the
    rest stay under GSPMD — partial auto).  None means fully manual.
    ``check``: replication/VMA checking (off by default: the callers here
    all perform axis-reducing collectives the checker cannot follow).

    Usable directly or as a decorator factory (``f=None``).
    """
    if f is None:
        return lambda fn: shard_map(fn, mesh=mesh, in_specs=in_specs,
                                    out_specs=out_specs,
                                    manual_axes=manual_axes, check=check)
    new = getattr(jax, "shard_map", None)
    if new is not None:
        kwargs = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_vma=check)
        if manual_axes is not None:
            kwargs["axis_names"] = set(manual_axes)
        return new(f, **kwargs)
    from jax.experimental.shard_map import shard_map as _sm

    kwargs = dict(check_rep=check)
    if manual_axes is not None:
        kwargs["auto"] = frozenset(mesh.axis_names) - frozenset(manual_axes)
    return _sm(f, mesh, in_specs=in_specs, out_specs=out_specs, **kwargs)
