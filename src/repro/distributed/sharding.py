"""Logical-axis sharding (MaxText-style, raw JAX).

Tensors are annotated with *logical* axis names; a rule table maps logical
axes to mesh axes.  Spec construction is shape-aware and greedy:

* logical axes are resolved in PRIORITY order (e.g. 'expert' grabs the
  'model' mesh axis before 'mlp' does, 'kvheads' before 'kv_seq');
* a mesh axis is used at most once per spec;
* a candidate mesh axis is skipped when the dim size is not divisible by
  its size (the divisibility fallback chain of DESIGN.md §4 — e.g.
  qwen2-moe's 60 experts fall through to per-expert TP on mlp=1408).

Params and activations use different tables: params additionally shard
their 'embed'/'residual' dims over the data axis (ZeRO-3/FSDP), so the
llama4-400B train state fits 512 chips.
"""

from __future__ import annotations

import threading
from contextlib import contextmanager

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed import compat

# Resolution priority: earlier names grab contested mesh axes first.
PRIORITY = (
    "batch", "expert", "expert_out", "heads", "kvheads", "mlp", "vocab",
    "embed", "mamba_inner", "xl_inner", "kv_seq", "seq", "capacity",
    "stack", "layers", "head_dim", "conv", "state", "scales", "expert_in",
    "none",
)
assert PRIORITY.index("seq") > PRIORITY.index("heads")

# logical axis -> candidate mesh axes, tried in order.
ACT_RULES: dict[str, tuple[str, ...]] = {
    "batch": ("pod", "data"),  # folded: batch shards over pod x data
    # sequence-parallel fallback: when heads/kvheads cannot take the model
    # axis (llama4: 40 heads, gemma-2b: 8 heads on model=16), activations
    # shard over seq instead, bounding the attention-logits footprint.
    # PRIORITY puts 'seq' after heads/kvheads/mlp, so it only fires when
    # those fail divisibility.
    "seq": ("model",),
    "kv_seq": ("model",),  # decode caches: shard seq when heads cannot
    "heads": ("model",),
    "kvheads": ("model",),
    "head_dim": (),
    "embed": (),
    "mlp": ("model",),
    "vocab": ("model",),
    "expert": ("model",),
    # dispatch capacity dim = (examples x per-example slots): the major
    # factor is the batch, so 'data' sharding stays representable; without
    # it the dispatch buffers replicate when E can't take 'model'
    # (qwen2-moe: 5.4 GB/device -> 335 MB).
    "capacity": ("data",),
    # Expert FFN weights: out-dim takes the first free of model/data, the
    # in (contraction) dim stays replicated.  With E | model (llama4,
    # jamba) experts are then fully (expert x data)-sharded with NO FSDP
    # gather — tokens move to experts (EP all-to-all), not weights to
    # tokens (EXPERIMENTS.md §Perf A).  With E unshardable (qwen2-moe 60)
    # this degrades gracefully to per-expert TP on 'model'.
    "expert_out": ("model", "data"),
    "expert_in": (),
    "mamba_inner": ("model",),
    "xl_inner": ("model",),
    "state": (),
    "conv": (),
    "stack": (),
    "layers": (),
    "scales": (),
    "none": (),
}

# Param tables: 2D FSDP x TP — big output dims on 'model', the residual
# ('embed') dim additionally on 'data' (ZeRO-3).  Expert FFN weights get
# the mlp dim on 'data' when 'model' is already taken by the expert dim:
# they are then fully 256-way sharded *without* any FSDP gather — tokens
# move to experts (EP all-to-all) instead of weights moving to tokens,
# which collapses the 400B-train collective term (EXPERIMENTS.md §Perf A).
PARAM_RULES: dict[str, tuple[str, ...]] = dict(
    ACT_RULES,
    embed=("data",),
    batch=(),
    kv_seq=(),
)

# A rule-set bundle selectable per run (cfg.logical_rules).
RULE_SETS = {
    "default": (ACT_RULES, PARAM_RULES),
    # serving at batch=1 (long_500k): nothing to gain from data-parallel
    # activations; keep params TP-only so no all-gathers on the hot path.
    "serve_tp": (
        dict(ACT_RULES, batch=()),
        dict(PARAM_RULES, embed=()),
    ),
    # batched serving (the continuous engine): activations keep the full
    # default table (batch over data, kvheads over model), but params
    # drop the embed/data FSDP dim — weights are TP-resident, so the
    # shard_map-wrapped quantized linears (repro.dispatch.shard) see
    # their storage sharding exactly match their in_specs and the hot
    # path issues no per-layer FSDP gathers.
    "serve": (ACT_RULES, dict(PARAM_RULES, embed=())),
}


class _Ctx(threading.local):
    mesh: Mesh | None = None
    rules: str = "default"


_CTX = _Ctx()


@contextmanager
def use(mesh: Mesh, rules: str = "default"):
    """Activate a mesh + rule set for logical constraints."""
    prev = (_CTX.mesh, _CTX.rules)
    _CTX.mesh, _CTX.rules = mesh, rules
    try:
        with compat.set_mesh(mesh):
            yield
    finally:
        _CTX.mesh, _CTX.rules = prev


def active_mesh() -> Mesh | None:
    return _CTX.mesh


def active_rules() -> str:
    return _CTX.rules


def _resolve(axes: tuple, shape: tuple, mesh: Mesh, table: dict) -> P:
    """Greedy shape-aware logical->mesh resolution."""
    order = sorted(
        range(len(axes)),
        key=lambda i: PRIORITY.index(axes[i]) if axes[i] in PRIORITY else 99,
    )
    used: set[str] = set()
    out: list = [None] * len(axes)
    for i in order:
        name = axes[i]
        if name is None or name == "none":
            continue
        fold = name == "batch"  # only batch folds ('pod' x 'data')
        for cand in table.get(name, ()):
            if cand not in mesh.shape or cand in used:
                continue
            if shape[i] % mesh.shape[cand] == 0:
                out[i] = cand if out[i] is None else tuple(
                    (out[i] if isinstance(out[i], tuple) else (out[i],))
                    + (cand,))
                used.add(cand)
                if not fold:
                    break  # fallback semantics: first available candidate
        # combined divisibility for folded axes
        if isinstance(out[i], tuple):
            total = int(np.prod([mesh.shape[a] for a in out[i]]))
            if shape[i] % total != 0:
                out[i] = out[i][0]
    return P(*out)


def spec_for(axes: tuple, shape: tuple, *, mesh: Mesh | None = None,
             kind: str = "act", rules: str | None = None) -> P:
    mesh = mesh or _CTX.mesh
    if mesh is None:
        return P()
    act, par = RULE_SETS[rules or _CTX.rules]
    return _resolve(tuple(axes), tuple(shape), mesh, act if kind == "act" else par)


def constrain(x, *axes):
    """with_sharding_constraint on logical axes; no-op without a mesh."""
    mesh = _CTX.mesh
    if mesh is None:
        return x
    if len(axes) != x.ndim:
        raise ValueError(f"axes {axes} vs shape {x.shape}")
    spec = spec_for(axes, x.shape, mesh=mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ---------------------------------------------------------------------------
# Param-tree spec inference
# ---------------------------------------------------------------------------
# Each linear/param leaf lives under a descriptive key; the table maps that
# key to logical axes of the *dense* (out, in) orientation.  Quantized
# layouts ('idx', 'u8', 'scales') inherit the same logical axes (their
# second dim is a packed function of 'in').  Leading stacked dims
# ('layers', 'expert') are prepended by the tree walker based on depth.

LINEAR_AXES: dict[str, tuple] = {
    "wq": ("heads", "embed"),
    "wk": ("kvheads", "embed"),
    "wv": ("kvheads", "embed"),
    "wo": ("embed", "heads"),
    "up": ("mlp", "embed"),
    "gate": ("mlp", "embed"),
    "down": ("embed", "mlp"),
    "router": ("expert", "embed"),
    "lm_head": ("vocab", "embed"),
    "in_proj": ("mamba_inner", "embed"),
    "x_proj": ("none", "mamba_inner"),
    "dt_proj": ("mamba_inner", "none"),
    "out_proj": ("embed", "mamba_inner"),
    "xl_up": ("xl_inner", "embed"),
    "xl_o": ("xl_inner", "embed"),
    "xl_gates": ("none", "xl_inner"),
    "xl_down": ("embed", "xl_inner"),
    "sl_w": ("embed", "none"),
    "sl_r": ("embed", "none"),
}
VECTOR_AXES: dict[str, tuple] = {
    "embedding": ("vocab", "embed"),
    "scale": ("none",),
    "bias": ("none",),
    "A_log": ("mamba_inner", "state"),
    "D": ("mamba_inner",),
    "conv_w": ("conv", "mamba_inner"),
    "conv_b": ("mamba_inner",),
    "xl_conv_w": ("conv", "xl_inner"),
    "xl_conv_b": ("xl_inner",),
    "xl_q": ("heads", "head_dim", "head_dim"),
    "xl_k": ("heads", "head_dim", "head_dim"),
    "xl_v": ("heads", "head_dim", "head_dim"),
}


def _leaf_axes(path: tuple, leaf_ndim: int) -> tuple:
    names = [getattr(p, "key", getattr(p, "name", str(p))) for p in path]
    # innermost linear-ish ancestor key
    anc = None
    for n in reversed(names):
        if n in LINEAR_AXES or n in VECTOR_AXES:
            anc = n
            break
    leaf = names[-1]
    is_expert = any(n == "experts" for n in names)
    if anc in LINEAR_AXES:
        base = LINEAR_AXES[anc]
        if is_expert and anc in ("up", "gate", "down"):
            base = ("expert_out", "expert_in")
        if leaf in ("w", "idx", "u8"):
            axes = base
        elif leaf == "scales":
            axes = (base[0], "scales")
        elif leaf == "codebook":
            axes = ("scales",)  # 16-entry value table: replicated
        elif leaf in ("b", "bias"):
            axes = (base[0],)
        else:
            axes = base
    elif anc in VECTOR_AXES:
        axes = VECTOR_AXES[anc]
    else:
        axes = ("none",) * leaf_ndim
    # prepend stacked dims (scan groups, experts)
    extra = leaf_ndim - len(axes)
    if extra < 0:
        axes = axes[-leaf_ndim:] if leaf_ndim else ()
        extra = 0
    prefix = []
    is_expert = any(n == "experts" for n in names)
    for e in range(extra):
        if is_expert and e == extra - 1 and anc in ("up", "gate", "down"):
            prefix.append("expert")
        else:
            prefix.append("layers")
    return tuple(prefix) + tuple(axes)


# Decode/prefill cache leaves (under the stacked (G, ...) block groups).
CACHE_AXES: dict[str, tuple] = {
    "k": ("batch", "kv_seq", "kvheads", "head_dim"),
    "v": ("batch", "kv_seq", "kvheads", "head_dim"),
    "cross_k": ("batch", "kv_seq", "kvheads", "head_dim"),
    "cross_v": ("batch", "kv_seq", "kvheads", "head_dim"),
    "ssm": ("batch", "mamba_inner", "state"),
    "conv": ("batch", "conv", "mamba_inner"),
    "C": ("batch", "heads", "head_dim", "head_dim"),
    "n": ("batch", "heads", "head_dim"),
    "m": ("batch", "heads"),
    "h": ("batch", "embed"),
    "c": ("batch", "embed"),
}


def cache_specs(cache_shape, mesh: Mesh, rules: str = "default"):
    """PartitionSpec pytree for a transformer.init_cache tree (leaves are
    stacked (G, ...) -> 'layers' prefix)."""

    def one(path, leaf):
        name = getattr(path[-1], "key", str(path[-1]))
        axes = CACHE_AXES.get(name, ("none",) * (len(leaf.shape) - 1))
        axes = ("layers",) + tuple(axes)
        if len(axes) != len(leaf.shape):  # xlstm 'm' vs mamba trees etc.
            axes = ("layers",) + ("none",) * (len(leaf.shape) - 1)
        return spec_for(axes, leaf.shape, mesh=mesh, kind="act", rules=rules)

    return jax.tree_util.tree_map_with_path(one, cache_shape)


# Paged-serving KV block pools (runtime.serve.init_paged_cache): leaves
# are (G, num_blocks, block_size, Hk, Dh).  The pool has no batch dim —
# sequences own block subsets via host-side tables — so only the
# kvheads/head_dim tail shards (kvheads over 'model' per ACT_RULES);
# the block and slot dims stay replicated: scatter/gather by flat slot
# id must find every sequence's blocks on every data shard.
PAGED_CACHE_AXES: dict[str, tuple] = {
    # full-precision values *or* quantized u8 codes (last dim Dh or the
    # packed Dhp — 'head_dim' maps to () in serve rules, so both shard
    # identically: replicated tail, kvheads on 'model')
    "k": ("layers", "none", "none", "kvheads", "head_dim"),
    "v": ("layers", "none", "none", "kvheads", "head_dim"),
    # quantized-pool scale leaves (repro.kvq.pool): (G, nb, bs, Hk) f32,
    # same (block, slot) replication + kvheads placement as the codes so
    # a flat slot id addresses codes and scales on the same shard
    "k_scale": ("layers", "none", "none", "kvheads"),
    "v_scale": ("layers", "none", "none", "kvheads"),
}


def paged_cache_specs(pool_shape, mesh: Mesh, rules: str = "default"):
    """PartitionSpec pytree for a runtime.serve.init_paged_cache tree."""

    def one(path, leaf):
        name = getattr(path[-1], "key", str(path[-1]))
        axes = PAGED_CACHE_AXES.get(
            name, ("layers",) + ("none",) * (len(leaf.shape) - 1))
        return spec_for(axes, leaf.shape, mesh=mesh, kind="act", rules=rules)

    return jax.tree_util.tree_map_with_path(one, pool_shape)


def batch_specs(batch_shape, mesh: Mesh, rules: str = "default"):
    """PartitionSpec pytree for data batches / serve inputs by rank."""

    def one(path, leaf):
        name = getattr(path[-1], "key", str(path[-1]))
        if name in ("token", "pos"):
            axes = ("batch",)
        else:
            axes = {1: ("batch",), 2: ("batch", "seq"),
                    3: ("batch", "seq", "embed")}[len(leaf.shape)]
        return spec_for(axes, leaf.shape, mesh=mesh, kind="act", rules=rules)

    return jax.tree_util.tree_map_with_path(one, batch_shape)


def param_specs(params_shape, mesh: Mesh, rules: str = "default"):
    """Infer a PartitionSpec pytree for a params (shape) pytree."""

    def one(path, leaf):
        shape = leaf.shape
        axes = _leaf_axes(path, len(shape))
        return spec_for(axes, shape, mesh=mesh, kind="param", rules=rules)

    return jax.tree_util.tree_map_with_path(one, params_shape)


def constrain_params(tree, *, int8_gather: bool = False):
    """Pin a param (sub)tree to its storage sharding — used at the top of
    each scanned layer group so the FSDP all-gather happens per-group
    inside the loop body, not on the full (G, ...) stack outside it
    (full-stack gather = G x the memory; see EXPERIMENTS.md §Perf).

    int8_gather=True additionally routes FSDP('data')-sharded float
    leaves through the explicit int8 all-gather wire format."""
    mesh = _CTX.mesh
    if mesh is None:
        return tree

    def one(path, leaf):
        axes = _leaf_axes(path, leaf.ndim)
        spec = spec_for(axes, leaf.shape, mesh=mesh, kind="param")
        leaf = jax.lax.with_sharding_constraint(
            leaf, NamedSharding(mesh, spec))
        if int8_gather and jnp.issubdtype(leaf.dtype, jnp.floating):
            from repro.distributed.collectives import int8_all_gather

            leaf = int8_all_gather(leaf, mesh, spec, axis="data")
        return leaf

    return jax.tree_util.tree_map_with_path(one, tree)


def shardings(tree_shape, mesh: Mesh, rules: str = "default"):
    specs = param_specs(tree_shape, mesh, rules)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda s: isinstance(s, P))
