"""Explicit collective helpers.

``int8_all_gather`` — quantized FSDP weight gather: the parameter shard is
quantized to int8 (one symmetric scale per leaf, agreed via a scalar
pmax), all-gathered over the data axis in the int8 wire format (halving
the dominant 400B-train collective, EXPERIMENTS.md §Perf A), and
dequantized locally.  Backward is the exact FSDP transpose — a full-
precision reduce-scatter of the gradient (straight-through w.r.t. the
quantization, standard for compressed weight gathers).

Implemented with fully-manual shard_map (repro.distributed.compat): the
gather axis carries the collectives, the model/tensor axes are pure
per-shard layout.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed import compat


def _gather_spec(spec: P, axis: str):
    """Locate `axis` in a PartitionSpec; return (dim, spec-without-axis)."""
    entries = list(spec) + [None] * 8
    for i, e in enumerate(entries):
        names = e if isinstance(e, tuple) else (e,)
        if axis in names:
            rest = tuple(n for n in names if n != axis)
            new = list(spec)
            new[i] = rest if len(rest) > 1 else (rest[0] if rest else None)
            return i, P(*new)
    return None, spec


def int8_all_gather(x: jnp.ndarray, mesh, spec: P, *, axis: str = "data"):
    """Gather the `axis`-sharded dim of x in int8; exact-gradient RS bwd."""
    dim, out_spec = _gather_spec(spec, axis)
    if dim is None or axis not in mesh.shape or mesh.shape[axis] == 1:
        return x
    # fully-manual shard_map over the leaf's own storage spec: the only
    # collectives inside are over `axis`; the model/tensor axes are pure
    # layout (each shard just carries its slice through).  Partial-auto
    # (manual_axes={axis}) would be tidier but trips the SPMD
    # partitioner's manual-subgroup check on the older jax spelling.
    gather = functools.partial(
        compat.shard_map, mesh=mesh, in_specs=(spec,), out_specs=out_spec,
        check=False)
    scatter = functools.partial(
        compat.shard_map, mesh=mesh, in_specs=(out_spec,), out_specs=spec,
        check=False)

    @jax.custom_vjp
    def f(xs):
        @gather
        def run(s):
            amax = jax.lax.pmax(jnp.max(jnp.abs(s)).astype(jnp.float32),
                                axis)
            scale = jnp.where(amax > 0, amax / 127.0, 1.0)
            q = jnp.clip(jnp.round(s.astype(jnp.float32) / scale),
                         -127, 127).astype(jnp.int8)
            g = jax.lax.all_gather(q, axis, axis=dim, tiled=True)
            return (g.astype(jnp.float32) * scale).astype(s.dtype)

        return run(xs)

    def fwd(xs):
        return f(xs), None

    def bwd(_, ct):
        # The gathered output is replicated over `axis`, so its cotangent
        # arrives reduced+replicated; the exact transpose is the local
        # slice.  XLA's reduce-scatter-creator pass fuses the upstream
        # all-reduce with this partition-indexed slice into a
        # reduce-scatter where profitable.
        @scatter
        def run(c):
            size = c.shape[dim] // mesh.shape[axis]
            start = jax.lax.axis_index(axis) * size
            return jax.lax.dynamic_slice_in_dim(c, start, size, axis=dim)

        return (run(ct),)

    f.defvjp(fwd, bwd)
    return f(x)
