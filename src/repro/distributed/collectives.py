"""Explicit collective helpers.

``int8_all_gather`` — quantized FSDP weight gather: the parameter shard is
quantized to int8 (one symmetric scale per leaf, agreed via a scalar
pmax), all-gathered over the data axis in the int8 wire format (halving
the dominant 400B-train collective, EXPERIMENTS.md §Perf A), and
dequantized locally.  Backward is the exact FSDP transpose — a full-
precision reduce-scatter of the gradient (straight-through w.r.t. the
quantization, standard for compressed weight gathers).

``ring_psum`` / ``ring_reduce_scatter`` / ``ring_all_gather`` — explicit
``lax.ppermute`` rings for use *inside* a fully-manual shard_map body.
XLA's fused ``psum``/``psum_scatter`` are opaque single ops: nothing can
be scheduled between their internal steps, so the contraction collective
of a row-parallel linear serializes behind the whole GeMM.  The ring
spellings decompose the same reduction into N-1 point-to-point hops,
each a separate HLO the scheduler may interleave with independent
compute — which is what lets ``dispatch.shard`` overlap the collective
for contraction-chunk *i* with the msGeMM consume of chunk *i+1*.
``collective_cost`` is the matching analytic (hops, bytes) model used by
``obs.perfmodel`` to rank pipelined plan variants without measuring
every chunk count.

Implemented with fully-manual shard_map (repro.distributed.compat): the
gather axis carries the collectives, the model/tensor axes are pure
per-shard layout.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed import compat


def _ring_perm(n: int):
    """Shift-by-one permutation over an axis of size n."""
    return [(i, (i + 1) % n) for i in range(n)]


def ring_reduce_scatter(y, axis: str, *, axis_size: int | None = None,
                        dim: int = -1):
    """Block ring reduce-scatter of ``y`` over named axis ``axis``.

    Must be called inside a fully-manual shard_map.  ``y.shape[dim]``
    must be divisible by the axis size N; device p ends with block p of
    the cross-device sum — the same block→device assignment as
    ``lax.psum_scatter(..., tiled=True)``.  N-1 hops, each carrying one
    1/N-size block, every hop a separate ppermute the scheduler can
    slide under unrelated compute.
    """
    n = axis_size if axis_size is not None else compat.axis_size(axis)
    if n == 1:
        return y
    dim = dim % y.ndim
    if y.shape[dim] % n:
        raise ValueError(
            f"ring_reduce_scatter: dim {dim} of {y.shape} not divisible "
            f"by axis {axis!r} size {n}")
    sz = y.shape[dim] // n
    p = jax.lax.axis_index(axis)
    perm = _ring_perm(n)

    def blk(i):
        # i is traced and may exceed n; reduce mod n (always >= 0 here).
        return jax.lax.dynamic_slice_in_dim(y, (i % n) * sz, sz, axis=dim)

    # Device p seeds the ring with block (p-1); after hop t it holds the
    # running sum of block (p-t-2 mod n) over devices p-t..p, so after
    # n-1 hops it ends with block p fully reduced.
    acc = blk(p + n - 1)
    for t in range(n - 1):
        acc = jax.lax.ppermute(acc, axis, perm)
        acc = acc + blk(p + 2 * n - t - 2)
    return acc


def ring_all_gather(y, axis: str, *, axis_size: int | None = None,
                    dim: int = -1):
    """Ring all-gather over named axis ``axis`` (inverse of the scatter).

    Device p contributes block p; output concatenates all N blocks along
    ``dim`` in axis order.  N-1 single-block hops.
    """
    n = axis_size if axis_size is not None else compat.axis_size(axis)
    if n == 1:
        return y
    dim = dim % y.ndim
    sz = y.shape[dim]
    p = jax.lax.axis_index(axis)
    perm = _ring_perm(n)
    shape = y.shape[:dim] + (n * sz,) + y.shape[dim + 1:]
    out = jnp.zeros(shape, y.dtype)
    out = jax.lax.dynamic_update_slice_in_dim(out, y, p * sz, axis=dim)
    cur = y
    for t in range(n - 1):
        cur = jax.lax.ppermute(cur, axis, perm)
        # hop t delivers the block of device (p - t - 1) mod n
        out = jax.lax.dynamic_update_slice_in_dim(
            out, cur, ((p + 2 * n - t - 1) % n) * sz, axis=dim)
    return out


def ring_psum(y, axis: str, *, axis_size: int | None = None):
    """Ring all-reduce of ``y`` over named axis ``axis``.

    When the last dim divides the axis size, runs the bandwidth-optimal
    reduce-scatter + all-gather ring (2(N-1) hops of 1/N-size blocks).
    Otherwise falls back to the naive full-buffer ring (N-1 hops, each
    carrying the whole partial).  Either way every hop is an independent
    ppermute that can overlap unrelated compute.
    """
    n = axis_size if axis_size is not None else compat.axis_size(axis)
    if n == 1:
        return y
    if y.shape[-1] % n == 0:
        sc = ring_reduce_scatter(y, axis, axis_size=n, dim=-1)
        return ring_all_gather(sc, axis, axis_size=n, dim=-1)
    perm = _ring_perm(n)
    acc = y
    cur = y
    for _ in range(n - 1):
        cur = jax.lax.ppermute(cur, axis, perm)
        acc = acc + cur
    return acc


def collective_cost(*, impl: str, collective: str, axis_size: int,
                    elems: int, dtype_bytes: int = 4,
                    pipeline_chunks: int = 1):
    """Analytic (hops, bytes) one device moves to resolve a k-sharded
    contraction whose full (unscattered) partial output has ``elems``
    elements, split into ``pipeline_chunks`` k-chunks.

    Returns ``(hops_total, bytes_total)`` summed over all chunks.  The
    ring impls count their actual ppermute hops; the opaque XLA ops are
    modeled as one logical hop per chunk moving the standard-algorithm
    byte volume (ring-equivalent: (N-1)/N of the buffer for a
    reduce-scatter, twice that for an all-reduce).  This is the single
    source of truth for ``obs.perfmodel.collective_features``.
    """
    n = int(axis_size)
    pc = max(int(pipeline_chunks), 1)
    if n <= 1:
        return 0, 0.0
    chunk_bytes = elems / pc * dtype_bytes
    if impl == "ring":
        if collective == "reduce_scatter":
            hops_c = n - 1
            bytes_c = (n - 1) * chunk_bytes / n
        elif chunk_bytes and elems % (pc * n) == 0:
            # rs+ag ring: 2(N-1) hops of 1/N-size blocks
            hops_c = 2 * (n - 1)
            bytes_c = 2 * (n - 1) * chunk_bytes / n
        else:
            # naive full-buffer ring
            hops_c = n - 1
            bytes_c = (n - 1) * chunk_bytes
    else:  # opaque xla psum / psum_scatter
        hops_c = 1
        scale = 1 if collective == "reduce_scatter" else 2
        bytes_c = scale * (n - 1) * chunk_bytes / n
    return hops_c * pc, bytes_c * pc


def _gather_spec(spec: P, axis: str):
    """Locate `axis` in a PartitionSpec; return (dim, spec-without-axis)."""
    entries = list(spec) + [None] * 8
    for i, e in enumerate(entries):
        names = e if isinstance(e, tuple) else (e,)
        if axis in names:
            rest = tuple(n for n in names if n != axis)
            new = list(spec)
            new[i] = rest if len(rest) > 1 else (rest[0] if rest else None)
            return i, P(*new)
    return None, spec


def int8_all_gather(x: jnp.ndarray, mesh, spec: P, *, axis: str = "data"):
    """Gather the `axis`-sharded dim of x in int8; exact-gradient RS bwd."""
    dim, out_spec = _gather_spec(spec, axis)
    if dim is None or axis not in mesh.shape or mesh.shape[axis] == 1:
        return x
    # fully-manual shard_map over the leaf's own storage spec: the only
    # collectives inside are over `axis`; the model/tensor axes are pure
    # layout (each shard just carries its slice through).  Partial-auto
    # (manual_axes={axis}) would be tidier but trips the SPMD
    # partitioner's manual-subgroup check on the older jax spelling.
    gather = functools.partial(
        compat.shard_map, mesh=mesh, in_specs=(spec,), out_specs=out_spec,
        check=False)
    scatter = functools.partial(
        compat.shard_map, mesh=mesh, in_specs=(out_spec,), out_specs=spec,
        check=False)

    @jax.custom_vjp
    def f(xs):
        @gather
        def run(s):
            amax = jax.lax.pmax(jnp.max(jnp.abs(s)).astype(jnp.float32),
                                axis)
            scale = jnp.where(amax > 0, amax / 127.0, 1.0)
            q = jnp.clip(jnp.round(s.astype(jnp.float32) / scale),
                         -127, 127).astype(jnp.int8)
            g = jax.lax.all_gather(q, axis, axis=dim, tiled=True)
            return (g.astype(jnp.float32) * scale).astype(s.dtype)

        return run(xs)

    def fwd(xs):
        return f(xs), None

    def bwd(_, ct):
        # The gathered output is replicated over `axis`, so its cotangent
        # arrives reduced+replicated; the exact transpose is the local
        # slice.  XLA's reduce-scatter-creator pass fuses the upstream
        # all-reduce with this partition-indexed slice into a
        # reduce-scatter where profitable.
        @scatter
        def run(c):
            size = c.shape[dim] // mesh.shape[axis]
            start = jax.lax.axis_index(axis) * size
            return jax.lax.dynamic_slice_in_dim(c, start, size, axis=dim)

        return (run(ct),)

    f.defvjp(fwd, bwd)
    return f(x)
