"""Explicit collective helpers.

``int8_all_gather`` — quantized FSDP weight gather: the parameter shard is
quantized to int8 (one symmetric scale per leaf, agreed via a scalar
pmax), all-gathered over the data axis in the int8 wire format (halving
the dominant 400B-train collective, EXPERIMENTS.md §Perf A), and
dequantized locally.  Backward is the exact FSDP transpose — a full-
precision reduce-scatter of the gradient (straight-through w.r.t. the
quantization, standard for compressed weight gathers).

Implemented with partial-auto shard_map: only the gather axis is manual;
the model/tensor axes stay under GSPMD.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def _gather_spec(spec: P, axis: str):
    """Locate `axis` in a PartitionSpec; return (dim, spec-without-axis)."""
    entries = list(spec) + [None] * 8
    for i, e in enumerate(entries):
        names = e if isinstance(e, tuple) else (e,)
        if axis in names:
            rest = tuple(n for n in names if n != axis)
            new = list(spec)
            new[i] = rest if len(rest) > 1 else (rest[0] if rest else None)
            return i, P(*new)
    return None, spec


def int8_all_gather(x: jnp.ndarray, mesh, spec: P, *, axis: str = "data"):
    """Gather the `axis`-sharded dim of x in int8; exact-gradient RS bwd."""
    dim, out_spec = _gather_spec(spec, axis)
    if dim is None or axis not in mesh.shape or mesh.shape[axis] == 1:
        return x
    # partial-auto: only the gather axis is manual; model/tensor axes stay
    # under GSPMD — shard_map specs may only name manual axes.
    def manual_only(s: P) -> P:
        out = []
        for e in s:
            names = e if isinstance(e, tuple) else (e,)
            out.append(axis if axis in names else None)
        return P(*out)

    m_in, m_out = manual_only(spec), manual_only(out_spec)
    gather = functools.partial(
        jax.shard_map, mesh=mesh, in_specs=(m_in,), out_specs=m_out,
        axis_names={axis}, check_vma=False)
    scatter = functools.partial(
        jax.shard_map, mesh=mesh, in_specs=(m_out,), out_specs=m_in,
        axis_names={axis}, check_vma=False)

    @jax.custom_vjp
    def f(xs):
        @gather
        def run(s):
            amax = jax.lax.pmax(jnp.max(jnp.abs(s)).astype(jnp.float32),
                                axis)
            scale = jnp.where(amax > 0, amax / 127.0, 1.0)
            q = jnp.clip(jnp.round(s.astype(jnp.float32) / scale),
                         -127, 127).astype(jnp.int8)
            g = jax.lax.all_gather(q, axis, axis=dim, tiled=True)
            return (g.astype(jnp.float32) * scale).astype(s.dtype)

        return run(xs)

    def fwd(xs):
        return f(xs), None

    def bwd(_, ct):
        # The gathered output is replicated over `axis`, so its cotangent
        # arrives reduced+replicated; the exact transpose is the local
        # slice.  XLA's reduce-scatter-creator pass fuses the upstream
        # all-reduce with this partition-indexed slice into a
        # reduce-scatter where profitable.
        @scatter
        def run(c):
            n = jax.lax.axis_size(axis)
            size = c.shape[dim] // n
            start = jax.lax.axis_index(axis) * size
            return jax.lax.dynamic_slice_in_dim(c, start, size, axis=dim)

        return (run(ct),)

    f.defvjp(fwd, bwd)
    return f(x)
