"""Distribution layer: logical-axis sharding rules, mesh builders,
collective helpers, straggler watchdog."""

from repro.distributed import sharding  # noqa: F401
