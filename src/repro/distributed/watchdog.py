"""Straggler / hang detection for the training driver.

On multi-host TPU fleets the common failure modes are (a) a host that
stops making progress (hang) and (b) a slow host stretching every step
(straggler).  Without real multi-host telemetry here, the watchdog tracks
wall-clock per step with a rolling mean/std and

* flags steps whose duration z-score exceeds ``z_threshold`` (straggler
  signal -> logged + counted; hook for re-dispatch/drain in production),
* arms a hang timer (``hang_factor`` x rolling mean) that fires a callback
  — the driver uses it to abort + restart from the last checkpoint.
"""

from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass, field

from repro import obs


@dataclass
class Watchdog:
    window: int = 50
    z_threshold: float = 4.0
    hang_factor: float = 10.0
    min_steps: int = 5
    # floor on the hang timeout: mean*hang_factor can be microseconds on
    # tiny models, which would fire on any GC pause.  Serving (and fast
    # tests) lower it deliberately.
    min_timeout_s: float = 1.0
    on_straggler: callable = None
    on_hang: callable = None
    _times: deque = field(default_factory=lambda: deque(maxlen=200))
    _timer: threading.Timer | None = None
    straggler_count: int = 0
    hang_count: int = 0

    def _stats(self):
        xs = list(self._times)[-self.window:]
        n = len(xs)
        mean = sum(xs) / n
        var = sum((x - mean) ** 2 for x in xs) / max(n - 1, 1)
        return mean, var**0.5

    def step_started(self):
        self._t0 = time.monotonic()
        if len(self._times) >= self.min_steps:
            mean, _ = self._stats()
            timeout = max(mean * self.hang_factor, self.min_timeout_s)
            self._timer = threading.Timer(timeout, self._hang)
            self._timer.daemon = True
            self._timer.start()

    def _hang(self):
        self.hang_count += 1
        obs.registry().counter(
            "watchdog_hangs_total",
            help="hang-timer firings (step exceeded hang_factor x mean)"
        ).inc()
        obs.tracer().instant("watchdog.hang", cat="watchdog",
                             hang_count=self.hang_count)
        if self.on_hang:
            self.on_hang()

    def step_finished(self) -> dict:
        dt = time.monotonic() - self._t0
        if self._timer is not None:
            self._timer.cancel()
            self._timer = None
        info = {"step_time": dt, "straggler": False}
        if len(self._times) >= self.min_steps:
            mean, std = self._stats()
            if std > 0 and (dt - mean) / std > self.z_threshold:
                self.straggler_count += 1
                info["straggler"] = True
                obs.registry().counter(
                    "watchdog_stragglers_total",
                    help="steps whose z-score exceeded the threshold"
                ).inc()
                obs.tracer().instant("watchdog.straggler", cat="watchdog",
                                     step_time=dt, mean=mean, std=std)
                if self.on_straggler:
                    self.on_straggler(dt, mean, std)
        self._times.append(dt)
        return info
