"""Deterministic synthetic data pipeline.

Every batch is a pure function of (seed, step) — random access by step
index, which is what makes checkpoint/restart exact: a resumed run sees
the same stream with no iterator state to persist (DESIGN.md §4 fault
tolerance).  A learnable 'lcg' mode gives train-loss-decrease tests real
signal; 'uniform' mode stresses throughput.

``device_batch`` places the global batch with the logical ('batch','seq')
sharding; a background prefetch thread overlaps host generation with
device compute.
"""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass

import jax
import numpy as np
from jax.sharding import NamedSharding

from repro.distributed import sharding as shd


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    mode: str = "lcg"  # lcg | uniform
    frontend: str = ""  # '' | 'audio_frames' | 'image_patches'
    d_model: int = 0  # frontend embedding dim
    num_frames: int = 0
    num_patches: int = 0


class SyntheticStream:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def host_batch(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step]))
        B, S = cfg.global_batch, cfg.seq_len
        if cfg.mode == "lcg":
            # learnable sequences: affine recurrence over a small alphabet
            # with occasional noise tokens.
            a = rng.integers(1, 17, size=(B, 1))
            c = rng.integers(0, 23, size=(B, 1))
            x0 = rng.integers(0, cfg.vocab_size, size=(B, 1))
            idx = np.arange(S)[None, :]
            toks = (x0 + a * idx + c * (idx // 7)) % min(cfg.vocab_size, 251)
            noise = rng.random((B, S)) < 0.02
            toks = np.where(noise,
                            rng.integers(0, cfg.vocab_size, size=(B, S)),
                            toks)
        else:
            toks = rng.integers(0, cfg.vocab_size, size=(B, S))
        tokens = toks[:, :-1].astype(np.int32)
        labels = toks[:, 1:].astype(np.int32)
        batch = {"tokens": tokens, "labels": labels}
        if cfg.frontend == "audio_frames":
            batch["frames"] = rng.standard_normal(
                (B, cfg.num_frames, cfg.d_model)).astype(np.float32)
        elif cfg.frontend == "image_patches":
            batch["patch_embeds"] = rng.standard_normal(
                (B, cfg.num_patches, cfg.d_model)).astype(np.float32)
        return batch

    def device_batch(self, step: int, mesh=None) -> dict:
        hb = self.host_batch(step)
        mesh = mesh or shd.active_mesh()
        if mesh is None:
            return {k: jax.numpy.asarray(v) for k, v in hb.items()}
        out = {}
        for k, v in hb.items():
            axes = {2: ("batch", "seq"),
                    3: ("batch", "seq", "embed")}[v.ndim]
            spec = shd.spec_for(axes, v.shape, mesh=mesh)
            out[k] = jax.device_put(v, NamedSharding(mesh, spec))
        return out

    def prefetch(self, start_step: int, depth: int = 2):
        """Generator with background host-batch production."""
        q: queue.Queue = queue.Queue(maxsize=depth)
        stop = threading.Event()

        def worker():
            s = start_step
            while not stop.is_set():
                try:
                    q.put((s, self.host_batch(s)), timeout=0.5)
                    s += 1
                except queue.Full:
                    continue

        t = threading.Thread(target=worker, daemon=True)
        t.start()
        try:
            while True:
                yield q.get()
        finally:
            stop.set()
