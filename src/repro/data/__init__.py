from repro.data.pipeline import DataConfig, SyntheticStream  # noqa: F401
