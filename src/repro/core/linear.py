"""QuantizedLinear — msGeMM as a first-class linear-layer execution mode.

Every weight-bearing linear in every architecture (attention projections,
MLPs, MoE expert FFNs, mamba/xLSTM projections, lm_head) routes through this
module.  Execution modes:

* ``bf16``         dense matmul (training + dense-serve baseline; the
                   paper's "naive GeMM", Eq. 14)
* ``int4_dequant`` practical current-TPU int4 path: dequantize -> MXU matmul
* ``msgemm``       the paper's algorithm (produce LUT on MXU, consume via
                   gather-add), in the lowerable jnp formulation; ``impl=
                   'pallas'`` selects the fused VMEM-tiled kernel for
                   small-scale validation (kernels/msgemm.py)

Weight-storage layouts for quantized modes (a §Perf lever — see
EXPERIMENTS.md):

* ``packed_idx``  int32 LUT indices, ceil(k/d) per row  (4·d bits -> 32 bits
                  per chunk; 10.67 bits/weight at d=3).  Zero index math in
                  the hot loop — the paper's §4 assumption.
* ``packed_u8``   true int4 storage (2 codes/byte, 4 bits/weight); LUT
                  indices built on the fly (free for d=2 — the byte IS the
                  index; unpack+repack otherwise).

Activation convention is row-major ``x (..., k) -> y (..., m)`` with the
weight stored as the paper's ``M (m, k)``; internally we transpose to the
paper's column layout.
"""

from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import lut, packing, scales


_MODES = ("bf16", "int4_dequant", "msgemm")
_STORAGES = ("packed_idx", "packed_u8")
_IMPLS = ("jnp", "pallas")
_CODEBOOKS = ("none", "learned")


@dataclass(frozen=True)
class QuantConfig:
    mode: str = "bf16"  # bf16 | int4_dequant | msgemm
    # LUT depth: an int, or 'adaptive' — pick d* = argmax Eq. 15 per
    # linear from its static (out, in) dims (beyond-paper: small-m
    # projections get d=2 where 16^d amortizes, big-m heads keep d=3/4;
    # EXPERIMENTS.md §Perf C5).  Deterministic in the shapes, so init and
    # apply always agree.
    d: int | str = 3
    scale_block: int = 0  # 0 -> 12*d (multiple of every d in 2..4, §3.3)
    storage: str = "packed_idx"  # packed_idx | packed_u8
    impl: str = "jnp"  # jnp | pallas
    consume_chunk: int = 1  # j-chunks per consume scan step
    # Pallas execution mode for impl='pallas': None auto-detects the
    # backend (compiled on TPU, interpreter elsewhere); set explicitly to
    # force either mode (e.g. interpret=True to debug on TPU).
    interpret: bool | None = None
    # 'learned' gives every quantized linear a 16-entry value codebook
    # leaf (repro.calib fits them; init seeds the uniform int4 table so
    # checkpoint trees always match).  'none' is the plain int4 grid.
    codebook: str = "none"  # none | learned

    def __post_init__(self):
        # Eager validation: every config invariant the quantized paths
        # rely on is checked here, at construction, instead of surfacing
        # as a shape error deep inside consume()/the Pallas kernel.
        if self.mode not in _MODES:
            raise ValueError(f"unknown quant mode {self.mode!r}; one of {_MODES}")
        if self.storage not in _STORAGES:
            raise ValueError(
                f"unknown storage {self.storage!r}; one of {_STORAGES}")
        if self.impl not in _IMPLS:
            raise ValueError(f"unknown impl {self.impl!r}; one of {_IMPLS}")
        if self.codebook not in _CODEBOOKS:
            raise ValueError(
                f"unknown codebook policy {self.codebook!r}; one of {_CODEBOOKS}")
        if self.d != "adaptive":
            if not isinstance(self.d, int) or not 1 <= self.d <= 4:
                raise ValueError(
                    f"LUT depth d={self.d!r} must be 'adaptive' or an int in "
                    "[1, 4] (the 16^d LUT is produced in full)")
        if self.consume_chunk < 1:
            raise ValueError(f"consume_chunk={self.consume_chunk} must be >= 1")
        if self.scale_block < 0:
            raise ValueError(f"scale_block={self.scale_block} must be >= 0")
        if self.d != "adaptive" and self.scale_block == 0:
            object.__setattr__(self, "scale_block", 12 * int(self.d))
        elif self.scale_block == 0:
            object.__setattr__(self, "scale_block", 12)
        if self.mode == "msgemm":
            # §3.3 applicability — for adaptive d the block must compose
            # with the smallest candidate depth (resolve_d only shrinks d
            # until it divides the block, so d=2 is the floor).
            scales.check_applicable(
                self.scale_block, 2 if self.d == "adaptive" else int(self.d))

    def resolve_d(self, in_dim: int, out_dim: int) -> int:
        """The depth this linear actually uses (static in the shapes)."""
        if self.d != "adaptive":
            return int(self.d)
        from repro.core import complexity

        d_star, _ = complexity.best_d(out_dim, in_dim, range(2, 5))
        # the shared scale block must stay a multiple of d (§3.3)
        while self.scale_block % d_star:
            d_star -= 1
        return max(d_star, 2)


DENSE = QuantConfig(mode="bf16")

# Optional activation-statistics observer (repro.calib.stats installs one
# during calibration via set_observer; None costs nothing).  Kept here so
# core never imports calib.
_OBSERVER = None


def set_observer(obs) -> None:
    """Install (or clear, with None) the linear-input observer.  While set,
    every tagged apply() reports its input activations to
    ``obs.record(tag, x)`` — the hook repro.calib.stats collects per-linear
    input second moments through."""
    global _OBSERVER
    _OBSERVER = obs


def init(key, in_dim: int, out_dim: int, cfg: QuantConfig = DENSE, *,
         dtype=jnp.float32, init_scale: float | None = None) -> dict:
    """Initialise params.  Quantized modes initialise by quantizing a random
    dense weight (real deployments call quant.quantize_model on a trained
    checkpoint; init keeps every mode self-contained for tests/dry-runs)."""
    scale = init_scale if init_scale is not None else in_dim**-0.5
    w = jax.random.normal(key, (out_dim, in_dim), jnp.float32) * scale
    return from_dense(w, cfg, dtype=dtype)


def from_dense(w: jnp.ndarray, cfg: QuantConfig = DENSE, *,
               dtype=jnp.float32, codebook=None) -> dict:
    """Build this layer's params from a dense (out, in) weight matrix.

    ``codebook``: optional (16,) value table.  With cfg.codebook='learned'
    and no explicit table, the uniform int4 values are stored as a
    placeholder so param-tree structure is calibration-independent
    (checkpoint restore targets always match).
    """
    if cfg.mode == "bf16":
        return {"w": w.astype(dtype)}
    if codebook is None and cfg.codebook == "learned":
        codebook = packing.b_values(jnp.float32)
    if codebook is not None:
        qt = scales.quantize_codebook(w, codebook, cfg.scale_block)
    else:
        qt = scales.quantize_int4(w, cfg.scale_block)
    return from_quantized(qt, cfg)


def from_quantized(qt: scales.QuantizedTensor, cfg: QuantConfig) -> dict:
    """Param dict from an already-quantized tensor (calib's GPTQ path
    produces codes directly; from_dense routes through here too)."""
    out_dim, in_dim = qt.shape
    p: dict[str, Any] = {"scales": qt.scales.astype(jnp.float32)}
    if cfg.storage == "packed_idx":
        p["idx"] = packing.pack_indices(qt.codes,
                                        cfg.resolve_d(in_dim, out_dim))
    else:
        p["u8"] = packing.pack_storage(qt.codes)
    if qt.codebook is not None:
        p["codebook"] = jnp.asarray(qt.codebook, jnp.float32)
    return p


def apply(params: dict, x: jnp.ndarray, cfg: QuantConfig = DENSE, *,
          in_dim: int | None = None, precision=None,
          tag: str | None = None) -> jnp.ndarray:
    """x (..., in) -> y (..., out).

    ``tag`` names this linear for the activation-statistics observer
    (calibration); it does not affect the computation.
    """
    if _OBSERVER is not None and tag is not None:
        _OBSERVER.record(tag, x)
    if cfg.mode == "bf16":
        w = params["w"]
        return jax.lax.dot_general(
            x, w, (((x.ndim - 1,), (1,)), ((), ())),
            preferred_element_type=x.dtype, precision=precision)

    k = in_dim if in_dim is not None else _infer_k(params, cfg)
    m = params["scales"].shape[0]
    d = cfg.resolve_d(k, m)
    codebook = params.get("codebook")
    if cfg.mode == "int4_dequant":
        codes = _codes(params, cfg, k, d)
        qt = scales.QuantizedTensor(
            codes=codes, scales=params["scales"], block=cfg.scale_block,
            shape=(codes.shape[0], k), codebook=codebook)
        w = scales.dequantize(qt, x.dtype)
        return jax.lax.dot_general(
            x, w, (((x.ndim - 1,), (1,)), ((), ())),
            preferred_element_type=x.dtype)

    # ---- msgemm ----
    if cfg.impl == "pallas":
        from repro.kernels import ops as kops

        codes = _codes(params, cfg, k, d)
        batch = x.shape[:-1]
        y = kops.msgemm(
            codes, x.reshape(-1, k).T, d,
            scales=params["scales"], scale_block=cfg.scale_block,
            codebook=codebook, interpret=cfg.interpret)
        return y.T.reshape(*batch, -1).astype(x.dtype)

    batch = x.shape[:-1]
    xt = x.reshape(-1, k).T  # (k, B) — the paper's column layout
    lut_t = lut.produce(xt, d, dtype=jnp.float32, codebook=codebook)
    idx = params["idx"] if cfg.storage == "packed_idx" else (
        packing.indices_from_storage(params["u8"], d, k))
    y = lut.consume(
        lut_t, idx, scales=params["scales"], scale_block=cfg.scale_block,
        d=d, chunk=cfg.consume_chunk)
    return y.T.reshape(*batch, -1).astype(x.dtype)


def _infer_k(params: dict, cfg: QuantConfig) -> int:
    if cfg.storage == "packed_u8":
        return params["u8"].shape[-1] * 2
    if cfg.d != "adaptive":
        return params["idx"].shape[-1] * int(cfg.d)
    raise ValueError("adaptive-d msgemm needs an explicit in_dim")


def _codes(params: dict, cfg: QuantConfig, k: int, d: int) -> jnp.ndarray:
    if cfg.storage == "packed_idx":
        return packing.unpack_indices(params["idx"], d, k)
    return packing.unpack_storage(params["u8"], k)


def serving_config(cfg: QuantConfig, mode: str) -> QuantConfig:
    """Derive a serving-time quant config from a layer's config."""
    return replace(cfg, mode=mode)
