"""QuantizedLinear — msGeMM as a first-class linear-layer execution mode.

Every weight-bearing linear in every architecture (attention projections,
MLPs, MoE expert FFNs, mamba/xLSTM projections, lm_head) routes through this
module.  The weight *representation* is described by a frozen
:class:`repro.core.spec.QuantSpec`:

* ``bf16``         dense matmul (training + dense-serve baseline; the
                   paper's "naive GeMM", Eq. 14)
* ``int4_dequant`` practical current-TPU int4 path: dequantize -> MXU matmul
* ``msgemm``       the paper's algorithm (produce LUT on MXU, consume via
                   gather-add)

*How* a linear runs — which registered backend, which VMEM tiles, which
consume chunking — is a separate, per-shape decision made by
``repro.dispatch`` (backend registry + ExecPlan + persistent autotuner).
``apply`` below is a thin wrapper over ``dispatch.execute``.

Weight-storage layouts for quantized modes (a §Perf lever — see
EXPERIMENTS.md):

* ``packed_idx``  int32 LUT indices, ceil(k/d) per row  (4·d bits -> 32 bits
                  per chunk; 10.67 bits/weight at d=3).  Zero index math in
                  the hot loop — the paper's §4 assumption.
* ``packed_u8``   true int4 storage (2 codes/byte, 4 bits/weight); LUT
                  indices built on the fly (free for d=2 — the byte IS the
                  index; unpack+repack otherwise).

Activation convention is row-major ``x (..., k) -> y (..., m)`` with the
weight stored as the paper's ``M (m, k)``; internally we transpose to the
paper's column layout.

``QuantConfig`` remains as a **deprecated shim** that splits itself into
``.spec`` (QuantSpec) + ``.policy`` (dispatch.ExecPolicy); every
pre-registry call site keeps working unchanged.
"""

from __future__ import annotations

import warnings
from dataclasses import dataclass, replace
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import packing, scales
from repro.core.spec import DENSE, QuantSpec, as_spec  # noqa: F401 (re-export)

_IMPLS = ("jnp", "pallas")
# impl -> forced backend name for mode='msgemm' (the shim's hardcoded
# choice IS what the old if/elif dispatch did)
_IMPL_BACKENDS = {"jnp": "msgemm_jnp", "pallas": "msgemm_pallas"}


@dataclass(frozen=True)
class QuantConfig:
    """Deprecated: use :class:`repro.core.spec.QuantSpec` for the weight
    representation and ``repro.dispatch.ExecPolicy``/``ExecPlan`` for
    execution choices.  Kept as a shim: ``.spec``/``.policy`` split it
    into the two new halves, and every function here accepts either."""

    mode: str = "bf16"  # bf16 | int4_dequant | msgemm
    d: int | str = 3
    scale_block: int = 0  # 0 -> 12*d (multiple of every d in 2..4, §3.3)
    storage: str = "packed_idx"  # packed_idx | packed_u8
    impl: str = "jnp"  # jnp | pallas
    consume_chunk: int = 1  # j-chunks per consume scan step
    interpret: bool | None = None  # Pallas mode; None auto-detects
    codebook: str = "none"  # none | learned

    def __post_init__(self):
        warnings.warn(
            "QuantConfig is deprecated: describe the weights with "
            "core.spec.QuantSpec and execution with repro.dispatch "
            "(ExecPolicy / ExecPlan); QuantConfig.spec / .policy perform "
            "the split", DeprecationWarning, stacklevel=3)
        if self.impl not in _IMPLS:
            raise ValueError(f"unknown impl {self.impl!r}; one of {_IMPLS}")
        if self.consume_chunk < 1:
            raise ValueError(f"consume_chunk={self.consume_chunk} must be >= 1")
        # representation invariants live in QuantSpec; constructing the
        # spec validates mode/d/storage/codebook/scale_block eagerly and
        # resolves the scale_block=0 default
        spec = QuantSpec(mode=self.mode, d=self.d,
                         scale_block=self.scale_block,
                         storage=self.storage, codebook=self.codebook)
        object.__setattr__(self, "scale_block", spec.scale_block)

    @property
    def spec(self) -> QuantSpec:
        """The weight-representation half."""
        return QuantSpec(mode=self.mode, d=self.d,
                         scale_block=self.scale_block,
                         storage=self.storage, codebook=self.codebook)

    @property
    def policy(self):
        """The execution half (a dispatch.ExecPolicy).  ``impl`` maps to
        a forced backend for msgemm — exactly the old hardcoded branch —
        and auto-selection handles the other modes."""
        from repro.dispatch import ExecPolicy

        backend = _IMPL_BACKENDS[self.impl] if self.mode == "msgemm" else None
        return ExecPolicy(backend=backend, interpret=self.interpret,
                          consume_chunk=self.consume_chunk)

    def resolve_d(self, in_dim: int, out_dim: int) -> int:
        return self.spec.resolve_d(in_dim, out_dim)


# Optional activation-statistics observer (repro.calib.stats installs one
# during calibration via set_observer; None costs nothing).  Kept here so
# core never imports calib.
_OBSERVER = None


def set_observer(obs) -> None:
    """Install (or clear, with None) the linear-input observer.  While set,
    every tagged apply() reports its input activations to
    ``obs.record(tag, x)`` — the hook repro.calib.stats collects per-linear
    input second moments through."""
    global _OBSERVER
    _OBSERVER = obs


def init(key, in_dim: int, out_dim: int, cfg=DENSE, *,
         dtype=jnp.float32, init_scale: float | None = None) -> dict:
    """Initialise params.  Quantized modes initialise by quantizing a random
    dense weight (real deployments call quant.quantize_model on a trained
    checkpoint; init keeps every mode self-contained for tests/dry-runs)."""
    scale = init_scale if init_scale is not None else in_dim**-0.5
    w = jax.random.normal(key, (out_dim, in_dim), jnp.float32) * scale
    return from_dense(w, cfg, dtype=dtype)


def from_dense(w: jnp.ndarray, cfg=DENSE, *,
               dtype=jnp.float32, codebook=None) -> dict:
    """Build this layer's params from a dense (out, in) weight matrix.

    ``cfg``: a QuantSpec (or deprecated QuantConfig).  ``codebook``:
    optional (16,) value table.  With cfg.codebook='learned' and no
    explicit table, the uniform int4 values are stored as a placeholder
    so param-tree structure is calibration-independent (checkpoint
    restore targets always match).
    """
    spec = as_spec(cfg)
    if spec.mode == "bf16":
        return {"w": w.astype(dtype)}
    if codebook is None and spec.codebook == "learned":
        codebook = packing.b_values(jnp.float32)
    if codebook is not None:
        qt = scales.quantize_codebook(w, codebook, spec.scale_block)
    else:
        qt = scales.quantize_int4(w, spec.scale_block)
    return from_quantized(qt, spec)


def from_quantized(qt: scales.QuantizedTensor, cfg) -> dict:
    """Param dict from an already-quantized tensor (calib's GPTQ path
    produces codes directly; from_dense routes through here too)."""
    spec = as_spec(cfg)
    out_dim, in_dim = qt.shape
    p: dict[str, Any] = {"scales": qt.scales.astype(jnp.float32)}
    if spec.storage == "packed_idx":
        p["idx"] = packing.pack_indices(qt.codes,
                                        spec.resolve_d(in_dim, out_dim))
    else:
        p["u8"] = packing.pack_storage(qt.codes)
    if qt.codebook is not None:
        p["codebook"] = jnp.asarray(qt.codebook, jnp.float32)
    return p


def apply(params: dict, x: jnp.ndarray, cfg=DENSE, *,
          in_dim: int | None = None, precision=None,
          tag: str | None = None, plan=None, policy=None,
          epilogue=None, bias=None, residual=None,
          shard_axes: tuple | None = None) -> jnp.ndarray:
    """x (..., in) -> y (..., out), through the dispatch registry.

    ``cfg`` is a QuantSpec (or deprecated QuantConfig, whose embedded
    policy is honoured).  ``plan``: an explicit dispatch.ExecPlan
    bypassing planning; ``policy``: a dispatch.ExecPolicy overriding both
    the shim's and the process default.  ``tag`` names this linear for
    the activation-statistics observer (calibration); it does not affect
    the computation.

    ``epilogue`` (core.epilogue.Epilogue) + ``bias`` (out,) +
    ``residual`` (..., out): the element-wise tail fused into the kernel
    writeback when the planned backend supports it, applied unfused
    (identical math) otherwise — see dispatch.execute.

    ``shard_axes``: the weight's logical (out, in) axis names; under an
    active mesh the dispatch layer plans local-shard tiles and runs the
    backend inside a shard_map (models.common.linear_apply derives this
    from ``tag`` automatically).
    """
    if _OBSERVER is not None and tag is not None:
        _OBSERVER.record(tag, x)
    from repro import dispatch

    return dispatch.execute(params, x, cfg, in_dim=in_dim,
                            precision=precision, plan_override=plan,
                            policy=policy, epilogue=epilogue, bias=bias,
                            residual=residual, shard_axes=shard_axes)


def _infer_k(params: dict, cfg) -> int:
    spec = as_spec(cfg)
    if spec.mode == "bf16":
        return params["w"].shape[-1]
    if spec.storage == "packed_u8":
        return params["u8"].shape[-1] * 2
    if spec.d != "adaptive":
        return params["idx"].shape[-1] * int(spec.d)
    raise ValueError(
        "cannot infer the input dim of an adaptive-d 'packed_idx' linear "
        f"from its params (keys={sorted(params)}): 'idx' has ceil(k/d) "
        "columns but d itself depends on (in_dim, out_dim).  Pass the "
        "layer's input dim explicitly, e.g. linear.apply(params, x, cfg, "
        "in_dim=<in_dim>) — model code does this via "
        "common.linear_apply(..., in_dim=...).")


def _codes(params: dict, cfg, k: int, d: int) -> jnp.ndarray:
    spec = as_spec(cfg)
    if spec.storage == "packed_idx":
        return packing.unpack_indices(params["idx"], d, k)
    return packing.unpack_storage(params["u8"], k)


def serving_config(cfg, mode: str):
    """Derive a serving-time quant spec/config from a layer's config.
    Preserves the input type: QuantSpec -> QuantSpec, shim -> shim."""
    return replace(cfg, mode=mode)
