"""Shared-scale (bounding-box) quantization — paper §3.3.

MSFP-style datatypes store int4 mantissas with a scale shared by a block of
elements.  §3.3's applicability rule for msGeMM:

* blocks laid out along a ROW of M with block size r, r >= d and ideally
  d | r  -> applicable (the scale factors out after consuming L);
* blocks laid out along a COLUMN of M -> NOT applicable (each LUT entry
  would need a different scale per row).

``quantize_int4`` produces the row-block format; ``check_applicable``
enforces the rule and is exercised by tests/test_scales.py.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import packing


class QuantizedTensor(NamedTuple):
    """Row-block 4-bit quantized matrix (m, k).

    codes:      (m, k) uint8, 4-bit codes (canonical)
    scales:     (m, k//block) float32, shared per row-block (§3.3)
    block:      scale block size r along k
    shape:      original (m, k)
    codebook:   optional (16,) float32 value table (repro.calib learned
                codebooks); None means the uniform two's-complement int4
                grid.  Entry 0 must be 0.0 (code 0 is the padding code).
    """

    codes: jnp.ndarray
    scales: jnp.ndarray
    block: int
    shape: tuple
    codebook: jnp.ndarray | None = None


def check_applicable(block: int, d: int, axis: str = "row") -> None:
    """§3.3 rule: row-blocked scales with d | r compose with msGeMM."""
    if axis != "row":
        raise ValueError(
            "§3.3: column-wise bounding boxes make msGeMM inapplicable "
            "(each LUT entry would need a per-row scale)"
        )
    if block < d or block % d != 0:
        raise ValueError(
            f"§3.3: scale block r={block} must be >= d and a multiple of d={d}"
        )


def quantize_int4(
    w: jnp.ndarray, block: int = 32, *, power_of_two: bool = False
) -> QuantizedTensor:
    """Symmetric row-block int4 quantization of a dense (m, k) matrix.

    ``power_of_two=True`` restricts scales to 2^e (MSFP12-like shared
    exponents, ref. [8] of the paper).
    """
    m, k = w.shape
    kp = -(-k // block) * block
    wp = jnp.pad(w.astype(jnp.float32), ((0, 0), (0, kp - k)))
    wb = wp.reshape(m, kp // block, block)
    amax = jnp.max(jnp.abs(wb), axis=-1)
    scale = amax / packing.INT4_MAX  # symmetric: amax -> ±7, no clip error
    if power_of_two:
        scale = jnp.exp2(jnp.ceil(jnp.log2(jnp.maximum(scale, 1e-30))))
    scale = jnp.where(amax == 0, 1.0, scale)
    q = jnp.clip(
        jnp.round(wb / scale[..., None]), packing.INT4_MIN, packing.INT4_MAX
    ).astype(jnp.int32)
    codes = packing.b_hat(q).reshape(m, kp)[:, :k]
    return QuantizedTensor(codes=codes, scales=scale, block=block, shape=(m, k))


def quantize_codebook(
    w: jnp.ndarray, codebook, block: int = 32
) -> QuantizedTensor:
    """Row-block quantization of (m, k) onto a 16-entry value ``codebook``.

    Scales use the same bounding-box normalization as :func:`quantize_int4`
    (``amax / 7``), so a codebook fit and the uniform grid are compared on
    identical scale grids; codes are nearest-entry assignments of the
    normalized values.  ``codebook[0]`` must be 0 so zero-padded columns
    (code 0) contribute nothing downstream.
    """
    m, k = w.shape
    kp = -(-k // block) * block
    wp = jnp.pad(w.astype(jnp.float32), ((0, 0), (0, kp - k)))
    wb = wp.reshape(m, kp // block, block)
    amax = jnp.max(jnp.abs(wb), axis=-1)
    scale = amax / packing.INT4_MAX
    scale = jnp.where(amax == 0, 1.0, scale)
    cb = jnp.asarray(codebook, jnp.float32)
    z = wb / scale[..., None]
    codes = jnp.argmin(jnp.abs(z[..., None] - cb), axis=-1).astype(jnp.uint8)
    return QuantizedTensor(codes=codes.reshape(m, kp)[:, :k], scales=scale,
                           block=block, shape=(m, k), codebook=cb)


def dequantize(qt: QuantizedTensor, dtype=jnp.float32) -> jnp.ndarray:
    """Reconstruct the dense matrix (the int4_dequant baseline path)."""
    m, k = qt.shape
    values = (packing.b_values(jnp.float32) if qt.codebook is None
              else jnp.asarray(qt.codebook, jnp.float32))
    vals = jnp.take(values, jnp.asarray(qt.codes, jnp.int32), axis=0)
    q = jnp.repeat(qt.scales, qt.block, axis=1)[:, :k]
    return (vals * q).astype(dtype)


def quantization_error(w: jnp.ndarray, qt: QuantizedTensor) -> jnp.ndarray:
    return jnp.max(jnp.abs(w - dequantize(qt, w.dtype)))


def weighted_quantization_error(
    w: jnp.ndarray, qt: QuantizedTensor, col_weights: jnp.ndarray | None = None
) -> jnp.ndarray:
    """Activation-aware reconstruction error: mean over rows of
    ``sum_j cw_j (w_ij - deq_ij)^2 / sum_j cw_j`` — the proxy for the
    layer-output MSE ``E||(W - Q)x||^2`` under diagonal input second
    moments ``cw_j = E[x_j^2]`` (repro.calib's fitting objective).
    """
    err = (w.astype(jnp.float32) - dequantize(qt, jnp.float32)) ** 2
    if col_weights is None:
        return jnp.mean(err)
    cw = jnp.asarray(col_weights, jnp.float32)
    cw = cw / jnp.maximum(jnp.sum(cw), 1e-30)
    return jnp.mean(jnp.sum(err * cw[None, :], axis=1))
