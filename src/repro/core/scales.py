"""Shared-scale (bounding-box) quantization — paper §3.3.

MSFP-style datatypes store int4 mantissas with a scale shared by a block of
elements.  §3.3's applicability rule for msGeMM:

* blocks laid out along a ROW of M with block size r, r >= d and ideally
  d | r  -> applicable (the scale factors out after consuming L);
* blocks laid out along a COLUMN of M -> NOT applicable (each LUT entry
  would need a different scale per row).

``quantize_int4`` produces the row-block format; ``check_applicable``
enforces the rule and is exercised by tests/test_scales.py.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import packing


class QuantizedTensor(NamedTuple):
    """Row-block int4 quantized matrix (m, k).

    codes:      (m, k) uint8, 4-bit codes (canonical)
    scales:     (m, k//block) float32, shared per row-block (§3.3)
    block:      scale block size r along k
    shape:      original (m, k)
    """

    codes: jnp.ndarray
    scales: jnp.ndarray
    block: int
    shape: tuple


def check_applicable(block: int, d: int, axis: str = "row") -> None:
    """§3.3 rule: row-blocked scales with d | r compose with msGeMM."""
    if axis != "row":
        raise ValueError(
            "§3.3: column-wise bounding boxes make msGeMM inapplicable "
            "(each LUT entry would need a per-row scale)"
        )
    if block < d or block % d != 0:
        raise ValueError(
            f"§3.3: scale block r={block} must be >= d and a multiple of d={d}"
        )


def quantize_int4(
    w: jnp.ndarray, block: int = 32, *, power_of_two: bool = False
) -> QuantizedTensor:
    """Symmetric row-block int4 quantization of a dense (m, k) matrix.

    ``power_of_two=True`` restricts scales to 2^e (MSFP12-like shared
    exponents, ref. [8] of the paper).
    """
    m, k = w.shape
    kp = -(-k // block) * block
    wp = jnp.pad(w.astype(jnp.float32), ((0, 0), (0, kp - k)))
    wb = wp.reshape(m, kp // block, block)
    amax = jnp.max(jnp.abs(wb), axis=-1)
    scale = amax / packing.INT4_MAX  # symmetric: amax -> ±7, no clip error
    if power_of_two:
        scale = jnp.exp2(jnp.ceil(jnp.log2(jnp.maximum(scale, 1e-30))))
    scale = jnp.where(amax == 0, 1.0, scale)
    q = jnp.clip(
        jnp.round(wb / scale[..., None]), packing.INT4_MIN, packing.INT4_MAX
    ).astype(jnp.int32)
    codes = packing.b_hat(q).reshape(m, kp)[:, :k]
    return QuantizedTensor(codes=codes, scales=scale, block=block, shape=(m, k))


def dequantize(qt: QuantizedTensor, dtype=jnp.float32) -> jnp.ndarray:
    """Reconstruct the dense matrix (the int4_dequant baseline path)."""
    m, k = qt.shape
    vals = packing.b_values(jnp.float32)[jnp.asarray(qt.codes, jnp.int32)]
    q = jnp.repeat(qt.scales, qt.block, axis=1)[:, :k]
    return (vals * q).astype(dtype)


def quantization_error(w: jnp.ndarray, qt: QuantizedTensor) -> jnp.ndarray:
    return jnp.max(jnp.abs(w - dequantize(qt, w.dtype)))
