"""int4 code packing for msGeMM (paper §3.1–3.2).

The paper stores the weight matrix M in int4. Code <-> value mapping is the
two's-complement map ``b`` of §3.1::

    b(0b0000)=0, b(0b0001)=1, ..., b(0b0111)=7, b(0b1000)=-8, ..., b(0b1111)=-1

and its inverse ``b_hat`` (§3.2).  ``d`` consecutive 4-bit codes of a row of
M concatenate into one look-up index ("d concatenated int4 together to form
an int4d which can be used directly to dereference ... L" — §4).  We keep
three representations:

* ``codes``      uint8, one 4-bit code per element, shape (m, k)   — canonical
* ``packed_u8``  uint8, two codes per byte, shape (m, ceil(k/2))   — storage
* ``packed_idx`` int32, one LUT index per d-chunk, (m, ceil(k/d))  — consume

``packed_idx`` is layout-compatible with the flattened LUT: index =
sum_r code[j*d + r] * 16**(d-1-r) (big-endian within the chunk), matching
``lut.tuple_basis``.  k is zero-padded to a multiple of d with code 0
(b(0)=0, so padding contributes nothing regardless of the activations —
paper footnote 2 assumes d | k; padding removes the assumption).
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

INT4_MIN = -8
INT4_MAX = 7
NLEVELS = 16


def b_values(dtype=jnp.float32) -> jnp.ndarray:
    """The table b: code (0..15) -> int4 value (§3.1)."""
    v = np.arange(NLEVELS)
    v = np.where(v <= INT4_MAX, v, v - NLEVELS)  # two's complement
    return jnp.asarray(v, dtype=dtype)


def b_hat(values: jnp.ndarray) -> jnp.ndarray:
    """Inverse map b_hat: int4 value -> 4-bit code (§3.2), e.g. -1 -> 0b1111."""
    v = jnp.asarray(values, jnp.int32)
    return jnp.where(v >= 0, v, v + NLEVELS).astype(jnp.uint8)


def check_int4(values) -> None:
    v = np.asarray(values)
    if v.size and (v.min() < INT4_MIN or v.max() > INT4_MAX):
        raise ValueError(f"values outside int4 range [{INT4_MIN},{INT4_MAX}]")


def pad_k(arr: jnp.ndarray, d: int, axis: int = -1, value=0) -> jnp.ndarray:
    """Zero-pad ``axis`` up to a multiple of d (code 0 == value 0)."""
    k = arr.shape[axis]
    rem = (-k) % d
    if rem == 0:
        return arr
    pads = [(0, 0)] * arr.ndim
    pads[axis] = (0, rem)
    return jnp.pad(arr, pads, constant_values=value)


def pack_storage(codes: jnp.ndarray) -> jnp.ndarray:
    """codes (m, k) uint8 -> packed bytes (m, ceil(k/2)); hi nibble first."""
    c = pad_k(jnp.asarray(codes, jnp.uint8), 2)
    hi, lo = c[..., 0::2], c[..., 1::2]
    return (hi << 4 | lo).astype(jnp.uint8)


def unpack_storage(packed: jnp.ndarray, k: int) -> jnp.ndarray:
    """Inverse of :func:`pack_storage`."""
    hi = (packed >> 4) & 0xF
    lo = packed & 0xF
    c = jnp.stack([hi, lo], axis=-1).reshape(*packed.shape[:-1], -1)
    return c[..., :k].astype(jnp.uint8)


def pack_indices(codes: jnp.ndarray, d: int) -> jnp.ndarray:
    """codes (m, k) -> LUT indices (m, ceil(k/d)) int32 (big-endian chunks).

    This is the zero-cost indexing of §4: the 4·d-bit concatenation of d
    consecutive codes *is* the flat LUT index.
    """
    c = pad_k(jnp.asarray(codes, jnp.int32), d)
    m = c.shape[:-1]
    c = c.reshape(*m, -1, d)
    weights = NLEVELS ** jnp.arange(d - 1, -1, -1, dtype=jnp.int32)
    return jnp.sum(c * weights, axis=-1, dtype=jnp.int32)


def unpack_indices(idx: jnp.ndarray, d: int, k: int) -> jnp.ndarray:
    """Inverse of :func:`pack_indices` (drops the zero padding)."""
    idx = jnp.asarray(idx, jnp.int32)[..., :, None]
    shifts = 4 * jnp.arange(d - 1, -1, -1, dtype=jnp.int32)
    c = (idx >> shifts) & 0xF
    c = c.reshape(*idx.shape[:-2], -1)
    return c[..., :k].astype(jnp.uint8)


def indices_from_storage(packed_u8: jnp.ndarray, d: int, k: int) -> jnp.ndarray:
    """On-the-fly index construction from the 2-codes/byte storage format.

    For d=2 with aligned chunks this is the identity (the byte *is* the LUT
    index) — the TPU fast path.  For other d we unpack and repack.
    """
    if d == 2:
        return packed_u8[..., : (k + 1) // 2].astype(jnp.int32)
    return pack_indices(unpack_storage(packed_u8, k), d)
