"""repro.core — the paper's contribution: msGeMM (LUT-based low-precision GeMM).

Public API:
    packing     int4 code <-> value maps, storage/LUT-index packing
    lut         produce / consume / msgemm (lowerable jnp formulation)
    scales      row-block shared-scale quantization (§3.3)
    complexity  Eqs. 7-15 analytic model + instrumented op counting
    spec        QuantSpec — frozen weight-representation description
    linear      QuantizedLinear — the framework integration point
                (execution is planned by repro.dispatch)
"""

from repro.core import complexity, linear, lut, packing, scales, spec  # noqa: F401
from repro.core.linear import DENSE, QuantConfig  # noqa: F401
from repro.core.spec import QuantSpec, as_spec  # noqa: F401
from repro.core.lut import msgemm, msgemm_reference, produce, consume  # noqa: F401
from repro.core.scales import (  # noqa: F401
    quantize_int4, quantize_codebook, dequantize, QuantizedTensor,
    weighted_quantization_error,
)
