"""QuantSpec — a frozen description of *what the weights are*.

The execution API splits into three layers (the EmuGEMM-style
front-end/back-end separation the multi-backend roadmap needs):

1. ``QuantSpec`` (this module) — weight **representation** only: quant
   mode, LUT depth d, §3.3 scale-block size, storage layout, codebook
   policy.  It says nothing about *how* a GeMM runs.
2. ``repro.dispatch`` — the backend registry: the dense MXU path, the
   jnp produce/consume msGeMM, the fused Pallas msGeMM and the
   int4-dequant kernels register as peers with capability predicates.
3. ``repro.dispatch.plan(spec, m, k, batch) -> ExecPlan`` — a frozen,
   hashable *physical* execution choice (backend + tiles + chunking),
   produced by the shape heuristic or the persistent autotuner.

``core.linear.QuantConfig`` survives as a deprecated shim that splits
itself into ``.spec`` (a QuantSpec) + ``.policy`` (a dispatch.ExecPolicy)
so every existing call site keeps working unchanged.
"""

from __future__ import annotations

from dataclasses import dataclass, replace

from repro.core import scales

MODES = ("bf16", "int4_dequant", "msgemm")
STORAGES = ("packed_idx", "packed_u8")
CODEBOOKS = ("none", "learned")


@dataclass(frozen=True)
class QuantSpec:
    """Frozen weight-representation description (no execution choices).

    mode : ``bf16`` dense weights | ``int4_dequant`` | ``msgemm``
    d : LUT depth — an int in [1, 4], or ``'adaptive'`` to pick the
        per-linear argmax of Eq. 15 from the static (out, in) dims.
    scale_block : §3.3 shared-scale row-block size; 0 resolves to 12·d
        (a multiple of every d in 2..4).
    storage : ``packed_idx`` (int32 LUT indices, 4·d bits -> 32 bits per
        chunk) | ``packed_u8`` (true int4, 2 codes/byte).
    codebook : ``none`` (uniform int4 grid) | ``learned`` (16-entry value
        table leaf, fitted by repro.calib).
    """

    mode: str = "bf16"
    d: int | str = 3
    scale_block: int = 0
    storage: str = "packed_idx"
    codebook: str = "none"

    def __post_init__(self):
        # Eager validation: every representation invariant the quantized
        # paths rely on is checked at construction instead of surfacing
        # as a shape error deep inside consume()/the Pallas kernel.
        if self.mode not in MODES:
            raise ValueError(f"unknown quant mode {self.mode!r}; one of {MODES}")
        if self.storage not in STORAGES:
            raise ValueError(
                f"unknown storage {self.storage!r}; one of {STORAGES}")
        if self.codebook not in CODEBOOKS:
            raise ValueError(
                f"unknown codebook policy {self.codebook!r}; one of {CODEBOOKS}")
        if self.d != "adaptive":
            if not isinstance(self.d, int) or not 1 <= self.d <= 4:
                raise ValueError(
                    f"LUT depth d={self.d!r} must be 'adaptive' or an int in "
                    "[1, 4] (the 16^d LUT is produced in full)")
        if self.scale_block < 0:
            raise ValueError(f"scale_block={self.scale_block} must be >= 0")
        if self.d != "adaptive" and self.scale_block == 0:
            object.__setattr__(self, "scale_block", 12 * int(self.d))
        elif self.scale_block == 0:
            object.__setattr__(self, "scale_block", 12)
        if self.mode == "msgemm":
            # §3.3 applicability — for adaptive d the block must compose
            # with the smallest candidate depth (resolve_d only shrinks d
            # until it divides the block, so d=2 is the floor).
            scales.check_applicable(
                self.scale_block, 2 if self.d == "adaptive" else int(self.d))

    def resolve_d(self, in_dim: int, out_dim: int) -> int:
        """The depth this linear actually uses (static in the shapes)."""
        if self.d != "adaptive":
            return int(self.d)
        from repro.core import complexity

        d_star, _ = complexity.best_d(out_dim, in_dim, range(2, 5))
        # the shared scale block must stay a multiple of d (§3.3)
        while self.scale_block % d_star:
            d_star -= 1
        return max(d_star, 2)

    def with_mode(self, mode: str) -> "QuantSpec":
        return replace(self, mode=mode)


DENSE = QuantSpec(mode="bf16")


def as_spec(cfg) -> QuantSpec:
    """Coerce a QuantSpec or a (deprecated) QuantConfig to a QuantSpec."""
    if isinstance(cfg, QuantSpec):
        return cfg
    spec = getattr(cfg, "spec", None)
    if isinstance(spec, QuantSpec):
        return spec
    raise TypeError(f"expected QuantSpec or QuantConfig, got {type(cfg)!r}")
