"""msGeMM look-up-table production and consumption (paper §3).

Produce (§3.1):  ``L[i0..i_{d-1}, j] = sum_r b(i_r) * x(j*d + r)``  — all
possible linear combinations of d consecutive activations with int4
coefficients.  TPU adaptation (DESIGN.md §2.A): flattening the d index dims,
this is one dense matmul ``L = B_d @ x_chunks`` with ``B_d (16^d, d)`` the
tuple-basis matrix — i.e. phase 1 runs on the MXU.

Consume (§3.2, Eq. 5): ``y(i) = sum_j L[packed_idx(i, j), j]`` — k/d table
adds per output element instead of k FMAs.

Shapes here follow the paper: ``x`` is (k, b) column-activations, ``y`` is
(m, b).  ``core.linear`` adapts to the row-major (..., features) activation
convention used by the models.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import packing


@functools.lru_cache(maxsize=8)
def _tuple_codes_np(d: int):
    import numpy as np

    n = packing.NLEVELS**d
    idx = np.arange(n)
    cols = []
    for r in range(d):
        shift = 4 * (d - 1 - r)
        cols.append((idx >> shift) & 0xF)
    return np.stack(cols, axis=1)  # (16^d, d) codes, big-endian


@functools.lru_cache(maxsize=8)
def _tuple_basis_np(d: int):
    import numpy as np

    codes = _tuple_codes_np(d)
    vals = np.where(codes <= packing.INT4_MAX, codes, codes - packing.NLEVELS)
    return vals.astype(np.float32)


def tuple_basis(d: int, dtype=jnp.float32, *, codebook=None) -> jnp.ndarray:
    """C_d (16^d, d): row ``i`` holds (C(i_0), ..., C(i_{d-1})) for flat index i.

    ``codebook`` is an optional (16,) value table replacing the uniform
    two's-complement map ``b`` — nothing in Eq. 5 requires the 16 levels
    to be the int4 grid, so an arbitrary learned codebook (repro.calib)
    rides through produce/consume at zero extra cost.  ``codebook[0]``
    must be 0 (code 0 is the k-padding code; see core.packing).
    """
    if codebook is None:
        return jnp.asarray(_tuple_basis_np(d), dtype=dtype)
    cb = jnp.asarray(codebook, dtype)
    return jnp.take(cb, jnp.asarray(_tuple_codes_np(d), jnp.int32), axis=0)


def produce(x: jnp.ndarray, d: int, *, dtype=None, codebook=None) -> jnp.ndarray:
    """Phase 1.  x (k, b) -> L (16^d, k/d, b).

    Equivalent to Eq. 3, evaluated as the single matmul C_d @ x_chunks
    (MXU-native).  Cost: 16^d * k * b FMAs == C(L)·b of Eq. 7 — identical
    for the uniform int4 basis and a learned ``codebook`` basis.
    """
    if x.ndim == 1:
        x = x[:, None]
    k, b = x.shape
    xp = packing.pad_k(x, d, axis=0)
    kc = xp.shape[0] // d
    x_chunks = xp.reshape(kc, d, b)  # (k/d, d, b)
    basis = tuple_basis(d, dtype=dtype or x.dtype, codebook=codebook)
    # (16^d, d) @ (d, k/d * b) -> (16^d, k/d, b)
    lut = jax.lax.dot_general(
        basis,
        x_chunks,
        ((((1,), (1,)), ((), ()))),
        preferred_element_type=dtype or jnp.promote_types(x.dtype, jnp.float32),
    )
    return lut  # (16^d, k/d, b)


def consume(
    lut: jnp.ndarray,
    packed_idx: jnp.ndarray,
    *,
    scales: jnp.ndarray | None = None,
    scale_block: int | None = None,
    d: int | None = None,
    chunk: int = 1,
) -> jnp.ndarray:
    """Phase 2 (Eq. 5).  lut (16^d, k/d, b), packed_idx (m, k/d) -> y (m, b).

    Pure-jnp formulation that lowers for the at-scale dry-runs: a
    ``lax.scan`` over j-chunks, each step gathering (m, b) rows from the
    current LUT slab and accumulating — HLO stays compact regardless of k.

    ``scales``/``scale_block`` implement §3.3 row-block shared scales:
    chunk j belongs to scale block (j*d)//scale_block, applied per chunk
    (same result as the factored form; the Pallas kernel factors it).
    """
    n, kc, b = lut.shape
    m = packed_idx.shape[0]
    if scales is not None:
        if d is None or scale_block is None:
            raise ValueError("scales require d and scale_block")
        if scale_block % d != 0:
            raise ValueError(
                f"§3.3: msGeMM needs scale blocks aligned to d (block={scale_block}, d={d})"
            )
    nsteps = (kc + chunk - 1) // chunk
    pad = nsteps * chunk - kc
    if pad:
        lut = jnp.pad(lut, ((0, 0), (0, pad), (0, 0)))
        packed_idx = jnp.pad(packed_idx, ((0, 0), (0, pad)))
    # (steps, chunk, ...) leading-axis layout for scan
    lut_s = jnp.moveaxis(lut.reshape(n, nsteps, chunk, b), 1, 0)
    idx_s = jnp.moveaxis(packed_idx.reshape(m, nsteps, chunk), 1, 0)
    if scales is not None:
        cpd = scale_block // d  # chunks per scale block
        jidx = jnp.arange(nsteps * chunk) // cpd
        jidx = jnp.minimum(jidx, scales.shape[1] - 1).reshape(nsteps, chunk)
        q_s = scales[:, jidx]  # (m, steps, chunk)
        q_s = jnp.moveaxis(q_s, 1, 0)  # (steps, m, chunk)
    else:
        q_s = jnp.zeros((nsteps, 0, 0), lut.dtype)

    def step(acc, args):
        lut_j, idx_j, q_j = args  # (n, chunk, b), (m, chunk), (m, chunk)
        lut_cj = jnp.moveaxis(lut_j, 1, 0)  # (chunk, n, b)
        g = jax.vmap(lambda t, i: jnp.take(t, i, axis=0))(lut_cj, idx_j.T)
        if scales is not None:  # g: (chunk, m, b)
            g = g * q_j.T[..., None]
        return acc + jnp.sum(g, axis=0, dtype=acc.dtype), None

    acc0 = jnp.zeros((m, b), lut.dtype)
    y, _ = jax.lax.scan(step, acc0, (lut_s, idx_s, q_s))
    return y


def msgemm(
    codes: jnp.ndarray,
    x: jnp.ndarray,
    d: int,
    *,
    scales: jnp.ndarray | None = None,
    scale_block: int | None = None,
    chunk: int = 1,
    dtype=None,
    codebook=None,
) -> jnp.ndarray:
    """Full two-phase msGeMM: y = dequant(codes) @ x (paper Eq. 1/5).

    codes (m, k) uint8 4-bit codes; x (k, b) or (k,).  Returns (m, b)/(m,).
    ``codebook``: optional (16,) learned value table (uniform int4 when None).
    """
    squeeze = x.ndim == 1
    if squeeze:
        x = x[:, None]
    lut = produce(x, d, dtype=dtype, codebook=codebook)
    idx = packing.pack_indices(codes, d)
    y = consume(lut, idx, scales=scales, scale_block=scale_block, d=d, chunk=chunk)
    return y[:, 0] if squeeze else y


def msgemm_reference(codes, x, d, *, scales=None, scale_block=None,
                     codebook=None):
    """Naive O(m·k·b) oracle: dequantize then dense matmul (paper Eq. 14 path)."""
    squeeze = x.ndim == 1
    if squeeze:
        x = x[:, None]
    values = (packing.b_values(x.dtype) if codebook is None
              else jnp.asarray(codebook, x.dtype))
    w = values[jnp.asarray(codes, jnp.int32)]  # (m, k)
    if scales is not None:
        q = jnp.repeat(scales, scale_block, axis=1)[:, : w.shape[1]]
        w = w * q
    y = w.astype(jnp.float32) @ x.astype(jnp.float32)
    return (y[:, 0] if squeeze else y).astype(x.dtype if x.dtype == jnp.float64 else jnp.float32)
