"""Epilogue — the element-wise tail fused into a quantized GeMM.

EmuGEMM's observation (PAPERS.md) is that once the GeMM itself is fast,
the remaining wall time hides in the element-wise ops issued *around* it:
bias add, activation, residual add, output cast.  Each of those is an
extra HBM round trip over the (..., m) output.  An :class:`Epilogue`
describes that tail declaratively so a kernel backend can execute it
inside its final VMEM writeback (kernels/msgemm.py, kernels/int4_matmul.py)
while non-fusing backends fall back to :func:`apply_epilogue` — the exact
same math as separate jnp ops, so fused and unfused paths agree.

The op order is fixed and identical in both implementations::

    y = act(acc + bias) + residual      # then cast to out_dtype

which is the transformer convention (bias before activation, residual
after).  ``Epilogue()`` is the identity: backends must produce bit-
identical results to a no-epilogue call for it.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

ACTIVATIONS = ("none", "relu", "gelu", "silu")


def _act_fn(name: str):
    return {"none": lambda v: v, "relu": jax.nn.relu,
            "gelu": jax.nn.gelu, "silu": jax.nn.silu}[name]


@dataclass(frozen=True)
class Epilogue:
    """Frozen, hashable description of the fused element-wise tail.

    act : activation applied after the bias add — one of
        ``none | relu | gelu | silu``.
    bias : whether a per-output-row bias vector (m,) is added to the
        accumulator before the activation.
    residual : whether a residual tensor (shaped like the output) is
        added after the activation.
    out_dtype : output dtype name (e.g. ``'bfloat16'``); None keeps the
        accumulation dtype.

    Hashable so it rides through ``jax.jit`` as static closure state and
    can key backend capability checks (registry.supports_epilogue).
    """

    act: str = "none"
    bias: bool = False
    residual: bool = False
    out_dtype: str | None = None

    def __post_init__(self):
        if self.act not in ACTIVATIONS:
            raise ValueError(
                f"unknown epilogue activation {self.act!r}; "
                f"one of {ACTIVATIONS}")
        if self.out_dtype is not None:
            jnp.dtype(self.out_dtype)  # eager validation

    @property
    def is_identity(self) -> bool:
        return (self.act == "none" and not self.bias and not self.residual
                and self.out_dtype is None)

    def act_fn(self):
        return _act_fn(self.act)


IDENTITY = Epilogue()


def apply_epilogue(y: jnp.ndarray, ep: Epilogue | None,
                   bias: jnp.ndarray | None = None,
                   residual: jnp.ndarray | None = None) -> jnp.ndarray:
    """Unfused fallback path: y (..., m) row-major model layout.

    Used by backends that cannot fuse (dense / jnp paths).  The tail is
    computed at float32-or-better — matching the fused kernels, which run
    it on the f32 VMEM accumulator — then cast back.  For f32 models the
    two routes are the same ops on the same values; for low-precision
    activations they can differ by final-rounding ulps (the unfused route
    sees the GeMM output after its cast to the activation dtype, the
    fused route sees the un-rounded accumulator).
    """
    if ep is None or ep.is_identity:
        return y
    in_dtype = y.dtype
    compute = jnp.promote_types(in_dtype, jnp.float32)
    y = y.astype(compute)
    if ep.bias:
        if bias is None:
            raise ValueError("Epilogue.bias set but no bias array given")
        y = y + bias.astype(compute)
    y = ep.act_fn()(y)
    if ep.residual:
        if residual is None:
            raise ValueError(
                "Epilogue.residual set but no residual array given")
        y = y + residual.astype(compute)
    return y.astype(ep.out_dtype if ep.out_dtype is not None else in_dtype)
