"""Analytic cost model of msGeMM — paper §4 & §5, Eqs. 7–15.

Plus an *instrumented* executable model (`counted_msgemm`) that runs the
algorithm with explicit loops on small inputs and counts every FMA / add /
memory access, so tests can verify the closed-form formulas against actual
operation counts (benchmarks/complexity_table.py reports both).
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

NLEVELS = 16


# --------------------------------------------------------------------------
# Closed forms (paper equations)
# --------------------------------------------------------------------------

def c_lut(k: int, d: int) -> int:
    """Eq. 7: C(L) = 2^{4d} * k  (FMAs, per batch column)."""
    return NLEVELS**d * k


def m_lut(k: int) -> int:
    """Eq. 8: memory accesses to build L = reads of x."""
    return k


def c_consume(m: int, k: int, d: int) -> int:
    """Eq. 9: C(y) = (k/d - 1) * m  (adds, per batch column)."""
    return (k // d - 1) * m


def m_consume(m: int, k: int) -> int:
    """Eq. 10: reads of M."""
    return m * k


def c_msgemm(m: int, k: int, b: int = 1, d: int = 3) -> int:
    """Eq. 13: total msGeMM ops for an m×k×b GeMM."""
    return (c_lut(k, d) + c_consume(m, k, d)) * b


def c_gemm(m: int, k: int, b: int = 1) -> int:
    """Eq. 14: naive GeMM FMAs (rounded up to m·k·b, see §4 footnote 3)."""
    return m * k * b


def m_msgemm(m: int, k: int, b: int = 1) -> int:
    """Eq. 12 (× batch, §4.2): identical to naive GeMM memory traffic."""
    return k * b + m * k


def m_gemm(m: int, k: int, b: int = 1) -> int:
    return k * b + m * k


def speedup(m: int, k: int, b: int = 1, d: int = 3) -> float:
    """Eq. 15: C(GeMM) / C(msGeMM)."""
    return c_gemm(m, k, b) / c_msgemm(m, k, b, d)


def best_d(m: int, k: int, d_range=range(1, 7)) -> tuple[int, float]:
    """Sweep d (Fig. 3) and return (argmax_d, max speedup)."""
    s = {d: speedup(m, k, 1, d) for d in d_range if d <= 8}
    d_star = max(s, key=s.get)
    return d_star, s[d_star]


def lut_bytes(k: int, d: int, b: int, itemsize: int = 4) -> int:
    """Transient LUT footprint — the VMEM budget driver for the kernel."""
    return NLEVELS**d * (-(-k // d)) * b * itemsize


# --------------------------------------------------------------------------
# Instrumented execution (ground truth for the formulas)
# --------------------------------------------------------------------------

@dataclass
class OpCounts:
    fma: int = 0        # fused multiply-adds (produce phase)
    add: int = 0        # table adds (consume phase)
    mem: int = 0        # memory accesses (x reads + M reads)

    @property
    def total_compute(self) -> int:
        return self.fma + self.add


def counted_msgemm(codes: np.ndarray, x: np.ndarray, d: int):
    """Run msGeMM with explicit loops, counting ops per the paper's rules.

    Counting conventions follow §4 exactly: each LUT entry costs d FMAs
    (rounded up from d-1 adds + d muls); each y element costs k/d - 1 adds;
    indexing via code concatenation is free; L reads are cache hits (§4:
    "we assume that L ... is kept in cache").
    """
    m, k = codes.shape
    assert k % d == 0, "counted model follows the paper's d | k assumption"
    b = 1 if x.ndim == 1 else x.shape[1]
    xm = x.reshape(k, b).astype(np.float64)
    vals = np.where(np.arange(NLEVELS) <= 7, np.arange(NLEVELS), np.arange(NLEVELS) - 16)

    counts = OpCounts()
    kc = k // d
    n = NLEVELS**d
    lut = np.zeros((n, kc, b))
    # ---- produce (Eq. 2/3) ----
    counts.mem += k * b  # reads of x (Eq. 8, × batch)
    basis = np.zeros((n, d))
    for i in range(n):
        for r in range(d):
            basis[i, r] = vals[(i >> (4 * (d - 1 - r))) & 0xF]
    for i in range(n):
        for j in range(kc):
            for col in range(b):
                acc = 0.0
                for r in range(d):
                    acc += basis[i, r] * xm[j * d + r, col]
                    counts.fma += 1  # d FMAs per entry (§4 rounding)
                lut[i, j, col] = acc
    # ---- consume (Eq. 5) ----
    counts.mem += m * k  # reads of M (Eq. 10)
    y = np.zeros((m, b))
    for i in range(m):
        for col in range(b):
            idx0 = 0
            for r in range(d):
                idx0 = idx0 * NLEVELS + int(codes[i, r])
            acc = lut[idx0, 0, col]  # first lookup: no add yet
            for j in range(1, kc):
                idx = 0
                for r in range(d):
                    idx = idx * NLEVELS + int(codes[i, j * d + r])
                acc += lut[idx, j, col]
                counts.add += 1  # (k/d - 1) adds per element (Eq. 9)
            y[i, col] = acc
    return (y[:, 0] if x.ndim == 1 else y), counts


def counted_gemm(w: np.ndarray, x: np.ndarray):
    """Naive GeMM with §4's counting (m·k·b FMAs, k·b + m·k accesses)."""
    m, k = w.shape
    b = 1 if x.ndim == 1 else x.shape[1]
    counts = OpCounts(fma=m * k * b, add=0, mem=k * b + m * k)
    y = w.astype(np.float64) @ x.reshape(k, b).astype(np.float64)
    return (y[:, 0] if x.ndim == 1 else y), counts
