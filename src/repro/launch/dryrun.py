import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape)
cell on the production meshes — 16x16 (single pod, 256 chips) and
2x16x16 (two pods, 512 chips) — and record memory/cost/collective
artifacts for the roofline analysis (EXPERIMENTS.md §Dry-run/§Roofline).

No tensor is ever allocated at full scale: inputs and state are
ShapeDtypeStructs; the deliverable is that ``.lower().compile()``
succeeds (sharding coherent, memory fits) for all cells.

Usage:
    python -m repro.launch.dryrun --arch gemma_2b --shape train_4k
    python -m repro.launch.dryrun --all --mesh both
    python -m repro.launch.dryrun --all --mesh single --quant msgemm
"""

import argparse
import functools
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.configs import shapes as shp
from repro.core.spec import QuantSpec
from repro.distributed import sharding as shd
from repro.launch.mesh import make_production_mesh, mesh_devices
from repro.models import transformer as T
from repro.optim import AdamWConfig
from repro.runtime import train as RT

RESULTS_DIR = os.path.join(os.path.dirname(__file__),
                           "../../../benchmarks/results/dryrun")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

# Per-arch train-cell memory policy (DESIGN.md §4: 2.4TB llama4 train state).
TRAIN_OVERRIDES = {
    "llama4_maverick": {"param_dtype": "bfloat16", "opt_dtype": "bfloat16",
                        "grad_dtype": "bfloat16", "microbatches": 8},
    "jamba_v01": {"microbatches": 8},
}
_SHAPE_RE = re.compile(
    r"(\w+)\[([\d,]*)\].* (all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\(")
_DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
                "u8": 1, "pred": 1, "f64": 8, "s64": 8, "u64": 8, "s16": 2,
                "u16": 2, "f8e4m3fn": 1, "f8e5m2": 1}


def parse_collectives(hlo_text: str) -> dict:
    """Inventory of collective ops: per kind, op count + result bytes
    (per-device partitioned shapes, scan bodies counted once — the
    analytic roofline model supplies trip-count weighting)."""
    out = {k: {"count": 0, "bytes": 0} for k in COLLECTIVES}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = _SHAPE_RE.search(line)
        if not m:
            continue
        dtype, dims, kind = m.group(1), m.group(2), m.group(3)
        if line.startswith("ROOT"):
            line = line[5:]
        # only count the defining op, not operands
        nbytes = _DTYPE_BYTES.get(dtype, 4)
        for d in dims.split(","):
            if d:
                nbytes *= int(d)
        out[kind]["count"] += 1
        out[kind]["bytes"] += nbytes
    return out


def serve_quant_config(mode: str, d=None) -> QuantSpec:
    if mode == "bf16":
        return QuantSpec(mode="bf16")
    env_d = os.environ.get("DRYRUN_D", "3")  # §Perf B/C lever
    d = d or ("adaptive" if env_d == "adaptive" else int(env_d))
    storage = os.environ.get("DRYRUN_STORAGE", "packed_idx")
    return QuantSpec(mode=mode, d=d,
                     scale_block=12 if d == "adaptive" else 12 * d,
                     storage=storage)


def _key_sds():
    return jax.eval_shape(lambda: jax.random.PRNGKey(0))


def build_cell(arch: str, shape_name: str, quant: str):
    """Returns (fn, args_sds, in_specs_builder, label) for the cell."""
    base = configs.get_config(arch)
    shape = shp.SHAPES[shape_name]
    ok, reason = shp.applicable(base, shape_name)
    if not ok:
        return None, reason

    if shape.kind == "train":
        cfg = base  # training is bf16-dense (quantized weights don't train)
        # gradient accumulation keeps per-microbatch activations (incl. the
        # (tokens, vocab) logits block) inside v5e HBM; 4 microbatches
        # => 64k tokens per microbatch at train_4k.  The 400B MoE also
        # needs bf16 params + bf16 Adam state to fit 256 chips (2.4 TB
        # train state; f32 Adam alone would be 4.8 TB > 4 TB pod HBM).
        ov = TRAIN_OVERRIDES.get(arch, {})
        cfg = cfg.replace(**{k: v for k, v in ov.items()
                             if k in ("param_dtype",)})
        if os.environ.get("DRYRUN_INT8_GATHER"):  # §Perf A lever
            cfg = cfg.replace(fsdp_int8_gather=True)
        if os.environ.get("DRYRUN_SAVE_GATHERED"):  # §Perf A lever
            cfg = cfg.replace(save_gathered_weights=True)
        if os.environ.get("DRYRUN_REMAT_POLICY"):  # §Perf A4 lever
            cfg = cfg.replace(
                remat_policy=os.environ["DRYRUN_REMAT_POLICY"])
        tcfg = RT.TrainConfig(
            optimizer=AdamWConfig(state_dtype=ov.get("opt_dtype", "float32")),
            grad_accum_dtype=ov.get("grad_dtype", "float32"),
            microbatches=int(os.environ.get(
                "DRYRUN_MICROBATCHES", str(ov.get("microbatches", 4)))))
        state_sds = jax.eval_shape(
            functools.partial(RT.init_state, cfg=cfg, tcfg=tcfg), _key_sds())
        batch_sds = shp.input_specs(cfg, shape_name)
        fn = functools.partial(RT.train_step, cfg=cfg, tcfg=tcfg)

        def specs(mesh, rules):
            st = shd.param_specs(state_sds, mesh, rules)
            bt = shd.batch_specs(batch_sds, mesh, rules)
            return (st, bt), (st, None)

        return (fn, (state_sds, batch_sds), specs, cfg), None

    qc = serve_quant_config(quant)
    cfg = base.replace(quant=qc) if quant != "bf16" else base
    params_sds = jax.eval_shape(
        functools.partial(T.init_params, cfg=cfg), _key_sds())
    inputs = shp.input_specs(cfg, shape_name)

    if shape.kind == "prefill":
        cache = None  # prefill cell lowers the forward over the prompt
        fn = functools.partial(_prefill_forward, cfg=cfg)
        args = (params_sds, inputs)

        def specs(mesh, rules):
            ps = shd.param_specs(params_sds, mesh, rules)
            bs = shd.batch_specs(inputs, mesh, rules)
            return (ps, bs), None

        return (fn, args, specs, cfg), None

    # decode: one token against a seq_len-deep cache
    cache_dt = {"bf16": jnp.bfloat16, "f32": jnp.float32,
                "f8": jnp.float8_e4m3fn}[
        os.environ.get("DRYRUN_CACHE_DTYPE", "bf16")]  # §Perf B lever
    inputs = shp.input_specs(cfg, shape_name, cache_dtype=cache_dt) \
        if shape.kind == "decode" else inputs
    fn = functools.partial(_decode, cfg=cfg)
    args = (params_sds, inputs["token"], inputs["cache"], inputs["pos"])

    def specs(mesh, rules):
        ps = shd.param_specs(params_sds, mesh, rules)
        cs = shd.cache_specs(inputs["cache"], mesh, rules)
        ts = shd.batch_specs({"token": inputs["token"]}, mesh, rules)["token"]
        pos_s = ts
        logits_s = None
        return (ps, ts, cs, pos_s), (logits_s, cs)

    return (fn, args, specs, cfg), None


def _prefill_forward(params, batch, *, cfg):
    logits, _ = T.forward(params, cfg, batch, mode="prefill")
    return logits[:, -1]


def _decode(params, token, cache, pos, *, cfg):
    return T.decode_step(params, cfg, token, cache, pos)


def to_shardings(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s if s is not None else P()),
        spec_tree, is_leaf=lambda s: isinstance(s, P) or s is None)


def run_cell(arch: str, shape_name: str, *, multi_pod: bool, quant: str,
             rules: str = "default", verbose: bool = True) -> dict:
    label = f"{arch}/{shape_name}/{'multi' if multi_pod else 'single'}/{quant}"
    built, reason = build_cell(arch, shape_name, quant)
    if built is None:
        return {"cell": label, "status": "skipped", "reason": reason}
    fn, args, specs_builder, cfg = built
    mesh = make_production_mesh(multi_pod=multi_pod)
    shape = shp.SHAPES[shape_name]
    t0 = time.time()
    with shd.use(mesh, rules):
        in_specs, out_specs = specs_builder(mesh, rules)
        # donate the train state / decode cache (standard in-place update;
        # without it memory_analysis double-counts state as out + temps)
        donate = {"train": (0,), "prefill": (), "decode": (2,)}[shape.kind]
        jf = jax.jit(fn, in_shardings=to_shardings(mesh, in_specs),
                     out_shardings=(to_shardings(mesh, out_specs)
                                    if out_specs is not None else None),
                     donate_argnums=donate)
        lowered = jf.lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):  # older jax: one dict per computation
        ca = ca[0] if ca else {}
    hlo = compiled.as_text()
    coll = parse_collectives(hlo)
    n_dev = mesh_devices(mesh)
    result = {
        "cell": label,
        "status": "ok",
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "devices": n_dev,
        "quant": quant,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes_per_device": ma.argument_size_in_bytes,
            "output_bytes_per_device": ma.output_size_in_bytes,
            "temp_bytes_per_device": ma.temp_size_in_bytes,
            "code_bytes": ma.generated_code_size_in_bytes,
            "total_per_device_gb": round(
                (ma.argument_size_in_bytes + ma.temp_size_in_bytes) / 2**30,
                3),
        },
        "cost_analysis": {
            "flops_per_device_hlo": ca.get("flops", 0.0),
            "bytes_accessed_per_device_hlo": ca.get("bytes accessed", 0.0),
        },
        "collectives": coll,
    }
    if verbose:
        print(f"[dryrun] {label}: compile={t_compile:.1f}s "
              f"mem/dev={result['memory']['total_per_device_gb']}GB")
        print(f"[dryrun]   memory_analysis: {ma}")
        print(f"[dryrun]   cost_analysis: flops={ca.get('flops')} "
              f"bytes={ca.get('bytes accessed')}")
        print(f"[dryrun]   collectives: "
              + ", ".join(f"{k}:{v['count']}({v['bytes']/2**20:.1f}MiB)"
                          for k, v in coll.items() if v["count"]))
    return result


def save_result(res: dict) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    name = res["cell"].replace("/", "__") + ".json"
    path = os.path.join(RESULTS_DIR, name)
    with open(path, "w") as f:
        json.dump(res, f, indent=1)
    return path


def default_quant_for(shape_name: str, quant_arg: str) -> str:
    if quant_arg != "auto":
        return quant_arg
    # serve cells default to the paper's target (msgemm int4); train is bf16
    return "bf16" if shape_name == "train_4k" else "msgemm"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--quant", default="auto",
                    choices=["auto", "bf16", "msgemm", "int4_dequant"])
    ap.add_argument("--rules", default="default")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    archs = configs.ARCHS if (args.all or not args.arch) else [args.arch]
    shapes = list(shp.SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    results = []
    failures = 0
    for arch in archs:
        for shape_name in shapes:
            for multi in meshes:
                quant = default_quant_for(shape_name, args.quant)
                label = (f"{arch}__{shape_name}__"
                         f"{'multi' if multi else 'single'}__{quant}.json")
                path = os.path.join(RESULTS_DIR, label)
                if os.path.exists(path) and not args.force:
                    print(f"[dryrun] cached: {label}")
                    continue
                try:
                    res = run_cell(arch, shape_name, multi_pod=multi,
                                   quant=quant, rules=args.rules)
                except Exception as e:  # a failure here is a system bug
                    traceback.print_exc()
                    res = {"cell": label, "status": "failed",
                           "error": f"{type(e).__name__}: {e}"}
                    failures += 1
                save_result(res)
                results.append(res)
    ok = sum(r["status"] == "ok" for r in results)
    sk = sum(r["status"] == "skipped" for r in results)
    print(f"[dryrun] done: {ok} ok, {sk} skipped, {failures} FAILED")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
