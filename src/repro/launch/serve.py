"""Serving entry point: quantize a model and serve generation with msGeMM
(or int4-dequant / bf16 baseline) weights.

Two engines:

* ``--engine static``      fixed-shape batched prefill+decode
  (runtime.serve.generate) — the original path;
* ``--engine continuous``  the continuous-batching engine with a paged KV
  cache (repro.serving) driven by a simulated Poisson arrival stream of
  mixed-length requests.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma_2b --smoke \
        --quant msgemm --engine continuous --num-requests 6 \
        --backend msgemm_pallas --autotune

Both engines are mesh-aware: ``--mesh model=4,data=2`` serves
tensor-parallel over a device mesh (weights TP over 'model', batches
over 'data', quantized GeMMs inside shard_map with per-shard LUT
produce — see repro.dispatch.shard).  On a CPU host add
``--force-host-devices 8`` to fake the devices:

    PYTHONPATH=src python -m repro.launch.serve --arch gemma_2b --smoke \
        --quant msgemm --engine continuous --mesh model=4,data=2 \
        --force-host-devices 8
"""

from __future__ import annotations

import argparse
import json
import sys
import time

import jax
import numpy as np

from repro import configs, dispatch, obs
from repro.core.spec import QuantSpec
from repro.distributed import sharding as shd
from repro.models import transformer as T
from repro.quant import quantize_model
from repro.runtime import serve as SV


def build_model(args):
    cfg = (configs.get_smoke(args.arch) if args.smoke
           else configs.get_config(args.arch))
    key = jax.random.PRNGKey(args.seed)
    params = T.init_params(key, cfg)
    if args.quant != "bf16":
        spec = QuantSpec(mode=args.quant, d=args.d, scale_block=12 * args.d)
        params = quantize_model(params, cfg, spec)
        cfg = cfg.replace(quant=spec)
        print(f"[serve] quantized weights to {args.quant} (d={args.d})")
    return params, cfg, key


def check_run_regressions(args) -> None:
    """Run the perf-model regression sentinel over this run's measured
    ``kernel_gemm_s`` series (obs.perfmodel).  SystemExit(1) when any
    kernel ran slower than the tolerance band allows; a missing or
    mismatched calibration skips with a note (a fresh machine should
    serve, not crash — CI pins a calibration and relies on the exit
    code)."""
    from repro.obs import perfmodel as pm

    cal = pm.load_calibration(args.calibration)
    if cal is None:
        path = args.calibration or pm.default_calibration_path()
        print(f"[serve] check-regressions: no calibration matching this "
              f"device/interpret partition at {path}; skipped "
              f"(python -m repro.obs --calibrate)", file=sys.stderr)
        return
    samples = pm.samples_from_registry()
    report = pm.check_regressions(samples, cal)
    print(pm.render_report(report))
    if not report["n_samples"]:
        print("[serve] check-regressions: no kernel_gemm_s samples "
              "recorded (is tracing on?)", file=sys.stderr)
    elif not report["ok"]:
        raise SystemExit(
            f"[serve] check-regressions: {report['n_outliers']} kernel "
            f"timing(s) exceeded {report['tolerance']:g}x the model "
            f"prediction")


def exec_policy(args) -> dispatch.ExecPolicy | None:
    """The CLI's execution choices as an ExecPolicy (None: defaults)."""
    backend = None if args.backend == "auto" else args.backend
    if backend is None and not args.autotune and args.mesh is None:
        return None
    return dispatch.ExecPolicy(backend=backend, autotune=args.autotune,
                               shard_collective=args.shard_collective,
                               shard_pipeline=args.shard_pipeline,
                               shard_impl=args.shard_impl)


def parse_mesh(s: str):
    """'model=4,data=2' -> a jax mesh with those axes (given order)."""
    from repro.launch import mesh as M

    pairs = [kv.split("=") for kv in s.split(",") if kv]
    axes = tuple(name for name, _ in pairs)
    shape = tuple(int(size) for _, size in pairs)
    need = 1
    for n in shape:
        need *= n
    import jax as _jax

    have = _jax.device_count()
    if need > have:
        raise SystemExit(
            f"--mesh {s} needs {need} devices but only {have} are "
            f"visible; on a CPU host pass --force-host-devices {need} "
            "(or set XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{need} before jax initializes)")
    return M.make_mesh(shape, axes)


def run_static(args, params, cfg, key):
    batch = {"tokens": jax.random.randint(
        key, (args.batch, args.prompt_len), 0, cfg.vocab_size)}
    if cfg.is_encdec:
        batch["frames"] = jax.random.normal(
            key, (args.batch, 16, cfg.d_model))
    elif cfg.frontend == "image_patches":
        batch["patch_embeds"] = jax.random.normal(
            key, (args.batch, cfg.num_patches, cfg.d_model))

    policy = exec_policy(args)
    if policy is not None and policy.autotune:
        # plans must be tuned OUTSIDE the trace: collect the shape keys
        # abstractly, warm them concretely, then generate for real
        with dispatch.collecting() as reqs:
            jax.eval_shape(lambda p, b: SV.generate(
                p, cfg, b, max_new_tokens=args.new_tokens), params, batch)
        plans = dispatch.warm(reqs, policy=policy)
        print(f"[serve] resolved {len(plans)} exec plans before trace "
              f"(cache={dispatch.cache().path})")

    t0 = time.time()
    out = SV.generate(params, cfg, batch, max_new_tokens=args.new_tokens)
    out.block_until_ready()
    dt = time.time() - t0
    tput = args.batch * args.new_tokens / dt
    print(f"[serve] generated {out.shape} in {dt:.2f}s "
          f"({tput:.1f} tok/s incl. compile)")
    print(out[:, :12])
    return out


def make_request_stream(args, cfg):
    """Mixed-length prompts with Poisson (exponential inter-arrival)
    timing — deterministic in --seed."""
    from repro.serving import poisson_stream

    return poisson_stream(args.num_requests, cfg.vocab_size,
                          max_new_tokens=args.new_tokens,
                          rate=args.arrival_rate,
                          min_prompt=max(1, args.prompt_len // 4),
                          max_prompt=args.prompt_len, seed=args.seed)


def kv_spec_from_args(args, params, cfg):
    """--kv-bits/--kv-codebook -> KVQuantSpec (None at 16 bits).  A
    learned codebook is fitted here, once, from the model's own K/V
    activations on a synthetic batch (repro.kvq.fit)."""
    if args.kv_bits == 16:
        if args.kv_codebook == "learned":
            print("[serve] --kv-codebook learned ignored at --kv-bits 16")
        return None
    codebook = None
    if args.kv_codebook == "learned":
        if args.kv_bits != 4:
            print("[serve] --kv-codebook learned ignored at --kv-bits 8 "
                  "(codebooks are a 4-bit construct)")
        else:
            from repro import kvq

            codebook = kvq.fit_kv_codebook(params, cfg, seed=args.seed)
            print("[serve] fitted 16-entry KV codebook from model "
                  "activations")
    from repro.kvq import KVQuantSpec

    return KVQuantSpec(bits=args.kv_bits, codebook=codebook)


def run_continuous(args, params, cfg, mesh=None):
    from repro.serving import Engine

    kv_spec = kv_spec_from_args(args, params, cfg)
    if kv_spec is not None:
        print(f"[serve] quantized KV cache: {kv_spec.describe()}")
    max_len = args.prompt_len + args.new_tokens
    engine = Engine(params, cfg,
                    max_slots=args.max_slots,
                    block_size=args.block_size,
                    num_blocks=args.num_blocks or None,
                    max_model_len=max_len,
                    prefill_chunk=args.prefill_chunk,
                    backend=None if args.backend == "auto" else args.backend,
                    autotune=args.autotune,
                    autotune_cache=args.autotune_cache,
                    mesh=mesh, mesh_rules=args.mesh_rules,
                    shard_collective=args.shard_collective,
                    shard_pipeline=args.shard_pipeline,
                    shard_impl=args.shard_impl,
                    kv_quant=kv_spec,
                    kv_pool_bytes=(int(args.kv_pool_mib * 2**20)
                                   if args.kv_pool_mib else None),
                    max_queue=args.max_queue or None,
                    deadline_s=args.deadline_s or None,
                    ttft_deadline_s=args.ttft_deadline_s or None,
                    watchdog=args.watchdog or None)
    if mesh is not None:
        n_sharded = sum(1 for p in engine.exec_plans.values()
                        if p.shard is not None)
        print(f"[serve] mesh {dict(mesh.shape)}: {len(engine.exec_plans)} "
              f"plans resolved at build, {n_sharded} sharded "
              f"(rules={args.mesh_rules}, "
              f"collective={args.shard_collective})")
    reqs = make_request_stream(args, cfg)
    print(f"[serve] continuous engine: {len(reqs)} requests, prompt lens "
          f"{sorted(len(r.prompt) for r in reqs)}, rate="
          f"{args.arrival_rate or 'inf'} req/s, block_size="
          f"{args.block_size}, slots={args.max_slots}")
    if engine.exec_plans:
        print(f"[serve] resolved {len(engine.exec_plans)} exec plans at "
              f"build (autotune={'on' if args.autotune else 'off'}, "
              f"cache={dispatch.cache().path})")
    t0 = time.time()
    results = engine.run(reqs)
    dt = time.time() - t0
    for rid in sorted(results):
        seq = results[rid]
        m = seq.metrics()
        if m["status"] != "ok":
            print(f"  req {rid}: prompt={m['prompt_tokens']:3d} "
                  f"new={m['new_tokens']:3d} status={m['status']}")
            continue
        print(f"  req {rid}: prompt={m['prompt_tokens']:3d} "
              f"new={m['new_tokens']:3d} ttft={m['ttft_s'] * 1e3:7.1f}ms "
              f"lat={m['latency_s'] * 1e3:7.1f}ms "
              f"preempt={m['preemptions']} tok={seq.generated[:8]}")
    s = engine.summary()
    # percentiles are None when nothing finished — coalesce for display
    print(f"[serve] {s['generated_tokens']} tokens in {dt:.2f}s "
          f"({s['tok_per_s']:.1f} tok/s) "
          f"p50={(s['latency_p50_s'] or 0.0) * 1e3:.1f}ms "
          f"p95={(s['latency_p95_s'] or 0.0) * 1e3:.1f}ms "
          f"preemptions={s['preemptions']}")
    if s["shed"] or s["cancelled"] or s["step_retries"] or s["replans"]:
        print(f"[serve] resilience: shed={s['shed']} "
              f"cancelled={s['cancelled']} retries={s['step_retries']} "
              f"nan_quarantined={s['nan_quarantined']} "
              f"replans={s['replans']}")

    if args.check:
        live = {rid: seq for rid, seq in results.items()
                if seq.status == "ok"}
        bad = 0
        for rid, seq in live.items():
            toks = np.array([list(seq.req.prompt)], np.int32)
            ref = SV.generate(params, cfg, {"tokens": toks},
                              max_new_tokens=seq.req.max_new_tokens)
            if [int(t) for t in np.asarray(ref)[0]] != seq.generated:
                bad += 1
        print(f"[serve] static-path parity check: "
              f"{len(live) - bad}/{len(live)} identical "
              f"({len(results) - len(live)} non-ok skipped)")
        if bad:
            raise SystemExit("continuous engine diverged from static path")
    return results


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--quant", default="msgemm",
                    choices=["bf16", "int4_dequant", "msgemm"])
    ap.add_argument("--d", type=int, default=3, help="LUT depth (paper d)")
    ap.add_argument("--engine", default="static",
                    choices=["static", "continuous"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    # continuous-engine knobs
    ap.add_argument("--num-requests", type=int, default=6)
    ap.add_argument("--arrival-rate", type=float, default=50.0,
                    help="mean req/s of the Poisson stream (<=0: all at t=0)")
    ap.add_argument("--max-slots", type=int, default=4)
    ap.add_argument("--block-size", type=int, default=8)
    ap.add_argument("--num-blocks", type=int, default=0,
                    help="KV pool blocks (0: sized to never preempt)")
    ap.add_argument("--prefill-chunk", type=int, default=8)
    # quantized KV cache (repro.kvq; continuous engine only)
    ap.add_argument("--kv-bits", type=int, default=16, choices=[16, 8, 4],
                    help="paged KV pool storage: 16 = full precision, "
                         "8/4 = quantized codes + per-slot scales")
    ap.add_argument("--kv-codebook", default="uniform",
                    choices=["uniform", "learned"],
                    help="4-bit code map: uniform int4 grid or a 16-entry "
                         "codebook fitted from the model's K/V activations")
    ap.add_argument("--kv-pool-mib", type=float, default=0,
                    help="size the KV pool by a device-byte budget (MiB) "
                         "instead of --num-blocks; quantized pools fit "
                         "proportionally more blocks")
    ap.add_argument("--check", action="store_true",
                    help="assert token parity vs the static generate path")
    # resilience (continuous engine; README §Resilience)
    ap.add_argument("--max-queue", type=int, default=0,
                    help="shed submissions beyond this waiting-queue "
                         "depth (0: unbounded)")
    ap.add_argument("--deadline-s", type=float, default=0,
                    help="default per-request total-latency SLO; expired "
                         "requests are cancelled cleanly (0: none)")
    ap.add_argument("--ttft-deadline-s", type=float, default=0,
                    help="default first-token SLO (0: none)")
    ap.add_argument("--watchdog", action="store_true",
                    help="arm the per-step hang watchdog (hangs escalate "
                         "to a backend quarantine + replan)")
    ap.add_argument("--faults", default=None, metavar="SPEC",
                    help="arm deterministic fault injection: 'all' or "
                         "'cls:p=..,after=..,max=..,mag=..;cls2' "
                         "(classes: repro.faults.CLASSES; overrides "
                         "REPRO_FAULTS)")
    ap.add_argument("--fault-seed", type=int, default=0,
                    help="seed for the injected-fault schedule")
    # execution planning (repro.dispatch)
    ap.add_argument("--backend", default="auto",
                    choices=["auto"] + dispatch.backend_names(),
                    help="force a registered execution backend "
                         "(auto: capability+priority selection)")
    ap.add_argument("--autotune", nargs="?", const=True, default=False,
                    choices=["model", "full"], metavar="MODE",
                    help="time candidate tile configs per linear shape and "
                         "persist winners to the plan cache; bare flag "
                         "auto-selects model-guided search when a perf-model "
                         "calibration exists, '=model'/'=full' force the "
                         "pruned/exhaustive sweep")
    ap.add_argument("--autotune-cache", default=None,
                    help="plan-cache JSON path (default: REPRO_PLAN_CACHE "
                         "env or ~/.cache/msgemm-repro/plans.json)")
    # sharded serving (repro.dispatch.shard over a device mesh)
    ap.add_argument("--mesh", default=None,
                    help="serve tensor-parallel over a device mesh, e.g. "
                         "'model=4,data=2' (axis order preserved)")
    ap.add_argument("--mesh-rules", default="serve",
                    choices=sorted(shd.RULE_SETS),
                    help="logical-axis rule set for params/activations")
    ap.add_argument("--shard-collective", default="psum",
                    choices=["psum", "reduce_scatter"],
                    help="contraction collective for row-parallel linears")
    ap.add_argument("--shard-pipeline", type=int, default=1,
                    metavar="CHUNKS",
                    help="pipeline the TP contraction: split the local "
                         "contraction dim into CHUNKS slices so chunk i's "
                         "collective overlaps chunk i+1's LUT consume "
                         "(1: one-shot; 0: autotune the variant grid and "
                         "replay the cached winner)")
    ap.add_argument("--shard-impl", default="xla",
                    choices=sorted(dispatch.shard.COLLECTIVE_IMPLS),
                    help="contraction-collective implementation: 'xla' "
                         "native psum/psum_scatter, 'ring' explicit "
                         "ppermute ring (overlappable per hop)")
    ap.add_argument("--force-host-devices", type=int, default=0,
                    help="fake N host CPU devices (sets XLA_FLAGS; must "
                         "run before jax touches the backend)")
    # observability (repro.obs) — all off by default, near-zero cost off
    ap.add_argument("--metrics-json", default=None, metavar="PATH",
                    help="write a versioned registry snapshot "
                         "(obs.metrics) on exit")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="enable tracing and write Chrome-trace JSON "
                         "(load at https://ui.perfetto.dev) on exit")
    ap.add_argument("--prom-port", type=int, default=0,
                    help="expose /metrics in Prometheus text format on "
                         "this port for the lifetime of the run")
    ap.add_argument("--check-regressions", action="store_true",
                    help="after the run, compare measured kernel times "
                         "against the calibrated perf model "
                         "(obs.perfmodel); exit 1 on outliers — implies "
                         "tracing so kernel timings are recorded")
    ap.add_argument("--calibration", default=None, metavar="PATH",
                    help="perf-model calibration.json for "
                         "--check-regressions (default: "
                         "$REPRO_CALIBRATION or the user cache dir)")
    args = ap.parse_args(argv)

    from repro.launch.mesh import force_host_devices

    force_host_devices(args.force_host_devices)
    mesh = parse_mesh(args.mesh) if args.mesh else None

    from repro import faults

    if args.faults:
        plan = faults.FaultPlan(faults.parse_spec(args.faults),
                                seed=args.fault_seed)
        faults.arm(plan)
        print(f"[serve] fault injection armed: {plan.describe()}")
    else:
        plan = faults.plan_from_env()  # REPRO_FAULTS / REPRO_FAULT_SEED
        if plan is not None:
            faults.arm(plan)
            print(f"[serve] fault injection armed from env: "
                  f"{plan.describe()}")

    # tracing must be on BEFORE the engine builds/compiles: jit marks are
    # staged at trace time, so a later enable would record host spans but
    # no in-graph gemm/collective events
    if args.trace_out or args.check_regressions:
        # the sentinel reads kernel_gemm_s series, which only exist when
        # the in-graph jit marks were staged at trace time
        obs.enable_tracing(clear=True)
    prom = None
    if args.prom_port:
        prom = obs.serve_prometheus(args.prom_port)
        print(f"[serve] prometheus /metrics on port "
              f"{prom.server_address[1]}")

    try:
        params, cfg, key = build_model(args)
        if args.engine == "continuous":
            out = run_continuous(args, params, cfg, mesh)
        else:
            if args.kv_bits != 16 or args.kv_pool_mib:
                print("[serve] --kv-bits/--kv-pool-mib apply to the paged "
                      "pool only; ignored by --engine static",
                      file=sys.stderr)
            if args.autotune_cache is not None:
                dispatch.set_cache_path(args.autotune_cache)
            if mesh is not None:
                params = jax.device_put(
                    params, shd.shardings(params, mesh, args.mesh_rules))
                with shd.use(mesh, args.mesh_rules), \
                        dispatch.using_policy(exec_policy(args)):
                    out = run_static(args, params, cfg, key)
            else:
                with dispatch.using_policy(exec_policy(args)):
                    out = run_static(args, params, cfg, key)
        if args.check_regressions:
            jax.effects_barrier()  # flush kernel timing callbacks
            check_run_regressions(args)
        return out
    finally:
        if args.trace_out:
            jax.effects_barrier()  # flush in-flight debug callbacks
            obs.tracer().save(args.trace_out)
            obs.disable_tracing()
            print(f"[serve] wrote trace {args.trace_out} "
                  f"({len(obs.tracer().events())} events)")
        if args.metrics_json:
            snap = obs.registry().snapshot(extra={
                "arch": args.arch, "quant": args.quant,
                "engine": args.engine, "mesh": args.mesh,
                "backend": args.backend, "kv_bits": args.kv_bits,
                "kv_codebook": args.kv_codebook})
            with open(args.metrics_json, "w") as f:
                json.dump(snap, f, indent=1)
            print(f"[serve] wrote metrics snapshot {args.metrics_json}")
        if prom is not None:
            prom.shutdown()


if __name__ == "__main__":
    main()
