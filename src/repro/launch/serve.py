"""Serving entry point: quantize a model and serve batched generation
with msGeMM (or int4-dequant / bf16 baseline) weights.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma_2b --smoke \
        --quant msgemm --batch 4 --prompt-len 16 --new-tokens 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.core.linear import QuantConfig
from repro.models import transformer as T
from repro.quant import quantize_model
from repro.runtime import serve as SV


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--quant", default="msgemm",
                    choices=["bf16", "int4_dequant", "msgemm"])
    ap.add_argument("--d", type=int, default=3, help="LUT depth (paper d)")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = (configs.get_smoke(args.arch) if args.smoke
           else configs.get_config(args.arch))
    key = jax.random.PRNGKey(args.seed)
    params = T.init_params(key, cfg)
    if args.quant != "bf16":
        qc = QuantConfig(mode=args.quant, d=args.d, scale_block=12 * args.d)
        params = quantize_model(params, cfg, qc)
        cfg = cfg.replace(quant=qc)
        print(f"[serve] quantized weights to {args.quant} (d={args.d})")

    batch = {"tokens": jax.random.randint(
        key, (args.batch, args.prompt_len), 0, cfg.vocab_size)}
    if cfg.is_encdec:
        batch["frames"] = jax.random.normal(
            key, (args.batch, 16, cfg.d_model))
    elif cfg.frontend == "image_patches":
        batch["patch_embeds"] = jax.random.normal(
            key, (args.batch, cfg.num_patches, cfg.d_model))

    t0 = time.time()
    out = SV.generate(params, cfg, batch, max_new_tokens=args.new_tokens)
    out.block_until_ready()
    dt = time.time() - t0
    tput = args.batch * args.new_tokens / dt
    print(f"[serve] generated {out.shape} in {dt:.2f}s "
          f"({tput:.1f} tok/s incl. compile)")
    print(out[:, :12])
    return out


if __name__ == "__main__":
    main()
