"""Training entry point.

    PYTHONPATH=src python -m repro.launch.train --arch gemma_2b --smoke \
        --steps 100 --mesh 1x1 --checkpoint-dir /tmp/ckpt

On a real fleet the same module runs under the production mesh
(--mesh 16x16 / 2x16x16); on this host use --mesh 1x1 or set
XLA_FLAGS=--xla_force_host_platform_device_count=N first.
"""

from __future__ import annotations

import argparse
import functools

import jax

from repro import configs
from repro.data import DataConfig, SyntheticStream
from repro.distributed import sharding as shd
from repro.launch.mesh import make_mesh
from repro.optim import AdamWConfig, schedules
from repro.runtime import train as RT
from repro.runtime.driver import DriverConfig, run


def parse_mesh(s: str):
    dims = tuple(int(x) for x in s.split("x"))
    axes = {1: ("data",), 2: ("data", "model"),
            3: ("pod", "data", "model")}[len(dims)]
    return make_mesh(dims, axes)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--checkpoint-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = (configs.get_smoke(args.arch) if args.smoke
           else configs.get_config(args.arch))
    mesh = parse_mesh(args.mesh)
    tcfg = RT.TrainConfig(
        optimizer=AdamWConfig(
            lr=schedules.warmup_cosine(args.lr, 10, args.steps)),
        microbatches=args.microbatches)
    data = SyntheticStream(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq_len + 1,
        global_batch=args.global_batch, seed=args.seed,
        frontend=cfg.frontend, d_model=cfg.d_model,
        num_frames=max(args.seq_len // 2, 8), num_patches=cfg.num_patches))

    with shd.use(mesh, cfg.logical_rules):
        state = RT.init_state(jax.random.PRNGKey(args.seed), cfg, tcfg)
        state_sh = shd.shardings(jax.eval_shape(lambda: state), mesh,
                                 cfg.logical_rules)
        state = jax.tree.map(lambda x, s: jax.device_put(x, s), state,
                             state_sh)
        step_fn = jax.jit(
            functools.partial(RT.train_step, cfg=cfg, tcfg=tcfg),
            in_shardings=(state_sh, None), out_shardings=(state_sh, None),
            donate_argnums=(0,))
        res = run(state, step_fn, data,
                  DriverConfig(total_steps=args.steps,
                               checkpoint_every=args.checkpoint_every,
                               checkpoint_dir=args.checkpoint_dir),
                  shardings=state_sh)
    print(f"final loss: {res['metrics'][-1]['loss']:.4f} "
          f"(resumed_at={res['resumed_at']})")
    return res


if __name__ == "__main__":
    main()
