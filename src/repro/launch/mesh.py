"""Production mesh builders.

Functions (never module-level constants) so importing this module never
touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first jax
init; tests and benches must keep seeing 1 device.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips.
    Multi-pod: (pod=2, data=16, model=16) = 512 chips; 'pod' is the DCN
    axis (data-parallel only), 'model' stays intra-pod ICI."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: tuple, axes: tuple):
    """Arbitrary mesh (elastic re-scale, tests on host devices)."""
    return jax.make_mesh(tuple(shape), tuple(axes))


def mesh_devices(mesh) -> int:
    import numpy as np

    return int(np.prod(list(mesh.shape.values())))
