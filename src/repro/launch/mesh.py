"""Production mesh builders.

Functions (never module-level constants) so importing this module never
touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first jax
init; tests and benches must keep seeing 1 device.
"""

from __future__ import annotations

import os

import jax


def force_host_devices(n: int) -> None:
    """Fake ``n`` host CPU devices via XLA_FLAGS.  Only effective before
    jax first touches the backend — the CLI entry points call this from
    argument handling, ahead of any device use."""
    if n:
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={n}")


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips.
    Multi-pod: (pod=2, data=16, model=16) = 512 chips; 'pod' is the DCN
    axis (data-parallel only), 'model' stays intra-pod ICI."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: tuple, axes: tuple):
    """Arbitrary mesh (elastic re-scale, tests on host devices).

    Unlike ``jax.make_mesh`` this accepts a shape smaller than the
    visible device count (a 2x2 sweep entry on an 8-device host uses the
    first 4 devices) — what the serve/bench ``--mesh`` sweeps need."""
    import numpy as np

    shape, axes = tuple(shape), tuple(axes)
    n = int(np.prod(shape))
    devs = jax.devices()
    if n < len(devs):
        from jax.sharding import Mesh

        return Mesh(np.asarray(devs[:n]).reshape(shape), axes)
    return jax.make_mesh(shape, axes)


def mesh_devices(mesh) -> int:
    import numpy as np

    return int(np.prod(list(mesh.shape.values())))
