"""Deterministic, seeded fault plans.

A :class:`FaultPlan` is a schedule of fault *classes* (``CLASSES``),
each with a per-opportunity probability, an opportunity offset, a fire
budget, and a class-specific magnitude.  Every injection site in the
stack calls ``faults.fire("<class>")`` at its opportunity point; the
plan answers with a :class:`FaultEvent` (fire) or ``None`` (pass).

Determinism contract: the decision stream per class is a function of
``(seed, class)`` and the opportunity index only — two runs of the same
workload under the same plan inject the exact same faults at the exact
same points, which is what lets the chaos benchmark assert token
identity of everything the faults did not touch.

Spec strings (CLI ``--faults`` / env ``REPRO_FAULTS``)::

    all                               # every class, default knobs
    nan_logits                        # one class, default knobs
    step_fail:p=0.5,after=2,max=3     # per-class overrides
    oom:p=0.2;disconnect:max=1        # ';'-separated multi-class

Knobs: ``p`` (probability per opportunity), ``after`` (skip the first N
opportunities), ``max`` (total fire budget; 0 = unbounded), ``mag``
(class magnitude — sleep seconds for latency/hang).
"""

from __future__ import annotations

from dataclasses import dataclass, replace
from typing import NamedTuple

import numpy as np

# The fault taxonomy.  Each class maps to exactly one injection site
# (see README §Resilience for the site/recovery table).
CLASSES = (
    "latency",             # engine step-latency spike (sleep)
    "oom",                 # BlockPool.alloc artificially exhausted
    "nan_logits",          # non-finite logits row after a step
    "step_fail",           # transient host-side step failure (raises)
    "hang",                # step stalls past the watchdog hang timer
    "disconnect",          # mid-stream client disconnect of a live seq
    "corrupt_plan_cache",  # garbage written over the plan-cache JSON
    "corrupt_calibration", # garbage written over calibration.json
    "corrupt_checkpoint",  # garbage written over a checkpoint manifest
)

# per-class default knobs: (p, after, max_fires, magnitude)
_DEFAULTS = {
    "latency": (0.25, 2, 4, 0.05),
    "oom": (0.25, 1, 4, 0.0),
    "nan_logits": (0.5, 3, 1, 0.0),
    "step_fail": (0.5, 1, 2, 0.0),
    "hang": (1.0, 4, 1, 0.25),
    "disconnect": (0.5, 4, 1, 0.0),
    "corrupt_plan_cache": (1.0, 0, 1, 0.0),
    "corrupt_calibration": (1.0, 0, 1, 0.0),
    "corrupt_checkpoint": (1.0, 0, 1, 0.0),
}


@dataclass(frozen=True)
class FaultSpec:
    """One armed fault class with its schedule knobs."""

    cls: str
    p: float = 1.0          # fire probability per opportunity
    after: int = 0          # opportunities to skip before the first roll
    max_fires: int = 1      # total budget (0 = unbounded)
    magnitude: float = 0.0  # class-specific size (sleep seconds, ...)

    def __post_init__(self):
        if self.cls not in CLASSES:
            raise ValueError(
                f"unknown fault class {self.cls!r}; known: {CLASSES}")
        if not 0.0 <= self.p <= 1.0:
            raise ValueError(f"fault p={self.p} outside [0, 1]")
        if self.after < 0 or self.max_fires < 0:
            raise ValueError("after and max must be >= 0")


class FaultEvent(NamedTuple):
    """One fired fault: which class, the nth fire of that class, its
    magnitude, and a per-event RNG for deterministic victim/byte
    choices at the injection site."""

    cls: str
    index: int
    magnitude: float
    rng: np.random.Generator


def default_spec(cls: str) -> FaultSpec:
    if cls not in _DEFAULTS:
        raise ValueError(
            f"unknown fault class {cls!r}; pick from {sorted(CLASSES)}")
    p, after, max_fires, mag = _DEFAULTS[cls]
    return FaultSpec(cls=cls, p=p, after=after, max_fires=max_fires,
                     magnitude=mag)


def parse_spec(text: str) -> list[FaultSpec]:
    """Parse a ``--faults`` spec string into FaultSpecs (see module
    docstring for the grammar)."""
    text = (text or "").strip()
    if not text:
        return []
    if text == "all":
        return [default_spec(c) for c in CLASSES]
    out = []
    for part in text.split(";"):
        part = part.strip()
        if not part:
            continue
        cls, _, knobs = part.partition(":")
        spec = default_spec(cls.strip())
        for kv in filter(None, (s.strip() for s in knobs.split(","))):
            key, _, val = kv.partition("=")
            key = {"max": "max_fires", "mag": "magnitude"}.get(key, key)
            if key not in ("p", "after", "max_fires", "magnitude"):
                raise ValueError(f"unknown fault knob {kv!r} in {part!r}")
            cast = int if key in ("after", "max_fires") else float
            spec = replace(spec, **{key: cast(val)})
        out.append(spec)
    return out


class FaultPlan:
    """Seeded multi-class fault schedule.  ``fire(cls)`` is the single
    decision point every injection site goes through."""

    def __init__(self, specs, *, seed: int = 0):
        if isinstance(specs, str):
            specs = parse_spec(specs)
        specs = [s if isinstance(s, FaultSpec) else FaultSpec(cls=s)
                 for s in specs]
        dup = [s.cls for s in specs]
        if len(dup) != len(set(dup)):
            raise ValueError(f"duplicate fault classes in plan: {dup}")
        self.seed = int(seed)
        self.specs: dict[str, FaultSpec] = {s.cls: s for s in specs}
        self._opportunities: dict[str, int] = {c: 0 for c in self.specs}
        self._fires: dict[str, int] = {c: 0 for c in self.specs}
        self._rngs = {
            c: np.random.default_rng(
                np.random.SeedSequence([self.seed, CLASSES.index(c)]))
            for c in self.specs}

    # ------------------------------------------------------------ state
    def armed_classes(self) -> tuple[str, ...]:
        return tuple(self.specs)

    def fires(self, cls: str | None = None) -> int:
        if cls is not None:
            return self._fires.get(cls, 0)
        return sum(self._fires.values())

    def exhausted(self) -> bool:
        """True when every armed class has spent its fire budget (an
        unbounded class never exhausts)."""
        return all(s.max_fires and self._fires[c] >= s.max_fires
                   for c, s in self.specs.items())

    # ------------------------------------------------------------- fire
    def fire(self, cls: str) -> FaultEvent | None:
        spec = self.specs.get(cls)
        if spec is None:
            return None
        n = self._opportunities[cls]
        self._opportunities[cls] = n + 1
        if n < spec.after:
            return None
        if spec.max_fires and self._fires[cls] >= spec.max_fires:
            return None
        rng = self._rngs[cls]
        # always draw, so the decision stream depends only on the
        # opportunity index — not on earlier budget exhaustion
        roll = rng.random()
        if roll >= spec.p:
            return None
        idx = self._fires[cls]
        self._fires[cls] = idx + 1
        return FaultEvent(
            cls=cls, index=idx, magnitude=spec.magnitude,
            rng=np.random.default_rng(
                np.random.SeedSequence([self.seed, CLASSES.index(cls),
                                        idx])))

    def describe(self) -> str:
        return ",".join(
            f"{c}(p={s.p:g},after={s.after},max={s.max_fires or 'inf'})"
            for c, s in self.specs.items()) or "<empty>"
