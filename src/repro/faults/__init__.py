"""Fault injection facade (zero overhead when off).

Mirrors the ``obs.trace`` contract: when no plan is armed, every
injection site is a single ``None`` check — nothing is drawn, counted,
or recorded, and ``faults_armed`` stays 0.

Usage::

    import repro.faults as faults

    faults.arm("step_fail:p=0.5,max=2", seed=0)
    ...
    ev = faults.fire("step_fail")     # FaultEvent | None
    if ev is not None:
        raise InjectedFault("step_fail", ev)
    ...
    faults.disarm()

Injection sites and the components that recover from them are listed in
README §Resilience.  ``plan_from_env()`` arms from ``REPRO_FAULTS`` /
``REPRO_FAULT_SEED`` so any entry point (CLI, benchmark, test) can be
chaos-tested without code changes.
"""

from __future__ import annotations

import os

from repro import obs
from repro.faults.plan import (
    CLASSES,
    FaultEvent,
    FaultPlan,
    FaultSpec,
    default_spec,
    parse_spec,
)

__all__ = [
    "CLASSES",
    "FaultEvent",
    "FaultPlan",
    "FaultSpec",
    "InjectedFault",
    "active",
    "arm",
    "corrupt_file",
    "default_spec",
    "disarm",
    "fire",
    "parse_spec",
    "plan_from_env",
]

_PLAN: FaultPlan | None = None


class InjectedFault(RuntimeError):
    """Raised by injection sites whose fault class is "this call
    fails".  Recovery paths treat it exactly like the organic error it
    models, but tests can assert on the class."""

    def __init__(self, cls: str, event: FaultEvent):
        super().__init__(f"injected fault: {cls} (fire #{event.index})")
        self.cls = cls
        self.event = event


def arm(plan, *, seed: int = 0) -> FaultPlan:
    """Arm a fault plan process-wide.  ``plan`` is a FaultPlan, a spec
    string (``"all"``, ``"oom:p=0.3;hang"``), or a list of FaultSpecs."""
    global _PLAN
    if not isinstance(plan, FaultPlan):
        plan = FaultPlan(plan, seed=seed)
    _PLAN = plan
    obs.registry().gauge("faults_armed").set(len(plan.specs))
    return plan


def disarm() -> None:
    global _PLAN
    _PLAN = None
    obs.registry().gauge("faults_armed").set(0)


def active() -> FaultPlan | None:
    return _PLAN


def fire(cls: str) -> FaultEvent | None:
    """The hot-path check.  One attribute load + None test when
    disarmed; when armed, ask the plan and count any fire."""
    plan = _PLAN
    if plan is None:
        return None
    ev = plan.fire(cls)
    if ev is not None:
        obs.registry().counter("faults_injected_total", cls=cls).inc()
    return ev


def plan_from_env() -> FaultPlan | None:
    """Arm from ``REPRO_FAULTS`` / ``REPRO_FAULT_SEED`` if set; returns
    the armed plan or None.  A no-op when the variable is unset, so
    importing callers stay zero-overhead by default."""
    spec = os.environ.get("REPRO_FAULTS", "").strip()
    if not spec:
        return None
    seed = int(os.environ.get("REPRO_FAULT_SEED", "0"))
    return arm(spec, seed=seed)


def corrupt_file(path, event: FaultEvent) -> bool:
    """Deterministically corrupt an artifact file in place (used by the
    ``corrupt_*`` classes).  Truncates to a prefix and appends garbage
    bytes drawn from the event RNG, guaranteeing the result is neither
    valid JSON nor CRC-consistent.  Returns False if the file does not
    exist."""
    path = os.fspath(path)
    if not os.path.exists(path):
        return False
    with open(path, "rb") as f:
        data = f.read()
    keep = int(event.rng.integers(0, max(1, len(data) // 2)))
    junk = event.rng.integers(0, 256, size=16, dtype="uint8").tobytes()
    with open(path, "wb") as f:
        f.write(data[:keep] + b"\x00{corrupt" + junk)
    return True
