"""jamba-v0.1-52b [hybrid] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=65536; Mamba:attention 7:1 interleave (attention at index 4 of each
8-layer group), MoE 16 experts top-2 on every other layer.
Mamba-dominated -> runs long_500k (attention layers decode linearly
against their cache).  [arXiv:2403.19887; hf]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=65536,
    max_seq_len=262144,
    block_pattern=("mamba", "mamba_moe", "mamba", "mamba_moe",
                   "attn", "mamba_moe", "mamba", "mamba_moe"),
    mlp_activation="swiglu",
    num_experts=16,
    num_experts_per_tok=2,
    moe_d_ff=14336,
    mamba_d_state=16,
    mamba_expand=2,
    mamba_d_conv=4,
    use_rope=False,  # jamba has no positional encoding
    dtype="bfloat16",
)

SMOKE = CONFIG.replace(
    num_layers=8, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, moe_d_ff=128, num_experts=4, num_experts_per_tok=2,
    vocab_size=512, max_seq_len=128, mamba_chunk=8, dtype="float32",
    capacity_factor=4.0,
)
