"""gemma-2b [dense] — 18L d_model=2048 8H MQA (kv=1) d_ff=16384
vocab=256000, GeGLU, head_dim=256, RMSNorm with (1+w) offset, embeddings
scaled by sqrt(d) and tied with the LM head.  [arXiv:2403.08295; hf]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma-2b",
    family="dense",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=256000,
    max_seq_len=8192,
    block_pattern=("attn",),
    mlp_activation="geglu",
    rms_offset=True,
    embed_scale=True,
    tie_embeddings=True,
    dtype="bfloat16",
)

SMOKE = CONFIG.replace(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=1, head_dim=32,
    d_ff=256, vocab_size=512, max_seq_len=128, dtype="float32",
)
