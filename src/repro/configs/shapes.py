"""Input-shape cells for the assigned (architecture x shape) grid.

  train_4k      seq_len=4096    global_batch=256   -> train_step
  prefill_32k   seq_len=32768   global_batch=32    -> serve prefill
  decode_32k    seq_len=32768   global_batch=128   -> serve_step (1 token,
                                                      KV cache @ 32k)
  long_500k     seq_len=524288  global_batch=1     -> serve_step, only for
                                                      sub-quadratic archs

Skip rules (DESIGN.md §5): long_500k runs only for family ssm/hybrid
(xlstm, jamba); all pure full-attention archs skip it.  Whisper maps
seq_len to *encoder frames* with a fixed 448-token decoder target.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp
from jax import ShapeDtypeStruct as SDS

from repro.models.config import ModelConfig


@dataclass(frozen=True)
class Shape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": Shape("train_4k", 4096, 256, "train"),
    "prefill_32k": Shape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": Shape("decode_32k", 32768, 128, "decode"),
    "long_500k": Shape("long_500k", 524288, 1, "decode"),
}


def applicable(cfg: ModelConfig, shape_name: str) -> tuple[bool, str]:
    """(runs?, reason-if-skipped)."""
    if shape_name == "long_500k" and not cfg.subquadratic:
        return False, ("full-attention sequence mixing is quadratic at "
                       "524288 tokens (DESIGN.md §5 skip)")
    return True, ""


def cells(cfg: ModelConfig):
    """All live (shape, skip-reason) rows for this arch — 4 per arch."""
    return {s: applicable(cfg, s) for s in SHAPES}


# ---------------------------------------------------------------- specs
def _whisper_lens(cfg: ModelConfig, shape: Shape) -> tuple[int, int]:
    """(encoder frames, decoder tokens) for enc-dec cells."""
    dec = min(cfg.max_seq_len, 448)
    return shape.seq_len, dec


def train_input_specs(cfg: ModelConfig, shape: Shape, *, batch=None) -> dict:
    """ShapeDtypeStruct stand-ins for a train_step batch (no allocation)."""
    B = batch or shape.global_batch
    tok = jnp.int32
    if cfg.is_encdec:
        src, dec = _whisper_lens(cfg, shape)
        return {
            "frames": SDS((B, src, cfg.d_model), jnp.bfloat16
                          if cfg.dtype == "bfloat16" else jnp.float32),
            "tokens": SDS((B, dec), tok),
            "labels": SDS((B, dec), tok),
        }
    S = shape.seq_len
    out = {}
    if cfg.frontend == "image_patches":
        P = cfg.num_patches
        out["patch_embeds"] = SDS((B, P, cfg.d_model), jnp.bfloat16
                                  if cfg.dtype == "bfloat16" else jnp.float32)
        out["tokens"] = SDS((B, S - P), tok)
        out["labels"] = SDS((B, S), tok)  # patch positions labeled IGNORE
    else:
        out["tokens"] = SDS((B, S), tok)
        out["labels"] = SDS((B, S), tok)
    return out


def prefill_input_specs(cfg: ModelConfig, shape: Shape, *, batch=None) -> dict:
    B = batch or shape.global_batch
    specs = train_input_specs(cfg, shape, batch=B)
    specs.pop("labels")
    return specs


def _cache_specs(cfg: ModelConfig, B: int, max_len: int, dtype) -> dict:
    """Mirror transformer.init_cache as ShapeDtypeStructs."""
    from repro.models import transformer  # local to avoid cycles
    import jax

    return jax.eval_shape(
        lambda: transformer.init_cache(cfg, B, max_len, dtype))


def decode_input_specs(cfg: ModelConfig, shape: Shape, *, batch=None,
                       cache_dtype=jnp.bfloat16) -> dict:
    """Inputs for serve_step: one new token + the seq_len-deep cache."""
    B = batch or shape.global_batch
    if cfg.is_encdec:
        src, dec = _whisper_lens(cfg, shape)
        max_len = dec
        cfg = cfg.replace(max_source_len=src)
    else:
        max_len = shape.seq_len
    return {
        "token": SDS((B,), jnp.int32),
        "pos": SDS((B,), jnp.int32),
        "cache": _cache_specs(cfg, B, max_len, cache_dtype),
    }


def input_specs(cfg: ModelConfig, shape_name: str, **kw) -> dict:
    shape = SHAPES[shape_name]
    if shape.kind == "train":
        return train_input_specs(cfg, shape, **kw)
    if shape.kind == "prefill":
        return prefill_input_specs(cfg, shape, **kw)
    return decode_input_specs(cfg, shape, **kw)
