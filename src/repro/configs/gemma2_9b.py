"""gemma2-9b [dense] — 42L d_model=3584 16H (GQA kv=8) d_ff=14336
vocab=256000, alternating local(4096-window)/global attention, logit
softcapping (attn 50, final 30), GeGLU, head_dim=256.
[arXiv:2408.00118; hf]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b",
    family="dense",
    num_layers=42,
    d_model=3584,
    num_heads=16,
    num_kv_heads=8,
    head_dim=256,
    d_ff=14336,
    vocab_size=256000,
    max_seq_len=8192,
    block_pattern=("local", "attn"),  # sliding-window / global alternation
    sliding_window=4096,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    mlp_activation="geglu",
    rms_offset=True,
    embed_scale=True,
    tie_embeddings=True,
    dtype="bfloat16",
)

SMOKE = CONFIG.replace(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=32,
    d_ff=256, vocab_size=512, max_seq_len=128, sliding_window=32,
    dtype="float32",
)
