"""whisper-medium [audio] — encoder-decoder, 24L decoder + 24L encoder,
d_model=1024 16H (kv=16) d_ff=4096 vocab=51865 (padded to 51968 for even
sharding), conv frontend STUBBED per the assignment (input_specs provides
precomputed frame embeddings), GELU MLP, LayerNorm, absolute positions
(sinusoidal encoder / learned decoder) — no RoPE.
[arXiv:2212.04356; unverified]"""

from repro.models.config import ModelConfig

VOCAB_RAW = 51865  # padded below; logits beyond 51865 are never labeled

CONFIG = ModelConfig(
    name="whisper-medium",
    family="audio",
    num_layers=24,
    encoder_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=51968,  # 51865 padded to a multiple of 256
    max_seq_len=448,  # decoder positions (whisper max target length)
    max_source_len=32768,  # encoder frames for the prefill_32k cell
    block_pattern=("attn",),
    mlp_activation="gelu",
    norm="layernorm",
    use_rope=False,
    frontend="audio_frames",
    dtype="bfloat16",
)

SMOKE = CONFIG.replace(
    num_layers=2, encoder_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
    head_dim=16, d_ff=128, vocab_size=512, max_seq_len=64, max_source_len=32,
    dtype="float32",
)
