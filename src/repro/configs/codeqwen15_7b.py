"""codeqwen1.5-7b [dense] — 32L d_model=4096 32H MHA (kv=32) d_ff=13440
vocab=92416, SwiGLU, qwen1.5 architecture.
[hf:Qwen/CodeQwen1.5-7B; hf]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="codeqwen1.5-7b",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=32,
    head_dim=128,
    d_ff=13440,
    vocab_size=92416,
    max_seq_len=65536,
    block_pattern=("attn",),
    mlp_activation="swiglu",
    rope_theta=1000000.0,
    dtype="bfloat16",
)

SMOKE = CONFIG.replace(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
    d_ff=192, vocab_size=512, max_seq_len=128, dtype="float32",
)
