"""xlstm-1.3b [ssm] — 48 blocks d_model=2048, 4 heads, vocab=50304;
xLSTM[7:1] layout = 7 mLSTM (matrix memory) : 1 sLSTM (scalar memory,
memory mixing) per 8-block group.  Attention-free -> runs long_500k.
[arXiv:2405.04517; unverified]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=4,
    num_kv_heads=4,
    head_dim=512,
    d_ff=0,  # blocks carry their own projections (factor 2 / MLP 4/3)
    vocab_size=50304,
    max_seq_len=4096,
    block_pattern=("mlstm",) * 7 + ("slstm",),
    xlstm_proj_factor=2.0,
    slstm_mlp_factor=4 / 3,
    norm="layernorm",
    dtype="bfloat16",
)

SMOKE = CONFIG.replace(
    num_layers=8, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
    vocab_size=512, max_seq_len=128, dtype="float32",
)
