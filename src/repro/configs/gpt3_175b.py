"""gpt3-175b — the paper's own evaluation model (§5): its MLP GeMMs are
the 12288x49152 / 49152x12288 pair of Eqs. 16-21 (Fig. 3).  Not part of
the assigned pool; provided so the paper's exact shapes are selectable
for dry-runs/benchmarks (quantized serving is the paper's scenario).
[arXiv:2005.14165]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gpt3-175b",
    family="dense",
    num_layers=96,
    d_model=12288,
    num_heads=96,
    num_kv_heads=96,
    head_dim=128,
    d_ff=49152,
    vocab_size=50304,  # padded (original 50257)
    max_seq_len=2048,
    block_pattern=("attn",),
    mlp_activation="gelu",
    norm="layernorm",
    use_rope=False,  # learned positions in the original; stubbed via rope
    dtype="bfloat16",
)

SMOKE = CONFIG.replace(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
    d_ff=256, vocab_size=512, max_seq_len=128, dtype="float32",
)
