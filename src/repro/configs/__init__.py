"""Assigned-architecture registry: one module per architecture, each
exporting ``CONFIG`` (exact public config) and ``SMOKE`` (reduced
same-family config for CPU tests).  ``get_config(name)`` /
``get_smoke(name)`` / ``ARCHS`` are the public API; shapes.py defines the
input-shape cells and skip rules."""

from __future__ import annotations

import importlib

ARCHS = (
    "llama4_maverick",
    "qwen2_moe",
    "whisper_medium",
    "xlstm_1b3",
    "gemma_2b",
    "codeqwen15_7b",
    "starcoder2_15b",
    "gemma2_9b",
    "jamba_v01",
    "phi3_vision",
    "gpt3_175b",  # the paper's own model (not in the assigned pool)
)

ALIASES = {
    "llama4-maverick-400b-a17b": "llama4_maverick",
    "qwen2-moe-a2.7b": "qwen2_moe",
    "whisper-medium": "whisper_medium",
    "xlstm-1.3b": "xlstm_1b3",
    "gemma-2b": "gemma_2b",
    "codeqwen1.5-7b": "codeqwen15_7b",
    "starcoder2-15b": "starcoder2_15b",
    "gemma2-9b": "gemma2_9b",
    "jamba-v0.1-52b": "jamba_v01",
    "phi-3-vision-4.2b": "phi3_vision",
}


def _module(name: str):
    name = ALIASES.get(name, name)
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {ARCHS}")
    return importlib.import_module(f"repro.configs.{name}")


def get_config(name: str):
    return _module(name).CONFIG


def get_smoke(name: str):
    return _module(name).SMOKE
