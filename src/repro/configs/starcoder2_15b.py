"""starcoder2-15b [dense] — 40L d_model=6144 48H (GQA kv=4) d_ff=24576
vocab=49152, GQA + RoPE, GELU MLP, LayerNorm.
[arXiv:2402.19173; hf]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-15b",
    family="dense",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=4,
    head_dim=128,
    d_ff=24576,
    vocab_size=49152,
    max_seq_len=16384,
    block_pattern=("attn",),
    mlp_activation="gelu",
    norm="layernorm",
    rope_theta=100000.0,
    dtype="bfloat16",
)

SMOKE = CONFIG.replace(
    num_layers=2, d_model=64, num_heads=8, num_kv_heads=2, head_dim=8,
    d_ff=256, vocab_size=512, max_seq_len=128, dtype="float32",
)
