"""phi-3-vision-4.2b [vlm] — phi3-mini backbone: 32L d_model=3072 32H MHA
(kv=32) d_ff=8192 vocab=32064, SwiGLU; CLIP vision frontend STUBBED per
the assignment (input_specs provides precomputed patch embeddings, 576
patches prepended to the text tokens).
[hf:microsoft/Phi-3-vision-128k-instruct; hf]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    num_layers=32,
    d_model=3072,
    num_heads=32,
    num_kv_heads=32,
    head_dim=96,
    d_ff=8192,
    vocab_size=32064,
    max_seq_len=131072,
    block_pattern=("attn",),
    mlp_activation="swiglu",
    frontend="image_patches",
    num_patches=576,  # CLIP-L/14 @ 336px
    dtype="bfloat16",
)

SMOKE = CONFIG.replace(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=512, max_seq_len=128, num_patches=8,
    dtype="float32",
)
