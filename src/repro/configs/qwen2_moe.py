"""qwen2-moe-a2.7b [moe] — 24L d_model=2048 16H (kv=16) per-expert
d_ff=1408 vocab=151936; 60 routed experts top-4 + 4 shared experts
(fused shared hidden 4x1408=5632).  [hf:Qwen/Qwen1.5-MoE-A2.7B; hf]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab_size=151936,
    max_seq_len=4096,
    block_pattern=("moe",),  # every layer MoE
    mlp_activation="swiglu",
    num_experts=60,
    num_experts_per_tok=4,
    moe_d_ff=1408,
    num_shared_experts=4,
    shared_expert_d_ff=5632,
    rope_theta=1000000.0,
    dtype="bfloat16",
)

SMOKE = CONFIG.replace(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
    d_ff=32, moe_d_ff=32, shared_expert_d_ff=128, num_experts=6,
    num_experts_per_tok=2, vocab_size=512, max_seq_len=128,
    dtype="float32", capacity_factor=4.0,
)
