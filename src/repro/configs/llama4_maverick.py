"""llama4-maverick-400b-a17b [moe] — 48L d_model=5120 40H (GQA kv=8)
d_ff=8192 vocab=202048, MoE 128 experts top-1 + 1 shared, dense/MoE
interleaved every other layer (early-fusion Maverick layout).
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    max_seq_len=4096,
    block_pattern=("attn", "moe"),  # interleave_moe_layer_step = 2
    mlp_activation="swiglu",
    num_experts=128,
    num_experts_per_tok=1,
    moe_d_ff=8192,
    num_shared_experts=1,
    shared_expert_d_ff=8192,
    rope_theta=500000.0,
    qk_norm=True,
    dtype="bfloat16",
    param_dtype="float32",
)

SMOKE = CONFIG.replace(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, moe_d_ff=128, shared_expert_d_ff=128, num_experts=8,
    vocab_size=512, max_seq_len=128, dtype="float32", capacity_factor=4.0,
)
