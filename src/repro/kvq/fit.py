"""Fit the 16-entry KV codebook from real K/V activations.

Reuses calib's weighted Lloyd k-means (calib.fit.fit_codebook — entry 0
pinned at 0, initialized at the uniform int4 grid so the learned table
never does worse than uniform on the fitted samples).  Samples are the
*scale-normalized* K/V values the pool will actually store: we run the
model's dense-cache prefill over calibration batches, read every layer's
K/V out of the cache, and normalize each (token, head) row by its
``amax / 7`` write scale — exactly the quantizer's input distribution
(kvq.quantize.kv_quantize with bits=4).

Fitting is host-side numpy, offline, once per model — only the resulting
16 floats ride the hot path (inside KVQuantSpec, a jit-static tuple).
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.kvq.quantize import kv_dequantize, kv_quantize
from repro.kvq.spec import KVQuantSpec

INT4_MAX = 7


def collect_kv_samples(params, cfg, batches, *, max_samples: int = 1 << 20,
                       seed: int = 0) -> np.ndarray:
    """Scale-normalized K/V values from a dense-cache prefill of each
    batch.  Returns a flat float array (subsampled to ``max_samples``)."""
    from repro.models import transformer

    chunks = []
    for batch in batches:
        tokens = np.asarray(batch["tokens"])
        B, S = tokens.shape
        cache = transformer.init_cache(cfg, B, S, jnp.float32)
        _, cache = transformer.prefill(params, cfg, {"tokens": tokens}, cache)
        for group in cache.values():
            for name in ("k", "v"):
                if name not in group:
                    continue
                a = np.asarray(group[name], np.float64)  # (G, B, S, Hk, Dh)
                amax = np.abs(a).max(axis=-1, keepdims=True)
                z = a / np.where(amax > 0, amax / INT4_MAX, 1.0)
                chunks.append(z.reshape(-1))
    z = np.concatenate(chunks) if chunks else np.zeros((0,))
    if z.size > max_samples:
        rng = np.random.default_rng(seed)
        z = z[rng.choice(z.size, size=max_samples, replace=False)]
    return z


def fit_kv_codebook(params, cfg, batches=None, *, tokens=None,
                    iters: int = 25, max_samples: int = 1 << 20,
                    seed: int = 0) -> tuple[float, ...]:
    """Fit and return the 16-entry KV value table as a KVQuantSpec-ready
    tuple.  ``batches`` is an iterable of {'tokens': (B, S)} dicts;
    without one, a small synthetic batch is drawn (enough to place the
    centroids on the model's actual K/V distribution — e.g. RoPE'd keys
    are far from the weight distribution the weight codebooks see)."""
    from repro.calib.fit import fit_codebook

    if batches is None:
        if tokens is None:
            key = jax.random.PRNGKey(seed)
            S = min(32, cfg.max_seq_len)
            tokens = jax.random.randint(key, (2, S), 0, cfg.vocab_size)
        batches = [{"tokens": np.asarray(tokens)}]
    z = collect_kv_samples(params, cfg, batches, max_samples=max_samples,
                           seed=seed)
    cb = fit_codebook(z, iters=iters, sample_limit=max_samples, seed=seed)
    return tuple(float(v) for v in cb)


def kv_reconstruction_error(params, cfg, batches, spec: KVQuantSpec,
                            *, max_samples: int = 1 << 18,
                            seed: int = 0) -> float:
    """Mean squared quantize->dequantize error over real K/V samples —
    the value-space analogue of calib's weighted_quant_err, used by the
    quality bench to gate learned-vs-uniform (Lloyd is monotone from the
    uniform init, so on the fitting samples learned <= uniform holds by
    construction)."""
    z = collect_kv_samples(params, cfg, batches, max_samples=max_samples,
                           seed=seed)
    x = jnp.asarray(z, jnp.float32).reshape(1, -1)
    # pad to an even length for 4-bit packing of the flat sample row
    if x.shape[-1] % 2:
        x = jnp.pad(x, ((0, 0), (0, 1)))
    codes, scales = kv_quantize(x, spec)
    back = kv_dequantize(codes, scales, spec, x.shape[-1])
    return float(jnp.mean((back - x) ** 2))
