"""Quantize-on-write / dequantize-on-read ops for the paged KV pool.

All functions are shape-generic over a trailing ``head_dim`` axis: the
write path quantizes freshly projected K/V ``(B, C, Hk, Dh)`` before the
scatter into the pool, the read path dequantizes gathered code rows
``(B, W, Hk, Dhp)``.  The scale axis is everything but the last dim —
one symmetric scale per (token, kv-head), so a token's codes never need
revisiting after its write (append-only pool).

Code <-> value maps:

* int8: two's-complement byte, value = code (signed) * scale;
* int4 uniform: the paper §3.1 map ``b`` (core.packing.b_values), two
  codes packed per byte hi-nibble-first (core.packing.pack_storage);
* int4 learned: code = nearest entry of the spec's 16-value codebook on
  the scale-normalized value, value = codebook[code] * scale.

Round-trip exactness (tests/test_kvq.py): any input of the form
``grid_value * scale`` with ``|grid_value| <= qmax`` survives
quantize -> dequantize bit-exactly, because the amax-derived scale
reproduces exactly and round() hits the grid point.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import packing
from repro.kvq.spec import KVQuantSpec


def pack_codes(codes: jnp.ndarray, bits: int) -> jnp.ndarray:
    """codes (..., Dh) uint8 -> packed u8 storage (..., Dhp)."""
    if bits == 8:
        return jnp.asarray(codes, jnp.uint8)
    return packing.pack_storage(codes)


def unpack_codes(packed: jnp.ndarray, bits: int, head_dim: int
                 ) -> jnp.ndarray:
    """Inverse of :func:`pack_codes` (drops 4-bit pad columns)."""
    if bits == 8:
        return packed
    return packing.unpack_storage(packed, head_dim)


def kv_scales(x: jnp.ndarray, spec: KVQuantSpec) -> jnp.ndarray:
    """Symmetric per-(token, head) scale over the trailing head_dim:
    amax / qmax, with all-zero rows mapped to scale 1 (codes are all the
    zero code, so the round trip stays exact)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    return jnp.where(amax > 0, amax / spec.qmax, 1.0).astype(jnp.float32)


def kv_quantize(x: jnp.ndarray, spec: KVQuantSpec
                ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """x (..., Dh) float -> (packed codes (..., Dhp) uint8, scales (...)
    f32).  The write half of the pool's storage format."""
    xf = x.astype(jnp.float32)
    scale = kv_scales(xf, spec)
    z = xf / scale[..., None]
    if spec.codebook is None:
        q = jnp.clip(jnp.round(z), -spec.qmax, spec.qmax).astype(jnp.int32)
        mask = 0xFF if spec.bits == 8 else 0xF  # two's complement in u8
        codes = (q & mask).astype(jnp.uint8)
    else:
        cb = jnp.asarray(spec.codebook, jnp.float32)
        codes = jnp.argmin(
            jnp.abs(z[..., None] - cb), axis=-1).astype(jnp.uint8)
    return pack_codes(codes, spec.bits), scale


def decode_values(codes: jnp.ndarray, spec: KVQuantSpec) -> jnp.ndarray:
    """Unpacked codes (..., Dh) uint8 -> grid/codebook values f32 (the
    value table lookup, before the scale multiply)."""
    c = codes.astype(jnp.int32)
    if spec.codebook is not None:
        return jnp.take(jnp.asarray(spec.codebook, jnp.float32), c, axis=0)
    if spec.bits == 8:
        return jnp.where(c < 128, c, c - 256).astype(jnp.float32)
    return jnp.take(packing.b_values(), c, axis=0)


def kv_dequantize(packed: jnp.ndarray, scales: jnp.ndarray,
                  spec: KVQuantSpec, head_dim: int,
                  dtype=jnp.float32) -> jnp.ndarray:
    """(packed (..., Dhp) u8, scales (...)) -> values (..., Dh) ``dtype``.
    The read half; the jnp reference backend materializes this in HBM,
    the Pallas kernel runs the same math per block inside VMEM."""
    vals = decode_values(unpack_codes(packed, spec.bits, head_dim), spec)
    return (vals * scales[..., None].astype(jnp.float32)).astype(dtype)
