"""Paged-attention backends over the quantized KV pool.

Two peers register in the dispatch capability/priority registry under
mode ``"paged_attn"`` (duck-typed spec — Backend.supports only reads
``mode`` / ``storage`` / ``codebook``):

* ``paged_attn_jnp``     gather codes+scales by view_slots with
                         ``jnp.take`` and dequantize in HBM, then the
                         exact ``models.layers._sdpa`` math — the
                         reference/fallback, runs anywhere;
* ``paged_attn_pallas``  kernels/paged_attention.py — block tables via
                         scalar prefetch, dequantize in VMEM, flash
                         online softmax; outranks jnp on real TPU.

Selection (:func:`select`) honors ``KVQuantSpec.backend`` as a forced
override, and pins the jnp path whenever a mesh is active: the Pallas
kernel is a single-device program and we don't shard_map it yet, while
the jnp gather lowers through GSPMD with the existing ``constrain``
pool layouts (slots replicated, kvheads on the model axis).

The dequantized HBM footprint is the observable difference: the jnp
path materializes 2 * B * W * Hk * Dh f32 view bytes per layer-step
(engine gauge ``kv_dequant_hbm_bytes``); the Pallas path reports 0 —
the acceptance check that no HBM-resident dequantized K/V copy exists.
"""

from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from repro import obs
from repro.dispatch import registry
from repro.distributed.sharding import active_mesh, constrain
from repro.kvq.quantize import kv_dequantize
from repro.kvq.spec import KVQuantSpec

KV_STORAGE = "kv_u8"


class _AttnQuery(NamedTuple):
    """Duck-typed stand-in for QuantSpec in registry capability checks."""
    mode: str
    storage: str
    codebook: str


def run_jnp(spec: KVQuantSpec, cfg, q, pool, view_slots, positions, *,
            window: int = 0):
    """Reference: gather + dequantize the view in HBM, dense sdpa.

    q (B, C, H, Dh); pool the layer's quantized leaves (nb, bs, Hk, ...);
    view_slots (B, W) flat slots; positions (B, C).  Returns (B, C, H*Dh).
    """
    from repro.models import layers  # lazy: layers imports kvq

    nb, bs, hk, dhp = pool["k"].shape
    dh = q.shape[-1]
    kc = pool["k"].reshape(nb * bs, hk, dhp)
    vc = pool["v"].reshape(nb * bs, hk, dhp)
    ks = pool["k_scale"].reshape(nb * bs, hk)
    vs = pool["v_scale"].reshape(nb * bs, hk)
    kc = obs.jit_begin(kc, "kv_dequant")
    k_view = kv_dequantize(jnp.take(kc, view_slots, axis=0),
                           jnp.take(ks, view_slots, axis=0), spec, dh)
    v_view = kv_dequantize(jnp.take(vc, view_slots, axis=0),
                           jnp.take(vs, view_slots, axis=0), spec, dh)
    v_view = obs.jit_end(v_view, "kv_dequant", cat="kv",
                         hist="kv_dequant_s")
    k_view = constrain(k_view, "batch", "kv_seq", "kvheads", "head_dim")
    v_view = constrain(v_view, "batch", "kv_seq", "kvheads", "head_dim")
    m = layers.view_mask(view_slots.shape[1], positions, window=window)
    return layers._sdpa(cfg, q, k_view, v_view, m[:, None])


def run_pallas(spec: KVQuantSpec, cfg, q, pool, view_slots, positions, *,
               window: int = 0):
    """In-kernel dequant: derive block tables from the slot view (view
    position w*bs starts block w's slots, slot // bs = block id — exact
    because the scheduler builds views from whole blocks) and hand the
    quantized leaves straight to the kernel."""
    from repro.kernels.paged_attention import paged_attention_pallas

    bs = pool["k"].shape[1]
    block_tables = view_slots[:, ::bs] // bs
    B, C, H, dh = q.shape
    out = paged_attention_pallas(
        q, pool["k"], pool["k_scale"], pool["v"], pool["v_scale"],
        block_tables, positions, bits=spec.bits, codebook=spec.codebook,
        block_size=bs, window=window,
        softcap=float(cfg.attn_logit_softcap or 0.0))
    return out.reshape(B, C, H * dh)


registry.register_backend(
    "paged_attn_jnp", modes=("paged_attn",), run=run_jnp, priority=50,
    storages=(KV_STORAGE,), codebooks=("none", "learned"),
    description="gather+dequantize in HBM, dense sdpa (reference)",
    overwrite=True)
registry.register_backend(
    "paged_attn_pallas", modes=("paged_attn",), run=run_pallas,
    priority=lambda dev: 60 if dev == "tpu" else 40,
    storages=(KV_STORAGE,), codebooks=("none", "learned"),
    description="Pallas paged attention, dequantize in VMEM",
    overwrite=True)


def select(spec: KVQuantSpec) -> str:
    """Resolve the backend name serving this spec right now (forced
    override > mesh pin > registry priority)."""
    if spec.backend is not None:
        be = registry.get_backend(spec.backend)
        if "paged_attn" not in be.modes:
            raise ValueError(
                f"backend {spec.backend!r} is not a paged-attention "
                f"backend (modes={be.modes})")
        return spec.backend
    if active_mesh() is not None:
        return "paged_attn_jnp"
    query = _AttnQuery("paged_attn", KV_STORAGE, spec.codebook_kind)
    return registry.select_backend(query, 1).name


def run(spec: KVQuantSpec, cfg, q, pool, view_slots, positions, *,
        window: int = 0):
    """Dispatch one paged-attention step through the selected backend."""
    be = registry.get_backend(select(spec))
    return be.run(spec, cfg, q, pool, view_slots, positions, window=window)


def dequant_hbm_bytes(spec: KVQuantSpec, cfg, max_slots: int,
                      view_width: int) -> int:
    """Per-layer-step HBM bytes of dequantized K/V the selected backend
    materializes (engine gauge ``kv_dequant_hbm_bytes``; 0 for Pallas —
    the kernel's f32 K/V tiles live only in VMEM)."""
    if select(spec) == "paged_attn_pallas":
        return 0
    return 2 * max_slots * view_width * cfg.num_kv_heads * cfg.head_dim * 4
