"""Quantized pool tensors + the capacity arithmetic the engine and the
serving benchmarks size pools with.

A quantized pool entry stores four leaves instead of two:

    k        (num_blocks, block_size, Hk, Dhp)  uint8 packed codes
    k_scale  (num_blocks, block_size, Hk)       f32 per-slot-per-head
    v        (num_blocks, block_size, Hk, Dhp)  uint8
    v_scale  (num_blocks, block_size, Hk)       f32

Dhp = spec.packed_dim(head_dim) (= Dh at 8-bit, ceil(Dh/2) at 4-bit).
Alignment follows core/scales: codes row-major with the head_dim packed
innermost, scales a separate f32 tensor indexed by the same (block,
slot, head) coordinates — so a flat slot id addresses codes and scales
identically and serving/kv_blocks.py stays byte-agnostic (block tables
never learn what a slot costs).

Capacity math (README §Quantized KV cache):

    bytes/token = num_layers * 2 * Hk * (Dhp + 4)          [quantized]
                = num_layers * 2 * Hk * Dh * itemsize      [kv_quant=None]

so at f32 pools and Dh=64: int8 is ~3.8x and int4 ~7.1x smaller — the
same pool-byte budget holds 2–4x+ more resident sequences.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.kvq.spec import KVQuantSpec

SCALE_BYTES = 4  # scales are f32


def init_kv_pool(spec: KVQuantSpec, num_blocks: int, block_size: int,
                 num_kv_heads: int, head_dim: int) -> dict:
    """One layer's quantized pool entry (codes + scales, zero-filled —
    code 0 dequantizes to exactly 0 under every code map)."""
    dhp = spec.packed_dim(head_dim)
    codes = (num_blocks, block_size, num_kv_heads, dhp)
    scales = (num_blocks, block_size, num_kv_heads)
    return {"k": jnp.zeros(codes, jnp.uint8),
            "k_scale": jnp.zeros(scales, jnp.float32),
            "v": jnp.zeros(codes, jnp.uint8),
            "v_scale": jnp.zeros(scales, jnp.float32)}


def bytes_per_token(cfg, spec: KVQuantSpec | None = None,
                    dtype=jnp.float32) -> int:
    """Pool bytes one token slot costs across the whole layer stack
    (k + v, codes + scales).  ``spec=None`` prices the full-precision
    pool at ``dtype``."""
    hk, dh = cfg.num_kv_heads, cfg.head_dim
    if spec is None:
        per_layer = 2 * hk * dh * jnp.dtype(dtype).itemsize
    else:
        per_layer = 2 * hk * (spec.packed_dim(dh) + SCALE_BYTES)
    return cfg.num_layers * per_layer


def pool_bytes(cfg, num_blocks: int, block_size: int,
               spec: KVQuantSpec | None = None, dtype=jnp.float32) -> int:
    """Total device bytes of a pool of ``num_blocks`` (incl. scratch)."""
    return num_blocks * block_size * bytes_per_token(cfg, spec, dtype)


def blocks_for_bytes(cfg, budget_bytes: int, block_size: int,
                     spec: KVQuantSpec | None = None,
                     dtype=jnp.float32) -> int:
    """Largest pool (block count, incl. the scratch block) fitting a byte
    budget — what ``Engine(kv_pool_bytes=)`` admits against.  Always
    >= 2 (one scratch + one allocatable block) so a tiny budget degrades
    to a working, heavily-preempting pool rather than a crash."""
    bpb = block_size * bytes_per_token(cfg, spec, dtype)
    return max(2, int(np.floor(budget_bytes / bpb)))


def capacity_table(cfg, block_size: int, dtypes=(jnp.float32,),
                   specs: dict | None = None) -> list[dict]:
    """Rows for the README capacity table: bytes/token and relative
    resident-sequence multiplier per storage option."""
    rows = []
    base = bytes_per_token(cfg, None, dtypes[0])
    options = {"kv16": None, "kv8": KVQuantSpec(bits=8),
               "kv4": KVQuantSpec(bits=4)}
    if specs:
        options.update(specs)
    for name, spec in options.items():
        bpt = bytes_per_token(cfg, spec, dtypes[0])
        rows.append({"kv": name, "bytes_per_token": bpt,
                     "bytes_per_block": bpt * block_size,
                     "resident_multiplier": round(base / bpt, 2)})
    return rows
