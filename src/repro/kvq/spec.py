"""KVQuantSpec — the frozen description of *what the KV cache stores*.

Mirrors core/spec.QuantSpec's role for weights: a hashable value object
that model code closes over statically (it rides ``ModelConfig.kv_quant``
into the jitted serving step), validated eagerly at construction.

Storage layout (repro.kvq.quantize / repro.kvq.pool):

* ``bits=8``  one two's-complement int8 code per element in a uint8 byte,
              symmetric scale ``amax / 127`` per (token-slot, kv-head);
* ``bits=4``  two 4-bit codes per byte (hi nibble first — the same
              convention as core/packing.pack_storage), scale
              ``amax / 7``; codes map through either the uniform int4
              grid (two's-complement ``b`` of paper §3.1) or a 16-entry
              **learned codebook** fitted by calib's Lloyd k-means
              (repro.kvq.fit) — the paper's look-up-table reconstruction
              applied to the KV cache instead of the weights.

Scales are per-block-per-head arrays ``(num_blocks, block_size, Hk)``:
one scale per token slot of each block per kv head.  Slot granularity
(not one scale per whole block) keeps writes append-only — quantizing a
new token never re-quantizes earlier tokens in its block, so the pool
keeps the produce-once/consume-many property the kernels rely on.

``codebook`` is stored as a plain tuple of 16 floats so the spec stays
hashable (jit-static); entry 0 is pinned at 0.0 — code 0 is the padding
code, exactly like the weight-side codebooks (calib/codebook.py).
"""

from __future__ import annotations

from dataclasses import dataclass, replace

NLEVELS = 16  # 4-bit codebook entries (shared with core.packing.NLEVELS)
BITS = (8, 4)
CODEBOOKS = ("none", "learned")


@dataclass(frozen=True)
class KVQuantSpec:
    """What the paged KV pool stores (bits=16 / full precision is spelled
    ``kv_quant=None`` — the unchanged pre-kvq path, not a spec)."""

    bits: int = 8
    # 16-entry value table for bits=4 (None: uniform int4 grid).  A tuple
    # of floats, entry 0 == 0.0 (padding code dequantizes to exactly 0).
    codebook: tuple[float, ...] | None = None
    # force a registered paged-attention backend by name
    # ('paged_attn_jnp' | 'paged_attn_pallas'; None: auto-selection via
    # the dispatch capability/priority registry)
    backend: str | None = None

    def __post_init__(self):
        if self.bits not in BITS:
            raise ValueError(
                f"kv bits must be one of {BITS} (full precision is "
                f"kv_quant=None), got {self.bits}")
        if self.codebook is not None:
            if self.bits != 4:
                raise ValueError("codebooks are a 16-entry (4-bit) "
                                 f"construct; bits={self.bits} cannot use one")
            cb = tuple(float(v) for v in self.codebook)
            if len(cb) != NLEVELS:
                raise ValueError(
                    f"codebook must have {NLEVELS} entries, got {len(cb)}")
            if cb[0] != 0.0:
                raise ValueError("codebook entry 0 is the padding code and "
                                 f"must be 0.0, got {cb[0]}")
            object.__setattr__(self, "codebook", cb)

    # ------------------------------------------------------------ derived
    @property
    def qmax(self) -> int:
        """Symmetric integer range of the uniform grid (scale = amax/qmax)."""
        return 127 if self.bits == 8 else 7

    @property
    def codebook_kind(self) -> str:
        """'none' | 'learned' — the dispatch capability-predicate axis."""
        return "none" if self.codebook is None else "learned"

    @property
    def codes_per_byte(self) -> int:
        return 1 if self.bits == 8 else 2

    def packed_dim(self, head_dim: int) -> int:
        """Packed-u8 length of one head's code row (2 codes/byte at 4-bit)."""
        return head_dim if self.bits == 8 else -(-head_dim // 2)

    def code_bytes(self, head_dim: int) -> int:
        return self.packed_dim(head_dim)

    def with_codebook(self, values) -> "KVQuantSpec":
        """A copy carrying ``values`` (any 16-float sequence, e.g. a
        checkpoint-restored np array) as the learned codebook."""
        return replace(self, codebook=tuple(float(v) for v in values))

    def describe(self) -> str:
        cb = "learned" if self.codebook is not None else "uniform"
        return f"kv_int{self.bits}[{cb}]"
