"""Quantized paged KV cache — the paper's LUT quantization applied to
serving memory.

The pool stores low-bit codes + per-slot-per-head scales instead of
bf16/f32 values (2–4x+ more resident sequences per pool byte), and the
Pallas paged-attention kernel (kernels/paged_attention.py) dequantizes
K/V inside VMEM at consume time — the serving-side analogue of
msGeMM's produce-once/consume-many LUT reconstruction.

Public surface:

* :class:`KVQuantSpec` — frozen, hashable storage description
  (``ModelConfig.kv_quant``);
* :func:`kv_quantize` / :func:`kv_dequantize` — write/read ops;
* :func:`init_kv_pool`, :func:`bytes_per_token`, :func:`pool_bytes`,
  :func:`blocks_for_bytes`, :func:`capacity_table` — pool tensors and
  the capacity arithmetic the engine sizes pools with;
* :mod:`repro.kvq.attention` — paged-attention backends (importing this
  package registers them in the dispatch registry);
* :func:`fit_kv_codebook` — Lloyd-fitted 16-entry KV codebook (lazy:
  pulls in calib only when called).
"""

from __future__ import annotations

from repro.kvq import attention  # noqa: F401  (registers backends)
from repro.kvq.pool import (blocks_for_bytes, bytes_per_token,  # noqa: F401
                            capacity_table, init_kv_pool, pool_bytes)
from repro.kvq.quantize import (kv_dequantize, kv_quantize,  # noqa: F401
                                pack_codes, unpack_codes)
from repro.kvq.spec import KVQuantSpec  # noqa: F401


def fit_kv_codebook(*args, **kwargs):
    """Lazy re-export of :func:`repro.kvq.fit.fit_kv_codebook` (keeps
    calib out of the serving import path)."""
    from repro.kvq.fit import fit_kv_codebook as _fit
    return _fit(*args, **kwargs)


def kv_reconstruction_error(*args, **kwargs):
    from repro.kvq.fit import kv_reconstruction_error as _err
    return _err(*args, **kwargs)
