"""Shape-keyed autotuner with a persistent JSON plan cache.

For a (spec, m, k, batch, backend, device) key the tuner times every
candidate tile/chunk configuration on synthetic data shaped exactly like
the real call, picks the fastest, and persists the winner — so a serving
process warm-starts from disk and never retunes a shape it (or any
earlier process on the machine) has already measured.

Cache location, first hit wins:

1. ``REPRO_PLAN_CACHE`` env var (file path; CI points it next to the
   benchmark artifacts);
2. ``$XDG_CACHE_HOME/msgemm-repro/plans.json``;
3. ``~/.cache/msgemm-repro/plans.json``.

The JSON is a flat {key: plan-fields} map — human-diffable, and tolerant
on load (a corrupt or newer-versioned file degrades to an empty cache,
never an exception on the serving path).

CLI::

    python -m repro.dispatch.autotune --smoke \
        --cache benchmarks/results/autotune_cache.json

tunes a tiny interpret-mode shape grid twice, asserting the second pass
is served entirely from the reloaded cache (the CI smoke step).
"""

from __future__ import annotations

import dataclasses
import json
import os
import time
from pathlib import Path

import numpy as np

from repro import obs
from repro.core.spec import QuantSpec
from repro.dispatch import registry
from repro.dispatch.plan import (
    ExecPlan, ExecPolicy, heuristic_plan, plan_d, plan_key,
)

_CACHE_VERSION = 3  # v3: key gains the mesh/shard tag; m/k/b are
# local-shard shapes (a 1-device winner is never replayed as a sharded
# plan, and every mesh shape tunes independently).  v2 files migrate on
# load: their keys gain the unsharded '|sh-' tag — v2 was only ever
# written off-mesh, so the entries keep their value without ever
# leaking into sharded lookups.
# NB: 'interpret' and 'shard' are deliberately not persisted — both are
# runtime/policy overlays (plan() re-attaches the active policy's
# interpret mode and the live mesh's ShardSpec on every cache hit);
# persisting interpret would let an interpret-mode tuning run pin the
# ~100x slower interpreter onto later compiled runs of the same shape.
_PLAN_FIELDS = ("backend", "tm", "tj", "tb", "consume_chunk",
                "acc_in_vmem", "acc_dtype", "epilogue")

# observability hook: incremented per timed candidate (tests assert the
# second run of a cached shape does zero timing)
num_timed_candidates = 0

# how many predicted-best candidates the model-guided search measures
MODEL_TOP_K = 3


def default_cache_path() -> Path:
    env = os.environ.get("REPRO_PLAN_CACHE")
    if env:
        return Path(env)
    base = os.environ.get("XDG_CACHE_HOME") or os.path.join(
        os.path.expanduser("~"), ".cache")
    return Path(base) / "msgemm-repro" / "plans.json"


class PlanCache:
    """In-memory view of the persistent plan cache (lazy load)."""

    def __init__(self, path: str | os.PathLike | None = None):
        self.path = Path(path) if path is not None else default_cache_path()
        self._plans: dict[str, ExecPlan] = {}
        self._timings: dict[str, list] = {}
        self._shard_variants: dict[str, dict] = {}
        self._loaded = False

    # ------------------------------------------------------------- io
    def load(self) -> "PlanCache":
        self._loaded = True
        from repro.obs import artifacts

        # parse + CRC check; a corrupt file is quarantined aside
        # (artifact_quarantined_total{artifact="plan_cache"}) and the
        # cache rebuilds empty — warm restarts survive bit rot.
        raw = artifacts.load_json_checked(self.path, "plan_cache")
        if raw is None:
            return self
        try:
            ver = raw.get("version")
            if ver not in (2, _CACHE_VERSION):
                return self
            for key, fields in raw.get("plans", {}).items():
                if ver == 2:
                    # v2 keys never carried a mesh tag (the format
                    # predates sharded planning) and were only written
                    # by unsharded runs: migrate to the '-' tag so they
                    # keep serving single-device lookups but can never
                    # be replayed as sharded plans.
                    key = key + "|sh-"
                self._plans[key] = ExecPlan(
                    **{f: fields.get(f) for f in _PLAN_FIELDS
                       if fields.get(f) is not None},
                    source="autotuned")
            # additive key (still version 3): per-key candidate timing
            # tables from the tuning run that produced each winner.
            # Older readers never look at it; older writers simply drop
            # it on their next save.
            t = raw.get("timings")
            if isinstance(t, dict):
                self._timings.update(t)
            # additive key (still version 3): measured pipelined-
            # collective winners per one-shot base key (ISSUE 10).  v3
            # files written before the table existed simply lack it.
            sv = raw.get("shard_variants")
            if isinstance(sv, dict):
                self._shard_variants.update(sv)
        except (ValueError, TypeError, AttributeError):
            # parsed + CRC-clean but schema-invalid (e.g. hand-edited):
            # quarantine like any other corruption and start empty
            self._plans.clear()
            self._timings.clear()
            self._shard_variants.clear()
            artifacts.quarantine(self.path, "plan_cache", reason="schema")
        return self

    def save(self) -> None:
        from repro import faults
        from repro.obs import artifacts

        payload = {"version": _CACHE_VERSION, "plans": {
            key: {f: getattr(p, f) for f in _PLAN_FIELDS
                  if getattr(p, f) is not None}
            for key, p in sorted(self._plans.items())}}
        if self._timings:
            payload["timings"] = {k: self._timings[k]
                                  for k in sorted(self._timings)}
        if self._shard_variants:
            payload["shard_variants"] = {
                k: self._shard_variants[k]
                for k in sorted(self._shard_variants)}
        artifacts.atomic_write_json(self.path, artifacts.stamp_crc(payload))
        ev = faults.fire("corrupt_plan_cache")
        if ev is not None:
            faults.corrupt_file(self.path, ev)

    # ----------------------------------------------------------- plans
    def get(self, key: str) -> ExecPlan | None:
        if not self._loaded:
            self.load()
        return self._plans.get(key)

    def put(self, key: str, plan: ExecPlan, *, persist: bool = True,
            timings: list | None = None) -> None:
        if not self._loaded:
            self.load()
        self._plans[key] = plan
        if timings is not None:
            self._timings[key] = timings
        if persist:
            self.save()

    def timings(self, key: str) -> list | None:
        """Candidate timing rows recorded when ``key`` was tuned (None
        for keys tuned before timings were persisted)."""
        if not self._loaded:
            self.load()
        return self._timings.get(key)

    # --------------------------------------------- pipelined collectives
    def shard_variant(self, base_key: str) -> dict | None:
        """Measured pipelined-collective winner for the one-shot plan
        keyed by ``base_key``: {'pipeline_chunks', 'collective_impl',
        'rows'} (rows = the per-variant timing table), or None when this
        linear's variants were never tuned."""
        if not self._loaded:
            self.load()
        return self._shard_variants.get(base_key)

    def put_shard_variant(self, base_key: str, variant: dict, *,
                          persist: bool = True) -> None:
        if not self._loaded:
            self.load()
        self._shard_variants[base_key] = variant
        if persist:
            self.save()

    def __len__(self) -> int:
        if not self._loaded:
            self.load()
        return len(self._plans)


_cache: PlanCache | None = None


def cache() -> PlanCache:
    global _cache
    if _cache is None:
        _cache = PlanCache()
    return _cache


def set_cache_path(path: str | os.PathLike | None) -> PlanCache:
    """Point the process at a specific cache file (None -> default)."""
    global _cache
    _cache = PlanCache(path)
    return _cache


# ------------------------------------------------------------ candidates
def _round_up(v: int, mult: int) -> int:
    return -(-v // mult) * mult


def candidate_plans(spec: QuantSpec, d: int, m: int, k: int, batch: int,
                    backend: str, interpret: bool | None,
                    acc_dtype: str = "float32") -> list[ExecPlan]:
    """Deterministic candidate grid for one shape key.  Always contains
    the heuristic choice, so tuning can only match or beat it.  For the
    Pallas backends the grid also covers the accumulation knob
    (``acc_in_vmem`` False — the legacy per-step formulation), so a shape
    where the reordered grid somehow loses is caught by measurement."""
    from repro.kernels import ops

    pol = ExecPolicy(interpret=interpret, acc_dtype=acc_dtype)
    base = heuristic_plan(spec, d, m, k, batch, backend, pol)
    cands = {base}
    if backend in ("msgemm_pallas", "int4_pallas"):
        cands.add(dataclasses.replace(base, acc_in_vmem=False))
    if backend == "msgemm_jnp":
        for chunk in (1, 2, 4, 8):
            cands.add(dataclasses.replace(base, consume_chunk=chunk))
    elif backend == "msgemm_pallas":
        kc = -(-k // d)
        cpb = spec.scale_block // d
        n = 16 ** d
        tjs = {t for t in (cpb, 2 * cpb, 4 * cpb, 8 * cpb)
               if t <= max(_round_up(kc, cpb), cpb)}
        for tj in tjs:
            for tm in (64, 128, 256):
                for tb in (8, 64, 128):
                    if n * tj * tb * 4 > ops.VMEM_BUDGET:
                        continue
                    tmv = min(tm, _round_up(m, 8))
                    tbv = min(tb, _round_up(batch, 8))
                    cands.add(dataclasses.replace(
                        base, tm=tmv, tj=tj, tb=tbv,
                        # keep the persisted flag truthful: a candidate
                        # whose stripe cannot fit runs (and is timed as)
                        # the legacy accumulation
                        acc_in_vmem=base.acc_in_vmem
                        and ops.acc_stripe_fits(m, tmv, tbv)))
    elif backend == "int4_pallas":
        sb = spec.scale_block
        for tk in (sb, 2 * sb, 4 * sb):
            if tk % 2:
                continue
            for tb in (8, 64, 128):
                cands.add(dataclasses.replace(
                    base, tj=tk, tb=min(tb, _round_up(batch, 8))))
    out = sorted(cands, key=lambda p: (p.tm or 0, p.tj or 0, p.tb or 0,
                                       p.consume_chunk, p.acc_in_vmem))
    # interpret mode multiplies kernel cost ~100x — keep the sweep tiny
    if interpret or (interpret is None and registry.device_kind() != "tpu"):
        out = out[:6]
        if base not in out:
            out.append(base)
        if backend in ("msgemm_pallas", "int4_pallas"):
            legacy = dataclasses.replace(base, acc_in_vmem=False)
            if legacy not in out:  # keep the acc knob measurable
                out.append(legacy)
    return out


# ------------------------------------------------------------ synthetic
def _synthetic_call(spec: QuantSpec, d: int, m: int, k: int, batch: int):
    """Build (params, x) shaped exactly like the real linear call."""
    from repro.core import packing

    rng = np.random.default_rng(0)
    codes = rng.integers(0, 16, size=(m, k)).astype(np.uint8)
    params = {"scales": np.abs(
        rng.standard_normal((m, -(-k // spec.scale_block)))
    ).astype(np.float32) + 0.1}
    if spec.storage == "packed_idx":
        params["idx"] = np.asarray(packing.pack_indices(codes, d))
    else:
        params["u8"] = np.asarray(packing.pack_storage(codes))
    x = rng.standard_normal((batch, k)).astype(np.float32)
    return params, x


def _time_plan(backend: registry.Backend, spec: QuantSpec, p: ExecPlan,
               params, x, k: int, reps: int) -> float:
    global num_timed_candidates
    num_timed_candidates += 1
    import jax

    run = lambda: jax.block_until_ready(
        backend.run(spec, p, params, x, k=k))
    run()  # warmup / compile
    best = float("inf")
    for _ in range(max(reps, 1)):
        t0 = time.perf_counter()
        run()
        best = min(best, time.perf_counter() - t0)
    reg = obs.registry()
    reg.counter("dispatch_autotune_candidates_total",
                help="tile candidates measured",
                backend=backend.name).inc()
    reg.histogram("dispatch_autotune_candidate_s",
                  help="best-of-reps candidate wall time",
                  backend=backend.name).observe(best)
    return best


# ------------------------------------------------------- model pruning
def _model_prune(cands: list[ExecPlan], spec: QuantSpec, d: int, m: int,
                 k: int, batch: int, backend: str, base: ExecPlan,
                 calib) -> list[ExecPlan]:
    """Rank candidates by the calibrated perf model's predicted time and
    keep only the predicted-best ``MODEL_TOP_K``.  The heuristic base
    plan is always in the measured set (replacing the last pick when the
    model ranks it out), so model-guided tuning can only match or beat
    the heuristic — a badly extrapolating calibration costs tuning
    quality, never correctness or a worse-than-default plan."""
    from repro.obs import perfmodel

    def pred(p: ExecPlan) -> float:
        feats = perfmodel.features(
            backend, spec.mode, max(d, 1), spec.scale_block, m, k, batch,
            tm=p.tm, tj=p.tj, tb=p.tb, consume_chunk=p.consume_chunk,
            acc_in_vmem=p.acc_in_vmem)
        return perfmodel.predict_features(feats, calib,
                                          backend=backend).t_total_s

    ranked = sorted(cands, key=pred)
    keep = ranked[:MODEL_TOP_K]
    if base not in keep:
        keep[-1] = base
    return keep


# -------------------------------------------------------------- autotune
def autotune(spec: QuantSpec, m: int, k: int, batch: int, backend: str, *,
             device: str | None = None, interpret: bool | None = None,
             acc_dtype: str = "float32", reps: int = 2,
             persist: bool = True, tag: str = "-",
             search: str = "auto") -> ExecPlan:
    """Measure candidates for one shape key; cache and return the winner.

    ``m/k/batch`` are the shapes the backend will actually execute on
    one device — under a mesh the caller (dispatch.plan / warm) passes
    the *local-shard* shapes and the matching mesh/shard ``tag``, so
    candidates are synthesized and timed at exactly the per-device size
    and the winner is keyed to that mesh shape.

    ``search`` selects the sweep: ``'full'`` measures every candidate;
    ``'model'``/``'auto'`` rank candidates with the calibrated analytic
    perf model (obs.perfmodel) and measure only the predicted-best
    ``MODEL_TOP_K`` (heuristic base always included).  When no
    calibration matching this (device, interpret) partition exists, both
    fall back to the full sweep (``dispatch_autotune_model_fallback_total``
    counts these; ``dispatch_autotune_model_pruned_total`` counts the
    candidates a model-guided run skipped).

    Returns the cached plan immediately when the key is known (from this
    process or a previous one via the JSON file)."""
    device = device or registry.device_kind()
    be = registry.get_backend(backend)
    d = plan_d(spec, m, k)
    key = plan_key(backend, spec, d, m, k, batch, device, acc_dtype, tag)
    hit = cache().get(key)
    if hit is not None:
        # interpret is runtime policy, never part of the cached tuning
        return dataclasses.replace(hit, interpret=interpret)
    pol = ExecPolicy(interpret=interpret, acc_dtype=acc_dtype)
    if not be.tunable:
        return heuristic_plan(spec, d, m, k, batch, backend, pol)
    cands = candidate_plans(spec, d, m, k, batch, backend, interpret,
                            acc_dtype)
    # the partition every timing row in this run belongs to — persisted
    # per row so calibration never mixes interpreter and compiled times
    from repro.obs import perfmodel

    eff_interpret = perfmodel.effective_interpret(interpret)
    pruned = 0
    if search in ("model", "auto") and len(cands) > MODEL_TOP_K:
        calib = perfmodel.load_calibration(device=device,
                                           interpret=eff_interpret)
        reg = obs.registry()
        if calib is None:
            reg.counter("dispatch_autotune_model_fallback_total",
                        help="model-guided searches that fell back to "
                             "the full sweep (no matching calibration)",
                        backend=backend).inc()
        else:
            base = heuristic_plan(spec, d, m, k, batch, backend, pol)
            kept = _model_prune(cands, spec, d, m, k, batch, backend,
                                base, calib)
            pruned = len(cands) - len(kept)
            cands = kept
            reg.counter("dispatch_autotune_model_pruned_total",
                        help="candidates skipped by model-guided search",
                        backend=backend).inc(pruned)
    params, x = _synthetic_call(spec, d, m, k, batch)
    with obs.tracer().span("autotune", cat="dispatch", key=key,
                           candidates=len(cands), model_pruned=pruned):
        timed = [(_time_plan(be, spec, p, params, x, k, reps), i, p)
                 for i, p in enumerate(cands)]
    best_s, best_i, winner = min(timed)
    winner = dataclasses.replace(winner, source="autotuned")
    # candidate timings ride along in the cache JSON instead of being
    # discarded — they are the calibration data for the analytic perf
    # model (obs.perfmodel) and make regressions diffable across runs.
    # 'interpret'/'device' tag the partition each row was measured under
    # (additive; readers skip untagged pre-tag rows).
    rows = [{"s": t, "tm": p.tm, "tj": p.tj, "tb": p.tb,
             "consume_chunk": p.consume_chunk,
             "acc_in_vmem": p.acc_in_vmem, "winner": i == best_i,
             "interpret": eff_interpret, "device": device}
            for t, i, p in sorted(timed)]
    cache().put(key, winner, persist=persist, timings=rows)
    # same contract as a cache hit: the caller's interpret overlays the
    # winner (a fresh tune and a reload must return identical plans)
    return dataclasses.replace(winner, interpret=interpret)


# ------------------------------------------------- pipelined collectives
# (pipeline_chunks, collective_impl) candidates timed against the
# one-shot plan for every k-sharded linear when ExecPolicy.shard_pipeline
# is 0 (auto).  Chunk counts that don't divide the local k slice (or
# break packed-storage alignment) are dropped per linear.
SHARD_VARIANT_GRID = ((1, "xla"), (1, "ring"), (2, "ring"), (4, "ring"),
                      (2, "xla"))


def _variant_prune(variants, spec, shard, m: int, batch: int,
                   device: str, interpret: bool | None,
                   search: str) -> list:
    """Model-guided pruning of the variant grid: rank by the calibrated
    collective-time term (obs.perfmodel) and keep the one-shot base plus
    the predicted-best few.  No collective calibration -> measure all
    (same fallback contract as the tile sweep)."""
    from repro.distributed import collectives as coll
    from repro.obs import perfmodel

    if search not in ("model", "auto") or len(variants) <= MODEL_TOP_K:
        return list(variants)
    calib = perfmodel.load_calibration(
        device=device, interpret=perfmodel.effective_interpret(interpret))
    reg = obs.registry()
    if calib is None or not getattr(calib, "collective", None):
        reg.counter("dispatch_autotune_model_fallback_total",
                    help="model-guided searches that fell back to "
                         "the full sweep (no matching calibration)",
                    backend="shard_variants").inc()
        return list(variants)
    n = shard.axis_size(shard.k)
    lb = batch // shard.axis_size(shard.batch)
    elems = m * lb  # the partial output one device contracts

    def pred(v):
        pc, impl = v
        hops, nbytes = coll.collective_cost(
            impl=impl, collective=shard.collective, axis_size=n,
            elems=elems, pipeline_chunks=pc)
        return perfmodel.predict_collective(
            calls=pc, hops=hops, nbytes=nbytes, collective=calib.collective)

    ranked = sorted(variants, key=pred)
    keep = ranked[:MODEL_TOP_K]
    if (1, "xla") not in keep:
        keep[-1] = (1, "xla")
    reg.counter("dispatch_autotune_model_pruned_total",
                help="candidates skipped by model-guided search",
                backend="shard_variants").inc(len(variants) - len(keep))
    return keep


def tune_shard_variants(spec: QuantSpec, m: int, k: int, batch: int,
                        backend: str, shard, mesh, *,
                        device: str | None = None,
                        interpret: bool | None = None,
                        acc_dtype: str = "float32", reps: int = 1,
                        persist: bool = True,
                        search: str = "auto") -> dict:
    """Time pipelined-collective variants of one k-sharded linear under
    the live mesh and cache the winner.

    ``m/k/batch`` are GLOBAL shapes and ``shard`` the linear's derived
    one-shot-or-not ShardSpec; each (pipeline_chunks, collective_impl)
    candidate from ``SHARD_VARIANT_GRID`` re-shapes it, gets a kernel
    plan on its per-chunk shapes (cached winner or heuristic — kernel
    tiles and collective layout tune independently), and the whole
    ``run_sharded`` linear (compute + collective, epilogue excluded) is
    timed end-to-end on synthetic global operands.  The winner lands in
    the plan cache's additive ``shard_variants`` table keyed by the
    one-shot base plan key, which is how plan() replays it at trace time
    and how warm restarts skip re-measuring.  Timing rows carry the
    analytic (hops, bytes) of each candidate — the calibration data for
    perfmodel's collective-time term."""
    global num_timed_candidates
    import jax

    from repro.dispatch import shard as _shard
    from repro.distributed import collectives as coll
    from repro.obs import perfmodel

    device = device or registry.device_kind()
    base_shard = dataclasses.replace(shard, pipeline_chunks=1,
                                     collective_impl="xla")
    d = plan_d(spec, m, k)
    blm, blk, blb = base_shard.exec_mkb(m, k, batch)
    base_key = plan_key(backend, spec, d, blm, blk, blb, device,
                        acc_dtype, base_shard.tag())
    hit = cache().shard_variant(base_key)
    if hit is not None:
        return hit

    n = shard.axis_size(shard.k)
    k_local = k // n
    cands, seen = [], set()
    for pc, impl in SHARD_VARIANT_GRID:
        if pc > 1 and (k_local % pc
                       or not _shard._quant_aligned(spec, k_local // pc)):
            continue
        if (pc, impl) not in seen:
            seen.add((pc, impl))
            cands.append((pc, impl))
    cands = _variant_prune(cands, spec, shard, m, batch, device,
                           interpret, search)

    be = registry.get_backend(backend)
    pol = ExecPolicy(interpret=interpret, acc_dtype=acc_dtype)
    params, x = _synthetic_call(spec, d, m, k, batch)
    eff_interpret = perfmodel.effective_interpret(interpret)
    lb = batch // shard.axis_size(shard.batch)
    elems = m * lb
    rows = []
    with obs.tracer().span("autotune.shard_variants", cat="dispatch",
                           key=base_key, candidates=len(cands)):
        for pc, impl in cands:
            cand = dataclasses.replace(shard, pipeline_chunks=pc,
                                       collective_impl=impl)
            clm, clk, clb = cand.exec_mkb(m, k, batch)
            ckey = plan_key(backend, spec, d, clm, clk, clb, device,
                            acc_dtype, cand.tag())
            p = cache().get(ckey) or heuristic_plan(spec, d, clm, clk, clb,
                                                    backend, pol)
            p = dataclasses.replace(p, interpret=interpret, shard=cand)
            fn = jax.jit(lambda pr, xr, _p=p: _shard.run_sharded(
                be, spec, _p, pr, xr, k=k, mesh=mesh))
            num_timed_candidates += 1
            jax.block_until_ready(fn(params, x))  # compile + warm
            best = float("inf")
            for _ in range(max(reps, 1)):
                t0 = time.perf_counter()
                jax.block_until_ready(fn(params, x))
                best = min(best, time.perf_counter() - t0)
            hops, nbytes = coll.collective_cost(
                impl=impl, collective=cand.collective, axis_size=n,
                elems=elems, pipeline_chunks=pc)
            rows.append({"s": best, "pipeline_chunks": pc,
                         "collective_impl": impl, "hops": hops,
                         "bytes": nbytes, "interpret": eff_interpret,
                         "device": device, "winner": False})
            obs.registry().counter(
                "dispatch_autotune_candidates_total",
                help="tile candidates measured",
                backend="shard_variants").inc()
    best_row = min(rows, key=lambda r: r["s"])
    best_row["winner"] = True
    variant = {"pipeline_chunks": best_row["pipeline_chunks"],
               "collective_impl": best_row["collective_impl"],
               "rows": sorted(rows, key=lambda r: r["s"])}
    cache().put_shard_variant(base_key, variant, persist=persist)
    return variant


def warm(requests, *, policy: ExecPolicy | None = None,
         persist: bool = True) -> dict[str, ExecPlan]:
    """Resolve a batch of collected plan requests up front (engine
    build).  ``requests`` holds ``dispatch.plan.PlanRequest`` entries
    from ``dispatch.collecting()`` (bare (spec, m, k, batch, backend)
    tuples from older callers still work — they warm unsharded).  Shapes
    in the requests are GLOBAL; each request's ShardSpec maps them to
    the local-shard shapes + mesh tag that key the cache, mirroring
    exactly what plan() will compute at trace time.  With
    ``policy.autotune`` each tunable key is measured (and its winner
    persisted); otherwise keys resolve to their cached winner when one
    exists, falling back to the heuristic — heuristic plans are NOT
    written to the cache, so a later autotune run can still improve
    them.

    ``policy.shard_pipeline == 0`` (auto) additionally times pipelined-
    collective variants of every k-sharded request under the live mesh
    (``tune_shard_variants``) before warming its kernel plan — the
    variant winner reshapes the request, so the kernel plan is tuned on
    the winner's per-chunk shapes and plan() finds both at trace time.
    shard_pipeline=0 is its own opt-in: the variant grid is timed even
    when kernel-tile autotuning is off (kernel plans then stay
    heuristic for every variant, so the comparison isolates the
    collective strategy)."""
    policy = policy or ExecPolicy()
    out: dict[str, ExecPlan] = {}
    device = registry.device_kind()
    mesh = None
    if policy.shard_pipeline == 0:
        from repro.distributed.sharding import active_mesh

        mesh = active_mesh()
    for req in dict.fromkeys(requests):
        spec, m, k, batch, backend = req[:5]
        shard = getattr(req, "shard", None)
        tag = getattr(req, "tag", "-")
        d = plan_d(spec, m, k)
        if mesh is not None and shard is not None and shard.k is not None:
            search = (policy.autotune
                      if policy.autotune in ("model", "full") else "auto")
            var = tune_shard_variants(
                spec, m, k, batch, backend, shard, mesh, device=device,
                interpret=policy.interpret, acc_dtype=policy.acc_dtype,
                persist=persist, search=search)
            shard = dataclasses.replace(
                shard, pipeline_chunks=int(var["pipeline_chunks"]),
                collective_impl=str(var["collective_impl"]))
            tag = shard.tag()
        lm, lk, lb = shard.exec_mkb(m, k, batch) if shard is not None \
            else (m, k, batch)
        key = plan_key(backend, spec, d, lm, lk, lb, device,
                       policy.acc_dtype, tag)
        if policy.autotune and registry.get_backend(backend).tunable:
            search = (policy.autotune
                      if policy.autotune in ("model", "full") else "auto")
            p = autotune(spec, lm, lk, lb, backend, device=device,
                         interpret=policy.interpret,
                         acc_dtype=policy.acc_dtype, persist=persist,
                         tag=tag, search=search)
        else:
            hit = cache().get(key)
            p = hit if hit is not None else heuristic_plan(
                spec, d, lm, lk, lb, backend, policy)
        out[key] = dataclasses.replace(p, shard=shard)
    return out


# ------------------------------------------------------------------- CLI
def _smoke(cache_path: str | None) -> int:
    """Tiny interpret-mode tune: write cache -> reload -> assert hits."""
    global num_timed_candidates
    set_cache_path(cache_path)
    shapes = [("msgemm", "msgemm_jnp", 2, 16, 24, 8),
              ("msgemm", "msgemm_pallas", 2, 16, 24, 8),
              ("int4_dequant", "int4_pallas", 2, 16, 32, 8)]
    num_timed_candidates = 0
    plans = {}
    for mode, backend, d, m, k, batch in shapes:
        spec = QuantSpec(mode=mode, d=d, scale_block=4 * d,
                         storage="packed_u8" if backend == "int4_pallas"
                         else "packed_idx")
        p = autotune(spec, m, k, batch, backend, interpret=True, reps=1)
        plans[backend] = p
        print(f"[autotune] {backend:14s} m={m} k={k} b={batch} -> "
              f"tm={p.tm} tj={p.tj} tb={p.tb} chunk={p.consume_chunk} "
              f"({p.source})")
    first_pass = num_timed_candidates
    print(f"[autotune] cache: {cache().path} ({len(cache())} plans, "
          f"{first_pass} candidates timed)")

    # fresh in-memory cache, same file: everything must come from disk
    set_cache_path(cache_path)
    num_timed_candidates = 0
    for mode, backend, d, m, k, batch in shapes:
        spec = QuantSpec(mode=mode, d=d, scale_block=4 * d,
                         storage="packed_u8" if backend == "int4_pallas"
                         else "packed_idx")
        p = autotune(spec, m, k, batch, backend, interpret=True, reps=1)
        assert p == plans[backend], (p, plans[backend])
    assert num_timed_candidates == 0, \
        f"cache reload re-timed {num_timed_candidates} candidates"
    print(f"[autotune] reload: all {len(shapes)} keys served from disk, "
          "0 candidates re-timed")
    return 0


def main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--smoke", action="store_true",
                    help="tiny tune + cache write->reload assertion")
    ap.add_argument("--cache", default=None,
                    help="plan-cache JSON path (default: REPRO_PLAN_CACHE "
                         "env or ~/.cache/msgemm-repro/plans.json)")
    ap.add_argument("--mode", default="msgemm",
                    choices=["msgemm", "int4_dequant"])
    ap.add_argument("--backend", default="msgemm_pallas")
    ap.add_argument("--d", type=int, default=3)
    ap.add_argument("--m", type=int, default=256)
    ap.add_argument("--k", type=int, default=256)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--interpret", action="store_true")
    args = ap.parse_args(argv)

    if args.smoke:
        return _smoke(args.cache)
    set_cache_path(args.cache)
    spec = QuantSpec(mode=args.mode, d=args.d, scale_block=12 * args.d)
    p = autotune(spec, args.m, args.k, args.batch, args.backend,
                 interpret=args.interpret or None)
    print(f"[autotune] winner: {p}")
    print(f"[autotune] cache: {cache().path} ({len(cache())} plans)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
