"""The built-in execution backends, registered as peers.

Each ``run`` body is the corresponding branch that used to live inline
in ``core.linear.apply`` (dense, jnp msGeMM, fused Pallas msGeMM,
int4 dequant) — moved behind the registry so numerics are unchanged —
plus ``int4_pallas``, the blocked dequant+MXU Pallas kernel.

``run`` takes optional ``epilogue``/``bias``/``residual`` kwargs:
``dispatch.execute`` only passes them when the backend's ``epilogue_ok``
predicate accepted the requested :class:`core.epilogue.Epilogue` (and
the plan allows fusion) — the Pallas kernels then execute the tail
inside their final VMEM writeback; every other backend never sees an
epilogue and ``execute`` applies it unfused after ``run``.

Priorities encode today's defaults so registry auto-selection matches
the old hardcoded if/elif chain: ``msgemm_jnp`` outranks the fused
Pallas kernel everywhere except real TPU (where the fused kernel is the
point of the paper), and ``int4_jnp`` outranks ``int4_pallas`` (the jnp
dequant path is what `mode='int4_dequant'` always did).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import linear as _linear
from repro.core import lut, packing, scales
from repro.dispatch.registry import register_backend


def _dot_rows(x: jnp.ndarray, w: jnp.ndarray, precision=None) -> jnp.ndarray:
    return jax.lax.dot_general(
        x, w, (((x.ndim - 1,), (1,)), ((), ())),
        preferred_element_type=x.dtype, precision=precision)


def _residual_cols(residual, m: int):
    """Model-layout residual (..., m) -> the kernels' (m, B) columns."""
    if residual is None:
        return None
    return residual.reshape(-1, m).T


def _out_dtype(epilogue, x):
    return (jnp.dtype(epilogue.out_dtype)
            if epilogue is not None and epilogue.out_dtype else x.dtype)


def _pallas_epilogue_ok(epilogue) -> bool:
    """Both Pallas kernels fuse the full epilogue envelope: any
    activation in core.epilogue.ACTIVATIONS, bias, residual, out cast."""
    return True


def run_dense(spec, plan, params, x, *, k, precision=None, epilogue=None,
              bias=None, residual=None):
    return _dot_rows(x, params["w"], precision=precision)


def run_int4_jnp(spec, plan, params, x, *, k, precision=None, epilogue=None,
                 bias=None, residual=None):
    m = params["scales"].shape[0]
    d = spec.resolve_d(k, m)
    codes = _linear._codes(params, spec, k, d)
    qt = scales.QuantizedTensor(
        codes=codes, scales=params["scales"], block=spec.scale_block,
        shape=(codes.shape[0], k), codebook=params.get("codebook"))
    w = scales.dequantize(qt, x.dtype)
    return _dot_rows(x, w)


def run_int4_pallas(spec, plan, params, x, *, k, precision=None,
                    epilogue=None, bias=None, residual=None):
    from repro.kernels import ops as kops

    m = params["scales"].shape[0]
    if spec.storage == "packed_u8":
        u8 = params["u8"]
    else:
        d = spec.resolve_d(k, m)
        u8 = packing.pack_storage(_linear._codes(params, spec, k, d))
    batch = x.shape[:-1]
    y = kops.int4_matmul(
        u8, params["scales"], x.reshape(-1, k).T,
        scale_block=spec.scale_block, interpret=plan.interpret,
        tm=plan.tm, tk=plan.tj, tb=plan.tb,
        acc_dtype=jnp.dtype(plan.acc_dtype), acc_in_vmem=plan.acc_in_vmem,
        epilogue=epilogue, bias=bias,
        residual=_residual_cols(residual, m))
    return y.T.reshape(*batch, -1).astype(_out_dtype(epilogue, x))


def run_msgemm_jnp(spec, plan, params, x, *, k, precision=None,
                   epilogue=None, bias=None, residual=None):
    m = params["scales"].shape[0]
    d = spec.resolve_d(k, m)
    codebook = params.get("codebook")
    batch = x.shape[:-1]
    xt = x.reshape(-1, k).T  # (k, B) — the paper's column layout
    lut_t = lut.produce(xt, d, dtype=jnp.float32, codebook=codebook)
    idx = params["idx"] if spec.storage == "packed_idx" else (
        packing.indices_from_storage(params["u8"], d, k))
    y = lut.consume(
        lut_t, idx, scales=params["scales"], scale_block=spec.scale_block,
        d=d, chunk=plan.consume_chunk)
    return y.T.reshape(*batch, -1).astype(x.dtype)


def run_msgemm_pallas(spec, plan, params, x, *, k, precision=None,
                      epilogue=None, bias=None, residual=None):
    from repro.kernels import ops as kops

    m = params["scales"].shape[0]
    d = spec.resolve_d(k, m)
    codes = _linear._codes(params, spec, k, d)
    batch = x.shape[:-1]
    y = kops.msgemm(
        codes, x.reshape(-1, k).T, d,
        scales=params["scales"], scale_block=spec.scale_block,
        codebook=params.get("codebook"), interpret=plan.interpret,
        tm=plan.tm, tj=plan.tj, tb=plan.tb,
        acc_dtype=jnp.dtype(plan.acc_dtype), acc_in_vmem=plan.acc_in_vmem,
        epilogue=epilogue, bias=bias,
        residual=_residual_cols(residual, m))
    return y.T.reshape(*batch, -1).astype(_out_dtype(epilogue, x))


def run_dense_fallback(spec, plan, params, x, *, k, precision=None,
                       epilogue=None, bias=None, residual=None):
    """Dequantize to dense and matmul — numerically the quantization
    round-trip (same weights every other backend sees), executed on the
    plain MXU path.  The bottom rung of the degradation ladder: always
    available, no LUT/Pallas machinery to go wrong."""
    m = params["scales"].shape[0]
    d = spec.resolve_d(k, m)
    codes = _linear._codes(params, spec, k, d)
    qt = scales.QuantizedTensor(
        codes=codes, scales=params["scales"], block=spec.scale_block,
        shape=(codes.shape[0], k), codebook=params.get("codebook"))
    w = scales.dequantize(qt, x.dtype)
    return _dot_rows(x, w)


register_backend(
    "dense", modes=("bf16",), run=run_dense, priority=100,
    description="dense MXU matmul (the paper's naive GeMM, Eq. 14)")

# Last-resort safe path for quantized modes: priority below every
# specialized backend, selected only when the rest of the ladder is
# quarantined (NaN guard / watchdog escalation) or unavailable.
register_backend(
    "dense_fallback", modes=("msgemm", "int4_dequant"),
    run=run_dense_fallback, priority=-100,
    description="dequantize -> dense MXU matmul; quarantine-safe bottom "
                "rung of the degradation ladder (pallas -> jnp -> dense)")

register_backend(
    "msgemm_jnp", modes=("msgemm",), run=run_msgemm_jnp, priority=50,
    tunable=("consume_chunk",),
    description="produce/consume msGeMM in lowerable jnp (scan consume)")

# On real TPU the fused kernel IS the paper's contribution — it outranks
# the scan formulation there; everywhere else it only runs in interpret
# mode, so auto-selection demotes it below msgemm_jnp.
register_backend(
    "msgemm_pallas", modes=("msgemm",), run=run_msgemm_pallas,
    priority=lambda dev: 60 if dev == "tpu" else 40,
    tunable=("tm", "tj", "tb", "acc_in_vmem"),
    epilogue_ok=_pallas_epilogue_ok,
    description="fused VMEM-tiled produce+consume Pallas kernel "
                "(amortized produce, VMEM acc stripe, fused epilogue)")

register_backend(
    "int4_jnp", modes=("int4_dequant",), run=run_int4_jnp, priority=50,
    description="dequantize -> MXU matmul (practical current-TPU path)")

register_backend(
    "int4_pallas", modes=("int4_dequant",), run=run_int4_pallas, priority=40,
    codebooks=("none",),  # the blocked kernel dequantizes the uniform grid
    tunable=("tm", "tj", "tb", "acc_in_vmem"),
    epilogue_ok=_pallas_epilogue_ok,
    description="blocked dequant+dot Pallas kernel (kernels/int4_matmul)")
