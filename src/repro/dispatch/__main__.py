"""``python -m repro.dispatch`` — the autotuner CLI (see autotune.py).

Preferred over ``python -m repro.dispatch.autotune``: running the
submodule as __main__ creates a second copy of its module state next to
the one the package already imported.
"""

from repro.dispatch.autotune import main

raise SystemExit(main())
