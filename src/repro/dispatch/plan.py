"""ExecPlan / ExecPolicy — the physical half of a quantized linear.

``plan(spec, m, k, batch) -> ExecPlan`` answers "how should THIS shape
run on THIS device": which registered backend, which VMEM tiles, which
consume chunking.  Plans come from three sources, in precedence order:

1. an explicit ``ExecPolicy.plan`` override (tests, power users);
2. the persistent autotune cache (shape-keyed winners measured by
   ``repro.dispatch.autotune`` and stored as JSON, so warm serving
   restarts skip retuning);
3. the shape heuristic (``kernels.ops`` tile picker) — exactly what the
   pre-registry code did, keeping default numerics identical.

Plans are frozen and hashable: they ride through ``jax.jit`` as static
closure state, and a (spec, plan) pair fully determines the lowered
kernel.  Plan resolution happens at trace time with concrete static
shapes — the serving engine pre-collects and warms every (shape, batch)
it will ever step so tracing only ever hits the cache.
"""

from __future__ import annotations

import contextlib
import math
from dataclasses import dataclass, field, replace

from repro.core.spec import QuantSpec
from repro.dispatch import registry


ACC_DTYPES = ("float32", "bfloat16", "float16", "float64")


@dataclass(frozen=True)
class ExecPlan:
    """A frozen, hashable physical execution choice.

    backend : registered backend name (``repro.dispatch.registry``).
    tm, tj, tb : kernel tiles for Pallas backends — output rows, k-axis
        inner tile (j-chunks for msgemm, k elements for int4), batch
        columns.  None -> the kernel wrapper's heuristic.
    consume_chunk : j-chunks per consume scan step (jnp msgemm backend).
    acc_in_vmem : Pallas kernels accumulate in VMEM scratch with a single
        HBM writeback (the reordered produce-amortized msgemm grid);
        False selects the legacy per-step ``y_ref +=`` formulation (kept
        as a baseline and autotuner candidate).
    acc_dtype : accumulation dtype name for the Pallas kernels; part of
        the autotune cache key (a plan measured at one precision never
        serves another).
    epilogue : allow fusing a requested core.epilogue.Epilogue into the
        kernel's final writeback when the backend's capability predicate
        accepts it; False forces the unfused fallback (execute applies
        the same ops after the GeMM).
    interpret : Pallas execution mode; None auto-detects (compiled on
        TPU, interpreter elsewhere).
    source : provenance tag — 'heuristic' | 'autotuned' | 'explicit';
        metadata only, excluded from equality/hash.
    """

    backend: str
    tm: int | None = None
    tj: int | None = None
    tb: int | None = None
    consume_chunk: int = 1
    acc_in_vmem: bool = True
    acc_dtype: str = "float32"
    epilogue: bool = True
    interpret: bool | None = None
    source: str = field(default="heuristic", compare=False)

    def __post_init__(self):
        if self.consume_chunk < 1:
            raise ValueError(f"consume_chunk={self.consume_chunk} must be >= 1")
        if self.acc_dtype not in ACC_DTYPES:
            raise ValueError(f"acc_dtype={self.acc_dtype!r} must be one of "
                             f"{ACC_DTYPES}")


@dataclass(frozen=True)
class ExecPolicy:
    """Preferences that *steer* planning without naming exact tiles.

    backend : force a registered backend by name (None -> registry
        auto-selection by capability + priority).
    interpret / consume_chunk / acc_dtype : forwarded into heuristic
        plans (acc_dtype also keys the autotune cache).
    autotune : measure candidate tile configs for unseen shape keys and
        persist winners to the plan cache.
    plan : a fully explicit ExecPlan override (skips planning entirely).
    """

    backend: str | None = None
    interpret: bool | None = None
    consume_chunk: int = 1
    acc_dtype: str = "float32"
    autotune: bool = False
    plan: ExecPlan | None = None

    def __post_init__(self):
        if self.consume_chunk < 1:
            raise ValueError(f"consume_chunk={self.consume_chunk} must be >= 1")
        if self.acc_dtype not in ACC_DTYPES:
            raise ValueError(f"acc_dtype={self.acc_dtype!r} must be one of "
                             f"{ACC_DTYPES}")


DEFAULT_POLICY = ExecPolicy()
_default_policy: ExecPolicy = DEFAULT_POLICY


def set_default_policy(policy: ExecPolicy | None) -> None:
    """Install the process-wide default ExecPolicy (None resets).  CLI
    flags (``launch/serve --backend/--autotune``) land here so the choice
    reaches every linear without threading a new argument through the
    model stack."""
    global _default_policy
    _default_policy = policy or DEFAULT_POLICY


def get_default_policy() -> ExecPolicy:
    return _default_policy


@contextlib.contextmanager
def using_policy(policy: ExecPolicy | None):
    """Scoped default policy (the serving engine wraps its jitted step
    calls so the policy is active exactly while tracing)."""
    if policy is None:
        yield
        return
    prev = _default_policy
    set_default_policy(policy)
    try:
        yield
    finally:
        set_default_policy(prev)


# ------------------------------------------------------- plan collection
_collector: list | None = None


@contextlib.contextmanager
def collecting():
    """Record every plan request made while active (autotuning is
    suppressed).  The engine runs an abstract ``jax.eval_shape`` of its
    step under this to enumerate the exact (spec, m, k, batch) keys it
    will trace, then warms them concretely — plans resolved once at
    engine build, never mid-step."""
    global _collector
    prev, _collector = _collector, []
    try:
        yield _collector
    finally:
        _collector = prev


def _tracing_active() -> bool:
    """True while inside a jax trace (jit/eval_shape/...).  Autotuning is
    impossible there: omnistaging stages every jnp op into the ambient
    trace, so 'timing' a candidate would just grow the traced graph (and
    crash converting tracers to numpy).  plan() falls back to the
    heuristic; callers that want tuned plans pre-warm the cache outside
    the trace (collecting() + warm(), as the engine and serve CLI do)."""
    import jax

    try:
        return not jax.core.trace_state_clean()
    except AttributeError:  # future jax: probe with a throwaway op
        import jax.numpy as jnp

        return isinstance(jnp.zeros(()), jax.core.Tracer)


# ---------------------------------------------------------------- keys
def plan_d(spec: QuantSpec, m: int, k: int) -> int:
    """The depth that keys plans/capabilities for this (spec, shape):
    the resolved LUT depth for msgemm, the (irrelevant but stable)
    declared d otherwise, 0 for adaptive non-msgemm."""
    if spec.mode == "msgemm":
        return spec.resolve_d(k, m)
    return int(spec.d) if isinstance(spec.d, int) else 0


def plan_key(backend: str, spec: QuantSpec, d: int, m: int, k: int,
             batch: int, device: str, acc_dtype: str = "float32") -> str:
    """Shape key for the persistent autotune cache.  ``acc_dtype`` is
    part of the key: a winner measured at one accumulation precision is
    never served to a caller asking for another."""
    return (f"{device}|{backend}|{spec.mode}|d{d}|sb{spec.scale_block}|"
            f"{spec.storage}|cb{spec.codebook}|m{m}|k{k}|b{batch}|"
            f"acc{acc_dtype}")


# ------------------------------------------------------------ heuristics
def heuristic_plan(spec: QuantSpec, d: int, m: int, k: int, batch: int,
                   backend: str, policy: ExecPolicy) -> ExecPlan:
    """The shape-heuristic tile/chunk choices, as an explicit plan.

    Small-batch (decode) shapes get their presets through
    ``ops.msgemm_tiles``: tb is sized to the actual batch (round_up(b, 8),
    never padded to 128) and the LUT budget freed by the narrow stripe
    lets tj — and for decode shapes tm — grow, which is where the
    produce-amortized kernel wins hardest (large-m, small-b)."""
    from repro.kernels import ops

    if backend == "msgemm_pallas":
        kc = math.ceil(k / d)
        tm, tj, tb = ops.msgemm_tiles(m, kc, batch, d, spec.scale_block)
        return ExecPlan(backend=backend, tm=tm, tj=tj, tb=tb,
                        # vocab-sized m can't hold a VMEM stripe: plan the
                        # legacy accumulation up front (the ops wrapper
                        # guards the same condition as a backstop)
                        acc_in_vmem=ops.acc_stripe_fits(m, tm, tb),
                        acc_dtype=policy.acc_dtype,
                        interpret=policy.interpret)
    if backend == "int4_pallas":
        tm, tk, tb = ops.int4_tiles(m, k, batch, spec.scale_block)
        return ExecPlan(backend=backend, tm=tm, tj=tk, tb=tb,
                        acc_dtype=policy.acc_dtype,
                        interpret=policy.interpret)
    if backend == "msgemm_jnp":
        return ExecPlan(backend=backend, consume_chunk=policy.consume_chunk)
    return ExecPlan(backend=backend)


# ------------------------------------------------------------------ plan
def plan(spec: QuantSpec, m: int, k: int, batch: int = 1, *,
         device: str | None = None, policy: ExecPolicy | None = None
         ) -> ExecPlan:
    """Resolve the physical execution for one (spec, shape) cell.

    m/k are the linear's (out, in) dims; ``batch`` the flattened
    activation row count.  All static Python ints — safe at trace time.
    """
    policy = policy or get_default_policy()
    if policy.plan is not None:
        return policy.plan
    device = device or registry.device_kind()
    d = plan_d(spec, m, k)

    be = None
    if policy.backend is not None:
        forced = registry.get_backend(policy.backend)
        # a forced backend applies only to specs it can execute; other
        # linears fall back to auto-selection.  This mirrors the shim's
        # impl= semantics (it only ever forced msgemm-mode linears) and
        # keeps model-wide --backend flags working on models that mix
        # modes per layer (MoE experts run int4_dequant inside an
        # msgemm model).
        if forced.supports(spec, d):
            be = forced
    if be is None:
        be = registry.select_backend(spec, d, device)

    if _collector is not None:
        _collector.append((spec, m, k, batch, be.name))
        return heuristic_plan(spec, d, m, k, batch, be.name, policy)

    import repro.dispatch.autotune as at

    cached = at.cache().get(plan_key(be.name, spec, d, m, k, batch, device,
                                     policy.acc_dtype))
    if cached is not None:
        # interpret is a runtime/policy choice, not a tunable: the
        # current policy always wins over whatever mode the plan was
        # measured under (None -> per-backend auto-detect), so an
        # interpret-mode tuning run can never pin the interpreter onto
        # later compiled runs.
        return replace(cached, interpret=policy.interpret)

    if policy.autotune and be.tunable and not _tracing_active():
        return at.autotune(spec, m, k, batch, be.name, device=device,
                           interpret=policy.interpret,
                           acc_dtype=policy.acc_dtype)
    return heuristic_plan(spec, d, m, k, batch, be.name, policy)
