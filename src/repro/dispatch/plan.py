"""ExecPlan / ExecPolicy — the physical half of a quantized linear.

``plan(spec, m, k, batch) -> ExecPlan`` answers "how should THIS shape
run on THIS device": which registered backend, which VMEM tiles, which
consume chunking.  Plans come from three sources, in precedence order:

1. an explicit ``ExecPolicy.plan`` override (tests, power users);
2. the persistent autotune cache (shape-keyed winners measured by
   ``repro.dispatch.autotune`` and stored as JSON, so warm serving
   restarts skip retuning);
3. the shape heuristic (``kernels.ops`` tile picker) — exactly what the
   pre-registry code did, keeping default numerics identical.

Plans are frozen and hashable: they ride through ``jax.jit`` as static
closure state, and a (spec, plan) pair fully determines the lowered
kernel.  Plan resolution happens at trace time with concrete static
shapes — the serving engine pre-collects and warms every (shape, batch)
it will ever step so tracing only ever hits the cache.
"""

from __future__ import annotations

import contextlib
import math
from dataclasses import dataclass, field, replace
from typing import NamedTuple

from repro import obs
from repro.core.spec import QuantSpec
from repro.dispatch import registry
from repro.dispatch.shard import (
    COLLECTIVE_IMPLS, COLLECTIVES, ShardSpec, plan_shard_tag,
    shard_spec_for,
)


ACC_DTYPES = ("float32", "bfloat16", "float16", "float64")


@dataclass(frozen=True)
class ExecPlan:
    """A frozen, hashable physical execution choice.

    backend : registered backend name (``repro.dispatch.registry``).
    tm, tj, tb : kernel tiles for Pallas backends — output rows, k-axis
        inner tile (j-chunks for msgemm, k elements for int4), batch
        columns.  None -> the kernel wrapper's heuristic.
    consume_chunk : j-chunks per consume scan step (jnp msgemm backend).
    acc_in_vmem : Pallas kernels accumulate in VMEM scratch with a single
        HBM writeback (the reordered produce-amortized msgemm grid);
        False selects the legacy per-step ``y_ref +=`` formulation (kept
        as a baseline and autotuner candidate).
    acc_dtype : accumulation dtype name for the Pallas kernels; part of
        the autotune cache key (a plan measured at one precision never
        serves another).
    epilogue : allow fusing a requested core.epilogue.Epilogue into the
        kernel's final writeback when the backend's capability predicate
        accepts it; False forces the unfused fallback (execute applies
        the same ops after the GeMM).
    interpret : Pallas execution mode; None auto-detects (compiled on
        TPU, interpreter elsewhere).
    shard : dispatch.shard.ShardSpec laying the GeMM out on the active
        mesh (m / k / batch mesh axes + contraction collective); None
        runs unsharded (or under plain GSPMD).  Like ``interpret`` it is
        a runtime overlay — derived from the ambient mesh at plan time,
        never persisted to the plan cache (the cache key carries the
        mesh/shard tag instead, and tm/tj/tb are planned and timed on
        the *local-shard* shapes).
    source : provenance tag — 'heuristic' | 'autotuned' | 'explicit';
        metadata only, excluded from equality/hash.
    """

    backend: str
    tm: int | None = None
    tj: int | None = None
    tb: int | None = None
    consume_chunk: int = 1
    acc_in_vmem: bool = True
    acc_dtype: str = "float32"
    epilogue: bool = True
    interpret: bool | None = None
    shard: ShardSpec | None = None
    source: str = field(default="heuristic", compare=False)

    def __post_init__(self):
        if self.consume_chunk < 1:
            raise ValueError(f"consume_chunk={self.consume_chunk} must be >= 1")
        if self.acc_dtype not in ACC_DTYPES:
            raise ValueError(f"acc_dtype={self.acc_dtype!r} must be one of "
                             f"{ACC_DTYPES}")


@dataclass(frozen=True)
class ExecPolicy:
    """Preferences that *steer* planning without naming exact tiles.

    backend : force a registered backend by name (None -> registry
        auto-selection by capability + priority).
    interpret / consume_chunk / acc_dtype : forwarded into heuristic
        plans (acc_dtype also keys the autotune cache).
    autotune : measure candidate tile configs for unseen shape keys and
        persist winners to the plan cache.  ``True`` uses the analytic
        perf model to prune the candidate sweep when a matching
        calibration exists (falling back to the full sweep otherwise);
        ``'full'`` always measures every candidate, ``'model'`` requires
        the model-guided path.  ``False`` disables tuning.
    shard_collective : how k-sharded (row-parallel) linears resolve
        their partial sums under a mesh: 'psum' | 'reduce_scatter'
        (see dispatch.shard.ShardSpec).
    shard_pipeline : contraction pipeline chunks for k-sharded linears.
        1 (default) is the classic one-collective-per-linear plan; N>1
        splits the local k slice into N chunks whose collectives overlap
        the next chunk's consume; 0 means *auto* — the autotuner times
        pipelined variants against the one-shot plan per linear and the
        measured winner (persisted in the plan cache's shard_variants
        table) is replayed on warm restarts.
    shard_impl : collective implementation for k-sharded linears:
        'xla' (fused psum/psum_scatter) | 'ring' (explicit ppermute
        hops, independently schedulable under compute).  Ignored when
        shard_pipeline == 0 (auto picks the impl too).
    plan : a fully explicit ExecPlan override (skips planning entirely).
    """

    backend: str | None = None
    interpret: bool | None = None
    consume_chunk: int = 1
    acc_dtype: str = "float32"
    autotune: bool | str = False
    shard_collective: str = "psum"
    shard_pipeline: int = 1
    shard_impl: str = "xla"
    plan: ExecPlan | None = None

    def __post_init__(self):
        if self.consume_chunk < 1:
            raise ValueError(f"consume_chunk={self.consume_chunk} must be >= 1")
        if self.acc_dtype not in ACC_DTYPES:
            raise ValueError(f"acc_dtype={self.acc_dtype!r} must be one of "
                             f"{ACC_DTYPES}")
        if self.autotune not in (False, True, "model", "full"):
            raise ValueError(f"autotune={self.autotune!r} must be one of "
                             f"False, True, 'model', 'full'")
        if self.shard_collective not in COLLECTIVES:
            raise ValueError(f"shard_collective={self.shard_collective!r} "
                             f"must be one of {COLLECTIVES}")
        if self.shard_pipeline < 0:
            raise ValueError(f"shard_pipeline={self.shard_pipeline} must "
                             f"be >= 0 (0 = autotuned)")
        if self.shard_impl not in COLLECTIVE_IMPLS:
            raise ValueError(f"shard_impl={self.shard_impl!r} must be one "
                             f"of {COLLECTIVE_IMPLS}")


DEFAULT_POLICY = ExecPolicy()
_default_policy: ExecPolicy = DEFAULT_POLICY


def set_default_policy(policy: ExecPolicy | None) -> None:
    """Install the process-wide default ExecPolicy (None resets).  CLI
    flags (``launch/serve --backend/--autotune``) land here so the choice
    reaches every linear without threading a new argument through the
    model stack."""
    global _default_policy
    _default_policy = policy or DEFAULT_POLICY


def get_default_policy() -> ExecPolicy:
    return _default_policy


@contextlib.contextmanager
def using_policy(policy: ExecPolicy | None):
    """Scoped default policy (the serving engine wraps its jitted step
    calls so the policy is active exactly while tracing)."""
    if policy is None:
        yield
        return
    prev = _default_policy
    set_default_policy(policy)
    try:
        yield
    finally:
        set_default_policy(prev)


# ------------------------------------------------------- plan collection
class PlanRequest(NamedTuple):
    """One collected plan() call: GLOBAL shapes + the derived shard.
    ``warm`` recomputes the local-shard shapes and cache key from these,
    so a collected request resolves to exactly the plan the later trace
    will ask for."""

    spec: QuantSpec
    m: int
    k: int
    batch: int
    backend: str
    shard: "ShardSpec | None" = None
    tag: str = "-"


_collector: list | None = None


@contextlib.contextmanager
def collecting():
    """Record every plan request made while active (autotuning is
    suppressed).  The engine runs an abstract ``jax.eval_shape`` of its
    step under this to enumerate the exact (spec, m, k, batch) keys it
    will trace, then warms them concretely — plans resolved once at
    engine build, never mid-step."""
    global _collector
    prev, _collector = _collector, []
    try:
        yield _collector
    finally:
        _collector = prev


def _tracing_active() -> bool:
    """True while inside a jax trace (jit/eval_shape/...).  Autotuning is
    impossible there: omnistaging stages every jnp op into the ambient
    trace, so 'timing' a candidate would just grow the traced graph (and
    crash converting tracers to numpy).  plan() falls back to the
    heuristic; callers that want tuned plans pre-warm the cache outside
    the trace (collecting() + warm(), as the engine and serve CLI do)."""
    import jax

    try:
        return not jax.core.trace_state_clean()
    except AttributeError:  # future jax: probe with a throwaway op
        import jax.numpy as jnp

        return isinstance(jnp.zeros(()), jax.core.Tracer)


# ---------------------------------------------------------------- keys
def plan_d(spec: QuantSpec, m: int, k: int) -> int:
    """The depth that keys plans/capabilities for this (spec, shape):
    the resolved LUT depth for msgemm, the (irrelevant but stable)
    declared d otherwise, 0 for adaptive non-msgemm."""
    if spec.mode == "msgemm":
        return spec.resolve_d(k, m)
    return int(spec.d) if isinstance(spec.d, int) else 0


def plan_key(backend: str, spec: QuantSpec, d: int, m: int, k: int,
             batch: int, device: str, acc_dtype: str = "float32",
             shard: str = "-") -> str:
    """Shape key for the persistent autotune cache.  ``acc_dtype`` is
    part of the key: a winner measured at one accumulation precision is
    never served to a caller asking for another.  ``shard`` is the
    mesh/shard tag (dispatch.shard.plan_shard_tag) and m/k/batch are the
    *local-shard* shapes: a plan measured on one device is never
    replayed as a sharded plan on a mesh, nor vice versa — different
    mesh shapes key (and time) independently."""
    return (f"{device}|{backend}|{spec.mode}|d{d}|sb{spec.scale_block}|"
            f"{spec.storage}|cb{spec.codebook}|m{m}|k{k}|b{batch}|"
            f"acc{acc_dtype}|sh{shard}")


# ------------------------------------------------------------ heuristics
def heuristic_plan(spec: QuantSpec, d: int, m: int, k: int, batch: int,
                   backend: str, policy: ExecPolicy) -> ExecPlan:
    """The shape-heuristic tile/chunk choices, as an explicit plan.

    Small-batch (decode) shapes get their presets through
    ``ops.msgemm_tiles``: tb is sized to the actual batch (round_up(b, 8),
    never padded to 128) and the LUT budget freed by the narrow stripe
    lets tj — and for decode shapes tm — grow, which is where the
    produce-amortized kernel wins hardest (large-m, small-b)."""
    from repro.kernels import ops

    if backend == "msgemm_pallas":
        kc = math.ceil(k / d)
        tm, tj, tb = ops.msgemm_tiles(m, kc, batch, d, spec.scale_block)
        return ExecPlan(backend=backend, tm=tm, tj=tj, tb=tb,
                        # vocab-sized m can't hold a VMEM stripe: plan the
                        # legacy accumulation up front (the ops wrapper
                        # guards the same condition as a backstop)
                        acc_in_vmem=ops.acc_stripe_fits(m, tm, tb),
                        acc_dtype=policy.acc_dtype,
                        interpret=policy.interpret)
    if backend == "int4_pallas":
        tm, tk, tb = ops.int4_tiles(m, k, batch, spec.scale_block)
        return ExecPlan(backend=backend, tm=tm, tj=tk, tb=tb,
                        acc_dtype=policy.acc_dtype,
                        interpret=policy.interpret)
    if backend == "msgemm_jnp":
        return ExecPlan(backend=backend, consume_chunk=policy.consume_chunk)
    return ExecPlan(backend=backend)


# ------------------------------------------------------------------ plan
def plan(spec: QuantSpec, m: int, k: int, batch: int = 1, *,
         device: str | None = None, policy: ExecPolicy | None = None,
         shard_axes: tuple | None = None, lead_batch: int | None = None
         ) -> ExecPlan:
    """Resolve the physical execution for one (spec, shape) cell.

    m/k are the linear's GLOBAL (out, in) dims; ``batch`` the flattened
    activation row count.  All static Python ints — safe at trace time.

    ``shard_axes``: the weight's logical (out, in) axis names (the
    ``distributed.sharding.LINEAR_AXES`` entry for this linear's tag).
    With an active mesh (``distributed.sharding.use``) they derive the
    plan's ShardSpec, and tile heuristics / cache lookups / autotuning
    all run on the **local-shard** shapes — what one device actually
    executes under TP.  ``lead_batch``: the activations' leading dim
    (what the batch mesh axis shards); defaults to ``batch``.
    """
    policy = policy or get_default_policy()
    if policy.plan is not None:
        return policy.plan
    device = device or registry.device_kind()
    d = plan_d(spec, m, k)

    from repro.distributed.sharding import active_mesh, active_rules

    mesh = active_mesh()
    # shard_pipeline == 0 (auto) derives the one-shot base layout first;
    # the tuned (chunks, impl) winner — if the cache has one — replaces
    # it below, once the backend (part of the variant key) is known.
    shard = shard_spec_for(spec, shard_axes, m, k, batch, mesh,
                           lead_batch=lead_batch,
                           collective=policy.shard_collective,
                           rules=active_rules(),
                           pipeline_chunks=max(policy.shard_pipeline, 1),
                           collective_impl=(
                               policy.shard_impl
                               if policy.shard_pipeline != 0 else "xla"))
    if shard is not None and not shard.is_sharded:
        shard = None
    tag = plan_shard_tag(shard, mesh)
    lm, lk, lb = shard.exec_mkb(m, k, batch) if shard else (m, k, batch)

    be = None
    if policy.backend is not None:
        forced = registry.get_backend(policy.backend)
        # a forced backend applies only to specs it can execute; other
        # linears fall back to auto-selection.  This mirrors the shim's
        # impl= semantics (it only ever forced msgemm-mode linears) and
        # keeps model-wide --backend flags working on models that mix
        # modes per layer (MoE experts run int4_dequant inside an
        # msgemm model).
        # a quarantined forced backend degrades to auto-selection —
        # same ladder the NaN guard / watchdog escalation rely on
        if forced.supports(spec, d) and not registry.is_quarantined(
                forced.name):
            be = forced
    if be is None:
        be = registry.select_backend(spec, d, device)

    if _collector is not None:
        # collection is an abstract dry run — its plan() calls are not
        # real resolutions, so they stay out of the telemetry
        _collector.append(PlanRequest(spec, m, k, batch, be.name, shard, tag))
        return replace(heuristic_plan(spec, d, lm, lk, lb, be.name, policy),
                       shard=shard)

    reg = obs.registry()
    reg.counter("dispatch_backend_selected_total",
                help="plan resolutions per backend",
                backend=be.name).inc()

    import repro.dispatch.autotune as at

    if policy.shard_pipeline == 0 and shard is not None \
            and shard.k is not None:
        var = at.cache().shard_variant(
            plan_key(be.name, spec, d, lm, lk, lb, device,
                     policy.acc_dtype, tag))
        if var is not None:
            shard = shard_spec_for(
                spec, shard_axes, m, k, batch, mesh,
                lead_batch=lead_batch,
                collective=policy.shard_collective,
                rules=active_rules(),
                pipeline_chunks=int(var["pipeline_chunks"]),
                collective_impl=str(var["collective_impl"]))
            tag = plan_shard_tag(shard, mesh)
            lm, lk, lb = (shard.exec_mkb(m, k, batch) if shard
                          else (m, k, batch))

    cached = at.cache().get(plan_key(be.name, spec, d, lm, lk, lb, device,
                                     policy.acc_dtype, tag))
    reg.counter("dispatch_plan_cache_total",
                help="persistent plan-cache lookups",
                result="hit" if cached is not None else "miss").inc()
    if cached is not None:
        # interpret and shard are runtime/policy choices, not tunables:
        # the current policy/mesh always wins over whatever the plan was
        # measured under (None -> per-backend auto-detect), so an
        # interpret-mode tuning run can never pin the interpreter onto
        # later compiled runs, and a plan tuned on the local-shard
        # shapes re-attaches to the live mesh on every hit.
        return replace(cached, interpret=policy.interpret, shard=shard)

    if policy.autotune and be.tunable and not _tracing_active():
        search = (policy.autotune
                  if policy.autotune in ("model", "full") else "auto")
        return replace(
            at.autotune(spec, lm, lk, lb, be.name, device=device,
                        interpret=policy.interpret,
                        acc_dtype=policy.acc_dtype, tag=tag,
                        search=search),
            shard=shard)
    return replace(heuristic_plan(spec, d, lm, lk, lb, be.name, policy),
                   shard=shard)
