"""Sharded execution of one quantized linear on a device mesh.

The paper's produce/consume split interacts with tensor parallelism in a
specific way (§6): the LUT produce cost is amortized over the output
rows m, so sharding m (column parallelism) keeps the amortization
*per shard* — every device produces the LUT for its own activation
shard once and consumes it over its m rows — instead of replicating the
whole GeMM.  Sharding the contraction dim k (row parallelism, the
Megatron down-proj/wo pattern) makes every device produce a LUT over
its k-slice of the activations, and the partial sums meet in exactly
one collective, after which the epilogue (bias/residual — which must
NOT be applied per shard) runs once.

This module carries that story end to end:

* :class:`ShardSpec` — the frozen, hashable ``ExecPlan.shard`` field:
  which mesh axis shards m / k / the activation batch, which collective
  resolves the contraction (``psum`` keeps the output replicated over
  the k axis, ``reduce_scatter`` leaves it m-sharded), and the mesh
  shape it was derived against (part of the plan-cache key).
* :func:`shard_spec_for` — derives a ShardSpec for one linear from its
  *logical* weight axes (the same ``distributed.sharding.LINEAR_AXES``
  names the param-placement rules use), with divisibility and
  quantization-alignment guards: a dim only shards when every packed
  storage view (idx / u8 / scales) splits cleanly on the shard
  boundary.  Anything that cannot shard safely (adaptive d, expert
  stacks under vmap, misaligned dims) returns None and stays under
  GSPMD exactly as before.
* :func:`run_sharded` — wraps a registered backend's ``run`` in a
  fully-manual ``shard_map``: per-shard LUT produce, per-shard VMEM
  accumulation, the epilogue fused into the kernel writeback when no
  contraction collective separates them, and applied exactly once
  *after* the collective when one does.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import obs
from repro.core.epilogue import apply_epilogue
from repro.distributed import collectives as coll
from repro.distributed import compat
from repro.distributed import sharding as shd
from repro.kernels import ops as kops

COLLECTIVES = ("psum", "reduce_scatter")
COLLECTIVE_IMPLS = ("xla", "ring")


@dataclass(frozen=True)
class ShardSpec:
    """How one linear's GeMM is laid out on the mesh (ExecPlan.shard).

    mesh_axes : ordered ((axis_name, size), ...) snapshot of the mesh the
        spec was derived against — makes the spec self-describing (cache
        keys, warm()) without holding a live Mesh object.
    m / k / batch : mesh axis name sharding the weight's output rows,
        the contraction dim, and the activations' leading (batch) dim;
        None leaves that dim whole on every device.  m and k are
        mutually exclusive (one TP axis per linear).
    collective : how k-sharded partial sums meet: ``psum`` (output
        replicated over the k axis) or ``reduce_scatter`` (output rows
        scattered over the k axis — the next layer's column-parallel
        input sharding).  Ignored when k is None.
    pipeline_chunks : number of contraction slices the k-sharded GeMM is
        split into so chunk i's collective overlaps chunk i+1's consume;
        1 is the classic one-collective-per-linear plan.  Only
        meaningful with k sharded.
    collective_impl : ``xla`` (fused psum/psum_scatter ops) or ``ring``
        (explicit ppermute hops from distributed.collectives, each hop
        schedulable under compute).  Only meaningful with k sharded.
    """

    mesh_axes: tuple[tuple[str, int], ...] = ()
    m: str | None = None
    k: str | None = None
    batch: str | None = None
    collective: str = "psum"
    pipeline_chunks: int = 1
    collective_impl: str = "xla"

    def __post_init__(self):
        if self.collective not in COLLECTIVES:
            raise ValueError(f"collective={self.collective!r} must be one "
                             f"of {COLLECTIVES}")
        if self.collective_impl not in COLLECTIVE_IMPLS:
            raise ValueError(
                f"collective_impl={self.collective_impl!r} must be one of "
                f"{COLLECTIVE_IMPLS}")
        if self.m is not None and self.k is not None:
            raise ValueError("m and k cannot both be sharded by one linear "
                             f"(m={self.m!r}, k={self.k!r})")
        if self.pipeline_chunks < 1:
            raise ValueError(
                f"pipeline_chunks={self.pipeline_chunks} must be >= 1")
        if self.k is None and (self.pipeline_chunks != 1
                               or self.collective_impl != "xla"):
            raise ValueError(
                "pipeline_chunks/collective_impl apply only to k-sharded "
                "(row-parallel) linears — there is no contraction "
                "collective to pipeline otherwise")

    # ------------------------------------------------------------ sizes
    def axis_size(self, axis: str | None) -> int:
        if axis is None:
            return 1
        return dict(self.mesh_axes)[axis]

    @property
    def is_sharded(self) -> bool:
        return any(a is not None and self.axis_size(a) > 1
                   for a in (self.m, self.k, self.batch))

    @property
    def is_pipelined(self) -> bool:
        return self.pipeline_chunks > 1 or self.collective_impl != "xla"

    def local_mkb(self, m: int, k: int, batch: int) -> tuple[int, int, int]:
        """Per-device (m, k, batch-rows) under this spec."""
        return (m // self.axis_size(self.m), k // self.axis_size(self.k),
                batch // self.axis_size(self.batch))

    def exec_mkb(self, m: int, k: int, batch: int) -> tuple[int, int, int]:
        """Per-kernel-invocation (m, k, batch-rows) — what tile
        heuristics and the autotuner must plan/time under this spec.
        Same as :meth:`local_mkb` except the contraction dim shrinks by
        ``pipeline_chunks``: a pipelined plan invokes the kernel once
        per k-chunk."""
        lm, lk, lb = self.local_mkb(m, k, batch)
        return lm, lk // self.pipeline_chunks, lb

    # ------------------------------------------------------------- keys
    def tag(self) -> str:
        """Cache-key fragment: mesh shape + the shard choice.

        The pipeline suffix (``/pc{n}.{impl}``) is appended only when it
        differs from the classic one-shot layout, so every key a v3
        cache file recorded before pipelining existed is byte-identical
        to the key the same plan derives today (additive-key
        discipline)."""
        mesh = ".".join(f"{a}{s}" for a, s in self.mesh_axes)
        base = (f"{mesh}/m={self.m or '-'}/k={self.k or '-'}"
                f"/b={self.batch or '-'}/{self.collective}")
        if self.is_pipelined:
            base += f"/pc{self.pipeline_chunks}.{self.collective_impl}"
        return base


def mesh_tag(mesh) -> str:
    """Cache-key fragment for the ambient mesh alone ('-' off-mesh).
    Distinguishes plans measured on N devices from single-device plans
    even when the linear itself ends up unsharded."""
    if mesh is None:
        return "-"
    return ".".join(f"{a}{s}" for a, s in mesh.shape.items())


def plan_shard_tag(shard: "ShardSpec | None", mesh) -> str:
    return shard.tag() if shard is not None else mesh_tag(mesh)


# ------------------------------------------------------------ derivation
def _quant_aligned(spec, k_local: int) -> bool:
    """Can the packed weight storage split at a k_local boundary?  Every
    per-shard view must be whole: scale blocks (scales columns), d-chunks
    (packed_idx columns) and code pairs (packed_u8 columns)."""
    if spec.mode == "bf16":
        return True
    if k_local % spec.scale_block:
        return False
    if k_local % int(spec.d):
        return False
    if spec.storage == "packed_u8" and k_local % 2:
        return False
    return True


def _collective_fallback(kind: str, **labels):
    """Count a downgraded collective layout (satellite of ISSUE 10: the
    reduce_scatter->psum fallback used to be silent)."""
    obs.registry().counter(
        "dispatch_shard_collective_fallback_total",
        help="shard derivations that downgraded the requested collective "
             "layout (reduce_scatter->psum, pipeline-chunk clamping)",
        kind=kind, **labels).inc()


def shard_spec_for(spec, axes, m: int, k: int, batch: int, mesh, *,
                   lead_batch: int | None = None,
                   collective: str = "psum",
                   rules: str = "default",
                   pipeline_chunks: int = 1,
                   collective_impl: str = "xla") -> ShardSpec | None:
    """Derive the ShardSpec for one linear, or None to stay under GSPMD.

    ``axes``: the weight's logical (out, in) axis names — the
    ``distributed.sharding.LINEAR_AXES`` entry for this linear's tag.
    Candidate mesh axes come from the activation table of the selected
    ``rules`` set (the TP table: heads / kvheads / mlp / vocab / ... ->
    'model'), the batch axis from its 'batch' rule ('pod' x 'data' —
    empty under 'serve_tp', which therefore never batch-shards); a
    candidate is taken only when the dim divides and (for k) the packed
    storage stays shard-aligned.

    ``pipeline_chunks``/``collective_impl`` request the pipelined
    contraction (ISSUE 10): the request is *clamped*, never rejected —
    the chunk count drops to the largest value that both divides the
    local k slice and keeps every packed-storage view (scales / idx /
    u8) whole per chunk, and both knobs normalize to the one-shot
    defaults for anything that is not k-sharded.  Every downgrade
    (including the pre-existing reduce_scatter->psum fallback when m
    does not divide the k axis) bumps
    ``dispatch_shard_collective_fallback_total``.

    Adaptive-d specs never shard: ``resolve_d`` keys off the *global*
    (in, out) dims the weights were quantized with, and a local-shape
    resolve could silently reinterpret the packed codes.
    """
    if mesh is None or axes is None or len(axes) != 2:
        return None
    if spec.mode != "bf16" and spec.d == "adaptive":
        return None
    out_ax, in_ax = axes
    act_rules = shd.RULE_SETS[rules][0]
    mesh_axes = tuple(mesh.shape.items())
    used: set[str] = set()

    def pick(logical, dim, *, need_alignment: bool):
        for cand in act_rules.get(logical, ()):
            size = mesh.shape.get(cand, 1)
            if size == 1 or cand in used or dim % size:
                continue
            if need_alignment and not _quant_aligned(spec, dim // size):
                continue
            used.add(cand)
            return cand
        return None

    m_axis = pick(out_ax, m, need_alignment=False)
    k_axis = None
    if m_axis is None:
        k_axis = pick(in_ax, k, need_alignment=True)
    if k_axis is not None and collective == "reduce_scatter" \
            and m % mesh.shape[k_axis]:
        collective = "psum"  # cannot scatter the output rows: fall back
        _collective_fallback("reduce_scatter_to_psum", axis=k_axis)
    pc, impl = 1, "xla"
    if k_axis is not None:
        impl = collective_impl if collective_impl in COLLECTIVE_IMPLS \
            else "xla"
        want = max(int(pipeline_chunks), 1)
        pc = want
        k_local = k // mesh.shape[k_axis]
        while pc > 1 and (k_local % pc
                          or not _quant_aligned(spec, k_local // pc)):
            pc -= 1
        if pc != want:
            _collective_fallback("pipeline_chunks_clamped", axis=k_axis,
                                 requested=want, clamped=pc)
    lead = batch if lead_batch is None else lead_batch
    b_axis = None
    for cand in act_rules.get("batch", ()):
        size = mesh.shape.get(cand, 1)
        if size == 1 or cand in used:
            continue
        if lead % size == 0 and batch % size == 0:
            b_axis = cand
            break
    if m_axis is None and k_axis is None and b_axis is None:
        return None
    return ShardSpec(mesh_axes=mesh_axes, m=m_axis, k=k_axis, batch=b_axis,
                     collective=collective, pipeline_chunks=pc,
                     collective_impl=impl)


# -------------------------------------------------------------- execution
def _param_specs(spec, params: dict, s: ShardSpec) -> dict:
    """Per-leaf PartitionSpecs for a linear's param dict.  All weight
    views share (m, k) orientation — their packed second dims split
    cleanly because shard_spec_for guarded the alignment; the codebook
    (16,) value table is replicated."""
    out = {}
    for name, leaf in params.items():
        if name == "codebook":
            out[name] = P(*([None] * leaf.ndim))
        else:
            out[name] = P(s.m, s.k)
    return out


def run_sharded(backend, spec, plan, params: dict, x, *, k: int, mesh,
                precision=None, epilogue=None, bias=None, residual=None,
                fuse: bool = False):
    """Run one planned linear under shard_map on ``mesh``.

    The inner call sees *local* shapes — exactly the shapes
    ``dispatch.plan`` planned tiles for — so per-shard LUT produce and
    per-shard VMEM accumulation follow from the unmodified kernels.
    With a k-sharded (row-parallel) linear the epilogue runs once after
    the contraction collective; otherwise it fuses into the kernel
    writeback per shard (disjoint m rows) whenever the backend can.

    Pipelined plans (``shard.pipeline_chunks > 1`` and/or
    ``collective_impl == 'ring'``) split the local contraction into
    k-chunks: the collective for chunk i (a ppermute ring under the ring
    impl, so each hop is an independently schedulable HLO) carries no
    data dependency on chunk i+1's produce/consume, letting the compiler
    slide the communication under the next chunk's compute.  Partials
    are double-buffered — the chunk whose collective is in flight
    (``pending``) is only folded into the accumulator after the *next*
    chunk's compute has been issued.

    Column-parallel (m-sharded) outputs are never gathered here:
    ``out_specs`` leaves them m-sharded, so the all-gather a consumer
    might need is deferred into that consumer's own produce phase (and
    vanishes entirely when the next linear is row-parallel — its k
    sharding *is* this layer's m sharding, the up-proj -> down-proj
    pattern).
    """
    s = plan.shard
    size = dict(s.mesh_axes)
    if any(mesh.shape.get(a) != n for a, n in s.mesh_axes) \
            or len(mesh.shape) != len(s.mesh_axes):
        raise ValueError(
            f"plan was sharded for mesh {dict(s.mesh_axes)} but the active "
            f"mesh is {dict(mesh.shape)}; re-plan under the current mesh")
    k_local = k // size.get(s.k, 1) if s.k else k
    pc = s.pipeline_chunks if s.k else 1
    k_chunk = k_local // pc
    inner_plan = dataclasses.replace(plan, shard=None)
    rank = x.ndim
    mid = (None,) * (rank - 2)
    # the m dim of y / bias / residual: m-sharded linears keep their own
    # axis; reduce_scatter hands the k axis over; psum replicates.
    out_m = s.m if s.k is None else (
        s.k if s.collective == "reduce_scatter" else None)

    operands = {"params": params, "x": x}
    in_specs = {"params": _param_specs(spec, params, s),
                "x": P(*((s.batch,) + mid + (s.k,)))}
    if bias is not None:
        operands["bias"] = bias
        in_specs["bias"] = P(out_m)
    if residual is not None:
        operands["residual"] = residual
        in_specs["residual"] = P(*((s.batch,) + mid + (out_m,)))
    out_specs = P(*((s.batch,) + mid + (out_m,)))

    # trace attribution: compute vs contraction collective, named by the
    # shard layout so a mesh trace splits step time between them.  The
    # marks are keyed to the *output* of each stage (data dependency, no
    # ordered side channel — safe under shard_map), and fire once per
    # device shard (per chunk when pipelined — the span overlap between
    # the two families is the measured comms/compute overlap).
    tagname = s.tag()
    mk_compute = f"shard.compute.{tagname}.k{k_chunk}"
    mk_coll = f"shard.collective.{s.collective}.{tagname}"

    def contract(y):
        """Resolve k-sharded partials with the planned collective."""
        n = size[s.k]
        if s.collective == "reduce_scatter":
            if s.collective_impl == "ring":
                return coll.ring_reduce_scatter(y, s.k, axis_size=n,
                                                dim=y.ndim - 1)
            return jax.lax.psum_scatter(y, s.k,
                                        scatter_dimension=y.ndim - 1,
                                        tiled=True)
        if s.collective_impl == "ring":
            return coll.ring_psum(y, s.k, axis_size=n)
        return jax.lax.psum(y, s.k)

    def compute_chunk(p_c, x_c):
        x_c = obs.jit_begin(x_c, mk_compute)
        y = backend.run(spec, inner_plan, p_c, x_c, k=k_chunk,
                        precision=precision)
        return obs.jit_end(y, mk_compute, cat="shard",
                           hist="shard_compute_s",
                           hist_labels={"tag": tagname})

    def collect_chunk(y):
        y = obs.jit_begin(y, mk_coll)
        y = contract(y)
        return obs.jit_end(y, mk_coll, cat="shard",
                           hist="shard_collective_s",
                           hist_labels={"collective": s.collective,
                                        "axis": s.k,
                                        "impl": s.collective_impl})

    def local(ops):
        b_l, r_l = ops.get("bias"), ops.get("residual")
        if s.k is None:
            x_l = obs.jit_begin(ops["x"], mk_compute)
            if fuse:
                y = backend.run(spec, inner_plan, ops["params"], x_l,
                                k=k_local, precision=precision,
                                epilogue=epilogue, bias=b_l, residual=r_l)
                return obs.jit_end(y, mk_compute, cat="shard",
                                   hist="shard_compute_s",
                                   hist_labels={"tag": tagname})
            y = backend.run(spec, inner_plan, ops["params"], x_l,
                            k=k_local, precision=precision)
            y = obs.jit_end(y, mk_compute, cat="shard",
                            hist="shard_compute_s",
                            hist_labels={"tag": tagname})
            return apply_epilogue(y, epilogue, bias=b_l, residual=r_l)
        # row-parallel: partial sums over the local k slice; the epilogue
        # must see the *resolved* sum, never the per-shard partials
        if pc == 1:
            y = compute_chunk(ops["params"], ops["x"])
            y = collect_chunk(y)
            return apply_epilogue(y, epilogue, bias=b_l, residual=r_l)
        d_pack = 1 if spec.mode == "bf16" else int(spec.d)
        sb_pack = 1 if spec.mode == "bf16" else int(spec.scale_block)
        p_chunks = kops.k_chunk_params(ops["params"], k=k_local, chunks=pc,
                                       d=d_pack, scale_block=sb_pack)
        x_chunks = jnp.split(ops["x"], pc, axis=-1)
        out = None      # partials whose collective has been retired
        pending = None  # the chunk whose collective is in flight
        for ci in range(pc):
            y_c = compute_chunk(p_chunks[ci], x_chunks[ci])
            if pending is not None:
                # retire the previous chunk only after this chunk's
                # compute is issued — the in-flight ring and the compute
                # above share no dataflow, so the scheduler overlaps them
                out = pending if out is None else out + pending
            pending = collect_chunk(y_c)
        y = pending if out is None else out + pending
        return apply_epilogue(y, epilogue, bias=b_l, residual=r_l)

    fn = compat.shard_map(local, mesh=mesh, in_specs=(in_specs,),
                          out_specs=out_specs, check=False)
    return fn(operands)
