"""Sharded execution of one quantized linear on a device mesh.

The paper's produce/consume split interacts with tensor parallelism in a
specific way (§6): the LUT produce cost is amortized over the output
rows m, so sharding m (column parallelism) keeps the amortization
*per shard* — every device produces the LUT for its own activation
shard once and consumes it over its m rows — instead of replicating the
whole GeMM.  Sharding the contraction dim k (row parallelism, the
Megatron down-proj/wo pattern) makes every device produce a LUT over
its k-slice of the activations, and the partial sums meet in exactly
one collective, after which the epilogue (bias/residual — which must
NOT be applied per shard) runs once.

This module carries that story end to end:

* :class:`ShardSpec` — the frozen, hashable ``ExecPlan.shard`` field:
  which mesh axis shards m / k / the activation batch, which collective
  resolves the contraction (``psum`` keeps the output replicated over
  the k axis, ``reduce_scatter`` leaves it m-sharded), and the mesh
  shape it was derived against (part of the plan-cache key).
* :func:`shard_spec_for` — derives a ShardSpec for one linear from its
  *logical* weight axes (the same ``distributed.sharding.LINEAR_AXES``
  names the param-placement rules use), with divisibility and
  quantization-alignment guards: a dim only shards when every packed
  storage view (idx / u8 / scales) splits cleanly on the shard
  boundary.  Anything that cannot shard safely (adaptive d, expert
  stacks under vmap, misaligned dims) returns None and stays under
  GSPMD exactly as before.
* :func:`run_sharded` — wraps a registered backend's ``run`` in a
  fully-manual ``shard_map``: per-shard LUT produce, per-shard VMEM
  accumulation, the epilogue fused into the kernel writeback when no
  contraction collective separates them, and applied exactly once
  *after* the collective when one does.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import jax
from jax.sharding import PartitionSpec as P

from repro import obs
from repro.core.epilogue import apply_epilogue
from repro.distributed import compat
from repro.distributed import sharding as shd

COLLECTIVES = ("psum", "reduce_scatter")


@dataclass(frozen=True)
class ShardSpec:
    """How one linear's GeMM is laid out on the mesh (ExecPlan.shard).

    mesh_axes : ordered ((axis_name, size), ...) snapshot of the mesh the
        spec was derived against — makes the spec self-describing (cache
        keys, warm()) without holding a live Mesh object.
    m / k / batch : mesh axis name sharding the weight's output rows,
        the contraction dim, and the activations' leading (batch) dim;
        None leaves that dim whole on every device.  m and k are
        mutually exclusive (one TP axis per linear).
    collective : how k-sharded partial sums meet: ``psum`` (output
        replicated over the k axis) or ``reduce_scatter`` (output rows
        scattered over the k axis — the next layer's column-parallel
        input sharding).  Ignored when k is None.
    """

    mesh_axes: tuple[tuple[str, int], ...] = ()
    m: str | None = None
    k: str | None = None
    batch: str | None = None
    collective: str = "psum"

    def __post_init__(self):
        if self.collective not in COLLECTIVES:
            raise ValueError(f"collective={self.collective!r} must be one "
                             f"of {COLLECTIVES}")
        if self.m is not None and self.k is not None:
            raise ValueError("m and k cannot both be sharded by one linear "
                             f"(m={self.m!r}, k={self.k!r})")

    # ------------------------------------------------------------ sizes
    def axis_size(self, axis: str | None) -> int:
        if axis is None:
            return 1
        return dict(self.mesh_axes)[axis]

    @property
    def is_sharded(self) -> bool:
        return any(a is not None and self.axis_size(a) > 1
                   for a in (self.m, self.k, self.batch))

    def local_mkb(self, m: int, k: int, batch: int) -> tuple[int, int, int]:
        """Per-device (m, k, batch-rows) — what tile heuristics and the
        autotuner must plan/time under this spec."""
        return (m // self.axis_size(self.m), k // self.axis_size(self.k),
                batch // self.axis_size(self.batch))

    # ------------------------------------------------------------- keys
    def tag(self) -> str:
        """Cache-key fragment: mesh shape + the shard choice."""
        mesh = ".".join(f"{a}{s}" for a, s in self.mesh_axes)
        return (f"{mesh}/m={self.m or '-'}/k={self.k or '-'}"
                f"/b={self.batch or '-'}/{self.collective}")


def mesh_tag(mesh) -> str:
    """Cache-key fragment for the ambient mesh alone ('-' off-mesh).
    Distinguishes plans measured on N devices from single-device plans
    even when the linear itself ends up unsharded."""
    if mesh is None:
        return "-"
    return ".".join(f"{a}{s}" for a, s in mesh.shape.items())


def plan_shard_tag(shard: "ShardSpec | None", mesh) -> str:
    return shard.tag() if shard is not None else mesh_tag(mesh)


# ------------------------------------------------------------ derivation
def _quant_aligned(spec, k_local: int) -> bool:
    """Can the packed weight storage split at a k_local boundary?  Every
    per-shard view must be whole: scale blocks (scales columns), d-chunks
    (packed_idx columns) and code pairs (packed_u8 columns)."""
    if spec.mode == "bf16":
        return True
    if k_local % spec.scale_block:
        return False
    if k_local % int(spec.d):
        return False
    if spec.storage == "packed_u8" and k_local % 2:
        return False
    return True


def shard_spec_for(spec, axes, m: int, k: int, batch: int, mesh, *,
                   lead_batch: int | None = None,
                   collective: str = "psum",
                   rules: str = "default") -> ShardSpec | None:
    """Derive the ShardSpec for one linear, or None to stay under GSPMD.

    ``axes``: the weight's logical (out, in) axis names — the
    ``distributed.sharding.LINEAR_AXES`` entry for this linear's tag.
    Candidate mesh axes come from the activation table of the selected
    ``rules`` set (the TP table: heads / kvheads / mlp / vocab / ... ->
    'model'), the batch axis from its 'batch' rule ('pod' x 'data' —
    empty under 'serve_tp', which therefore never batch-shards); a
    candidate is taken only when the dim divides and (for k) the packed
    storage stays shard-aligned.

    Adaptive-d specs never shard: ``resolve_d`` keys off the *global*
    (in, out) dims the weights were quantized with, and a local-shape
    resolve could silently reinterpret the packed codes.
    """
    if mesh is None or axes is None or len(axes) != 2:
        return None
    if spec.mode != "bf16" and spec.d == "adaptive":
        return None
    out_ax, in_ax = axes
    act_rules = shd.RULE_SETS[rules][0]
    mesh_axes = tuple(mesh.shape.items())
    used: set[str] = set()

    def pick(logical, dim, *, need_alignment: bool):
        for cand in act_rules.get(logical, ()):
            size = mesh.shape.get(cand, 1)
            if size == 1 or cand in used or dim % size:
                continue
            if need_alignment and not _quant_aligned(spec, dim // size):
                continue
            used.add(cand)
            return cand
        return None

    m_axis = pick(out_ax, m, need_alignment=False)
    k_axis = None
    if m_axis is None:
        k_axis = pick(in_ax, k, need_alignment=True)
    if k_axis is not None and collective == "reduce_scatter" \
            and m % mesh.shape[k_axis]:
        collective = "psum"  # cannot scatter the output rows: fall back
    lead = batch if lead_batch is None else lead_batch
    b_axis = None
    for cand in act_rules.get("batch", ()):
        size = mesh.shape.get(cand, 1)
        if size == 1 or cand in used:
            continue
        if lead % size == 0 and batch % size == 0:
            b_axis = cand
            break
    if m_axis is None and k_axis is None and b_axis is None:
        return None
    return ShardSpec(mesh_axes=mesh_axes, m=m_axis, k=k_axis, batch=b_axis,
                     collective=collective)


# -------------------------------------------------------------- execution
def _param_specs(spec, params: dict, s: ShardSpec) -> dict:
    """Per-leaf PartitionSpecs for a linear's param dict.  All weight
    views share (m, k) orientation — their packed second dims split
    cleanly because shard_spec_for guarded the alignment; the codebook
    (16,) value table is replicated."""
    out = {}
    for name, leaf in params.items():
        if name == "codebook":
            out[name] = P(*([None] * leaf.ndim))
        else:
            out[name] = P(s.m, s.k)
    return out


def run_sharded(backend, spec, plan, params: dict, x, *, k: int, mesh,
                precision=None, epilogue=None, bias=None, residual=None,
                fuse: bool = False):
    """Run one planned linear under shard_map on ``mesh``.

    The inner call sees *local* shapes — exactly the shapes
    ``dispatch.plan`` planned tiles for — so per-shard LUT produce and
    per-shard VMEM accumulation follow from the unmodified kernels.
    With a k-sharded (row-parallel) linear the epilogue runs once after
    the contraction collective; otherwise it fuses into the kernel
    writeback per shard (disjoint m rows) whenever the backend can.
    """
    s = plan.shard
    size = dict(s.mesh_axes)
    if any(mesh.shape.get(a) != n for a, n in s.mesh_axes) \
            or len(mesh.shape) != len(s.mesh_axes):
        raise ValueError(
            f"plan was sharded for mesh {dict(s.mesh_axes)} but the active "
            f"mesh is {dict(mesh.shape)}; re-plan under the current mesh")
    k_local = k // size.get(s.k, 1) if s.k else k
    inner_plan = dataclasses.replace(plan, shard=None)
    rank = x.ndim
    mid = (None,) * (rank - 2)
    # the m dim of y / bias / residual: m-sharded linears keep their own
    # axis; reduce_scatter hands the k axis over; psum replicates.
    out_m = s.m if s.k is None else (
        s.k if s.collective == "reduce_scatter" else None)

    operands = {"params": params, "x": x}
    in_specs = {"params": _param_specs(spec, params, s),
                "x": P(*((s.batch,) + mid + (s.k,)))}
    if bias is not None:
        operands["bias"] = bias
        in_specs["bias"] = P(out_m)
    if residual is not None:
        operands["residual"] = residual
        in_specs["residual"] = P(*((s.batch,) + mid + (out_m,)))
    out_specs = P(*((s.batch,) + mid + (out_m,)))

    # trace attribution: compute vs contraction collective, named by the
    # shard layout so a mesh trace splits step time between them.  The
    # marks are keyed to the *output* of each stage (data dependency, no
    # ordered side channel — safe under shard_map), and fire once per
    # device shard.
    tagname = s.tag()
    mk_compute = f"shard.compute.{tagname}.k{k_local}"
    mk_coll = f"shard.collective.{s.collective}.{tagname}"

    def local(ops):
        b_l, r_l = ops.get("bias"), ops.get("residual")
        x_l = obs.jit_begin(ops["x"], mk_compute)
        if s.k is None:
            if fuse:
                y = backend.run(spec, inner_plan, ops["params"], x_l,
                                k=k_local, precision=precision,
                                epilogue=epilogue, bias=b_l, residual=r_l)
                return obs.jit_end(y, mk_compute, cat="shard",
                                   hist="shard_compute_s",
                                   hist_labels={"tag": tagname})
            y = backend.run(spec, inner_plan, ops["params"], x_l,
                            k=k_local, precision=precision)
            y = obs.jit_end(y, mk_compute, cat="shard",
                            hist="shard_compute_s",
                            hist_labels={"tag": tagname})
            return apply_epilogue(y, epilogue, bias=b_l, residual=r_l)
        # row-parallel: partial sums over the local k slice; the epilogue
        # must see the *resolved* sum, never the per-shard partials
        y = backend.run(spec, inner_plan, ops["params"], x_l,
                        k=k_local, precision=precision)
        y = obs.jit_end(y, mk_compute, cat="shard",
                        hist="shard_compute_s",
                        hist_labels={"tag": tagname})
        y = obs.jit_begin(y, mk_coll)
        if s.collective == "reduce_scatter":
            y = jax.lax.psum_scatter(y, s.k, scatter_dimension=y.ndim - 1,
                                     tiled=True)
        else:
            y = jax.lax.psum(y, s.k)
        y = obs.jit_end(y, mk_coll, cat="shard",
                        hist="shard_collective_s",
                        hist_labels={"collective": s.collective,
                                     "axis": s.k})
        return apply_epilogue(y, epilogue, bias=b_l, residual=r_l)

    fn = compat.shard_map(local, mesh=mesh, in_specs=(in_specs,),
                          out_specs=out_specs, check=False)
    return fn(operands)
