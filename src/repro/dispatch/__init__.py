"""repro.dispatch — pluggable GeMM execution behind a stable front-end.

The three-layer split (see core/spec.py):

* ``QuantSpec`` (core.spec) says *what the weights are*;
* this package's **registry** holds the physical execution paths
  (dense MXU, jnp produce/consume msGeMM, fused Pallas msGeMM, int4
  dequant jnp + Pallas) as capability-scoped peers;
* ``plan()`` maps (spec, m, k, batch, device) to a frozen **ExecPlan**
  via heuristic or the persistent **autotuner**; ``execute()`` runs one
  linear through its plan.

``core.linear.apply`` is a thin wrapper over :func:`execute`; every
model linear in every architecture routes through here.
"""

from __future__ import annotations

import math

from repro import obs
from repro.core.epilogue import Epilogue, apply_epilogue  # noqa: F401
from repro.core.spec import QuantSpec, as_spec
from repro.dispatch.registry import (  # noqa: F401
    Backend, available_backends, backend_names, clear_quarantine,
    device_kind, get_backend, is_quarantined, quarantine_backend,
    quarantined, register_backend, select_backend, unregister_backend,
)
from repro.dispatch.plan import (  # noqa: F401
    DEFAULT_POLICY, ExecPlan, ExecPolicy, PlanRequest, collecting,
    get_default_policy, heuristic_plan, plan, plan_d, plan_key,
    set_default_policy, using_policy,
)
from repro.dispatch.shard import (  # noqa: F401
    ShardSpec, mesh_tag, plan_shard_tag, shard_spec_for,
)
from repro.dispatch import shard as _shard
from repro.dispatch import backends as _backends  # noqa: F401  (registers)
# NOTE: the tuner *function* lives at dispatch.autotune.autotune — the
# bare name is not re-exported so the ``autotune`` submodule stays
# addressable as dispatch.autotune.
from repro.dispatch.autotune import (  # noqa: F401
    PlanCache, cache, default_cache_path, set_cache_path, warm,
)


def split(cfg) -> tuple[QuantSpec, ExecPolicy | None]:
    """(spec, policy) from a QuantSpec (no policy) or a deprecated
    QuantConfig shim (which carries one)."""
    if isinstance(cfg, QuantSpec):
        return cfg, None
    spec = getattr(cfg, "spec", None)
    pol = getattr(cfg, "policy", None)
    if isinstance(spec, QuantSpec):
        return spec, pol
    raise TypeError(f"expected QuantSpec or QuantConfig, got {type(cfg)!r}")


def execute(params: dict, x, cfg, *, in_dim: int | None = None,
            precision=None, plan_override: ExecPlan | None = None,
            policy: ExecPolicy | None = None, epilogue: Epilogue | None = None,
            bias=None, residual=None, shard_axes: tuple | None = None):
    """Run one linear ``x (..., k) -> y (..., m)`` through the registry.

    Precedence for execution choices: explicit ``plan_override`` >
    ``policy`` argument > policy embedded in a QuantConfig shim >
    process default policy (``set_default_policy`` / CLI flags).

    ``epilogue`` (core.epilogue.Epilogue) describes the element-wise tail
    ``y = act(y + bias) + residual`` (then cast).  When the plan allows
    fusion (``plan.epilogue``) and the backend's capability predicate
    accepts the spec, the tail executes inside the kernel's final VMEM
    writeback — zero extra HBM passes; otherwise the same op sequence
    runs unfused after ``run`` (apply_epilogue, computed at f32-or-better
    like the fused accumulator).  For f32 activations the two routes are
    the same function; at lower activation precision they can differ by
    final-rounding ulps (the unfused route sees the GeMM output after
    its activation-dtype cast).  ``bias`` is (m,); ``residual`` matches
    the output shape (..., m) — both row-major model layout.

    ``shard_axes`` (the weight's logical (out, in) axis names) makes the
    linear mesh-aware: under an active mesh the resolved plan carries a
    ShardSpec and the backend runs inside a shard_map — per-shard LUT
    produce / VMEM accumulation, one contraction collective, the
    epilogue applied after it (dispatch.shard.run_sharded).
    """
    from repro.core import linear as _linear

    spec, cfg_policy = split(cfg)
    policy = policy or cfg_policy or get_default_policy()
    k = in_dim if in_dim is not None else _linear._infer_k(params, spec)
    m = (params["w"].shape[0] if spec.mode == "bf16"
         else params["scales"].shape[0])
    batch = math.prod(x.shape[:-1]) if x.ndim > 1 else 1
    p = plan_override
    if p is None:
        lead = x.shape[0] if x.ndim > 1 else 1
        p = plan(spec, m, k, batch, policy=policy,
                 shard_axes=shard_axes if x.ndim > 1 else None,
                 lead_batch=lead)
    be = get_backend(p.backend)
    d = plan_d(spec, m, k)
    # full capability check — matters for explicit plans (plan_override /
    # ExecPolicy.plan), which bypass plan()'s selection: e.g. int4_pallas
    # would silently dequantize a learned codebook with the uniform grid
    if not be.supports(spec, d):
        raise ValueError(
            f"plan backend {be.name!r} cannot execute mode={spec.mode!r} "
            f"d={d} storage={spec.storage!r} codebook={spec.codebook!r} "
            f"(modes={be.modes}, d_range={be.d_range}, "
            f"storages={be.storages}, codebooks={be.codebooks})")
    # a bias/residual array without a matching Epilogue flag would be
    # silently ignored by both the fused and unfused paths — reject it
    # (the inverse mismatch, flag without array, already raises)
    if bias is not None and (epilogue is None or not epilogue.bias):
        raise ValueError(
            "bias array given but the epilogue does not declare bias=True "
            "(pass epilogue=Epilogue(bias=True, ...) — or use "
            "common.linear_apply, which builds it for you)")
    if residual is not None and (epilogue is None or not epilogue.residual):
        raise ValueError(
            "residual array given but the epilogue does not declare "
            "residual=True (pass epilogue=Epilogue(residual=True, ...) — "
            "or use common.linear_apply, which builds it for you)")
    fuse = (epilogue is not None and not epilogue.is_identity
            and p.epilogue and be.epilogue_ok(epilogue))
    if epilogue is not None and not epilogue.is_identity:
        # fusion *rate* = fused / (fused + unfused); counted per traced
        # call site, which is once per (shape, phase) executable
        obs.registry().counter(
            "dispatch_epilogue_total",
            help="non-identity epilogues by fused/unfused execution",
            fused="true" if fuse else "false").inc()
    if p.shard is not None and p.shard.is_sharded:
        from repro.distributed.sharding import active_mesh

        mesh = active_mesh()
        if mesh is not None:
            return _shard.run_sharded(
                be, spec, p, params, x, k=k, mesh=mesh, precision=precision,
                epilogue=epilogue, bias=bias, residual=residual, fuse=fuse)
        # a sharded plan without a live mesh (explicit override outside
        # sharding.use): fall through and run unsharded on local math
    mark = f"gemm.{be.name}.m{m}.k{k}.b{batch}"
    # mode/d/sb make the series self-describing for the perf-model
    # regression sentinel (obs.perfmodel.samples_from_snapshot)
    labels = {"backend": be.name, "m": m, "k": k, "b": batch,
              "mode": spec.mode, "d": d, "sb": spec.scale_block}
    x = obs.jit_begin(x, mark)
    if fuse:
        y = be.run(spec, p, params, x, k=k, precision=precision,
                   epilogue=epilogue, bias=bias, residual=residual)
        return obs.jit_end(y, mark, cat="gemm", hist="kernel_gemm_s",
                           hist_labels=labels)
    y = be.run(spec, p, params, x, k=k, precision=precision)
    y = obs.jit_end(y, mark, cat="gemm", hist="kernel_gemm_s",
                    hist_labels=labels)
    return apply_epilogue(y, epilogue, bias=bias, residual=residual)
