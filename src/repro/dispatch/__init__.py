"""repro.dispatch — pluggable GeMM execution behind a stable front-end.

The three-layer split (see core/spec.py):

* ``QuantSpec`` (core.spec) says *what the weights are*;
* this package's **registry** holds the physical execution paths
  (dense MXU, jnp produce/consume msGeMM, fused Pallas msGeMM, int4
  dequant jnp + Pallas) as capability-scoped peers;
* ``plan()`` maps (spec, m, k, batch, device) to a frozen **ExecPlan**
  via heuristic or the persistent **autotuner**; ``execute()`` runs one
  linear through its plan.

``core.linear.apply`` is a thin wrapper over :func:`execute`; every
model linear in every architecture routes through here.
"""

from __future__ import annotations

import math

from repro.core.spec import QuantSpec, as_spec
from repro.dispatch.registry import (  # noqa: F401
    Backend, available_backends, backend_names, device_kind, get_backend,
    register_backend, select_backend, unregister_backend,
)
from repro.dispatch.plan import (  # noqa: F401
    DEFAULT_POLICY, ExecPlan, ExecPolicy, collecting, get_default_policy,
    heuristic_plan, plan, plan_d, plan_key, set_default_policy,
    using_policy,
)
from repro.dispatch import backends as _backends  # noqa: F401  (registers)
# NOTE: the tuner *function* lives at dispatch.autotune.autotune — the
# bare name is not re-exported so the ``autotune`` submodule stays
# addressable as dispatch.autotune.
from repro.dispatch.autotune import (  # noqa: F401
    PlanCache, cache, default_cache_path, set_cache_path, warm,
)


def split(cfg) -> tuple[QuantSpec, ExecPolicy | None]:
    """(spec, policy) from a QuantSpec (no policy) or a deprecated
    QuantConfig shim (which carries one)."""
    if isinstance(cfg, QuantSpec):
        return cfg, None
    spec = getattr(cfg, "spec", None)
    pol = getattr(cfg, "policy", None)
    if isinstance(spec, QuantSpec):
        return spec, pol
    raise TypeError(f"expected QuantSpec or QuantConfig, got {type(cfg)!r}")


def execute(params: dict, x, cfg, *, in_dim: int | None = None,
            precision=None, plan_override: ExecPlan | None = None,
            policy: ExecPolicy | None = None):
    """Run one linear ``x (..., k) -> y (..., m)`` through the registry.

    Precedence for execution choices: explicit ``plan_override`` >
    ``policy`` argument > policy embedded in a QuantConfig shim >
    process default policy (``set_default_policy`` / CLI flags).
    """
    from repro.core import linear as _linear

    spec, cfg_policy = split(cfg)
    policy = policy or cfg_policy or get_default_policy()
    k = in_dim if in_dim is not None else _linear._infer_k(params, spec)
    m = (params["w"].shape[0] if spec.mode == "bf16"
         else params["scales"].shape[0])
    p = plan_override
    if p is None:
        batch = math.prod(x.shape[:-1]) if x.ndim > 1 else 1
        p = plan(spec, m, k, batch, policy=policy)
    be = get_backend(p.backend)
    d = plan_d(spec, m, k)
    # full capability check — matters for explicit plans (plan_override /
    # ExecPolicy.plan), which bypass plan()'s selection: e.g. int4_pallas
    # would silently dequantize a learned codebook with the uniform grid
    if not be.supports(spec, d):
        raise ValueError(
            f"plan backend {be.name!r} cannot execute mode={spec.mode!r} "
            f"d={d} storage={spec.storage!r} codebook={spec.codebook!r} "
            f"(modes={be.modes}, d_range={be.d_range}, "
            f"storages={be.storages}, codebooks={be.codebooks})")
    return be.run(spec, p, params, x, k=k, precision=precision)
