"""Pluggable execution-backend registry.

One logical GeMM admits many physical executions (paper Eq. 15: the best
choice depends on shape and hardware).  Each execution path registers
here as a peer with capability predicates; selection is deterministic —
highest priority among the available backends that can run the spec,
ties broken by name.

A backend's ``run`` callable has the uniform signature::

    run(spec, plan, params, x, *, k, precision=None) -> y

with ``x (..., k)`` row-major activations and ``y (..., m)`` — the
convention of ``core.linear.apply``.  New backends (CPU/GPU Pallas
variants, XLA int8, ...) plug in via :func:`register_backend` without
touching ``core.linear`` or any model code.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable

import jax

from repro.core.spec import QuantSpec


def _always(device_kind: str) -> bool:
    return True


def _no_epilogue(epilogue) -> bool:
    """Default epilogue capability: fuse nothing (dispatch.execute applies
    the epilogue unfused after ``run`` — core.epilogue.apply_epilogue)."""
    return False


@dataclass(frozen=True)
class Backend:
    """A registered execution path with its capability envelope."""

    name: str
    modes: tuple[str, ...]            # quant modes it can execute
    run: Callable                      # run(spec, plan, params, x, *, k, ...)
    is_available: Callable[[str], bool] = _always  # device kind -> bool
    # higher wins in auto-selection; an int, or a callable(device_kind)
    # for device-dependent ranking (msgemm_pallas outranks the jnp scan
    # on real TPU but not in CPU interpret mode)
    priority: int | Callable[[str], int] = 0
    d_range: tuple[int, int] = (1, 4)  # inclusive LUT-depth envelope
    storages: tuple[str, ...] = ("packed_idx", "packed_u8")
    codebooks: tuple[str, ...] = ("none", "learned")
    tunable: tuple[str, ...] = ()      # ExecPlan fields the autotuner explores
    # epilogue capability predicate: can this backend execute the given
    # core.epilogue.Epilogue *inside* its kernel (fused into the final
    # writeback)?  False -> dispatch.execute applies it unfused after run.
    epilogue_ok: Callable = _no_epilogue
    description: str = ""

    def priority_for(self, device_kind: str) -> int:
        return self.priority(device_kind) if callable(self.priority) \
            else self.priority

    def supports(self, spec: QuantSpec, d: int) -> bool:
        """Can this backend execute weights described by ``spec`` at depth d?"""
        if spec.mode not in self.modes:
            return False
        if spec.storage not in self.storages:
            return False
        if spec.codebook not in self.codebooks:
            return False
        if spec.mode == "msgemm" and not self.d_range[0] <= d <= self.d_range[1]:
            return False
        return True


_REGISTRY: dict[str, Backend] = {}

# Runtime quarantine: backend name -> reason.  A quarantined backend is
# skipped by auto-selection and by forced-policy resolution so a
# misbehaving execution path (NaN logits, watchdog hang) degrades to the
# next backend on the ladder instead of crashing the server.  Quarantine
# is process-local, never persisted, and cleared by clear_quarantine().
_QUARANTINED: dict[str, str] = {}


def quarantine_backend(name: str, reason: str = "") -> None:
    """Mark a backend suspect; selection skips it until cleared.  The
    dense fallback ladder guarantees a safe backend always remains, but
    if quarantine would leave a spec with zero candidates, selection
    ignores the quarantine rather than fail (see available_backends)."""
    get_backend(name)  # raise on unknown names
    _QUARANTINED[name] = reason or "quarantined"
    from repro import obs
    obs.registry().counter(
        "dispatch_backend_quarantined_total", backend=name).inc()
    obs.registry().gauge("dispatch_backends_quarantined").set(
        len(_QUARANTINED))


def clear_quarantine(name: str | None = None) -> None:
    """Lift quarantine for one backend, or all when name is None."""
    if name is None:
        _QUARANTINED.clear()
    else:
        _QUARANTINED.pop(name, None)
    from repro import obs
    obs.registry().gauge("dispatch_backends_quarantined").set(
        len(_QUARANTINED))


def is_quarantined(name: str) -> bool:
    return name in _QUARANTINED


def quarantined() -> dict[str, str]:
    """Snapshot of the current quarantine list (name -> reason)."""
    return dict(_QUARANTINED)


def register_backend(name: str, *, modes, run, is_available=_always,
                     priority: int = 0, d_range=(1, 4),
                     storages=("packed_idx", "packed_u8"),
                     codebooks=("none", "learned"), tunable=(),
                     epilogue_ok=_no_epilogue,
                     description: str = "", overwrite: bool = False) -> Backend:
    """Register an execution backend.  Raises on duplicate names unless
    ``overwrite`` (tests use overwrite to shadow a backend temporarily)."""
    if name in _REGISTRY and not overwrite:
        raise ValueError(f"backend {name!r} already registered; "
                         "pass overwrite=True to replace it")
    be = Backend(name=name, modes=tuple(modes), run=run,
                 is_available=is_available, priority=priority,
                 d_range=tuple(d_range), storages=tuple(storages),
                 codebooks=tuple(codebooks), tunable=tuple(tunable),
                 epilogue_ok=epilogue_ok,
                 description=description)
    _REGISTRY[name] = be
    return be


def unregister_backend(name: str) -> None:
    _REGISTRY.pop(name, None)


def get_backend(name: str) -> Backend:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown backend {name!r}; registered: {sorted(_REGISTRY)}"
        ) from None


def backend_names() -> list[str]:
    return sorted(_REGISTRY)


def device_kind() -> str:
    """The platform string auto-selection keys on ('cpu'|'gpu'|'tpu')."""
    return jax.default_backend()


def available_backends(spec: QuantSpec, d: int, device: str | None = None
                       ) -> list[Backend]:
    """Backends that can run ``spec`` on ``device``, best-first
    (priority desc, then name — fully deterministic)."""
    dev = device or device_kind()
    cands = [b for b in _REGISTRY.values()
             if b.supports(spec, d) and b.is_available(dev)]
    if _QUARANTINED:
        healthy = [b for b in cands if b.name not in _QUARANTINED]
        # never quarantine into an empty candidate set — serving a
        # suspect backend beats serving nothing
        cands = healthy or cands
    return sorted(cands, key=lambda b: (-b.priority_for(dev), b.name))


def select_backend(spec: QuantSpec, d: int, device: str | None = None
                   ) -> Backend:
    """Deterministic auto-selection: highest-priority capable backend."""
    cands = available_backends(spec, d, device)
    if not cands:
        raise ValueError(
            f"no backend can execute mode={spec.mode!r} d={d} "
            f"storage={spec.storage!r} codebook={spec.codebook!r} on "
            f"{device or device_kind()!r}; registered: {backend_names()}")
    return cands[0]
