"""Paged attention over the quantized KV block pool — dequantize in VMEM.

The serving-side analogue of kernels/msgemm.py's produce-once/consume-many
structure: the pool stores low-bit codes + scales in HBM (repro.kvq),
and the *kernel* reconstructs K/V values from the 16-entry table / int
grid inside VMEM right before the dot — the HBM-resident dequantized
copies that ``models/layers.attn_paged``'s jnp reference path
materializes via ``jnp.take`` never exist here.  Per step the kernel
reads the quantized bytes once; the f32 K/V blocks live only as
(block_size, Dh) VMEM tiles.

Block tables ride scalar prefetch (pltpu.PrefetchScalarGridSpec): the
grid is (B, H, blocks-per-view) and the kv-side index maps dereference
``block_tables[b, i]`` to DMA exactly the block each step consumes —
gather-by-block-table at the BlockSpec level, no flat-slot gather op.

Softmax is the standard flash online recurrence (kernels/
flash_attention.py) carried in VMEM scratch across the innermost grid
dim; masking is position-based (layers.view_mask semantics): view index
w holds logical position w, so kvpos = i*block_size + offset and a row
attends iff kvpos <= qpos (+ sliding window).  Scratch-padded blocks sit
at view positions > every qpos, so they mask to probability exactly 0;
view position 0 is always valid (every query attends to it), so the
running max is grounded before any fully-masked block is folded in.

Validated against the jnp gather+dequant reference in interpret mode
(tests/test_kvq.py); ``interpret=None`` auto-detects like the other
kernels (compiled on TPU, interpreter elsewhere).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_block(codes, scales, *, bits: int, codebook, head_dim: int):
    """(bs, Dhp) u8 codes + (bs,) scales -> (bs, Dh) f32 values, all in
    VMEM.  The codebook path reconstructs via a 16-way select chain on
    scalar constants — no in-kernel gather, no captured array consts."""
    c = codes.astype(jnp.int32)
    if bits == 8:
        vals = jnp.where(c < 128, c, c - 256).astype(jnp.float32)
    else:
        hi, lo = (c >> 4) & 0xF, c & 0xF  # hi nibble first (pack_storage)
        cc = jnp.stack([hi, lo], axis=-1).reshape(c.shape[0], -1)
        cc = cc[:, :head_dim]
        if codebook is None:
            vals = jnp.where(cc <= 7, cc, cc - 16).astype(jnp.float32)
        else:
            # 16-way select chain over scalar constants: pallas_call
            # rejects captured *array* constants, and a chain of selects
            # on the (bs, Dh) code tile is VPU-trivial next to the dot
            vals = jnp.zeros(cc.shape, jnp.float32)
            for j, entry in enumerate(codebook):
                if entry:
                    vals = jnp.where(cc == j, jnp.float32(entry), vals)
    return vals * scales.astype(jnp.float32)[:, None]


def _kernel(bt_ref, q_ref, pos_ref, kc_ref, ks_ref, vc_ref, vs_ref, o_ref,
            m_scr, l_scr, acc_scr, *, bits: int, codebook,
            block_size: int, nseq: int, head_dim: int, window: int,
            softcap: float, scale: float):
    i = pl.program_id(2)

    @pl.when(i == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr[...], NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr[...])
        acc_scr[...] = jnp.zeros_like(acc_scr[...])

    qb = q_ref[...][0, :, 0, :].astype(jnp.float32) * scale  # (C, Dh)
    k = _decode_block(kc_ref[...][0, :, 0, :], ks_ref[...][0, :, 0],
                      bits=bits, codebook=codebook, head_dim=head_dim)
    v = _decode_block(vc_ref[...][0, :, 0, :], vs_ref[...][0, :, 0],
                      bits=bits, codebook=codebook, head_dim=head_dim)
    s = qb @ k.T  # (C, bs)
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    qpos = pos_ref[...][0]  # (C,)
    kvpos = i * block_size + jax.lax.iota(jnp.int32, block_size)
    ok = kvpos[None, :] <= qpos[:, None]
    if window:
        ok &= kvpos[None, :] > qpos[:, None] - window
    s = jnp.where(ok, s, NEG_INF)

    m, l = m_scr[...][:, 0], l_scr[...][:, 0]
    m_new = jnp.maximum(m, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m - m_new)
    l_new = corr * l + jnp.sum(p, axis=-1)
    acc_new = corr[:, None] * acc_scr[...] + p @ v
    m_scr[...] = m_new[:, None]
    l_scr[...] = l_new[:, None]
    acc_scr[...] = acc_new

    @pl.when(i == nseq - 1)
    def _writeback():
        o_ref[...] = (acc_new / jnp.maximum(l_new, 1e-30)[:, None]
                      )[None, :, None, :].astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("bits", "codebook", "block_size", "window",
                              "softcap", "interpret"))
def paged_attention_pallas(q, k_codes, k_scales, v_codes, v_scales,
                           block_tables, positions, *, bits: int,
                           codebook=None, block_size: int,
                           window: int = 0, softcap: float = 0.0,
                           interpret: bool | None = None):
    """q (B, C, H, Dh); codes (nb, bs, Hk, Dhp) u8 + scales (nb, bs, Hk)
    f32 (the repro.kvq pool layout); block_tables (B, nseq) int32 block
    ids covering each row's view positions [0, nseq*bs); positions (B, C)
    int32 logical query positions.  Returns (B, C, H, Dh) in q.dtype.

    ``codebook`` is the spec's static 16-float tuple (None: int grid) —
    embedded as a compile-time constant, consumed from VMEM per block."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, C, H, dh = q.shape
    nb, bs, hk, dhp = k_codes.shape
    assert bs == block_size, (bs, block_size)
    assert H % hk == 0, (H, hk)
    g = H // hk
    nseq = block_tables.shape[1]
    kern = functools.partial(
        _kernel, bits=bits, codebook=codebook, block_size=block_size,
        nseq=nseq, head_dim=dh, window=window, softcap=softcap,
        scale=dh**-0.5)
    code_spec = pl.BlockSpec((1, bs, 1, dhp),
                             lambda b, h, i, bt: (bt[b, i], 0, h // g, 0))
    scale_spec = pl.BlockSpec((1, bs, 1),
                              lambda b, h, i, bt: (bt[b, i], 0, h // g))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, H, nseq),
        in_specs=[
            pl.BlockSpec((1, C, 1, dh), lambda b, h, i, bt: (b, 0, h, 0)),
            pl.BlockSpec((1, C), lambda b, h, i, bt: (b, 0)),
            code_spec, scale_spec, code_spec, scale_spec,
        ],
        out_specs=pl.BlockSpec((1, C, 1, dh),
                               lambda b, h, i, bt: (b, 0, h, 0)),
        scratch_shapes=[
            pltpu.VMEM((C, 1), jnp.float32),   # running max m
            pltpu.VMEM((C, 1), jnp.float32),   # running denom l
            pltpu.VMEM((C, dh), jnp.float32),  # output accumulator
        ],
    )
    return pl.pallas_call(
        kern, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, C, H, dh), q.dtype),
        interpret=interpret,
    )(jnp.asarray(block_tables, jnp.int32), q,
      jnp.asarray(positions, jnp.int32), k_codes, k_scales,
      v_codes, v_scales)
