"""jit'd public wrappers around the Pallas kernels.

Handles tile-size selection (VMEM budgeting), padding to tile multiples,
backend detection (interpret=True off-TPU), and the quantized-param
plumbing used by core.linear's ``impl='pallas'`` path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import packing
from repro.kernels import int4_matmul as _i4
from repro.kernels import msgemm as _ms

VMEM_BUDGET = 8 * 1024 * 1024  # conservative per-step LUT budget (bytes)


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _round_up(v: int, mult: int) -> int:
    return -(-v // mult) * mult


def _pick_tiles(m: int, kc: int, b: int, d: int, scale_block: int):
    """Pick (tm, tj, tb) fitting the 16^d LUT tile in the VMEM budget.

    tj must stay a multiple of scale_block // d (factored-scale tiling,
    §3.3).  Growth doubles tj only while the doubled tile still divides
    kc evenly AND fits within kc: the old ``kc % (tj*2) == 0 or
    kc > tj*2`` condition let non-power-of-two kc overshoot into a
    non-divisor tile, silently padding dead columns the kernel then
    gathered for nothing (e.g. kc=86, cpb=12 grew tj to 96 -> 10 dead
    chunk columns per row).
    """
    n = 16**d
    cpb = scale_block // d
    tb = min(128, _round_up(b, 8))
    tj = cpb
    # grow tj while the LUT tile (n * tj * tb * 4B) stays in budget and
    # the doubled tile still tiles kc exactly (tj <= kc, kc % tj == 0)
    while (n * tj * 2 * tb * 4 <= VMEM_BUDGET
           and tj * 2 <= kc and kc % (tj * 2) == 0):
        tj *= 2
    tm = min(256, _round_up(m, 8))
    return tm, tj, tb


def msgemm_tiles(m: int, kc: int, b: int, d: int, scale_block: int):
    """Public heuristic tile choice for the fused msgemm kernel —
    (tm, tj, tb) for (m rows, kc packed chunks, b batch cols).  The
    dispatch planner records these into ExecPlans; the autotuner seeds
    its candidate grid from them."""
    return _pick_tiles(m, kc, b, d, scale_block)


def int4_tiles(m: int, k: int, b: int, scale_block: int):
    """Heuristic (tm, tk, tb) for the blocked int4 dequant kernel."""
    tk = scale_block * max(1, 128 // scale_block)
    tm = min(256, _round_up(m, 8))
    tb = min(128, _round_up(b, 8))
    return tm, tk, tb


def msgemm(codes: jnp.ndarray, x: jnp.ndarray, d: int, *,
           scales: jnp.ndarray | None = None, scale_block: int = 36,
           codebook: jnp.ndarray | None = None,
           interpret: bool | None = None,
           tm: int | None = None, tj: int | None = None,
           tb: int | None = None) -> jnp.ndarray:
    """y (m, b) = dequant(codes (m,k)) @ x (k, b) via the fused kernel.

    Pads every dim to tile multiples; zero code rows/cols contribute 0
    (codebooks pin value 0 at code 0, so this holds for learned tables
    too).  ``codebook``: optional (16,) non-uniform value table.

    ``tm/tj/tb``: explicit tile sizes from a dispatch ExecPlan (the
    autotuner's winners); None falls back to the heuristic.  tj must be
    a multiple of scale_block // d (§3.3 factored-scale tiling).
    """
    m, k = codes.shape
    squeeze = x.ndim == 1
    if squeeze:
        x = x[:, None]
    b = x.shape[1]
    if scales is None:
        scales = jnp.ones((m, -(-k // scale_block)), jnp.float32)
    idx = packing.pack_indices(codes, d)
    kc = idx.shape[1]

    htm, htj, htb = _pick_tiles(m, kc, b, d, scale_block)
    tm, tj, tb = tm or htm, tj or htj, tb or htb
    mp, kcp, bp = _round_up(m, tm), _round_up(kc, tj), _round_up(b, tb)
    sj = kcp * d // scale_block
    idx_p = jnp.pad(idx, ((0, mp - m), (0, kcp - kc)))
    x_p = jnp.pad(x.astype(jnp.float32),
                  ((0, kcp * d - x.shape[0]), (0, bp - b)))
    sc_p = jnp.pad(scales.astype(jnp.float32),
                   ((0, mp - m), (0, sj - scales.shape[1])))
    y = _ms.msgemm_pallas(
        idx_p, x_p, sc_p, codebook, d=d, scale_block=scale_block,
        tm=tm, tj=tj, tb=tb,
        interpret=_interpret() if interpret is None else interpret)
    y = y[:m, :b]
    return y[:, 0] if squeeze else y


def int4_matmul(u8: jnp.ndarray, scales: jnp.ndarray, x: jnp.ndarray, *,
                scale_block: int = 32, interpret: bool | None = None,
                tm: int | None = None, tk: int | None = None,
                tb: int | None = None) -> jnp.ndarray:
    """y = dequant(packed u8 (m, k/2)) @ x (k, b) via the dequant kernel.

    ``tm/tk/tb``: explicit tiles from a dispatch ExecPlan; None falls
    back to the heuristic (tk must be even and % scale_block == 0)."""
    m = u8.shape[0]
    squeeze = x.ndim == 1
    if squeeze:
        x = x[:, None]
    k, b = x.shape
    htm, htk, htb = int4_tiles(m, k, b, scale_block)
    tm, tk, tb = tm or htm, tk or htk, tb or htb
    mp, kp, bp = _round_up(m, tm), _round_up(k, tk), _round_up(b, tb)
    u8_p = jnp.pad(u8, ((0, mp - m), (0, kp // 2 - u8.shape[1])))
    sc_p = jnp.pad(scales.astype(jnp.float32),
                   ((0, mp - m), (0, kp // scale_block - scales.shape[1])))
    x_p = jnp.pad(x.astype(jnp.float32), ((0, kp - k), (0, bp - b)))
    y = _i4.int4_matmul_pallas(
        u8_p, sc_p, x_p, scale_block=scale_block, tm=tm, tk=tk, tb=tb,
        interpret=_interpret() if interpret is None else interpret)
    y = y[:m, :b]
    return y[:, 0] if squeeze else y


def flash_attention(q, k, v, *, causal=True, window=0, softcap=0.0,
                    interpret=None):
    """Multi-head attention via the flash kernel.

    q (B, Sq, H, dh), k/v (B, Skv, Hk, dh) with H % Hk == 0 (GQA kv heads
    broadcast).  Pads sequence dims to tile multiples (masked out)."""
    from repro.kernels import flash_attention as _fa

    B, Sq, H, dh = q.shape
    Skv, Hk = k.shape[1], k.shape[2]
    if Hk != H:  # broadcast GQA kv heads
        rep = H // Hk
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    tq = min(128, _round_up(Sq, 8))
    tk = min(128, _round_up(Skv, 8))
    sqp, skp = _round_up(Sq, tq), _round_up(Skv, tk)
    qt = jnp.moveaxis(jnp.pad(q, ((0, 0), (0, sqp - Sq), (0, 0), (0, 0))),
                      2, 1).reshape(B * H, sqp, dh)
    kt = jnp.moveaxis(jnp.pad(k, ((0, 0), (0, skp - Skv), (0, 0), (0, 0))),
                      2, 1).reshape(B * H, skp, dh)
    vt = jnp.moveaxis(jnp.pad(v, ((0, 0), (0, skp - Skv), (0, 0), (0, 0))),
                      2, 1).reshape(B * H, skp, dh)
    # padded keys must never win the softmax: causal masking handles the
    # q-pad rows; mask k-pad via a window-free explicit guard in-kernel is
    # unnecessary because padded kpos > any real qpos under causal; for
    # non-causal callers we require Skv % tk == 0 (asserted).
    if not causal:
        assert skp == Skv, "non-causal flash requires Skv % tile == 0"
    o = _fa.flash_attention_pallas(
        qt, kt, vt, causal=causal, window=window, softcap=softcap,
        tq=tq, tk=tk,
        interpret=_interpret() if interpret is None else interpret)
    o = jnp.moveaxis(o.reshape(B, H, sqp, dh), 1, 2)[:, :Sq]
    return o
