"""jit'd public wrappers around the Pallas kernels.

Handles tile-size selection (VMEM budgeting), padding to tile multiples,
backend detection (interpret=True off-TPU), epilogue padding/layout, and
the quantized-param plumbing used by the dispatch backends.

VMEM budget math (README §Kernel performance): the fused msgemm kernel
holds, per core,

* the LUT tile           16^d · TJ · TB · 4 B   (≤ ``VMEM_BUDGET``)
* the f32 acc stripe     mp · TB · 4 B          (≤ ``ACC_BUDGET`` together
* the resident out block mp · TB · out_bytes     with the out stripe)

plus the small idx/x/scale blocks.  ``_pick_tiles`` first sizes TB to the
batch (decode: TB == round_up(b, 8), *not* padded to 128 — small-batch
decode shapes get narrow stripes and the freed LUT budget lets TJ grow),
shrinks TB if the acc stripe would blow ``ACC_BUDGET``, then grows TJ
while the LUT tile stays within ``VMEM_BUDGET``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import packing
from repro.core.epilogue import Epilogue
from repro.kernels import int4_matmul as _i4
from repro.kernels import msgemm as _ms

VMEM_BUDGET = 8 * 1024 * 1024  # conservative per-step LUT budget (bytes)
ACC_BUDGET = 4 * 1024 * 1024   # acc + out stripe budget (bytes)
DECODE_BATCH = 32  # b <= this is treated as a decode shape (tall-skinny)


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _round_up(v: int, mult: int) -> int:
    return -(-v // mult) * mult


def _pick_tiles(m: int, kc: int, b: int, d: int, scale_block: int,
                out_bytes: int = 4, residual: bool = False):
    """Pick (tm, tj, tb) fitting the 16^d LUT tile in the VMEM budget.

    tj must stay a multiple of scale_block // d (factored-scale tiling,
    §3.3).  Growth doubles tj only while the doubled tile still divides
    kc evenly AND fits within kc: the old ``kc % (tj*2) == 0 or
    kc > tj*2`` condition let non-power-of-two kc overshoot into a
    non-divisor tile, silently padding dead columns the kernel then
    gathered for nothing (e.g. kc=86, cpb=12 grew tj to 96 -> 10 dead
    chunk columns per row).

    tb is sized to the actual batch (decode: round_up(b, 8), never padded
    to 128) and shrunk while the fused kernel's VMEM acc+out stripe
    (mp·tb·8 B) exceeds ACC_BUDGET.  Decode shapes (b <= DECODE_BATCH)
    take tm up to 512: more rows per gather step against the same
    resident LUT tile.
    """
    n = 16**d
    cpb = scale_block // d
    tb = min(128, _round_up(b, 8))
    tm_cap = 512 if b <= DECODE_BATCH else 256
    tm = min(tm_cap, _round_up(m, 8))
    # acc stripe (f32 acc + f32 out ~ 8 B/elem) must stay within budget —
    # but only shrink tb when some tb can actually satisfy it; if even the
    # tb floor cannot (vocab-sized m), the shape runs the legacy kernel
    # (no stripe) and a batch-wide tb is the right choice there
    # out_bytes/residual let ops.msgemm shrink for the stripes the fused
    # call will actually keep resident (the planner, which cannot know
    # the per-call epilogue, budgets the plain acc+out stripes)
    if acc_stripe_fits(m, tm, 8, out_bytes, residual):
        while tb > 8 and not acc_stripe_fits(m, tm, tb, out_bytes, residual):
            tb = max(8, _round_up(tb // 2, 8))
    tj = cpb
    # grow tj while the LUT tile (n * tj * tb * 4B) stays in budget and
    # the doubled tile still tiles kc exactly (tj <= kc, kc % tj == 0)
    while (n * tj * 2 * tb * 4 <= VMEM_BUDGET
           and tj * 2 <= kc and kc % (tj * 2) == 0):
        tj *= 2
    return tm, tj, tb


def acc_stripe_fits(m: int, tm: int, tb: int, out_bytes: int = 4,
                    residual: bool = False) -> bool:
    """Can the fused kernel's VMEM-resident stripes for this shape stay
    within (2x of) ACC_BUDGET?  Counts the f32 acc scratch, the resident
    out block, and — when a residual is fused — the residual operand's
    resident (mp, tb) block.  Beyond that — e.g. a vocab-sized lm-head m
    at the tb floor — ops.msgemm falls back to the legacy j-innermost
    accumulation (no stripes) rather than asking Mosaic for an
    unbuildable allocation."""
    mp = _round_up(m, tm)
    per_elem = 4 + out_bytes + (4 if residual else 0)
    return mp * tb * per_elem <= 2 * ACC_BUDGET


def msgemm_tiles(m: int, kc: int, b: int, d: int, scale_block: int):
    """Public heuristic tile choice for the fused msgemm kernel —
    (tm, tj, tb) for (m rows, kc packed chunks, b batch cols).  The
    dispatch planner records these into ExecPlans; the autotuner seeds
    its candidate grid from them."""
    return _pick_tiles(m, kc, b, d, scale_block)


def int4_tiles(m: int, k: int, b: int, scale_block: int):
    """Heuristic (tm, tk, tb) for the blocked int4 dequant kernel."""
    tk = scale_block * max(1, 128 // scale_block)
    tm = min(256, _round_up(m, 8))
    tb = min(128, _round_up(b, 8))
    return tm, tk, tb


def _epilogue_cols(y: jnp.ndarray, ep: Epilogue | None,
                   bias: jnp.ndarray | None,
                   residual: jnp.ndarray | None) -> jnp.ndarray:
    """Unfused epilogue in the kernels' (m, b) column layout — the exact
    op order of the fused writeback, for acc_in_vmem=False / jnp paths."""
    if ep is None or ep.is_identity:
        return y
    if ep.bias:
        y = y + bias[:, None].astype(y.dtype)
    y = ep.act_fn()(y)
    if ep.residual:
        y = y + residual.astype(y.dtype)
    if ep.out_dtype is not None:
        y = y.astype(ep.out_dtype)
    return y


def msgemm(codes: jnp.ndarray, x: jnp.ndarray, d: int, *,
           scales: jnp.ndarray | None = None, scale_block: int = 36,
           codebook: jnp.ndarray | None = None,
           interpret: bool | None = None,
           tm: int | None = None, tj: int | None = None,
           tb: int | None = None,
           acc_dtype=jnp.float32, acc_in_vmem: bool = True,
           epilogue: Epilogue | None = None,
           bias: jnp.ndarray | None = None,
           residual: jnp.ndarray | None = None) -> jnp.ndarray:
    """y (m, b) = epilogue(dequant(codes (m,k)) @ x (k, b)) via the kernel.

    Pads every dim to tile multiples; zero code rows/cols contribute 0
    (codebooks pin value 0 at code 0, so this holds for learned tables
    too).  ``codebook``: optional (16,) non-uniform value table.

    ``tm/tj/tb``: explicit tile sizes from a dispatch ExecPlan (the
    autotuner's winners); None falls back to the heuristic, which is only
    computed when at least one tile is missing (an ExecPlan that names
    all three skips the pick entirely).  tj must be a multiple of
    scale_block // d (§3.3 factored-scale tiling).

    ``epilogue``: a core.epilogue.Epilogue fused into the kernel's final
    VMEM writeback (``acc_in_vmem=True``); the legacy path
    (``acc_in_vmem=False``) applies it unfused after the kernel, same op
    order.  ``bias`` is (m,), ``residual`` is (m, b) column layout.
    """
    ep = epilogue or Epilogue()
    m, k = codes.shape
    squeeze = x.ndim == 1
    if squeeze:
        x = x[:, None]
        if residual is not None and residual.ndim == 1:
            residual = residual[:, None]
    b = x.shape[1]
    if scales is None:
        scales = jnp.ones((m, -(-k // scale_block)), jnp.float32)
    idx = packing.pack_indices(codes, d)
    kc = idx.shape[1]

    out_bytes = jnp.dtype(ep.out_dtype or jnp.float32).itemsize
    if tm is None or tj is None or tb is None:
        htm, htj, htb = _pick_tiles(
            m, kc, b, d, scale_block, out_bytes,
            residual=acc_in_vmem and ep.residual)
        tm, tj, tb = tm or htm, tj or htj, tb or htb
    if acc_in_vmem and not acc_stripe_fits(
            m, tm, tb, out_bytes, residual=ep.residual):
        acc_in_vmem = False  # stripes would blow VMEM — legacy accumulation
    mp, kcp, bp = _round_up(m, tm), _round_up(kc, tj), _round_up(b, tb)
    sj = kcp * d // scale_block
    idx_p = jnp.pad(idx, ((0, mp - m), (0, kcp - kc)))
    x_p = jnp.pad(x.astype(jnp.float32),
                  ((0, kcp * d - x.shape[0]), (0, bp - b)))
    sc_p = jnp.pad(scales.astype(jnp.float32),
                   ((0, mp - m), (0, sj - scales.shape[1])))
    interpret = _interpret() if interpret is None else interpret

    fuse = acc_in_vmem and not ep.is_identity
    bias_p = res_p = None
    if fuse:
        if ep.bias:
            bias_p = jnp.pad(bias.astype(jnp.float32)[:, None],
                             ((0, mp - m), (0, 0)))
        if ep.residual:
            res_p = jnp.pad(residual.astype(jnp.float32),
                            ((0, mp - m), (0, bp - b)))
    y = _ms.msgemm_pallas(
        idx_p, x_p, sc_p, codebook, bias_p, res_p, d=d,
        scale_block=scale_block, tm=tm, tj=tj, tb=tb, interpret=interpret,
        acc_dtype=acc_dtype, acc_in_vmem=acc_in_vmem,
        epilogue=ep if fuse else None)
    y = y[:m, :b]
    if not fuse:
        y = _epilogue_cols(y, ep, bias, residual)
    return y[:, 0] if squeeze else y


def int4_matmul(u8: jnp.ndarray, scales: jnp.ndarray, x: jnp.ndarray, *,
                scale_block: int = 32, interpret: bool | None = None,
                tm: int | None = None, tk: int | None = None,
                tb: int | None = None,
                acc_dtype=jnp.float32, acc_in_vmem: bool = True,
                epilogue: Epilogue | None = None,
                bias: jnp.ndarray | None = None,
                residual: jnp.ndarray | None = None) -> jnp.ndarray:
    """y = epilogue(dequant(packed u8 (m, k/2)) @ x (k, b)) via the kernel.

    ``tm/tk/tb``: explicit tiles from a dispatch ExecPlan; the heuristic
    only runs when one is missing (tk must be even and % scale_block ==
    0).  Epilogue semantics match :func:`msgemm`."""
    ep = epilogue or Epilogue()
    m = u8.shape[0]
    squeeze = x.ndim == 1
    if squeeze:
        x = x[:, None]
        if residual is not None and residual.ndim == 1:
            residual = residual[:, None]
    k, b = x.shape
    if tm is None or tk is None or tb is None:
        htm, htk, htb = int4_tiles(m, k, b, scale_block)
        tm, tk, tb = tm or htm, tk or htk, tb or htb
    mp, kp, bp = _round_up(m, tm), _round_up(k, tk), _round_up(b, tb)
    u8_p = jnp.pad(u8, ((0, mp - m), (0, kp // 2 - u8.shape[1])))
    sc_p = jnp.pad(scales.astype(jnp.float32),
                   ((0, mp - m), (0, kp // scale_block - scales.shape[1])))
    x_p = jnp.pad(x.astype(jnp.float32), ((0, kp - k), (0, bp - b)))
    interpret = _interpret() if interpret is None else interpret

    fuse = acc_in_vmem and not ep.is_identity
    bias_p = res_p = None
    if fuse:
        if ep.bias:
            bias_p = jnp.pad(bias.astype(jnp.float32)[:, None],
                             ((0, mp - m), (0, 0)))
        if ep.residual:
            res_p = jnp.pad(residual.astype(jnp.float32),
                            ((0, mp - m), (0, bp - b)))
    y = _i4.int4_matmul_pallas(
        u8_p, sc_p, x_p, bias_p, res_p, scale_block=scale_block,
        tm=tm, tk=tk, tb=tb, interpret=interpret, acc_dtype=acc_dtype,
        acc_in_vmem=acc_in_vmem, epilogue=ep if fuse else None)
    y = y[:m, :b]
    if not fuse:
        y = _epilogue_cols(y, ep, bias, residual)
    return y[:, 0] if squeeze else y


def k_chunk_params(params: dict, *, k: int, chunks: int, d: int = 1,
                   scale_block: int = 1) -> list[dict]:
    """Split a quantized linear's packed params into ``chunks``
    contraction slices — the chunked-consume entry point for pipelined
    sharded execution (dispatch.shard).

    Every packed leaf stores the contraction dim in columns at a
    leaf-specific density: ``w`` (dense) has k columns, ``idx`` k/d
    packed tuples, ``u8`` k/2 nibble pairs, ``scales`` k/scale_block
    blocks.  Chunk c of leaf L is columns [c*w_L, (c+1)*w_L) where
    ``w_L = cols_L // chunks``; ``codebook`` (and any unrecognized leaf)
    is the 16-entry value table — replicated into every chunk.  Feeding
    chunk c's slice dict plus the matching k-slice of x back through the
    same backend reproduces that chunk's partial product exactly: the
    LUT produce runs per chunk against 1/chunks of the consume columns,
    which is the granularity the collective ring overlaps.

    Requires k to be chunk-aligned at every density (the dispatch layer
    guarantees this by construction: shard_spec_for only admits
    pipeline_chunks where k_chunk stays scale_block/d/nibble aligned).
    """
    chunks = max(int(chunks), 1)
    if chunks == 1:
        return [dict(params)]
    cols = {"w": k, "idx": k // max(int(d), 1), "u8": k // 2,
            "scales": k // max(int(scale_block), 1)}
    out = []
    for c in range(chunks):
        sl = {}
        for name, leaf in params.items():
            width = cols.get(name)
            if width is None:  # codebook etc.: no contraction dim
                sl[name] = leaf
                continue
            if width % chunks:
                raise ValueError(
                    f"k_chunk_params: leaf {name!r} has {width} "
                    f"contraction columns, not divisible by {chunks}")
            w = width // chunks
            sl[name] = jax.lax.slice_in_dim(leaf, c * w, (c + 1) * w,
                                            axis=1)
        out.append(sl)
    return out


def profile_gemm(kind: str, m: int, k: int, b: int, *, d: int = 3,
                 scale_block: int | None = None, reps: int = 3,
                 interpret: bool | None = None, seed: int = 0) -> dict:
    """Time one kernel invocation on synthetic data and annotate it with
    the analytic cost model (obs.costs): per-shape wall time, the
    produce-vs-consume op split, bytes moved, and the achieved-vs-
    roofline fraction for this process's device.

    ``kind``: 'msgemm' | 'int4'.  Times best-of-``reps`` of one jitted
    call (compile excluded), records the measurement into the
    ``kernel_profile_s`` registry histogram, and returns the annotated
    row — what kernel_microbench embeds in BENCH_kernels.json.
    """
    import time as _time

    import numpy as np

    from repro import obs

    sb = scale_block if scale_block is not None else 12 * d
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((k, b)), jnp.float32)
    sc = jnp.asarray(np.abs(rng.standard_normal((m, -(-k // sb)))) + 0.1,
                     jnp.float32)
    if kind == "msgemm":
        codes = jnp.asarray(rng.integers(0, 16, size=(m, k)), jnp.uint8)
        fn = jax.jit(lambda: msgemm(codes, x, d, scales=sc, scale_block=sb,
                                    interpret=interpret))
        quant = "msgemm"
    elif kind == "int4":
        u8 = jnp.asarray(
            packing.pack_storage(rng.integers(0, 16, size=(m, k))
                                 .astype(np.uint8)))
        fn = jax.jit(lambda: int4_matmul(u8, sc, x, scale_block=sb,
                                         interpret=interpret))
        quant = "int4_dequant"
    else:
        raise ValueError(f"kind={kind!r} must be 'msgemm' or 'int4'")

    jax.block_until_ready(fn())  # compile + warm
    best = float("inf")
    for _ in range(max(reps, 1)):
        t0 = _time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, _time.perf_counter() - t0)

    row = obs.costs.annotate(best, m, k, b, quant=quant, d=d)
    row["kind"] = kind
    obs.registry().histogram(
        "kernel_profile_s", help="profiled kernel wall time",
        kind=kind, m=m, k=k, b=b).observe(best)
    return row


def flash_attention(q, k, v, *, causal=True, window=0, softcap=0.0,
                    interpret=None):
    """Multi-head attention via the flash kernel.

    q (B, Sq, H, dh), k/v (B, Skv, Hk, dh) with H % Hk == 0.  GQA kv
    heads are NOT materialized: the kernel's k/v index maps divide the
    query-head grid index by the group size, so each kv head's (Skv, dh)
    block is fetched from HBM once per group instead of being expanded
    H//Hk-fold by ``jnp.repeat`` first.  Pads sequence dims to tile
    multiples (masked out)."""
    from repro.kernels import flash_attention as _fa

    B, Sq, H, dh = q.shape
    Skv, Hk = k.shape[1], k.shape[2]
    assert H % Hk == 0, (H, Hk)
    tq = min(128, _round_up(Sq, 8))
    tk = min(128, _round_up(Skv, 8))
    sqp, skp = _round_up(Sq, tq), _round_up(Skv, tk)
    qt = jnp.moveaxis(jnp.pad(q, ((0, 0), (0, sqp - Sq), (0, 0), (0, 0))),
                      2, 1)  # (B, H, Sqp, dh)
    kt = jnp.moveaxis(jnp.pad(k, ((0, 0), (0, skp - Skv), (0, 0), (0, 0))),
                      2, 1)  # (B, Hk, Skp, dh)
    vt = jnp.moveaxis(jnp.pad(v, ((0, 0), (0, skp - Skv), (0, 0), (0, 0))),
                      2, 1)
    # padded keys must never win the softmax: causal masking handles the
    # q-pad rows; padded kpos > any real qpos under causal; for
    # non-causal callers we require Skv % tk == 0 (asserted).
    if not causal:
        assert skp == Skv, "non-causal flash requires Skv % tile == 0"
    o = _fa.flash_attention_pallas(
        qt, kt, vt, causal=causal, window=window, softcap=softcap,
        tq=tq, tk=tk,
        interpret=_interpret() if interpret is None else interpret)
    return jnp.moveaxis(o, 1, 2)[:, :Sq]
