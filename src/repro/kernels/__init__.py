"""Pallas TPU kernels for the compute hot-spots msGeMM targets.

msgemm.py       fused LUT produce+consume (the paper's algorithm; VMEM LUT)
int4_matmul.py  blocked dequant+MXU dot (practical current-TPU baseline)
ops.py          jit'd wrappers (tiling, padding, backend detection)
ref.py          pure-jnp oracles used by the allclose sweeps
"""

from repro.kernels import ops, ref  # noqa: F401
