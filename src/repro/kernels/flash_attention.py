"""Row-blocked online-softmax attention Pallas kernel (flash-style).

The prefill-side hot spot: msGeMM covers the weight GeMMs, attention
covers the O(S²) sequence mixing — at prefill_32k the (S, S) logits must
never materialize (the jnp path q-chunks via lax.scan; this kernel is the
TPU-native tile version with the online-softmax rescaling, so the working
set is one (TQ, TK) tile + the (TQ, dh) accumulator in VMEM).

Grid: (batch, q heads, q blocks); the kernel loops over k blocks with a
fori_loop carrying (m, l, acc) — the standard flash recurrence:

    m' = max(m, rowmax(s));  p = exp(s - m');  c = exp(m - m')
    l' = c·l + rowsum(p);    acc' = c·acc + p @ v

GQA/MQA is resolved *in the index maps*: k/v keep their native
(B, Hk, Skv, dh) layout and the kv block index is ``h // (H // Hk)`` —
each kv head streams from HBM once per query-head group instead of being
expanded H//Hk-fold into a materialized ``jnp.repeat`` copy first (the
old wrapper's behavior, which multiplied both HBM footprint and
bandwidth by the group size).

Supports causal masking, sliding windows (gemma2 'local'), and logit
soft-capping.  Validated against ref.flash_attention_ref in interpret
mode (tests/test_kernels.py)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, *, tq: int, tk: int, causal: bool,
            window: int, softcap: float, scale: float):
    iq = pl.program_id(2)
    qb = q_ref[...][0, 0].astype(jnp.float32) * scale  # (TQ, dh)
    kfull = k_ref[...][0, 0]  # (Skv, dh) — this kv head's whole block
    vfull = v_ref[...][0, 0]
    S = kfull.shape[0]
    qpos = iq * tq + jax.lax.iota(jnp.int32, tq)

    def body(j, carry):
        m, l, acc = carry
        kb = jax.lax.dynamic_slice_in_dim(kfull, j * tk, tk, axis=0)
        vb = jax.lax.dynamic_slice_in_dim(vfull, j * tk, tk, axis=0)
        s = qb @ kb.astype(jnp.float32).T  # (TQ, TK)
        if softcap:
            s = softcap * jnp.tanh(s / softcap)
        kpos = j * tk + jax.lax.iota(jnp.int32, tk)
        ok = jnp.ones((tq, tk), bool)
        if causal:
            ok &= kpos[None, :] <= qpos[:, None]
        if window:
            ok &= kpos[None, :] > qpos[:, None] - window
        s = jnp.where(ok, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m - m_new)
        l_new = corr * l + jnp.sum(p, axis=-1)
        acc_new = corr[:, None] * acc + p @ vb.astype(jnp.float32)
        return m_new, l_new, acc_new

    m0 = jnp.full((tq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((tq,), jnp.float32)
    a0 = jnp.zeros((tq, q_ref.shape[-1]), jnp.float32)
    # causal: blocks beyond the diagonal contribute nothing; bound the loop
    nk = S // tk
    if causal:
        nk_eff = jnp.minimum(((iq + 1) * tq + tk - 1) // tk, nk)
    else:
        nk_eff = nk
    m, l, acc = jax.lax.fori_loop(0, nk_eff, body, (m0, l0, a0))
    o_ref[...] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(
        o_ref.dtype)[None, None]


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "softcap", "tq", "tk",
                              "interpret"))
def flash_attention_pallas(q, k, v, *, causal: bool = True, window: int = 0,
                           softcap: float = 0.0, tq: int = 128,
                           tk: int = 128, interpret: bool | None = None):
    """q (B, H, Sq, dh), k/v (B, Hk, Skv, dh) -> (B, H, Sq, dh).

    H % Hk == 0; kv heads are shared across each group of H//Hk query
    heads through the index maps (no repeat/materialization).

    ``interpret=None`` auto-detects (compiled on TPU, interpreter off-TPU).
    Caller pads Sq % tq == 0 and Skv % tk == 0 (ops.py wrapper)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, H, Sq, dh = q.shape
    Hk, Skv = k.shape[1], k.shape[2]
    assert H % Hk == 0, (H, Hk)
    g = H // Hk  # query heads per kv head
    assert Sq % tq == 0 and Skv % tk == 0, (Sq, Skv, tq, tk)
    scale = dh**-0.5
    kern = functools.partial(_kernel, tq=tq, tk=tk, causal=causal,
                             window=window, softcap=softcap, scale=scale)
    return pl.pallas_call(
        kern,
        grid=(B, H, Sq // tq),
        in_specs=[
            pl.BlockSpec((1, 1, tq, dh), lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, Skv, dh), lambda b, h, i: (b, h // g, 0, 0)),
            pl.BlockSpec((1, 1, Skv, dh), lambda b, h, i: (b, h // g, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, tq, dh), lambda b, h, i: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, dh), q.dtype),
        interpret=interpret,
    )(q, k, v)
