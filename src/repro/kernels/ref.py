"""Pure-jnp oracles for the Pallas kernels (no pallas imports).

Every kernel in this package is validated with assert_allclose against
these references across shape/dtype/tile sweeps (tests/test_kernels.py).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import lut, packing


def msgemm_ref(idx: jnp.ndarray, x: jnp.ndarray, scales: jnp.ndarray, *,
               d: int, scale_block: int, codebook=None) -> jnp.ndarray:
    """Oracle for kernels.msgemm.msgemm_pallas (paper Eq. 5 with §3.3 scales,
    optionally over a learned 16-entry codebook basis)."""
    table = lut.produce(x.astype(jnp.float32), d, dtype=jnp.float32,
                        codebook=codebook)
    return lut.consume(
        table, idx, scales=scales, scale_block=scale_block, d=d)


def int4_matmul_ref(u8: jnp.ndarray, scales: jnp.ndarray, x: jnp.ndarray, *,
                    scale_block: int) -> jnp.ndarray:
    """Oracle for kernels.int4_matmul: dequantize -> dense matmul."""
    k = x.shape[0]
    codes = packing.unpack_storage(u8, k).astype(jnp.int32)
    vals = jnp.where(codes <= 7, codes, codes - 16).astype(jnp.float32)
    q = jnp.repeat(scales, scale_block, axis=1)[:, :k].astype(jnp.float32)
    w = vals * q
    return w @ x.astype(jnp.float32)


def flash_attention_ref(q, k, v, *, causal=True, window=0, softcap=0.0):
    """Oracle for kernels.flash_attention: plain masked softmax attention.

    q (BH, Sq, dh), k/v (BH, Skv, dh)."""
    dh = q.shape[-1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * dh**-0.5
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    Sq, Skv = s.shape[1], s.shape[2]
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Skv)[None, :]
    ok = jnp.ones((Sq, Skv), bool)
    if causal:
        ok &= kpos <= qpos
    if window:
        ok &= kpos > qpos - window
    s = jnp.where(ok[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
