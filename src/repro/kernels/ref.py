"""Pure-jnp oracles for the Pallas kernels (no pallas imports).

Every kernel in this package is validated with assert_allclose against
these references across shape/dtype/tile sweeps (tests/test_kernels.py).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import lut, packing


def msgemm_ref(idx: jnp.ndarray, x: jnp.ndarray, scales: jnp.ndarray, *,
               d: int, scale_block: int, codebook=None) -> jnp.ndarray:
    """Oracle for kernels.msgemm.msgemm_pallas (paper Eq. 5 with §3.3 scales,
    optionally over a learned 16-entry codebook basis)."""
    table = lut.produce(x.astype(jnp.float32), d, dtype=jnp.float32,
                        codebook=codebook)
    return lut.consume(
        table, idx, scales=scales, scale_block=scale_block, d=d)


def msgemm_tiled_ref(codes: jnp.ndarray, x: jnp.ndarray,
                     scales: jnp.ndarray, *, d: int, scale_block: int,
                     tm: int, tj: int, tb: int, codebook=None,
                     epilogue=None, bias=None,
                     residual=None) -> jnp.ndarray:
    """Bit-faithful oracle for the fused kernel (msgemm_pallas with
    ``acc_in_vmem=True``): replays the exact (b, j, m) tile loops, the
    per-tile produce dot, the chunk gather order, the §3.3 factored-scale
    multiply, the j-ordered stripe accumulation, and the epilogue-in-
    writeback — as plain jnp in the same op order, so interpret-mode
    outputs match bit for bit (asserted in tests/test_kernels.py).

    Mirrors ops.msgemm's padding; codes (m, k) unpacked, x (k, b),
    bias (m,) / residual (m, b) in the kernels' column layout.
    """
    from repro.core import lut as lut_mod
    from repro.core.epilogue import Epilogue

    ep = epilogue or Epilogue()
    m, k = codes.shape
    b = x.shape[1]
    idx = packing.pack_indices(codes, d)
    kc = idx.shape[1]
    rup = lambda v, t: -(-v // t) * t
    mp, kcp, bp = rup(m, tm), rup(kc, tj), rup(b, tb)
    sj = kcp * d // scale_block
    idx = jnp.pad(idx, ((0, mp - m), (0, kcp - kc)))
    xp = jnp.pad(x.astype(jnp.float32), ((0, kcp * d - x.shape[0]),
                                         (0, bp - b)))
    sc = jnp.pad(scales.astype(jnp.float32),
                 ((0, mp - m), (0, sj - scales.shape[1])))
    basis = lut_mod.tuple_basis(d, dtype=jnp.float32, codebook=codebook)
    bias_p = (jnp.pad(bias.astype(jnp.float32), (0, mp - m))
              if ep.bias else None)
    res_p = (jnp.pad(residual.astype(jnp.float32),
                     ((0, mp - m), (0, bp - b))) if ep.residual else None)
    out_dtype = jnp.dtype(ep.out_dtype) if ep.out_dtype else jnp.float32
    cpb = scale_block // d
    cols = []
    for ib in range(bp // tb):
        acc_stripe = [None] * (mp // tm)
        for ij in range(kcp // tj):
            xblk = xp[ij * tj * d:(ij + 1) * tj * d,
                      ib * tb:(ib + 1) * tb].reshape(tj, d, tb)
            lut_t = jax.lax.dot_general(
                basis, xblk, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32)
            for im in range(mp // tm):
                idx_t = idx[im * tm:(im + 1) * tm, ij * tj:(ij + 1) * tj]
                sc_t = sc[im * tm:(im + 1) * tm,
                          ij * (tj * d // scale_block):
                          (ij + 1) * (tj * d // scale_block)]
                acc = jnp.zeros((tm, tb), jnp.float32)
                for blk in range(tj // cpb):
                    part = jnp.zeros((tm, tb), jnp.float32)
                    for c in range(cpb):
                        tjc = blk * cpb + c
                        part = part + jnp.take(lut_t[:, tjc, :],
                                               idx_t[:, tjc], axis=0)
                    acc = acc + part * sc_t[:, blk][:, None]
                acc_stripe[im] = (acc if acc_stripe[im] is None
                                  else acc_stripe[im] + acc)
        stripe = []
        for im, total in enumerate(acc_stripe):
            if ep.bias:
                total = total + bias_p[im * tm:(im + 1) * tm][:, None]
            total = ep.act_fn()(total)
            if ep.residual:
                total = total + res_p[im * tm:(im + 1) * tm,
                                      ib * tb:(ib + 1) * tb]
            stripe.append(total.astype(out_dtype))
        cols.append(jnp.concatenate(stripe, axis=0))
    return jnp.concatenate(cols, axis=1)[:m, :b]


def int4_matmul_ref(u8: jnp.ndarray, scales: jnp.ndarray, x: jnp.ndarray, *,
                    scale_block: int) -> jnp.ndarray:
    """Oracle for kernels.int4_matmul: dequantize -> dense matmul."""
    k = x.shape[0]
    codes = packing.unpack_storage(u8, k).astype(jnp.int32)
    vals = jnp.where(codes <= 7, codes, codes - 16).astype(jnp.float32)
    q = jnp.repeat(scales, scale_block, axis=1)[:, :k].astype(jnp.float32)
    w = vals * q
    return w @ x.astype(jnp.float32)


def flash_attention_ref(q, k, v, *, causal=True, window=0, softcap=0.0):
    """Oracle for kernels.flash_attention: plain masked softmax attention.

    q (BH, Sq, dh), k/v (BH, Skv, dh)."""
    dh = q.shape[-1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * dh**-0.5
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    Sq, Skv = s.shape[1], s.shape[2]
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Skv)[None, :]
    ok = jnp.ones((Sq, Skv), bool)
    if causal:
        ok &= kpos <= qpos
    if window:
        ok &= kpos > qpos - window
    s = jnp.where(ok[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
