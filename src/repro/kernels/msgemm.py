"""Fused msGeMM Pallas TPU kernel — produce + consume with a VMEM-resident LUT.

TPU adaptation of the paper's proposed "LUT-add unit" (paper §6, DESIGN.md
§2.B).  Per grid step the kernel:

1. *produce*: builds the LUT tile for TJ consecutive j-chunks directly in
   VMEM via one small MXU dot  ``basis (16^d, d) · x_chunk (d, TJ·TB)``
   — phase 1 at MXU rate, the TPU analogue of the paper's Tensor-Core
   produce phase;
2. *consume*: for each chunk, a vector gather from the VMEM LUT tile using
   the packed 4·d-bit row codes as indices (zero index arithmetic, §4),
   accumulating into the output block — phase 2 on the VPU/scalar path,
   which is exactly the unit the paper says must be strengthened.

Grid = (b_tiles, m_tiles, j_tiles) with j innermost so the output block
accumulates across j steps (classic Pallas accumulation pattern).  Shared
scales (§3.3) are applied in the *factored* form: one multiply per scale
block after the block's chunks are summed, requiring TJ·d ≡ 0
(mod scale_block) — enforced by ops.py.

VMEM budget per step ≈ 16^d·TJ·TB·4 bytes for the LUT tile (d=3, TJ=12,
TB=128 → 25 MB; ops.py sizes tiles to stay within ~8 MB by default).

Validated bit-exactly against kernels/ref.py in interpret mode
(tests/test_kernels.py sweeps shapes, dtypes, d, and tile sizes).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import lut as lut_mod


def _kernel(idx_ref, x_ref, basis_ref, scale_ref, y_ref, *, d: int,
            tj: int, scale_block: int, acc_dtype):
    """One (b_tile, m_tile, j_tile) grid step."""
    jstep = pl.program_id(2)

    @pl.when(jstep == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    # ---- produce: LUT tile in VMEM via one MXU dot ------------------------
    # x block: (TJ*d, TB) -> chunks (TJ, d, TB); basis: (16^d, d)
    tb = x_ref.shape[-1]
    x_chunks = x_ref[...].reshape(tj, d, tb).astype(acc_dtype)
    basis = basis_ref[...].astype(acc_dtype)  # (N, d)
    # lut[n, j, b] = sum_r basis[n, r] * x_chunks[j, r, b]
    lut = jax.lax.dot_general(
        basis, x_chunks, (((1,), (1,)), ((), ())),
        preferred_element_type=acc_dtype)  # (N, TJ, TB)

    # ---- consume: gather-add from the VMEM LUT (paper Eq. 5) -------------
    idx = idx_ref[...]  # (TM, TJ) packed 4d-bit codes == LUT row ids
    cpb = scale_block // d  # chunks per scale block
    acc = jnp.zeros((idx.shape[0], tb), acc_dtype)
    for blk in range(tj // cpb):
        part = jnp.zeros((idx.shape[0], tb), acc_dtype)
        for c in range(cpb):
            tjc = blk * cpb + c
            part = part + jnp.take(lut[:, tjc, :], idx[:, tjc], axis=0)
        # §3.3 factored scale: one multiply per bounding box
        acc = acc + part * scale_ref[:, blk][:, None].astype(acc_dtype)
    y_ref[...] += acc.astype(y_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("d", "scale_block", "tm", "tj", "tb", "interpret",
                     "acc_dtype"),
)
def msgemm_pallas(
    idx: jnp.ndarray,      # (m, kc) int32 packed LUT indices
    x: jnp.ndarray,        # (k_pad = kc*d, b)
    scales: jnp.ndarray,   # (m, kc*d // scale_block)
    codebook: jnp.ndarray | None = None,  # optional (16,) value table
    *,
    d: int,
    scale_block: int,
    tm: int = 256,
    tj: int | None = None,
    tb: int = 128,
    interpret: bool | None = None,
    acc_dtype=jnp.float32,
) -> jnp.ndarray:
    """y (m, b) = dequant(codes) @ x via the fused produce+consume kernel.

    ``codebook`` swaps the uniform int4 tuple basis for a learned 16-entry
    one (repro.calib) — the kernel body is untouched: the basis matrix is
    already an operand, so non-uniform codebooks are literally zero extra
    kernel cost (the issue's point about Eq. 5 never requiring the uniform
    grid).  ``codebook[0]`` must be 0 (padding rows/chunks use index 0).

    ``interpret=None`` auto-detects: compiled on TPU, interpreter
    elsewhere (CPU/GPU have no Mosaic lowering for this kernel).

    Caller (ops.py) guarantees: m % tm == 0, kc % tj == 0, b % tb == 0,
    tj*d % scale_block == 0.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    m, kc = idx.shape
    k, b = x.shape
    assert k == kc * d, (k, kc, d)
    if tj is None:
        tj = scale_block // d
    assert (tj * d) % scale_block == 0, "factored-scale tiling (§3.3)"
    assert m % tm == 0 and kc % tj == 0 and b % tb == 0, (m, kc, b, tm, tj, tb)
    sj = tj * d // scale_block
    basis = lut_mod.tuple_basis(d, dtype=acc_dtype, codebook=codebook)
    n = basis.shape[0]

    grid = (b // tb, m // tm, kc // tj)
    kern = functools.partial(
        _kernel, d=d, tj=tj, scale_block=scale_block, acc_dtype=acc_dtype)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, tj), lambda ib, im, ij: (im, ij)),       # idx
            pl.BlockSpec((tj * d, tb), lambda ib, im, ij: (ij, ib)),   # x
            pl.BlockSpec((n, d), lambda ib, im, ij: (0, 0)),           # basis
            pl.BlockSpec((tm, sj), lambda ib, im, ij: (im, ij)),       # scales
        ],
        out_specs=pl.BlockSpec((tm, tb), lambda ib, im, ij: (im, ib)),
        out_shape=jax.ShapeDtypeStruct((m, b), acc_dtype),
        interpret=interpret,
    )(idx, x, basis, scales)
