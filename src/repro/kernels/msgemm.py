"""Fused msGeMM Pallas TPU kernel — amortized produce, VMEM-resident
accumulation, and a fused epilogue.

TPU adaptation of the paper's proposed "LUT-add unit" (paper §4-§6,
DESIGN.md §2.B).  The performance-first formulation keeps both halves of
the paper's argument true on the actual grid:

* **produce is amortized over m** (§6): grid = (b_tiles, j_tiles,
  m_tiles) with **m innermost**.  The LUT tile for a (b, j) cell is built
  by one small MXU dot ``basis (16^d, d) · x_chunk (d, TJ·TB)`` into a
  VMEM scratch buffer on the *first* m-step only — every other m-tile
  gathers from the already-resident scratch.  Produce cost per output
  column drops by the number of m-tiles (the per-shape amortization
  factor reported by benchmarks/kernel_microbench.py).
* **consume never leaves fast memory** (§4): the output accumulates in a
  VMEM scratch stripe ``(mp, TB)`` that stays resident across the whole
  j-reduction; HBM sees exactly one writeback per (b-stripe, m-tile), on
  the last j-step — not one read-modify-write per j-step.
* **the epilogue rides the final writeback**: bias add, activation
  (relu/gelu/silu), residual add, and the output-dtype cast execute on
  the VMEM accumulator just before the single store, so callers stop
  issuing separate element-wise HBM passes after the GeMM
  (core/epilogue.Epilogue; EmuGEMM's fusion argument in PAPERS.md).

Shared scales (§3.3) are applied in the *factored* form: one multiply per
scale block after the block's chunks are summed, requiring TJ·d ≡ 0
(mod scale_block) — enforced by ops.py.

VMEM budget per step ≈ 16^d·TJ·TB·4 bytes for the LUT tile plus
(mp·TB·4) for the f32 accumulator stripe and (mp·TB·out_bytes) for the
resident output block; ops.py sizes TB/TJ to keep the LUT within
~8 MB and the stripes within ~4 MB (see README §Kernel performance).

The pre-overhaul formulation (j innermost, ``y_ref +=`` per step,
produce re-run on every m-tile) is kept behind ``acc_in_vmem=False`` as
the comparison baseline for the microbench and as an autotuner
candidate; with the identity epilogue the two paths are bit-identical
(same op order per output element — asserted in tests/test_kernels.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import lut as lut_mod
from repro.core.epilogue import Epilogue


def _consume_tile(lut, idx, scale_ref, *, d: int, tj: int, scale_block: int,
                  tb: int, acc_dtype):
    """Gather-add one (TM, TJ) index tile against a (N, TJ, TB) LUT tile,
    §3.3 factored scales — shared by the fused and legacy kernels so the
    two paths stay bit-identical per j-step."""
    cpb = scale_block // d  # chunks per scale block
    acc = jnp.zeros((idx.shape[0], tb), acc_dtype)
    for blk in range(tj // cpb):
        part = jnp.zeros((idx.shape[0], tb), acc_dtype)
        for c in range(cpb):
            tjc = blk * cpb + c
            part = part + jnp.take(lut[:, tjc, :], idx[:, tjc], axis=0)
        # §3.3 factored scale: one multiply per bounding box
        acc = acc + part * scale_ref[:, blk][:, None].astype(acc_dtype)
    return acc


def _kernel_fused(idx_ref, x_ref, basis_ref, scale_ref, *rest, d: int,
                  tm: int, tj: int, scale_block: int, acc_dtype, nj: int,
                  epilogue: Epilogue, has_bias: bool, has_res: bool):
    """One (b_tile, j_tile, m_tile) grid step — m innermost."""
    refs = list(rest)
    bias_ref = refs.pop(0) if has_bias else None
    res_ref = refs.pop(0) if has_res else None
    y_ref, lut_ref, acc_ref = refs
    ij, im = pl.program_id(1), pl.program_id(2)

    # ---- produce: once per (b, j), amortized over every m-tile ----------
    @pl.when(im == 0)
    def _produce():
        tb = x_ref.shape[-1]
        x_chunks = x_ref[...].reshape(tj, d, tb).astype(acc_dtype)
        basis = basis_ref[...].astype(acc_dtype)  # (N, d)
        # lut[n, j, b] = sum_r basis[n, r] * x_chunks[j, r, b]
        lut_ref[...] = jax.lax.dot_general(
            basis, x_chunks, (((1,), (1,)), ((), ())),
            preferred_element_type=acc_dtype)  # (N, TJ, TB)

    # ---- consume: gather-add from the resident LUT (paper Eq. 5) -------
    tb = y_ref.shape[-1]
    acc = _consume_tile(lut_ref[...], idx_ref[...], scale_ref, d=d, tj=tj,
                        scale_block=scale_block, tb=tb, acc_dtype=acc_dtype)

    # ---- accumulate in the VMEM stripe; HBM sees only the final store --
    rows = pl.dslice(im * tm, tm)

    @pl.when(ij == 0)
    def _init():
        acc_ref[rows, :] = acc

    @pl.when(ij > 0)
    def _accum():
        acc_ref[rows, :] += acc

    @pl.when(ij == nj - 1)
    def _writeback():
        total = acc_ref[rows, :]
        if has_bias:
            total = total + bias_ref[rows, :].astype(acc_dtype)
        total = epilogue.act_fn()(total)
        if has_res:
            total = total + res_ref[rows, :].astype(acc_dtype)
        y_ref[rows, :] = total.astype(y_ref.dtype)


def _kernel_legacy(idx_ref, x_ref, basis_ref, scale_ref, y_ref, *, d: int,
                   tj: int, scale_block: int, acc_dtype):
    """Pre-overhaul step — grid (b, m, j) with j innermost: the produce
    dot re-runs on every (b, m, j) step and the output block accumulates
    via ``y_ref +=``.  Kept as the microbench baseline and as an
    autotuner candidate (ExecPlan.acc_in_vmem=False)."""
    jstep = pl.program_id(2)

    @pl.when(jstep == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    tb = x_ref.shape[-1]
    x_chunks = x_ref[...].reshape(tj, d, tb).astype(acc_dtype)
    basis = basis_ref[...].astype(acc_dtype)  # (N, d)
    lut = jax.lax.dot_general(
        basis, x_chunks, (((1,), (1,)), ((), ())),
        preferred_element_type=acc_dtype)  # (N, TJ, TB)
    acc = _consume_tile(lut, idx_ref[...], scale_ref, d=d, tj=tj,
                        scale_block=scale_block, tb=tb, acc_dtype=acc_dtype)
    y_ref[...] += acc.astype(y_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("d", "scale_block", "tm", "tj", "tb", "interpret",
                     "acc_dtype", "acc_in_vmem", "epilogue"),
)
def msgemm_pallas(
    idx: jnp.ndarray,      # (m, kc) int32 packed LUT indices
    x: jnp.ndarray,        # (k_pad = kc*d, b)
    scales: jnp.ndarray,   # (m, kc*d // scale_block)
    codebook: jnp.ndarray | None = None,  # optional (16,) value table
    bias: jnp.ndarray | None = None,      # (m, 1) when epilogue.bias
    residual: jnp.ndarray | None = None,  # (m, b) when epilogue.residual
    *,
    d: int,
    scale_block: int,
    tm: int = 256,
    tj: int | None = None,
    tb: int = 128,
    interpret: bool | None = None,
    acc_dtype=jnp.float32,
    acc_in_vmem: bool = True,
    epilogue: Epilogue | None = None,
) -> jnp.ndarray:
    """y (m, b) = epilogue(dequant(codes) @ x) via the fused kernel.

    ``codebook`` swaps the uniform int4 tuple basis for a learned 16-entry
    one (repro.calib) — the basis matrix is already an operand, so
    non-uniform codebooks are zero extra kernel cost.  ``codebook[0]``
    must be 0 (padding rows/chunks use index 0).

    ``acc_in_vmem=True`` (default) runs the reordered grid — m innermost,
    LUT produced once per (b, j) into VMEM scratch, output accumulated in
    a VMEM stripe with one HBM writeback on the last j-step.  ``False``
    selects the legacy j-innermost formulation (baseline; no fused
    epilogue — callers apply it unfused).

    ``epilogue`` (a hashable core.epilogue.Epilogue) executes inside the
    final writeback: ``y = act(acc + bias) + residual`` cast to
    ``epilogue.out_dtype``.  With the identity epilogue the output is
    bit-identical to the legacy path.

    ``interpret=None`` auto-detects: compiled on TPU, interpreter
    elsewhere (CPU/GPU have no Mosaic lowering for this kernel).

    Caller (ops.py) guarantees: m % tm == 0, kc % tj == 0, b % tb == 0,
    tj*d % scale_block == 0, bias (m, 1) / residual (m, b) pre-padded.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    ep = epilogue or Epilogue()
    m, kc = idx.shape
    k, b = x.shape
    assert k == kc * d, (k, kc, d)
    if tj is None:
        tj = scale_block // d
    assert (tj * d) % scale_block == 0, "factored-scale tiling (§3.3)"
    assert m % tm == 0 and kc % tj == 0 and b % tb == 0, (m, kc, b, tm, tj, tb)
    sj = tj * d // scale_block
    basis = lut_mod.tuple_basis(d, dtype=acc_dtype, codebook=codebook)
    n = basis.shape[0]
    out_dtype = jnp.dtype(ep.out_dtype) if ep.out_dtype else jnp.dtype(
        acc_dtype)

    if not acc_in_vmem:
        assert ep.is_identity, \
            "the legacy path has no fused epilogue (ops.py applies it unfused)"
        grid = (b // tb, m // tm, kc // tj)
        kern = functools.partial(
            _kernel_legacy, d=d, tj=tj, scale_block=scale_block,
            acc_dtype=acc_dtype)
        return pl.pallas_call(
            kern,
            grid=grid,
            in_specs=[
                pl.BlockSpec((tm, tj), lambda ib, im, ij: (im, ij)),      # idx
                pl.BlockSpec((tj * d, tb), lambda ib, im, ij: (ij, ib)),  # x
                pl.BlockSpec((n, d), lambda ib, im, ij: (0, 0)),          # basis
                pl.BlockSpec((tm, sj), lambda ib, im, ij: (im, ij)),      # scales
            ],
            out_specs=pl.BlockSpec((tm, tb), lambda ib, im, ij: (im, ib)),
            out_shape=jax.ShapeDtypeStruct((m, b), acc_dtype),
            interpret=interpret,
        )(idx, x, basis, scales)

    has_bias, has_res = ep.bias, ep.residual
    nj = kc // tj
    grid = (b // tb, nj, m // tm)
    # the y stripe and the epilogue operands ignore ij/im in their index
    # maps -> the blocks stay VMEM-resident for a whole b-stripe and are
    # fetched/written exactly once per (b-stripe)
    in_specs = [
        pl.BlockSpec((tm, tj), lambda ib, ij, im: (im, ij)),       # idx
        pl.BlockSpec((tj * d, tb), lambda ib, ij, im: (ij, ib)),   # x
        pl.BlockSpec((n, d), lambda ib, ij, im: (0, 0)),           # basis
        pl.BlockSpec((tm, sj), lambda ib, ij, im: (im, ij)),       # scales
    ]
    operands = [idx, x, basis, scales]
    if has_bias:
        assert bias is not None and bias.shape == (m, 1), (m, bias)
        in_specs.append(pl.BlockSpec((m, 1), lambda ib, ij, im: (0, 0)))
        operands.append(bias)
    if has_res:
        assert residual is not None and residual.shape == (m, b), \
            (m, b, residual)
        in_specs.append(pl.BlockSpec((m, tb), lambda ib, ij, im: (0, ib)))
        operands.append(residual)
    kern = functools.partial(
        _kernel_fused, d=d, tm=tm, tj=tj, scale_block=scale_block,
        acc_dtype=acc_dtype, nj=nj, epilogue=ep, has_bias=has_bias,
        has_res=has_res)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((m, tb), lambda ib, ij, im: (0, ib)),
        out_shape=jax.ShapeDtypeStruct((m, b), out_dtype),
        scratch_shapes=[
            pltpu.VMEM((n, tj, tb), jnp.dtype(acc_dtype)),  # LUT tile
            pltpu.VMEM((m, tb), jnp.dtype(acc_dtype)),      # acc stripe
        ],
        interpret=interpret,
    )(*operands)
