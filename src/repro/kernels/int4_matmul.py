"""Blocked int4 dequantize-then-matmul Pallas kernel — the *practical
current-TPU* baseline the paper's proposal competes against (DESIGN.md §2.C).

Weights are stored truly packed (2 codes/byte).  Per grid step the kernel
unpacks a (TM, TK) weight tile in VMEM with bit ops, applies the §3.3
row-block scales, and feeds the MXU with a dense (TM, TK)·(TK, TB) dot,
accumulating over k tiles.  This is the standard int4 weight-only-quant
GeMM shape used in production TPU serving stacks.

Grid = (b_tiles, m_tiles, k_tiles), k innermost for output accumulation.
Requires tk % scale_block == 0 so each k tile covers whole scale blocks.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(u8_ref, scale_ref, x_ref, y_ref, *, tk: int, scale_block: int,
            acc_dtype):
    kstep = pl.program_id(2)

    @pl.when(kstep == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    packed = u8_ref[...]  # (TM, TK//2) uint8, two codes per byte
    hi = (packed >> 4) & 0xF
    lo = packed & 0xF
    codes = jnp.stack([hi, lo], axis=-1).reshape(packed.shape[0], tk)
    c = codes.astype(jnp.int32)
    vals = jnp.where(c <= 7, c, c - 16).astype(acc_dtype)  # b() map, §3.1
    # §3.3 row-block scales
    q = scale_ref[...].astype(acc_dtype)  # (TM, TK // scale_block)
    w = (vals.reshape(packed.shape[0], tk // scale_block, scale_block)
         * q[..., None]).reshape(packed.shape[0], tk)
    x = x_ref[...].astype(acc_dtype)  # (TK, TB)
    y_ref[...] += jax.lax.dot(w, x, preferred_element_type=acc_dtype).astype(
        y_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("scale_block", "tm", "tk", "tb", "interpret", "acc_dtype"),
)
def int4_matmul_pallas(
    u8: jnp.ndarray,       # (m, k//2) packed codes
    scales: jnp.ndarray,   # (m, k // scale_block)
    x: jnp.ndarray,        # (k, b)
    *,
    scale_block: int,
    tm: int = 256,
    tk: int | None = None,
    tb: int = 128,
    interpret: bool | None = None,
    acc_dtype=jnp.float32,
) -> jnp.ndarray:
    if interpret is None:  # auto-detect: compiled on TPU, interpreter off-TPU
        interpret = jax.default_backend() != "tpu"
    m, k2 = u8.shape
    k, b = x.shape
    assert k == k2 * 2
    if tk is None:
        tk = scale_block * max(1, 256 // scale_block)
    assert tk % scale_block == 0 and tk % 2 == 0
    assert m % tm == 0 and k % tk == 0 and b % tb == 0, (m, k, b, tm, tk, tb)
    sk = tk // scale_block

    grid = (b // tb, m // tm, k // tk)
    kern = functools.partial(
        _kernel, tk=tk, scale_block=scale_block, acc_dtype=acc_dtype)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, tk // 2), lambda ib, im, ik: (im, ik)),
            pl.BlockSpec((tm, sk), lambda ib, im, ik: (im, ik)),
            pl.BlockSpec((tk, tb), lambda ib, im, ik: (ik, ib)),
        ],
        out_specs=pl.BlockSpec((tm, tb), lambda ib, im, ik: (im, ib)),
        out_shape=jax.ShapeDtypeStruct((m, b), acc_dtype),
        interpret=interpret,
    )(u8, scales, x)
