"""Blocked int4 dequantize-then-matmul Pallas kernel — the *practical
current-TPU* baseline the paper's proposal competes against (DESIGN.md §2.C).

Weights are stored truly packed (2 codes/byte).  Per grid step the kernel
unpacks a (TM, TK) weight tile in VMEM with bit ops, applies the §3.3
row-block scales, and feeds the MXU with a dense (TM, TK)·(TK, TB) dot.
This is the standard int4 weight-only-quant GeMM shape used in production
TPU serving stacks.

Grid = (b_tiles, m_tiles, k_tiles), k innermost.  The default path
(``acc_in_vmem=True``) accumulates over k in a VMEM scratch buffer and
stores to HBM exactly once per output block, executing the fused epilogue
(bias/act/residual/cast — core.epilogue.Epilogue) on the accumulator just
before that single store.  ``acc_in_vmem=False`` keeps the pre-overhaul
``y_ref +=`` formulation as the microbench baseline.  Requires
tk % scale_block == 0 so each k tile covers whole scale blocks.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.epilogue import Epilogue


def _dequant_dot(u8_ref, scale_ref, x_ref, *, tk: int, scale_block: int,
                 acc_dtype):
    """Unpack + §3.3 scales + one MXU dot for the current (TM, TK) tile —
    shared by the fused and legacy kernels (bit-identical per k-step)."""
    packed = u8_ref[...]  # (TM, TK//2) uint8, two codes per byte
    hi = (packed >> 4) & 0xF
    lo = packed & 0xF
    codes = jnp.stack([hi, lo], axis=-1).reshape(packed.shape[0], tk)
    c = codes.astype(jnp.int32)
    vals = jnp.where(c <= 7, c, c - 16).astype(acc_dtype)  # b() map, §3.1
    q = scale_ref[...].astype(acc_dtype)  # (TM, TK // scale_block)
    w = (vals.reshape(packed.shape[0], tk // scale_block, scale_block)
         * q[..., None]).reshape(packed.shape[0], tk)
    x = x_ref[...].astype(acc_dtype)  # (TK, TB)
    return jax.lax.dot(w, x, preferred_element_type=acc_dtype)


def _kernel_fused(u8_ref, scale_ref, x_ref, *rest, tk: int, scale_block: int,
                  acc_dtype, nk: int, epilogue: Epilogue, has_bias: bool,
                  has_res: bool):
    refs = list(rest)
    bias_ref = refs.pop(0) if has_bias else None
    res_ref = refs.pop(0) if has_res else None
    y_ref, acc_ref = refs
    kstep = pl.program_id(2)
    part = _dequant_dot(u8_ref, scale_ref, x_ref, tk=tk,
                        scale_block=scale_block, acc_dtype=acc_dtype)

    @pl.when(kstep == 0)
    def _init():
        acc_ref[...] = part

    @pl.when(kstep > 0)
    def _accum():
        acc_ref[...] += part

    @pl.when(kstep == nk - 1)
    def _writeback():
        total = acc_ref[...]
        if has_bias:
            total = total + bias_ref[...].astype(acc_dtype)
        total = epilogue.act_fn()(total)
        if has_res:
            total = total + res_ref[...].astype(acc_dtype)
        y_ref[...] = total.astype(y_ref.dtype)


def _kernel_legacy(u8_ref, scale_ref, x_ref, y_ref, *, tk: int,
                   scale_block: int, acc_dtype):
    kstep = pl.program_id(2)

    @pl.when(kstep == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    part = _dequant_dot(u8_ref, scale_ref, x_ref, tk=tk,
                        scale_block=scale_block, acc_dtype=acc_dtype)
    y_ref[...] += part.astype(y_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("scale_block", "tm", "tk", "tb", "interpret",
                     "acc_dtype", "acc_in_vmem", "epilogue"),
)
def int4_matmul_pallas(
    u8: jnp.ndarray,       # (m, k//2) packed codes
    scales: jnp.ndarray,   # (m, k // scale_block)
    x: jnp.ndarray,        # (k, b)
    bias: jnp.ndarray | None = None,      # (m, 1) when epilogue.bias
    residual: jnp.ndarray | None = None,  # (m, b) when epilogue.residual
    *,
    scale_block: int,
    tm: int = 256,
    tk: int | None = None,
    tb: int = 128,
    interpret: bool | None = None,
    acc_dtype=jnp.float32,
    acc_in_vmem: bool = True,
    epilogue: Epilogue | None = None,
) -> jnp.ndarray:
    if interpret is None:  # auto-detect: compiled on TPU, interpreter off-TPU
        interpret = jax.default_backend() != "tpu"
    ep = epilogue or Epilogue()
    m, k2 = u8.shape
    k, b = x.shape
    assert k == k2 * 2
    if tk is None:
        tk = scale_block * max(1, 256 // scale_block)
    assert tk % scale_block == 0 and tk % 2 == 0
    assert m % tm == 0 and k % tk == 0 and b % tb == 0, (m, k, b, tm, tk, tb)
    sk = tk // scale_block
    out_dtype = jnp.dtype(ep.out_dtype) if ep.out_dtype else jnp.dtype(
        acc_dtype)

    grid = (b // tb, m // tm, k // tk)
    if not acc_in_vmem:
        assert ep.is_identity, \
            "the legacy path has no fused epilogue (ops.py applies it unfused)"
        kern = functools.partial(
            _kernel_legacy, tk=tk, scale_block=scale_block,
            acc_dtype=acc_dtype)
        return pl.pallas_call(
            kern,
            grid=grid,
            in_specs=[
                pl.BlockSpec((tm, tk // 2), lambda ib, im, ik: (im, ik)),
                pl.BlockSpec((tm, sk), lambda ib, im, ik: (im, ik)),
                pl.BlockSpec((tk, tb), lambda ib, im, ik: (ik, ib)),
            ],
            out_specs=pl.BlockSpec((tm, tb), lambda ib, im, ik: (im, ib)),
            out_shape=jax.ShapeDtypeStruct((m, b), acc_dtype),
            interpret=interpret,
        )(u8, scales, x)

    has_bias, has_res = ep.bias, ep.residual
    in_specs = [
        pl.BlockSpec((tm, tk // 2), lambda ib, im, ik: (im, ik)),
        pl.BlockSpec((tm, sk), lambda ib, im, ik: (im, ik)),
        pl.BlockSpec((tk, tb), lambda ib, im, ik: (ik, ib)),
    ]
    operands = [u8, scales, x]
    if has_bias:
        assert bias is not None and bias.shape == (m, 1), (m, bias)
        in_specs.append(pl.BlockSpec((tm, 1), lambda ib, im, ik: (im, 0)))
        operands.append(bias)
    if has_res:
        assert residual is not None and residual.shape == (m, b), \
            (m, b, residual)
        in_specs.append(pl.BlockSpec((tm, tb), lambda ib, im, ik: (im, ib)))
        operands.append(residual)
    kern = functools.partial(
        _kernel_fused, tk=tk, scale_block=scale_block, acc_dtype=acc_dtype,
        nk=k // tk, epilogue=ep, has_bias=has_bias, has_res=has_res)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((tm, tb), lambda ib, im, ik: (im, ib)),
        out_shape=jax.ShapeDtypeStruct((m, b), out_dtype),
        scratch_shapes=[pltpu.VMEM((tm, tb), jnp.dtype(acc_dtype))],
        interpret=interpret,
    )(*operands)
