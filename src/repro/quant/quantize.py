"""Model-level weight quantization: walk a trained bf16/f32 param tree and
convert every QuantizedLinear leaf to the target int4 format (msgemm or
int4_dequant layout) — the train-dense / serve-quantized workflow of the
paper (M in int4, activations in higher precision).

Non-linear leaves (norms, embeddings, conv filters, recurrent R, A_log,
gates...) stay in floating point: msGeMM targets GeMMs (paper §2); the
embedding *lookup* is already a table read.
"""

from __future__ import annotations

import jax

from repro.core import linear as qlinear
from repro.core.linear import QuantConfig
from repro.models.config import ModelConfig

# params dict keys that hold a QuantizedLinear (see sharding.LINEAR_AXES)
QUANTIZABLE = {
    "wq", "wk", "wv", "wo", "up", "gate", "down", "lm_head",
    "in_proj", "x_proj", "out_proj",
    "xl_up", "xl_o", "xl_down",
}


def _convert(w, quant: QuantConfig):
    if w.ndim == 2:
        return qlinear.from_dense(w, quant)
    # stacked leading dims (scan groups / experts): vmap the conversion
    return jax.vmap(lambda ww: _convert(ww, quant))(w)


def quantize_model(params: dict, cfg: ModelConfig, quant: QuantConfig,
                   *, path=()) -> dict:
    """Return a new param tree for ``cfg.with_quant(quant.mode)`` serving."""
    out = {}
    for k, v in params.items():
        if k in QUANTIZABLE and isinstance(v, dict) and "w" in v:
            out[k] = _convert(v["w"], quant)
        elif isinstance(v, dict):
            out[k] = quantize_model(v, cfg, quant, path=path + (k,))
        else:
            out[k] = v
    return out


def quantized_size_bytes(params: dict) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(params))
