"""Model-level weight quantization: walk a trained bf16/f32 param tree and
convert every QuantizedLinear leaf to the target int4 format (msgemm or
int4_dequant layout) — the train-dense / serve-quantized workflow of the
paper (M in int4, activations in higher precision).

Non-linear leaves (norms, embeddings, conv filters, recurrent R, A_log,
gates...) stay in floating point: msGeMM targets GeMMs (paper §2); the
embedding *lookup* is already a table read.
"""

from __future__ import annotations

import jax

from repro.core import linear as qlinear
from repro.core.spec import QuantSpec, as_spec
from repro.models.config import ModelConfig

# params dict keys that hold a QuantizedLinear (see sharding.LINEAR_AXES)
QUANTIZABLE = {
    "wq", "wk", "wv", "wo", "up", "gate", "down", "lm_head",
    "in_proj", "x_proj", "out_proj",
    "xl_up", "xl_o", "xl_down",
}


def _convert(w, quant: QuantSpec, codebook=None):
    if w.ndim == 2:
        return qlinear.from_dense(w, quant, codebook=codebook)
    # stacked leading dims (scan groups / experts): vmap the conversion,
    # mapping per-slice codebooks alongside when they are stacked too
    if codebook is not None and codebook.ndim > 1:
        return jax.vmap(lambda ww, cb: _convert(ww, quant, cb))(w, codebook)
    return jax.vmap(lambda ww: _convert(ww, quant, codebook))(w)


def _codebook_for(codebooks, path: tuple):
    if codebooks is None:
        return None
    if isinstance(codebooks, dict):
        cb = codebooks.get("/".join(path), codebooks.get(path))
        return None if cb is None else jax.numpy.asarray(cb)
    return jax.numpy.asarray(codebooks)  # one shared table for every leaf


def quantize_model(params: dict, cfg: ModelConfig, quant: QuantSpec,
                   *, codebooks=None, path=()) -> dict:
    """Return a new param tree for ``cfg.with_quant(quant.mode)`` serving.

    ``quant``: a QuantSpec describing the target representation (the
    deprecated QuantConfig shim is accepted and reduced to its spec).
    ``codebooks``: optional learned value tables (repro.calib) — a single
    (16,) array shared model-wide, or a dict mapping 'a/b/leaf' path
    strings (or path tuples) to per-leaf (..., 16) tables; stacked leading
    dims must match the leaf's scan/expert stacking.  Leaves without an
    entry fall back to cfg-driven behavior (uniform placeholder table
    when quant.codebook='learned', plain int4 otherwise).
    """
    quant = as_spec(quant)
    out = {}
    for k, v in params.items():
        if k in QUANTIZABLE and isinstance(v, dict) and "w" in v:
            out[k] = _convert(v["w"], quant,
                              _codebook_for(codebooks, path + (k,)))
        elif isinstance(v, dict):
            out[k] = quantize_model(v, cfg, quant, codebooks=codebooks,
                                    path=path + (k,))
        else:
            out[k] = v
    return out


def quantized_size_bytes(params: dict) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(params))
