from repro.quant.quantize import quantize_model  # noqa: F401
