"""Fault-tolerant training driver.

Responsibilities (DESIGN.md §4):
* auto-resume from the latest complete checkpoint (atomic manager),
* periodic (optionally async) checkpointing,
* straggler/hang watchdog wiring,
* crash-injection hook for the restart integration test,
* preemption-style graceful stop (save + exit) on request.

The driver is mesh-agnostic: pass a jit'd step function and shardings;
on restart with a different mesh the checkpoint re-shards elastically.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable

import jax

from repro.checkpoint import CheckpointManager
from repro.distributed.watchdog import Watchdog


@dataclass
class DriverConfig:
    total_steps: int
    checkpoint_every: int = 50
    checkpoint_dir: str = "/tmp/repro_ckpt"
    keep: int = 3
    async_save: bool = False
    log_every: int = 10


@dataclass
class CrashInjector:
    """Test hook: raises at a given step, once."""
    at_step: int = -1
    fired: bool = False

    def maybe_crash(self, step: int):
        if step == self.at_step and not self.fired:
            self.fired = True
            raise RuntimeError(f"injected crash at step {step}")


def run(state, step_fn: Callable, data, dcfg: DriverConfig, *,
        shardings=None, crash: CrashInjector | None = None,
        stop_flag: list | None = None, log: Callable = print) -> dict:
    """Run (or resume) training.  Returns {'state', 'metrics', 'resumed_at'}."""
    ckpt = CheckpointManager(dcfg.checkpoint_dir, keep=dcfg.keep,
                             async_save=dcfg.async_save)
    start = 0
    latest = ckpt.latest_step()
    if latest is not None:
        state = ckpt.restore(latest, state, shardings=shardings)
        start = latest
        log(f"[driver] resumed from checkpoint step {latest}")
    wd = Watchdog()
    history = []
    for step in range(start, dcfg.total_steps):
        if stop_flag and stop_flag[0]:  # preemption signal
            ckpt.save(step, state)
            ckpt.wait()
            log(f"[driver] preempted; saved at step {step}")
            return {"state": state, "metrics": history, "resumed_at": start,
                    "preempted": True}
        batch = data.device_batch(step)
        wd.step_started()
        if crash is not None:
            crash.maybe_crash(step)
        state, metrics = step_fn(state, batch)
        jax.block_until_ready(metrics["loss"])
        info = wd.step_finished()
        if (step + 1) % dcfg.log_every == 0 or step == start:
            log(f"[driver] step {step + 1} loss={float(metrics['loss']):.4f} "
                f"t={info['step_time'] * 1e3:.1f}ms"
                + (" STRAGGLER" if info["straggler"] else ""))
        history.append({"step": step + 1,
                        "loss": float(metrics["loss"]),
                        **{k: float(v) for k, v in metrics.items()
                           if hasattr(v, "shape") and v.shape == ()}})
        if (step + 1) % dcfg.checkpoint_every == 0 \
                or step + 1 == dcfg.total_steps:
            ckpt.save(step + 1, state)
    ckpt.wait()
    return {"state": state, "metrics": history, "resumed_at": start,
            "preempted": False, "watchdog": {"stragglers": wd.straggler_count,
                                             "hangs": wd.hang_count}}
