from repro.runtime import serve, train  # noqa: F401
