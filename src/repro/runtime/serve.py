"""Serving runtime: batched prefill + decode steps with quantized
(msGeMM / int4) weights — the paper's target deployment.

``prefill_step`` and ``decode_step`` are the units the dry-run lowers at
scale; ``generate`` drives them for the runnable examples.  Quantized
serving params come from quant.quantize_model (train in bf16, serve in
int4/msgemm).

Two cache layouts share the same model code:

* static   — dense (batch, max_len, ...) tensors, fixed-shape batch
             (``init_cache`` / ``prefill_step`` / ``decode_step``);
* paged    — a shared block pool + per-sequence cache-view indices
             (``init_paged_cache`` / ``paged_step``), driven by the
             continuous-batching engine in ``repro.serving``.

``paged_step`` is deliberately phase-agnostic: a prefill chunk is a
(1, C) call and a decode batch a (B, 1) call of the *same* function, so
the engine interleaves both over one shared jitted step.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import transformer
from repro.models.config import ModelConfig


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.float32):
    return transformer.init_cache(cfg, batch, max_len, dtype)


def prefill_step(params, cfg: ModelConfig, batch: dict, cache):
    """Prompt ingestion.  Returns (first sampled token logits, cache)."""
    return transformer.prefill(params, cfg, batch, cache)


def decode_step(params, cfg: ModelConfig, token, cache, pos):
    """One token for every sequence in the batch."""
    return transformer.decode_step(params, cfg, token, cache, pos)


def init_paged_cache(cfg: ModelConfig, num_blocks: int, block_size: int,
                     dtype=jnp.float32, *, kv_spec=None, mesh=None,
                     rules: str = "serve"):
    """Paged KV block pool; with ``mesh`` the pool tensors are laid out
    per the logical sharding rules (kvheads over 'model' when divisible,
    block/slot dims replicated — distributed.sharding.paged_cache_specs)
    so the engine's donated pool buffer keeps its placement across
    steps.  ``kv_spec`` (default ``cfg.kv_quant``) selects the quantized
    codes+scales pool layout (repro.kvq)."""
    kv = transformer.init_paged_cache(cfg, num_blocks, block_size, dtype,
                                      kv_spec=kv_spec)
    if mesh is None:
        return kv
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.distributed import sharding as shd

    specs = shd.paged_cache_specs(kv, mesh, rules)
    shardings = jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                             is_leaf=lambda s: isinstance(s, P))
    return jax.device_put(kv, shardings)


def paged_step(params, cfg: ModelConfig, tokens, pool, positions,
               write_slots, view_slots, last_idx):
    """One serving step over the paged KV cache (prefill chunk or decode
    batch — same code, two shapes).

    tokens/positions/write_slots (B, C); view_slots (B, W); last_idx (B,)
    selects the chunk position whose next-token logits each row returns
    (C-1 for decode, the last real prompt token for a prefill chunk).

    Returns (logits (B, V), new_pool).
    """
    logits, pool = transformer.forward_paged(
        params, cfg, tokens, pool, positions, write_slots, view_slots)
    sel = jnp.take_along_axis(logits, last_idx[:, None, None], axis=1)[:, 0]
    return sel, pool


def greedy(logits):
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def sample(logits, key, temperature: float = 1.0):
    if temperature == 0.0:
        return greedy(logits)
    return jax.random.categorical(key, logits / temperature).astype(jnp.int32)


def generate(params, cfg: ModelConfig, batch: dict, *, max_new_tokens: int,
             max_len: int | None = None, temperature: float = 0.0,
             key=None, cache_dtype=jnp.float32):
    """Batched greedy/temperature generation (prefill + decode loop)."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    extra = cfg.num_patches if cfg.frontend == "image_patches" else 0
    max_len = max_len or (S + extra + max_new_tokens)
    cache = init_cache(cfg, B, max_len, cache_dtype)
    logits, cache = prefill_step(params, cfg, batch, cache)
    key = key if key is not None else jax.random.PRNGKey(0)
    tok = sample(logits, key, temperature)
    pos0 = S + extra

    def body(carry, i):
        tok, cache, key = carry
        key, sub = jax.random.split(key)
        # tok was sampled for position pos0 + i; decode it there to get
        # the logits of the next position
        pos = jnp.full((tok.shape[0],), pos0, jnp.int32) + i
        logits, cache = decode_step(params, cfg, tok, cache, pos)
        nxt = sample(logits, sub, temperature)
        return (nxt, cache, key), tok

    (last, cache, _), toks = jax.lax.scan(
        body, (tok, cache, key), jnp.arange(max_new_tokens - 1))
    out = jnp.concatenate([jnp.moveaxis(toks, 0, 1), last[:, None]], axis=1)
    return out


def decode_positions(cfg: ModelConfig, batch: int, seq_len: int):
    """Positions array for a decode_step at context length seq_len."""
    return jnp.full((batch,), seq_len - 1, jnp.int32)
