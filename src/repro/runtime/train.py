"""Training step: loss, grad, optimizer — with microbatched gradient
accumulation, remat (in the model's scanned blocks), optional int8
cross-pod gradient compression via partial-auto shard_map.

TrainState is a plain dict pytree: {'params', 'opt', 'step'} — shardable,
checkpointable, elastic.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

from repro.models import transformer
from repro.models.config import ModelConfig
from repro.optim import AdamWConfig, adamw_init, adamw_update, compression

IGNORE = -100  # label id excluded from the loss (e.g. vlm patch positions)


@dataclass(frozen=True)
class TrainConfig:
    optimizer: AdamWConfig = field(default_factory=AdamWConfig)
    microbatches: int = 1  # gradient accumulation steps per train step
    grad_accum_dtype: str = "float32"  # bfloat16 halves the grad buffer
    z_loss: float = 1e-4
    router_aux_weight: float = 0.01
    # Cross-pod int8 gradient reduction (optim/compression.py) applies in
    # manual-FSDP deployments via compressed_pmean_tree inside a shard_map
    # over 'pod'; under GSPMD-auto training the pod all-reduce is
    # compiler-inserted and not interceptable (see DESIGN.md §7 int8
    # collective lessons) — the wire-format primitive is tested standalone.
    grad_compression: str = "none"  # none | int8_pod (manual-FSDP only)


def cross_entropy(logits, labels):
    """Masked CE with z-loss.  logits (B,S,V) f32, labels (B,S) int."""
    mask = (labels != IGNORE).astype(jnp.float32)
    labels_safe = jnp.where(labels == IGNORE, 0, labels)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels_safe[..., None], axis=-1)[..., 0]
    nll = (lse - ll) * mask
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    zl = jnp.sum(jnp.square(lse) * mask) / denom
    return jnp.sum(nll) / denom, zl


def loss_fn(params, cfg: ModelConfig, tcfg: TrainConfig, batch: dict):
    logits, aux = transformer.forward(params, cfg, batch)
    ce, zl = cross_entropy(logits, batch["labels"])
    loss = ce + tcfg.z_loss * zl
    if cfg.num_experts:
        loss = loss + tcfg.router_aux_weight * aux["load_balance"] / max(
            sum(k in ("moe", "mamba_moe") for k in cfg.block_pattern)
            * cfg.num_groups, 1)
    metrics = {"ce": ce, "z_loss": zl, **aux}
    return loss, metrics


def _grads(params, cfg, tcfg, batch):
    """Microbatched value_and_grad (lax.scan accumulation)."""
    if tcfg.microbatches == 1:
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, cfg, tcfg, batch)
        return loss, metrics, grads
    A = tcfg.microbatches
    adt = jnp.dtype(tcfg.grad_accum_dtype)
    mb = jax.tree.map(
        lambda x: x.reshape(A, x.shape[0] // A, *x.shape[1:]), batch)

    def step(acc, mbatch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, cfg, tcfg, mbatch)
        acc_loss, acc_metrics, acc_grads = acc
        return (acc_loss + loss / A,
                jax.tree.map(lambda a, b: a + b / A, acc_metrics, metrics),
                jax.tree.map(lambda a, b: (a + (b / A).astype(adt)),
                             acc_grads, grads)), None

    l0 = jnp.zeros((), jnp.float32)
    m0 = {"ce": l0, "z_loss": l0, "load_balance": l0, "dropped_frac": l0}
    g0 = jax.tree.map(lambda x: jnp.zeros(x.shape, adt), params)
    (loss, metrics, grads), _ = jax.lax.scan(step, (l0, m0, g0), mb)
    return loss, metrics, grads


def init_state(key, cfg: ModelConfig, tcfg: TrainConfig | None = None) -> dict:
    params = transformer.init_params(key, cfg)
    ocfg = tcfg.optimizer if tcfg is not None else None
    return {"params": params, "opt": adamw_init(params, ocfg),
            "step": jnp.zeros((), jnp.int32)}


def train_step(state: dict, batch: dict, cfg: ModelConfig,
               tcfg: TrainConfig) -> tuple[dict, dict]:
    """One optimizer step.  Pure function of (state, batch) — jit/pjit it."""
    loss, metrics, grads = _grads(state["params"], cfg, tcfg, batch)
    params, opt, om = adamw_update(grads, state["opt"], state["params"],
                                   tcfg.optimizer)
    metrics = {"loss": loss, **metrics, **om}
    return ({"params": params, "opt": opt, "step": state["step"] + 1},
            metrics)


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig):
    return functools.partial(train_step, cfg=cfg, tcfg=tcfg)
