"""Raw-JAX model substrate: unified config, layers, MoE, Mamba, xLSTM,
and the scan-over-layers transformer assembly."""

from repro.models.config import ModelConfig, param_count  # noqa: F401
from repro.models import transformer  # noqa: F401
