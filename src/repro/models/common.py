"""Shared building blocks: norms, activations, initializers, linear glue."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import linear as qlinear
from repro.core.epilogue import Epilogue
from repro.distributed import sharding as shd_rules
from repro.distributed.sharding import constrain


def truncated_normal(key, shape, scale, dtype=jnp.float32):
    return jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) \
        .astype(dtype) * scale


# ---------------------------------------------------------------- norms
def norm_init(d: int, kind: str) -> dict:
    p = {"scale": jnp.ones((d,), jnp.float32)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), jnp.float32)
    return p


def norm_apply(p: dict, x: jnp.ndarray, kind: str, *, rms_offset: bool = False,
               eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(xf * xf, axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps)
        w = (1.0 + p["scale"]) if rms_offset else p["scale"]
        return (y * w).astype(x.dtype)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mean) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"] + p["bias"]).astype(x.dtype)


# ---------------------------------------------------------------- linears
def linear_init(key, in_dim, out_dim, cfg, quant=qlinear.DENSE, *, scale=None):
    """A QuantizedLinear leaf (dict with 'w' or quantized params)."""
    return qlinear.init(key, in_dim, out_dim, quant,
                        dtype=jnp.dtype(cfg.param_dtype), init_scale=scale)


def linear_apply(p, x, quant=qlinear.DENSE, *, in_dim=None, tag=None,
                 act="none", bias=None, residual=None, out_dtype=None,
                 shard_axes=None):
    """``tag`` names the linear for calibration's activation-statistics
    observer (repro.calib.stats); it never changes the computation —
    but it *does* name the weight's logical axes: under an active mesh
    (distributed.sharding.use) the LINEAR_AXES entry for the tag rides
    to the dispatch layer as ``shard_axes``, which plans local-shard
    tiles and runs the quantized GeMM inside a shard_map (tensor
    parallelism with per-shard LUT produce).  Tags without an entry
    (e.g. the vmapped MoE expert linears) stay under plain GSPMD.

    ``act``/``bias``/``residual``/``out_dtype`` describe the element-wise
    tail ``y = act(Wx + bias) + residual`` (cast to ``out_dtype``): they
    become a core.epilogue.Epilogue that fuses into the Pallas kernels'
    final VMEM writeback and falls back to the same unfused op sequence
    on every other backend (identical at f32 activations) — so model
    code stops issuing separate element-wise HBM passes after its
    quantized matmuls.  Under a contraction-sharded (row-parallel) plan
    the tail instead runs exactly once after the psum/reduce-scatter."""
    ep = None
    if act != "none" or bias is not None or residual is not None \
            or out_dtype is not None:
        ep = Epilogue(act=act, bias=bias is not None,
                      residual=residual is not None, out_dtype=out_dtype)
    if shard_axes is None and tag is not None:
        shard_axes = shd_rules.LINEAR_AXES.get(tag)
    return qlinear.apply(p, x, quant, in_dim=in_dim, tag=tag, epilogue=ep,
                         bias=bias, residual=residual,
                         shard_axes=shard_axes)


def softcap(x: jnp.ndarray, cap: float) -> jnp.ndarray:
    """gemma2 logit soft-capping: cap * tanh(x / cap)."""
    return cap * jnp.tanh(x / cap) if cap else x


def chunked_scan(step, carry, xs, *, chunk: int, remat: bool = True):
    """Two-level lax.scan: outer over chunks (carry checkpointed per
    chunk), inner rematerialized.  Backward memory for a T-step recurrence
    drops from O(T x state) to O(T/chunk x state) at the cost of one
    recomputed forward — the standard sqrt-T checkpointing for the
    mLSTM/sLSTM sequence scans (xlstm train at 4k stores 274 GB/device of
    per-step matrix-memory states without this).

    xs leaves must have leading dim T with T % chunk == 0 (caller pads).
    """
    T = jax.tree.leaves(xs)[0].shape[0]
    if chunk >= T:
        return jax.lax.scan(step, carry, xs)
    assert T % chunk == 0, (T, chunk)
    xs_c = jax.tree.map(
        lambda a: a.reshape(T // chunk, chunk, *a.shape[1:]), xs)

    def outer(c, xc):
        return jax.lax.scan(step, c, xc)

    if remat:
        outer = jax.checkpoint(outer)
    carry, ys_c = jax.lax.scan(outer, carry, xs_c)
    ys = jax.tree.map(lambda a: a.reshape(T, *a.shape[2:]), ys_c)
    return carry, ys


def activation(name: str):
    return {"gelu": jax.nn.gelu, "silu": jax.nn.silu, "relu": jax.nn.relu}[name]


# ---------------------------------------------------------------- MLP
def mlp_init(key, cfg, d_ff: int, quant=None) -> dict:
    q = quant if quant is not None else cfg.quant
    d = cfg.d_model
    ks = jax.random.split(key, 3)
    gated = cfg.mlp_activation in ("swiglu", "geglu")
    p = {"up": linear_init(ks[0], d, d_ff, cfg, q),
         "down": linear_init(ks[1], d_ff, d, cfg, q)}
    if gated:
        p["gate"] = linear_init(ks[2], d, d_ff, cfg, q)
    return p


def mlp_apply(p: dict, x: jnp.ndarray, cfg, quant=None, *,
              residual=None) -> jnp.ndarray:
    """MLP with the element-wise tail folded into the linears' epilogues:
    the non-gated activation fuses into the up projection's writeback and
    ``residual`` (the block input) into the down projection's, so the
    quantized hot path issues no separate activation/residual HBM passes
    (gated variants still need the gate×up product — only the gate's
    activation fuses)."""
    q = quant if quant is not None else cfg.quant
    act_name = {"swiglu": "silu", "geglu": "gelu",
                "gelu": "gelu"}[cfg.mlp_activation]
    if "gate" in p:
        up = linear_apply(p["up"], x, q, in_dim=cfg.d_model, tag="up")
        gate = linear_apply(p["gate"], x, q, in_dim=cfg.d_model, tag="gate",
                            act=act_name)
        h = gate * up
    else:
        h = linear_apply(p["up"], x, q, in_dim=cfg.d_model, tag="up",
                         act=act_name)
    h = constrain(h, *(("batch",) + ("seq",) * (h.ndim - 2) + ("mlp",)))
    return linear_apply(p["down"], h, q, in_dim=h.shape[-1], tag="down",
                        residual=residual)
