"""Mamba-1 (S6 selective scan) block — jamba's sequence mixer.

Training/prefill uses a chunked scan: ``lax.scan`` over sequence chunks
carrying the (B, d_inner, N) state, with a parallel
``lax.associative_scan`` inside each chunk — the standard TPU-friendly
two-level decomposition (compact HLO, work-efficient, state never
materialized beyond one chunk).  Decode is the single-step recurrence with
an SSM state + conv-tail cache (linear-time in sequence length — this is
what makes jamba long_500k-eligible).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models import common


def mamba_init(key, cfg) -> dict:
    d, di, n, dr = cfg.d_model, cfg.mamba_d_inner, cfg.mamba_d_state, cfg.dt_rank
    ks = jax.random.split(key, 6)
    p = {
        "in_proj": common.linear_init(ks[0], d, 2 * di, cfg, cfg.quant),
        "conv_w": common.truncated_normal(ks[1], (cfg.mamba_d_conv, di),
                                          cfg.mamba_d_conv**-0.5),
        "conv_b": jnp.zeros((di,), jnp.float32),
        "x_proj": common.linear_init(ks[2], di, dr + 2 * n, cfg, cfg.quant),
        "dt_proj": {"w": common.truncated_normal(ks[3], (di, dr), dr**-0.5),
                    "b": jnp.log(jnp.expm1(  # softplus^-1 of dt_init
                        jnp.exp(jax.random.uniform(
                            ks[4], (di,), minval=jnp.log(1e-3),
                            maxval=jnp.log(1e-1))))).astype(jnp.float32)},
        "A_log": jnp.log(jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32),
                                  (di, 1))),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": common.linear_init(ks[5], di, d, cfg, cfg.quant),
    }
    return p


def _causal_conv(x, w, b, tail=None):
    """Depthwise causal conv. x (B, L, di), w (K, di); tail (B, K-1, di)."""
    K = w.shape[0]
    pad = tail if tail is not None else jnp.zeros(
        (x.shape[0], K - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)  # (B, L+K-1, di)
    out = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(K))
    new_tail = xp[:, -(K - 1):, :] if K > 1 else pad[:, :0]
    return out + b, new_tail


def _ssm_params(p, cfg, xc):
    """xc (B, L, di) -> dt (B,L,di), B/C (B,L,N)."""
    n, dr = cfg.mamba_d_state, cfg.dt_rank
    proj = common.linear_apply(p["x_proj"], xc, cfg.quant,
                               in_dim=xc.shape[-1], tag="x_proj")
    dtr, Bm, Cm = jnp.split(proj.astype(jnp.float32), [dr, dr + n], axis=-1)
    dt = jax.nn.softplus(dtr @ p["dt_proj"]["w"].T + p["dt_proj"]["b"])
    return dt, Bm, Cm


def _scan_chunked(dA, dBu, C, h0, chunk):
    """h_t = dA_t * h_{t-1} + dBu_t ; y_t = <C_t, h_t>.

    dA/dBu (B, L, di, N), C (B, L, N).  Two-level scan (see module doc).
    """
    Bsz, L, di, N = dA.shape
    nc = -(-L // chunk)
    pad = nc * chunk - L
    if pad:
        dA = jnp.pad(dA, ((0, 0), (0, pad), (0, 0), (0, 0)),
                     constant_values=1.0)
        dBu = jnp.pad(dBu, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    resh = lambda t: jnp.moveaxis(
        t.reshape(Bsz, nc, chunk, *t.shape[2:]), 1, 0)
    dA_c, dBu_c, C_c = resh(dA), resh(dBu), resh(C)

    def outer(h, xs):
        a, b, c = xs  # (B, chunk, di, N) x2, (B, chunk, N)

        def combine(l, r):
            al, bl = l
            ar, br = r
            return al * ar, bl * ar + br

        a_cum, b_cum = jax.lax.associative_scan(combine, (a, b), axis=1)
        h_all = b_cum + a_cum * h[:, None]  # (B, chunk, di, N)
        y = jnp.einsum("bldn,bln->bld", h_all, c)
        return h_all[:, -1], y

    h_last, ys = jax.lax.scan(outer, h0, (dA_c, dBu_c, C_c))
    y = jnp.moveaxis(ys, 0, 1).reshape(Bsz, nc * chunk, di)
    return y[:, :L], h_last


def mamba_apply(p, cfg, x, *, state=None):
    """Full-sequence pass. x (B, L, d) -> (y, final_state)."""
    di = cfg.mamba_d_inner
    xz = common.linear_apply(p["in_proj"], x, cfg.quant,
                             in_dim=cfg.d_model, tag="in_proj")
    xs, z = jnp.split(xz, 2, axis=-1)
    xs = constrain(xs, "batch", "seq", "mamba_inner")
    conv_state = state["conv"] if state is not None else None
    xc, new_tail = _causal_conv(xs, p["conv_w"], p["conv_b"], conv_state)
    xc = jax.nn.silu(xc)
    dt, Bm, Cm = _ssm_params(p, cfg, xc)
    A = -jnp.exp(p["A_log"])  # (di, N)
    xf = xc.astype(jnp.float32)
    dA = jnp.exp(dt[..., None] * A)  # (B, L, di, N)
    dBu = (dt * xf)[..., None] * Bm[:, :, None, :]
    h0 = (state["ssm"] if state is not None else
          jnp.zeros((x.shape[0], di, cfg.mamba_d_state), jnp.float32))
    y, h_last = _scan_chunked(dA, dBu, Cm, h0, cfg.mamba_chunk)
    y = y + p["D"] * xf
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = common.linear_apply(p["out_proj"], y, cfg.quant, in_dim=di,
                              tag="out_proj")
    return constrain(out, "batch", "seq", "embed"), {
        "ssm": h_last, "conv": new_tail}


def mamba_decode(p, cfg, x, state):
    """Single-step decode. x (B, 1, d); state {'ssm','conv'}."""
    y, new_state = mamba_apply(p, cfg, x, state=state)
    return y, new_state


def init_state(cfg, batch: int, dtype=jnp.float32) -> dict:
    di = cfg.mamba_d_inner
    return {
        "ssm": jnp.zeros((batch, di, cfg.mamba_d_state), jnp.float32),
        "conv": jnp.zeros((batch, cfg.mamba_d_conv - 1, di), dtype),
    }
