"""Unified architecture config covering all 10 assigned families.

A model is a stack of ``num_layers`` blocks whose kinds repeat with period
``len(block_pattern)`` — the scan-over-layers unit (compact HLO, fast SPMD
compiles).  Block kinds:

    'attn'    global self-attention + MLP           (dense transformers)
    'local'   sliding-window self-attention + MLP   (gemma2 alternation)
    'moe'     self-attention + MoE FFN              (llama4, qwen2-moe, ...)
    'mamba'   Mamba-1 selective-scan block          (jamba)
    'mamba_moe'  mamba block with MoE FFN           (jamba MoE layers)
    'mlstm'   xLSTM matrix-memory block
    'slstm'   xLSTM scalar-memory block

Encoder-decoder (whisper) adds ``encoder_layers`` of bidirectional 'attn'
blocks plus cross-attention in every decoder block.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field

from repro.core.linear import DENSE, QuantConfig  # noqa: F401 (re-export)
from repro.core.spec import QuantSpec
from repro.kvq.spec import KVQuantSpec


@dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"  # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int = 2
    d_model: int = 256
    num_heads: int = 4
    num_kv_heads: int = 4
    head_dim: int = 0  # 0 -> d_model // num_heads
    d_ff: int = 1024
    vocab_size: int = 1024
    max_seq_len: int = 4096

    # block layout
    block_pattern: tuple[str, ...] = ("attn",)

    # attention details
    use_rope: bool = True  # whisper: absolute positions instead
    rope_theta: float = 10000.0
    attn_chunk: int = 4096  # q-chunked attention above this seq len
    sliding_window: int = 0  # 'local' blocks attend to this window
    attn_logit_softcap: float = 0.0  # gemma2: 50.0
    final_logit_softcap: float = 0.0  # gemma2: 30.0
    qk_norm: bool = False

    # MLP
    mlp_activation: str = "swiglu"  # swiglu | geglu | gelu
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    rms_offset: bool = False  # gemma: (1 + w) scaling
    embed_scale: bool = False  # gemma: embeddings scaled by sqrt(d)
    tie_embeddings: bool = False

    # MoE
    num_experts: int = 0
    num_experts_per_tok: int = 0
    moe_d_ff: int = 0  # per-expert hidden dim (0 -> d_ff)
    num_shared_experts: int = 0
    shared_expert_d_ff: int = 0
    capacity_factor: float = 1.25
    router_aux_loss: float = 0.0
    moe_groups: int = 16  # dispatch groups (match the data-parallel degree)

    # Mamba (jamba)
    mamba_d_state: int = 16
    mamba_expand: int = 2
    mamba_d_conv: int = 4
    mamba_dt_rank: int = 0  # 0 -> ceil(d_model / 16)
    mamba_chunk: int = 128

    # xLSTM
    xlstm_proj_factor: float = 2.0
    slstm_mlp_factor: float = 4 / 3
    xlstm_conv: int = 4
    xlstm_chunk: int = 128
    xlstm_parallel: bool = True  # chunkwise-parallel mLSTM (train path)

    # encoder-decoder (whisper)
    encoder_layers: int = 0
    max_source_len: int = 0  # encoder positions (frames)

    # modality frontend stubs (assignment: precomputed embeddings)
    frontend: str = ""  # '' | 'audio_frames' | 'image_patches'
    num_patches: int = 0  # vlm: patch tokens prepended to text

    # numerics / execution
    dtype: str = "float32"  # activation compute dtype
    param_dtype: str = "float32"
    # quantize the FSDP all-gather wire format to int8 (per-layer-group
    # symmetric scale, dequantized after the gather) — halves the
    # dominant train collective term at 400B scale (EXPERIMENTS.md §Perf)
    fsdp_int8_gather: bool = False
    # remat policy: save the per-group gathered weights from the forward
    # pass so the backward does not re-all-gather them (collective -33%,
    # memory +1 group of gathered params; EXPERIMENTS.md §Perf A)
    save_gathered_weights: bool = False
    # weight representation (QuantSpec; the deprecated QuantConfig shim
    # is accepted anywhere a spec is and carries its own exec policy)
    quant: QuantSpec = field(default_factory=lambda: DENSE)
    # paged-KV-cache storage (serving only): None keeps full-precision
    # pools; a KVQuantSpec stores codes+scales and routes paged attention
    # through repro.kvq (quantize-on-write, dequantize-on-read/in-kernel)
    kv_quant: KVQuantSpec | None = None
    remat: bool = True
    # 'nothing' recomputes the whole group in backward (min memory);
    # 'dots' saves matmul outputs (no re-forward of the MXU work — trades
    # ~EXEC/MODEL 0.75 -> 0.9 for per-group activation memory; §Perf A4)
    remat_policy: str = "nothing"  # nothing | dots
    scan_layers: bool = True
    logical_rules: str = "default"  # distributed/sharding.py rule set

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.num_layers % len(self.block_pattern) != 0:
            raise ValueError(
                f"{self.name}: num_layers={self.num_layers} not a multiple of "
                f"block_pattern period {len(self.block_pattern)}")
        if self.num_heads % self.num_kv_heads != 0:
            raise ValueError(f"{self.name}: heads must divide into kv groups")

    # ------------------------------------------------------------------
    @property
    def num_groups(self) -> int:
        """Scan length: how many times the block pattern repeats."""
        return self.num_layers // len(self.block_pattern)

    @property
    def dt_rank(self) -> int:
        return self.mamba_dt_rank or -(-self.d_model // 16)

    @property
    def mamba_d_inner(self) -> int:
        return self.mamba_expand * self.d_model

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def attention_free(self) -> bool:
        return not any(k in ("attn", "local", "moe") for k in self.block_pattern)

    @property
    def subquadratic(self) -> bool:
        """True if sequence mixing is sub-quadratic (long_500k eligibility)."""
        quad = {"attn", "local", "moe"}
        # 'local' is linear in seq; a pattern is subquadratic iff no block
        # kind does *global* quadratic attention over the full sequence.
        # jamba's sparse 'attn' layers decode linearly -> special-cased by
        # family ('hybrid'/'ssm' run long_500k per the assignment).
        return self.family in ("ssm", "hybrid")

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def with_quant(self, mode: str, **kw) -> "ModelConfig":
        return self.replace(quant=dataclasses.replace(self.quant, mode=mode, **kw))


def param_count(cfg: ModelConfig) -> dict:
    """Analytic parameter counts (total and active-per-token) — used for
    MODEL_FLOPS in the roofline and verified against real init in tests."""
    d, dff = cfg.d_model, cfg.d_ff
    h, hk, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    total = 0
    active = 0
    embed = cfg.vocab_size * d * (1 if cfg.tie_embeddings else 2)
    total += embed
    active += embed

    def attn_params():
        return d * (h * dh) + 2 * d * (hk * dh) + (h * dh) * d

    def mlp_params(ff):
        mult = 3 if cfg.mlp_activation in ("swiglu", "geglu") else 2
        return mult * d * ff

    def mamba_params():
        di, n, dr = cfg.mamba_d_inner, cfg.mamba_d_state, cfg.dt_rank
        return (d * 2 * di + cfg.mamba_d_conv * di + di * (dr + 2 * n)
                + dr * di + di * n + di + di * d)

    def mlstm_params():
        di = int(cfg.d_model * cfg.xlstm_proj_factor)
        dh_ = di // cfg.num_heads
        # up(2x) + block-diag q/k/v + scalar i/f gates + o gate + conv + down
        return (d * 2 * di + 3 * cfg.num_heads * dh_ * dh_
                + 2 * cfg.num_heads * di + d * di
                + cfg.xlstm_conv * di + di * d)

    def slstm_params():
        mlp = int(d * cfg.slstm_mlp_factor)
        # 4 gates x (input W + recurrent R) + GeGLU MLP
        return 4 * (d * d + d * d) + 3 * d * mlp

    for kind in cfg.block_pattern:
        reps = cfg.num_groups
        if kind in ("attn", "local"):
            p = attn_params() + mlp_params(dff)
            a = p
        elif kind == "moe":
            mdff = cfg.moe_d_ff or dff
            routed = cfg.num_experts * mlp_params(mdff)
            # shared experts fuse into one dense MLP of summed hidden dim
            shared = (mlp_params(cfg.shared_expert_d_ff or
                                 cfg.num_shared_experts * mdff)
                      if cfg.num_shared_experts else 0)
            router = d * cfg.num_experts
            p = attn_params() + routed + shared + router
            a = (attn_params() + router + shared
                 + cfg.num_experts_per_tok * mlp_params(mdff))
        elif kind == "mamba":
            p = a = mamba_params() + mlp_params(dff)
        elif kind == "mamba_moe":
            mdff = cfg.moe_d_ff or dff
            p = mamba_params() + cfg.num_experts * mlp_params(mdff) + d * cfg.num_experts
            a = mamba_params() + cfg.num_experts_per_tok * mlp_params(mdff) + d * cfg.num_experts
        elif kind == "mlstm":
            p = a = mlstm_params()
        elif kind == "slstm":
            p = a = slstm_params()
        else:
            raise ValueError(kind)
        total += p * reps
        active += a * reps

    if cfg.encoder_layers:
        enc = cfg.encoder_layers * (attn_params() + mlp_params(dff))
        # decoder cross-attention
        dec_cross = cfg.num_layers * attn_params()
        total += enc + dec_cross
        active += enc + dec_cross
    return {"total": total, "active": active}
