"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory, per-head C in
R^{dh x dh}) and sLSTM (scalar memory with recurrent memory mixing), both
with exponential gating + max-stabilizer state m.

The recurrences run as ``lax.scan`` over time — exact semantics, compact
HLO (one step body regardless of L), and the same step function drives
single-token decode, which is the long_500k path (state size is
O(H·dh^2) per layer, independent of sequence length).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models import common


# =========================================================== mLSTM block
def mlstm_init(key, cfg) -> dict:
    d = cfg.d_model
    di = int(d * cfg.xlstm_proj_factor)
    H = cfg.num_heads
    dh = di // H
    ks = jax.random.split(key, 8)
    bd = lambda k: common.truncated_normal(k, (H, dh, dh), dh**-0.5)
    return {
        "norm": common.norm_init(d, cfg.norm),
        "xl_up": common.linear_init(ks[0], d, 2 * di, cfg, cfg.quant),
        "xl_conv_w": common.truncated_normal(
            ks[1], (cfg.xlstm_conv, di), cfg.xlstm_conv**-0.5),
        "xl_conv_b": jnp.zeros((di,), jnp.float32),
        # q/k/v are per-head block-diagonal (the xLSTM paper's layout)
        "xl_q": {"w": bd(ks[2])},
        "xl_k": {"w": bd(ks[3])},
        "xl_v": {"w": bd(ks[4])},
        # i~, f~ scalar gates per head (from the conv branch)
        "xl_gates": {"w": common.truncated_normal(
            ks[5], (2 * cfg.num_heads, di), di**-0.5),
            "b": jnp.concatenate([jnp.zeros((cfg.num_heads,)),
                                  3.0 * jnp.ones((cfg.num_heads,)),  # f bias
                                  ]).astype(jnp.float32)},
        # o gate per channel from the block input
        "xl_o": common.linear_init(ks[7], d, di, cfg, cfg.quant),
        "xl_down": common.linear_init(ks[6], di, d, cfg, cfg.quant),
        "lskip": jnp.ones((di,), jnp.float32),
    }


def _blockdiag(w, x, B, L, H, dh):
    """x (B, L, di) -> per-head block-diagonal projection (B, L, H, dh)."""
    xh = x.reshape(B, L, H, dh).astype(jnp.float32)
    return jnp.einsum("blhd,hed->blhe", xh, w)


def _mlstm_step(state, inp):
    """Stabilized mLSTM recurrence (paper eqs. 19-27).

    state: C (B,H,dh,dh), n (B,H,dh), m (B,H)
    inp:   q,k,v (B,H,dh); i~, f~ (B,H)
    """
    C, n, m = state
    q, k, v, it, ft = inp
    m_new = jnp.maximum(ft + m, it)
    i_p = jnp.exp(it - m_new)[..., None]
    f_p = jnp.exp(ft + m - m_new)[..., None]
    C = f_p[..., None] * C + i_p[..., None] * (v[..., :, None] * k[..., None, :])
    n = f_p * n + i_p * k
    num = jnp.einsum("bhij,bhj->bhi", C, q)
    # C/n are exp(-m)-stabilized, so the paper's max(|n.q|, 1) floor is
    # exp(-m) in stabilized units
    den = jnp.maximum(jnp.abs(jnp.einsum("bhj,bhj->bh", n, q)),
                      jnp.exp(-m_new))
    h = num / den[..., None]
    return (C, n, m_new), h


def mlstm_sequence(q, k, v, it, ft, state, *, chunk: int = 128):
    """q/k/v (B, L, H, dh); it/ft (B, L, H).  Returns (h (B,L,H,dh), state).

    Uses the chunk-checkpointed scan: the (B,H,dh,dh) matrix memory is
    saved once per `chunk` steps for backward, not per step."""
    L = q.shape[1]
    pad = (-L) % chunk if L > chunk else 0
    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (q, k, v, it, ft))
    if pad:
        xs = tuple(jnp.pad(t, ((0, pad),) + ((0, 0),) * (t.ndim - 1))
                   for t in xs)
    state, hs = common.chunked_scan(_mlstm_step, state, xs, chunk=chunk)
    return jnp.moveaxis(hs[:L], 0, 1), state


def _mlstm_chunk_parallel(state, inp):
    """One chunk of the *parallel* (attention-like) stabilized mLSTM.

    state: C (B,H,dh,dh), n (B,H,dh), m (B,H) — absolute stabilizer.
    inp:   q,k,v (B,W,H,dh); it,ft (B,W,H)  (ft already log-sigmoid).

    Within the chunk, position t sees
        h_t = [ exp(m0-a_t)·q_t C0  +  Σ_{s<=t} exp(g_s-a_t)(q_t·k_s) v_s ]
              / max(|den_t|, exp(-m_t))
    with b_t = Σ_{s<=t} f̃_s,  g_s = ĩ_s - b_s,
    a_t = max(m0, cummax g),  m_t = b_t + a_t — algebraically identical to
    the sequential recurrence (verified in tests to 1e-4), O(W²) parallel
    work instead of W sequential steps.
    """
    C0, n0, m0 = state
    q, k, v, it, ft = inp
    B, W, H, dh = q.shape
    b = jnp.cumsum(ft, axis=1)  # (B, W, H)
    g = it - b
    a = jnp.maximum(m0[:, None], jax.lax.cummax(g, axis=1))  # (B, W, H)
    m = b + a

    # intra-chunk: D[t, s] = exp(g_s - a_t), s <= t
    decay = jnp.exp(g[:, None, :, :] - a[:, :, None, :])  # (B, t, s, H)
    mask = jnp.tril(jnp.ones((W, W), bool))
    decay = jnp.where(mask[None, :, :, None], decay, 0.0)
    qk = jnp.einsum("bthd,bshd->btsh", q, k)  # (B, t, s, H)
    w_ts = qk * decay
    num = jnp.einsum("btsh,bshd->bthd", w_ts, v)
    den = jnp.sum(w_ts, axis=2)  # (B, t, H)

    # inter-chunk: carried memory, decayed to position t.  C[i, j] = v_i k_j,
    # retrieval contracts the k index: (C0 q)_i = sum_j C0[i, j] q_j.
    scale0 = jnp.exp(m0[:, None] - a)  # (B, W, H)
    num = num + jnp.einsum("bthd,bhed->bthe", q, C0) * scale0[..., None]
    den = den + jnp.einsum("bthd,bhd->bth", q, n0) * scale0

    h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m))[..., None]

    # carry to the next chunk (position W)
    aW, bW = a[:, -1], b[:, -1]  # (B, H)
    wk = jnp.exp(g - aW[:, None])  # (B, W, H)
    C = (jnp.einsum("bshd,bshe,bsh->bhde", v, k, wk)
         + jnp.exp(m0 - aW)[..., None, None] * C0)
    n = (jnp.einsum("bshd,bsh->bhd", k, wk)
         + jnp.exp(m0 - aW)[..., None] * n0)
    return (C, n, bW + aW), h


def mlstm_sequence_parallel(q, k, v, it, ft, state, *, chunk: int = 128):
    """Chunkwise-parallel mLSTM: scan over chunks, O(W²) attention-like
    math inside — the production training path (mLSTM paper's chunkwise
    form).  Exactly equivalent to `mlstm_sequence` (tested)."""
    B, L, H, dh = q.shape
    W = min(chunk, L)
    pad = (-L) % W
    def prep(t):
        if pad:
            t = jnp.pad(t, ((0, 0), (0, pad)) + ((0, 0),) * (t.ndim - 2))
        return jnp.moveaxis(
            t.reshape(B, (L + pad) // W, W, *t.shape[2:]), 1, 0)

    xs = tuple(prep(t) for t in (q, k, v, it, ft))
    fn = jax.checkpoint(_mlstm_chunk_parallel)
    state, hs = jax.lax.scan(fn, state, xs)
    h = jnp.moveaxis(hs, 0, 1).reshape(B, L + pad, H, dh)
    return h[:, :L], state


def mlstm_block_apply(p, cfg, x, *, state=None):
    """x (B, L, d) -> (y, new_state)."""
    B, L, d = x.shape
    H = cfg.num_heads
    di = int(d * cfg.xlstm_proj_factor)
    dh = di // H
    h_in = common.norm_apply(p["norm"], x, cfg.norm)
    ab = common.linear_apply(p["xl_up"], h_in, cfg.quant, in_dim=d,
                             tag="xl_up")
    a, b = jnp.split(ab, 2, axis=-1)
    a = constrain(a, "batch", "seq", "xl_inner")
    from repro.models.mamba import _causal_conv  # shared depthwise conv

    conv_state = state["conv"] if state is not None else None
    ac, new_tail = _causal_conv(a, p["xl_conv_w"], p["xl_conv_b"], conv_state)
    ac = jax.nn.silu(ac)
    q = _blockdiag(p["xl_q"]["w"], ac, B, L, H, dh)
    k = _blockdiag(p["xl_k"]["w"], ac, B, L, H, dh) * dh**-0.5
    v = _blockdiag(p["xl_v"]["w"], a, B, L, H, dh)
    gates = (ac.astype(jnp.float32) @ p["xl_gates"]["w"].T
             + p["xl_gates"]["b"])
    it = gates[..., :H]
    ft = jax.nn.log_sigmoid(gates[..., H:])
    o = jax.nn.sigmoid(common.linear_apply(p["xl_o"], h_in, cfg.quant,
                                           in_dim=d, tag="xl_o")
                       .astype(jnp.float32))
    st = (state["C"], state["n"], state["m"]) if state is not None else (
        jnp.zeros((B, H, dh, dh), jnp.float32),
        jnp.zeros((B, H, dh), jnp.float32),
        -jnp.inf * jnp.ones((B, H), jnp.float32),
    )
    seq_fn = (mlstm_sequence_parallel if L > 1 and cfg.xlstm_parallel
              else mlstm_sequence)
    hseq, (C, n, m) = seq_fn(q, k, v, it, ft, st, chunk=cfg.xlstm_chunk)
    hseq = hseq.reshape(B, L, di) * o
    # learnable skip from the conv branch
    hseq = (hseq + p["lskip"] * ac.astype(jnp.float32)).astype(x.dtype)
    out = hseq * jax.nn.silu(b)
    out = common.linear_apply(p["xl_down"], out, cfg.quant, in_dim=di,
                               tag="xl_down")
    return x + constrain(out, "batch", "seq", "embed"), {
        "C": C, "n": n, "m": m, "conv": new_tail}


def mlstm_state(cfg, batch: int, dtype=jnp.float32) -> dict:
    d = cfg.d_model
    di = int(d * cfg.xlstm_proj_factor)
    H = cfg.num_heads
    dh = di // H
    return {
        "C": jnp.zeros((batch, H, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, H, dh), jnp.float32),
        "m": jnp.full((batch, H), -jnp.inf, jnp.float32),
        "conv": jnp.zeros((batch, cfg.xlstm_conv - 1, di), dtype),
    }


# =========================================================== sLSTM block
def slstm_init(key, cfg) -> dict:
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    mlp_ff = int(d * cfg.slstm_mlp_factor)
    return {
        "norm": common.norm_init(d, cfg.norm),
        "norm2": common.norm_init(d, cfg.norm),
        "sl_w": {"w": common.truncated_normal(ks[0], (4 * d, d), d**-0.5),
                 "b": jnp.concatenate([
                     jnp.zeros((2 * d,)), 3.0 * jnp.ones((d,)),
                     jnp.zeros((d,))]).astype(jnp.float32)},
        "sl_r": {"w": common.truncated_normal(ks[1], (4 * d, d), d**-0.5)},
        "mlp": common.mlp_init(ks[2], cfg.replace(mlp_activation="geglu"),
                               mlp_ff),
    }


def _slstm_step(state, wx, R):
    """state: (h, c, n, m) each (B, d); wx (B, 4d) precomputed W x_t + b."""
    h, c, n, m = state
    zifo = wx + h @ R.T  # memory mixing through the recurrent matrix
    z, it, ft, o = jnp.split(zifo, 4, axis=-1)
    ft = jax.nn.log_sigmoid(ft)
    m_new = jnp.maximum(ft + m, it)
    i_p = jnp.exp(it - m_new)
    f_p = jnp.exp(ft + m - m_new)
    c_new = f_p * c + i_p * jnp.tanh(z)
    n_new = f_p * n + i_p
    h_new = jax.nn.sigmoid(o) * c_new / jnp.maximum(n_new, 1e-6)
    return (h_new, c_new, n_new, m_new), h_new


def slstm_block_apply(p, cfg, x, *, state=None):
    B, L, d = x.shape
    xi = common.norm_apply(p["norm"], x, cfg.norm).astype(jnp.float32)
    wx = xi @ p["sl_w"]["w"].T + p["sl_w"]["b"]  # (B, L, 4d)
    st = (state["h"], state["c"], state["n"], state["m"]) if state else tuple(
        jnp.zeros((B, d), jnp.float32) for _ in range(3)) + (
        jnp.full((B, d), -jnp.inf, jnp.float32),)
    R = p["sl_r"]["w"]

    def step(s, wx_t):
        return _slstm_step(s, wx_t, R)

    wx_t = jnp.moveaxis(wx, 1, 0)
    pad = (-L) % cfg.xlstm_chunk if L > cfg.xlstm_chunk else 0
    if pad:
        wx_t = jnp.pad(wx_t, ((0, pad), (0, 0), (0, 0)))
    (h, c, n, m), hs = common.chunked_scan(step, st, wx_t,
                                           chunk=cfg.xlstm_chunk)
    y = jnp.moveaxis(hs[:L], 0, 1).astype(x.dtype)
    x = x + y
    x = x + common.mlp_apply(p["mlp"], common.norm_apply(p["norm2"], x, cfg.norm),
                             cfg.replace(mlp_activation="geglu"))
    return x, {"h": h, "c": c, "n": n, "m": m}


def slstm_state(cfg, batch: int, dtype=jnp.float32) -> dict:
    d = cfg.d_model
    z = lambda: jnp.zeros((batch, d), jnp.float32)
    return {"h": z(), "c": z(), "n": z(),
            "m": jnp.full((batch, d), -jnp.inf, jnp.float32)}
