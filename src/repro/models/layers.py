"""Attention (GQA/MQA, RoPE, sliding-window, soft-cap, cross-attn) with
full-sequence and single-step-decode paths."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models import common


# ---------------------------------------------------------------- RoPE
def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return theta ** (-jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float
               ) -> jnp.ndarray:
    """x (B, S, H, Dh), positions (B, S) -> rotated x."""
    freqs = rope_freqs(x.shape[-1], theta)  # (Dh/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, Dh/2)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------- attention
def attn_init(key, cfg, *, cross: bool = False) -> dict:
    d, h, hk, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": common.linear_init(ks[0], d, h * dh, cfg, cfg.quant),
        "wk": common.linear_init(ks[1], d, hk * dh, cfg, cfg.quant),
        "wv": common.linear_init(ks[2], d, hk * dh, cfg, cfg.quant),
        "wo": common.linear_init(ks[3], h * dh, d, cfg, cfg.quant),
    }
    if cfg.qk_norm:
        p["q_norm"] = common.norm_init(dh, "rmsnorm")
        p["k_norm"] = common.norm_init(dh, "rmsnorm")
    return p


def _qkv(p, cfg, xq, xkv, positions_q, positions_kv, *, rope=True):
    B = xq.shape[0]
    h, hk, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = common.linear_apply(p["wq"], xq, cfg.quant, in_dim=cfg.d_model,
                            tag="wq")
    k = common.linear_apply(p["wk"], xkv, cfg.quant, in_dim=cfg.d_model,
                            tag="wk")
    v = common.linear_apply(p["wv"], xkv, cfg.quant, in_dim=cfg.d_model,
                            tag="wv")
    q = q.reshape(B, -1, h, dh)
    k = k.reshape(B, -1, hk, dh)
    v = v.reshape(B, -1, hk, dh)
    if cfg.qk_norm:
        q = common.norm_apply(p["q_norm"], q, "rmsnorm")
        k = common.norm_apply(p["k_norm"], k, "rmsnorm")
    if rope and cfg.use_rope:
        q = apply_rope(q, positions_q, cfg.rope_theta)
        k = apply_rope(k, positions_kv, cfg.rope_theta)
    q = constrain(q, "batch", "seq", "heads", "head_dim")
    k = constrain(k, "batch", "seq", "kvheads", "head_dim")
    v = constrain(v, "batch", "seq", "kvheads", "head_dim")
    return q, k, v


def _sdpa(cfg, q, k, v, mask) -> jnp.ndarray:
    """q (B,Sq,H,Dh), k/v (B,Skv,Hk,Dh), mask (B,1,Sq,Skv) bool or None."""
    B, Sq, h, dh = q.shape
    hk = k.shape[2]
    g = h // hk
    qg = q.reshape(B, Sq, hk, g, dh)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * dh**-0.5
    logits = common.softcap(logits, cfg.attn_logit_softcap)
    if mask is not None:
        logits = jnp.where(mask[:, :, None] if mask.ndim == 4 else mask,
                           logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v.astype(jnp.float32))
    out = out.reshape(B, Sq, h * dh).astype(q.dtype)
    return out


def causal_mask(Sq: int, Skv: int, *, window: int = 0, offset: int = 0
                ) -> jnp.ndarray:
    """(1, 1, Sq, Skv) bool; offset = start position of the query block."""
    qpos = offset + jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Skv)[None, :]
    m = kpos <= qpos
    if window:
        m &= kpos > qpos - window
    return m[None, None]


def attn_apply(p, cfg, x, positions, *, window: int = 0,
               mask: jnp.ndarray | None = None, causal: bool = True,
               return_kv: bool = False, residual=None):
    """Full-sequence self-attention (train / prefill).

    ``residual`` (the block input) is folded into the output
    projection's epilogue — one fused writeback instead of a separate
    elementwise add over (B, S, d) after every attention block.

    Above cfg.attn_chunk the query dim is processed in chunks via
    lax.scan (flash-style row blocking, exact math): the (Sq, Skv) logits
    block never exceeds (chunk, Skv) — this is what makes prefill_32k
    lowerable without an O(S^2) footprint.
    """
    B, S, _ = x.shape
    q, k, v = _qkv(p, cfg, x, x, positions, positions)
    pm = mask[:, None, None, :] if mask is not None else None
    C = cfg.attn_chunk
    if C and S > C and S % C == 0:
        nc = S // C
        qs = jnp.moveaxis(q.reshape(B, nc, C, *q.shape[2:]), 1, 0)
        offs = jnp.arange(nc) * C

        def body(_, xs):
            qc, off = xs
            m = _chunk_mask(C, S, window, off) if causal else None
            if pm is not None:
                m = pm if m is None else (m & pm)
            return None, _sdpa(cfg, qc, k, v, m)

        _, outs = jax.lax.scan(body, None, (qs, offs))
        out = jnp.moveaxis(outs, 0, 1).reshape(B, S, -1)
    else:
        m = causal_mask(S, S, window=window) if causal else None
        if pm is not None:
            m = pm if m is None else (m & pm)
        out = _sdpa(cfg, q, k, v, m)
    out = common.linear_apply(p["wo"], out, cfg.quant,
                              in_dim=cfg.num_heads * cfg.head_dim, tag="wo",
                              residual=residual)
    out = constrain(out, "batch", "seq", "embed")
    return (out, k, v) if return_kv else out


def _chunk_mask(C: int, Skv: int, window: int, offset) -> jnp.ndarray:
    """Traced-offset causal (+sliding window) mask for one q chunk."""
    qpos = offset + jnp.arange(C)[:, None]
    kpos = jnp.arange(Skv)[None, :]
    m = kpos <= qpos
    if window:
        m &= kpos > qpos - window
    return m[None, None]


def view_mask(Skv: int, positions, *, window: int = 0) -> jnp.ndarray:
    """Causal (+sliding-window) mask over a logically-ordered KV view.

    positions (B, C) are the query tokens' logical positions; view index w
    holds the KV of logical position w (true for both the dense cache and
    a block-table-expanded paged view).  Returns (B, C, Skv) bool — shared
    by the static decode and paged serving paths.
    """
    kpos = jnp.arange(Skv)[None, None, :]
    qpos = positions[:, :, None]
    m = kpos <= qpos
    if window:
        m &= kpos > qpos - window
    return m


def attn_decode(p, cfg, x, cache_k, cache_v, pos, *, window: int = 0,
                residual=None):
    """Single-token decode. x (B, 1, d); cache (B, Skv, Hk, Dh); pos (B,).

    Returns (out, new_k, new_v).  The KV cache is logically
    ('batch','kv_seq','kvheads','head_dim') — on meshes where kv-heads
    cannot shard, kv_seq takes the model axis (DESIGN.md §4).
    """
    q, k, v = _qkv(p, cfg, x, x, pos[:, None], pos[:, None])
    B, Skv = cache_k.shape[0], cache_k.shape[1]
    # where-based write: no arithmetic on the cache dtype, so quantized
    # (f8) caches lower cleanly
    mask = (jnp.arange(Skv)[None, :] == pos[:, None])[..., None, None]
    new_k = jnp.where(mask, k.astype(cache_k.dtype), cache_k)
    new_v = jnp.where(mask, v.astype(cache_v.dtype), cache_v)
    new_k = constrain(new_k, "batch", "kv_seq", "kvheads", "head_dim")
    new_v = constrain(new_v, "batch", "kv_seq", "kvheads", "head_dim")
    m = view_mask(Skv, pos[:, None], window=window)[:, 0]
    out = _sdpa(cfg, q, new_k, new_v, m[:, None, None, :])
    out = common.linear_apply(p["wo"], out, cfg.quant,
                              in_dim=cfg.num_heads * cfg.head_dim, tag="wo",
                              residual=residual)
    return out, new_k, new_v


def attn_paged(p, cfg, x, cache, positions, write_slots, view_slots,
               *, window: int = 0, residual=None):
    """Self-attention over a paged (block-pooled) KV cache — one step of
    chunked prefill (C > 1) or batched decode (C == 1); the two share this
    code and its compiled form.

    x (B, C, d) normed hidden; ``cache`` is the layer's shared block pool:
    {"k", "v"} of (num_blocks, bs, Hk, Dh) at full precision, or the
    quantized {"k", "k_scale", "v", "v_scale"} layout of repro.kvq.pool
    when ``cfg.kv_quant`` is set; positions (B, C) logical token
    positions; write_slots (B, C) flat pool slots (block*bs + offset)
    where this step's K/V are scattered — padding rows point into the
    reserved scratch block; view_slots (B, W) flat pool slots such that
    view index w holds sequence b's logical position w (block tables
    expanded by the host scheduler, padded with scratch).  Masked
    (future / scratch) view entries get probability exactly 0, so
    outputs match the dense-cache path bit-for-bit.

    Returns (out, new_cache).
    """
    q, k, v = _qkv(p, cfg, x, x, positions, positions)
    if cfg.kv_quant is not None:
        out, new_cache = _attn_paged_quantized(
            cfg, q, k, v, cache, positions, write_slots, view_slots,
            window=window)
    else:
        k_pool, v_pool = cache["k"], cache["v"]
        nb, bs, hk, dh = k_pool.shape
        kp = k_pool.reshape(nb * bs, hk, dh)
        vp = v_pool.reshape(nb * bs, hk, dh)
        ws = write_slots.reshape(-1)
        kp = kp.at[ws].set(k.reshape(-1, hk, dh).astype(kp.dtype))
        vp = vp.at[ws].set(v.reshape(-1, hk, dh).astype(vp.dtype))
        # mesh-aware pool layout: slots replicated (every data shard must
        # resolve any sequence's blocks), kvheads on the model axis when
        # divisible — matching runtime.serve.init_paged_cache's placement
        # so the scatter/gather pair stays local to each model shard
        kp = constrain(kp, "none", "kvheads", "head_dim")
        vp = constrain(vp, "none", "kvheads", "head_dim")
        k_view = jnp.take(kp, view_slots, axis=0)  # (B, W, Hk, Dh)
        v_view = jnp.take(vp, view_slots, axis=0)
        k_view = constrain(k_view, "batch", "kv_seq", "kvheads", "head_dim")
        v_view = constrain(v_view, "batch", "kv_seq", "kvheads", "head_dim")
        m = view_mask(view_slots.shape[1], positions, window=window)
        out = _sdpa(cfg, q, k_view, v_view, m[:, None])
        new_cache = dict(cache,
                         k=kp.reshape(nb, bs, hk, dh),
                         v=vp.reshape(nb, bs, hk, dh))
    out = common.linear_apply(p["wo"], out, cfg.quant,
                              in_dim=cfg.num_heads * cfg.head_dim, tag="wo",
                              residual=residual)
    return out, new_cache


def _attn_paged_quantized(cfg, q, k, v, cache, positions, write_slots,
                          view_slots, *, window: int = 0):
    """Quantize-on-write into the codes+scales pool, then dispatch the
    attention math through the registered paged-attention backend
    (repro.kvq.attention: jnp gather+dequant reference, or the Pallas
    kernel that dequantizes in VMEM)."""
    from repro import kvq
    from repro.kvq import attention as kvq_attn

    spec = cfg.kv_quant
    B, C, H, dh = q.shape
    nb, bs, hk, dhp = cache["k"].shape
    ws = write_slots.reshape(-1)
    kq, ks = kvq.kv_quantize(k, spec)  # codes (B, C, Hk, Dhp), scales f32
    vq, vs = kvq.kv_quantize(v, spec)
    new_cache = {}
    for name, codes, scales in (("k", kq, ks), ("v", vq, vs)):
        cp = cache[name].reshape(nb * bs, hk, dhp)
        sp = cache[f"{name}_scale"].reshape(nb * bs, hk)
        cp = cp.at[ws].set(codes.reshape(-1, hk, dhp))
        sp = sp.at[ws].set(scales.reshape(-1, hk))
        cp = constrain(cp, "none", "kvheads", "none")
        sp = constrain(sp, "none", "kvheads")
        new_cache[name] = cp.reshape(nb, bs, hk, dhp)
        new_cache[f"{name}_scale"] = sp.reshape(nb, bs, hk)
    out = kvq_attn.run(spec, cfg, q, new_cache, view_slots, positions,
                       window=window)
    return out, new_cache


def cross_attn_apply(p, cfg, x, enc_k, enc_v, positions, *, residual=None):
    """Decoder cross-attention against precomputed encoder K/V."""
    B = x.shape[0]
    h, dh = cfg.num_heads, cfg.head_dim
    q = common.linear_apply(p["wq"], x, cfg.quant, in_dim=cfg.d_model,
                            tag="wq")
    q = q.reshape(B, -1, h, dh)
    out = _sdpa(cfg, q, enc_k, enc_v, None)
    out = common.linear_apply(p["wo"], out, cfg.quant,
                              in_dim=cfg.num_heads * cfg.head_dim, tag="wo",
                              residual=residual)
    return out


def cross_kv(p, cfg, enc_out):
    """Project encoder output once; cached for all decode steps."""
    B = enc_out.shape[0]
    hk, dh = cfg.num_kv_heads, cfg.head_dim
    k = common.linear_apply(p["wk"], enc_out, cfg.quant, in_dim=cfg.d_model,
                            tag="wk")
    v = common.linear_apply(p["wv"], enc_out, cfg.quant, in_dim=cfg.d_model,
                            tag="wv")
    return k.reshape(B, -1, hk, dh), v.reshape(B, -1, hk, dh)
