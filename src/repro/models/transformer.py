"""Model assembly: embeddings, heterogeneous block stacks (scan-over-layers),
KV/SSM caches, decoder-only + encoder-decoder forward/prefill/decode.

Layers are grouped by the repeating ``cfg.block_pattern``; parameters are
stacked (G, ...) along a leading scan axis so the HLO contains each distinct
block body once regardless of depth — essential for 512-way SPMD compile
times and for XLA's collective overlap scheduling (DESIGN.md §4).
"""

from __future__ import annotations

import jax
from jax.ad_checkpoint import checkpoint_name
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.distributed.sharding import constrain_params as \
    sharding_constrain_params
from repro.models import common, layers, mamba, moe, xlstm
from repro.models.config import ModelConfig


# ----------------------------------------------------------------- blocks
def block_init(key, cfg: ModelConfig, kind: str, *, cross: bool = False) -> dict:
    ks = jax.random.split(key, 8)
    if kind in ("attn", "local"):
        p = {"ln1": common.norm_init(cfg.d_model, cfg.norm),
             "attn": layers.attn_init(ks[0], cfg),
             "ln2": common.norm_init(cfg.d_model, cfg.norm),
             "mlp": common.mlp_init(ks[1], cfg, cfg.d_ff)}
    elif kind == "moe":
        p = {"ln1": common.norm_init(cfg.d_model, cfg.norm),
             "attn": layers.attn_init(ks[0], cfg),
             "ln2": common.norm_init(cfg.d_model, cfg.norm),
             "moe": moe.moe_init(ks[1], cfg)}
    elif kind == "mamba":
        p = {"ln1": common.norm_init(cfg.d_model, cfg.norm),
             "mamba": mamba.mamba_init(ks[0], cfg),
             "ln2": common.norm_init(cfg.d_model, cfg.norm),
             "mlp": common.mlp_init(ks[1], cfg, cfg.d_ff)}
    elif kind == "mamba_moe":
        p = {"ln1": common.norm_init(cfg.d_model, cfg.norm),
             "mamba": mamba.mamba_init(ks[0], cfg),
             "ln2": common.norm_init(cfg.d_model, cfg.norm),
             "moe": moe.moe_init(ks[1], cfg)}
    elif kind == "mlstm":
        p = xlstm.mlstm_init(ks[0], cfg)
    elif kind == "slstm":
        p = xlstm.slstm_init(ks[0], cfg)
    else:
        raise ValueError(kind)
    if cross and kind in ("attn", "local", "moe"):
        p["ln_cross"] = common.norm_init(cfg.d_model, cfg.norm)
        p["cross"] = layers.attn_init(ks[2], cfg, cross=True)
    return p


def block_cache(cfg: ModelConfig, kind: str, batch: int, max_len: int,
                dtype) -> dict:
    """`dtype` applies to the (large, read-only-per-step) KV tensors — it
    may be a storage dtype like f8.  Recurrent states participate in
    arithmetic every step and stay in the activation dtype."""
    hk, dh = cfg.num_kv_heads, cfg.head_dim
    if kind in ("attn", "local", "moe"):
        c = {"k": jnp.zeros((batch, max_len, hk, dh), dtype),
             "v": jnp.zeros((batch, max_len, hk, dh), dtype)}
        if cfg.is_encdec:
            src = cfg.max_source_len or max_len
            c["cross_k"] = jnp.zeros((batch, src, hk, dh), dtype)
            c["cross_v"] = jnp.zeros((batch, src, hk, dh), dtype)
        return c
    state_dt = jnp.dtype(cfg.dtype)
    if kind in ("mamba", "mamba_moe"):
        return mamba.init_state(cfg, batch, state_dt)
    if kind == "mlstm":
        return xlstm.mlstm_state(cfg, batch, state_dt)
    if kind == "slstm":
        return xlstm.slstm_state(cfg, batch, state_dt)
    raise ValueError(kind)


def _ffn(p, cfg, x, aux):
    if "mlp" in p:
        h = common.norm_apply(p["ln2"], x, cfg.norm, rms_offset=cfg.rms_offset)
        # residual rides the down projection's fused epilogue
        return common.mlp_apply(p["mlp"], h, cfg, residual=x), aux
    h = common.norm_apply(p["ln2"], x, cfg.norm, rms_offset=cfg.rms_offset)
    y, a = moe.moe_apply(p["moe"], h, cfg)
    for k, v in a.items():
        aux[k] = aux.get(k, 0.0) + v
    return x + y, aux


def block_apply(p, cfg: ModelConfig, kind: str, x, positions, *,
                mode: str = "train", cache: dict | None = None,
                pos=None, enc_out=None, paged=None):
    """Dispatch one block.  Returns (x, new_cache, aux).

    mode 'paged' runs the serving path over a block-pooled KV cache:
    ``paged`` carries (write_slots (B, C), view_slots (B, W)) and ``cache``
    holds this group's pool tensors (num_blocks, bs, Hk, Dh).
    """
    aux: dict = {}
    window = cfg.sliding_window if kind == "local" else 0
    if mode == "paged" and kind not in ("attn", "local", "moe"):
        raise NotImplementedError(
            f"paged serving supports attention block kinds only, got {kind!r}")
    if kind in ("attn", "local", "moe"):
        h = common.norm_apply(p["ln1"], x, cfg.norm, rms_offset=cfg.rms_offset)
        new_cache = dict(cache) if cache is not None else None
        # the block-input residual rides each attention out-projection's
        # fused epilogue (no separate x + y elementwise pass)
        if mode == "paged":
            write_slots, view_slots = paged
            y, paged_cache = layers.attn_paged(
                p["attn"], cfg, h, cache, positions,
                write_slots, view_slots, window=window, residual=x)
            new_cache.update(paged_cache)
        elif mode == "decode":
            y, nk, nv = layers.attn_decode(
                p["attn"], cfg, h, cache["k"], cache["v"], pos, window=window,
                residual=x)
            new_cache["k"], new_cache["v"] = nk, nv
        else:
            causal = not (cfg.is_encdec and mode == "encode")
            if cache is not None:  # prefill: also write the prompt's K/V
                y, k, v = layers.attn_apply(p["attn"], cfg, h, positions,
                                            window=window, causal=causal,
                                            return_kv=True, residual=x)
                new_cache["k"] = jax.lax.dynamic_update_slice(
                    cache["k"], k.astype(cache["k"].dtype), (0, 0, 0, 0))
                new_cache["v"] = jax.lax.dynamic_update_slice(
                    cache["v"], v.astype(cache["v"].dtype), (0, 0, 0, 0))
            else:
                y = layers.attn_apply(p["attn"], cfg, h, positions,
                                      window=window, causal=causal,
                                      residual=x)
        x = y
        if cfg.is_encdec and mode != "encode" and "cross" in p:
            hc = common.norm_apply(p["ln_cross"], x, cfg.norm,
                                   rms_offset=cfg.rms_offset)
            if cache is not None and mode == "decode":
                ck, cv = cache["cross_k"], cache["cross_v"]
            elif cache is not None:  # prefill computes + stores cross K/V
                ck, cv = layers.cross_kv(p["cross"], cfg, enc_out)
                new_cache["cross_k"] = ck.astype(cache["cross_k"].dtype)
                new_cache["cross_v"] = cv.astype(cache["cross_v"].dtype)
            else:
                ck, cv = layers.cross_kv(p["cross"], cfg, enc_out)
            x = layers.cross_attn_apply(p["cross"], cfg, hc, ck, cv,
                                        positions, residual=x)
        x, aux = _ffn(p, cfg, x, aux)
        return x, new_cache, aux
    if kind in ("mamba", "mamba_moe"):
        h = common.norm_apply(p["ln1"], x, cfg.norm, rms_offset=cfg.rms_offset)
        y, new_state = mamba.mamba_apply(p["mamba"], cfg, h, state=cache)
        x = x + y
        x, aux = _ffn(p, cfg, x, aux)
        return x, new_state if cache is not None else None, aux
    if kind == "mlstm":
        x, new_state = xlstm.mlstm_block_apply(p, cfg, x, state=cache)
        return x, new_state if cache is not None else None, aux
    if kind == "slstm":
        x, new_state = xlstm.slstm_block_apply(p, cfg, x, state=cache)
        return x, new_state if cache is not None else None, aux
    raise ValueError(kind)


# ----------------------------------------------------------------- stacks
def _stack_init(key, cfg: ModelConfig, pattern, groups: int, *,
                cross: bool = False) -> dict:
    out = {}
    for i, kind in enumerate(pattern):
        keys = jax.random.split(jax.random.fold_in(key, i), groups)
        out[f"{i}:{kind}"] = jax.vmap(
            lambda k: block_init(k, cfg, kind, cross=cross))(keys)
    return out


def _stack_apply(blocks: dict, cfg: ModelConfig, pattern, x, positions, *,
                 mode="train", cache=None, pos=None, enc_out=None,
                 paged=None):
    """Scan the block-pattern groups.  cache leaves are stacked (G, ...)."""
    has_cache = cache is not None

    def group_fn(x, xs):
        params_g, cache_g = xs
        params_g = sharding_constrain_params(
            params_g,
            int8_gather=cfg.fsdp_int8_gather and mode == "train")
        if cfg.save_gathered_weights and mode == "train":
            params_g = jax.tree.map(
                lambda p: checkpoint_name(p, "gathered"),
                params_g)
        new_cache_g = {}
        auxs = {"load_balance": jnp.zeros((), jnp.float32),
                "dropped_frac": jnp.zeros((), jnp.float32)}
        for i, kind in enumerate(pattern):
            key = f"{i}:{kind}"
            c = cache_g.get(key) if has_cache else None
            x, nc, aux = block_apply(
                params_g[key], cfg, kind, x, positions,
                mode=mode, cache=c, pos=pos, enc_out=enc_out, paged=paged)
            if has_cache:
                new_cache_g[key] = nc
            for k, v in aux.items():
                auxs[k] = auxs[k] + v
        return x, (new_cache_g, auxs)

    policy = (jax.checkpoint_policies.save_only_these_names("gathered")
              if cfg.save_gathered_weights else None)
    if cfg.remat_policy == "dots" and policy is None:
        policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    fn = (jax.checkpoint(group_fn, policy=policy)
          if (cfg.remat and mode == "train") else group_fn)

    if cfg.scan_layers:
        xs = (blocks, cache if has_cache else {})
        x, (new_cache, auxs) = jax.lax.scan(fn, x, xs)
        aux = {k: jnp.sum(v) for k, v in auxs.items()}
        return x, (new_cache if has_cache else None), aux
    # unscanned fallback (debugging / perf comparison)
    new_cache = cache
    total_aux = {"load_balance": 0.0, "dropped_frac": 0.0}
    for g in range(_stack_len(blocks)):
        params_g = jax.tree.map(lambda a: a[g], blocks)
        cache_g = (jax.tree.map(lambda a: a[g], cache) if has_cache else {})
        x, (ncg, auxs) = fn(x, (params_g, cache_g))
        if has_cache:
            new_cache = jax.tree.map(lambda full, one: full.at[g].set(one),
                                     new_cache, ncg)
        for k, v in auxs.items():
            total_aux[k] = total_aux[k] + v
    return x, (new_cache if has_cache else None), total_aux


def _stack_len(blocks: dict) -> int:
    return jax.tree.leaves(blocks)[0].shape[0]


# ----------------------------------------------------------------- model
def init_params(key, cfg: ModelConfig) -> dict:
    ks = jax.random.split(key, 6)
    d = cfg.d_model
    params = {
        "embedding": common.truncated_normal(ks[0], (cfg.vocab_size, d), 1.0),
        "final_norm": common.norm_init(d, cfg.norm),
        "blocks": _stack_init(ks[1], cfg, cfg.block_pattern, cfg.num_groups,
                              cross=cfg.is_encdec),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = common.linear_init(ks[2], d, cfg.vocab_size, cfg,
                                               cfg.quant)
    if cfg.is_encdec:
        params["encoder"] = {
            "blocks": _stack_init(ks[3], cfg, ("attn",), cfg.encoder_layers),
            "final_norm": common.norm_init(d, cfg.norm),
        }
        params["pos_embedding"] = common.truncated_normal(
            ks[4], (cfg.max_seq_len, d), 0.02)
    return params


def _sinusoidal(S: int, d: int) -> jnp.ndarray:
    pos = jnp.arange(S)[:, None].astype(jnp.float32)
    div = jnp.exp(jnp.arange(0, d, 2) * (-jnp.log(10000.0) / (d // 2 - 1)))
    pe = jnp.zeros((S, d))
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div))
    return pe


def embed_inputs(params, cfg: ModelConfig, tokens, *, patch_embeds=None):
    """tokens (B, S_text); vlm: patch embeds are prepended (stub frontend)."""
    x = jnp.take(params["embedding"], tokens, axis=0)
    if cfg.embed_scale:
        x = x * cfg.d_model**0.5
    if patch_embeds is not None:
        x = jnp.concatenate([patch_embeds.astype(x.dtype), x], axis=1)
    return constrain(x.astype(jnp.dtype(cfg.dtype)), "batch", "seq", "embed")


def encode(params, cfg: ModelConfig, frames) -> jnp.ndarray:
    """Encoder for enc-dec models; frames (B, S_src, d) from the stub
    frontend, sinusoidal positions (length-safe at 32k)."""
    x = frames.astype(jnp.dtype(cfg.dtype))
    x = x + _sinusoidal(x.shape[1], cfg.d_model).astype(x.dtype)
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    enc = params["encoder"]
    x, _, _ = _stack_apply(enc["blocks"], cfg, ("attn",), x, positions,
                           mode="encode")
    return common.norm_apply(enc["final_norm"], x, cfg.norm,
                             rms_offset=cfg.rms_offset)


def logits_from_hidden(params, cfg: ModelConfig, x) -> jnp.ndarray:
    x = common.norm_apply(params["final_norm"], x, cfg.norm,
                          rms_offset=cfg.rms_offset)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x.astype(jnp.float32),
                            params["embedding"].astype(jnp.float32))
    else:
        logits = common.linear_apply(params["lm_head"], x, cfg.quant,
                                     in_dim=cfg.d_model, tag="lm_head").astype(jnp.float32)
    logits = common.softcap(logits, cfg.final_logit_softcap)
    return constrain(logits, "batch", "seq", "vocab")


def forward(params, cfg: ModelConfig, batch: dict, *, mode="train"):
    """Full-sequence forward.  batch: tokens (+frames / +patch_embeds).

    Returns (logits, aux)."""
    enc_out = None
    if cfg.is_encdec:
        enc_out = encode(params, cfg, batch["frames"])
    x = embed_inputs(params, cfg, batch["tokens"],
                     patch_embeds=batch.get("patch_embeds"))
    if cfg.is_encdec:
        S = x.shape[1]
        x = x + params["pos_embedding"][:S].astype(x.dtype)
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    x, _, aux = _stack_apply(params["blocks"], cfg, cfg.block_pattern, x,
                             positions, mode=mode, enc_out=enc_out)
    return logits_from_hidden(params, cfg, x), aux


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.float32) -> dict:
    """Stacked (G, ...) cache pytree for decode."""
    out = {}
    for i, kind in enumerate(cfg.block_pattern):
        one = block_cache(cfg, kind, batch, max_len, dtype)
        out[f"{i}:{kind}"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (cfg.num_groups, *a.shape)).copy(),
            one)
    return out


def prefill(params, cfg: ModelConfig, batch: dict, cache: dict):
    """Run the prompt through the model, filling the cache.

    Returns (logits_last (B, V), cache)."""
    enc_out = encode(params, cfg, batch["frames"]) if cfg.is_encdec else None
    x = embed_inputs(params, cfg, batch["tokens"],
                     patch_embeds=batch.get("patch_embeds"))
    if cfg.is_encdec:
        x = x + params["pos_embedding"][: x.shape[1]].astype(x.dtype)
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    x, cache, _ = _stack_apply(params["blocks"], cfg, cfg.block_pattern, x,
                               positions, mode="prefill", cache=cache,
                               enc_out=enc_out)
    logits = logits_from_hidden(params, cfg, x[:, -1:, :])
    return logits[:, 0], cache


def init_paged_cache(cfg: ModelConfig, num_blocks: int, block_size: int,
                     dtype=jnp.float32, *, kv_spec=None) -> dict:
    """Stacked (G, num_blocks, bs, Hk, Dh) KV block pool for paged serving.

    One shared pool per layer group: sequences own disjoint block subsets
    via host-side block tables (serving/kv_blocks.py), so the (batch,
    max_len) dense cache footprint becomes (blocks actually in use).
    ``kv_spec`` (default ``cfg.kv_quant``) switches the pool tensors to
    the quantized codes+scales layout of repro.kvq.pool — same block/slot
    indexing, 2–4x+ fewer bytes per token.  Attention-free (recurrent)
    block kinds, enc-dec, and modality frontends are not paged — the
    continuous engine rejects them.
    """
    if cfg.is_encdec or cfg.frontend:
        raise NotImplementedError(
            "paged serving supports plain decoder-only models")
    if kv_spec is None:
        kv_spec = cfg.kv_quant
    hk, dh = cfg.num_kv_heads, cfg.head_dim
    out = {}
    for i, kind in enumerate(cfg.block_pattern):
        if kind not in ("attn", "local", "moe"):
            raise NotImplementedError(
                f"paged KV cache for block kind {kind!r}")
        if kv_spec is not None:
            from repro import kvq
            one = kvq.init_kv_pool(kv_spec, num_blocks, block_size, hk, dh)
        else:
            one = {"k": jnp.zeros((num_blocks, block_size, hk, dh), dtype),
                   "v": jnp.zeros((num_blocks, block_size, hk, dh), dtype)}
        out[f"{i}:{kind}"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (cfg.num_groups, *a.shape)).copy(),
            one)
    return out


def forward_paged(params, cfg: ModelConfig, tokens, pool: dict, positions,
                  write_slots, view_slots):
    """One paged serving step — chunked prefill (C > 1) and batched decode
    (C == 1) both lower through this single function, so the two phases
    share all model code with each other and with the dense-cache path.

    tokens/positions/write_slots (B, C); view_slots (B, W) flat pool slots
    covering each row's logical positions 0..W-1 (see layers.attn_paged).

    Returns (logits (B, C, V), new_pool).
    """
    x = embed_inputs(params, cfg, tokens)
    x, pool, _ = _stack_apply(params["blocks"], cfg, cfg.block_pattern, x,
                              positions, mode="paged", cache=pool,
                              paged=(write_slots, view_slots))
    return logits_from_hidden(params, cfg, x), pool


def decode_step(params, cfg: ModelConfig, token, cache: dict, pos):
    """One decode step.  token (B,), pos (B,) current position.

    Returns (logits (B, V), new_cache)."""
    x = embed_inputs(params, cfg, token[:, None])
    if cfg.is_encdec:
        x = x + jnp.take(params["pos_embedding"], pos, axis=0)[:, None].astype(
            x.dtype)
    positions = pos[:, None]
    x, cache, _ = _stack_apply(params["blocks"], cfg, cfg.block_pattern, x,
                               positions, mode="decode", cache=cache, pos=pos)
    logits = logits_from_hidden(params, cfg, x)
    return logits[:, 0], cache
