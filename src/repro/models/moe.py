"""Mixture-of-Experts FFN: top-k routing, capacity-bounded sort-free
dispatch (one-hot cumsum positions + scatter), shared experts, aux loss.

Expert weights are stacked (E, d_ff, d) and shard over the 'expert'
logical axis (EP) when E divides the model axis (llama4 128e, jamba 16e);
otherwise the per-expert ffn dim shards (qwen2-moe 60e -> TP over
mlp=1408).  Tokens cross from the data shards to the expert shards through
the dispatch einsum — GSPMD materializes this as the MoE all-to-all, which
the roofline's collective term picks up.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models import common


def moe_init(key, cfg) -> dict:
    d = cfg.d_model
    mdff = cfg.moe_d_ff or cfg.d_ff
    E = cfg.num_experts
    ks = jax.random.split(key, 3)
    gated = cfg.mlp_activation in ("swiglu", "geglu")

    def stack_init(k, in_dim, out_dim):
        keys = jax.random.split(k, E)
        return jax.vmap(
            lambda kk: common.linear_init(kk, in_dim, out_dim, cfg, cfg.quant)
        )(keys)

    p = {
        "router": {"w": common.truncated_normal(ks[0], (E, d), d**-0.5)},
        "experts": {
            "up": stack_init(jax.random.fold_in(ks[1], 0), d, mdff),
            "down": stack_init(jax.random.fold_in(ks[1], 1), mdff, d),
        },
    }
    if gated:
        p["experts"]["gate"] = stack_init(jax.random.fold_in(ks[1], 2), d, mdff)
    if cfg.num_shared_experts:
        sdff = cfg.shared_expert_d_ff or cfg.num_shared_experts * mdff
        p["shared"] = common.mlp_init(ks[2], cfg, sdff)
    return p


def _expert_ffn(pe: dict, x: jnp.ndarray, cfg) -> jnp.ndarray:
    """x (E, C, d) -> (E, C, d) via per-expert (batched) QuantizedLinears.

    Per-layer quant policy: experts run int4_dequant even in msgemm mode —
    per-expert output dims (m = moe_d_ff) are below 16^d, so the LUT
    produce phase cannot amortize (paper Eq. 15 / DESIGN.md §5), and each
    expert would need its own LUT over its routed activations.
    """
    import dataclasses

    q = cfg.quant
    if q.mode == "msgemm":
        q = dataclasses.replace(q, mode="int4_dequant")
    def apply_e(tag, act="none"):
        # 'moe_'-prefixed tags keep expert input stats separate from the
        # dense MLPs' in the calibration collector; the activation rides
        # the linear's epilogue (fused on kernel backends)
        return jax.vmap(lambda p, xx: common.linear_apply(
            p, xx, q, in_dim=xx.shape[-1], tag=f"moe_{tag}", act=act))

    act_name = {"swiglu": "silu", "geglu": "gelu",
                "gelu": "gelu"}[cfg.mlp_activation]
    if "gate" in pe:
        up = apply_e("up")(pe["up"], x)
        h = apply_e("gate", act_name)(pe["gate"], x) * up
    else:
        h = apply_e("up", act_name)(pe["up"], x)
    h = constrain(h, "expert", "capacity", "expert_out")
    return apply_e("down")(pe["down"], h)


def moe_apply(p: dict, x: jnp.ndarray, cfg, *, capacity: int | None = None):
    """x (B, S, d) -> (y (B, S, d), aux_metrics dict).

    Switch-style capacity dispatch, *grouped by token shards*: tokens are
    split into G contiguous groups (G = cfg.moe_groups, matched to the
    data-parallel degree) and each group scatters into its own per-group
    capacity slots.  The scatter/gather then vmaps over G, so GSPMD keeps
    every dispatch operand sharded over the batch axis — an ungrouped
    global scatter gets replicated by the partitioner (2.5 GB/device
    operands at llama4 scale; see EXPERIMENTS.md §Perf).  Experts see a
    (E, G*Cg, d) batch; tokens past their group's capacity are dropped
    (residual passes through) — standard Switch semantics.
    """
    B, S, d = x.shape
    E, K = cfg.num_experts, cfg.num_experts_per_tok
    # One dispatch group per example: B stays the (sharded) major dim and
    # S is never merged with it, so every dispatch tensor keeps a
    # GSPMD-representable sharding even when seq itself is model-sharded
    # (llama4's sequence-parallel fallback).  Flattening (B,S,d)->(B*S,d)
    # with seq sharded forces full replication (2.5 GB/device operands).
    logits = jnp.einsum("bsd,ed->bse", x.astype(jnp.float32),
                        p["router"]["w"])
    gates, eidx = jax.lax.top_k(logits, K)  # (B, S, K)
    gates = jax.nn.softmax(gates, axis=-1)

    if capacity is None:
        capacity = max(int(S * K / E * cfg.capacity_factor), 4)
    C = capacity  # capacity per example

    # position-in-expert via cumsum over each example's (S*K) slots
    oh = jax.nn.one_hot(eidx.reshape(B, S * K), E, dtype=jnp.int32)
    pos = jnp.cumsum(oh, axis=1) - 1  # (B, S*K, E)
    pos = jnp.sum(pos * oh, axis=-1)  # (B, S*K)
    keep = pos < C
    dest = jnp.where(keep, eidx.reshape(B, S * K) * C + pos, E * C)

    xr = jnp.repeat(x, K, axis=1) if K > 1 else x  # (B, S*K, d)

    def example_scatter(dest_b, x_b):
        buf = jnp.zeros((E * C + 1, d), x_b.dtype)
        return buf.at[dest_b].set(x_b, mode="drop")[:-1]

    bufs = jax.vmap(example_scatter)(dest, xr)  # (B, E*C, d)
    dispatched = (bufs.reshape(B, E, C, d).transpose(1, 0, 2, 3)
                  .reshape(E, B * C, d))
    dispatched = constrain(dispatched, "expert", "capacity", "expert_in")

    out = _expert_ffn(p["experts"], dispatched, cfg)  # (E, B*C, d)
    out = (out.reshape(E, B, C, d).transpose(1, 0, 2, 3)
           .reshape(B, E * C, d))
    # Pin the expert-slot dim replicated before the per-example combine:
    # the concat(+sentinel row)+take pair below is not partitionable
    # along E*C, and letting the expert sharding flow into it makes the
    # SPMD partitioner gather from the wrong shards (observed 1e-1
    # output error on an 8-device host mesh — not reassociation noise).
    out = constrain(out, "batch", "none", "none")

    def example_gather(out_b, dest_b):
        padded = jnp.concatenate([out_b, jnp.zeros((1, d), out_b.dtype)], 0)
        return jnp.take(padded, jnp.minimum(dest_b, E * C), axis=0)

    gathered = jax.vmap(example_gather)(out, dest)  # (B, S*K, d)
    gathered = gathered.reshape(B, S, K, d)
    w = (gates * keep.reshape(B, S, K)).astype(gathered.dtype)
    y = jnp.einsum("bskd,bsk->bsd", gathered, w)

    if "shared" in p:
        y = y + common.mlp_apply(p["shared"], x, cfg).astype(y.dtype)

    # Switch aux load-balancing loss terms
    probs = jax.nn.softmax(logits, axis=-1)
    me = jnp.mean(probs, axis=(0, 1))
    ce = jnp.mean(oh.reshape(B, S, K, E).sum(2).astype(jnp.float32),
                  axis=(0, 1))
    aux = {"load_balance": E * jnp.sum(me * ce),
           "dropped_frac": 1.0 - jnp.mean(keep.astype(jnp.float32))}
    return y.astype(x.dtype), aux
