"""Paper §4 complexity model (Eqs. 7-14): closed forms vs instrumented
op-counting, plus memory-access identity M(msGeMM) == M(GeMM) (Eq. 12),
swept over shapes and d."""

from __future__ import annotations

import numpy as np

from repro.core import complexity as C

SWEEP = [
    # (m, k, b, d)
    (8, 8, 1, 1), (16, 16, 2, 2), (32, 24, 1, 2), (64, 12, 3, 2),
    (24, 36, 2, 3),
]


def run() -> list[str]:
    lines = ["name,us_per_call,derived"]
    rng = np.random.default_rng(0)
    for m, k, b, d in SWEEP:
        codes = rng.integers(0, 16, size=(m, k)).astype(np.uint8)
        x = rng.standard_normal((k, b))
        y, cnt = C.counted_msgemm(codes, x, d)
        ok_fma = cnt.fma == C.c_lut(k, d) * b
        ok_add = cnt.add == C.c_consume(m, k, d) * b
        ok_mem = cnt.mem == C.m_msgemm(m, k, b)
        _, gcnt = C.counted_gemm(rng.standard_normal((m, k)), x)
        ok_mem_eq = cnt.mem == gcnt.mem  # Eq. 12: identical memory traffic
        lines.append(
            f"complexity/m{m}k{k}b{b}d{d},0.0,"
            f"eq7={ok_fma} eq9={ok_add} eq12={ok_mem} "
            f"mem_identical={ok_mem_eq} "
            f"total={cnt.total_compute} bound_eq13={C.c_msgemm(m, k, b, d)}")
    # LUT footprint table (drives the kernel's VMEM budget)
    for d in (1, 2, 3, 4):
        lines.append(
            f"complexity/lut_bytes_k12288_b64_d{d},0.0,"
            f"bytes={C.lut_bytes(12288, d, 64)}")
    return lines
