"""Measured CPU wall-time microbenchmarks (honest small-scale numbers):
jnp msGeMM vs dense matmul vs the dequant path, and the Pallas kernels in
interpret mode.  On CPU there is no MXU/VPU split, so these measure the
*algorithm* (instruction mix), not the paper's hardware claim — the
roofline/phase_rates modules carry the TPU-rate analysis."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import lut, packing, scales


def _timeit(fn, *args, warmup=2, iters=5) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6  # us


def run() -> list[str]:
    lines = ["name,us_per_call,derived"]
    rng = np.random.default_rng(0)
    for m, k, b, d in [(512, 384, 8, 2), (1024, 768, 16, 3),
                       (4096, 768, 16, 3)]:
        w = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
        qt = scales.quantize_int4(w, block=12 * d if (12 * d) % d == 0 else 12)
        x = jnp.asarray(rng.standard_normal((k, b)), jnp.float32)

        dense = jax.jit(lambda w, x: w @ x)
        t_dense = _timeit(dense, w, x)

        ms = jax.jit(lambda c, x: lut.msgemm(
            c, x, d, scales=qt.scales, scale_block=qt.block, chunk=8))
        t_ms = _timeit(ms, qt.codes, x)

        dq = jax.jit(lambda c, x: scales.dequantize(
            scales.QuantizedTensor(c, qt.scales, qt.block, (m, k))) @ x)
        t_dq = _timeit(dq, qt.codes, x)

        lines.append(
            f"walltime/msgemm_m{m}k{k}b{b}d{d},{t_ms:.1f},"
            f"dense_us={t_dense:.1f} dequant_us={t_dq:.1f} "
            f"cpu_ratio={t_dense / t_ms:.2f}")
    # produce phase alone (the MXU-friendly reformulation)
    x = jnp.asarray(rng.standard_normal((768, 16)), jnp.float32)
    prod = jax.jit(lambda x: lut.produce(x, 3))
    lines.append(f"walltime/produce_k768_b16_d3,{_timeit(prod, x):.1f},"
                 f"lut_elems={16**3 * 256 * 16}")
    return lines
