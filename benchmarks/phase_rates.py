"""Paper §6 hardware analysis transposed to TPU (DESIGN.md §2).

The paper: produce runs at Tensor-Core rate (312 TF A100), consume at
CUDA-core rate (19.5 TF) -> msGeMM unrealizable without a LUT-add unit.
TPU v5e-class analogue: produce on the MXU (197 TF bf16), consume as
vector gather-adds on the VPU (~4 TF effective).

For each assigned-arch *decode* GeMM (m = output dim, k = input dim) we
report the end-to-end time model under three execution schemes:
  dense-MXU      2·m·k MACs at MXU rate (naive GeMM, Eq. 14)
  msgemm-tpu     produce@MXU + consume@VPU  (current hardware, §6 problem)
  msgemm-lutadd  produce@MXU + consume@MXU-rate (the paper's proposal)
"""

from __future__ import annotations

from benchmarks.roofline import HW
from repro import configs
from repro.core import complexity as C


def gemm_times(m: int, k: int, d: int = 3, b: int = 1):
    fma_rate = HW.peak_flops / 2  # FMA/s; a LUT-add unit does 1 add/slot
    dense = m * k * b / fma_rate
    produce = 16**d * k * b / fma_rate  # d FMAs per entry x 16^d·k/d entries
    consume_ops = (k / d) * m * b
    return {
        "dense_mxu": dense,
        "msgemm_tpu": produce + consume_ops / HW.vpu_flops,
        "msgemm_lutadd": produce + consume_ops / fma_rate,
        "instr_ratio": C.speedup(m, k, b, d),
    }


def run() -> list[str]:
    lines = ["name,us_per_call,derived"]
    for name, (m, k) in {
        "gpt3_mlp2": (49152, 12288),
        "starcoder2_up": (24576, 6144),
        "gemma2b_lmhead": (256000, 2048),
        "llama4_wq": (5120, 5120),
    }.items():
        t = gemm_times(m, k)
        lines.append(
            f"phase_rates/{name},{t['msgemm_tpu'] * 1e6:.2f},"
            f"dense_us={t['dense_mxu'] * 1e6:.2f} "
            f"lutadd_us={t['msgemm_lutadd'] * 1e6:.2f} "
            f"speedup_with_unit={t['dense_mxu'] / t['msgemm_lutadd']:.2f} "
            f"slowdown_without={t['msgemm_tpu'] / t['dense_mxu']:.2f} "
            f"instr_ratio={t['instr_ratio']:.2f}")
    lines.append(
        "phase_rates/conclusion,0.0,"
        "consume-on-VPU dominates without a LUT-add unit — the paper's §6 "
        "argument holds on TPU as well (DESIGN.md §2.B)")
    return lines
