"""Measured end-to-end serving throughput (CPU, small model): batched
prefill+decode generation under the three quantized-linear modes, the
weight-bytes each mode ships, and the continuous-batching engine driven
at several simulated arrival rates.  CPU has no MXU/VPU asymmetry, so
this validates the *plumbing* (identical tokens from the two int4 paths)
and quantifies weight compression; the TPU-rate projections live in
phase_rates/roofline.

The continuous-engine rows are also written machine-readable to
``benchmarks/results/BENCH_serve.json`` (tok/s, p50/p95 latency and TTFT
per arrival rate) so the serving perf trajectory is tracked across PRs.

Run standalone with ``--autotune`` to exercise the dispatch autotuner
end-to-end: the engine resolves and persists shape-keyed ExecPlans to
``benchmarks/results/autotune_cache.json`` at build, and a second engine
build asserts every plan is served from the reloaded cache (no
re-timing).
"""

from __future__ import annotations

import json
import time
from pathlib import Path

import jax

from repro import dispatch, obs
from repro.core.spec import QuantSpec
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.quant import quantize_model
from repro.quant.quantize import quantized_size_bytes
from repro.runtime import serve as SV

RESULTS_JSON = Path(__file__).parent / "results" / "BENCH_serve.json"
AUTOTUNE_CACHE = Path(__file__).parent / "results" / "autotune_cache.json"
SHARD_JSON = Path(__file__).parent / "results" / "BENCH_shard.json"

# BENCH_serve.json / BENCH_shard.json schema history:
#   (unversioned) — PR 2-5: tok/s + latency/TTFT percentiles per run
#   2 — PR 6: adds schema_version; per run preemptions/evicted_blocks/
#       admitted + intertoken percentiles (engine.metrics()), and a
#       "queue_depth" block sampled each scheduler step via the obs
#       registry
#   3 — quantized KV cache (repro.kvq): every continuous run gains
#       kv_bits / kv_bytes_per_token / kv_pool_bytes / max_resident_seqs,
#       the arrival-rate sweep also sweeps kv_bits {16, 8, 4}, and a new
#       "capacity" block measures max resident sequences before first
#       preemption at a FIXED pool-byte budget per kv_bits
BENCH_SERVE_SCHEMA = 3

CFG = ModelConfig(num_layers=4, d_model=256, num_heads=8, num_kv_heads=4,
                  d_ff=1024, vocab_size=8192, max_seq_len=512)


def _bench(params, cfg, batch, new_tokens=16):
    gen = jax.jit(lambda p, b: SV.generate(p, cfg, b,
                                           max_new_tokens=new_tokens,
                                           max_len=64))
    out = gen(params, batch)
    out.block_until_ready()  # compile
    t0 = time.perf_counter()
    out = gen(params, batch)
    out.block_until_ready()
    dt = time.perf_counter() - t0
    return batch["tokens"].shape[0] * new_tokens / dt, out


def run() -> list[str]:
    lines = ["name,us_per_call,derived"]
    key = jax.random.PRNGKey(0)
    params = T.init_params(key, CFG)
    outs = {}
    for mode, d in (("bf16", 3), ("int4_dequant", 3), ("msgemm", 3),
                    ("msgemm", "adaptive")):
        if mode == "bf16":
            p, c = params, CFG
        else:
            qc = QuantSpec(mode=mode, d=d)
            p = quantize_model(params, CFG, qc)
            c = CFG.replace(quant=qc)
        for bsz in (1, 8):
            batch = {"tokens": jax.random.randint(key, (bsz, 16), 0,
                                                  CFG.vocab_size)}
            tps, out = _bench(p, c, batch)
            tag = f"{mode}{'' if d == 3 else '_dadapt'}"
            outs.setdefault(tag, {})[bsz] = out
            lines.append(
                f"serve_throughput/{tag}/b{bsz},{1e6 / tps:.1f},"
                f"tok_per_s={tps:.1f} "
                f"weight_mib={quantized_size_bytes(p) / 2**20:.2f}")
    same = bool((outs["int4_dequant"][8] == outs["msgemm"][8]).mean() > 0.9)
    lines.append(f"serve_throughput/int4_vs_msgemm_tokens_match,0.0,{same}")
    lines += _continuous(params)
    return lines


def _queue_depth() -> dict:
    """Per-step queue-depth distribution for the run just measured.
    ``Engine.reset_metrics()`` clears the ``serving_*`` registry prefix,
    so the histogram holds exactly the measured run's samples."""
    for h in obs.registry().series("histogram"):
        if h.name == "serving_queue_depth_samples":
            return {"samples": h.count,
                    "mean": h.sum / h.count if h.count else 0.0,
                    "max": h.max if h.count else 0.0,
                    "p50": h.percentile(50) or 0.0,
                    "p95": h.percentile(95) or 0.0}
    return {"samples": 0, "mean": 0.0, "max": 0.0, "p50": 0.0, "p95": 0.0}


def _kv_spec(kv_bits: int):
    from repro import kvq

    return None if kv_bits == 16 else kvq.KVQuantSpec(bits=kv_bits)


def _kv_fields(eng, kv_bits: int) -> dict:
    """The schema-3 per-run KV columns."""
    from repro import kvq

    spec = eng.cfg.kv_quant
    return {"kv_bits": kv_bits,
            "kv_bytes_per_token": kvq.bytes_per_token(eng.cfg, spec),
            "kv_pool_bytes": kvq.pool_bytes(eng.cfg, eng.pool.num_blocks,
                                            eng.block_size, spec),
            "max_resident_seqs": eng.max_resident_seqs}


def _capacity(params, n=24, prompt=16, new_tokens=8) -> tuple[dict, list]:
    """Max resident sequences before the first preemption at a FIXED
    pool-byte budget, per kv_bits — the headline capacity claim: the
    budget buys 13 full-precision blocks, and the quantized pools spend
    the same bytes on proportionally more blocks (schema 3).

    Every request is prompt+new = 3 blocks; kv16 fits ~4 resident
    sequences, kv4 fits all 24 — asserted >= 2x kv16."""
    from repro import kvq
    from repro.serving import Engine, poisson_stream

    budget = 13 * 8 * kvq.bytes_per_token(CFG, None)  # 13 f32 blocks
    rows = []
    lines = []
    for kv_bits in (16, 8, 4):
        eng = Engine(params, CFG, max_slots=n, block_size=8,
                     prefill_chunk=16, max_model_len=prompt + new_tokens,
                     kv_quant=_kv_spec(kv_bits), kv_pool_bytes=budget)
        eng.run(poisson_stream(n, CFG.vocab_size,
                               max_new_tokens=new_tokens, rate=0.0,
                               min_prompt=prompt, max_prompt=prompt,
                               seed=5))
        s = eng.metrics()
        row = {"requests": n, "pool_blocks": eng.pool.num_blocks,
               "preemptions": s["preemptions"],
               "tok_per_s": s["tok_per_s"], **_kv_fields(eng, kv_bits)}
        rows.append(row)
        lines.append(
            f"serve_throughput/capacity/kv{kv_bits},0.0,"
            f"max_resident={row['max_resident_seqs']} "
            f"blocks={row['pool_blocks']} "
            f"bytes_per_token={row['kv_bytes_per_token']} "
            f"preemptions={row['preemptions']}")
    by_bits = {r["kv_bits"]: r for r in rows}
    ratio = (by_bits[4]["max_resident_seqs"]
             / max(1, by_bits[16]["max_resident_seqs"]))
    if ratio < 2.0:
        raise SystemExit(
            f"kv4 resident-sequence multiplier {ratio:.2f}x vs kv16 at "
            f"equal pool bytes — expected >= 2x")
    cap = {"pool_byte_budget": budget, "prompt_tokens": prompt,
           "new_tokens": new_tokens, "kv4_resident_multiplier": ratio,
           "runs": rows}
    lines.append(f"serve_throughput/capacity/kv4_multiplier,0.0,"
                 f"{ratio:.2f}x")
    return cap, lines


def _continuous(params, rates=(0.0, 100.0, 25.0), n=10, new_tokens=10
                ) -> list[str]:
    """Continuous-batching engine at several simulated arrival rates
    (rate 0 = closed batch: everything queued at t=0), with the msgemm
    weights additionally swept over kv_bits {16, 8, 4} (schema 3).  A
    warmup stream triggers both jit compiles (prefill + decode shapes)
    per engine before the measured run, so the JSON tracks serving
    throughput, not XLA compile time."""
    from repro.serving import Engine, poisson_stream

    runs = []
    lines = []
    qc = QuantSpec(mode="msgemm", d=3)
    variants = [("bf16", params, CFG, 16)]
    mp, mc = quantize_model(params, CFG, qc), CFG.replace(quant=qc)
    variants += [("msgemm", mp, mc, kv_bits) for kv_bits in (16, 8, 4)]
    for mode, p, c, kv_bits in variants:
        for rate in rates:
            eng = Engine(p, c, max_slots=4, block_size=8, prefill_chunk=16,
                         max_model_len=48, kv_quant=_kv_spec(kv_bits))
            eng.run(poisson_stream(2, c.vocab_size, max_new_tokens=2,
                                   seed=1))  # warmup: compile both shapes
            eng.reset_metrics()
            eng.run(poisson_stream(n, c.vocab_size,
                                   max_new_tokens=new_tokens, rate=rate))
            s = eng.metrics()
            qd = _queue_depth()
            run = {"mode": mode, "arrival_rate": rate, "requests": n,
                   "new_tokens": new_tokens, "queue_depth": qd,
                   **_kv_fields(eng, kv_bits), **s}
            runs.append(run)
            tag = f"continuous/{mode}/kv{kv_bits}/rate{rate:g}"
            lines.append(
                f"serve_throughput/{tag},{1e6 / s['tok_per_s']:.1f},"
                f"tok_per_s={s['tok_per_s']:.1f} "
                f"p50_ms={(s['latency_p50_s'] or 0.0) * 1e3:.1f} "
                f"p95_ms={(s['latency_p95_s'] or 0.0) * 1e3:.1f} "
                f"ttft_p50_ms={(s['ttft_p50_s'] or 0.0) * 1e3:.1f} "
                f"preemptions={s['preemptions']} "
                f"evicted_blocks={s['evicted_blocks']} "
                f"queue_p95={qd['p95']:g}")
    capacity, cap_lines = _capacity(params)
    lines += cap_lines
    RESULTS_JSON.parent.mkdir(parents=True, exist_ok=True)
    RESULTS_JSON.write_text(json.dumps(
        {"bench": "serve_continuous", "schema_version": BENCH_SERVE_SCHEMA,
         "engine": {"max_slots": 4, "block_size": 8, "prefill_chunk": 16},
         "model": {"layers": CFG.num_layers, "d_model": CFG.d_model},
         "runs": runs, "capacity": capacity}, indent=2))
    lines.append(f"serve_throughput/continuous/json,0.0,{RESULTS_JSON}")
    return lines


def run_autotune(cache_path=None) -> list[str]:
    """--autotune: drive the continuous engine with build-time plan
    autotuning, writing the persistent cache, then rebuild and assert the
    cache is reused (zero candidates re-timed)."""
    from repro.dispatch import autotune as at
    from repro.serving import Engine, poisson_stream

    cache_path = Path(cache_path or AUTOTUNE_CACHE)
    cache_path.parent.mkdir(parents=True, exist_ok=True)
    if cache_path.exists():
        cache_path.unlink()  # measure a cold write -> warm reload cycle

    key = jax.random.PRNGKey(0)
    params = T.init_params(key, CFG)
    spec = QuantSpec(mode="msgemm", d=3)
    p, c = quantize_model(params, CFG, spec), CFG.replace(quant=spec)

    def build_and_run():
        eng = Engine(p, c, max_slots=4, block_size=8, prefill_chunk=16,
                     max_model_len=48, autotune=True,
                     autotune_cache=cache_path)
        res = eng.run(poisson_stream(4, c.vocab_size, max_new_tokens=4,
                                     seed=7))
        toks = {rid: seq.generated for rid, seq in res.items()}
        return eng, toks

    at.num_timed_candidates = 0
    eng1, toks1 = build_and_run()
    timed = at.num_timed_candidates
    n_plans = len(eng1.exec_plans)
    assert cache_path.exists() and n_plans, "autotune wrote no plans"

    at.num_timed_candidates = 0
    dispatch.set_cache_path(cache_path)  # fresh in-memory view of the file
    eng2, toks2 = build_and_run()
    assert at.num_timed_candidates == 0, \
        f"warm rebuild re-timed {at.num_timed_candidates} candidates"
    assert toks1 == toks2, "autotuned plans changed generated tokens"

    lines = ["name,us_per_call,derived",
             f"serve_throughput/autotune/cold,0.0,"
             f"plans={n_plans} candidates_timed={timed}",
             f"serve_throughput/autotune/warm,0.0,"
             f"plans={len(eng2.exec_plans)} candidates_timed=0 "
             f"tokens_identical=True",
             f"serve_throughput/autotune/json,0.0,{cache_path}"]
    return lines


def run_mesh_sweep(meshes: list[str], n=8, new_tokens=8,
                   trace_out=None) -> list[str]:
    """--mesh sweep: drive the continuous engine tensor-parallel over
    each requested mesh ('model=4,data=2' strings), assert the sharded
    engine's greedy tokens are identical to the single-device baseline,
    and write throughput + plan stats to BENCH_shard.json.

    With ``trace_out`` the whole sweep is traced: the Chrome-trace file
    attributes sharded step time to per-shard compute vs contraction
    collectives (shard.compute.* / shard.collective.* spans)."""
    from repro.launch.mesh import mesh_devices
    from repro.launch.serve import parse_mesh
    from repro.serving import Engine, poisson_stream

    if trace_out:
        # must precede engine builds: jit marks are staged at trace time
        obs.enable_tracing(clear=True)

    key = jax.random.PRNGKey(0)
    params = T.init_params(key, CFG)
    # d=2 / scale_block=8 keeps the packed storage shard-aligned at every
    # k_local this sweep produces, so row-parallel (k-sharded + psum)
    # plans actually form — with d=3 the d-chunk alignment guard rejects
    # them all and the sweep would only ever exercise column-parallel
    spec = QuantSpec(mode="msgemm", d=2, scale_block=8)
    p, c = quantize_model(params, CFG, spec), CFG.replace(quant=spec)
    eng_kw = dict(max_slots=4, block_size=8, prefill_chunk=16,
                  max_model_len=48)
    stream = lambda: poisson_stream(n, c.vocab_size,
                                    max_new_tokens=new_tokens, rate=0.0,
                                    seed=3)

    def drive(mesh):
        eng = Engine(p, c, **eng_kw, mesh=mesh)
        eng.run(poisson_stream(2, c.vocab_size, max_new_tokens=2, seed=1))
        eng.reset_metrics()
        res = eng.run(stream())
        toks = {rid: seq.generated for rid, seq in res.items()}
        return eng, toks, {**eng.summary(), "queue_depth": _queue_depth()}

    _, base_toks, base_s = drive(None)
    lines = ["name,us_per_call,derived",
             f"serve_throughput/shard/baseline,"
             f"{1e6 / base_s['tok_per_s']:.1f},"
             f"tok_per_s={base_s['tok_per_s']:.1f}"]
    runs = []
    for mesh_str in meshes:
        mesh = parse_mesh(mesh_str)
        eng, toks, s = drive(mesh)
        identical = toks == base_toks
        n_sharded = sum(1 for pl in eng.exec_plans.values()
                        if pl.shard is not None)
        runs.append({"mesh": mesh_str, "devices": mesh_devices(mesh),
                     "tokens_identical": identical,
                     "plans": len(eng.exec_plans),
                     "sharded_plans": n_sharded, **s})
        lines.append(
            f"serve_throughput/shard/{mesh_str},"
            f"{1e6 / s['tok_per_s']:.1f},"
            f"tok_per_s={s['tok_per_s']:.1f} sharded_plans={n_sharded} "
            f"tokens_identical={identical}")
        if not identical:
            raise SystemExit(
                f"sharded engine on mesh {mesh_str} diverged from the "
                "single-device baseline")
    SHARD_JSON.parent.mkdir(parents=True, exist_ok=True)
    SHARD_JSON.write_text(json.dumps(
        {"bench": "serve_shard", "schema_version": BENCH_SERVE_SCHEMA,
         "engine": eng_kw,
         "model": {"layers": CFG.num_layers, "d_model": CFG.d_model},
         "requests": n, "new_tokens": new_tokens,
         "baseline": base_s, "runs": runs}, indent=2))
    lines.append(f"serve_throughput/shard/json,0.0,{SHARD_JSON}")
    if trace_out:
        jax.effects_barrier()  # flush pending jit-mark callbacks
        obs.tracer().save(trace_out)
        obs.disable_tracing()
        lines.append(f"serve_throughput/shard/trace,0.0,{trace_out}")
    return lines


def main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--autotune", action="store_true",
                    help="exercise build-time plan autotuning + the "
                         "persistent cache write->reload cycle")
    ap.add_argument("--cache", default=None,
                    help=f"plan-cache path (default {AUTOTUNE_CACHE})")
    ap.add_argument("--mesh", action="append", default=None,
                    help="mesh sweep entry, e.g. 'model=4,data=2' "
                         "(repeatable); emits BENCH_shard.json")
    ap.add_argument("--trace-out", default=None,
                    help="with --mesh: write a Chrome-trace JSON of the "
                         "sweep (compute vs collective attribution)")
    ap.add_argument("--force-host-devices", type=int, default=0,
                    help="fake N host CPU devices (must be set before "
                         "jax touches the backend)")
    args = ap.parse_args(argv)
    from repro.launch.mesh import force_host_devices

    force_host_devices(args.force_host_devices)
    if args.mesh:
        lines = run_mesh_sweep(args.mesh, trace_out=args.trace_out)
    elif args.autotune:
        lines = run_autotune(args.cache)
    else:
        lines = run()
    print("\n".join(lines))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
