"""Measured end-to-end serving throughput (CPU, small model): batched
prefill+decode generation under the three quantized-linear modes, and the
weight-bytes each mode ships.  CPU has no MXU/VPU asymmetry, so this
validates the *plumbing* (identical tokens from the two int4 paths) and
quantifies weight compression; the TPU-rate projections live in
phase_rates/roofline."""

from __future__ import annotations

import time

import jax

from repro.core.linear import QuantConfig
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.quant import quantize_model
from repro.quant.quantize import quantized_size_bytes
from repro.runtime import serve as SV

CFG = ModelConfig(num_layers=4, d_model=256, num_heads=8, num_kv_heads=4,
                  d_ff=1024, vocab_size=8192, max_seq_len=512)


def _bench(params, cfg, batch, new_tokens=16):
    gen = jax.jit(lambda p, b: SV.generate(p, cfg, b,
                                           max_new_tokens=new_tokens,
                                           max_len=64))
    out = gen(params, batch)
    out.block_until_ready()  # compile
    t0 = time.perf_counter()
    out = gen(params, batch)
    out.block_until_ready()
    dt = time.perf_counter() - t0
    return batch["tokens"].shape[0] * new_tokens / dt, out


def run() -> list[str]:
    lines = ["name,us_per_call,derived"]
    key = jax.random.PRNGKey(0)
    params = T.init_params(key, CFG)
    outs = {}
    for mode, d in (("bf16", 3), ("int4_dequant", 3), ("msgemm", 3),
                    ("msgemm", "adaptive")):
        if mode == "bf16":
            p, c = params, CFG
        else:
            qc = QuantConfig(mode=mode, d=d)
            p = quantize_model(params, CFG, qc)
            c = CFG.replace(quant=qc)
        for bsz in (1, 8):
            batch = {"tokens": jax.random.randint(key, (bsz, 16), 0,
                                                  CFG.vocab_size)}
            tps, out = _bench(p, c, batch)
            tag = f"{mode}{'' if d == 3 else '_dadapt'}"
            outs.setdefault(tag, {})[bsz] = out
            lines.append(
                f"serve_throughput/{tag}/b{bsz},{1e6 / tps:.1f},"
                f"tok_per_s={tps:.1f} "
                f"weight_mib={quantized_size_bytes(p) / 2**20:.2f}")
    same = bool((outs["int4_dequant"][8] == outs["msgemm"][8]).mean() > 0.9)
    lines.append(f"serve_throughput/int4_vs_msgemm_tokens_match,0.0,{same}")
    return lines
