"""Measured end-to-end serving throughput (CPU, small model): batched
prefill+decode generation under the three quantized-linear modes, the
weight-bytes each mode ships, and the continuous-batching engine driven
at several simulated arrival rates.  CPU has no MXU/VPU asymmetry, so
this validates the *plumbing* (identical tokens from the two int4 paths)
and quantifies weight compression; the TPU-rate projections live in
phase_rates/roofline.

The continuous-engine rows are also written machine-readable to
``benchmarks/results/BENCH_serve.json`` (tok/s, p50/p95 latency and TTFT
per arrival rate) so the serving perf trajectory is tracked across PRs.

Run standalone with ``--autotune`` to exercise the dispatch autotuner
end-to-end: the engine resolves and persists shape-keyed ExecPlans to
``benchmarks/results/autotune_cache.json`` at build, and a second engine
build asserts every plan is served from the reloaded cache (no
re-timing).
"""

from __future__ import annotations

import json
import os
import time
from pathlib import Path

import jax

from repro import dispatch, obs
from repro.core.spec import QuantSpec
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.quant import quantize_model
from repro.quant.quantize import quantized_size_bytes
from repro.runtime import serve as SV

RESULTS_JSON = Path(__file__).parent / "results" / "BENCH_serve.json"
AUTOTUNE_CACHE = Path(__file__).parent / "results" / "autotune_cache.json"
SHARD_JSON = Path(__file__).parent / "results" / "BENCH_shard.json"

# BENCH_serve.json / BENCH_shard.json schema history:
#   (unversioned) — PR 2-5: tok/s + latency/TTFT percentiles per run
#   2 — PR 6: adds schema_version; per run preemptions/evicted_blocks/
#       admitted + intertoken percentiles (engine.metrics()), and a
#       "queue_depth" block sampled each scheduler step via the obs
#       registry
#   3 — quantized KV cache (repro.kvq): every continuous run gains
#       kv_bits / kv_bytes_per_token / kv_pool_bytes / max_resident_seqs,
#       the arrival-rate sweep also sweeps kv_bits {16, 8, 4}, and a new
#       "capacity" block measures max resident sequences before first
#       preemption at a FIXED pool-byte budget per kv_bits
#   4 — pipelined collectives: each mesh-sweep mesh now runs a one_shot
#       AND a pipelined (chunked contraction + ring collective) variant
#       (new shard_pipeline / shard_impl columns), every variant carries
#       an "overlap" block computed from the run's shard.compute.* vs
#       shard.collective.* trace spans (fraction of collective time
#       covered by compute), and a "per_device_baselines" block records
#       the single-device engine at EQUAL PER-DEVICE batch (max_slots /
#       data-axis size) — the bar the CI --gate compares mesh throughput
#       against
BENCH_SERVE_SCHEMA = 4

CFG = ModelConfig(num_layers=4, d_model=256, num_heads=8, num_kv_heads=4,
                  d_ff=1024, vocab_size=8192, max_seq_len=512)


def _bench(params, cfg, batch, new_tokens=16):
    gen = jax.jit(lambda p, b: SV.generate(p, cfg, b,
                                           max_new_tokens=new_tokens,
                                           max_len=64))
    out = gen(params, batch)
    out.block_until_ready()  # compile
    t0 = time.perf_counter()
    out = gen(params, batch)
    out.block_until_ready()
    dt = time.perf_counter() - t0
    return batch["tokens"].shape[0] * new_tokens / dt, out


def run() -> list[str]:
    lines = ["name,us_per_call,derived"]
    key = jax.random.PRNGKey(0)
    params = T.init_params(key, CFG)
    outs = {}
    for mode, d in (("bf16", 3), ("int4_dequant", 3), ("msgemm", 3),
                    ("msgemm", "adaptive")):
        if mode == "bf16":
            p, c = params, CFG
        else:
            qc = QuantSpec(mode=mode, d=d)
            p = quantize_model(params, CFG, qc)
            c = CFG.replace(quant=qc)
        for bsz in (1, 8):
            batch = {"tokens": jax.random.randint(key, (bsz, 16), 0,
                                                  CFG.vocab_size)}
            tps, out = _bench(p, c, batch)
            tag = f"{mode}{'' if d == 3 else '_dadapt'}"
            outs.setdefault(tag, {})[bsz] = out
            lines.append(
                f"serve_throughput/{tag}/b{bsz},{1e6 / tps:.1f},"
                f"tok_per_s={tps:.1f} "
                f"weight_mib={quantized_size_bytes(p) / 2**20:.2f}")
    same = bool((outs["int4_dequant"][8] == outs["msgemm"][8]).mean() > 0.9)
    lines.append(f"serve_throughput/int4_vs_msgemm_tokens_match,0.0,{same}")
    lines += _continuous(params)
    return lines


def _queue_depth() -> dict:
    """Per-step queue-depth distribution for the run just measured.
    ``Engine.reset_metrics()`` clears the ``serving_*`` registry prefix,
    so the histogram holds exactly the measured run's samples."""
    for h in obs.registry().series("histogram"):
        if h.name == "serving_queue_depth_samples":
            return {"samples": h.count,
                    "mean": h.sum / h.count if h.count else 0.0,
                    "max": h.max if h.count else 0.0,
                    "p50": h.percentile(50) or 0.0,
                    "p95": h.percentile(95) or 0.0}
    return {"samples": 0, "mean": 0.0, "max": 0.0, "p50": 0.0, "p95": 0.0}


def _kv_spec(kv_bits: int):
    from repro import kvq

    return None if kv_bits == 16 else kvq.KVQuantSpec(bits=kv_bits)


def _kv_fields(eng, kv_bits: int) -> dict:
    """The schema-3 per-run KV columns."""
    from repro import kvq

    spec = eng.cfg.kv_quant
    return {"kv_bits": kv_bits,
            "kv_bytes_per_token": kvq.bytes_per_token(eng.cfg, spec),
            "kv_pool_bytes": kvq.pool_bytes(eng.cfg, eng.pool.num_blocks,
                                            eng.block_size, spec),
            "max_resident_seqs": eng.max_resident_seqs}


def _capacity(params, n=24, prompt=16, new_tokens=8) -> tuple[dict, list]:
    """Max resident sequences before the first preemption at a FIXED
    pool-byte budget, per kv_bits — the headline capacity claim: the
    budget buys 13 full-precision blocks, and the quantized pools spend
    the same bytes on proportionally more blocks (schema 3).

    Every request is prompt+new = 3 blocks; kv16 fits ~4 resident
    sequences, kv4 fits all 24 — asserted >= 2x kv16."""
    from repro import kvq
    from repro.serving import Engine, poisson_stream

    budget = 13 * 8 * kvq.bytes_per_token(CFG, None)  # 13 f32 blocks
    rows = []
    lines = []
    for kv_bits in (16, 8, 4):
        eng = Engine(params, CFG, max_slots=n, block_size=8,
                     prefill_chunk=16, max_model_len=prompt + new_tokens,
                     kv_quant=_kv_spec(kv_bits), kv_pool_bytes=budget)
        eng.run(poisson_stream(n, CFG.vocab_size,
                               max_new_tokens=new_tokens, rate=0.0,
                               min_prompt=prompt, max_prompt=prompt,
                               seed=5))
        s = eng.metrics()
        row = {"requests": n, "pool_blocks": eng.pool.num_blocks,
               "preemptions": s["preemptions"],
               "tok_per_s": s["tok_per_s"], **_kv_fields(eng, kv_bits)}
        rows.append(row)
        lines.append(
            f"serve_throughput/capacity/kv{kv_bits},0.0,"
            f"max_resident={row['max_resident_seqs']} "
            f"blocks={row['pool_blocks']} "
            f"bytes_per_token={row['kv_bytes_per_token']} "
            f"preemptions={row['preemptions']}")
    by_bits = {r["kv_bits"]: r for r in rows}
    ratio = (by_bits[4]["max_resident_seqs"]
             / max(1, by_bits[16]["max_resident_seqs"]))
    if ratio < 2.0:
        raise SystemExit(
            f"kv4 resident-sequence multiplier {ratio:.2f}x vs kv16 at "
            f"equal pool bytes — expected >= 2x")
    cap = {"pool_byte_budget": budget, "prompt_tokens": prompt,
           "new_tokens": new_tokens, "kv4_resident_multiplier": ratio,
           "runs": rows}
    lines.append(f"serve_throughput/capacity/kv4_multiplier,0.0,"
                 f"{ratio:.2f}x")
    return cap, lines


def _continuous(params, rates=(0.0, 100.0, 25.0), n=10, new_tokens=10
                ) -> list[str]:
    """Continuous-batching engine at several simulated arrival rates
    (rate 0 = closed batch: everything queued at t=0), with the msgemm
    weights additionally swept over kv_bits {16, 8, 4} (schema 3).  A
    warmup stream triggers both jit compiles (prefill + decode shapes)
    per engine before the measured run, so the JSON tracks serving
    throughput, not XLA compile time."""
    from repro.serving import Engine, poisson_stream

    runs = []
    lines = []
    qc = QuantSpec(mode="msgemm", d=3)
    variants = [("bf16", params, CFG, 16)]
    mp, mc = quantize_model(params, CFG, qc), CFG.replace(quant=qc)
    variants += [("msgemm", mp, mc, kv_bits) for kv_bits in (16, 8, 4)]
    for mode, p, c, kv_bits in variants:
        for rate in rates:
            eng = Engine(p, c, max_slots=4, block_size=8, prefill_chunk=16,
                         max_model_len=48, kv_quant=_kv_spec(kv_bits))
            eng.run(poisson_stream(2, c.vocab_size, max_new_tokens=2,
                                   seed=1))  # warmup: compile both shapes
            eng.reset_metrics()
            eng.run(poisson_stream(n, c.vocab_size,
                                   max_new_tokens=new_tokens, rate=rate))
            s = eng.metrics()
            qd = _queue_depth()
            run = {"mode": mode, "arrival_rate": rate, "requests": n,
                   "new_tokens": new_tokens, "queue_depth": qd,
                   **_kv_fields(eng, kv_bits), **s}
            runs.append(run)
            tag = f"continuous/{mode}/kv{kv_bits}/rate{rate:g}"
            lines.append(
                f"serve_throughput/{tag},{1e6 / s['tok_per_s']:.1f},"
                f"tok_per_s={s['tok_per_s']:.1f} "
                f"p50_ms={(s['latency_p50_s'] or 0.0) * 1e3:.1f} "
                f"p95_ms={(s['latency_p95_s'] or 0.0) * 1e3:.1f} "
                f"ttft_p50_ms={(s['ttft_p50_s'] or 0.0) * 1e3:.1f} "
                f"preemptions={s['preemptions']} "
                f"evicted_blocks={s['evicted_blocks']} "
                f"queue_p95={qd['p95']:g}")
    capacity, cap_lines = _capacity(params)
    lines += cap_lines
    RESULTS_JSON.parent.mkdir(parents=True, exist_ok=True)
    RESULTS_JSON.write_text(json.dumps(
        {"bench": "serve_continuous", "schema_version": BENCH_SERVE_SCHEMA,
         "engine": {"max_slots": 4, "block_size": 8, "prefill_chunk": 16},
         "model": {"layers": CFG.num_layers, "d_model": CFG.d_model},
         "runs": runs, "capacity": capacity}, indent=2))
    lines.append(f"serve_throughput/continuous/json,0.0,{RESULTS_JSON}")
    return lines


def run_autotune(cache_path=None) -> list[str]:
    """--autotune: drive the continuous engine with build-time plan
    autotuning, writing the persistent cache, then rebuild and assert the
    cache is reused (zero candidates re-timed)."""
    from repro.dispatch import autotune as at
    from repro.serving import Engine, poisson_stream

    cache_path = Path(cache_path or AUTOTUNE_CACHE)
    cache_path.parent.mkdir(parents=True, exist_ok=True)
    if cache_path.exists():
        cache_path.unlink()  # measure a cold write -> warm reload cycle

    key = jax.random.PRNGKey(0)
    params = T.init_params(key, CFG)
    spec = QuantSpec(mode="msgemm", d=3)
    p, c = quantize_model(params, CFG, spec), CFG.replace(quant=spec)

    def build_and_run():
        eng = Engine(p, c, max_slots=4, block_size=8, prefill_chunk=16,
                     max_model_len=48, autotune=True,
                     autotune_cache=cache_path)
        res = eng.run(poisson_stream(4, c.vocab_size, max_new_tokens=4,
                                     seed=7))
        toks = {rid: seq.generated for rid, seq in res.items()}
        return eng, toks

    at.num_timed_candidates = 0
    eng1, toks1 = build_and_run()
    timed = at.num_timed_candidates
    n_plans = len(eng1.exec_plans)
    assert cache_path.exists() and n_plans, "autotune wrote no plans"

    at.num_timed_candidates = 0
    dispatch.set_cache_path(cache_path)  # fresh in-memory view of the file
    eng2, toks2 = build_and_run()
    assert at.num_timed_candidates == 0, \
        f"warm rebuild re-timed {at.num_timed_candidates} candidates"
    assert toks1 == toks2, "autotuned plans changed generated tokens"

    lines = ["name,us_per_call,derived",
             f"serve_throughput/autotune/cold,0.0,"
             f"plans={n_plans} candidates_timed={timed}",
             f"serve_throughput/autotune/warm,0.0,"
             f"plans={len(eng2.exec_plans)} candidates_timed=0 "
             f"tokens_identical=True",
             f"serve_throughput/autotune/json,0.0,{cache_path}"]
    return lines


def _interval_union(ivs: list) -> list:
    """Merge [start, end) intervals into a disjoint sorted union."""
    out: list = []
    for a, b in sorted(ivs):
        if out and a <= out[-1][1]:
            out[-1][1] = max(out[-1][1], b)
        else:
            out.append([a, b])
    return out


def span_overlap(events: list) -> dict:
    """Overlap attribution from a slice of trace events: how much of the
    shard.collective.* span time is wall-clock-covered by shard.compute.*
    spans.  All devices' jit-mark callbacks funnel into one host
    timeline, so the fraction includes cross-device interleave (device
    A's collective under device B's compute) as well as the pipelined
    path's intra-device overlap (chunk i's ring issued before chunk
    i+1's consume) — it measures how much collective time the schedule
    actually hid under compute, whatever the mechanism."""
    comp, coll = [], []
    for ev in events:
        if ev.get("ph") != "X":
            continue
        name = ev.get("name", "")
        iv = (float(ev["ts"]), float(ev["ts"]) + float(ev.get("dur", 0.0)))
        if name.startswith("shard.compute."):
            comp.append(iv)
        elif name.startswith("shard.collective."):
            coll.append(iv)
    comp_u, coll_u = _interval_union(comp), _interval_union(coll)
    coll_us = sum(b - a for a, b in coll_u)
    comp_us = sum(b - a for a, b in comp_u)
    overlap_us = 0.0
    for a, b in coll_u:
        for x, y in comp_u:
            lo, hi = max(a, x), min(b, y)
            if lo < hi:
                overlap_us += hi - lo
    return {"compute_us": comp_us, "collective_us": coll_us,
            "overlap_us": overlap_us,
            "overlap_fraction": overlap_us / coll_us if coll_us else 0.0}


def run_mesh_sweep(meshes: list[str], n=8, new_tokens=8,
                   trace_out=None, gate=False) -> list[str]:
    """--mesh sweep: drive the continuous engine tensor-parallel over
    each requested mesh ('model=4,data=2' strings) in TWO variants —
    one_shot (the classic consume-then-collective) and pipelined (the
    chunked contraction whose ring collective overlaps the next chunk's
    LUT consume) — assert every variant's greedy tokens are identical to
    the single-device baseline, and write throughput + plan stats +
    per-variant overlap fractions to BENCH_shard.json (schema 4).

    Tracing is always on during the sweep (the overlap fraction is
    computed from the shard.compute.* / shard.collective.* spans of each
    variant's own event slice); ``trace_out`` additionally writes the
    whole sweep's Chrome-trace file.

    ``gate`` turns the acceptance claims into a hard exit status:
    pipelined must beat one_shot on the first mesh with a non-zero
    overlap fraction, and the best mesh throughput must be >= the
    single-device engine at EQUAL PER-DEVICE batch."""
    from repro.launch.mesh import mesh_devices
    from repro.launch.serve import parse_mesh
    from repro.serving import Engine, poisson_stream

    # must precede engine builds: jit marks are staged at trace time
    obs.enable_tracing(clear=True)

    key = jax.random.PRNGKey(0)
    params = T.init_params(key, CFG)
    # d=2 / scale_block=8 keeps the packed storage shard-aligned at every
    # k_local this sweep produces, so row-parallel (k-sharded + psum)
    # plans actually form — with d=3 the d-chunk alignment guard rejects
    # them all and the sweep would only ever exercise column-parallel
    spec = QuantSpec(mode="msgemm", d=2, scale_block=8)
    p, c = quantize_model(params, CFG, spec), CFG.replace(quant=spec)
    eng_kw = dict(max_slots=4, block_size=8, prefill_chunk=16,
                  max_model_len=48)
    stream = lambda: poisson_stream(n, c.vocab_size,
                                    max_new_tokens=new_tokens, rate=0.0,
                                    seed=3)

    def drive(mesh, max_slots=None, **extra):
        kw = dict(eng_kw)
        if max_slots is not None:
            kw["max_slots"] = max_slots
        eng = Engine(p, c, **kw, mesh=mesh, **extra)
        eng.run(poisson_stream(2, c.vocab_size, max_new_tokens=2, seed=1))
        eng.reset_metrics()
        jax.effects_barrier()  # settle warmup's jit-mark callbacks
        ev0 = len(obs.tracer().events())
        res = eng.run(stream())
        jax.effects_barrier()  # flush the measured run's callbacks
        events = obs.tracer().events()[ev0:]
        toks = {rid: seq.generated for rid, seq in res.items()}
        return (eng, toks,
                {**eng.summary(), "queue_depth": _queue_depth()}, events)

    _, base_toks, base_s, _ = drive(None)
    lines = ["name,us_per_call,derived",
             f"serve_throughput/shard/baseline,"
             f"{1e6 / base_s['tok_per_s']:.1f},"
             f"tok_per_s={base_s['tok_per_s']:.1f}"]

    # equal per-device batch: a mesh with data-axis size D steps D
    # per-device rows for every max_slots global rows, so the fair
    # single-device bar runs max_slots // D slots
    def data_size(mesh):
        return int(dict(mesh.shape).get("data", 1))

    per_dev_base: dict[str, dict] = {}
    for mesh_str in meshes:
        dsz = data_size(parse_mesh(mesh_str))
        slots = max(1, eng_kw["max_slots"] // dsz)
        key_ = str(dsz)
        if key_ in per_dev_base or dsz == 1:
            continue
        _, _, s, _ = drive(None, max_slots=slots)
        per_dev_base[key_] = {"max_slots": slots, **s}
        lines.append(
            f"serve_throughput/shard/baseline_slots{slots},"
            f"{1e6 / s['tok_per_s']:.1f},"
            f"tok_per_s={s['tok_per_s']:.1f} (equal per-device batch "
            f"for data={dsz})")

    # pipelined = shard_pipeline=0: the autotuner times the variant grid
    # per row-parallel linear (cold, into a dedicated cache) and the
    # engine replays the per-linear winners — forcing one global chunk
    # count would mix winners and losers, which is exactly what the
    # variant table exists to avoid
    vcache = SHARD_JSON.parent / "shard_variant_cache.json"
    if vcache.exists():
        vcache.unlink()
    VARIANTS = (("one_shot", dict()),
                ("pipelined", dict(shard_pipeline=0,
                                   autotune_cache=vcache)))
    runs = []
    for mesh_str in meshes:
        mesh = parse_mesh(mesh_str)
        for vname, vkw in VARIANTS:
            eng, toks, s, events = drive(mesh, **vkw)
            identical = toks == base_toks
            n_sharded = sum(1 for pl in eng.exec_plans.values()
                            if pl.shard is not None)
            n_piped = sum(1 for pl in eng.exec_plans.values()
                          if pl.shard is not None and pl.shard.is_pipelined)
            ov = span_overlap(events)
            winners = sorted({f"{pl.shard.pipeline_chunks}."
                              f"{pl.shard.collective_impl}"
                              for pl in eng.exec_plans.values()
                              if pl.shard is not None
                              and pl.shard.k is not None})
            runs.append({"mesh": mesh_str, "devices": mesh_devices(mesh),
                         "variant": vname,
                         "shard_pipeline": vkw.get("shard_pipeline", 1),
                         "shard_impl": vkw.get("shard_impl", "xla"),
                         "variant_winners": winners,
                         "tokens_identical": identical,
                         "plans": len(eng.exec_plans),
                         "sharded_plans": n_sharded,
                         "pipelined_plans": n_piped,
                         "overlap": ov, **s})
            lines.append(
                f"serve_throughput/shard/{mesh_str}/{vname},"
                f"{1e6 / s['tok_per_s']:.1f},"
                f"tok_per_s={s['tok_per_s']:.1f} sharded_plans={n_sharded} "
                f"pipelined_plans={n_piped} "
                f"overlap={ov['overlap_fraction']:.3f} "
                f"tokens_identical={identical}")
            if not identical:
                raise SystemExit(
                    f"sharded engine on mesh {mesh_str} ({vname}) diverged "
                    "from the single-device baseline")
    SHARD_JSON.parent.mkdir(parents=True, exist_ok=True)
    SHARD_JSON.write_text(json.dumps(
        {"bench": "serve_shard", "schema_version": BENCH_SERVE_SCHEMA,
         "engine": eng_kw,
         "model": {"layers": CFG.num_layers, "d_model": CFG.d_model},
         "requests": n, "new_tokens": new_tokens,
         "host_cores": os.cpu_count(),
         "baseline": base_s, "per_device_baselines": per_dev_base,
         "runs": runs}, indent=2))
    lines.append(f"serve_throughput/shard/json,0.0,{SHARD_JSON}")
    if trace_out:
        obs.tracer().save(trace_out)
        lines.append(f"serve_throughput/shard/trace,0.0,{trace_out}")
    obs.disable_tracing()
    if gate:
        lines += _gate_mesh_sweep(meshes[0], runs, per_dev_base)
    return lines


def _gate_mesh_sweep(gate_mesh: str, runs: list, per_dev_base: dict
                     ) -> list[str]:
    """The CI regression gate over a finished sweep (SystemExit -> exit
    1 on any failed claim):

    1. on ``gate_mesh`` the pipelined variant beats one_shot (tok/s);
    2. the winning pipelined run overlapped compute with its collectives
       (overlap_fraction > 0) — the trace proves the mechanism, not just
       the outcome;
    3. some mesh run reaches the single-device engine at equal
       per-device batch (the ROADMAP 'mesh serving pays for itself'
       bar).  The bar is scaled by the host's attainable parallel
       fraction min(1, cores / mesh devices): a host that multiplexes V
       fake devices onto C < V cores executes the mesh's per-device
       programs serially, so matching the unscaled single-device number
       is physically impossible there — on real accelerators (C >= V
       workers) the factor is 1 and the bar is the ROADMAP target
       verbatim.
    """
    by = {(r["mesh"], r["variant"]): r for r in runs}
    one, pipe = by[(gate_mesh, "one_shot")], by[(gate_mesh, "pipelined")]
    problems = []
    if pipe["tok_per_s"] <= one["tok_per_s"]:
        problems.append(
            f"pipelined {pipe['tok_per_s']:.2f} tok/s did not beat "
            f"one_shot {one['tok_per_s']:.2f} tok/s on {gate_mesh}")
    if pipe["overlap"]["overlap_fraction"] <= 0:
        problems.append(
            f"pipelined run on {gate_mesh} shows zero compute/collective "
            f"overlap in its trace spans")
    cores = os.cpu_count() or 1
    bar = max((b["tok_per_s"] for b in per_dev_base.values()), default=0.0)

    def adjusted_bar(r):
        return bar * min(1.0, cores / max(r["devices"], 1))

    best = max(runs, key=lambda r: r["tok_per_s"] - adjusted_bar(r))
    if per_dev_base and best["tok_per_s"] < adjusted_bar(best):
        problems.append(
            f"best mesh throughput {best['tok_per_s']:.2f} tok/s "
            f"({best['mesh']}/{best['variant']}) below the equal "
            f"per-device-batch single-device bar "
            f"{adjusted_bar(best):.2f} tok/s ({bar:.2f} x "
            f"{min(1.0, cores / max(best['devices'], 1)):.3f} attainable "
            f"on {cores} core(s))")
    if problems:
        raise SystemExit("mesh-sweep gate failed:\n  "
                         + "\n  ".join(problems))
    return [f"serve_throughput/shard/gate,0.0,passed "
            f"pipelined={pipe['tok_per_s']:.2f} "
            f"one_shot={one['tok_per_s']:.2f} "
            f"overlap={pipe['overlap']['overlap_fraction']:.3f} "
            f"best={best['tok_per_s']:.2f} "
            f"per_device_bar={adjusted_bar(best):.2f}"]


def main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--autotune", action="store_true",
                    help="exercise build-time plan autotuning + the "
                         "persistent cache write->reload cycle")
    ap.add_argument("--cache", default=None,
                    help=f"plan-cache path (default {AUTOTUNE_CACHE})")
    ap.add_argument("--mesh", action="append", default=None,
                    help="mesh sweep entry, e.g. 'model=4,data=2' "
                         "(repeatable); emits BENCH_shard.json")
    ap.add_argument("--trace-out", default=None,
                    help="with --mesh: write a Chrome-trace JSON of the "
                         "sweep (compute vs collective attribution)")
    ap.add_argument("--gate", action="store_true",
                    help="with --mesh: exit non-zero unless pipelined "
                         "beats one_shot with overlap > 0 on the first "
                         "mesh AND the best mesh matches the "
                         "single-device engine at equal per-device batch")
    ap.add_argument("--force-host-devices", type=int, default=0,
                    help="fake N host CPU devices (must be set before "
                         "jax touches the backend)")
    args = ap.parse_args(argv)
    from repro.launch.mesh import force_host_devices

    force_host_devices(args.force_host_devices)
    if args.mesh:
        lines = run_mesh_sweep(args.mesh, trace_out=args.trace_out,
                               gate=args.gate)
    elif args.autotune:
        lines = run_autotune(args.cache)
    else:
        lines = run()
    print("\n".join(lines))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
