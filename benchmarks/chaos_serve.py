"""Chaos serving benchmark: drive the continuous-batching engine through
every fault class in ``repro.faults.CLASSES`` (deterministic, seeded
schedules) and measure what recovery actually costs.

Per serving fault class the benchmark runs a Poisson request stream on a
fresh msgemm-quantized engine with only that class armed, against a
fault-free reference run of the *same* stream, and asserts the ISSUE's
acceptance contract:

* ``latency`` / ``oom`` / ``step_fail`` / ``disconnect`` — every
  surviving request is **token-identical** to the reference (retries
  re-run from the paged-KV state; preemption re-prefill is exact),
* ``nan_logits`` / ``hang`` — the poisoned/stalled work is quarantined
  or replanned (counted), everything else terminates cleanly,
* every request reaches a terminal status; no exception escapes
  ``Engine.step()``/``run()``.

Artifact classes (``corrupt_plan_cache`` / ``corrupt_calibration`` /
``corrupt_checkpoint``) corrupt the real on-disk artifact through the
armed fault site and assert quarantine-and-rebuild: the reader counts
``artifact_quarantined_total``, moves the corpse aside, and the next
write/read round-trips cleanly.

A final combined run arms all serving classes at once under a deadline +
bounded queue and reports SLO attainment and shed rate.

Results go to ``benchmarks/results/BENCH_chaos.json`` and the process
exits non-zero if any class crashed or violated its contract — CI runs
``python -m benchmarks.chaos_serve --faults all --fault-seed 0``.
"""

from __future__ import annotations

import argparse
import json
import sys
import tempfile
import time
import traceback
from pathlib import Path

import jax
import numpy as np

from repro import dispatch, faults, obs
from repro.core.spec import QuantSpec
from repro.distributed.watchdog import Watchdog
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.quant import quantize_model
from repro.serving import Engine, poisson_stream

RESULTS_JSON = Path(__file__).parent / "results" / "BENCH_chaos.json"

# BENCH_chaos.json schema history:
#   1 — fault-tolerant serving PR: per-class {fires, statuses,
#       token_identical, recovery_latency_s, counters}, artifact-class
#       quarantine/rebuild results, and a combined-run SLO block
BENCH_CHAOS_SCHEMA = 1

CFG = ModelConfig(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                  d_ff=128, vocab_size=211, max_seq_len=128)

SERVE_CLASSES = ("latency", "oom", "nan_logits", "step_fail", "hang",
                 "disconnect")
ARTIFACT_CLASSES = ("corrupt_plan_cache", "corrupt_calibration",
                    "corrupt_checkpoint")
# classes whose surviving requests must match the reference bit-exactly;
# nan/hang replan onto another backend whose float error (~1e-6) may
# legally flip a greedy argmax, so they assert recovery + counters
TOKEN_IDENTICAL = ("latency", "oom", "step_fail", "disconnect")

# per-class schedules tuned so each class actually fires on a short
# stream (the defaults target longer-lived servers)
SPECS = {
    "latency": "latency:p=1.0,after=2,max=3,mag=0.02",
    "oom": "oom:p=0.5,after=1,max=4",
    "nan_logits": "nan_logits:p=1.0,after=3,max=2",
    "step_fail": "step_fail:p=1.0,after=2,max=2",
    "hang": "hang:p=1.0,after=4,max=1,mag=0.1",
    "disconnect": "disconnect:p=1.0,after=2,max=1",
}


def _build_model():
    params = T.init_params(jax.random.PRNGKey(0), CFG)
    spec = QuantSpec(mode="msgemm", d=3, scale_block=36)
    return quantize_model(params, CFG, spec), CFG.replace(quant=spec)


def _engine(params, cfg, **kw):
    kw.setdefault("max_slots", 3)
    kw.setdefault("block_size", 4)
    kw.setdefault("prefill_chunk", 4)
    kw.setdefault("max_model_len", 64)
    return Engine(params, cfg, **kw)


def _stream(n, new_tokens, rate, seed):
    return poisson_stream(n, CFG.vocab_size, max_new_tokens=new_tokens,
                          rate=rate, min_prompt=3, max_prompt=12,
                          seed=seed)


def _drive(engine, reqs, plan=None):
    """engine.run() with fire-time bookkeeping: wall seconds from the
    first injected fault to the next request finishing ok after it."""
    pending = sorted(reqs, key=lambda r: (r.arrival_time, r.rid))
    results = {}
    t_fire = t_recover = None
    while pending or engine.scheduler.has_work():
        while pending and pending[0].arrival_time <= engine.now:
            req = pending.pop(0)
            seq = engine.submit(req, arrival=min(req.arrival_time,
                                                 engine.now))
            if seq.status != "ok":
                results[req.rid] = seq
        if not engine.scheduler.has_work():
            if not pending:
                break
            req = pending.pop(0)
            seq = engine.submit(req)
            if seq.status != "ok":
                results[req.rid] = seq
            continue
        done = engine.step()
        if plan is not None and t_fire is None and plan.fires() > 0:
            t_fire = time.perf_counter()
        for seq in done:
            results[seq.req.rid] = seq
            if t_fire is not None and t_recover is None \
                    and seq.status == "ok":
                t_recover = time.perf_counter()
    rec = (t_recover - t_fire
           if t_fire is not None and t_recover is not None else None)
    return results, rec


def _tokens(results):
    return {rid: list(results[rid].generated) for rid in results
            if results[rid].status == "ok"}


def _reference(params, cfg, reqs):
    obs.registry().reset(prefix="serving_")
    eng = _engine(params, cfg)
    res, _ = _drive(eng, reqs)
    assert obs.registry().gauge("faults_armed").value == 0
    assert all(s.status == "ok" for s in res.values())
    return _tokens(res)


def _chaos_class(cls, params, cfg, reqs, ref, seed):
    obs.registry().reset(prefix="serving_")
    dispatch.clear_quarantine()
    wd = None
    if cls == "hang":
        wd = Watchdog(min_steps=2, min_timeout_s=0.05)
    eng = _engine(params, cfg, watchdog=wd)
    if cls == "hang":
        # warm both phase compiles so the rolling step-time mean (and
        # with it the armed hang timer) reflects steady-state steps
        _drive(eng, [reqs[0]])
        eng.reset_metrics()
    plan = faults.arm(SPECS[cls], seed=seed)
    try:
        res, recovery = _drive(eng, reqs)
    finally:
        faults.disarm()
        dispatch.clear_quarantine()
    statuses = {rid: res[rid].status for rid in res}
    missing = [r.rid for r in reqs if r.rid not in res]
    toks = _tokens(res)
    identical = all(toks[rid] == ref[rid] for rid in toks)
    out = {
        "cls": cls,
        "fires": plan.fires(),
        "requests": len(reqs),
        "terminal": len(res),
        "statuses": sorted(statuses.values()),
        "ok": sum(1 for s in statuses.values() if s == "ok"),
        "token_identical": identical,
        "recovery_latency_s": recovery,
        "step_retries": eng.num_step_retries,
        "nan_quarantined": eng.num_nan_events,
        "replans": eng.num_replans,
        "shed": eng.num_shed,
        "preempt_thrash": eng.scheduler.num_thrash,
    }
    errs = []
    if plan.fires() == 0:
        errs.append("fault never fired")
    if missing:
        errs.append(f"requests never terminated: {missing}")
    if cls in TOKEN_IDENTICAL and not identical:
        errs.append("surviving requests diverged from reference")
    if cls in ("latency", "oom", "step_fail") and out["ok"] != len(reqs):
        errs.append(f"expected full recovery, got {statuses}")
    if cls == "nan_logits" and eng.num_nan_events == 0:
        errs.append("nan guard never quarantined")
    if cls == "hang" and (wd.hang_count == 0 or eng.num_replans == 0):
        errs.append(f"hang not escalated (hangs={wd.hang_count}, "
                    f"replans={eng.num_replans})")
    if cls == "disconnect" and "disconnected" not in statuses.values():
        errs.append("disconnect victim not recorded")
    out["errors"] = errs
    return out


def _counter(artifact):
    """Sum of artifact_quarantined_total across ``reason`` labels."""
    return sum(s.value for s in obs.registry().series("counter")
               if s.name == "artifact_quarantined_total"
               and s.labels.get("artifact") == artifact)


def _chaos_plan_cache(tmp, seed):
    path = Path(tmp) / "plans.json"
    dispatch.set_cache_path(path)
    plan = dispatch.ExecPlan(backend="msgemm_jnp")
    before = _counter("plan_cache")
    faults.arm("corrupt_plan_cache", seed=seed)
    try:
        dispatch.cache().put("chaos|test", plan)  # save() -> corrupted
    finally:
        faults.disarm()
    reloaded = dispatch.set_cache_path(path)  # fresh cache object
    n_after_corrupt = len(reloaded)  # load quarantines, rebuilds empty
    quarantined = _counter("plan_cache") - before
    dispatch.cache().put("chaos|test", plan)  # rebuild
    n_rebuilt = len(dispatch.set_cache_path(path))
    dispatch.set_cache_path(None)
    errs = []
    if n_after_corrupt != 0:
        errs.append("corrupt cache served plans")
    if quarantined < 1:
        errs.append("corrupt cache not quarantined")
    if n_rebuilt != 1:
        errs.append("cache did not rebuild")
    return {"cls": "corrupt_plan_cache", "fires": 1,
            "quarantined": quarantined, "rebuilt": n_rebuilt == 1,
            "errors": errs}


def _chaos_calibration(tmp, seed):
    from repro.obs import perfmodel as pm

    path = Path(tmp) / "calibration.json"
    device, interpret = pm.current_partition()
    cal = pm.Calibration(device=device, interpret=interpret,
                         constants={"*": {"launch_s": 1e-6, "step_s": 1e-8,
                                          "produce_s_per_flop": 1e-9,
                                          "consume_s_per_op": 1e-9,
                                          "hbm_s_per_byte": 1e-10}},
                         fit={"n_samples": 4})
    before = _counter("calibration")
    faults.arm("corrupt_calibration", seed=seed)
    try:
        cal.save(path)
    finally:
        faults.disarm()
    corrupt_load = pm.load_calibration(path)  # quarantines, returns None
    quarantined = _counter("calibration") - before
    cal.save(path)  # rebuild
    ok_load = pm.load_calibration(path)
    errs = []
    if corrupt_load is not None:
        errs.append("corrupt calibration loaded")
    if quarantined < 1:
        errs.append("corrupt calibration not quarantined")
    if ok_load is None:
        errs.append("calibration did not rebuild")
    return {"cls": "corrupt_calibration", "fires": 1,
            "quarantined": quarantined, "rebuilt": ok_load is not None,
            "errors": errs}


def _chaos_checkpoint(tmp, seed):
    from repro.checkpoint import CheckpointManager

    mgr = CheckpointManager(str(Path(tmp) / "ckpt"), keep=3)
    tree = {"w": np.arange(12, dtype=np.float32).reshape(3, 4),
            "b": np.ones((4,), np.float32)}
    mgr.save(1, tree)
    before = _counter("checkpoint")
    # the next save's publish gets corrupted (armed only around it)
    faults.arm("corrupt_checkpoint", seed=seed)
    try:
        mgr.save(2, tree)
    finally:
        faults.disarm()
    step, restored = mgr.restore_latest(tree)
    quarantined = _counter("checkpoint") - before
    errs = []
    if step != 1:
        errs.append(f"restore_latest fell back to {step}, expected 1")
    if quarantined < 1:
        errs.append("corrupt checkpoint not quarantined")
    if restored is None or not np.array_equal(restored["w"], tree["w"]):
        errs.append("restored tree does not match")
    return {"cls": "corrupt_checkpoint", "fires": 1,
            "quarantined": quarantined,
            "rebuilt": step == 1 and restored is not None,
            "errors": errs}


def _chaos_combined(params, cfg, reqs, seed):
    """All serving classes at once, under a deadline and a bounded
    queue: the server must stay up and every request must reach a
    terminal status — finished, shed, or cleanly cancelled."""
    obs.registry().reset(prefix="serving_")
    dispatch.clear_quarantine()
    spec = ";".join(SPECS[c] for c in SERVE_CLASSES)
    eng = _engine(params, cfg, max_queue=8, deadline_s=30.0,
                  watchdog=Watchdog(min_steps=2, min_timeout_s=0.05))
    _drive(eng, [reqs[0]])
    eng.reset_metrics()
    plan = faults.arm(spec, seed=seed)
    try:
        res, recovery = _drive(eng, reqs)
    finally:
        faults.disarm()
        dispatch.clear_quarantine()
    statuses = [res[rid].status for rid in sorted(res)]
    missing = [r.rid for r in reqs if r.rid not in res]
    ok = sum(1 for s in statuses if s == "ok")
    m = eng.metrics()
    errs = []
    if missing:
        errs.append(f"requests never terminated: {missing}")
    if plan.fires() == 0:
        errs.append("combined plan never fired")
    out = {
        "cls": "combined", "fires": plan.fires(), "requests": len(reqs),
        "terminal": len(res), "statuses": sorted(statuses), "ok": ok,
        "slo_attainment": ok / len(reqs) if reqs else 1.0,
        "shed_rate": m["shed"] / len(reqs) if reqs else 0.0,
        "recovery_latency_s": recovery,
        "step_retries": m["step_retries"], "replans": m["replans"],
        "nan_quarantined": m["nan_quarantined"],
        "errors": errs,
    }
    return out


def run(*, fault_spec="all", seed=0, n_requests=4, new_tokens=6,
        rate=0.0) -> tuple[list[str], dict]:
    params, cfg = _build_model()
    reqs = _stream(n_requests, new_tokens, rate, seed=1)
    picked = (list(SERVE_CLASSES) + list(ARTIFACT_CLASSES)
              if fault_spec == "all"
              else [s.cls for s in faults.parse_spec(fault_spec)])
    lines = ["name,us_per_call,derived"]
    ref = _reference(params, cfg, reqs)
    rows, crashed = [], []
    with tempfile.TemporaryDirectory(prefix="chaos_") as tmp:
        for cls in picked:
            try:
                if cls == "corrupt_plan_cache":
                    row = _chaos_plan_cache(tmp, seed)
                elif cls == "corrupt_calibration":
                    row = _chaos_calibration(tmp, seed)
                elif cls == "corrupt_checkpoint":
                    row = _chaos_checkpoint(tmp, seed)
                else:
                    row = _chaos_class(cls, params, cfg, reqs, ref, seed)
            except Exception:
                faults.disarm()
                dispatch.clear_quarantine()
                row = {"cls": cls, "errors":
                       [f"CRASH: {traceback.format_exc(limit=8)}"]}
            rows.append(row)
            if row["errors"]:
                crashed.append(cls)
            rec = row.get("recovery_latency_s")
            lines.append(
                f"chaos/{cls},{(rec or 0.0) * 1e6:.1f},"
                f"fires={row.get('fires', 0)} ok={row.get('ok', '-')} "
                f"errors={len(row['errors'])}")
        if fault_spec == "all" and not crashed:
            try:
                row = _chaos_combined(params, cfg, reqs, seed)
            except Exception:
                faults.disarm()
                dispatch.clear_quarantine()
                row = {"cls": "combined", "errors":
                       [f"CRASH: {traceback.format_exc(limit=8)}"]}
            rows.append(row)
            if row["errors"]:
                crashed.append("combined")
            lines.append(
                f"chaos/combined,0.0,"
                f"slo={row.get('slo_attainment', 0):.2f} "
                f"shed_rate={row.get('shed_rate', 0):.2f} "
                f"errors={len(row['errors'])}")
    doc = {"schema_version": BENCH_CHAOS_SCHEMA, "fault_seed": seed,
           "requests": n_requests, "new_tokens": new_tokens, "rate": rate,
           "classes": rows, "failed_classes": crashed}
    return lines, doc


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--faults", default="all",
                    help="'all' or a repro.faults spec string naming the "
                         "classes to chaos-test")
    ap.add_argument("--fault-seed", type=int, default=0)
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=6)
    ap.add_argument("--rate", type=float, default=0.0,
                    help="Poisson arrival rate (req/s; <=0 all at t=0)")
    ap.add_argument("--json", default=str(RESULTS_JSON))
    args = ap.parse_args(argv)

    lines, doc = run(fault_spec=args.faults, seed=args.fault_seed,
                     n_requests=args.requests, new_tokens=args.new_tokens,
                     rate=args.rate)
    print("\n".join(lines))
    out = Path(args.json)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(doc, indent=1))
    print(f"wrote {out}")
    if doc["failed_classes"]:
        for row in doc["classes"]:
            for e in row["errors"]:
                print(f"FAIL {row['cls']}: {e}", file=sys.stderr)
        return 1
    print(f"chaos: {len(doc['classes'])} classes survived, "
          f"0 contract violations")
    return 0


if __name__ == "__main__":
    sys.exit(main())
