"""Kernel-level microbenchmark: the reordered produce-amortized msgemm
kernel vs the legacy formulation, plus fused-vs-unfused epilogues.

Emits ``benchmarks/results/BENCH_kernels.json`` so the repo has a
kernel-level perf trajectory across PRs:

* per shape: wall time of the new kernel (``acc_in_vmem=True`` — m
  innermost, LUT produced once per (b, j) into VMEM scratch, single HBM
  writeback) vs the legacy kernel (j innermost, produce re-run every
  m-tile, ``y_ref +=`` per step), and the **produce-amortization
  factor** — the number of m-tiles sharing one produce, i.e. how many
  times the legacy grid re-computed the LUT dot;
* per shape: the fused epilogue (gelu + residual inside the final
  writeback) vs the same kernel plus separate jnp elementwise ops (what
  model code used to issue);
* a **parity gate**: on exactly representable inputs the new kernel's
  identity-epilogue output must be bit-identical to ``kernels/ref.py`` —
  the process exits non-zero if it is not (CI fails the job).

Run::

    PYTHONPATH=src python benchmarks/kernel_microbench.py --smoke

``--smoke`` uses the small shape set + 2 reps (the CI configuration);
the default set adds larger shapes for real-hardware runs.
"""

from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import numpy as np

RESULTS = Path(__file__).parent / "results"

# BENCH_kernels.json schema history:
#   (unversioned) — PR 4: per-shape new/legacy/epilogue timings + parity
#   2 — PR 6: adds schema_version, and per shape a "roofline" block
#       (produce/consume op split, bytes moved, attainable_s,
#       roofline_fraction, hardware model) from the obs.costs model
BENCH_KERNELS_SCHEMA = 2

# name, d, scale_block, m, k, b — decode shapes are the tall-skinny
# (large-m, small-b) cells where the legacy grid's produce re-computation
# dominated; prefill is the wide-batch sanity cell.
SMOKE_SHAPES = [
    ("decode_m2048_k768_b8", 3, 12, 2048, 768, 8),
    ("decode_m2048_k768_b1", 3, 12, 2048, 768, 1),
    ("decode_m4096_k768_b8", 3, 12, 4096, 768, 8),
    ("prefill_m512_k768_b128", 3, 12, 512, 768, 128),
]
FULL_SHAPES = SMOKE_SHAPES + [
    ("decode_m8192_k1024_b8", 3, 12, 8192, 1024, 8),
    ("prefill_m2048_k2048_b256", 3, 12, 2048, 2048, 256),
]


def _bench(fn, reps: int) -> float:
    import jax

    jax.block_until_ready(fn())  # compile + warm
    best = float("inf")
    for _ in range(max(reps, 1)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def _parity_bitexact(d: int, sb: int, m: int, k: int, b: int) -> bool:
    """Identity-epilogue bit-identity vs kernels/ref.py on exactly
    representable inputs (every sum/product exact -> codegen-ulp-free)."""
    import jax.numpy as jnp

    from repro.core import packing
    from repro.kernels import ops, ref

    rng = np.random.default_rng(m + k + b)
    codes = jnp.asarray(rng.integers(0, 16, size=(m, k)), jnp.uint8)
    x = jnp.asarray(rng.integers(-4, 5, size=(k, b)), jnp.float32)
    sc = jnp.asarray(2.0 ** rng.integers(-2, 3, size=(m, -(-k // sb))),
                     jnp.float32)
    got = np.asarray(ops.msgemm(codes, x, d, scales=sc, scale_block=sb))
    want = np.asarray(ref.msgemm_ref(packing.pack_indices(codes, d), x, sc,
                                     d=d, scale_block=sb))
    return bool(np.array_equal(got, want))


def run(shapes=None, reps: int = 2) -> dict:
    import jax
    import jax.numpy as jnp

    from repro.core.epilogue import Epilogue
    from repro.kernels import ops

    shapes = shapes or SMOKE_SHAPES
    rng = np.random.default_rng(0)
    rows = []
    for name, d, sb, m, k, b in shapes:
        codes = jnp.asarray(rng.integers(0, 16, size=(m, k)), jnp.uint8)
        x = jnp.asarray(rng.standard_normal((k, b)), jnp.float32)
        sc = jnp.asarray(
            np.abs(rng.standard_normal((m, -(-k // sb)))) + 0.1, jnp.float32)
        tm, tj, tb = ops.msgemm_tiles(m, -(-k // d), b, d, sb)
        amort = -(-m // tm)  # m-tiles sharing one produce

        # every timed closure is one jitted program, so the comparison
        # measures the kernels — not eager pad/dispatch overhead
        t_new = _bench(jax.jit(lambda: ops.msgemm(
            codes, x, d, scales=sc, scale_block=sb)), reps)
        t_old = _bench(jax.jit(lambda: ops.msgemm(
            codes, x, d, scales=sc, scale_block=sb, acc_in_vmem=False)),
            reps)

        ep = Epilogue(act="gelu", residual=True)
        res = jnp.asarray(rng.standard_normal((m, b)), jnp.float32)
        t_fused = _bench(jax.jit(lambda: ops.msgemm(
            codes, x, d, scales=sc, scale_block=sb, epilogue=ep,
            residual=res)), reps)

        # fair baseline: the old model-side elementwise tail inside one
        # jit with the kernel call, exactly like pre-overhaul model code
        @jax.jit
        def unfused():
            y = ops.msgemm(codes, x, d, scales=sc, scale_block=sb)
            return jax.nn.gelu(y) + res

        t_unfused = _bench(unfused, reps)
        parity = _parity_bitexact(d, sb, m, k, b)
        from repro.obs import costs

        ann = costs.annotate(t_new, m, k, b, quant="msgemm", d=d)
        roofline = {f: ann[f] for f in
                    ("produce_flops", "consume_ops", "flops", "bytes",
                     "attainable_s", "roofline_fraction", "hardware")}
        rows.append({
            "shape": name, "d": d, "scale_block": sb, "m": m, "k": k, "b": b,
            "tiles": {"tm": tm, "tj": tj, "tb": tb},
            "produce_amortization_factor": amort,
            "new_kernel_s": t_new, "legacy_kernel_s": t_old,
            "speedup_new_vs_legacy": t_old / t_new,
            "epilogue_fused_s": t_fused, "epilogue_unfused_s": t_unfused,
            "epilogue_fusion_speedup": t_unfused / t_fused,
            "identity_parity_bitexact_vs_ref": parity,
            "roofline": roofline,
        })
        print(f"[kernels] {name}: amort={amort} "
              f"new={t_new * 1e3:.1f}ms legacy={t_old * 1e3:.1f}ms "
              f"({t_old / t_new:.2f}x) epilogue fused/unfused="
              f"{t_unfused / t_fused:.2f}x "
              f"roofline={roofline['roofline_fraction']:.3g} "
              f"parity={'OK' if parity else 'FAIL'}")

    decode = [r for r in rows if r["shape"].startswith("decode")]
    out = {
        "schema_version": BENCH_KERNELS_SCHEMA,
        "device": jax.default_backend(),
        "interpret": jax.default_backend() != "tpu",
        "reps": reps,
        "shapes": rows,
        "all_new_beat_legacy": all(
            r["speedup_new_vs_legacy"] > 1.0 for r in rows),
        "decode_min_speedup": min(
            (r["speedup_new_vs_legacy"] for r in decode), default=None),
        "parity_all_bitexact": all(
            r["identity_parity_bitexact_vs_ref"] for r in rows),
    }
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--smoke", action="store_true",
                    help="small shape set + 2 reps (the CI configuration)")
    ap.add_argument("--reps", type=int, default=None)
    ap.add_argument("--out", default=None,
                    help="output JSON path (default: "
                         "benchmarks/results/BENCH_kernels.json)")
    args = ap.parse_args(argv)
    shapes = SMOKE_SHAPES if args.smoke else FULL_SHAPES
    reps = args.reps if args.reps is not None else (2 if args.smoke else 3)
    out = run(shapes=shapes, reps=reps)
    path = Path(args.out) if args.out else RESULTS / "BENCH_kernels.json"
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(out, indent=1))
    print(f"[kernels] wrote {path}")
    if not out["parity_all_bitexact"]:
        print("[kernels] FAIL: identity-epilogue parity vs kernels/ref.py "
              "regressed")
        return 1
    if not out["all_new_beat_legacy"]:
        print("[kernels] WARNING: reordered kernel lost to legacy on some "
              "shape (see JSON)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
