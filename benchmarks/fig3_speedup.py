"""Paper Fig. 3 reproduction: msGeMM speedup vs LUT depth d for the GPT-3
MLP GeMMs (Eqs. 16-21), plus the instrumented-execution cross-check.

Claim validation (EXPERIMENTS.md §Claims):
* Eq. 21 (MLP2, m=49152, k=12288): d=3 -> 2.40x  — the "~2.5x" headline.
* Eq. 18 (MLP1, m=12288, k=49152): d=3 -> 1.50x, peak 1.92x at d=2 — the
  figure's "~2.5x for both" wording is inconsistent with its own Eq. 18;
  the large-m orientation is what reaches ~2.5x (consistent with the
  paper's "the larger the number of rows the better" observation).
* d >= 5 collapses (exponential 16^d LUT cost) — "d cannot be larger
  than 4" (§5).
"""

from __future__ import annotations

import numpy as np

from repro.core import complexity as C

GPT3_MLPS = {
    "MLP1 (12288x49152)": (12288, 49152),
    "MLP2 (49152x12288)": (49152, 12288),
}


def rows():
    out = []
    for name, (m, k) in GPT3_MLPS.items():
        for d in range(1, 7):
            if k % d:
                k_eff = -(-k // d) * d
            else:
                k_eff = k
            out.append({
                "gemm": name, "d": d,
                "speedup_eq15": C.speedup(m, k_eff, 1, d),
                "c_gemm": C.c_gemm(m, k_eff),
                "c_msgemm": C.c_msgemm(m, k_eff, 1, d),
            })
    return out


def instrumented_check():
    """Tiny-shape instrumented execution: counted ops match Eqs. 7/9/13."""
    rng = np.random.default_rng(0)
    m, k, d = 64, 24, 2
    codes = rng.integers(0, 16, size=(m, k)).astype(np.uint8)
    x = rng.standard_normal(k)
    _, cnt = C.counted_msgemm(codes, x, d)
    _, gcnt = C.counted_gemm(rng.standard_normal((m, k)), x)
    return {
        "counted_total": cnt.total_compute,
        "eq13": C.c_msgemm(m, k, 1, d),
        "counted_gemm": gcnt.fma,
        "eq14": C.c_gemm(m, k),
        "measured_speedup": gcnt.fma / cnt.total_compute,
        "eq15_speedup": C.speedup(m, k, 1, d),
    }


def run() -> list[str]:
    lines = ["name,us_per_call,derived"]
    for r in rows():
        lines.append(
            f"fig3/{r['gemm']}/d={r['d']},0.0,speedup={r['speedup_eq15']:.3f}")
    chk = instrumented_check()
    lines.append(
        f"fig3/instrumented_check,0.0,"
        f"counted={chk['counted_total']} eq13={chk['eq13']} "
        f"speedup={chk['measured_speedup']:.3f} eq15={chk['eq15_speedup']:.3f}")
    # headline claims
    mlp2_d3 = C.speedup(49152, 12288, 1, 3)
    mlp1_d3 = C.speedup(12288, 49152, 1, 3)
    lines.append(f"fig3/claim_2.5x_mlp2_d3,0.0,speedup={mlp2_d3:.3f}"
                 f" validated={2.3 < mlp2_d3 < 2.7}")
    lines.append(f"fig3/claim_mlp1_d3,0.0,speedup={mlp1_d3:.3f}"
                 f" note=eq18_gives_1.50_not_2.5")
    return lines
