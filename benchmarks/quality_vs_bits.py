"""Quality-vs-bits sweep: {uniform int4, learned codebook, bf16} x
{LUT depth d, scale block} on a small trained LM — plus the same
question asked of the *KV cache*: kv_bits in {16, 8, 4} x codebook in
{uniform, learned} through the paged serving path (repro.kvq).

For every (d, scale_block) cell both 4-bit variants ship identical bit
widths and identical kernels — the learned codebook only changes the
16-entry value table — so any quality gap is pure calibration win.
Records weighted quantization error (the calib fitting objective),
perplexity, logit MSE and top-1 agreement vs the bf16 reference to
``benchmarks/results/BENCH_quality.json``.

BENCH_quality.json schema history:
  (unversioned) — PR 3+: weight sweep only ("sweep" list)
  2 — adds schema_version and "kv_sweep": per-KV-variant quality metrics
      (calib.quality.compare_kv), KV reconstruction errors, and the
      kv4-learned perplexity budget check

    PYTHONPATH=src python benchmarks/quality_vs_bits.py [--steps 60]
"""

from __future__ import annotations

import argparse
import functools
import json
from pathlib import Path

import jax

from repro import calib
from repro.core.spec import QuantSpec
from repro.data import DataConfig, SyntheticStream
from repro.models.config import ModelConfig
from repro.optim import AdamWConfig, schedules
from repro.quant import quantize_model
from repro.runtime import train as RT

RESULTS_JSON = Path(__file__).parent / "results" / "BENCH_quality.json"
BENCH_QUALITY_SCHEMA = 2

CFG = ModelConfig(name="quality-bench", num_layers=3, d_model=96,
                  num_heads=6, num_kv_heads=2, d_ff=288, vocab_size=384,
                  max_seq_len=128, remat=False)

# Documented quality budget for 4-bit learned-codebook KV (README
# §Quantized KV cache): perplexity through the quantized-KV paged path
# must stay within this multiple of the bf16-KV reference on the bench
# corpus.  Measured headroom on this model is ~1.02x; the budget leaves
# slack for seed/model variation without ever letting a broken code map
# (which lands at 2x+) slip through.
KV4_PPL_BUDGET = 1.25
SWEEP = [  # (d, scale_block) — §3.3 requires d | scale_block
    (2, 24),
    (3, 24),
    (3, 48),
]


def train_reference(steps: int):
    data = SyntheticStream(DataConfig(vocab_size=CFG.vocab_size, seq_len=49,
                                      global_batch=16, mode="lcg"))
    tcfg = RT.TrainConfig(optimizer=AdamWConfig(
        lr=schedules.warmup_cosine(1e-2, 10, steps)))
    state = RT.init_state(jax.random.PRNGKey(0), CFG, tcfg)
    step_fn = jax.jit(functools.partial(RT.train_step, cfg=CFG, tcfg=tcfg),
                      donate_argnums=(0,))
    for step in range(steps):
        state, metrics = step_fn(state, batch=data.device_batch(step))
    print(f"reference trained {steps} steps, "
          f"final loss {float(metrics['loss']):.3f}")
    return state["params"], data


def run(steps: int) -> dict:
    params, data = train_reference(steps)
    results = {"config": {"model": CFG.name, "train_steps": steps},
               "sweep": []}
    for d, scale_block in SWEEP:
        quant = QuantSpec(mode="msgemm", d=d, scale_block=scale_block)
        res = calib.calibrate(params, CFG, data,
                              calib.Recipe(calib_steps=2, kmeans_iters=15),
                              quant=quant)
        qcfg = CFG.replace(quant=res.quant)
        uniform = quantize_model(params, CFG, res.quant)
        quality = calib.quality.compare(
            params, CFG,
            {"uniform_int4": (uniform, qcfg),
             "learned_codebook": (res.params, qcfg)},
            data, steps=2)
        agg = res.report["aggregate"]
        cell = {
            "d": d,
            "scale_block": scale_block,
            "weighted_quant_err": {
                "uniform_int4": agg["uniform_weighted_err"],
                "learned_codebook": agg["learned_weighted_err"],
            },
            "quality": quality,
        }
        results["sweep"].append(cell)
        won = (agg["learned_weighted_err"] < agg["uniform_weighted_err"])
        print(f"d={d} block={scale_block}: weighted err "
              f"{agg['uniform_weighted_err']:.3e} -> "
              f"{agg['learned_weighted_err']:.3e} "
              f"({'learned wins' if won else 'UNIFORM WINS'}); ppl "
              f"bf16={quality['bf16']['perplexity']:.2f} "
              f"uniform={quality['uniform_int4']['perplexity']:.2f} "
              f"learned={quality['learned_codebook']['perplexity']:.2f}")
    ok = all(c["weighted_quant_err"]["learned_codebook"]
             < c["weighted_quant_err"]["uniform_int4"]
             for c in results["sweep"])
    results["learned_strictly_better_everywhere"] = ok
    results["schema_version"] = BENCH_QUALITY_SCHEMA
    results["kv_sweep"] = kv_sweep(params, data)
    return results


def kv_sweep(params, data, *, steps: int = 2) -> dict:
    """KV-storage quality: kv_bits {16, 8, 4} x codebook {uniform,
    learned} through the paged serving path, vs the dense bf16-KV
    forward with the *same* (unquantized) weights — so every delta is
    attributable to KV storage alone.  The learned codebook is fitted on
    the same batches it is evaluated on, making Lloyd's monotonicity a
    hard guarantee for the reconstruction-error gate."""
    from repro import kvq
    from repro.calib.stats import batches_from
    from repro.kvq.fit import kv_reconstruction_error

    batches = batches_from(data, steps)
    cb = kvq.fit_kv_codebook(params, CFG, batches)
    variants = {
        "kv16": None,
        "kv8": kvq.KVQuantSpec(bits=8),
        "kv4_uniform": kvq.KVQuantSpec(bits=4),
        "kv4_learned": kvq.KVQuantSpec(bits=4, codebook=cb),
    }
    quality = calib.quality.compare_kv(params, CFG, variants, data,
                                       steps=steps)
    recon = {name: kv_reconstruction_error(params, CFG, batches, spec)
             for name, spec in variants.items() if spec is not None
             and spec.bits == 4}
    ppl_ref = quality["bf16_kv"]["perplexity"]
    ppl_kv4 = quality["kv4_learned"]["perplexity"]
    out = {
        "codebook": list(cb),
        "quality": quality,
        "reconstruction_mse": recon,
        "kv4_ppl_budget": KV4_PPL_BUDGET,
        "kv4_ppl_ratio": ppl_kv4 / ppl_ref,
        "learned_recon_le_uniform":
            recon["kv4_learned"] <= recon["kv4_uniform"],
        "kv4_within_budget": ppl_kv4 <= KV4_PPL_BUDGET * ppl_ref,
    }
    for name in ("bf16_kv", "kv16", "kv8", "kv4_uniform", "kv4_learned"):
        q = quality[name]
        print(f"kv {name:12s}: ppl={q['perplexity']:.3f} "
              f"logit_mse={q['logit_mse']:.3e} top1={q['top1_agree']:.3f}")
    print(f"kv4 recon mse uniform={recon['kv4_uniform']:.4e} "
          f"learned={recon['kv4_learned']:.4e}; ppl ratio "
          f"{out['kv4_ppl_ratio']:.3f} (budget {KV4_PPL_BUDGET})")
    return out


if __name__ == "__main__":
    parser = argparse.ArgumentParser()
    parser.add_argument("--steps", type=int, default=60)
    args = parser.parse_args()
    results = run(args.steps)
    RESULTS_JSON.parent.mkdir(parents=True, exist_ok=True)
    RESULTS_JSON.write_text(json.dumps(results, indent=2) + "\n")
    print(f"\nwrote {RESULTS_JSON}")
    assert results["learned_strictly_better_everywhere"], \
        "learned codebooks must beat uniform int4 in every sweep cell"
    assert results["kv_sweep"]["learned_recon_le_uniform"], \
        "learned KV codebook must not reconstruct worse than uniform int4"
    assert results["kv_sweep"]["kv4_within_budget"], \
        "kv4 learned-codebook perplexity exceeded its documented budget"
