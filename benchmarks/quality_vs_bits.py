"""Quality-vs-bits sweep: {uniform int4, learned codebook, bf16} x
{LUT depth d, scale block} on a small trained LM.

For every (d, scale_block) cell both 4-bit variants ship identical bit
widths and identical kernels — the learned codebook only changes the
16-entry value table — so any quality gap is pure calibration win.
Records weighted quantization error (the calib fitting objective),
perplexity, logit MSE and top-1 agreement vs the bf16 reference to
``benchmarks/results/BENCH_quality.json``.

    PYTHONPATH=src python benchmarks/quality_vs_bits.py [--steps 60]
"""

from __future__ import annotations

import argparse
import functools
import json
from pathlib import Path

import jax

from repro import calib
from repro.core.spec import QuantSpec
from repro.data import DataConfig, SyntheticStream
from repro.models.config import ModelConfig
from repro.optim import AdamWConfig, schedules
from repro.quant import quantize_model
from repro.runtime import train as RT

RESULTS_JSON = Path(__file__).parent / "results" / "BENCH_quality.json"

CFG = ModelConfig(name="quality-bench", num_layers=3, d_model=96,
                  num_heads=6, num_kv_heads=2, d_ff=288, vocab_size=384,
                  max_seq_len=128, remat=False)
SWEEP = [  # (d, scale_block) — §3.3 requires d | scale_block
    (2, 24),
    (3, 24),
    (3, 48),
]


def train_reference(steps: int):
    data = SyntheticStream(DataConfig(vocab_size=CFG.vocab_size, seq_len=49,
                                      global_batch=16, mode="lcg"))
    tcfg = RT.TrainConfig(optimizer=AdamWConfig(
        lr=schedules.warmup_cosine(1e-2, 10, steps)))
    state = RT.init_state(jax.random.PRNGKey(0), CFG, tcfg)
    step_fn = jax.jit(functools.partial(RT.train_step, cfg=CFG, tcfg=tcfg),
                      donate_argnums=(0,))
    for step in range(steps):
        state, metrics = step_fn(state, batch=data.device_batch(step))
    print(f"reference trained {steps} steps, "
          f"final loss {float(metrics['loss']):.3f}")
    return state["params"], data


def run(steps: int) -> dict:
    params, data = train_reference(steps)
    results = {"config": {"model": CFG.name, "train_steps": steps},
               "sweep": []}
    for d, scale_block in SWEEP:
        quant = QuantSpec(mode="msgemm", d=d, scale_block=scale_block)
        res = calib.calibrate(params, CFG, data,
                              calib.Recipe(calib_steps=2, kmeans_iters=15),
                              quant=quant)
        qcfg = CFG.replace(quant=res.quant)
        uniform = quantize_model(params, CFG, res.quant)
        quality = calib.quality.compare(
            params, CFG,
            {"uniform_int4": (uniform, qcfg),
             "learned_codebook": (res.params, qcfg)},
            data, steps=2)
        agg = res.report["aggregate"]
        cell = {
            "d": d,
            "scale_block": scale_block,
            "weighted_quant_err": {
                "uniform_int4": agg["uniform_weighted_err"],
                "learned_codebook": agg["learned_weighted_err"],
            },
            "quality": quality,
        }
        results["sweep"].append(cell)
        won = (agg["learned_weighted_err"] < agg["uniform_weighted_err"])
        print(f"d={d} block={scale_block}: weighted err "
              f"{agg['uniform_weighted_err']:.3e} -> "
              f"{agg['learned_weighted_err']:.3e} "
              f"({'learned wins' if won else 'UNIFORM WINS'}); ppl "
              f"bf16={quality['bf16']['perplexity']:.2f} "
              f"uniform={quality['uniform_int4']['perplexity']:.2f} "
              f"learned={quality['learned_codebook']['perplexity']:.2f}")
    ok = all(c["weighted_quant_err"]["learned_codebook"]
             < c["weighted_quant_err"]["uniform_int4"]
             for c in results["sweep"])
    results["learned_strictly_better_everywhere"] = ok
    return results


if __name__ == "__main__":
    parser = argparse.ArgumentParser()
    parser.add_argument("--steps", type=int, default=60)
    args = parser.parse_args()
    results = run(args.steps)
    RESULTS_JSON.parent.mkdir(parents=True, exist_ok=True)
    RESULTS_JSON.write_text(json.dumps(results, indent=2) + "\n")
    print(f"\nwrote {RESULTS_JSON}")
    assert results["learned_strictly_better_everywhere"], \
        "learned codebooks must beat uniform int4 in every sweep cell"
